//! Live fleet dashboard state and online energy invariants.
//!
//! The batch pipeline finds waste after the fact; this module watches
//! it happen. A [`Monitor`] holds operator-declared [`Invariant`]s
//! (`--max-op-j`, `--max-window-waste-pct`, `--max-resyncs-per-min`)
//! and evaluates every snapshot a [`crate::telemetry::follow::Follower`]
//! decodes, raising a typed [`Alarm`] — persisted and published as an
//! ordinary [`Snapshot::Alarm`] NDJSON line — the moment a pair
//! regresses past a limit. A [`DashState`] folds the same snapshot
//! stream into the rolling per-pair/fleet aggregates that
//! [`crate::report::render_dash`] draws, and an [`AlarmPublisher`]
//! fans alarm lines out to subscribers over bounded drop-and-count
//! channels (optionally over TCP), so one stalled collector can never
//! backpressure the stream being measured.
//!
//! Every check is deterministic over the snapshot stream: replaying a
//! directory through a fresh [`Monitor`] raises exactly the alarms the
//! live tail raised (deduped per offending window, so an operator sees
//! one line per violation, not one per poll).

use std::collections::{BTreeMap, BTreeSet};
use std::io::Write as _;
use std::net::TcpListener;
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread;

use crate::coordinator::fleet::FleetDivergence;
use crate::stream::WindowReport;
use crate::telemetry::{Alarm, RankEntry, Snapshot};
use crate::{Error, Result};

// ---- invariants ---------------------------------------------------------

/// One operator-declared online invariant over a snapshot stream.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Invariant {
    /// No operator (label) in any emitted window may cost more than
    /// this many Joules per op, on the more expensive side. Windows
    /// without per-label findings are checked on their mean pair cost.
    MaxOpJ(f64),
    /// No emitted window may waste more than this percentage of its
    /// more expensive side's energy.
    MaxWindowWastePct(f64),
    /// No pair may recover resyncs faster than this rate per minute of
    /// stream time (a rolling 60-second window over the pair's own
    /// cumulative op time — snapshots carry no wall clock).
    MaxResyncsPerMin(f64),
}

impl Invariant {
    /// The invariant's stable name — the CLI flag without the leading
    /// dashes, carried verbatim in [`Alarm::invariant`].
    pub fn name(&self) -> &'static str {
        match self {
            Invariant::MaxOpJ(_) => "max-op-j",
            Invariant::MaxWindowWastePct(_) => "max-window-waste-pct",
            Invariant::MaxResyncsPerMin(_) => "max-resyncs-per-min",
        }
    }

    pub fn limit(&self) -> f64 {
        match self {
            Invariant::MaxOpJ(l)
            | Invariant::MaxWindowWastePct(l)
            | Invariant::MaxResyncsPerMin(l) => *l,
        }
    }
}

/// Stream-time microseconds per rolling resync-rate window.
const MINUTE_US: f64 = 60.0 * 1_000_000.0;

/// Evaluates [`Invariant`]s over a decoded snapshot stream.
///
/// Feed every snapshot (live from a follower, or post-hoc from
/// [`crate::telemetry::load_dir`]) through [`Monitor::observe`]; each
/// violation is returned once — re-observing the same window (a replay
/// after a live tail, an overlapping poll) cannot re-raise its alarm.
pub struct Monitor {
    invariants: Vec<Invariant>,
    /// Per-pair cumulative stream time (µs), advanced per window by the
    /// slower side — the denominator for per-minute rates.
    cum_time_us: BTreeMap<String, f64>,
    /// Per-pair resync positions in cumulative stream time (µs),
    /// pruned to the rolling minute.
    resync_times: BTreeMap<String, Vec<f64>>,
    /// `(pair, invariant name, window seq or resync at_ops)` already
    /// alarmed — the exactly-once guard.
    seen: BTreeSet<(String, &'static str, usize)>,
    /// Every alarm raised, in observation order.
    pub alarms: Vec<Alarm>,
}

impl Monitor {
    pub fn new(invariants: Vec<Invariant>) -> Monitor {
        Monitor {
            invariants,
            cum_time_us: BTreeMap::new(),
            resync_times: BTreeMap::new(),
            seen: BTreeSet::new(),
            alarms: Vec::new(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.invariants.is_empty()
    }

    /// Check one snapshot against every invariant; returns the alarms
    /// it newly raised (also appended to [`Monitor::alarms`]).
    pub fn observe(&mut self, snap: &Snapshot) -> Vec<Alarm> {
        let mut raised = Vec::new();
        match snap {
            Snapshot::Window { pair, report } => {
                if report.seq == WindowReport::PEEK_SEQ {
                    return raised;
                }
                *self.cum_time_us.entry(pair.clone()).or_insert(0.0) +=
                    report.time_a_us.max(report.time_b_us);
                for inv in self.invariants.clone() {
                    let alarm = match inv {
                        Invariant::MaxOpJ(limit) => check_op_j(pair, report, limit),
                        Invariant::MaxWindowWastePct(limit) => {
                            check_waste_pct(pair, report, limit)
                        }
                        Invariant::MaxResyncsPerMin(_) => None,
                    };
                    if let Some(a) = alarm {
                        self.raise(&mut raised, inv.name(), report.seq, a);
                    }
                }
            }
            Snapshot::Resync { pair, event } => {
                let now = self.cum_time_us.get(pair).copied().unwrap_or(0.0);
                let times = self.resync_times.entry(pair.clone()).or_default();
                times.push(now);
                times.retain(|&t| t >= now - MINUTE_US);
                let in_window = times.len();
                for inv in self.invariants.clone() {
                    let Invariant::MaxResyncsPerMin(limit) = inv else { continue };
                    // a stream younger than a minute is rated over the
                    // time it has actually run (floor: one window hop),
                    // so a burst at startup still alarms
                    let minutes = if now <= 0.0 { 1.0 } else { now.min(MINUTE_US) / MINUTE_US };
                    let rate = in_window as f64 / minutes;
                    if rate > limit {
                        let a = Alarm {
                            pair: pair.clone(),
                            invariant: inv.name().to_string(),
                            seq: None,
                            value: rate,
                            limit,
                            detail: format!(
                                "{in_window} resyncs in the rolling minute at op {} \
                                 (last skipped {}+{})",
                                event.at_ops, event.skipped_a, event.skipped_b
                            ),
                        };
                        self.raise(&mut raised, inv.name(), event.at_ops, a);
                    }
                }
            }
            _ => {}
        }
        raised
    }

    fn raise(&mut self, out: &mut Vec<Alarm>, name: &'static str, at: usize, alarm: Alarm) {
        if self.seen.insert((alarm.pair.clone(), name, at)) {
            self.alarms.push(alarm.clone());
            out.push(alarm);
        }
    }
}

fn check_op_j(pair: &str, report: &WindowReport, limit: f64) -> Option<Alarm> {
    // worst per-op cost on the more expensive side: per label where the
    // window carries findings, else the window mean over its pairs
    let mut worst: Option<(f64, String)> = None;
    for f in &report.findings {
        if f.ops == 0 {
            continue;
        }
        let per_op = f.energy_a_j.max(f.energy_b_j) / f.ops as f64;
        if worst.as_ref().is_none_or(|(w, _)| per_op > *w) {
            worst = Some((per_op, format!("label {}", f.label)));
        }
    }
    if worst.is_none() && report.pairs > 0 {
        let per_op = report.energy_a_j.max(report.energy_b_j) / report.pairs as f64;
        worst = Some((per_op, format!("window mean over {} pairs", report.pairs)));
    }
    let (value, which) = worst?;
    (value > limit).then(|| Alarm {
        pair: pair.to_string(),
        invariant: "max-op-j".to_string(),
        seq: Some(report.seq),
        value,
        limit,
        detail: format!("{which} in window #{}", report.seq),
    })
}

fn check_waste_pct(pair: &str, report: &WindowReport, limit: f64) -> Option<Alarm> {
    let denom = report.energy_a_j.max(report.energy_b_j);
    if denom <= 0.0 {
        return None;
    }
    let pct = 100.0 * report.wasted_j / denom;
    (pct > limit).then(|| Alarm {
        pair: pair.to_string(),
        invariant: "max-window-waste-pct".to_string(),
        seq: Some(report.seq),
        value: pct,
        limit,
        detail: format!(
            "window #{} wasted {:.6} J of {:.6} J",
            report.seq, report.wasted_j, denom
        ),
    })
}

// ---- dashboard state ----------------------------------------------------

/// Rolling per-pair aggregates drawn by the dashboard.
#[derive(Clone, Debug, Default)]
pub struct PairStat {
    /// Windows observed (live counts; a `Summary` snapshot overwrites
    /// the cumulative fields below with the auditor's exact totals).
    pub windows: usize,
    pub windows_flagged: usize,
    pub quarantined: usize,
    pub wasted_j: f64,
    pub energy_a_j: f64,
    pub energy_b_j: f64,
    pub ops: usize,
    pub resyncs: usize,
    pub aligned: bool,
    pub last_seq: Option<usize>,
    /// True once the pair's `finish`-time summary has been observed.
    pub summarized: bool,
}

/// The dashboard's fold over a snapshot stream: rolling per-pair
/// stats, the divergence feed, and the alarm log. Rendering lives in
/// [`crate::report::render_dash`].
#[derive(Default)]
pub struct DashState {
    pub pairs: BTreeMap<String, PairStat>,
    pub divergences: Vec<FleetDivergence>,
    pub alarms: Vec<Alarm>,
    /// Latest persisted fleet ranking, if any.
    pub ranking: Vec<RankEntry>,
    pub windows: usize,
    pub resyncs: usize,
    pub session: String,
}

impl DashState {
    pub fn new() -> DashState {
        DashState::default()
    }

    /// Fold one snapshot into the dashboard.
    pub fn observe(&mut self, snap: &Snapshot) {
        match snap {
            Snapshot::Window { pair, report } => {
                if report.seq == WindowReport::PEEK_SEQ {
                    return;
                }
                let s = self.pairs.entry(pair.clone()).or_default();
                s.windows += 1;
                s.last_seq = Some(report.seq);
                s.aligned = report.aligned;
                if report.quarantined {
                    s.quarantined += 1;
                } else {
                    if report.findings.iter().any(|f| !f.is_tradeoff) {
                        s.windows_flagged += 1;
                    }
                    s.wasted_j += report.wasted_j;
                }
                s.ops += report.pairs;
                s.energy_a_j += report.energy_a_j;
                s.energy_b_j += report.energy_b_j;
                self.windows += 1;
            }
            Snapshot::Resync { pair, .. } => {
                self.pairs.entry(pair.clone()).or_default().resyncs += 1;
                self.resyncs += 1;
            }
            Snapshot::Summary { pair, summary } => {
                // the auditor's own cumulative accounting is exact
                // (windows double-count overlapping hops; the summary
                // ledgers each pair once) — overwrite the rolling view
                let s = self.pairs.entry(pair.clone()).or_default();
                s.wasted_j = summary.wasted_j;
                s.energy_a_j = summary.energy_a_j;
                s.energy_b_j = summary.energy_b_j;
                s.ops = summary.ops;
                s.windows = summary.windows;
                s.windows_flagged = summary.windows_flagged;
                s.quarantined = summary.windows_quarantined;
                s.resyncs = summary.resyncs;
                s.aligned = summary.aligned;
                s.summarized = true;
            }
            Snapshot::Divergence { event } => self.divergences.push(event.clone()),
            Snapshot::Fleet { ranking } => self.ranking = ranking.clone(),
            Snapshot::Session { header } => {
                if self.session.is_empty() {
                    self.session = header.session_id.clone();
                }
            }
            Snapshot::Alarm { alarm } => self.alarms.push(alarm.clone()),
            Snapshot::Ledger { .. } => {}
        }
    }

    /// Pairs ranked most-wasteful first (name tiebreak) — the same
    /// comparator as the persisted fleet ranking.
    pub fn ranked(&self) -> Vec<(&String, &PairStat)> {
        let mut v: Vec<(&String, &PairStat)> = self.pairs.iter().collect();
        v.sort_by(|a, b| {
            b.1.wasted_j.total_cmp(&a.1.wasted_j).then_with(|| a.0.cmp(b.0))
        });
        v
    }
}

// ---- alarm publishing ---------------------------------------------------

/// Fan-out of alarm NDJSON lines to subscribers over *bounded*
/// channels: a subscriber that stalls loses lines (counted in
/// [`AlarmPublisher::dropped`]) instead of backpressuring the stream
/// being measured. A disconnected subscriber is dropped from the list.
pub struct AlarmPublisher {
    subs: Arc<Mutex<Vec<SyncSender<String>>>>,
    depth: usize,
    /// Lines offered to subscribers (per [`AlarmPublisher::publish`]
    /// call, not per subscriber).
    pub published: usize,
    /// Sends refused because a subscriber's bounded queue was full.
    pub dropped: usize,
}

impl AlarmPublisher {
    /// `depth` is each subscriber's bounded queue length (the most a
    /// stalled collector can lag before losing lines). Must be > 0.
    pub fn new(depth: usize) -> AlarmPublisher {
        assert!(depth > 0, "a zero-depth queue would drop every line");
        AlarmPublisher {
            subs: Arc::new(Mutex::new(Vec::new())),
            depth,
            published: 0,
            dropped: 0,
        }
    }

    /// Attach an in-process subscriber; returns its receiving end.
    pub fn subscribe(&self) -> Receiver<String> {
        let (tx, rx) = sync_channel(self.depth);
        self.subs.lock().expect("publisher lock").push(tx);
        rx
    }

    pub fn subscriber_count(&self) -> usize {
        self.subs.lock().expect("publisher lock").len()
    }

    /// Offer one line to every live subscriber: full queues drop and
    /// count, disconnected subscribers are removed.
    pub fn publish(&mut self, line: &str) {
        self.published += 1;
        let mut dropped = 0usize;
        self.subs.lock().expect("publisher lock").retain(|tx| {
            match tx.try_send(line.to_string()) {
                Ok(()) => true,
                Err(TrySendError::Full(_)) => {
                    dropped += 1;
                    true
                }
                Err(TrySendError::Disconnected(_)) => false,
            }
        });
        self.dropped += dropped;
    }

    /// Serve alarm lines over TCP: every connection to the returned
    /// port becomes a subscriber (newline-delimited NDJSON, the same
    /// lines [`Snapshot::to_line`] persists). Bind to port 0 for an
    /// ephemeral port. The accept loop runs on a detached thread for
    /// the life of the process; a connection that stalls past the
    /// queue depth loses lines, a closed one unsubscribes itself.
    pub fn serve(&self, addr: &str) -> Result<u16> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| Error::msg(format!("bind alarm listener {addr}: {e}")))?;
        let port = listener
            .local_addr()
            .map_err(|e| Error::msg(format!("alarm listener addr: {e}")))?
            .port();
        let subs = Arc::clone(&self.subs);
        let depth = self.depth;
        thread::spawn(move || {
            for conn in listener.incoming() {
                let Ok(mut conn) = conn else { continue };
                let (tx, rx) = sync_channel::<String>(depth);
                subs.lock().expect("publisher lock").push(tx);
                thread::spawn(move || {
                    // rx disconnects when the publisher retires the
                    // sender; a write error retires the connection the
                    // other way round (publish sees Disconnected)
                    for line in rx {
                        if conn.write_all(line.as_bytes()).is_err()
                            || conn.write_all(b"\n").is_err()
                        {
                            break;
                        }
                    }
                });
            }
        });
        Ok(port)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stream::{ResyncEvent, StreamFinding};
    use crate::detect::Side;

    fn window(pair: &str, seq: usize, ea: f64, eb: f64, wasted: f64) -> Snapshot {
        Snapshot::Window {
            pair: pair.to_string(),
            report: WindowReport {
                seq,
                pairs: 10,
                energy_a_j: ea,
                energy_b_j: eb,
                time_a_us: 1000.0,
                time_b_us: 900.0,
                findings: Vec::new(),
                wasted_j: wasted,
                aligned: true,
                resyncs: 0,
                quarantined: false,
                content_mismatches: 0,
                window_fp: 7,
            },
        }
    }

    #[test]
    fn waste_pct_breach_alarms_exactly_once_per_window() {
        let mut m = Monitor::new(vec![Invariant::MaxWindowWastePct(10.0)]);
        let bad = window("p0", 3, 10.0, 6.0, 4.0); // 40% of 10 J
        let ok = window("p0", 4, 10.0, 9.6, 0.4); // 4%
        assert_eq!(m.observe(&bad).len(), 1);
        assert_eq!(m.observe(&bad).len(), 0, "re-observation must not re-alarm");
        assert_eq!(m.observe(&ok).len(), 0);
        assert_eq!(m.alarms.len(), 1);
        let a = &m.alarms[0];
        assert_eq!(a.invariant, "max-window-waste-pct");
        assert_eq!(a.seq, Some(3));
        assert_eq!(a.limit, 10.0);
        assert!((a.value - 40.0).abs() < 1e-9);
    }

    #[test]
    fn op_j_checks_findings_first_and_window_mean_otherwise() {
        let mut m = Monitor::new(vec![Invariant::MaxOpJ(0.5)]);
        // no findings: mean = 10 J / 10 pairs = 1 J/op > 0.5
        let mean_bad = window("p0", 0, 10.0, 8.0, 0.0);
        let raised = m.observe(&mean_bad);
        assert_eq!(raised.len(), 1);
        assert!(raised[0].detail.contains("window mean"));
        // with findings the worst label wins and is named
        let mut w = window("p1", 0, 1.0, 1.0, 0.0);
        if let Snapshot::Window { report, .. } = &mut w {
            report.findings.push(StreamFinding {
                label: "serve.proj".to_string(),
                ops: 2,
                energy_a_j: 2.0,
                energy_b_j: 1.0,
                time_a_us: 10.0,
                time_b_us: 10.0,
                diff_frac: 0.5,
                wasteful: Side::A,
                is_tradeoff: false,
            });
        }
        let raised = m.observe(&w);
        assert_eq!(raised.len(), 1);
        assert!(raised[0].detail.contains("serve.proj"));
        assert!((raised[0].value - 1.0).abs() < 1e-12);
    }

    #[test]
    fn resync_rate_is_per_rolling_minute_of_stream_time() {
        let mut m = Monitor::new(vec![Invariant::MaxResyncsPerMin(2.0)]);
        let ev = |at| Snapshot::Resync {
            pair: "p0".to_string(),
            event: ResyncEvent { at_ops: at, skipped_a: 1, skipped_b: 0 },
        };
        // stream has run 1000 µs; even one resync in the window rates
        // far above 2/min once normalized — but the floor keeps a
        // zero-time stream from dividing by zero
        m.observe(&window("p0", 0, 1.0, 1.0, 0.0));
        let raised = m.observe(&ev(10));
        assert_eq!(raised.len(), 1);
        assert_eq!(raised[0].seq, None);
        assert!(raised[0].value > 2.0);
        // the same resync position never re-alarms
        assert_eq!(m.observe(&ev(10)).len(), 0);
    }

    #[test]
    fn dash_state_prefers_the_summary_totals_once_seen() {
        let mut d = DashState::new();
        d.observe(&window("p0", 0, 5.0, 4.0, 1.0));
        d.observe(&window("p0", 1, 5.0, 4.0, 1.0));
        assert_eq!(d.pairs["p0"].windows, 2);
        assert!((d.pairs["p0"].wasted_j - 2.0).abs() < 1e-12);
        let summary = crate::stream::StreamSummary {
            ops: 20,
            windows: 2,
            energy_a_j: 10.0,
            energy_b_j: 8.0,
            time_a_us: 2000.0,
            time_b_us: 1800.0,
            wasted_j: 1.5,
            windows_flagged: 1,
            windows_quarantined: 0,
            top_labels: Vec::new(),
            aligned: true,
            fingerprint_a: 1,
            fingerprint_b: 1,
            unpaired: 0,
            resyncs: 0,
            resync_skipped: 0,
            resync_log: Vec::new(),
            content_mismatches: 0,
            reports_dropped: 0,
            peak_retained_segments: 0,
            peak_window_pairs: 0,
            peak_pending: 0,
        };
        d.observe(&Snapshot::Summary { pair: "p0".to_string(), summary });
        assert!(d.pairs["p0"].summarized);
        assert!((d.pairs["p0"].wasted_j - 1.5).abs() < 1e-12, "summary is authoritative");
        // ranking: most wasteful first
        d.observe(&window("p1", 0, 9.0, 1.0, 8.0));
        let ranked = d.ranked();
        assert_eq!(ranked[0].0, "p1");
    }

    #[test]
    fn stalled_subscriber_drops_and_counts_instead_of_blocking() {
        let mut p = AlarmPublisher::new(2);
        let rx = p.subscribe();
        for i in 0..10 {
            p.publish(&format!("line {i}"));
        }
        assert_eq!(p.published, 10);
        assert_eq!(p.dropped, 8, "queue depth 2: the rest must drop");
        let got: Vec<String> = rx.try_iter().collect();
        assert_eq!(got, vec!["line 0".to_string(), "line 1".to_string()]);
        // a dropped receiver unsubscribes on the next publish
        drop(rx);
        p.publish("after");
        assert_eq!(p.subscriber_count(), 0);
    }

    #[test]
    fn tcp_subscriber_receives_published_lines() {
        use std::io::{BufRead as _, BufReader};
        use std::net::TcpStream;
        use std::time::Duration;

        let mut p = AlarmPublisher::new(16);
        let port = p.serve("127.0.0.1:0").unwrap();
        let conn = TcpStream::connect(("127.0.0.1", port)).unwrap();
        conn.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        // wait for the accept loop to register the subscription
        for _ in 0..200 {
            if p.subscriber_count() > 0 {
                break;
            }
            thread::sleep(Duration::from_millis(5));
        }
        assert!(p.subscriber_count() > 0, "accept loop never registered");
        p.publish("{\"type\":\"alarm\"}");
        let mut line = String::new();
        BufReader::new(conn).read_line(&mut line).unwrap();
        assert_eq!(line, "{\"type\":\"alarm\"}\n");
    }
}
