//! Online differential auditing of long-running serving traffic.
//!
//! The batch pipeline ([`crate::coordinator`]) audits finished,
//! fully-materialised runs: it needs both sides' complete
//! `RunArtifacts` in memory. Production serving traffic (the ML.ENERGY
//! / MLPerf-Power setting the ROADMAP points at) never finishes, so
//! this module audits *streams* instead: it ingests
//! `(KernelRecord, Segment)` events chunk-by-chunk from two live
//! executors (see [`crate::exec::StreamExec`]), maintains
//!
//! * a **sliding detection window** of the last `window_ops` matched op
//!   pairs with O(1) rolling cost sums,
//! * **rolling structural fingerprints** of each side's matched op
//!   history (polynomial hash over `(label, op)`), part of the
//!   alignment verdict and exported in the summary so operators can
//!   compare workloads across stream pairs and sessions,
//! * **ring-buffered power segments** ([`PowerRing`]) with eviction, so
//!   the retained power timeline — and through it the incremental NVML
//!   cursor ([`crate::energy::sampler::SamplerState`]) — is bounded by
//!   the ring capacity, never by the stream length,
//!
//! and emits incremental [`WindowReport`]s plus a cumulative
//! [`StreamSummary`] without ever holding the full trace.

use std::collections::{BTreeMap, VecDeque};

use crate::detect::{DetectConfig, Side};
use crate::energy::sampler::{NvmlSampler, SamplerState};
use crate::energy::{PowerSource, Segment};
use crate::exec::KernelRecord;

/// Fixed-capacity ring of power segments: the bounded stand-in for a
/// full [`crate::energy::PowerTrace`] on an unbounded stream. Evicted
/// segments fold their energy into a running total, so cumulative
/// accounting stays exact while retained memory stays O(capacity).
#[derive(Clone, Debug)]
pub struct PowerRing {
    segs: VecDeque<Segment>,
    cap: usize,
    /// Power reported outside the retained span.
    pub idle_w: f64,
    /// Energy of evicted segments, Joules (exact cumulative bookkeeping).
    pub evicted_energy_j: f64,
    /// Number of evicted segments.
    pub evicted: usize,
    /// High-water mark of retained segments (≤ cap by construction;
    /// exposed so callers can assert the memory bound).
    pub peak_retained: usize,
}

impl PowerRing {
    pub fn new(cap: usize, idle_w: f64) -> PowerRing {
        assert!(cap > 0, "ring capacity must be positive");
        PowerRing {
            segs: VecDeque::with_capacity(cap),
            cap,
            idle_w,
            evicted_energy_j: 0.0,
            evicted: 0,
            peak_retained: 0,
        }
    }

    /// Append a segment, evicting the oldest when full.
    pub fn push(&mut self, seg: Segment) {
        if self.segs.len() == self.cap {
            let old = self.segs.pop_front().expect("cap > 0");
            self.evicted_energy_j += old.energy_j();
            self.evicted += 1;
        }
        self.segs.push_back(seg);
        if self.segs.len() > self.peak_retained {
            self.peak_retained = self.segs.len();
        }
    }

    pub fn len(&self) -> usize {
        self.segs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.segs.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// End timestamp of the newest retained segment, µs.
    pub fn t_now_us(&self) -> f64 {
        self.segs.back().map(|s| s.t_end_us).unwrap_or(0.0)
    }

    /// Start timestamp of the oldest retained segment, µs.
    pub fn t_oldest_us(&self) -> f64 {
        self.segs.front().map(|s| s.t_start_us).unwrap_or(0.0)
    }

    /// Energy of the retained segments only, Joules.
    pub fn retained_energy_j(&self) -> f64 {
        self.segs.iter().map(|s| s.energy_j()).sum()
    }

    /// Exact energy of the whole stream so far (retained + evicted).
    pub fn total_energy_j(&self) -> f64 {
        self.evicted_energy_j + self.retained_energy_j()
    }
}

impl PowerSource for PowerRing {
    /// Instantaneous power at `t_us`: binary search over the retained
    /// (contiguous, time-ordered) segments; idle outside them. Evicted
    /// history reads as idle — callers advancing a sampler cursor see
    /// it only if they lag the stream by more than the ring span.
    fn power_at_us(&self, t_us: f64) -> f64 {
        if self.segs.is_empty() {
            return self.idle_w;
        }
        let lo = self.segs.partition_point(|s| s.t_end_us <= t_us);
        if lo < self.segs.len() && self.segs[lo].t_start_us <= t_us {
            self.segs[lo].watts
        } else {
            self.idle_w
        }
    }

    fn idle_watts(&self) -> f64 {
        self.idle_w
    }
}

/// Configuration of a [`StreamAuditor`].
#[derive(Clone, Debug)]
pub struct StreamConfig {
    /// Sliding detection window, in matched op pairs.
    pub window_ops: usize,
    /// Window hop: a report is emitted every `hop_ops` ingested pairs.
    /// `hop_ops == window_ops` (the default) tiles the stream, so
    /// summing window waste is exact; smaller hops overlap windows for
    /// finer-grained rolling detection.
    pub hop_ops: usize,
    /// Power segments retained per side.
    pub ring_cap: usize,
    /// Largest inter-side ingestion skew buffered before surplus
    /// events are dropped (counted in `unpaired`, breaking alignment).
    /// Bounds pending memory on one-sided floods; callers that ingest
    /// in large one-sided chunks must size this to their chunk length.
    pub max_pending: usize,
    /// Detection thresholds (reused from the batch detector).
    pub cfg: DetectConfig,
    /// NVML model backing the rolling counter readout; `None` disables.
    pub nvml: Option<NvmlSampler>,
}

impl Default for StreamConfig {
    fn default() -> StreamConfig {
        StreamConfig {
            window_ops: 256,
            hop_ops: 256,
            ring_cap: 512,
            max_pending: 4096,
            cfg: DetectConfig::default(),
            nvml: Some(NvmlSampler::default()),
        }
    }
}

/// One matched op pair in the sliding window.
#[derive(Clone, Debug)]
struct PairCost {
    label: String,
    energy_a_j: f64,
    energy_b_j: f64,
    time_a_us: f64,
    time_b_us: f64,
}

/// One side's pending (not yet paired) op event.
#[derive(Clone, Debug)]
struct OpEvent {
    label: String,
    op_name: &'static str,
    energy_j: f64,
    time_us: f64,
}

/// A per-label divergence flagged inside one window.
#[derive(Clone, Debug)]
pub struct StreamFinding {
    pub label: String,
    /// Matched op pairs under this label inside the window.
    pub ops: usize,
    pub energy_a_j: f64,
    pub energy_b_j: f64,
    pub time_a_us: f64,
    pub time_b_us: f64,
    /// |eA − eB| / max(eA, eB).
    pub diff_frac: f64,
    pub wasteful: Side,
    /// True when the efficient side pays more than the perf tolerance
    /// in time — a trade-off, not waste.
    pub is_tradeoff: bool,
}

impl StreamFinding {
    /// Joules of genuine waste this finding represents (0 for trade-offs).
    pub fn wasted_j(&self) -> f64 {
        if self.is_tradeoff {
            0.0
        } else {
            (self.energy_a_j - self.energy_b_j).abs()
        }
    }
}

/// Incremental detection report for one emitted window.
#[derive(Clone, Debug)]
pub struct WindowReport {
    /// 0-based index of the emitted window.
    pub seq: usize,
    /// Matched pairs inside the window.
    pub pairs: usize,
    pub energy_a_j: f64,
    pub energy_b_j: f64,
    pub time_a_us: f64,
    pub time_b_us: f64,
    pub findings: Vec<StreamFinding>,
    /// Joules of genuine (non-trade-off) waste across the findings.
    pub wasted_j: f64,
    /// Whether the rolling structural fingerprints still agree.
    pub aligned: bool,
}

/// Cumulative state of a stream audit.
#[derive(Clone, Debug)]
pub struct StreamSummary {
    /// Matched op pairs ingested.
    pub ops: usize,
    /// Windows emitted.
    pub windows: usize,
    /// Exact cumulative energies (records, not ring-truncated).
    pub energy_a_j: f64,
    pub energy_b_j: f64,
    pub time_a_us: f64,
    pub time_b_us: f64,
    /// Joules of genuine waste accumulated over emitted windows.
    pub wasted_j: f64,
    /// Windows that contained at least one non-trade-off finding.
    pub windows_flagged: usize,
    /// Most wasteful labels: `(label, wasted_j, windows flagged in)`,
    /// descending by waste.
    pub top_labels: Vec<(String, f64, usize)>,
    /// The two streams ran the same workload in the same order: every
    /// matched pair agreed on `(label, op)`, the matched-history
    /// fingerprints are equal, and (after `finish`) no unpaired tail
    /// remained.
    pub aligned: bool,
    /// Rolling structural fingerprint of each side's matched op
    /// history — equal whenever `aligned`; stable across runs, so
    /// operators can compare workloads across stream pairs/sessions.
    pub fingerprint_a: u64,
    pub fingerprint_b: u64,
    /// Events still unpaired (surplus of the longer stream). Non-zero
    /// after `finish` means the sides emitted different op counts —
    /// their cumulative energies are not directly comparable.
    pub unpaired: usize,
    /// Memory high-water marks: retained power segments (≤ ring cap),
    /// window pairs, pending unpaired events.
    pub peak_retained_segments: usize,
    pub peak_window_pairs: usize,
    pub peak_pending: usize,
}

/// Online differential auditor over two op streams.
///
/// Feed it with [`StreamAuditor::ingest_a`] / [`StreamAuditor::ingest_b`]
/// (order between sides is free up to [`StreamConfig::max_pending`]
/// skew; pairing is positional), drain emitted windows with
/// [`StreamAuditor::take_emitted`], and finish with
/// [`StreamAuditor::finish`]. All retained state is bounded: window +
/// rings + per-label aggregates + at most `max_pending` pending events
/// per side (surplus past the cap is dropped, counted in `unpaired`,
/// and breaks alignment).
pub struct StreamAuditor {
    pub cfg: StreamConfig,
    window: VecDeque<PairCost>,
    win_e_a: f64,
    win_e_b: f64,
    win_t_a: f64,
    win_t_b: f64,
    pend_a: VecDeque<OpEvent>,
    pend_b: VecDeque<OpEvent>,
    /// Rolling structural fingerprints over the full matched history.
    fp_a: u64,
    fp_b: u64,
    aligned: bool,
    /// Power rings (public: the example asserts the memory bound).
    pub ring_a: PowerRing,
    pub ring_b: PowerRing,
    sampler_a: SamplerState,
    sampler_b: SamplerState,
    pairs_since_hop: usize,
    emitted: Vec<WindowReport>,
    /// Pending events dropped after exceeding the skew cap.
    unpaired_dropped: usize,
    // cumulative accounting
    ops: usize,
    windows: usize,
    windows_flagged: usize,
    cum_e_a: f64,
    cum_e_b: f64,
    cum_t_a: f64,
    cum_t_b: f64,
    cum_wasted_j: f64,
    label_waste: BTreeMap<String, (f64, usize)>,
    peak_window_pairs: usize,
    peak_pending: usize,
}

/// FNV-1a over a label + op name (the structural identity of one op).
fn op_hash(label: &str, op_name: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in label.as_bytes().iter().chain([0xffu8].iter()).chain(op_name.as_bytes()) {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

impl StreamAuditor {
    pub fn new(cfg: StreamConfig, idle_w: f64) -> StreamAuditor {
        assert!(cfg.window_ops > 0 && cfg.hop_ops > 0, "window/hop must be positive");
        let ring_a = PowerRing::new(cfg.ring_cap, idle_w);
        let ring_b = PowerRing::new(cfg.ring_cap, idle_w);
        StreamAuditor {
            window: VecDeque::with_capacity(cfg.window_ops),
            win_e_a: 0.0,
            win_e_b: 0.0,
            win_t_a: 0.0,
            win_t_b: 0.0,
            pend_a: VecDeque::new(),
            pend_b: VecDeque::new(),
            fp_a: 0,
            fp_b: 0,
            aligned: true,
            ring_a,
            ring_b,
            sampler_a: SamplerState::new(idle_w),
            sampler_b: SamplerState::new(idle_w),
            pairs_since_hop: 0,
            emitted: Vec::new(),
            unpaired_dropped: 0,
            ops: 0,
            windows: 0,
            windows_flagged: 0,
            cum_e_a: 0.0,
            cum_e_b: 0.0,
            cum_t_a: 0.0,
            cum_t_b: 0.0,
            cum_wasted_j: 0.0,
            label_waste: BTreeMap::new(),
            peak_window_pairs: 0,
            peak_pending: 0,
            cfg,
        }
    }

    /// Ingest one op event from side A.
    pub fn ingest_a(&mut self, rec: &KernelRecord, seg: Segment) {
        self.ingest(Side::A, rec, seg)
    }

    /// Ingest one op event from side B.
    pub fn ingest_b(&mut self, rec: &KernelRecord, seg: Segment) {
        self.ingest(Side::B, rec, seg)
    }

    /// Shared ingestion body — side-symmetry is structural, not by
    /// copy-paste convention.
    fn ingest(&mut self, side: Side, rec: &KernelRecord, seg: Segment) {
        let (ring, pend, cum_e, cum_t) = match side {
            Side::A => (&mut self.ring_a, &mut self.pend_a, &mut self.cum_e_a, &mut self.cum_t_a),
            Side::B => (&mut self.ring_b, &mut self.pend_b, &mut self.cum_e_b, &mut self.cum_t_b),
        };
        ring.push(seg);
        *cum_e += rec.energy_j;
        *cum_t += rec.time_us;
        pend.push_back(OpEvent {
            label: rec.label.clone(),
            op_name: rec.op.name(),
            energy_j: rec.energy_j,
            time_us: rec.time_us,
        });
        self.drain_pairs();
    }

    /// Pair pending events positionally and slide the window.
    fn drain_pairs(&mut self) {
        let pending = self.pend_a.len().max(self.pend_b.len());
        if pending > self.peak_pending {
            self.peak_pending = pending;
        }
        while !self.pend_a.is_empty() && !self.pend_b.is_empty() {
            let a = self.pend_a.pop_front().expect("checked non-empty");
            let b = self.pend_b.pop_front().expect("checked non-empty");
            // structural check: positional pairing requires same op
            if a.label != b.label || a.op_name != b.op_name {
                self.aligned = false;
            }
            // rolling fingerprints over the *matched* history: equal
            // whenever the streams ran the same ops in the same order,
            // and exported so operators can compare workloads across
            // stream pairs and sessions
            self.fp_a = self.fp_a.rotate_left(1) ^ op_hash(&a.label, a.op_name);
            self.fp_b = self.fp_b.rotate_left(1) ^ op_hash(&b.label, b.op_name);
            self.ops += 1;
            let pair = PairCost {
                label: a.label,
                energy_a_j: a.energy_j,
                energy_b_j: b.energy_j,
                time_a_us: a.time_us,
                time_b_us: b.time_us,
            };
            self.win_e_a += pair.energy_a_j;
            self.win_e_b += pair.energy_b_j;
            self.win_t_a += pair.time_a_us;
            self.win_t_b += pair.time_b_us;
            self.window.push_back(pair);
            if self.window.len() > self.cfg.window_ops {
                let old = self.window.pop_front().expect("over capacity");
                self.win_e_a -= old.energy_a_j;
                self.win_e_b -= old.energy_b_j;
                self.win_t_a -= old.time_a_us;
                self.win_t_b -= old.time_b_us;
            }
            if self.window.len() > self.peak_window_pairs {
                self.peak_window_pairs = self.window.len();
            }
            self.pairs_since_hop += 1;
            if self.pairs_since_hop >= self.cfg.hop_ops && self.window.len() >= self.cfg.window_ops {
                self.pairs_since_hop = 0;
                self.emit_window();
            }
        }
        // bound the surplus side: drop (and count) events beyond the
        // skew cap so pending memory never scales with stream length
        let cap = self.cfg.max_pending;
        while self.pend_a.len() > cap {
            self.pend_a.pop_front();
            self.unpaired_dropped += 1;
            self.aligned = false;
        }
        while self.pend_b.len() > cap {
            self.pend_b.pop_front();
            self.unpaired_dropped += 1;
            self.aligned = false;
        }
    }

    /// Detect per-label divergence over the current window contents.
    fn window_findings(&self) -> Vec<StreamFinding> {
        let mut by_label: BTreeMap<&str, (usize, f64, f64, f64, f64)> = BTreeMap::new();
        for p in &self.window {
            let cell = by_label.entry(p.label.as_str()).or_insert((0, 0.0, 0.0, 0.0, 0.0));
            cell.0 += 1;
            cell.1 += p.energy_a_j;
            cell.2 += p.energy_b_j;
            cell.3 += p.time_a_us;
            cell.4 += p.time_b_us;
        }
        let mut findings = Vec::new();
        for (label, (ops, ea, eb, ta, tb)) in by_label {
            if ea <= 0.0 && eb <= 0.0 {
                continue;
            }
            let diff = (ea - eb).abs() / ea.max(eb);
            if diff < self.cfg.cfg.energy_threshold {
                continue;
            }
            let wasteful = if ea > eb { Side::A } else { Side::B };
            let (t_waste, t_eff) = match wasteful {
                Side::A => (ta, tb),
                Side::B => (tb, ta),
            };
            let is_tradeoff = t_eff > t_waste * (1.0 + self.cfg.cfg.perf_tolerance);
            findings.push(StreamFinding {
                label: label.to_string(),
                ops,
                energy_a_j: ea,
                energy_b_j: eb,
                time_a_us: ta,
                time_b_us: tb,
                diff_frac: diff,
                wasteful,
                is_tradeoff,
            });
        }
        findings.sort_by(|x, y| {
            let kx = x.energy_a_j.max(x.energy_b_j) * x.diff_frac;
            let ky = y.energy_a_j.max(y.energy_b_j) * y.diff_frac;
            ky.total_cmp(&kx)
        });
        findings
    }

    /// Build a report over the current window without emitting it.
    pub fn window_report(&self) -> WindowReport {
        let findings = self.window_findings();
        let wasted_j = findings.iter().map(|f| f.wasted_j()).sum();
        WindowReport {
            seq: self.windows,
            pairs: self.window.len(),
            energy_a_j: self.win_e_a,
            energy_b_j: self.win_e_b,
            time_a_us: self.win_t_a,
            time_b_us: self.win_t_b,
            findings,
            wasted_j,
            aligned: self.aligned,
        }
    }

    fn emit_window(&mut self) {
        let report = self.window_report();
        self.windows += 1;
        self.cum_wasted_j += report.wasted_j;
        if report.findings.iter().any(|f| !f.is_tradeoff) {
            self.windows_flagged += 1;
        }
        for f in &report.findings {
            if !f.is_tradeoff {
                let cell = self.label_waste.entry(f.label.clone()).or_insert((0.0, 0));
                cell.0 += f.wasted_j();
                cell.1 += 1;
            }
        }
        self.emitted.push(report);
    }

    /// Drain the window reports emitted since the last call (bounded by
    /// how often the caller drains relative to the hop size).
    pub fn take_emitted(&mut self) -> Vec<WindowReport> {
        std::mem::take(&mut self.emitted)
    }

    /// The NVML counter reading visible *now* on side A's ring, through
    /// the incremental cursor (O(new samples) per call).
    pub fn nvml_reading_a(&mut self) -> Option<f64> {
        self.nvml_reading(Side::A)
    }

    /// The NVML counter reading visible *now* on side B's ring.
    pub fn nvml_reading_b(&mut self) -> Option<f64> {
        self.nvml_reading(Side::B)
    }

    fn nvml_reading(&mut self, side: Side) -> Option<f64> {
        let nvml = self.cfg.nvml.clone()?;
        let (ring, state) = match side {
            Side::A => (&self.ring_a, &mut self.sampler_a),
            Side::B => (&self.ring_b, &mut self.sampler_b),
        };
        Some(nvml.advance(state, ring, ring.t_now_us()))
    }

    /// Drive two streaming executors to exhaustion in lock-step
    /// (pending skew ≤ 1 while both are live), handing every emitted
    /// window to `on_window`, then flush and return the final summary.
    /// This is the one pairing protocol shared by
    /// [`crate::coordinator::fleet::StreamFleet`] workers and the
    /// `stream_audit` example.
    pub fn drive(
        &mut self,
        a: &mut crate::exec::StreamExec<'_>,
        b: &mut crate::exec::StreamExec<'_>,
        mut on_window: impl FnMut(WindowReport),
    ) -> StreamSummary {
        loop {
            let na = a.next();
            let nb = b.next();
            if na.is_none() && nb.is_none() {
                break;
            }
            if let Some((rec, seg)) = na {
                self.ingest_a(&rec, seg);
            }
            if let Some((rec, seg)) = nb {
                self.ingest_b(&rec, seg);
            }
            for w in self.take_emitted() {
                on_window(w);
            }
        }
        let summary = self.finish();
        for w in self.take_emitted() {
            on_window(w);
        }
        summary
    }

    /// Cumulative summary so far (valid mid-stream).
    pub fn summary(&self) -> StreamSummary {
        let mut top: Vec<(String, f64, usize)> = self
            .label_waste
            .iter()
            .map(|(l, &(j, n))| (l.clone(), j, n))
            .collect();
        top.sort_by(|x, y| y.1.total_cmp(&x.1).then_with(|| x.0.cmp(&y.0)));
        StreamSummary {
            ops: self.ops,
            windows: self.windows,
            energy_a_j: self.cum_e_a,
            energy_b_j: self.cum_e_b,
            time_a_us: self.cum_t_a,
            time_b_us: self.cum_t_b,
            wasted_j: self.cum_wasted_j,
            windows_flagged: self.windows_flagged,
            top_labels: top,
            aligned: self.aligned && self.fp_a == self.fp_b,
            fingerprint_a: self.fp_a,
            fingerprint_b: self.fp_b,
            unpaired: self.pend_a.len() + self.pend_b.len() + self.unpaired_dropped,
            peak_retained_segments: self.ring_a.peak_retained.max(self.ring_b.peak_retained),
            peak_window_pairs: self.peak_window_pairs,
            peak_pending: self.peak_pending,
        }
    }

    /// Flush a partial trailing window (if any pairs arrived since the
    /// last emission) and return the final summary. The flushed window
    /// is trimmed to the residual tail, so under the default tiling
    /// every pair is counted exactly once in the waste ledger.
    pub fn finish(&mut self) -> StreamSummary {
        // a surplus on either side means the streams did not run the
        // same workload: flag it rather than silently reporting the
        // (incomparable) cumulative energies as a clean audit
        if !self.pend_a.is_empty() || !self.pend_b.is_empty() {
            self.aligned = false;
        }
        if self.pairs_since_hop > 0 {
            let residual = self.pairs_since_hop.min(self.window.len());
            while self.window.len() > residual {
                let old = self.window.pop_front().expect("len > residual >= 0");
                self.win_e_a -= old.energy_a_j;
                self.win_e_b -= old.energy_b_j;
                self.win_t_a -= old.time_a_us;
                self.win_t_b -= old.time_b_us;
            }
            self.pairs_since_hop = 0;
            self.emit_window();
        }
        self.summary()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::OpKind;
    use crate::trace::Frame;

    fn rec(label: &str, op: OpKind, energy_j: f64, time_us: f64) -> KernelRecord {
        KernelRecord {
            node: 0,
            op,
            label: label.to_string(),
            api: "api".into(),
            dispatch_key: op.name().to_string(),
            kernel: format!("k_{label}"),
            time_us,
            energy_j,
            avg_power_w: energy_j / (time_us * 1e-6),
            corr_id: 0,
            bb_trace: vec![],
            call_path: vec![Frame::py("serve")],
        }
    }

    fn seg_after(t0: f64, dur: f64, watts: f64) -> Segment {
        Segment { t_start_us: t0, t_end_us: t0 + dur, watts }
    }

    #[test]
    fn ring_evicts_but_keeps_exact_total() {
        let mut ring = PowerRing::new(4, 90.0);
        let mut t = 0.0;
        let mut expect = 0.0;
        for i in 0..10 {
            let w = 100.0 + i as f64;
            ring.push(seg_after(t, 1000.0, w));
            expect += w * 1000.0 * 1e-6;
            t += 1000.0;
        }
        assert_eq!(ring.len(), 4);
        assert_eq!(ring.evicted, 6);
        assert_eq!(ring.peak_retained, 4);
        assert!((ring.total_energy_j() - expect).abs() < 1e-12);
        // power lookups: inside the retained span, outside it, and gaps
        assert_eq!(ring.power_at_us(6500.0), 106.0);
        assert_eq!(ring.power_at_us(500.0), 90.0); // evicted -> idle
        assert_eq!(ring.power_at_us(20_000.0), 90.0); // future -> idle
        assert_eq!(ring.t_oldest_us(), 6000.0);
        assert_eq!(ring.t_now_us(), 10_000.0);
    }

    /// Feed two streams with a wasteful label on side A; the auditor
    /// must flag it window after window, with memory bounded.
    #[test]
    fn auditor_flags_wasteful_label_incrementally() {
        let cfg = StreamConfig {
            window_ops: 8,
            hop_ops: 8,
            ring_cap: 16,
            nvml: None,
            ..Default::default()
        };
        let mut aud = StreamAuditor::new(cfg, 90.0);
        let (mut ta, mut tb) = (0.0, 0.0);
        for i in 0..64 {
            let label = if i % 2 == 0 { "proj" } else { "act" };
            let op = if i % 2 == 0 { OpKind::MatMul } else { OpKind::Gelu };
            // side A burns 1.5x energy on proj at equal time
            let (ea, eb) = if i % 2 == 0 { (0.15, 0.10) } else { (0.02, 0.02) };
            aud.ingest_a(&rec(label, op, ea, 100.0), seg_after(ta, 100.0, ea / 100e-6));
            ta += 100.0;
            aud.ingest_b(&rec(label, op, eb, 100.0), seg_after(tb, 100.0, eb / 100e-6));
            tb += 100.0;
        }
        let reports = aud.take_emitted();
        assert_eq!(reports.len(), 8); // 64 pairs / hop 8
        for r in &reports {
            assert!(r.aligned);
            assert_eq!(r.pairs, 8);
            assert_eq!(r.findings.len(), 1, "only proj should be flagged");
            let f = &r.findings[0];
            assert_eq!(f.label, "proj");
            assert_eq!(f.wasteful, Side::A);
            assert!(!f.is_tradeoff);
            assert!(f.diff_frac > 0.30);
        }
        let s = aud.finish();
        assert_eq!(s.ops, 64);
        assert_eq!(s.windows, 8);
        assert_eq!(s.windows_flagged, 8);
        // waste = 4 proj pairs per window x 0.05 J x 8 windows
        assert!((s.wasted_j - 8.0 * 4.0 * 0.05).abs() < 1e-9);
        assert_eq!(s.top_labels[0].0, "proj");
        assert!(s.aligned);
        // memory bounds: ring capped, window capped, pairing keeps up
        assert!(s.peak_retained_segments <= 16);
        assert_eq!(s.peak_window_pairs, 8);
        assert!(s.peak_pending <= 2);
    }

    #[test]
    fn misaligned_streams_are_reported() {
        let mut aud = StreamAuditor::new(
            StreamConfig { window_ops: 2, hop_ops: 2, ..Default::default() },
            90.0,
        );
        aud.ingest_a(&rec("proj", OpKind::MatMul, 0.1, 50.0), seg_after(0.0, 50.0, 200.0));
        aud.ingest_b(&rec("act", OpKind::Gelu, 0.1, 50.0), seg_after(0.0, 50.0, 200.0));
        let s = aud.finish();
        assert!(!s.aligned);
        assert_ne!(s.fingerprint_a, s.fingerprint_b);
    }

    /// A surplus of events on one side (streams of different length)
    /// must flag the audit as misaligned instead of reporting the
    /// incomparable cumulative energies as clean.
    #[test]
    fn unequal_length_streams_flagged_misaligned() {
        let mut aud = StreamAuditor::new(
            StreamConfig { window_ops: 2, hop_ops: 2, nvml: None, ..Default::default() },
            90.0,
        );
        let r = rec("proj", OpKind::MatMul, 0.1, 50.0);
        let mut t = 0.0;
        for _ in 0..4 {
            aud.ingest_a(&r, seg_after(t, 50.0, 2000.0));
            t += 50.0;
        }
        for i in 0..2 {
            aud.ingest_b(&r, seg_after(i as f64 * 50.0, 50.0, 2000.0));
        }
        let s = aud.finish();
        assert!(!s.aligned, "surplus side-A events must break alignment");
        assert_eq!(s.unpaired, 2);
        assert_eq!(s.ops, 2); // only the matched prefix was audited
    }

    /// A one-sided flood (the other stream stalled or ended) must not
    /// grow pending memory with stream length: the surplus is dropped
    /// past the skew cap, counted as unpaired, and breaks alignment.
    #[test]
    fn one_sided_flood_is_capped() {
        let cap = 8;
        let mut aud = StreamAuditor::new(
            StreamConfig {
                window_ops: 4,
                hop_ops: 4,
                ring_cap: 8,
                max_pending: cap,
                nvml: None,
                ..Default::default()
            },
            90.0,
        );
        let r = rec("proj", OpKind::MatMul, 0.1, 50.0);
        let mut t = 0.0;
        for _ in 0..1000 {
            aud.ingest_a(&r, seg_after(t, 50.0, 2000.0));
            t += 50.0;
        }
        assert!(aud.ring_a.peak_retained <= 8);
        let s = aud.finish();
        assert!(!s.aligned);
        assert_eq!(s.unpaired, 1000); // dropped + still-pending
        assert_eq!(s.ops, 0);
        assert!(s.peak_pending <= cap + 1, "pending grew: {}", s.peak_pending);
    }

    /// The matched-history fingerprint is a stable workload identity:
    /// equal across both sides of an aligned audit and across two
    /// independent auditors fed the same workload.
    #[test]
    fn matched_history_fingerprint_is_stable() {
        let run = |energies: &[f64]| {
            let mut aud = StreamAuditor::new(
                StreamConfig { window_ops: 4, hop_ops: 4, nvml: None, ..Default::default() },
                90.0,
            );
            let mut t = 0.0;
            for (i, &e) in energies.iter().enumerate() {
                let label = if i % 2 == 0 { "proj" } else { "act" };
                let op = if i % 2 == 0 { OpKind::MatMul } else { OpKind::Gelu };
                aud.ingest_a(&rec(label, op, e, 50.0), seg_after(t, 50.0, 1000.0));
                aud.ingest_b(&rec(label, op, 0.1, 50.0), seg_after(t, 50.0, 1000.0));
                t += 50.0;
            }
            aud.finish()
        };
        // different energies, same op structure -> same fingerprint
        let s1 = run(&[0.1, 0.2, 0.3, 0.4]);
        let s2 = run(&[0.9, 0.8, 0.7, 0.6]);
        assert!(s1.aligned && s2.aligned);
        assert_eq!(s1.fingerprint_a, s1.fingerprint_b);
        assert_eq!(s1.fingerprint_a, s2.fingerprint_a);
        // different structure -> different fingerprint
        let s3 = run(&[0.1, 0.2]);
        assert_ne!(s1.fingerprint_a, s3.fingerprint_a);
    }

    #[test]
    fn equal_streams_produce_no_waste() {
        let mut aud = StreamAuditor::new(
            StreamConfig { window_ops: 4, hop_ops: 4, nvml: None, ..Default::default() },
            90.0,
        );
        let mut t = 0.0;
        for _ in 0..16 {
            let r = rec("proj", OpKind::MatMul, 0.1, 100.0);
            aud.ingest_a(&r, seg_after(t, 100.0, 1000.0));
            aud.ingest_b(&r, seg_after(t, 100.0, 1000.0));
            t += 100.0;
        }
        let s = aud.finish();
        assert_eq!(s.wasted_j, 0.0);
        assert_eq!(s.windows_flagged, 0);
        assert!(s.aligned);
    }

    /// A performance/energy trade-off (efficient side slower) must be
    /// annotated, not counted as waste.
    #[test]
    fn tradeoff_not_counted_as_waste() {
        let mut aud = StreamAuditor::new(
            StreamConfig { window_ops: 4, hop_ops: 4, nvml: None, ..Default::default() },
            90.0,
        );
        let (mut ta, mut tb) = (0.0, 0.0);
        for _ in 0..4 {
            // side A: more energy but much faster; B is "efficient" but slow
            aud.ingest_a(&rec("proj", OpKind::MatMul, 0.2, 50.0), seg_after(ta, 50.0, 4000.0));
            ta += 50.0;
            aud.ingest_b(&rec("proj", OpKind::MatMul, 0.1, 200.0), seg_after(tb, 200.0, 500.0));
            tb += 200.0;
        }
        let s = aud.finish();
        assert_eq!(s.windows, 1);
        assert_eq!(s.wasted_j, 0.0, "trade-off counted as waste");
        assert_eq!(s.windows_flagged, 0);
    }

    /// The incremental NVML cursor reads the ring without ever touching
    /// evicted history: readings stay finite and converge toward the
    /// recent power level.
    #[test]
    fn nvml_cursor_reads_ring() {
        // off-phase sample grid (step ≈ 997 µs vs 1000 µs segments) so
        // samples land inside segments, not on their idle boundaries
        let nvml = NvmlSampler { sample_hz: 1003.0, latency_us: 0.0, ema_alpha: 0.0 };
        let mut aud = StreamAuditor::new(
            StreamConfig { window_ops: 4, hop_ops: 4, ring_cap: 8, nvml: Some(nvml), ..Default::default() },
            90.0,
        );
        let mut t = 0.0;
        for _ in 0..100 {
            let r = rec("proj", OpKind::MatMul, 0.3, 1000.0);
            aud.ingest_a(&r, seg_after(t, 1000.0, 300.0));
            aud.ingest_b(&r, seg_after(t, 1000.0, 300.0));
            t += 1000.0;
        }
        let reading = aud.nvml_reading_a().expect("nvml configured");
        assert!((reading - 300.0).abs() < 1.0, "reading {reading}");
        // ring never grew past its capacity despite 100 segments
        assert_eq!(aud.ring_a.peak_retained, 8);
    }
}
