//! Online differential auditing of long-running serving traffic.
//!
//! The batch pipeline ([`crate::coordinator`]) audits finished,
//! fully-materialised runs: it needs both sides' complete
//! `RunArtifacts` in memory. Production serving traffic (the ML.ENERGY
//! / MLPerf-Power setting the ROADMAP points at) never finishes, so
//! this module audits *streams* instead: it ingests
//! `(KernelRecord, Segment)` events chunk-by-chunk from two live
//! executors (see [`crate::exec::StreamExec`]), maintains
//!
//! * a **sliding detection window** of the last `window_ops` matched op
//!   pairs with O(1) rolling cost sums,
//! * **rolling structural fingerprints** of each side's matched op
//!   history (polynomial hash over `(label, op)`), part of the
//!   alignment verdict and exported in the summary so operators can
//!   compare workloads across stream pairs and sessions,
//! * **resynchronisation** for diverged streams: when a positional
//!   pair disagrees on `(label, op)`, a bounded lookahead of both
//!   pending queues is searched for a new anchor using the per-event
//!   structural hashes; the minimal surplus is skipped, a
//!   [`ResyncEvent`] is recorded, and the window covering the
//!   divergence is **quarantined** (its waste excluded from the
//!   cumulative ledger) — so one dropped kernel poisons at most one
//!   window instead of every window after it,
//! * **content guards**: cheap per-op spectral moment sketches
//!   ([`crate::fingerprint::content_sketch`]) carried on
//!   [`KernelRecord`], compared per matched pair so streaming
//!   detection also guards output equivalence, not just structure,
//! * **ring-buffered power segments** ([`PowerRing`]) with eviction, so
//!   the retained power timeline — and through it the incremental NVML
//!   cursor ([`crate::energy::sampler::SamplerState`]) — is bounded by
//!   the ring capacity, never by the stream length; inter-request idle
//!   gaps ([`StreamAuditor::ingest_idle_a`]) are materialised as
//!   idle-power segments in the rings,
//!
//! and emits incremental [`WindowReport`]s (buffer bounded by
//! [`StreamConfig::max_emitted`]) plus a cumulative [`StreamSummary`]
//! without ever holding the full trace. With a snapshot sink attached
//! ([`StreamAuditor::set_sink`]), every emitted window, every
//! [`ResyncEvent`], and the final summary are also appended as durable
//! NDJSON snapshots ([`crate::telemetry`]) so the audit survives the
//! process and can be replayed offline (`magneton replay`).
//!
//! # The resync latch
//!
//! The anchor search after a positional mismatch costs
//! O(lookahead²·min_run) in the worst case. Running it once per op on a
//! *permanently* diverged pair (two streams that genuinely run
//! different workloads) would turn the auditor quadratic, so a
//! definitively failed search — both queues full to the lookahead with
//! no anchor — latches `diverged_mode`: pairing force-advances at O(1)
//! per op without re-scanning. The latch clears only after
//! [`StreamConfig::resync_min_run`] *consecutive* structural matches (a
//! demonstrated re-convergence; one coincidental match on a
//! quasi-diverged stream must not re-arm the scan), after which a later
//! dropped kernel is resynchronised normally again.

use std::collections::{BTreeMap, VecDeque};

use crate::detect::{DetectConfig, Side};
use crate::energy::sampler::{NvmlSampler, SamplerState};
use crate::energy::{PowerSource, Segment};
use crate::exec::{KernelRecord, Program};
use crate::fingerprint::{mix64, op_signature, WorkloadSig};
use crate::graph::OpKind;
use crate::telemetry::{SessionHeader, Snapshot, SnapshotSink};

/// Fixed-capacity ring of power segments: the bounded stand-in for a
/// full [`crate::energy::PowerTrace`] on an unbounded stream. Evicted
/// segments fold their energy into a running total, so cumulative
/// accounting stays exact while retained memory stays O(capacity).
#[derive(Clone, Debug)]
pub struct PowerRing {
    segs: VecDeque<Segment>,
    cap: usize,
    /// Power reported outside the retained span.
    pub idle_w: f64,
    /// Energy of evicted segments, Joules (exact cumulative bookkeeping).
    pub evicted_energy_j: f64,
    /// Number of evicted segments.
    pub evicted: usize,
    /// High-water mark of retained segments (≤ cap by construction;
    /// exposed so callers can assert the memory bound).
    pub peak_retained: usize,
}

impl PowerRing {
    pub fn new(cap: usize, idle_w: f64) -> PowerRing {
        assert!(cap > 0, "ring capacity must be positive");
        PowerRing {
            segs: VecDeque::with_capacity(cap),
            cap,
            idle_w,
            evicted_energy_j: 0.0,
            evicted: 0,
            peak_retained: 0,
        }
    }

    /// Append a segment, evicting the oldest when full. Segments must
    /// arrive in time order without overlapping the tail: the
    /// `power_at_us` binary search assumes segment *ends* are sorted,
    /// and an overlapping push would silently corrupt every later
    /// lookup. The tolerance absorbs float noise from the idle-gap
    /// time shifting (`(a + s) + g` vs `a + (s + g)`) and scales with
    /// absolute time so week-long streams don't trip it on ulps.
    pub fn push(&mut self, seg: Segment) {
        debug_assert!(
            self.segs.back().map_or(true, |b| {
                seg.t_start_us >= b.t_end_us - 1e-6f64.max(b.t_end_us.abs() * 1e-9)
            }),
            "out-of-order segment: t_start {} overlaps ring tail ending at {}",
            seg.t_start_us,
            self.segs.back().map(|b| b.t_end_us).unwrap_or(0.0),
        );
        if self.segs.len() == self.cap {
            let old = self.segs.pop_front().expect("cap > 0");
            self.evicted_energy_j += old.energy_j();
            self.evicted += 1;
        }
        self.segs.push_back(seg);
        if self.segs.len() > self.peak_retained {
            self.peak_retained = self.segs.len();
        }
    }

    pub fn len(&self) -> usize {
        self.segs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.segs.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// End timestamp of the newest retained segment, µs.
    pub fn t_now_us(&self) -> f64 {
        self.segs.back().map(|s| s.t_end_us).unwrap_or(0.0)
    }

    /// Start timestamp of the oldest retained segment, µs.
    pub fn t_oldest_us(&self) -> f64 {
        self.segs.front().map(|s| s.t_start_us).unwrap_or(0.0)
    }

    /// Energy of the retained segments only, Joules.
    pub fn retained_energy_j(&self) -> f64 {
        self.segs.iter().map(|s| s.energy_j()).sum()
    }

    /// Exact energy of the whole stream so far (retained + evicted).
    pub fn total_energy_j(&self) -> f64 {
        self.evicted_energy_j + self.retained_energy_j()
    }
}

impl PowerSource for PowerRing {
    /// Instantaneous power at `t_us`: binary search over the retained
    /// (contiguous, time-ordered) segments; idle outside them. Evicted
    /// history reads as idle — callers advancing a sampler cursor see
    /// it only if they lag the stream by more than the ring span.
    fn power_at_us(&self, t_us: f64) -> f64 {
        if self.segs.is_empty() {
            return self.idle_w;
        }
        let lo = self.segs.partition_point(|s| s.t_end_us <= t_us);
        if lo < self.segs.len() && self.segs[lo].t_start_us <= t_us {
            self.segs[lo].watts
        } else {
            self.idle_w
        }
    }

    fn idle_watts(&self) -> f64 {
        self.idle_w
    }
}

/// Configuration of a [`StreamAuditor`].
#[derive(Clone, Debug)]
pub struct StreamConfig {
    /// Sliding detection window, in matched op pairs.
    pub window_ops: usize,
    /// Window hop: a report is emitted every `hop_ops` ingested pairs.
    /// `hop_ops == window_ops` (the default) tiles the stream; smaller
    /// hops overlap windows for finer-grained rolling detection. The
    /// cumulative waste ledger attributes each matched pair exactly
    /// once regardless of overlap (only the pairs new since the last
    /// emission are ledgered) — which is why `hop_ops > window_ops` is
    /// rejected at construction: pairs sliding out between emissions
    /// would silently vanish from the ledger.
    pub hop_ops: usize,
    /// Power segments retained per side.
    pub ring_cap: usize,
    /// Largest inter-side ingestion skew buffered before surplus
    /// events are dropped (counted in `unpaired`, breaking alignment).
    /// Bounds pending memory on one-sided floods; callers that ingest
    /// in large one-sided chunks must size this to their chunk length.
    pub max_pending: usize,
    /// Bounded lookahead (events per side) searched for a new anchor
    /// after a positional `(label, op)` mismatch. `0` disables
    /// resynchronisation: a mismatch is force-paired and breaks
    /// alignment permanently (the pre-resync behaviour).
    pub resync_lookahead: usize,
    /// Consecutive structural matches required to accept a resync
    /// anchor mid-stream (at `finish` any fully-matching run is
    /// accepted, since no more events can arrive to confirm it).
    pub resync_min_run: usize,
    /// Relative tolerance for the per-op content-sketch comparison.
    /// Pairs whose sketches diverge beyond it are counted as content
    /// mismatches per window and cumulatively.
    pub content_eps: f64,
    /// Emitted-report buffer cap: once exceeded, the *oldest* buffered
    /// reports are dropped (counted in `reports_dropped`) so an
    /// undrained auditor cannot grow without bound. `0` = unbounded.
    pub max_emitted: usize,
    /// Detection thresholds (reused from the batch detector).
    pub cfg: DetectConfig,
    /// NVML model backing the rolling counter readout; `None` disables.
    pub nvml: Option<NvmlSampler>,
}

impl Default for StreamConfig {
    fn default() -> StreamConfig {
        StreamConfig {
            window_ops: 256,
            hop_ops: 256,
            ring_cap: 512,
            max_pending: 4096,
            resync_lookahead: 256,
            resync_min_run: 4,
            content_eps: 1e-3,
            max_emitted: 0,
            cfg: DetectConfig::default(),
            nvml: Some(NvmlSampler::default()),
        }
    }
}

impl StreamConfig {
    /// Digest of the comparison-relevant configuration, carried in the
    /// [`SessionHeader`]: two sessions persisted under different
    /// digests tiled their windows differently (or flagged at different
    /// thresholds), so their window sequences are not
    /// position-comparable even when the workload fingerprints match —
    /// `magneton diff` uses this to decide whether window alignment is
    /// meaningful.
    pub fn digest(&self) -> u64 {
        let fields: [u64; 8] = [
            self.window_ops as u64,
            self.hop_ops as u64,
            self.resync_lookahead as u64,
            self.resync_min_run as u64,
            self.content_eps.to_bits(),
            self.cfg.energy_threshold.to_bits(),
            self.cfg.perf_tolerance.to_bits(),
            self.cfg.output_tolerance.to_bits(),
        ];
        crate::util::fnv1a(fields.iter().flat_map(|v| v.to_le_bytes()))
    }
}

/// Cumulative per-label cost of the matched pairs of one stream audit —
/// the pair-level waste detector's per-label input, persisted at
/// `finish` (`Snapshot::Ledger`) so `magneton diff` can pair the
/// ledgers of two *sessions* of the same workload and run the
/// differential detector longitudinally.
#[derive(Clone, Debug, PartialEq)]
pub struct LabelLedger {
    pub label: String,
    /// Matched op pairs under this label.
    pub ops: usize,
    pub energy_a_j: f64,
    pub energy_b_j: f64,
    pub time_a_us: f64,
    pub time_b_us: f64,
}

impl LabelLedger {
    /// Fold another ledger cell for the same label into this one —
    /// the additive combine behind [`crate::telemetry::merge`]. Every
    /// field is a plain sum, so the operation is commutative over
    /// counts; callers wanting *bit-for-bit* reproducible float totals
    /// must apply it in one canonical order (float addition is not
    /// bitwise-associative), which is exactly what the merge's
    /// pair-name-ordered fold does.
    pub fn combine(&mut self, other: &LabelLedger) {
        debug_assert_eq!(self.label, other.label, "combine is per-label");
        self.ops += other.ops;
        self.energy_a_j += other.energy_a_j;
        self.energy_b_j += other.energy_b_j;
        self.time_a_us += other.time_a_us;
        self.time_b_us += other.time_b_us;
    }
}

/// One matched op pair in the sliding window.
#[derive(Clone, Debug)]
struct PairCost {
    label: String,
    /// Structural hash of the pair's `(label, op)` — folded (mixed)
    /// into the rolling window fingerprint.
    shash: u64,
    energy_a_j: f64,
    energy_b_j: f64,
    time_a_us: f64,
    time_b_us: f64,
    /// Whether the two sides' content sketches agreed (true when the
    /// guard is disabled on either side).
    content_ok: bool,
}

/// One side's pending (not yet paired) op event.
#[derive(Clone, Debug)]
struct OpEvent {
    label: String,
    op_name: &'static str,
    /// Structural hash of `(label, op)` — the unit the rolling
    /// fingerprints fold over and the resync anchor search compares.
    shash: u64,
    energy_j: f64,
    time_us: f64,
    /// Content sketch carried from the executor (may be empty).
    moments: Vec<f64>,
}

/// One recovered divergence: positional pairing disagreed, and the
/// auditor re-anchored by skipping the minimal surplus of pending
/// events on each side.
#[derive(Clone, Copy, Debug)]
pub struct ResyncEvent {
    /// Matched-pair count at which the divergence was detected.
    pub at_ops: usize,
    /// Events skipped from side A's pending queue to re-anchor.
    pub skipped_a: usize,
    /// Events skipped from side B's pending queue to re-anchor.
    pub skipped_b: usize,
}

/// Resync events retained in the summary log (counters are exact even
/// when the log saturates — bounded memory on pathological streams).
const RESYNC_LOG_CAP: usize = 32;

/// A per-label divergence flagged inside one window.
#[derive(Clone, Debug)]
pub struct StreamFinding {
    pub label: String,
    /// Matched op pairs under this label inside the window.
    pub ops: usize,
    pub energy_a_j: f64,
    pub energy_b_j: f64,
    pub time_a_us: f64,
    pub time_b_us: f64,
    /// |eA − eB| / max(eA, eB).
    pub diff_frac: f64,
    pub wasteful: Side,
    /// True when the efficient side pays more than the perf tolerance
    /// in time — a trade-off, not waste.
    pub is_tradeoff: bool,
}

impl StreamFinding {
    /// Joules of genuine waste this finding represents (0 for trade-offs).
    pub fn wasted_j(&self) -> f64 {
        if self.is_tradeoff {
            0.0
        } else {
            (self.energy_a_j - self.energy_b_j).abs()
        }
    }
}

/// Incremental detection report for one emitted window.
#[derive(Clone, Debug)]
pub struct WindowReport {
    /// 0-based index of the emitted window. Peeked (never-emitted)
    /// reports carry [`WindowReport::PEEK_SEQ`] instead, so they can
    /// never collide with the next emitted window's seq.
    pub seq: usize,
    /// Matched pairs inside the window.
    pub pairs: usize,
    pub energy_a_j: f64,
    pub energy_b_j: f64,
    pub time_a_us: f64,
    pub time_b_us: f64,
    pub findings: Vec<StreamFinding>,
    /// Joules of genuine (non-trade-off) waste across the findings.
    pub wasted_j: f64,
    /// Whether every pair since the last emission matched structurally.
    pub aligned: bool,
    /// Resyncs recovered inside this window.
    pub resyncs: usize,
    /// True when a resync poisoned this window: its findings are
    /// suspect and excluded from the cumulative waste ledger.
    pub quarantined: bool,
    /// Pairs in the window whose content sketches disagreed.
    pub content_mismatches: usize,
    /// Order-independent multiset hash over the `(label, op)`
    /// signatures of the pairs in this window. Two sessions of the same
    /// workload emit the same fingerprint sequence, so `magneton diff`
    /// can re-anchor their persisted window lists positionally
    /// (resync-style) without re-running the auditor.
    pub window_fp: u64,
}

impl WindowReport {
    /// Sentinel seq of a peeked ([`StreamAuditor::window_report`])
    /// report — never assigned to an emitted window.
    pub const PEEK_SEQ: usize = usize::MAX;
}

/// Cumulative state of a stream audit.
#[derive(Clone, Debug)]
pub struct StreamSummary {
    /// Matched op pairs ingested.
    pub ops: usize,
    /// Windows emitted.
    pub windows: usize,
    /// Exact cumulative energies (records, not ring-truncated).
    pub energy_a_j: f64,
    pub energy_b_j: f64,
    pub time_a_us: f64,
    pub time_b_us: f64,
    /// Joules of genuine waste accumulated over the ledger (each
    /// matched pair attributed exactly once; quarantined windows
    /// excluded).
    pub wasted_j: f64,
    /// Non-quarantined windows that contained at least one
    /// non-trade-off finding.
    pub windows_flagged: usize,
    /// Windows quarantined by a resync (waste excluded from the ledger).
    pub windows_quarantined: usize,
    /// Most wasteful labels: `(label, wasted_j, windows flagged in)`,
    /// descending by waste.
    pub top_labels: Vec<(String, f64, usize)>,
    /// The two streams ran the same workload in the same order: every
    /// matched pair agreed on `(label, op)`, no resync or flood drop
    /// was needed, the matched-history fingerprints are equal, and
    /// (after `finish`) no unpaired tail remained.
    pub aligned: bool,
    /// Rolling structural fingerprint of each side's matched op
    /// history — equal whenever `aligned`; stable across runs, so
    /// operators can compare workloads across stream pairs/sessions.
    pub fingerprint_a: u64,
    pub fingerprint_b: u64,
    /// Events that never got a partner: surplus of the longer stream,
    /// flood-dropped events, and events skipped by resyncs.
    pub unpaired: usize,
    /// Divergences recovered by re-anchoring.
    pub resyncs: usize,
    /// Total events skipped (both sides) across all resyncs.
    pub resync_skipped: usize,
    /// First [`RESYNC_LOG_CAP`] resync events (counters stay exact
    /// when the log saturates).
    pub resync_log: Vec<ResyncEvent>,
    /// Matched pairs whose content sketches disagreed (cumulative).
    pub content_mismatches: usize,
    /// Window reports dropped because the emitted buffer exceeded
    /// [`StreamConfig::max_emitted`] between drains.
    pub reports_dropped: usize,
    /// Memory high-water marks: retained power segments (≤ ring cap),
    /// window pairs, pending unpaired events.
    pub peak_retained_segments: usize,
    pub peak_window_pairs: usize,
    pub peak_pending: usize,
}

/// Outcome of the bounded anchor search after a positional mismatch.
enum Anchor {
    /// Skip this many pending events per side and resume pairing.
    Found { skip_a: usize, skip_b: usize },
    /// A candidate anchor exists but is too short to confirm (or the
    /// queues are shorter than the lookahead): wait for more events.
    NeedMore,
    /// No anchor inside the lookahead: the streams genuinely diverged.
    Diverged,
}

/// Online differential auditor over two op streams.
///
/// Feed it with [`StreamAuditor::ingest_a`] / [`StreamAuditor::ingest_b`]
/// (order between sides is free up to [`StreamConfig::max_pending`]
/// skew; pairing is positional with bounded-lookahead
/// resynchronisation), drain emitted windows with
/// [`StreamAuditor::take_emitted`], and finish with
/// [`StreamAuditor::finish`]. All retained state is bounded: window +
/// rings + per-label aggregates + at most `max_pending` pending events
/// per side + at most `max_emitted` undrained reports.
pub struct StreamAuditor {
    pub cfg: StreamConfig,
    window: VecDeque<PairCost>,
    win_e_a: f64,
    win_e_b: f64,
    win_t_a: f64,
    win_t_b: f64,
    /// Rolling order-independent multiset hash over the window's pair
    /// signatures (wrapping add on push, subtract on slide-out).
    win_fp: u64,
    /// Pairs in the window whose content sketches disagreed (rolling).
    win_content_bad: usize,
    pend_a: VecDeque<OpEvent>,
    pend_b: VecDeque<OpEvent>,
    /// Rolling structural fingerprints over the full matched history.
    fp_a: u64,
    fp_b: u64,
    /// Global: no divergence, resync, flood drop, or surplus ever.
    aligned: bool,
    /// Every pair since the last emission matched structurally.
    window_aligned: bool,
    /// A definitive anchor search already failed and pairing is
    /// force-advancing: skip the O(lookahead²) re-scan per pair until
    /// the streams demonstrably re-converge.
    diverged_mode: bool,
    /// Consecutive structurally-matched pairs (clears the diverged
    /// latch at `resync_min_run` — one coincidental match on a
    /// quasi-diverged stream must not re-trigger full anchor scans).
    matched_run: usize,
    /// Minimal-skip anchor candidate that fully matched but was too
    /// short to confirm: re-verified in O(min_run) on the next ingest
    /// instead of rescanning the whole O(lookahead²) candidate space.
    /// Invalidated whenever queue fronts shift (resync, flood drop).
    anchor_hint: Option<(usize, usize)>,
    /// The next emitted window covers a resync: quarantine it.
    quarantine_next: bool,
    window_resyncs: usize,
    resyncs: usize,
    resync_skipped: usize,
    resync_log: Vec<ResyncEvent>,
    /// Power rings (public: the example asserts the memory bound).
    pub ring_a: PowerRing,
    pub ring_b: PowerRing,
    /// Accumulated idle-gap shift applied to ingested segment times.
    shift_a: f64,
    shift_b: f64,
    sampler_a: SamplerState,
    sampler_b: SamplerState,
    pairs_since_hop: usize,
    emitted: VecDeque<WindowReport>,
    reports_dropped: usize,
    /// Durable telemetry hook: `(pair name, sink)`; every emitted
    /// window, resync, and the final summary are appended as snapshots.
    sink: Option<(String, SnapshotSink)>,
    /// Sink IO errors (counted, never unwinding the ingest hot path).
    sink_errors: usize,
    /// Pending events dropped after exceeding the skew cap.
    unpaired_dropped: usize,
    // cumulative accounting
    ops: usize,
    windows: usize,
    windows_flagged: usize,
    windows_quarantined: usize,
    cum_e_a: f64,
    cum_e_b: f64,
    cum_t_a: f64,
    cum_t_b: f64,
    cum_wasted_j: f64,
    cum_content_bad: usize,
    label_waste: BTreeMap<String, (f64, usize)>,
    /// Cumulative per-label pair costs:
    /// `(ops, energy_a, energy_b, time_a, time_b)` — every matched pair
    /// attributed, persisted at `finish` as a `Snapshot::Ledger`.
    label_ledger: BTreeMap<String, (usize, f64, f64, f64, f64)>,
    /// Session header applied to any attached sink (see
    /// [`StreamAuditor::set_session_header`]).
    session: Option<SessionHeader>,
    peak_window_pairs: usize,
    peak_pending: usize,
}

/// Structural identity of one op — shared with the session-level
/// workload fingerprint ([`crate::fingerprint::op_signature`]) so a
/// workload hashes identically online and in persisted session headers.
fn op_hash(label: &str, op_name: &str) -> u64 {
    op_signature(label, op_name)
}

/// Static workload signature of a program: the `(label, op)` multiset
/// the executor will emit kernel records for — every node except
/// `Input`/`Weight`/`Output` sources/sinks and the zero-copy metadata
/// ops (`Permute`/`Reshape`), exactly the skip rule
/// [`crate::exec::Executor::run`] and [`crate::exec::StreamExec`]
/// apply, so the static fingerprint equals the one an auditor would
/// observe from the emitted kernel stream. Computable *before* any
/// execution, which is what lets `magneton stream` write the
/// [`SessionHeader`] first in the snapshot series; and because
/// [`WorkloadSig`]'s fold is commutative, two deploys of the same
/// workload produce the same fingerprint however their streams
/// interleave.
pub fn workload_sig_of_program(prog: &Program) -> WorkloadSig {
    let mut sig = WorkloadSig::new();
    for node in &prog.graph.nodes {
        if matches!(
            node.op,
            OpKind::Input | OpKind::Weight | OpKind::Output | OpKind::Permute | OpKind::Reshape
        ) {
            continue;
        }
        sig.add(&node.label, node.op.name());
    }
    sig
}

/// Relative agreement of two content sketches. Empty sketches (guard
/// disabled on either side) always agree.
fn moments_close(a: &[f64], b: &[f64], eps: f64) -> bool {
    if a.is_empty() || b.is_empty() {
        return true;
    }
    if a.len() != b.len() {
        return false;
    }
    a.iter()
        .zip(b.iter())
        .all(|(x, y)| (x - y).abs() <= eps * x.abs().max(y.abs()).max(1e-30))
}

impl StreamAuditor {
    pub fn new(cfg: StreamConfig, idle_w: f64) -> StreamAuditor {
        assert!(cfg.window_ops > 0 && cfg.hop_ops > 0, "window/hop must be positive");
        assert!(
            cfg.hop_ops <= cfg.window_ops,
            "hop {} exceeds window {}: pairs sliding out between emissions would never reach the waste ledger",
            cfg.hop_ops,
            cfg.window_ops
        );
        assert!(
            cfg.resync_lookahead == 0
                || cfg.resync_lookahead + cfg.resync_min_run.max(1) <= cfg.max_pending,
            "resync lookahead {} + confirmation run {} exceeds the pending cap {}: an anchor near the \
             lookahead boundary would be flood-dropped before it can be confirmed",
            cfg.resync_lookahead,
            cfg.resync_min_run.max(1),
            cfg.max_pending
        );
        let ring_a = PowerRing::new(cfg.ring_cap, idle_w);
        let ring_b = PowerRing::new(cfg.ring_cap, idle_w);
        StreamAuditor {
            window: VecDeque::with_capacity(cfg.window_ops),
            win_e_a: 0.0,
            win_e_b: 0.0,
            win_t_a: 0.0,
            win_t_b: 0.0,
            win_fp: 0,
            win_content_bad: 0,
            pend_a: VecDeque::new(),
            pend_b: VecDeque::new(),
            fp_a: 0,
            fp_b: 0,
            aligned: true,
            window_aligned: true,
            diverged_mode: false,
            matched_run: 0,
            anchor_hint: None,
            quarantine_next: false,
            window_resyncs: 0,
            resyncs: 0,
            resync_skipped: 0,
            resync_log: Vec::new(),
            ring_a,
            ring_b,
            shift_a: 0.0,
            shift_b: 0.0,
            sampler_a: SamplerState::new(idle_w),
            sampler_b: SamplerState::new(idle_w),
            pairs_since_hop: 0,
            emitted: VecDeque::new(),
            reports_dropped: 0,
            sink: None,
            sink_errors: 0,
            unpaired_dropped: 0,
            ops: 0,
            windows: 0,
            windows_flagged: 0,
            windows_quarantined: 0,
            cum_e_a: 0.0,
            cum_e_b: 0.0,
            cum_t_a: 0.0,
            cum_t_b: 0.0,
            cum_wasted_j: 0.0,
            cum_content_bad: 0,
            label_waste: BTreeMap::new(),
            label_ledger: BTreeMap::new(),
            session: None,
            peak_window_pairs: 0,
            peak_pending: 0,
            cfg,
        }
    }

    /// Attach a durable snapshot sink: every window emitted from now
    /// on, every [`ResyncEvent`], and the final summary (at
    /// [`StreamAuditor::finish`]) are appended as NDJSON snapshots
    /// attributed to `pair`. Sink IO failures are counted in
    /// [`StreamAuditor::sink_errors`] rather than unwinding ingestion —
    /// a full disk must not kill a live audit.
    pub fn set_sink(&mut self, pair: &str, mut sink: SnapshotSink) {
        if let Some(h) = &self.session {
            if sink.set_header(&Snapshot::Session { header: h.clone() }).is_err() {
                self.sink_errors += 1;
            }
        }
        self.sink = Some((pair.to_string(), sink));
    }

    /// Stamp this audit with a session identity: the header is pinned
    /// to the attached sink (or to the next one attached), written
    /// first in its snapshot series and re-written across rotations, so
    /// the persisted session stays joinable with other deploys of the
    /// same workload (`magneton diff`).
    pub fn set_session_header(&mut self, header: SessionHeader) {
        if let Some((_, sink)) = &mut self.sink {
            if sink.set_header(&Snapshot::Session { header: header.clone() }).is_err() {
                self.sink_errors += 1;
            }
        }
        self.session = Some(header);
    }

    /// Cumulative per-label pair-cost ledger (label-sorted), valid
    /// mid-stream. Quarantined windows' pairs are included — the ledger
    /// tracks cost, not verdicts — while the *waste* ledger in the
    /// summary stays quarantine-filtered.
    pub fn label_ledger(&self) -> Vec<LabelLedger> {
        self.label_ledger
            .iter()
            .map(|(label, &(ops, ea, eb, ta, tb))| LabelLedger {
                label: label.clone(),
                ops,
                energy_a_j: ea,
                energy_b_j: eb,
                time_a_us: ta,
                time_b_us: tb,
            })
            .collect()
    }

    /// Detach and return the sink (to inspect rotation counters or
    /// hand it to another auditor).
    pub fn take_sink(&mut self) -> Option<SnapshotSink> {
        self.sink.take().map(|(_, s)| s)
    }

    /// Snapshot-sink IO errors so far (0 when no sink is attached).
    pub fn sink_errors(&self) -> usize {
        self.sink_errors
    }

    fn sink_window(&mut self, report: &WindowReport) {
        if let Some((pair, sink)) = &mut self.sink {
            let snap = Snapshot::Window { pair: pair.clone(), report: report.clone() };
            if sink.append(&snap).is_err() {
                self.sink_errors += 1;
            }
        }
    }

    fn sink_resync(&mut self, event: ResyncEvent) {
        if let Some((pair, sink)) = &mut self.sink {
            let snap = Snapshot::Resync { pair: pair.clone(), event };
            if sink.append(&snap).is_err() {
                self.sink_errors += 1;
            }
        }
    }

    fn sink_summary(&mut self, summary: &StreamSummary) {
        if let Some((pair, sink)) = &mut self.sink {
            let snap = Snapshot::Summary { pair: pair.clone(), summary: summary.clone() };
            if sink.append(&snap).is_err() {
                self.sink_errors += 1;
            }
        }
    }

    /// Ingest one op event from side A.
    pub fn ingest_a(&mut self, rec: &KernelRecord, seg: Segment) {
        self.ingest(Side::A, rec, seg)
    }

    /// Ingest one op event from side B.
    pub fn ingest_b(&mut self, rec: &KernelRecord, seg: Segment) {
        self.ingest(Side::B, rec, seg)
    }

    /// Materialise an inter-request idle gap on side A: the ring gains
    /// an idle-power segment and every later ingested segment is
    /// shifted by the gap, so the power timeline shows the lull a real
    /// arrival process produces (and the NVML cursor reads it).
    pub fn ingest_idle_a(&mut self, gap_us: f64) {
        self.ingest_idle(Side::A, gap_us)
    }

    /// Materialise an inter-request idle gap on side B.
    pub fn ingest_idle_b(&mut self, gap_us: f64) {
        self.ingest_idle(Side::B, gap_us)
    }

    fn ingest_idle(&mut self, side: Side, gap_us: f64) {
        if gap_us <= 0.0 {
            return;
        }
        let (ring, shift) = match side {
            Side::A => (&mut self.ring_a, &mut self.shift_a),
            Side::B => (&mut self.ring_b, &mut self.shift_b),
        };
        let t0 = ring.t_now_us();
        let idle_w = ring.idle_w;
        ring.push(Segment { t_start_us: t0, t_end_us: t0 + gap_us, watts: idle_w });
        *shift += gap_us;
    }

    /// Shared ingestion body — side-symmetry is structural, not by
    /// copy-paste convention.
    fn ingest(&mut self, side: Side, rec: &KernelRecord, seg: Segment) {
        let (ring, pend, cum_e, cum_t, shift) = match side {
            Side::A => {
                (&mut self.ring_a, &mut self.pend_a, &mut self.cum_e_a, &mut self.cum_t_a, self.shift_a)
            }
            Side::B => {
                (&mut self.ring_b, &mut self.pend_b, &mut self.cum_e_b, &mut self.cum_t_b, self.shift_b)
            }
        };
        // re-time the executor's segment past any materialised idle gaps
        ring.push(Segment {
            t_start_us: seg.t_start_us + shift,
            t_end_us: seg.t_end_us + shift,
            watts: seg.watts,
        });
        *cum_e += rec.energy_j;
        *cum_t += rec.time_us;
        pend.push_back(OpEvent {
            shash: op_hash(&rec.label, rec.op.name()),
            label: rec.label.clone(),
            op_name: rec.op.name(),
            energy_j: rec.energy_j,
            time_us: rec.time_us,
            moments: rec.moments.clone(),
        });
        self.drain(false);
    }

    /// Pair pending events positionally, resynchronising across
    /// divergences, and slide the window. `finishing` relaxes the
    /// anchor-confirmation rule (no more events will ever arrive) and
    /// force-pairs what cannot be anchored.
    fn drain(&mut self, finishing: bool) {
        let pending = self.pend_a.len().max(self.pend_b.len());
        if pending > self.peak_pending {
            self.peak_pending = pending;
        }
        while !self.pend_a.is_empty() && !self.pend_b.is_empty() {
            let fronts_match = {
                let (a, b) = (&self.pend_a[0], &self.pend_b[0]);
                a.shash == b.shash && a.label == b.label && a.op_name == b.op_name
            };
            if fronts_match {
                self.pair_fronts();
                continue;
            }
            if self.diverged_mode {
                // a definitive search already failed: force-advance at
                // O(1) per pair instead of re-scanning the lookahead
                self.pair_fronts();
                continue;
            }
            match self.find_anchor(finishing) {
                Anchor::Found { skip_a, skip_b } => {
                    for _ in 0..skip_a {
                        self.pend_a.pop_front();
                    }
                    for _ in 0..skip_b {
                        self.pend_b.pop_front();
                    }
                    self.resyncs += 1;
                    self.resync_skipped += skip_a + skip_b;
                    let ev = ResyncEvent { at_ops: self.ops, skipped_a: skip_a, skipped_b: skip_b };
                    if self.resync_log.len() < RESYNC_LOG_CAP {
                        self.resync_log.push(ev);
                    }
                    // the sink persists every event, even past the
                    // in-memory log cap — that is its whole point
                    self.sink_resync(ev);
                    // the divergence is recovered, but the window it
                    // happened in cannot be trusted
                    self.aligned = false;
                    self.window_aligned = false;
                    self.window_resyncs += 1;
                    self.quarantine_next = true;
                }
                Anchor::NeedMore => break,
                Anchor::Diverged => {
                    self.diverged_mode = true;
                    self.pair_fronts();
                }
            }
        }
        // bound the surplus side: drop (and count) events beyond the
        // skew cap so pending memory never scales with stream length
        let cap = self.cfg.max_pending;
        while self.pend_a.len() > cap {
            self.pend_a.pop_front();
            self.unpaired_dropped += 1;
            self.aligned = false;
            // queue fronts shifted: any cached anchor indices are stale
            self.anchor_hint = None;
        }
        while self.pend_b.len() > cap {
            self.pend_b.pop_front();
            self.unpaired_dropped += 1;
            self.aligned = false;
            self.anchor_hint = None;
        }
    }

    /// Pop and pair the two front events (force-pairing a structural
    /// mismatch when called on diverged fronts), then slide the window.
    fn pair_fronts(&mut self) {
        let a = self.pend_a.pop_front().expect("checked non-empty");
        let b = self.pend_b.pop_front().expect("checked non-empty");
        if a.shash != b.shash || a.label != b.label || a.op_name != b.op_name {
            // unrecoverable divergence (no anchor found): positional
            // pairing continues, but the audit is permanently suspect
            self.aligned = false;
            self.window_aligned = false;
            self.matched_run = 0;
        } else {
            // only a demonstrated re-convergence run lifts the diverged
            // latch — a lone coincidental match on a quasi-diverged
            // stream must not re-trigger full anchor scans per op
            self.matched_run += 1;
            if self.matched_run >= self.cfg.resync_min_run.max(1) {
                self.diverged_mode = false;
            }
        }
        // rolling fingerprints over the *matched* history: equal
        // whenever the streams ran the same ops in the same order,
        // and exported so operators can compare workloads across
        // stream pairs and sessions
        self.fp_a = self.fp_a.rotate_left(1) ^ a.shash;
        self.fp_b = self.fp_b.rotate_left(1) ^ b.shash;
        self.ops += 1;
        let content_ok = moments_close(&a.moments, &b.moments, self.cfg.content_eps);
        if !content_ok {
            self.cum_content_bad += 1;
        }
        // per-label pair-cost ledger: every matched pair attributed
        // (cost accounting, independent of the quarantine-filtered
        // waste ledger)
        if let Some(cell) = self.label_ledger.get_mut(&a.label) {
            cell.0 += 1;
            cell.1 += a.energy_j;
            cell.2 += b.energy_j;
            cell.3 += a.time_us;
            cell.4 += b.time_us;
        } else {
            self.label_ledger
                .insert(a.label.clone(), (1, a.energy_j, b.energy_j, a.time_us, b.time_us));
        }
        let pair = PairCost {
            label: a.label,
            shash: a.shash,
            energy_a_j: a.energy_j,
            energy_b_j: b.energy_j,
            time_a_us: a.time_us,
            time_b_us: b.time_us,
            content_ok,
        };
        self.win_e_a += pair.energy_a_j;
        self.win_e_b += pair.energy_b_j;
        self.win_t_a += pair.time_a_us;
        self.win_t_b += pair.time_b_us;
        self.win_fp = self.win_fp.wrapping_add(mix64(pair.shash));
        if !pair.content_ok {
            self.win_content_bad += 1;
        }
        self.window.push_back(pair);
        if self.window.len() > self.cfg.window_ops {
            let old = self.window.pop_front().expect("over capacity");
            self.win_e_a -= old.energy_a_j;
            self.win_e_b -= old.energy_b_j;
            self.win_t_a -= old.time_a_us;
            self.win_t_b -= old.time_b_us;
            self.win_fp = self.win_fp.wrapping_sub(mix64(old.shash));
            if !old.content_ok {
                self.win_content_bad -= 1;
            }
        }
        if self.window.len() > self.peak_window_pairs {
            self.peak_window_pairs = self.window.len();
        }
        self.pairs_since_hop += 1;
        if self.pairs_since_hop >= self.cfg.hop_ops && self.window.len() >= self.cfg.window_ops {
            let n_new = self.pairs_since_hop.min(self.window.len());
            self.pairs_since_hop = 0;
            self.emit_window(n_new);
        }
    }

    /// Structural agreement run at a candidate anchor, capped at the
    /// confirmation target: `(run, want)` where `want` is how many
    /// comparisons were possible.
    fn anchor_run(&self, skip_a: usize, skip_b: usize, run_target: usize) -> (usize, usize) {
        let avail = (self.pend_a.len() - skip_a).min(self.pend_b.len() - skip_b);
        let want = run_target.min(avail);
        let run = (0..want)
            .take_while(|&t| self.pend_a[skip_a + t].shash == self.pend_b[skip_b + t].shash)
            .count();
        (run, want)
    }

    /// Bounded lookahead over both pending queues for a re-anchoring
    /// point after the fronts disagreed: the `(skip_a, skip_b)` with
    /// the smallest total skip whose structural hashes agree for
    /// [`StreamConfig::resync_min_run`] consecutive events.
    fn find_anchor(&mut self, finishing: bool) -> Anchor {
        let lookahead = self.cfg.resync_lookahead;
        if lookahead == 0 {
            return Anchor::Diverged;
        }
        let run_target = self.cfg.resync_min_run.max(1);
        // fast path: a previous full scan already picked its minimal-
        // skip candidate and is only waiting for confirmation events —
        // one O(run_target) re-check instead of a full lookahead scan.
        // Already-mismatched candidates stay mismatched, so the hint is
        // preferred until it confirms or breaks; it resolves within
        // run_target further ingests either way.
        if let Some((skip_a, skip_b)) = self.anchor_hint {
            if skip_a < self.pend_a.len() && skip_b < self.pend_b.len() {
                let (run, want) = self.anchor_run(skip_a, skip_b, run_target);
                if run == want && run > 0 {
                    if run >= run_target || finishing {
                        self.anchor_hint = None;
                        return Anchor::Found { skip_a, skip_b };
                    }
                    return Anchor::NeedMore;
                }
            }
            // the candidate broke on extension: rescan from scratch
            self.anchor_hint = None;
        }
        let la = self.pend_a.len().min(lookahead);
        let lb = self.pend_b.len().min(lookahead);
        let mut need_more = false;
        // minimal total surplus first: the cheapest explanation of the
        // divergence (one dropped kernel => skip exactly one event)
        for d in 1..(la + lb) {
            for skip_a in d.saturating_sub(lb - 1)..=d.min(la - 1) {
                let skip_b = d - skip_a;
                let (run, want) = self.anchor_run(skip_a, skip_b, run_target);
                if run == want && run > 0 {
                    if run >= run_target || finishing {
                        return Anchor::Found { skip_a, skip_b };
                    }
                    // everything available matches, but the run is too
                    // short to be confident: remember the candidate and
                    // wait for more events
                    if !need_more {
                        self.anchor_hint = Some((skip_a, skip_b));
                        need_more = true;
                    }
                }
            }
        }
        if need_more && !finishing {
            return Anchor::NeedMore;
        }
        if !finishing && (self.pend_a.len() < lookahead || self.pend_b.len() < lookahead) {
            // the anchor may simply not have been ingested yet
            return Anchor::NeedMore;
        }
        Anchor::Diverged
    }

    /// Detect per-label divergence over a set of window pairs.
    fn findings_over<'a>(&self, pairs: impl Iterator<Item = &'a PairCost>) -> Vec<StreamFinding> {
        let mut by_label: BTreeMap<&str, (usize, f64, f64, f64, f64)> = BTreeMap::new();
        for p in pairs {
            let cell = by_label.entry(p.label.as_str()).or_insert((0, 0.0, 0.0, 0.0, 0.0));
            cell.0 += 1;
            cell.1 += p.energy_a_j;
            cell.2 += p.energy_b_j;
            cell.3 += p.time_a_us;
            cell.4 += p.time_b_us;
        }
        let mut findings = Vec::new();
        for (label, (ops, ea, eb, ta, tb)) in by_label {
            if ea <= 0.0 && eb <= 0.0 {
                continue;
            }
            let diff = (ea - eb).abs() / ea.max(eb);
            if diff < self.cfg.cfg.energy_threshold {
                continue;
            }
            let wasteful = if ea > eb { Side::A } else { Side::B };
            let (t_waste, t_eff) = match wasteful {
                Side::A => (ta, tb),
                Side::B => (tb, ta),
            };
            let is_tradeoff = t_eff > t_waste * (1.0 + self.cfg.cfg.perf_tolerance);
            findings.push(StreamFinding {
                label: label.to_string(),
                ops,
                energy_a_j: ea,
                energy_b_j: eb,
                time_a_us: ta,
                time_b_us: tb,
                diff_frac: diff,
                wasteful,
                is_tradeoff,
            });
        }
        findings.sort_by(|x, y| {
            let kx = x.energy_a_j.max(x.energy_b_j) * x.diff_frac;
            let ky = y.energy_a_j.max(y.energy_b_j) * y.diff_frac;
            ky.total_cmp(&kx)
        });
        findings
    }

    /// Build a report over the current window contents.
    fn build_report(&self, seq: usize, quarantined: bool) -> WindowReport {
        let findings = self.findings_over(self.window.iter());
        let wasted_j = findings.iter().map(|f| f.wasted_j()).sum();
        WindowReport {
            seq,
            pairs: self.window.len(),
            energy_a_j: self.win_e_a,
            energy_b_j: self.win_e_b,
            time_a_us: self.win_t_a,
            time_b_us: self.win_t_b,
            findings,
            wasted_j,
            aligned: self.window_aligned,
            resyncs: self.window_resyncs,
            quarantined,
            content_mismatches: self.win_content_bad,
            window_fp: self.win_fp,
        }
    }

    /// Peek a report over the current window without emitting it. The
    /// peek carries [`WindowReport::PEEK_SEQ`]: seqs are assigned only
    /// at emission, so drained sequences stay gap-free and unique.
    pub fn window_report(&self) -> WindowReport {
        self.build_report(WindowReport::PEEK_SEQ, self.quarantine_next)
    }

    /// Emit the current window. `n_new` is the number of pairs added
    /// since the previous emission: only those are attributed to the
    /// cumulative waste ledger, so overlapping windows
    /// (`hop_ops < window_ops`) never double-count a pair.
    fn emit_window(&mut self, n_new: usize) {
        let quarantined = self.quarantine_next;
        let report = self.build_report(self.windows, quarantined);
        self.windows += 1;
        if quarantined {
            self.windows_quarantined += 1;
        } else {
            if report.findings.iter().any(|f| !f.is_tradeoff) {
                self.windows_flagged += 1;
            }
            let skip = self.window.len() - n_new;
            let ledger = self.findings_over(self.window.iter().skip(skip));
            for f in &ledger {
                if !f.is_tradeoff {
                    self.cum_wasted_j += f.wasted_j();
                    let cell = self.label_waste.entry(f.label.clone()).or_insert((0.0, 0));
                    cell.0 += f.wasted_j();
                    cell.1 += 1;
                }
            }
        }
        self.sink_window(&report);
        self.emitted.push_back(report);
        if self.cfg.max_emitted > 0 {
            while self.emitted.len() > self.cfg.max_emitted {
                self.emitted.pop_front();
                self.reports_dropped += 1;
            }
        }
        self.window_aligned = true;
        self.window_resyncs = 0;
        self.quarantine_next = false;
    }

    /// Drain the window reports emitted since the last call (bounded by
    /// [`StreamConfig::max_emitted`] regardless of drain cadence).
    pub fn take_emitted(&mut self) -> Vec<WindowReport> {
        self.emitted.drain(..).collect()
    }

    /// The NVML counter reading visible *now* on side A's ring, through
    /// the incremental cursor (O(new samples) per call).
    pub fn nvml_reading_a(&mut self) -> Option<f64> {
        self.nvml_reading(Side::A)
    }

    /// The NVML counter reading visible *now* on side B's ring.
    pub fn nvml_reading_b(&mut self) -> Option<f64> {
        self.nvml_reading(Side::B)
    }

    fn nvml_reading(&mut self, side: Side) -> Option<f64> {
        let nvml = self.cfg.nvml.clone()?;
        let (ring, state) = match side {
            Side::A => (&self.ring_a, &mut self.sampler_a),
            Side::B => (&self.ring_b, &mut self.sampler_b),
        };
        Some(nvml.advance(state, ring, ring.t_now_us()))
    }

    /// Cumulative summary so far (valid mid-stream).
    pub fn summary(&self) -> StreamSummary {
        let mut top: Vec<(String, f64, usize)> = self
            .label_waste
            .iter()
            .map(|(l, &(j, n))| (l.clone(), j, n))
            .collect();
        top.sort_by(|x, y| y.1.total_cmp(&x.1).then_with(|| x.0.cmp(&y.0)));
        StreamSummary {
            ops: self.ops,
            windows: self.windows,
            energy_a_j: self.cum_e_a,
            energy_b_j: self.cum_e_b,
            time_a_us: self.cum_t_a,
            time_b_us: self.cum_t_b,
            wasted_j: self.cum_wasted_j,
            windows_flagged: self.windows_flagged,
            windows_quarantined: self.windows_quarantined,
            top_labels: top,
            aligned: self.aligned && self.fp_a == self.fp_b,
            fingerprint_a: self.fp_a,
            fingerprint_b: self.fp_b,
            unpaired: self.pend_a.len() + self.pend_b.len() + self.unpaired_dropped + self.resync_skipped,
            resyncs: self.resyncs,
            resync_skipped: self.resync_skipped,
            resync_log: self.resync_log.clone(),
            content_mismatches: self.cum_content_bad,
            reports_dropped: self.reports_dropped,
            peak_retained_segments: self.ring_a.peak_retained.max(self.ring_b.peak_retained),
            peak_window_pairs: self.peak_window_pairs,
            peak_pending: self.peak_pending,
        }
    }

    /// Resolve any pending divergence (final resyncs / forced pairs),
    /// flush a partial trailing window, and return the final summary.
    /// The trailing emission ledgers only the pairs added since the
    /// last hop, so every matched pair is counted exactly once.
    pub fn finish(&mut self) -> StreamSummary {
        self.drain(true);
        // a surplus on either side means the streams did not run the
        // same workload: flag it rather than silently reporting the
        // (incomparable) cumulative energies as a clean audit
        if !self.pend_a.is_empty() || !self.pend_b.is_empty() {
            self.aligned = false;
        }
        if self.pairs_since_hop > 0 {
            let n_new = self.pairs_since_hop.min(self.window.len());
            self.pairs_since_hop = 0;
            self.emit_window(n_new);
        }
        let summary = self.summary();
        self.sink_summary(&summary);
        // the per-label ledger rides behind the summary so a persisted
        // session can be differenced against another deploy's ledger
        let ledger = self.label_ledger();
        if let Some((pair, sink)) = &mut self.sink {
            let snap = Snapshot::Ledger { pair: pair.clone(), entries: ledger };
            if sink.append(&snap).is_err() {
                self.sink_errors += 1;
            }
        }
        summary
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::energy::PowerTrace;
    use crate::graph::OpKind;
    use crate::trace::Frame;

    fn rec(label: &str, op: OpKind, energy_j: f64, time_us: f64) -> KernelRecord {
        rec_m(label, op, energy_j, time_us, vec![])
    }

    fn rec_m(label: &str, op: OpKind, energy_j: f64, time_us: f64, moments: Vec<f64>) -> KernelRecord {
        KernelRecord {
            node: 0,
            op,
            label: label.to_string(),
            api: "api".into(),
            dispatch_key: op.name().to_string(),
            kernel: format!("k_{label}"),
            time_us,
            energy_j,
            avg_power_w: energy_j / (time_us * 1e-6),
            corr_id: 0,
            bb_trace: vec![],
            call_path: vec![Frame::py("serve")],
            moments,
        }
    }

    fn seg_after(t0: f64, dur: f64, watts: f64) -> Segment {
        Segment { t_start_us: t0, t_end_us: t0 + dur, watts }
    }

    /// The serving-shaped op cycle used by the resync tests: period 5,
    /// per-kind energies distinct enough that any mispairing flags.
    fn cycle_op(i: usize) -> (&'static str, OpKind, f64) {
        match i % 5 {
            0 => ("serve.proj", OpKind::MatMul, 0.30),
            1 => ("serve.scale", OpKind::Mul, 0.02),
            2 => ("serve.act", OpKind::Gelu, 0.05),
            3 => ("serve.out", OpKind::MatMul, 0.30),
            _ => ("serve.softmax", OpKind::Softmax, 0.08),
        }
    }

    /// Feed `n` cycle ops to both sides, dropping the event at global
    /// index `skip` on side A (None = identical streams).
    fn run_with_skip(cfg: StreamConfig, n: usize, skip: Option<usize>) -> (StreamAuditor, Vec<WindowReport>) {
        let mut aud = StreamAuditor::new(cfg, 90.0);
        let (mut ta, mut tb) = (0.0, 0.0);
        let mut reports = Vec::new();
        for i in 0..n {
            let (label, op, e) = cycle_op(i);
            if skip != Some(i) {
                aud.ingest_a(&rec(label, op, e, 100.0), seg_after(ta, 100.0, e / 100e-6));
                ta += 100.0;
            }
            aud.ingest_b(&rec(label, op, e, 100.0), seg_after(tb, 100.0, e / 100e-6));
            tb += 100.0;
            reports.append(&mut aud.take_emitted());
        }
        (aud, reports)
    }

    #[test]
    fn ring_evicts_but_keeps_exact_total() {
        let mut ring = PowerRing::new(4, 90.0);
        let mut t = 0.0;
        let mut expect = 0.0;
        for i in 0..10 {
            let w = 100.0 + i as f64;
            ring.push(seg_after(t, 1000.0, w));
            expect += w * 1000.0 * 1e-6;
            t += 1000.0;
        }
        assert_eq!(ring.len(), 4);
        assert_eq!(ring.evicted, 6);
        assert_eq!(ring.peak_retained, 4);
        assert!((ring.total_energy_j() - expect).abs() < 1e-12);
        // power lookups: inside the retained span, outside it, and gaps
        assert_eq!(ring.power_at_us(6500.0), 106.0);
        assert_eq!(ring.power_at_us(500.0), 90.0); // evicted -> idle
        assert_eq!(ring.power_at_us(20_000.0), 90.0); // future -> idle
        assert_eq!(ring.t_oldest_us(), 6000.0);
        assert_eq!(ring.t_now_us(), 10_000.0);
    }

    /// Ring and trace must agree on boundary semantics everywhere:
    /// interior points, segment starts, shared boundaries (`t ==
    /// t_end_us` of one segment == `t_start_us` of the next), the final
    /// end, and beyond — the contract `partition_point` must preserve.
    #[test]
    fn ring_and_trace_agree_on_boundary_semantics() {
        let durs = [1000.0, 500.0, 2000.0, 750.0];
        let watts = [100.0, 250.0, 180.0, 310.0];
        let mut ring = PowerRing::new(8, 90.0);
        let mut trace = PowerTrace::new(90.0);
        let mut t = 0.0;
        for (d, w) in durs.iter().zip(watts.iter()) {
            ring.push(seg_after(t, *d, *w));
            trace.push(*d, *w);
            t += d;
        }
        let mut probes = vec![0.0, 1.0, 999.0];
        let mut acc = 0.0;
        for d in durs {
            acc += d;
            probes.push(acc); // every t_end_us (== next t_start_us)
            probes.push(acc - 0.5);
            probes.push(acc + 0.5);
        }
        for p in probes {
            assert_eq!(
                ring.power_at_us(p),
                trace.power_at(p),
                "ring and trace disagree at t={p}"
            );
        }
        // t == final t_end_us reads as idle on both
        assert_eq!(trace.power_at(t), 90.0);
        assert_eq!(ring.power_at_us(t), 90.0);
    }

    /// Out-of-order segments would corrupt the binary search; the push
    /// asserts the timeline stays monotone.
    #[test]
    #[should_panic(expected = "out-of-order segment")]
    #[cfg(debug_assertions)]
    fn out_of_order_push_asserts() {
        let mut ring = PowerRing::new(4, 90.0);
        ring.push(seg_after(1000.0, 100.0, 200.0));
        ring.push(seg_after(0.0, 100.0, 200.0));
    }

    /// Feed two streams with a wasteful label on side A; the auditor
    /// must flag it window after window, with memory bounded.
    #[test]
    fn auditor_flags_wasteful_label_incrementally() {
        let cfg = StreamConfig {
            window_ops: 8,
            hop_ops: 8,
            ring_cap: 16,
            nvml: None,
            ..Default::default()
        };
        let mut aud = StreamAuditor::new(cfg, 90.0);
        let (mut ta, mut tb) = (0.0, 0.0);
        for i in 0..64 {
            let label = if i % 2 == 0 { "proj" } else { "act" };
            let op = if i % 2 == 0 { OpKind::MatMul } else { OpKind::Gelu };
            // side A burns 1.5x energy on proj at equal time
            let (ea, eb) = if i % 2 == 0 { (0.15, 0.10) } else { (0.02, 0.02) };
            aud.ingest_a(&rec(label, op, ea, 100.0), seg_after(ta, 100.0, ea / 100e-6));
            ta += 100.0;
            aud.ingest_b(&rec(label, op, eb, 100.0), seg_after(tb, 100.0, eb / 100e-6));
            tb += 100.0;
        }
        let reports = aud.take_emitted();
        assert_eq!(reports.len(), 8); // 64 pairs / hop 8
        for r in &reports {
            assert!(r.aligned);
            assert!(!r.quarantined);
            assert_eq!(r.pairs, 8);
            assert_eq!(r.findings.len(), 1, "only proj should be flagged");
            let f = &r.findings[0];
            assert_eq!(f.label, "proj");
            assert_eq!(f.wasteful, Side::A);
            assert!(!f.is_tradeoff);
            assert!(f.diff_frac > 0.30);
        }
        let s = aud.finish();
        assert_eq!(s.ops, 64);
        assert_eq!(s.windows, 8);
        assert_eq!(s.windows_flagged, 8);
        // waste = 4 proj pairs per window x 0.05 J x 8 windows
        assert!((s.wasted_j - 8.0 * 4.0 * 0.05).abs() < 1e-9);
        assert_eq!(s.top_labels[0].0, "proj");
        assert!(s.aligned);
        assert_eq!(s.resyncs, 0);
        assert_eq!(s.content_mismatches, 0);
        // memory bounds: ring capped, window capped, pairing keeps up
        assert!(s.peak_retained_segments <= 16);
        assert_eq!(s.peak_window_pairs, 8);
        assert!(s.peak_pending <= 2);
    }

    #[test]
    fn misaligned_streams_are_reported() {
        let mut aud = StreamAuditor::new(
            StreamConfig { window_ops: 2, hop_ops: 2, ..Default::default() },
            90.0,
        );
        aud.ingest_a(&rec("proj", OpKind::MatMul, 0.1, 50.0), seg_after(0.0, 50.0, 200.0));
        aud.ingest_b(&rec("act", OpKind::Gelu, 0.1, 50.0), seg_after(0.0, 50.0, 200.0));
        let s = aud.finish();
        assert!(!s.aligned);
        assert_ne!(s.fingerprint_a, s.fingerprint_b);
    }

    /// A surplus of events on one side (streams of different length)
    /// must flag the audit as misaligned instead of reporting the
    /// incomparable cumulative energies as clean.
    #[test]
    fn unequal_length_streams_flagged_misaligned() {
        let mut aud = StreamAuditor::new(
            StreamConfig { window_ops: 2, hop_ops: 2, nvml: None, ..Default::default() },
            90.0,
        );
        let r = rec("proj", OpKind::MatMul, 0.1, 50.0);
        let mut t = 0.0;
        for _ in 0..4 {
            aud.ingest_a(&r, seg_after(t, 50.0, 2000.0));
            t += 50.0;
        }
        for i in 0..2 {
            aud.ingest_b(&r, seg_after(i as f64 * 50.0, 50.0, 2000.0));
        }
        let s = aud.finish();
        assert!(!s.aligned, "surplus side-A events must break alignment");
        assert_eq!(s.unpaired, 2);
        assert_eq!(s.ops, 2); // only the matched prefix was audited
    }

    /// A one-sided flood (the other stream stalled or ended) must not
    /// grow pending memory with stream length: the surplus is dropped
    /// past the skew cap, counted as unpaired, and breaks alignment.
    #[test]
    fn one_sided_flood_is_capped() {
        let cap = 8;
        let mut aud = StreamAuditor::new(
            StreamConfig {
                window_ops: 4,
                hop_ops: 4,
                ring_cap: 8,
                max_pending: cap,
                resync_lookahead: 4,
                nvml: None,
                ..Default::default()
            },
            90.0,
        );
        let r = rec("proj", OpKind::MatMul, 0.1, 50.0);
        let mut t = 0.0;
        for _ in 0..1000 {
            aud.ingest_a(&r, seg_after(t, 50.0, 2000.0));
            t += 50.0;
        }
        assert!(aud.ring_a.peak_retained <= 8);
        let s = aud.finish();
        assert!(!s.aligned);
        assert_eq!(s.unpaired, 1000); // dropped + still-pending
        assert_eq!(s.ops, 0);
        assert!(s.peak_pending <= cap + 1, "pending grew: {}", s.peak_pending);
    }

    /// The matched-history fingerprint is a stable workload identity:
    /// equal across both sides of an aligned audit and across two
    /// independent auditors fed the same workload.
    #[test]
    fn matched_history_fingerprint_is_stable() {
        let run = |energies: &[f64]| {
            let mut aud = StreamAuditor::new(
                StreamConfig { window_ops: 4, hop_ops: 4, nvml: None, ..Default::default() },
                90.0,
            );
            let mut t = 0.0;
            for (i, &e) in energies.iter().enumerate() {
                let label = if i % 2 == 0 { "proj" } else { "act" };
                let op = if i % 2 == 0 { OpKind::MatMul } else { OpKind::Gelu };
                aud.ingest_a(&rec(label, op, e, 50.0), seg_after(t, 50.0, 1000.0));
                aud.ingest_b(&rec(label, op, 0.1, 50.0), seg_after(t, 50.0, 1000.0));
                t += 50.0;
            }
            aud.finish()
        };
        // different energies, same op structure -> same fingerprint
        let s1 = run(&[0.1, 0.2, 0.3, 0.4]);
        let s2 = run(&[0.9, 0.8, 0.7, 0.6]);
        assert!(s1.aligned && s2.aligned);
        assert_eq!(s1.fingerprint_a, s1.fingerprint_b);
        assert_eq!(s1.fingerprint_a, s2.fingerprint_a);
        // different structure -> different fingerprint
        let s3 = run(&[0.1, 0.2]);
        assert_ne!(s1.fingerprint_a, s3.fingerprint_a);
    }

    /// Window fingerprints are stable workload identities: two
    /// independent audits of the same workload emit bit-identical
    /// fingerprint sequences, and a different workload emits different
    /// ones — the property `magneton diff` aligns sessions by.
    #[test]
    fn window_fingerprints_reproduce_across_independent_audits() {
        let cfg = || StreamConfig {
            window_ops: 50,
            hop_ops: 50,
            ring_cap: 64,
            nvml: None,
            ..Default::default()
        };
        let (_, r1) = run_with_skip(cfg(), 500, None);
        let (_, r2) = run_with_skip(cfg(), 500, None);
        let f1: Vec<u64> = r1.iter().map(|w| w.window_fp).collect();
        let f2: Vec<u64> = r2.iter().map(|w| w.window_fp).collect();
        assert_eq!(f1.len(), 10);
        assert_eq!(f1, f2, "same workload must emit the same window fingerprints");
        // a structurally different stream fingerprints differently
        let mut aud = StreamAuditor::new(cfg(), 90.0);
        let mut t = 0.0;
        for _ in 0..50 {
            let r = rec("other.label", OpKind::MatMul, 0.1, 100.0);
            aud.ingest_a(&r, seg_after(t, 100.0, 1000.0));
            aud.ingest_b(&r, seg_after(t, 100.0, 1000.0));
            t += 100.0;
        }
        let other = aud.take_emitted();
        assert_eq!(other.len(), 1);
        assert_ne!(other[0].window_fp, f1[0]);
    }

    /// The static program signature agrees with a manual fold over the
    /// op sequence the executor emits — the contract that makes a
    /// pre-stream `SessionHeader` honest about the workload.
    #[test]
    fn program_workload_sig_matches_manual_fold() {
        use crate::workload::{serving_stream_program, ServingStream};
        let spec = ServingStream { requests: 7, batch: 4, d_model: 8 };
        let mut rng = crate::util::Prng::new(3);
        let prog = serving_stream_program(&mut rng, &spec);
        let sig = workload_sig_of_program(&prog);
        assert_eq!(sig.total_ops(), spec.kernel_ops());
        let mut manual = WorkloadSig::new();
        for _ in 0..spec.requests {
            manual.add("serve.proj", "matmul");
            manual.add("serve.scale", "scale");
            manual.add("serve.act", "gelu");
            manual.add("serve.out", "matmul");
            manual.add("serve.softmax", "softmax");
        }
        assert_eq!(sig.fp(), manual.fp());
        assert_eq!(sig.label_counts(), manual.label_counts());
        // zero-copy metadata ops (no kernel record) are excluded, so
        // the static fingerprint matches the observable stream
        let mut g = crate::graph::Graph::new("meta");
        let x = g.add(OpKind::Input, &[], "x");
        let p = g.add_attr1(OpKind::Permute, &[x], "perm", "perm", "1,0");
        let m = g.add(OpKind::Gelu, &[p], "act");
        g.add(OpKind::Output, &[m], "out");
        let meta_sig = workload_sig_of_program(&Program::new(g));
        assert_eq!(meta_sig.total_ops(), 1, "permute must not count as a kernel op");
        assert_eq!(meta_sig.label_counts(), vec![("act".to_string(), 1)]);
        // the config digest separates detection-relevant configs
        let base = StreamConfig { nvml: None, ..Default::default() };
        let mut other = base.clone();
        other.window_ops = base.window_ops + 1;
        assert_ne!(base.digest(), other.digest());
        assert_eq!(base.digest(), StreamConfig { nvml: None, ..Default::default() }.digest());
    }

    /// The per-label ledger attributes every matched pair exactly once
    /// and sums back to the cumulative energies.
    #[test]
    fn label_ledger_sums_to_cumulative_energies() {
        let cfg = StreamConfig { window_ops: 25, hop_ops: 25, nvml: None, ..Default::default() };
        let (mut aud, _) = run_with_skip(cfg, 200, None);
        let s = aud.finish();
        let ledger = aud.label_ledger();
        assert_eq!(ledger.len(), 5, "five cycle labels");
        assert_eq!(ledger.iter().map(|e| e.ops).sum::<usize>(), s.ops);
        let ea: f64 = ledger.iter().map(|e| e.energy_a_j).sum();
        let eb: f64 = ledger.iter().map(|e| e.energy_b_j).sum();
        assert!((ea - s.energy_a_j).abs() < 1e-9);
        assert!((eb - s.energy_b_j).abs() < 1e-9);
        // label-sorted, per-label counts match the cycle shares
        for e in &ledger {
            assert_eq!(e.ops, 40, "{}", e.label);
        }
    }

    #[test]
    fn equal_streams_produce_no_waste() {
        let mut aud = StreamAuditor::new(
            StreamConfig { window_ops: 4, hop_ops: 4, nvml: None, ..Default::default() },
            90.0,
        );
        let mut t = 0.0;
        for _ in 0..16 {
            let r = rec("proj", OpKind::MatMul, 0.1, 100.0);
            aud.ingest_a(&r, seg_after(t, 100.0, 1000.0));
            aud.ingest_b(&r, seg_after(t, 100.0, 1000.0));
            t += 100.0;
        }
        let s = aud.finish();
        assert_eq!(s.wasted_j, 0.0);
        assert_eq!(s.windows_flagged, 0);
        assert!(s.aligned);
    }

    /// A performance/energy trade-off (efficient side slower) must be
    /// annotated, not counted as waste.
    #[test]
    fn tradeoff_not_counted_as_waste() {
        let mut aud = StreamAuditor::new(
            StreamConfig { window_ops: 4, hop_ops: 4, nvml: None, ..Default::default() },
            90.0,
        );
        let (mut ta, mut tb) = (0.0, 0.0);
        for _ in 0..4 {
            // side A: more energy but much faster; B is "efficient" but slow
            aud.ingest_a(&rec("proj", OpKind::MatMul, 0.2, 50.0), seg_after(ta, 50.0, 4000.0));
            ta += 50.0;
            aud.ingest_b(&rec("proj", OpKind::MatMul, 0.1, 200.0), seg_after(tb, 200.0, 500.0));
            tb += 200.0;
        }
        let s = aud.finish();
        assert_eq!(s.windows, 1);
        assert_eq!(s.wasted_j, 0.0, "trade-off counted as waste");
        assert_eq!(s.windows_flagged, 0);
    }

    /// The incremental NVML cursor reads the ring without ever touching
    /// evicted history: readings stay finite and converge toward the
    /// recent power level.
    #[test]
    fn nvml_cursor_reads_ring() {
        // off-phase sample grid (step ≈ 997 µs vs 1000 µs segments) so
        // samples land inside segments, not on their idle boundaries
        let nvml = NvmlSampler { sample_hz: 1003.0, latency_us: 0.0, ema_alpha: 0.0 };
        let mut aud = StreamAuditor::new(
            StreamConfig { window_ops: 4, hop_ops: 4, ring_cap: 8, nvml: Some(nvml), ..Default::default() },
            90.0,
        );
        let mut t = 0.0;
        for _ in 0..100 {
            let r = rec("proj", OpKind::MatMul, 0.3, 1000.0);
            aud.ingest_a(&r, seg_after(t, 1000.0, 300.0));
            aud.ingest_b(&r, seg_after(t, 1000.0, 300.0));
            t += 1000.0;
        }
        let reading = aud.nvml_reading_a().expect("nvml configured");
        assert!((reading - 300.0).abs() < 1.0, "reading {reading}");
        // ring never grew past its capacity despite 100 segments
        assert_eq!(aud.ring_a.peak_retained, 8);
    }

    /// The tentpole acceptance scenario: one skipped kernel on side A
    /// of an otherwise identical 1000-op stream pair. The auditor must
    /// re-anchor immediately (skipping exactly the dropped kernel's
    /// partner), quarantine the one poisoned window, and keep every
    /// later window aligned with zero spurious findings.
    #[test]
    fn resync_after_single_skipped_kernel() {
        let cfg = StreamConfig {
            window_ops: 100,
            hop_ops: 100,
            ring_cap: 128,
            nvml: None,
            ..Default::default()
        };
        let (mut aud, mut reports) = run_with_skip(cfg, 1000, Some(437));
        let s = aud.finish();
        reports.append(&mut aud.take_emitted());

        assert_eq!(s.resyncs, 1);
        assert_eq!(s.resync_log.len(), 1);
        // divergence detected at the skipped position; B's surplus
        // partner (the kernel A dropped) is the only skipped event
        assert_eq!(s.resync_log[0].at_ops, 437);
        assert_eq!(s.resync_log[0].skipped_a, 0);
        assert_eq!(s.resync_log[0].skipped_b, 1);
        assert_eq!(s.unpaired, 1);
        assert_eq!(s.ops, 999);
        // exactly one window poisoned; its waste is not ledgered
        assert_eq!(s.windows_quarantined, 1);
        assert_eq!(s.wasted_j, 0.0);
        assert_eq!(s.windows_flagged, 0, "spurious findings after resync");
        // a recovered divergence is still a divergence overall
        assert!(!s.aligned);
        // exactly one drained report is quarantined; every other window
        // is clean and aligned — one dropped kernel poisons at most one
        let quarantined: Vec<&WindowReport> = reports.iter().filter(|r| r.quarantined).collect();
        assert_eq!(quarantined.len(), 1);
        assert_eq!(quarantined[0].resyncs, 1);
        assert!(!quarantined[0].aligned);
        for r in reports.iter().filter(|r| !r.quarantined) {
            assert!(r.aligned, "window #{} misaligned after resync", r.seq);
            assert!(r.findings.is_empty(), "window #{} has spurious findings", r.seq);
        }
        // post-resync matched histories agree
        assert_eq!(s.fingerprint_a, s.fingerprint_b);
    }

    /// The same scenario with resynchronisation disabled reproduces the
    /// old failure mode: every window after the skip is misaligned and
    /// flags garbage findings from shifted pairing.
    #[test]
    fn without_resync_one_skip_poisons_every_later_window() {
        let cfg = StreamConfig {
            window_ops: 100,
            hop_ops: 100,
            ring_cap: 128,
            resync_lookahead: 0, // the pre-resync behaviour
            nvml: None,
            ..Default::default()
        };
        let (mut aud, mut reports) = run_with_skip(cfg, 1000, Some(437));
        let s = aud.finish();
        reports.append(&mut aud.take_emitted());
        assert!(!s.aligned);
        assert_eq!(s.resyncs, 0);
        // shifted pairing garbles per-label sums: windows past the skip
        // are all misaligned and flag spurious waste
        let poisoned = reports.iter().filter(|r| !r.aligned).count();
        assert!(poisoned >= 5, "only {poisoned} poisoned windows");
        assert!(s.windows_flagged >= 5);
        assert!(s.wasted_j > 0.0);
        assert_ne!(s.fingerprint_a, s.fingerprint_b);
    }

    /// Clean streams through the same harness: no resyncs, no
    /// quarantine, fully aligned (guards the test harness itself).
    #[test]
    fn identical_streams_never_resync() {
        let cfg = StreamConfig { window_ops: 100, hop_ops: 100, ring_cap: 128, nvml: None, ..Default::default() };
        let (mut aud, reports) = run_with_skip(cfg, 1000, None);
        let s = aud.finish();
        assert!(s.aligned);
        assert_eq!(s.resyncs, 0);
        assert_eq!(s.windows_quarantined, 0);
        assert!(reports.iter().all(|r| r.aligned && !r.quarantined));
    }

    /// Overlapping windows (`hop_ops < window_ops`) must not inflate
    /// the cumulative ledger: halving the hop cannot change the total
    /// waste, because each matched pair is attributed exactly once.
    #[test]
    fn overlapping_windows_do_not_double_count_waste() {
        let run = |hop: usize| {
            let cfg = StreamConfig { window_ops: 8, hop_ops: hop, nvml: None, ..Default::default() };
            let mut aud = StreamAuditor::new(cfg, 90.0);
            let (mut ta, mut tb) = (0.0, 0.0);
            for i in 0..64 {
                let label = if i % 2 == 0 { "proj" } else { "act" };
                let op = if i % 2 == 0 { OpKind::MatMul } else { OpKind::Gelu };
                let (ea, eb) = if i % 2 == 0 { (0.15, 0.10) } else { (0.02, 0.02) };
                aud.ingest_a(&rec(label, op, ea, 100.0), seg_after(ta, 100.0, ea / 100e-6));
                ta += 100.0;
                aud.ingest_b(&rec(label, op, eb, 100.0), seg_after(tb, 100.0, eb / 100e-6));
                tb += 100.0;
            }
            aud.finish()
        };
        let tiled = run(8);
        let overlap2 = run(4);
        let overlap4 = run(2);
        // 32 proj pairs x 0.05 J, exactly once each, at every hop
        assert!((tiled.wasted_j - 32.0 * 0.05).abs() < 1e-9, "tiled {}", tiled.wasted_j);
        assert!(
            (overlap2.wasted_j - tiled.wasted_j).abs() < 1e-9,
            "hop 4 inflated waste: {} vs {}",
            overlap2.wasted_j,
            tiled.wasted_j
        );
        assert!(
            (overlap4.wasted_j - tiled.wasted_j).abs() < 1e-9,
            "hop 2 inflated waste: {} vs {}",
            overlap4.wasted_j,
            tiled.wasted_j
        );
        // overlap emits more windows, but the ledger is hop-invariant
        assert!(overlap4.windows > tiled.windows);
    }

    /// Peeked reports must not collide with emitted seqs: drained
    /// sequences are strictly increasing and gap-free no matter how
    /// often the caller peeks.
    #[test]
    fn peeked_reports_do_not_collide_with_emitted_seqs() {
        let cfg = StreamConfig { window_ops: 2, hop_ops: 2, nvml: None, ..Default::default() };
        let mut aud = StreamAuditor::new(cfg, 90.0);
        let mut t = 0.0;
        let mut drained = Vec::new();
        for i in 0..10 {
            let r = rec("proj", OpKind::MatMul, 0.1, 100.0);
            aud.ingest_a(&r, seg_after(t, 100.0, 1000.0));
            aud.ingest_b(&r, seg_after(t, 100.0, 1000.0));
            t += 100.0;
            // peek between every ingest: must never consume a seq
            let peek = aud.window_report();
            assert_eq!(peek.seq, WindowReport::PEEK_SEQ, "peek #{i} stole a seq");
            drained.append(&mut aud.take_emitted());
        }
        aud.finish();
        drained.append(&mut aud.take_emitted());
        assert_eq!(drained.len(), 5);
        for (i, r) in drained.iter().enumerate() {
            assert_eq!(r.seq, i, "emitted seqs must be gap-free");
        }
    }

    /// Content sketches diverging beyond the tolerance are counted per
    /// window and cumulatively, even when the structure matches.
    #[test]
    fn content_guard_flags_diverging_outputs() {
        let cfg = StreamConfig { window_ops: 4, hop_ops: 4, nvml: None, ..Default::default() };
        let mut aud = StreamAuditor::new(cfg, 90.0);
        let mut t = 0.0;
        for i in 0..8 {
            // same (label, op) and energy; outputs differ on odd ops
            let ma = vec![100.0, 10_000.0];
            let mb = if i % 2 == 1 { vec![103.0, 10_600.0] } else { ma.clone() };
            aud.ingest_a(&rec_m("proj", OpKind::MatMul, 0.1, 100.0, ma), seg_after(t, 100.0, 1000.0));
            aud.ingest_b(&rec_m("proj", OpKind::MatMul, 0.1, 100.0, mb), seg_after(t, 100.0, 1000.0));
            t += 100.0;
        }
        let reports = aud.take_emitted();
        assert_eq!(reports.len(), 2);
        for r in &reports {
            assert_eq!(r.content_mismatches, 2, "2 of 4 window pairs diverge");
            assert!(r.aligned, "content divergence is not structural misalignment");
        }
        let s = aud.finish();
        assert_eq!(s.content_mismatches, 4);
        // energies equal: no waste — the content guard is orthogonal
        assert_eq!(s.wasted_j, 0.0);
    }

    /// Sketch-free records (the guard disabled) never count as content
    /// mismatches, and a tolerance-sized wobble is not flagged.
    #[test]
    fn content_guard_tolerates_disabled_and_small_noise() {
        let cfg = StreamConfig { window_ops: 2, hop_ops: 2, nvml: None, ..Default::default() };
        let mut aud = StreamAuditor::new(cfg, 90.0);
        // disabled on side B
        aud.ingest_a(&rec_m("p", OpKind::MatMul, 0.1, 50.0, vec![1.0, 2.0]), seg_after(0.0, 50.0, 100.0));
        aud.ingest_b(&rec("p", OpKind::MatMul, 0.1, 50.0), seg_after(0.0, 50.0, 100.0));
        // within tolerance (1e-3 relative)
        aud.ingest_a(&rec_m("p", OpKind::MatMul, 0.1, 50.0, vec![1.0, 2.0]), seg_after(50.0, 50.0, 100.0));
        aud.ingest_b(
            &rec_m("p", OpKind::MatMul, 0.1, 50.0, vec![1.0000001, 2.0000002]),
            seg_after(50.0, 50.0, 100.0),
        );
        let s = aud.finish();
        assert_eq!(s.content_mismatches, 0);
    }

    /// Idle gaps must materialise as idle power in the ring and shift
    /// later segments so the timeline stays monotone and contiguous.
    #[test]
    fn idle_gaps_materialise_idle_power() {
        let cfg = StreamConfig { window_ops: 4, hop_ops: 4, ring_cap: 16, nvml: None, ..Default::default() };
        let mut aud = StreamAuditor::new(cfg, 90.0);
        let r = rec("proj", OpKind::MatMul, 0.1, 100.0);
        // executor timeline is contiguous from 0; gaps come from the caller
        aud.ingest_a(&r, seg_after(0.0, 100.0, 1000.0));
        aud.ingest_b(&r, seg_after(0.0, 100.0, 1000.0));
        aud.ingest_idle_a(400.0);
        aud.ingest_idle_b(400.0);
        aud.ingest_a(&r, seg_after(100.0, 100.0, 1000.0));
        aud.ingest_b(&r, seg_after(100.0, 100.0, 1000.0));
        // ring timeline: [0,100) busy, [100,500) idle, [500,600) busy
        assert_eq!(aud.ring_a.len(), 3);
        assert_eq!(aud.ring_a.power_at_us(50.0), 1000.0);
        assert_eq!(aud.ring_a.power_at_us(300.0), 90.0, "gap must read as idle power");
        assert_eq!(aud.ring_a.power_at_us(550.0), 1000.0);
        assert_eq!(aud.ring_a.t_now_us(), 600.0);
        // gaps carry no op events: pairing and energy are unaffected
        let s = aud.finish();
        assert_eq!(s.ops, 2);
        assert!(s.aligned);
        assert!((s.energy_a_j - 0.2).abs() < 1e-12);
    }

    /// An undrained auditor must not grow its report buffer without
    /// bound: the oldest reports are dropped, counted, and the
    /// survivors keep their (strictly increasing) emitted seqs.
    #[test]
    fn emitted_buffer_is_bounded_by_max_emitted() {
        let cfg = StreamConfig { window_ops: 1, hop_ops: 1, max_emitted: 4, nvml: None, ..Default::default() };
        let mut aud = StreamAuditor::new(cfg, 90.0);
        let r = rec("proj", OpKind::MatMul, 0.1, 100.0);
        let mut t = 0.0;
        for _ in 0..20 {
            aud.ingest_a(&r, seg_after(t, 100.0, 1000.0));
            aud.ingest_b(&r, seg_after(t, 100.0, 1000.0));
            t += 100.0;
        }
        let reports = aud.take_emitted();
        assert_eq!(reports.len(), 4, "buffer exceeded max_emitted");
        let seqs: Vec<usize> = reports.iter().map(|r| r.seq).collect();
        assert_eq!(seqs, vec![16, 17, 18, 19]);
        let s = aud.finish();
        assert_eq!(s.reports_dropped, 16);
        // dropped reports were still ledgered before being dropped
        assert_eq!(s.windows, 20);
    }

    /// `hop_ops > window_ops` would let pairs slide out of the window
    /// between emissions without ever reaching the waste ledger — the
    /// constructor rejects the configuration outright.
    #[test]
    #[should_panic(expected = "hop")]
    fn hop_larger_than_window_is_rejected() {
        StreamAuditor::new(
            StreamConfig { window_ops: 4, hop_ops: 8, nvml: None, ..Default::default() },
            90.0,
        );
    }

    /// Permanently diverged streams must keep force-advancing (one
    /// definitive anchor search, then O(1) per pair via the diverged
    /// latch) — and the latch must clear when the streams re-converge,
    /// so a later dropped kernel is still resynchronised.
    #[test]
    fn diverged_latch_force_pairs_then_clears_on_reconvergence() {
        let cfg = StreamConfig {
            window_ops: 4,
            hop_ops: 4,
            resync_lookahead: 8,
            max_pending: 64,
            nvml: None,
            ..Default::default()
        };
        let mut aud = StreamAuditor::new(cfg, 90.0);
        let mut t = 0.0;
        // phase 1: the two sides run entirely different workloads
        for _ in 0..100 {
            aud.ingest_a(&rec("proj", OpKind::MatMul, 0.1, 50.0), seg_after(t, 50.0, 2000.0));
            aud.ingest_b(&rec("act", OpKind::Gelu, 0.1, 50.0), seg_after(t, 50.0, 2000.0));
            t += 50.0;
        }
        // every pair was force-advanced despite the failed anchor search
        assert_eq!(aud.summary().ops, 100);
        assert_eq!(aud.summary().resyncs, 0);
        // phase 2: the streams re-converge, then side A drops a kernel —
        // the resync machinery must be live again
        for i in 0..40 {
            let (label, op, e) = cycle_op(i);
            if i != 20 {
                aud.ingest_a(&rec(label, op, e, 50.0), seg_after(t, 50.0, 2000.0));
            }
            aud.ingest_b(&rec(label, op, e, 50.0), seg_after(t, 50.0, 2000.0));
            t += 50.0;
        }
        let s = aud.finish();
        assert!(!s.aligned);
        assert_eq!(s.resyncs, 1, "resync must work again after re-convergence");
        assert_eq!(s.ops, 100 + 39);
        assert_eq!(s.resync_skipped, 1);
    }

    /// With a snapshot sink attached, every emitted window, every
    /// resync event, and the final summary land on disk as replayable
    /// NDJSON snapshots, and the persisted waste ledger is
    /// bit-identical to the live one.
    #[test]
    fn sink_persists_windows_resyncs_and_summary() {
        use crate::telemetry::{load_dir, SinkConfig, Snapshot, SnapshotSink};
        let dir =
            std::env::temp_dir().join(format!("magneton-stream-sink-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let cfg = StreamConfig {
            window_ops: 100,
            hop_ops: 100,
            ring_cap: 128,
            nvml: None,
            ..Default::default()
        };
        let mut aud = StreamAuditor::new(cfg, 90.0);
        aud.set_sink("pair-0", SnapshotSink::new(&dir, "pair-0", SinkConfig::default()).unwrap());
        let (mut ta, mut tb) = (0.0, 0.0);
        for i in 0..1000 {
            let (label, op, e) = cycle_op(i);
            if i != 437 {
                aud.ingest_a(&rec(label, op, e, 100.0), seg_after(ta, 100.0, e / 100e-6));
                ta += 100.0;
            }
            aud.ingest_b(&rec(label, op, e, 100.0), seg_after(tb, 100.0, e / 100e-6));
            tb += 100.0;
        }
        let live = aud.finish();
        assert_eq!(aud.sink_errors(), 0);
        let snaps = load_dir(&dir).expect("snapshots load back");
        let (mut windows, mut resyncs, mut summaries) = (0usize, 0usize, Vec::new());
        let mut ledgers = Vec::new();
        for s in snaps {
            match s {
                Snapshot::Window { pair, .. } => {
                    assert_eq!(pair, "pair-0");
                    windows += 1;
                }
                Snapshot::Resync { event, .. } => {
                    assert_eq!(event.at_ops, 437);
                    resyncs += 1;
                }
                Snapshot::Summary { summary, .. } => summaries.push(summary),
                Snapshot::Ledger { entries, .. } => ledgers.push(entries),
                other => panic!("unexpected snapshot {other:?}"),
            }
        }
        assert_eq!(windows, live.windows, "every emitted window must be persisted");
        assert_eq!(resyncs, 1);
        assert_eq!(summaries.len(), 1, "finish persists exactly one summary");
        assert_eq!(ledgers.len(), 1, "finish persists exactly one per-label ledger");
        // the persisted ledger sums back to the exact cumulative
        // energies of the matched pairs
        let led_ops: usize = ledgers[0].iter().map(|e| e.ops).sum();
        assert_eq!(led_ops, live.ops);
        let led_e_a: f64 = ledgers[0].iter().map(|e| e.energy_a_j).sum();
        assert!((led_e_a - summaries[0].energy_a_j).abs() < 1e-9 * summaries[0].energy_a_j.max(1.0));
        let s = &summaries[0];
        assert_eq!(s.wasted_j.to_bits(), live.wasted_j.to_bits(), "ledger must be bit-identical");
        assert_eq!(s.fingerprint_a, live.fingerprint_a);
        assert_eq!(s.fingerprint_b, live.fingerprint_b);
        assert_eq!(s.ops, live.ops);
        assert_eq!(s.windows_quarantined, live.windows_quarantined);
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// `take_sink` hands the sink — with its file index and byte
    /// accounting intact — to a fresh auditor, which continues the
    /// same snapshot series (the safe way to resume a series: a new
    /// `SnapshotSink::new` on the same directory would restart its
    /// indices and budget from zero).
    #[test]
    fn sink_hand_off_continues_the_same_file_series() {
        use crate::telemetry::{load_dir, SinkConfig, Snapshot, SnapshotSink};
        let dir =
            std::env::temp_dir().join(format!("magneton-stream-handoff-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let cfg = StreamConfig { window_ops: 2, hop_ops: 2, nvml: None, ..Default::default() };
        let mut aud = StreamAuditor::new(cfg.clone(), 90.0);
        aud.set_sink("pair-0", SnapshotSink::new(&dir, "pair-0", SinkConfig::default()).unwrap());
        let r = rec("proj", OpKind::MatMul, 0.1, 100.0);
        let mut t = 0.0;
        for _ in 0..4 {
            aud.ingest_a(&r, seg_after(t, 100.0, 1000.0));
            aud.ingest_b(&r, seg_after(t, 100.0, 1000.0));
            t += 100.0;
        }
        aud.finish(); // 2 windows + 1 summary + 1 ledger
        let sink = aud.take_sink().expect("sink was attached");
        let first_session_written = sink.written;
        assert_eq!(first_session_written, 4);
        assert!(aud.take_sink().is_none(), "take_sink must detach");
        // session restart: a fresh auditor continues the series
        let mut aud2 = StreamAuditor::new(cfg, 90.0);
        aud2.set_sink("pair-0", sink);
        let mut t2 = 0.0;
        for _ in 0..2 {
            aud2.ingest_a(&r, seg_after(t2, 100.0, 1000.0));
            aud2.ingest_b(&r, seg_after(t2, 100.0, 1000.0));
            t2 += 100.0;
        }
        aud2.finish(); // 1 window + 1 summary + 1 ledger more
        let sink2 = aud2.take_sink().expect("sink attached to second auditor");
        assert_eq!(sink2.written, first_session_written + 3, "accounting must carry over");
        // the combined series replays as one: both sessions' snapshots,
        // in write order
        let snaps = load_dir(&dir).expect("combined series loads");
        assert_eq!(snaps.len(), sink2.written);
        let summaries =
            snaps.iter().filter(|s| matches!(s, Snapshot::Summary { .. })).count();
        assert_eq!(summaries, 2, "one summary per session");
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// After a flood drops pending events, pairing resumes shifted;
    /// the resync machinery re-anchors instead of garbling every
    /// later window.
    #[test]
    fn resync_recovers_after_flood_shift() {
        let cfg = StreamConfig {
            window_ops: 10,
            hop_ops: 10,
            ring_cap: 16,
            max_pending: 16,
            resync_lookahead: 8,
            nvml: None,
            ..Default::default()
        };
        let mut aud = StreamAuditor::new(cfg, 90.0);
        let (mut ta, mut tb) = (0.0, 0.0);
        // A floods 30 cycle ops while B stalls: 14 oldest dropped
        for i in 0..30 {
            let (label, op, e) = cycle_op(i);
            aud.ingest_a(&rec(label, op, e, 100.0), seg_after(ta, 100.0, e / 100e-6));
            ta += 100.0;
        }
        // B catches up with the same 30-op workload
        for i in 0..30 {
            let (label, op, e) = cycle_op(i);
            aud.ingest_b(&rec(label, op, e, 100.0), seg_after(tb, 100.0, e / 100e-6));
            tb += 100.0;
        }
        let s = aud.finish();
        assert!(!s.aligned, "flood drops must break overall alignment");
        assert!(s.resyncs >= 1, "pairing must re-anchor after the flood shift");
        // once re-anchored, pairs match structurally again
        assert!(s.ops > 0);
        assert_eq!(s.windows_flagged, 0, "re-anchored windows must not flag garbage");
    }
}
