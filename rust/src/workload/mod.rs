//! Workload generators: LLM serving request mixes (Fig 5b / Fig 10),
//! convolution benchmarks (Fig 5c), image-generation steps (Fig 5d),
//! and the uneven data-parallel training workload behind the DDP
//! `dist.Join` case (Fig 4 / c9).

use crate::dispatch::{Env, KernelChoice, Routine};
use crate::energy::{ComputeUnit, DeviceSpec, PowerTrace};
use crate::exec::{Dispatcher, Executor, Program, RunArtifacts};
use crate::graph::{Attrs, Graph, OpKind};
use crate::tensor::Tensor;
use crate::trace::Frame;
use crate::util::Prng;

/// An offline-inference request mix: `(input_tokens, output_tokens)`
/// per request, as in Fig 5b's `(x, y)` annotation.
#[derive(Clone, Copy, Debug)]
pub struct ServeMix {
    pub input_tokens: usize,
    pub output_tokens: usize,
    pub requests: usize,
}

impl ServeMix {
    /// Total tokens processed (for J/token).
    pub fn total_tokens(&self) -> usize {
        self.requests * (self.input_tokens + self.output_tokens)
    }
}

/// Fig 5b request mixes.
pub fn fig5b_mixes() -> Vec<ServeMix> {
    vec![
        ServeMix { input_tokens: 128, output_tokens: 128, requests: 4 },
        ServeMix { input_tokens: 512, output_tokens: 64, requests: 4 },
    ]
}

/// DDP training workload with imbalanced per-rank batches (case c9):
/// rank 0 gets `ratio` x the samples of rank 1 (paper uses 1.3:1).
#[derive(Clone, Copy, Debug)]
pub struct DdpWorkload {
    pub batch_heavy: usize,
    pub batch_light: usize,
    pub hidden: usize,
    pub iterations: usize,
}

impl DdpWorkload {
    pub fn paper_setup() -> DdpWorkload {
        // batch split 1.3:1 across two GPUs, MLP model, 20 iters;
        // sized so compute time dominates launch overhead (the paper's
        // MLP at batch 128 on an H200 is in the same regime)
        DdpWorkload { batch_heavy: 208, batch_light: 160, hidden: 512, iterations: 20 }
    }
}

/// How the early-finishing rank waits for the straggler.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SyncStrategy {
    /// `dist.Join`: keep communicating — GPU never idles (the bug).
    Join,
    /// Hand-written early exit: the light rank drops to idle power.
    EarlyExit,
}

/// One rank's training-iteration program: fwd MLP + bwd-ish matmuls +
/// gradient all-reduce + (join-barrier | idle) filler to the straggler.
fn ddp_rank_program(
    rng: &mut Prng,
    w: &DdpWorkload,
    batch: usize,
    wait_us: f64,
    strategy: SyncStrategy,
    rank: usize,
) -> Program {
    let h = w.hidden;
    let mut g = Graph::new(&format!("ddp-rank{rank}"));
    let x = g.add(OpKind::Input, &[], "batch");
    let w1 = g.add(OpKind::Weight, &[], "w1");
    let w2 = g.add(OpKind::Weight, &[], "w2");
    let h1 = g.add(OpKind::MatMul, &[x, w1], "mlp.fc1");
    let a1 = g.add(OpKind::Relu, &[h1], "mlp.relu");
    let h2 = g.add(OpKind::MatMul, &[a1, w2], "mlp.fc2");
    // backward-ish: two gradient matmuls (same cost class as fwd)
    let a1t = g.add_attr1(OpKind::Permute, &[a1], "grad.a1_t", "perm", "1,0");
    let gw2 = g.add(OpKind::MatMul, &[a1t, h2], "grad.w2");
    let xt = g.add_attr1(OpKind::Permute, &[x], "grad.x_t", "perm", "1,0");
    let gw1 = g.add(OpKind::MatMul, &[xt, h1], "grad.w1");
    // gradient all-reduce across ranks
    let ar1 = g.add(OpKind::AllReduce, &[gw1], "ddp.all_reduce_w1");
    let ar2 = g.add(OpKind::AllReduce, &[gw2], "ddp.all_reduce_w2");
    // waiting for the straggler
    let waiter = if wait_us > 0.0 {
        let mut at = Attrs::new();
        at.insert("wait_us".into(), format!("{wait_us}"));
        match strategy {
            SyncStrategy::Join => {
                at.insert("power_frac".into(), "0.45".into());
                g.add_attrs(OpKind::Barrier, &[ar1], "dist.join_barrier", at)
            }
            SyncStrategy::EarlyExit => g.add_attrs(OpKind::Idle, &[ar1], "early_exit.idle", at),
        }
    } else {
        ar1
    };
    let join = g.add(OpKind::Add, &[waiter, ar2], "step.join_grads");
    g.add(OpKind::Output, &[join], "out");
    let mut p = Program::new(g);
    p.feed(0, Tensor::randn(rng, &[batch, h]));
    p.feed(1, Tensor::randn(rng, &[h, h]));
    p.feed(2, Tensor::randn(rng, &[h, h]));
    p
}

/// Result of simulating a 2-rank DDP step sequence.
#[derive(Clone, Debug)]
pub struct DdpRun {
    /// Per-rank power traces (aligned at t = 0).
    pub traces: Vec<PowerTrace>,
    /// Total energy across ranks, Joules.
    pub total_energy_j: f64,
    /// Wall time (slowest rank), µs.
    pub wall_us: f64,
    pub artifacts: Vec<RunArtifacts>,
}

/// Simulate `iterations` of 2-rank DDP under a sync strategy (Fig 4).
pub fn run_ddp(device: &DeviceSpec, w: &DdpWorkload, strategy: SyncStrategy, seed: u64) -> DdpRun {
    let mut rng = Prng::new(seed);
    let exec = Executor::new(device.clone(), Dispatcher::new(), Env::new());

    // Calibrate the straggler gap: run one heavy and one light iteration
    // without waiting.
    let probe_heavy = exec.run(&ddp_rank_program(&mut rng, w, w.batch_heavy, 0.0, strategy, 0));
    let probe_light = exec.run(&ddp_rank_program(&mut rng, w, w.batch_light, 0.0, strategy, 1));
    let gap_us = (probe_heavy.gpu_time_us - probe_light.gpu_time_us).max(0.0);

    let mut traces = vec![PowerTrace::new(device.idle_w), PowerTrace::new(device.idle_w)];
    let mut artifacts = Vec::new();
    let mut total_e = 0.0;
    for it in 0..w.iterations {
        let heavy = exec.run(&ddp_rank_program(&mut rng, w, w.batch_heavy, 0.0, strategy, 0));
        let light = exec.run(&ddp_rank_program(
            &mut rng,
            w,
            w.batch_light,
            gap_us,
            strategy,
            1,
        ));
        total_e += heavy.total_energy_j + light.total_energy_j;
        traces[0].extend_shifted(&heavy.power);
        traces[1].extend_shifted(&light.power);
        if it == 0 {
            artifacts.push(heavy);
            artifacts.push(light);
        }
    }
    let wall_us = traces.iter().map(|t| t.duration_us()).fold(0.0, f64::max);
    DdpRun { traces, total_energy_j: total_e, wall_us, artifacts }
}

/// A long-running serving stream: `requests` back-to-back decode-style
/// steps over shared weights, each hitting the same five call sites
/// (`serve.proj` → `serve.scale` → `serve.act` → `serve.out` →
/// `serve.softmax`). The graph is deliberately *long* (5 kernels per
/// request) with a *small* live set (one activation + two weights), the
/// shape [`crate::exec::StreamExec`] and the stream auditor are built
/// for. The trailing softmax renormalises each step, so activations
/// stay bounded over arbitrarily many requests.
#[derive(Clone, Copy, Debug)]
pub struct ServingStream {
    pub requests: usize,
    pub batch: usize,
    pub d_model: usize,
}

impl Default for ServingStream {
    fn default() -> ServingStream {
        // matmuls sized so dynamic energy is a visible share of the op
        // cost (a 0.6-efficiency kernel diverges well above the 10 %
        // detection threshold), yet each step stays CPU-cheap
        ServingStream { requests: 1000, batch: 64, d_model: 128 }
    }
}

impl ServingStream {
    /// Kernel launches per request (the request-boundary stride for
    /// arrival-gap injection).
    pub fn ops_per_request(&self) -> usize {
        5
    }

    /// Kernel launches the stream will emit (5 per request).
    pub fn kernel_ops(&self) -> usize {
        self.requests * self.ops_per_request()
    }
}

/// Build the serving-stream program (feeds included).
pub fn serving_stream_program(rng: &mut Prng, s: &ServingStream) -> Program {
    let d = s.d_model;
    let mut g = Graph::new("serving-stream");
    let x = g.add(OpKind::Input, &[], "tokens");
    let w1 = g.add(OpKind::Weight, &[], "w1");
    let w2 = g.add(OpKind::Weight, &[], "w2");
    let inv_sqrt_d = format!("{}", 1.0 / (d as f64).sqrt());
    let mut cur = x;
    for _ in 0..s.requests {
        let m = g.add(OpKind::MatMul, &[cur, w1], "serve.proj");
        let sc = g.add_attr1(OpKind::Scale, &[m], "serve.scale", "s", &inv_sqrt_d);
        let a = g.add(OpKind::Gelu, &[sc], "serve.act");
        let o = g.add(OpKind::MatMul, &[a, w2], "serve.out");
        cur = g.add(OpKind::Softmax, &[o], "serve.softmax");
    }
    g.add(OpKind::Output, &[cur], "serve.result");
    let mut p = Program::new(g);
    p.feed(x, Tensor::randn(rng, &[s.batch, d]));
    p.feed(w1, Tensor::randn(rng, &[d, d]));
    p.feed(w2, Tensor::randn(rng, &[d, d]));
    p
}

/// How serving requests arrive at a stream pair. The PR 2 loop ran
/// requests back-to-back; real deployments (MLPerf Power, ML.ENERGY)
/// see memoryless or bursty traffic, whose idle lulls the stream
/// auditor materialises as idle-power ring segments
/// ([`crate::stream::StreamAuditor::ingest_idle_a`]).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ArrivalProcess {
    /// No idle time between requests (the fixed serving loop).
    BackToBack,
    /// Memoryless arrivals at `rate_hz` requests/second: exponential
    /// inter-arrival gaps.
    Poisson { rate_hz: f64 },
    /// On/off traffic: `burst_len` back-to-back requests, then an
    /// exponential lull drawn at `lull_hz`.
    Bursty { burst_len: usize, lull_hz: f64 },
}

impl ArrivalProcess {
    /// Parse a CLI spelling (`steady` | `poisson` | `bursty`).
    pub fn parse(kind: &str, rate_hz: f64, burst_len: usize) -> Option<ArrivalProcess> {
        match kind {
            "steady" | "back-to-back" => Some(ArrivalProcess::BackToBack),
            "poisson" => Some(ArrivalProcess::Poisson { rate_hz }),
            "bursty" => Some(ArrivalProcess::Bursty { burst_len: burst_len.max(1), lull_hz: rate_hz }),
            _ => None,
        }
    }

    /// Stable textual description of the arrival configuration, carried
    /// in persisted [`crate::telemetry::SessionHeader`]s so a session
    /// diff can flag sessions captured under different traffic shapes.
    pub fn describe(&self) -> String {
        match *self {
            ArrivalProcess::BackToBack => "steady".to_string(),
            ArrivalProcess::Poisson { rate_hz } => format!("poisson@{rate_hz}Hz"),
            ArrivalProcess::Bursty { burst_len, lull_hz } => {
                format!("bursty[{burst_len}]@{lull_hz}Hz")
            }
        }
    }

    /// Idle gap (µs) preceding request `i` (request 0 starts
    /// immediately; callers pass `i >= 1`). Deterministic given the
    /// rng state, so both sides of a pair can share one gap sequence.
    pub fn gap_us(&self, rng: &mut Prng, i: usize) -> f64 {
        match *self {
            ArrivalProcess::BackToBack => 0.0,
            ArrivalProcess::Poisson { rate_hz } => exp_gap_us(rng, rate_hz),
            ArrivalProcess::Bursty { burst_len, lull_hz } => {
                if burst_len > 0 && i % burst_len == 0 {
                    exp_gap_us(rng, lull_hz)
                } else {
                    0.0
                }
            }
        }
    }
}

/// Exponential inter-arrival sample, µs (mean `1e6 / rate_hz`).
fn exp_gap_us(rng: &mut Prng, rate_hz: f64) -> f64 {
    if rate_hz <= 0.0 {
        return 0.0;
    }
    -rng.f64().max(1e-12).ln() / rate_hz * 1e6
}

/// Dispatcher for one side of a serving pair: its matmul kernel runs at
/// implementation quality `eff` (1.0 = energy-optimal; lower burns
/// extra power at equal speed — the differential signal the stream
/// auditor hunts).
pub fn serving_dispatcher(eff: f64) -> Dispatcher {
    let kernel = if eff < 1.0 { "legacy_sgemm" } else { "tf32_gemm" };
    let mut disp = Dispatcher::new();
    disp.register(
        "matmul",
        Routine::direct(
            "torch.matmul",
            vec![Frame::cpp("at::cuda::blas::gemm")],
            KernelChoice::new(kernel, ComputeUnit::TensorCore).quality(eff, 1.0, 1.0),
        ),
    );
    disp
}

/// Serve a request mix on an LLM system builder, returning artifacts for
/// the prefill pass and each decode step (J/token comes from these).
pub fn serve_mix(
    exec: &Executor,
    params: &crate::systems::llm::TransformerParams,
    opts: &crate::systems::llm::LlmBuildOpts,
    mix: &ServeMix,
) -> (f64, f64) {
    // prefill over the full input
    let prog = crate::systems::llm::build_llm(params, opts);
    let prefill = exec.run(&prog);
    // decode steps: approximate each output token as a seq-1 pass by
    // scaling the prefill costs (KV-cache hit): decode ≈ prefill/seq per
    // token plus attention over the cache.
    let per_decode_e = prefill.total_energy_j / params.spec.seq as f64 * 1.35;
    let per_decode_t = prefill.gpu_time_us / params.spec.seq as f64 * 1.35;
    let total_e = (prefill.total_energy_j + per_decode_e * mix.output_tokens as f64)
        * mix.requests as f64;
    let total_t =
        (prefill.gpu_time_us + per_decode_t * mix.output_tokens as f64) * mix.requests as f64;
    (total_e, total_t)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ddp_early_exit_saves_energy() {
        let dev = DeviceSpec::h200_sim();
        let w = DdpWorkload { iterations: 3, ..DdpWorkload::paper_setup() };
        let join = run_ddp(&dev, &w, SyncStrategy::Join, 7);
        let exit = run_ddp(&dev, &w, SyncStrategy::EarlyExit, 7);
        assert!(
            join.total_energy_j > exit.total_energy_j * 1.02,
            "join {} vs exit {}",
            join.total_energy_j,
            exit.total_energy_j
        );
        // wall time unchanged (same straggler)
        let rel = (join.wall_us - exit.wall_us).abs() / join.wall_us;
        assert!(rel < 0.05, "wall time diverged {rel}");
    }

    #[test]
    fn ddp_light_rank_power_drops_on_early_exit() {
        let dev = DeviceSpec::h200_sim();
        let w = DdpWorkload { iterations: 2, ..DdpWorkload::paper_setup() };
        let join = run_ddp(&dev, &w, SyncStrategy::Join, 9);
        let exit = run_ddp(&dev, &w, SyncStrategy::EarlyExit, 9);
        // the light rank's trace integrates to less energy with early
        // exit, and its minimum power touches the idle floor
        let ej = join.traces[1].total_energy();
        let ee = exit.traces[1].total_energy();
        assert!(ej > ee, "join light-rank energy {ej} <= early-exit {ee}");
        let min_exit = exit.traces[1]
            .segments
            .iter()
            .map(|s| s.watts)
            .fold(f64::INFINITY, f64::min);
        let min_join = join.traces[1]
            .segments
            .iter()
            .map(|s| s.watts)
            .fold(f64::INFINITY, f64::min);
        assert!(min_exit <= dev.idle_w + 1.0);
        assert!(min_join > dev.idle_w + 1.0);
    }

    #[test]
    fn serve_mix_reports_positive_energy() {
        let mut rng = Prng::new(11);
        let spec = crate::systems::llm::LlmSpec {
            batch: 1, seq: 16, d_model: 32, n_heads: 4, d_ff: 64, vocab: 64, layers: 1,
        };
        let params = crate::systems::llm::TransformerParams::new(&mut rng, spec);
        let exec = Executor::new(
            DeviceSpec::h200_sim(),
            crate::systems::llm::vllm_dispatcher(),
            crate::systems::llm::default_env(crate::systems::SystemId::MiniVllm),
        );
        let mix = ServeMix { input_tokens: 16, output_tokens: 8, requests: 2 };
        let (e, t) = serve_mix(&exec, &params, &crate::systems::llm::LlmBuildOpts::vllm(), &mix);
        assert!(e > 0.0 && t > 0.0);
    }

    #[test]
    fn mix_token_count() {
        let m = ServeMix { input_tokens: 128, output_tokens: 128, requests: 4 };
        assert_eq!(m.total_tokens(), 1024);
    }

    /// The serving stream emits exactly 5 kernels per request through
    /// the streaming executor, stays numerically bounded (softmax
    /// renormalisation), and its live tensor set is independent of the
    /// stream length.
    #[test]
    fn serving_stream_is_long_but_bounded() {
        let dev = DeviceSpec::h200_sim();
        let spec = ServingStream { requests: 40, batch: 16, d_model: 32 };
        let mut rng = Prng::new(17);
        let prog = serving_stream_program(&mut rng, &spec);
        let exec = Executor::new(dev, serving_dispatcher(1.0), Env::new());
        let mut stream = exec.stream(&prog);
        let mut ops = 0;
        for (rec, seg) in stream.by_ref() {
            assert!(rec.energy_j.is_finite() && rec.energy_j > 0.0, "{}", rec.label);
            assert!(seg.watts.is_finite());
            ops += 1;
        }
        assert_eq!(ops, spec.kernel_ops());
        let stats = stream.stats();
        assert_eq!(stats.ops, spec.kernel_ops());
        // live set: activation chain + 2 weights + input, far below the
        // 200+ node graph
        assert!(stats.live_tensors_peak <= 8, "peak {}", stats.live_tensors_peak);
    }

    /// Arrival processes: back-to-back never idles, Poisson gaps are
    /// exponential with the right mean, bursty idles only at burst
    /// boundaries — all deterministic under a fixed seed.
    #[test]
    fn arrival_processes_shape_idle_gaps() {
        let mut rng = Prng::new(23);
        for i in 1..100 {
            assert_eq!(ArrivalProcess::BackToBack.gap_us(&mut rng, i), 0.0);
        }
        // Poisson: mean gap ~= 1e6 / rate
        let poisson = ArrivalProcess::Poisson { rate_hz: 200.0 };
        let n = 20_000;
        let mut sum = 0.0;
        for i in 1..=n {
            let g = poisson.gap_us(&mut rng, i);
            assert!(g > 0.0);
            sum += g;
        }
        let mean = sum / n as f64;
        assert!((mean - 5000.0).abs() / 5000.0 < 0.05, "poisson mean {mean}");
        // bursty: idle only every `burst_len` requests
        let bursty = ArrivalProcess::Bursty { burst_len: 8, lull_hz: 50.0 };
        for i in 1..64 {
            let g = bursty.gap_us(&mut rng, i);
            if i % 8 == 0 {
                assert!(g > 0.0, "burst boundary {i} must idle");
            } else {
                assert_eq!(g, 0.0, "mid-burst {i} must not idle");
            }
        }
        // determinism: same seed, same gap sequence
        let mut r1 = Prng::new(7);
        let mut r2 = Prng::new(7);
        for i in 1..50 {
            assert_eq!(poisson.gap_us(&mut r1, i).to_bits(), poisson.gap_us(&mut r2, i).to_bits());
        }
    }

    #[test]
    fn arrival_describe_is_stable() {
        assert_eq!(ArrivalProcess::BackToBack.describe(), "steady");
        assert_eq!(ArrivalProcess::Poisson { rate_hz: 200.0 }.describe(), "poisson@200Hz");
        assert_eq!(
            ArrivalProcess::Bursty { burst_len: 16, lull_hz: 50.0 }.describe(),
            "bursty[16]@50Hz"
        );
    }

    #[test]
    fn arrival_parse_spellings() {
        assert_eq!(ArrivalProcess::parse("steady", 1.0, 4), Some(ArrivalProcess::BackToBack));
        assert_eq!(
            ArrivalProcess::parse("poisson", 120.0, 4),
            Some(ArrivalProcess::Poisson { rate_hz: 120.0 })
        );
        assert_eq!(
            ArrivalProcess::parse("bursty", 50.0, 16),
            Some(ArrivalProcess::Bursty { burst_len: 16, lull_hz: 50.0 })
        );
        assert_eq!(ArrivalProcess::parse("nope", 1.0, 1), None);
    }

    /// An inefficient matmul dispatcher must raise serving energy at
    /// equal time — the signal the streaming detector keys on.
    #[test]
    fn serving_dispatcher_efficiency_changes_energy_not_time() {
        let dev = DeviceSpec::h200_sim();
        let spec = ServingStream { requests: 6, batch: 64, d_model: 128 };
        let mut rng_a = Prng::new(5);
        let mut rng_b = Prng::new(5);
        let prog_a = serving_stream_program(&mut rng_a, &spec);
        let prog_b = serving_stream_program(&mut rng_b, &spec);
        let bad = Executor::new(dev.clone(), serving_dispatcher(0.6), Env::new()).run(&prog_a);
        let good = Executor::new(dev, serving_dispatcher(1.0), Env::new()).run(&prog_b);
        assert!(bad.total_energy_j > good.total_energy_j * 1.05);
        assert!((bad.gpu_time_us - good.gpu_time_us).abs() / good.gpu_time_us < 1e-9);
    }
}
