//! Graph executor: runs a computational graph on the tensor substrate
//! while accounting energy, time, power, and trace events per kernel.
//!
//! For every non-virtual node the executor (1) asks the [`Dispatcher`]
//! which kernel variant the owning framework would launch under the
//! current configuration (this is where misconfigurations change both
//! cost *and* numerics — a TF32 kernel truncates mantissas), (2)
//! computes the output tensor, (3) derives FLOP/byte counts from the
//! shapes, (4) evaluates the cost model and appends to the power trace,
//! and (5) emits correlated API-call + kernel-launch trace events.

pub mod counts;

use std::collections::BTreeMap;

use crate::dispatch::{Env, KernelChoice, Outcome, Routine};
use crate::energy::{ComputeUnit, DeviceSpec, KernelDesc, PowerTrace};
use crate::graph::{Graph, NodeId, OpKind};
use crate::tensor::{conv, nn, ops, Tensor};
use crate::trace::{EventKind, Frame, TraceBuffer};

/// A runnable program: a graph plus tensors bound to its source nodes.
#[derive(Clone, Debug)]
pub struct Program {
    pub graph: Graph,
    /// Tensor feeds for `Input` / `Weight` nodes.
    pub feeds: BTreeMap<NodeId, Tensor>,
}

impl Program {
    pub fn new(graph: Graph) -> Program {
        Program { graph, feeds: BTreeMap::new() }
    }

    pub fn feed(&mut self, node: NodeId, t: Tensor) -> &mut Self {
        self.feeds.insert(node, t);
        self
    }
}

/// Kernel-selection oracle: routines registered per dispatch key. Nodes
/// pick a routine via their `dispatch` attribute (falling back to the op
/// name); unknown keys get a default direct routine.
#[derive(Clone, Debug, Default)]
pub struct Dispatcher {
    pub routines: BTreeMap<String, Routine>,
}

impl Dispatcher {
    pub fn new() -> Dispatcher {
        Dispatcher::default()
    }

    pub fn register(&mut self, key: &str, routine: Routine) -> &mut Self {
        self.routines.insert(key.to_string(), routine);
        self
    }

    /// Dispatch a node under `env`. Falls back to a sane direct routine
    /// per op kind when no routine is registered.
    pub fn dispatch(&self, op: OpKind, key: &str, env: &Env) -> Outcome {
        if let Some(r) = self.routines.get(key) {
            return r.run(env);
        }
        if let Some(r) = self.routines.get(op.name()) {
            return r.run(env);
        }
        default_routine(op).run(env)
    }

    /// Find the routine a node would use (for diagnosis re-runs).
    pub fn routine_for(&self, op: OpKind, key: &str) -> Routine {
        self.routines
            .get(key)
            .or_else(|| self.routines.get(op.name()))
            .cloned()
            .unwrap_or_else(|| default_routine(op))
    }
}

/// Default kernel choice for an op when the framework registered nothing.
pub fn default_routine(op: OpKind) -> Routine {
    let unit = match op {
        OpKind::MatMul | OpKind::AddMm | OpKind::Conv2d | OpKind::Attention => ComputeUnit::TensorCore,
        OpKind::Tanh | OpKind::Gelu | OpKind::Silu | OpKind::Softmax | OpKind::Expm => ComputeUnit::Sfu,
        OpKind::Contiguous | OpKind::Copy | OpKind::Concat | OpKind::SplitChunk | OpKind::Slice => ComputeUnit::Mem,
        OpKind::AllReduce => ComputeUnit::Link,
        _ => ComputeUnit::CudaCore,
    };
    let kernel = format!("default_{}", op.name());
    Routine::direct(&format!("aten::{}", op.name()), vec![Frame::cpp("at::native::dispatch")], KernelChoice::new(&kernel, unit))
}

/// One executed kernel with full context (the unified trace row).
#[derive(Clone, Debug)]
pub struct KernelRecord {
    pub node: NodeId,
    pub op: OpKind,
    pub label: String,
    pub api: String,
    /// Dispatch-routine key the executor used (for diagnosis re-runs).
    pub dispatch_key: String,
    pub kernel: String,
    pub time_us: f64,
    pub energy_j: f64,
    pub avg_power_w: f64,
    pub corr_id: u64,
    pub bb_trace: Vec<(String, usize)>,
    pub call_path: Vec<Frame>,
}

/// Everything a run produces.
#[derive(Clone, Debug)]
pub struct RunArtifacts {
    pub graph: Graph,
    /// Output tensor per node (present when `record_tensors`).
    pub tensors: Vec<Option<Tensor>>,
    pub records: Vec<KernelRecord>,
    pub trace: TraceBuffer,
    pub power: PowerTrace,
    /// GPU busy time (µs).
    pub gpu_time_us: f64,
    /// End-to-end wall time incl. tracing overhead (µs).
    pub wall_time_us: f64,
    pub total_energy_j: f64,
}

impl RunArtifacts {
    /// The final output tensor (last Output node's input, or last node).
    pub fn output(&self) -> &Tensor {
        let out_node = self
            .graph
            .nodes
            .iter()
            .rev()
            .find(|n| n.op == OpKind::Output)
            .map(|n| n.inputs[0])
            .unwrap_or(self.graph.len() - 1);
        self.tensors[out_node].as_ref().expect("run with record_tensors")
    }

    /// Energy attributed to a node.
    pub fn node_energy_j(&self, node: NodeId) -> f64 {
        self.records.iter().filter(|r| r.node == node).map(|r| r.energy_j).sum()
    }

    /// Per-operator energy breakdown aggregated by op kind (Fig 2 rows).
    pub fn energy_by_op(&self) -> Vec<(String, f64)> {
        let mut agg: BTreeMap<String, f64> = BTreeMap::new();
        for r in &self.records {
            *agg.entry(r.op.name().to_string()).or_insert(0.0) += r.energy_j;
        }
        let mut v: Vec<(String, f64)> = agg.into_iter().collect();
        v.sort_by(|a, b| b.1.total_cmp(&a.1));
        v
    }
}

/// Executor options.
#[derive(Clone, Debug)]
pub struct ExecOptions {
    /// Capture trace events (charges per-event overhead on wall time).
    pub tracing: bool,
    /// Keep every node's output tensor (needed for fingerprint matching).
    pub record_tensors: bool,
    /// Per-event tracing overhead, µs (Fig 10's knob). Calibrated so
    /// the interception-cost : kernel-duration ratio matches the real
    /// CUPTI-vs-H200 testbed (~1 µs interception against ~40 µs
    /// kernels); our simulated kernels are ~40x shorter, so the
    /// per-event cost scales down with them.
    pub trace_overhead_us: f64,
}

impl Default for ExecOptions {
    fn default() -> ExecOptions {
        ExecOptions { tracing: true, record_tensors: true, trace_overhead_us: 0.008 }
    }
}

/// The executor: device + dispatcher + global config.
pub struct Executor {
    pub device: DeviceSpec,
    pub dispatcher: Dispatcher,
    pub config: Env,
    pub opts: ExecOptions,
}

impl Executor {
    pub fn new(device: DeviceSpec, dispatcher: Dispatcher, config: Env) -> Executor {
        Executor { device, dispatcher, config, opts: ExecOptions::default() }
    }

    /// Execute a program, producing tensors + energy + trace.
    pub fn run(&self, prog: &Program) -> RunArtifacts {
        let g = &prog.graph;
        let mut tensors: Vec<Option<Tensor>> = vec![None; g.len()];
        let mut records = Vec::new();
        let mut trace = TraceBuffer::new(if self.opts.tracing { self.opts.trace_overhead_us } else { 0.0 });
        let mut power = PowerTrace::new(self.device.idle_w);
        let mut gpu_time_us = 0.0;

        for node in &g.nodes {
            // 1. bind sources
            if matches!(node.op, OpKind::Input | OpKind::Weight) {
                let t = prog
                    .feeds
                    .get(&node.id)
                    .unwrap_or_else(|| panic!("no feed for {} `{}`", node.op.name(), node.label))
                    .clone();
                tensors[node.id] = Some(t);
                continue;
            }
            if node.op == OpKind::Output {
                tensors[node.id] = tensors[node.inputs[0]].clone();
                continue;
            }
            // zero-copy metadata ops: no kernel launch, no energy
            if matches!(node.op, OpKind::Permute | OpKind::Reshape) {
                let ins: Vec<&Tensor> = node
                    .inputs
                    .iter()
                    .map(|&i| tensors[i].as_ref().expect("topological order"))
                    .collect();
                tensors[node.id] = Some(eval_node(node.op, &node.attrs, &ins, false));
                continue;
            }

            // 2. dispatch: which kernel variant does the framework pick?
            let env = self.config.merged(&node.attrs);
            let key = node.attrs.get("dispatch").cloned().unwrap_or_else(|| node.op.name().to_string());
            let outcome = self.dispatcher.dispatch(node.op, &key, &env);
            let choice = &outcome.choice;

            // 3. numerics (TF32 kernels round inputs)
            let ins: Vec<&Tensor> = node
                .inputs
                .iter()
                .map(|&i| tensors[i].as_ref().expect("topological order"))
                .collect();
            let tf32 = choice.unit == ComputeUnit::TensorCore
                && matches!(node.op, OpKind::MatMul | OpKind::AddMm | OpKind::Attention | OpKind::Conv2d);
            let out = eval_node(node.op, &node.attrs, &ins, tf32);

            // 4. cost
            let (flops, bytes, n_launches) = counts::op_counts(node.op, &node.attrs, &ins, &out);
            let desc = if node.op == OpKind::Barrier || node.op == OpKind::Idle {
                let wait_us: f64 = node.attrs.get("wait_us").and_then(|s| s.parse().ok()).unwrap_or(1000.0);
                let frac: f64 = node.attrs.get("power_frac").and_then(|s| s.parse().ok()).unwrap_or(
                    if node.op == OpKind::Barrier { 0.45 } else { 0.0 },
                );
                let w = if node.op == OpKind::Idle {
                    self.device.idle_w
                } else {
                    self.device.base_w.max(frac * self.device.max_w)
                };
                KernelDesc::fixed(&choice.kernel, wait_us, w)
            } else {
                KernelDesc {
                    name: choice.kernel.clone(),
                    unit: choice.unit,
                    flops,
                    bytes: bytes * choice.bytes_mult,
                    efficiency: choice.efficiency,
                    time_mult: choice.time_mult,
                    fixed_time_us: 0.0,
                    fixed_power_w: 0.0,
                }
            };
            // multi-launch ops (e.g. per-launch overhead of split kernels)
            let mut cost = desc.cost(&self.device);
            if n_launches > 1 {
                let extra = (n_launches - 1) as f64 * self.device.launch_overhead_us;
                cost.time_us += extra;
                cost.energy_j += extra * 1e-6 * self.device.base_w;
                // keep the three energy views (records, trace, power
                // integral) consistent after the adjustment
                cost.avg_power_w = (cost.energy_j / (cost.time_us * 1e-6)).min(self.device.max_w);
                cost.energy_j = cost.energy_j.min(cost.avg_power_w * cost.time_us * 1e-6);
            }

            // 5. trace + power accounting
            let t0 = power.now_us();
            power.push(cost.time_us, cost.avg_power_w.max(self.device.base_w.min(cost.avg_power_w + 1.0)));
            gpu_time_us += cost.time_us;
            let corr = trace.next_corr_id();
            if self.opts.tracing {
                trace.record(
                    corr,
                    t0,
                    t0 + 1.0,
                    EventKind::ApiCall { api: outcome.call_path[0].func.clone() },
                    outcome.call_path.clone(),
                    Some(node.id),
                );
                trace.record(
                    corr,
                    t0,
                    t0 + cost.time_us,
                    EventKind::KernelLaunch { kernel: choice.kernel.clone(), energy_j: cost.energy_j },
                    vec![],
                    Some(node.id),
                );
            }
            records.push(KernelRecord {
                node: node.id,
                op: node.op,
                label: node.label.clone(),
                api: outcome.call_path[0].func.clone(),
                dispatch_key: key.clone(),
                kernel: choice.kernel.clone(),
                time_us: cost.time_us,
                energy_j: cost.energy_j,
                avg_power_w: cost.avg_power_w,
                corr_id: corr,
                bb_trace: outcome.bb_trace.clone(),
                call_path: outcome.call_path.clone(),
            });

            tensors[node.id] = Some(out);
        }

        let total_energy_j = records.iter().map(|r| r.energy_j).sum();
        let wall_time_us = gpu_time_us + trace.total_overhead_us;
        let mut arts = RunArtifacts {
            graph: g.clone(),
            tensors,
            records,
            trace,
            power,
            gpu_time_us,
            wall_time_us,
            total_energy_j,
        };
        if !self.opts.record_tensors {
            // keep only sources + final output to bound memory
            let keep: Vec<usize> = g
                .nodes
                .iter()
                .filter(|n| n.op == OpKind::Output)
                .map(|n| n.inputs[0])
                .collect();
            for i in 0..arts.tensors.len() {
                if !keep.contains(&i) && !g.nodes[i].inputs.is_empty() {
                    arts.tensors[i] = None;
                }
            }
        }
        arts
    }
}

/// Parse helpers for node attrs.
fn attr_usize(attrs: &crate::graph::Attrs, k: &str, default: usize) -> usize {
    attrs.get(k).and_then(|s| s.parse().ok()).unwrap_or(default)
}
fn attr_f32(attrs: &crate::graph::Attrs, k: &str, default: f32) -> f32 {
    attrs.get(k).and_then(|s| s.parse().ok()).unwrap_or(default)
}
fn attr_list(attrs: &crate::graph::Attrs, k: &str) -> Vec<usize> {
    attrs
        .get(k)
        .map(|s| s.split(',').filter_map(|x| x.trim().parse().ok()).collect())
        .unwrap_or_default()
}

/// Evaluate one operator's numerics.
pub fn eval_node(op: OpKind, attrs: &crate::graph::Attrs, ins: &[&Tensor], tf32: bool) -> Tensor {
    match op {
        OpKind::MatMul => ops::matmul_ex(ins[0], ins[1], tf32),
        OpKind::AddMm => ops::addmm(ins[0], ins[1], ins[2], tf32),
        OpKind::Add => ops::add(ins[0], ins[1]),
        OpKind::Sub => ops::sub(ins[0], ins[1]),
        OpKind::Mul => ops::mul(ins[0], ins[1]),
        OpKind::Div => ops::div(ins[0], ins[1]),
        OpKind::Scale => ops::scale(ins[0], attr_f32(attrs, "s", 1.0)),
        OpKind::Pow => {
            let p = attr_f32(attrs, "p", 2.0);
            ops::map(ins[0], |x| x.powf(p))
        }
        OpKind::Tanh => ops::map(ins[0], f32::tanh),
        OpKind::Gelu => match attrs.get("approx").map(String::as_str) {
            Some("tanh") => nn::gelu_tanh(ins[0]),
            _ => nn::gelu_exact(ins[0]),
        },
        OpKind::Silu => nn::silu(ins[0]),
        OpKind::Relu => ops::map(ins[0], |x| x.max(0.0)),
        OpKind::Softmax => nn::softmax(ins[0]),
        OpKind::LayerNorm => nn::layernorm(ins[0], ins[1], ins[2], 1e-5),
        OpKind::RmsNorm => nn::rmsnorm(ins[0], ins[1], 1e-6),
        OpKind::Attention => {
            // fused GQA: expand kv heads inside the kernel (no HBM cost —
            // the whole point of the c4 fix)
            let reps = attr_usize(attrs, "gqa_reps", 1);
            let nhd = attrs.get("layout").map(String::as_str) == Some("nhd");
            let (k, v) = if reps > 1 {
                let head_dim = if nhd { 2 } else { 1 };
                (
                    ops::repeat_interleave(ins[1], head_dim, reps),
                    ops::repeat_interleave(ins[2], head_dim, reps),
                )
            } else {
                (ins[1].clone(), ins[2].clone())
            };
            if nhd {
                nn::attention_nhd(ins[0], &k, &v)
            } else {
                nn::attention_hnd(ins[0], &k, &v)
            }
        }
        OpKind::Conv2d => {
            let pad = attr_usize(attrs, "pad", 1);
            let groups = attr_usize(attrs, "groups", 1);
            match attrs.get("layout").map(String::as_str) {
                Some("nhwc") => conv::conv2d_nhwc(ins[0], ins[1], pad, groups),
                _ => match attrs.get("algo").map(String::as_str) {
                    Some("im2col") => conv::conv2d_im2col(ins[0], ins[1], pad),
                    _ => conv::conv2d_nchw(ins[0], ins[1], pad, groups),
                },
            }
        }
        OpKind::Permute => ins[0].permute(&attr_list(attrs, "perm")),
        OpKind::Reshape => ins[0].reshape(&attr_list(attrs, "shape")),
        OpKind::Contiguous | OpKind::Copy => ins[0].contiguous(),
        OpKind::Concat => {
            let dim = attr_usize(attrs, "dim", 0);
            Tensor::concat(ins, dim)
        }
        OpKind::SplitChunk => {
            let dim = attr_usize(attrs, "dim", 0);
            let chunks = attr_usize(attrs, "chunks", 1);
            let index = attr_usize(attrs, "index", 0);
            ins[0].split(dim, chunks)[index].contiguous()
        }
        OpKind::Slice => {
            let dim = attr_usize(attrs, "dim", 0);
            ins[0]
                .slice(dim, attr_usize(attrs, "start", 0), attr_usize(attrs, "stop", ins[0].shape()[dim]))
                .contiguous()
        }
        OpKind::TopK => ops::topk_lastdim(ins[0], attr_usize(attrs, "k", 1)),
        OpKind::Sort => ops::sort_lastdim_desc(ins[0]),
        OpKind::CumSum => ops::cumsum_lastdim(ins[0]),
        OpKind::RepeatInterleave => {
            ops::repeat_interleave(ins[0], attr_usize(attrs, "dim", 0), attr_usize(attrs, "reps", 1))
        }
        OpKind::Embedding => {
            let ids: Vec<usize> = attr_list(attrs, "ids");
            ops::embedding(ins[0], &ids)
        }
        OpKind::Arange => Tensor::arange(attr_usize(attrs, "n", 1)),
        OpKind::CrossEntropy => {
            let targets = attr_list(attrs, "targets");
            Tensor::from_vec(vec![nn::cross_entropy(ins[0], &targets)], &[1])
        }
        OpKind::Eigvals => {
            // symmetrise then solve (c6: the efficient path for symmetric inputs)
            let sym = ops::scale(&ops::add(ins[0], &ins[0].t().contiguous()), 0.5);
            let ev = crate::linalg::eigvalsh(&sym);
            let n = ev.len();
            Tensor::from_vec(ev, &[n])
        }
        OpKind::Stft => crate::linalg::stft_mag(
            ins[0],
            attr_usize(attrs, "frame", 32),
            attr_usize(attrs, "hop", 16),
        ),
        OpKind::Expm => crate::linalg::expm(ins[0]),
        OpKind::CountNonzero => {
            Tensor::from_vec(vec![ops::count_nonzero(ins[0]) as f32], &[1])
        }
        OpKind::AllReduce => ins[0].contiguous(), // single-rank view: identity
        OpKind::Barrier | OpKind::Idle => ins
            .first()
            .map(|t| (*t).clone())
            .unwrap_or_else(|| Tensor::zeros(&[1])),
        OpKind::Input | OpKind::Weight | OpKind::Output => unreachable!("handled by run()"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Prng;

    fn simple_program(tf32_config: bool) -> (Executor, Program) {
        let mut g = Graph::new("test");
        let x = g.add(OpKind::Input, &[], "x");
        let w = g.add(OpKind::Weight, &[], "w");
        let m = g.add(OpKind::MatMul, &[x, w], "proj");
        let gl = g.add_attr1(OpKind::Gelu, &[m], "act", "approx", "tanh");
        g.add(OpKind::Output, &[gl], "out");
        let mut rng = Prng::new(1);
        let mut prog = Program::new(g);
        prog.feed(0, Tensor::randn(&mut rng, &[16, 32]));
        prog.feed(1, Tensor::randn(&mut rng, &[32, 8]));
        let mut config = Env::new();
        if tf32_config {
            config.set("allow_tf32", "true");
        }
        let exec = Executor::new(DeviceSpec::h200_sim(), Dispatcher::new(), config);
        (exec, prog)
    }

    #[test]
    fn run_produces_tensors_energy_trace() {
        let (exec, prog) = simple_program(false);
        let arts = exec.run(&prog);
        assert_eq!(arts.output().shape(), &[16, 8]);
        assert!(arts.total_energy_j > 0.0);
        assert!(arts.gpu_time_us > 0.0);
        assert_eq!(arts.records.len(), 2); // matmul + gelu
        assert_eq!(arts.trace.kernel_call_paths().len(), 2);
    }

    #[test]
    fn energy_by_op_sorted_desc() {
        let (exec, prog) = simple_program(false);
        let arts = exec.run(&prog);
        let breakdown = arts.energy_by_op();
        assert!(breakdown.windows(2).all(|w| w[0].1 >= w[1].1));
    }

    #[test]
    fn tracing_overhead_increases_wall_time() {
        let (mut exec, prog) = simple_program(false);
        let traced = exec.run(&prog);
        exec.opts.tracing = false;
        let untraced = exec.run(&prog);
        assert!(traced.wall_time_us > untraced.wall_time_us);
        assert_eq!(traced.gpu_time_us, untraced.gpu_time_us);
    }

    #[test]
    fn power_trace_energy_matches_records() {
        let (exec, prog) = simple_program(false);
        let arts = exec.run(&prog);
        let from_trace = arts.power.total_energy();
        let rel = (from_trace - arts.total_energy_j).abs() / arts.total_energy_j;
        assert!(rel < 0.05, "trace {from_trace} vs records {}", arts.total_energy_j);
    }
}
