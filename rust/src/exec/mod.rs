//! Graph executor: runs a computational graph on the tensor substrate
//! while accounting energy, time, power, and trace events per kernel.
//!
//! For every non-virtual node the executor (1) asks the [`Dispatcher`]
//! which kernel variant the owning framework would launch under the
//! current configuration (this is where misconfigurations change both
//! cost *and* numerics — a TF32 kernel truncates mantissas), (2)
//! computes the output tensor, (3) derives FLOP/byte counts from the
//! shapes, (4) evaluates the cost model and appends to the power trace,
//! and (5) emits correlated API-call + kernel-launch trace events.

pub mod counts;

use std::collections::{BTreeMap, HashSet};

use crate::dispatch::{Env, KernelChoice, Outcome, Routine};
use crate::energy::{ComputeUnit, DeviceSpec, KernelCost, KernelDesc, PowerTrace, Segment};
use crate::graph::{Graph, Node, NodeId, OpKind};
use crate::tensor::{conv, nn, ops, Tensor};
use crate::trace::{EventKind, Frame, TraceBuffer};

/// A runnable program: a graph plus tensors bound to its source nodes.
#[derive(Clone, Debug)]
pub struct Program {
    pub graph: Graph,
    /// Tensor feeds for `Input` / `Weight` nodes.
    pub feeds: BTreeMap<NodeId, Tensor>,
}

impl Program {
    pub fn new(graph: Graph) -> Program {
        Program { graph, feeds: BTreeMap::new() }
    }

    pub fn feed(&mut self, node: NodeId, t: Tensor) -> &mut Self {
        self.feeds.insert(node, t);
        self
    }
}

/// Kernel-selection oracle: routines registered per dispatch key. Nodes
/// pick a routine via their `dispatch` attribute (falling back to the op
/// name); unknown keys get a default direct routine.
#[derive(Clone, Debug, Default)]
pub struct Dispatcher {
    pub routines: BTreeMap<String, Routine>,
}

impl Dispatcher {
    pub fn new() -> Dispatcher {
        Dispatcher::default()
    }

    pub fn register(&mut self, key: &str, routine: Routine) -> &mut Self {
        self.routines.insert(key.to_string(), routine);
        self
    }

    /// Dispatch a node under `env`. Falls back to a sane direct routine
    /// per op kind when no routine is registered.
    pub fn dispatch(&self, op: OpKind, key: &str, env: &Env) -> Outcome {
        if let Some(r) = self.routines.get(key) {
            return r.run(env);
        }
        if let Some(r) = self.routines.get(op.name()) {
            return r.run(env);
        }
        default_routine(op).run(env)
    }

    /// Find the routine a node would use (for diagnosis re-runs).
    pub fn routine_for(&self, op: OpKind, key: &str) -> Routine {
        self.routines
            .get(key)
            .or_else(|| self.routines.get(op.name()))
            .cloned()
            .unwrap_or_else(|| default_routine(op))
    }
}

/// Default kernel choice for an op when the framework registered nothing.
pub fn default_routine(op: OpKind) -> Routine {
    let unit = match op {
        OpKind::MatMul | OpKind::AddMm | OpKind::Conv2d | OpKind::Attention => ComputeUnit::TensorCore,
        OpKind::Tanh | OpKind::Gelu | OpKind::Silu | OpKind::Softmax | OpKind::Expm => ComputeUnit::Sfu,
        OpKind::Contiguous | OpKind::Copy | OpKind::Concat | OpKind::SplitChunk | OpKind::Slice => ComputeUnit::Mem,
        OpKind::AllReduce => ComputeUnit::Link,
        _ => ComputeUnit::CudaCore,
    };
    let kernel = format!("default_{}", op.name());
    Routine::direct(&format!("aten::{}", op.name()), vec![Frame::cpp("at::native::dispatch")], KernelChoice::new(&kernel, unit))
}

/// One executed kernel with full context (the unified trace row).
#[derive(Clone, Debug)]
pub struct KernelRecord {
    pub node: NodeId,
    pub op: OpKind,
    pub label: String,
    pub api: String,
    /// Dispatch-routine key the executor used (for diagnosis re-runs).
    pub dispatch_key: String,
    pub kernel: String,
    pub time_us: f64,
    pub energy_j: f64,
    pub avg_power_w: f64,
    pub corr_id: u64,
    pub bb_trace: Vec<(String, usize)>,
    pub call_path: Vec<Frame>,
    /// Cheap spectral content sketch of the op's output
    /// ([`crate::fingerprint::content_sketch`]); empty when
    /// [`ExecOptions::content_sketch`] is off. The streaming auditor
    /// compares sketches per matched pair to guard output equivalence.
    pub moments: Vec<f64>,
}

/// Build the unified trace row for one executed kernel — the single
/// source of truth for both the batch path ([`Executor::run_observed`])
/// and the streaming path ([`StreamExec`]), so their records can never
/// drift apart field by field.
fn make_record(
    node: &Node,
    outcome: &Outcome,
    cost: &KernelCost,
    key: String,
    corr: u64,
    moments: Vec<f64>,
) -> KernelRecord {
    KernelRecord {
        node: node.id,
        op: node.op,
        label: node.label.clone(),
        api: outcome.call_path[0].func.clone(),
        dispatch_key: key,
        kernel: outcome.choice.kernel.clone(),
        time_us: cost.time_us,
        energy_j: cost.energy_j,
        avg_power_w: cost.avg_power_w,
        corr_id: corr,
        bb_trace: outcome.bb_trace.clone(),
        call_path: outcome.call_path.clone(),
        moments,
    }
}

/// Everything a run produces.
#[derive(Clone, Debug)]
pub struct RunArtifacts {
    pub graph: Graph,
    /// Output tensor per node (present when `record_tensors`).
    pub tensors: Vec<Option<Tensor>>,
    pub records: Vec<KernelRecord>,
    pub trace: TraceBuffer,
    pub power: PowerTrace,
    /// GPU busy time (µs).
    pub gpu_time_us: f64,
    /// End-to-end wall time incl. tracing overhead (µs).
    pub wall_time_us: f64,
    pub total_energy_j: f64,
}

impl RunArtifacts {
    /// The final output tensor (last well-formed Output node's input,
    /// or last node). Output nodes with no inputs are skipped.
    pub fn output(&self) -> &Tensor {
        let out_node = self
            .graph
            .nodes
            .iter()
            .rev()
            .find(|n| n.op == OpKind::Output && !n.inputs.is_empty())
            .map(|n| n.inputs[0])
            .unwrap_or(self.graph.len() - 1);
        self.tensors[out_node].as_ref().expect("run with record_tensors")
    }

    /// Energy attributed to a node.
    pub fn node_energy_j(&self, node: NodeId) -> f64 {
        self.records.iter().filter(|r| r.node == node).map(|r| r.energy_j).sum()
    }

    /// Per-operator energy breakdown aggregated by op kind (Fig 2 rows).
    pub fn energy_by_op(&self) -> Vec<(String, f64)> {
        let mut agg: BTreeMap<String, f64> = BTreeMap::new();
        for r in &self.records {
            *agg.entry(r.op.name().to_string()).or_insert(0.0) += r.energy_j;
        }
        let mut v: Vec<(String, f64)> = agg.into_iter().collect();
        v.sort_by(|a, b| b.1.total_cmp(&a.1));
        v
    }
}

/// Executor options.
#[derive(Clone, Debug)]
pub struct ExecOptions {
    /// Capture trace events (charges per-event overhead on wall time).
    pub tracing: bool,
    /// Keep every node's output tensor (needed for fingerprint matching).
    pub record_tensors: bool,
    /// Per-event tracing overhead, µs (Fig 10's knob). Calibrated so
    /// the interception-cost : kernel-duration ratio matches the real
    /// CUPTI-vs-H200 testbed (~1 µs interception against ~40 µs
    /// kernels); our simulated kernels are ~40x shorter, so the
    /// per-event cost scales down with them.
    pub trace_overhead_us: f64,
    /// Attach a cheap spectral content sketch
    /// ([`crate::fingerprint::content_sketch`]) to every
    /// [`KernelRecord`]. Off by default: the batch pipeline already
    /// fingerprints retained tensors, and big offline graphs would pay
    /// O(min² · max) per op for nothing. The streaming layer
    /// ([`crate::stream`]) turns it on to guard output equivalence.
    pub content_sketch: bool,
}

impl Default for ExecOptions {
    fn default() -> ExecOptions {
        ExecOptions {
            tracing: true,
            record_tensors: true,
            trace_overhead_us: 0.008,
            content_sketch: false,
        }
    }
}

/// The executor: device + dispatcher + global config.
pub struct Executor {
    pub device: DeviceSpec,
    pub dispatcher: Dispatcher,
    pub config: Env,
    pub opts: ExecOptions,
}

impl Executor {
    pub fn new(device: DeviceSpec, dispatcher: Dispatcher, config: Env) -> Executor {
        Executor { device, dispatcher, config, opts: ExecOptions::default() }
    }

    /// Dispatch, evaluate, and cost one materialised (non-virtual) node:
    /// steps 2–4 of the executor contract. Shared by the batch path
    /// ([`Executor::run_observed`]) and the streaming path
    /// ([`StreamExec`]), so both produce identical records.
    fn exec_kernel(&self, node: &Node, ins: &[&Tensor]) -> (Outcome, KernelCost, Tensor, String) {
        // 2. dispatch: which kernel variant does the framework pick?
        let env = self.config.merged(&node.attrs);
        let key = node.attrs.get("dispatch").cloned().unwrap_or_else(|| node.op.name().to_string());
        let outcome = self.dispatcher.dispatch(node.op, &key, &env);
        let choice = &outcome.choice;

        // 3. numerics (TF32 kernels round inputs)
        let tf32 = choice.unit == ComputeUnit::TensorCore
            && matches!(node.op, OpKind::MatMul | OpKind::AddMm | OpKind::Attention | OpKind::Conv2d);
        let out = eval_node(node.op, &node.attrs, ins, tf32);

        // 4. cost
        let (flops, bytes, n_launches) = counts::op_counts(node.op, &node.attrs, ins, &out);
        let desc = if node.op == OpKind::Barrier || node.op == OpKind::Idle {
            let wait_us: f64 = node.attrs.get("wait_us").and_then(|s| s.parse().ok()).unwrap_or(1000.0);
            let frac: f64 = node.attrs.get("power_frac").and_then(|s| s.parse().ok()).unwrap_or(
                if node.op == OpKind::Barrier { 0.45 } else { 0.0 },
            );
            let w = if node.op == OpKind::Idle {
                self.device.idle_w
            } else {
                self.device.base_w.max(frac * self.device.max_w)
            };
            KernelDesc::fixed(&choice.kernel, wait_us, w)
        } else {
            KernelDesc {
                name: choice.kernel.clone(),
                unit: choice.unit,
                flops,
                bytes: bytes * choice.bytes_mult,
                efficiency: choice.efficiency,
                time_mult: choice.time_mult,
                fixed_time_us: 0.0,
                fixed_power_w: 0.0,
            }
        };
        // multi-launch ops (e.g. per-launch overhead of split kernels)
        let mut cost = desc.cost(&self.device);
        if n_launches > 1 {
            let extra = (n_launches - 1) as f64 * self.device.launch_overhead_us;
            cost.time_us += extra;
            cost.energy_j += extra * 1e-6 * self.device.base_w;
            // keep the three energy views (records, trace, power
            // integral) consistent after the adjustment
            cost.avg_power_w = (cost.energy_j / (cost.time_us * 1e-6)).min(self.device.max_w);
            cost.energy_j = cost.energy_j.min(cost.avg_power_w * cost.time_us * 1e-6);
        }
        (outcome, cost, out, key)
    }

    /// Content sketch of an op output when enabled (empty otherwise).
    fn maybe_sketch(&self, out: &Tensor) -> Vec<f64> {
        if self.opts.content_sketch {
            crate::fingerprint::content_sketch(&crate::fingerprint::RustMomentEngine, out)
        } else {
            Vec::new()
        }
    }

    /// Execute a program, producing tensors + energy + trace.
    pub fn run(&self, prog: &Program) -> RunArtifacts {
        self.run_observed(prog, |_, _| {})
    }

    /// Like [`Executor::run`], additionally invoking `observer` after
    /// every kernel launch with the finished record and the power
    /// segment it contributed — the segment-emitting run mode the
    /// stream subsystem taps. For runs too long to materialise at all,
    /// use [`Executor::stream`] instead.
    pub fn run_observed(
        &self,
        prog: &Program,
        mut observer: impl FnMut(&KernelRecord, Segment),
    ) -> RunArtifacts {
        let g = &prog.graph;
        // reject malformed graphs (cycles, dangling inputs) with a
        // message naming the node instead of an index panic mid-run
        if let Err(e) = g.validate() {
            panic!("invalid graph: {e}");
        }
        let mut tensors: Vec<Option<Tensor>> = vec![None; g.len()];
        let mut records: Vec<KernelRecord> = Vec::new();
        let mut trace = TraceBuffer::new(if self.opts.tracing { self.opts.trace_overhead_us } else { 0.0 });
        let mut power = PowerTrace::new(self.device.idle_w);
        let mut gpu_time_us = 0.0;

        for node in &g.nodes {
            // 1. bind sources
            if matches!(node.op, OpKind::Input | OpKind::Weight) {
                let t = prog
                    .feeds
                    .get(&node.id)
                    .unwrap_or_else(|| panic!("no feed for {} `{}`", node.op.name(), node.label))
                    .clone();
                tensors[node.id] = Some(t);
                continue;
            }
            if node.op == OpKind::Output {
                // a malformed Output with no inputs stays unmaterialised
                tensors[node.id] = node.inputs.first().and_then(|&i| tensors[i].clone());
                continue;
            }
            // zero-copy metadata ops: no kernel launch, no energy
            if matches!(node.op, OpKind::Permute | OpKind::Reshape) {
                let ins: Vec<&Tensor> = node
                    .inputs
                    .iter()
                    .map(|&i| tensors[i].as_ref().expect("topological order"))
                    .collect();
                tensors[node.id] = Some(eval_node(node.op, &node.attrs, &ins, false));
                continue;
            }

            // 2–4. dispatch + numerics + cost
            let ins: Vec<&Tensor> = node
                .inputs
                .iter()
                .map(|&i| tensors[i].as_ref().expect("topological order"))
                .collect();
            let (outcome, cost, out, key) = self.exec_kernel(node, &ins);
            let choice = &outcome.choice;

            // 5. trace + power accounting. The trace segment carries the
            // record's own average power, so the power-integral and
            // record-sum energy views agree for every op (including
            // low-power Idle waits, which an earlier clamp here skewed).
            let seg = power.push(cost.time_us, cost.avg_power_w);
            let t0 = seg.t_start_us;
            gpu_time_us += cost.time_us;
            let corr = trace.next_corr_id();
            if self.opts.tracing {
                trace.record(
                    corr,
                    t0,
                    t0 + 1.0,
                    EventKind::ApiCall { api: outcome.call_path[0].func.clone() },
                    outcome.call_path.clone(),
                    Some(node.id),
                );
                trace.record(
                    corr,
                    t0,
                    t0 + cost.time_us,
                    EventKind::KernelLaunch { kernel: choice.kernel.clone(), energy_j: cost.energy_j },
                    vec![],
                    Some(node.id),
                );
            }
            records.push(make_record(node, &outcome, &cost, key, corr, self.maybe_sketch(&out)));
            observer(records.last().expect("just pushed"), seg);

            tensors[node.id] = Some(out);
        }

        let total_energy_j = records.iter().map(|r| r.energy_j).sum();
        let wall_time_us = gpu_time_us + trace.total_overhead_us;
        let mut arts = RunArtifacts {
            graph: g.clone(),
            tensors,
            records,
            trace,
            power,
            gpu_time_us,
            wall_time_us,
            total_energy_j,
        };
        if !self.opts.record_tensors {
            // keep only sources + final outputs to bound memory. O(1)
            // membership via HashSet (the old Vec::contains scan was
            // O(outputs) per node); Outputs with no inputs are skipped
            // instead of panicking.
            let keep: HashSet<usize> = g
                .nodes
                .iter()
                .filter(|n| n.op == OpKind::Output && !n.inputs.is_empty())
                .map(|n| n.inputs[0])
                .collect();
            for i in 0..arts.tensors.len() {
                if !keep.contains(&i) && !g.nodes[i].inputs.is_empty() {
                    arts.tensors[i] = None;
                }
            }
        }
        arts
    }

    /// Begin a pull-based streaming execution: see [`StreamExec`].
    pub fn stream<'a>(&'a self, prog: &'a Program) -> StreamExec<'a> {
        StreamExec::new(self, prog)
    }
}

/// Summary counters of a streaming run (no retained artifacts).
#[derive(Clone, Copy, Debug, Default)]
pub struct StreamStats {
    /// Kernels launched so far.
    pub ops: usize,
    /// GPU busy time so far, µs.
    pub gpu_time_us: f64,
    /// Wall time incl. tracing overhead, µs.
    pub wall_time_us: f64,
    /// Energy accounted so far, Joules.
    pub energy_j: f64,
    /// High-water mark of simultaneously live intermediate tensors.
    pub live_tensors_peak: usize,
}

/// Pull-based streaming executor: an iterator yielding one
/// `(KernelRecord, Segment)` per kernel launch, without materialising
/// [`RunArtifacts`] — no record vector, no trace buffer, no power trace.
/// Intermediate tensors are freed at their last use, so peak memory is
/// bounded by the graph's live set, not its length. Two `StreamExec`s
/// zipped together are the natural feed of
/// [`crate::stream::StreamAuditor`].
pub struct StreamExec<'a> {
    exec: &'a Executor,
    prog: &'a Program,
    tensors: Vec<Option<Tensor>>,
    /// For node `i`, the index of the last node consuming it (or `i`).
    last_use: Vec<usize>,
    idx: usize,
    t_us: f64,
    overhead_us: f64,
    next_corr: u64,
    live: usize,
    stats: StreamStats,
}

impl<'a> StreamExec<'a> {
    fn new(exec: &'a Executor, prog: &'a Program) -> StreamExec<'a> {
        let n = prog.graph.len();
        let mut last_use: Vec<usize> = (0..n).collect();
        for node in &prog.graph.nodes {
            for &i in &node.inputs {
                if last_use[i] < node.id {
                    last_use[i] = node.id;
                }
            }
        }
        StreamExec {
            exec,
            prog,
            tensors: vec![None; n],
            last_use,
            idx: 0,
            t_us: 0.0,
            overhead_us: 0.0,
            next_corr: 0,
            live: 0,
            stats: StreamStats::default(),
        }
    }

    /// Running counters (valid mid-stream and after exhaustion).
    pub fn stats(&self) -> StreamStats {
        self.stats
    }

    /// Store a node's output only if a later node consumes it; returns
    /// whether it was retained.
    fn retain(&mut self, id: usize, t: Tensor) {
        if self.last_use[id] > id {
            self.tensors[id] = Some(t);
            self.live += 1;
            if self.live > self.stats.live_tensors_peak {
                self.stats.live_tensors_peak = self.live;
            }
        }
    }

    /// Free inputs whose last consumer is `id`.
    fn release_inputs(&mut self, id: usize) {
        // split the borrow: inputs live in prog.graph, tensors in self
        for k in 0..self.prog.graph.nodes[id].inputs.len() {
            let i = self.prog.graph.nodes[id].inputs[k];
            if self.last_use[i] == id && self.tensors[i].is_some() {
                self.tensors[i] = None;
                self.live -= 1;
            }
        }
    }
}

impl Iterator for StreamExec<'_> {
    type Item = (KernelRecord, Segment);

    fn next(&mut self) -> Option<(KernelRecord, Segment)> {
        while self.idx < self.prog.graph.len() {
            let id = self.idx;
            self.idx += 1;
            let node = &self.prog.graph.nodes[id];
            if matches!(node.op, OpKind::Input | OpKind::Weight) {
                let t = self
                    .prog
                    .feeds
                    .get(&node.id)
                    .unwrap_or_else(|| panic!("no feed for {} `{}`", node.op.name(), node.label))
                    .clone();
                self.retain(id, t);
                continue;
            }
            if node.op == OpKind::Output {
                // stream mode yields events, not tensors: nothing to keep
                self.release_inputs(id);
                continue;
            }
            if matches!(node.op, OpKind::Permute | OpKind::Reshape) {
                let ins: Vec<&Tensor> = node
                    .inputs
                    .iter()
                    .map(|&i| self.tensors[i].as_ref().expect("topological order"))
                    .collect();
                let out = eval_node(node.op, &node.attrs, &ins, false);
                self.release_inputs(id);
                self.retain(id, out);
                continue;
            }

            let ins: Vec<&Tensor> = node
                .inputs
                .iter()
                .map(|&i| self.tensors[i].as_ref().expect("topological order"))
                .collect();
            let (outcome, cost, out, key) = self.exec.exec_kernel(node, &ins);
            self.next_corr += 1;
            let record = make_record(node, &outcome, &cost, key, self.next_corr, self.exec.maybe_sketch(&out));
            self.release_inputs(id);
            self.retain(id, out);

            let seg = Segment {
                t_start_us: self.t_us,
                t_end_us: self.t_us + cost.time_us,
                watts: cost.avg_power_w,
            };
            self.t_us = seg.t_end_us;
            if self.exec.opts.tracing {
                // two events per kernel (api + launch), as in run()
                self.overhead_us += 2.0 * self.exec.opts.trace_overhead_us;
            }
            self.stats.ops += 1;
            self.stats.gpu_time_us += cost.time_us;
            self.stats.energy_j += cost.energy_j;
            self.stats.wall_time_us = self.stats.gpu_time_us + self.overhead_us;
            return Some((record, seg));
        }
        None
    }
}

/// Parse helpers for node attrs.
fn attr_usize(attrs: &crate::graph::Attrs, k: &str, default: usize) -> usize {
    attrs.get(k).and_then(|s| s.parse().ok()).unwrap_or(default)
}
fn attr_f32(attrs: &crate::graph::Attrs, k: &str, default: f32) -> f32 {
    attrs.get(k).and_then(|s| s.parse().ok()).unwrap_or(default)
}
fn attr_list(attrs: &crate::graph::Attrs, k: &str) -> Vec<usize> {
    attrs
        .get(k)
        .map(|s| s.split(',').filter_map(|x| x.trim().parse().ok()).collect())
        .unwrap_or_default()
}

/// Evaluate one operator's numerics.
pub fn eval_node(op: OpKind, attrs: &crate::graph::Attrs, ins: &[&Tensor], tf32: bool) -> Tensor {
    match op {
        OpKind::MatMul => ops::matmul_ex(ins[0], ins[1], tf32),
        OpKind::AddMm => ops::addmm(ins[0], ins[1], ins[2], tf32),
        OpKind::Add => ops::add(ins[0], ins[1]),
        OpKind::Sub => ops::sub(ins[0], ins[1]),
        OpKind::Mul => ops::mul(ins[0], ins[1]),
        OpKind::Div => ops::div(ins[0], ins[1]),
        OpKind::Scale => ops::scale(ins[0], attr_f32(attrs, "s", 1.0)),
        OpKind::Pow => {
            let p = attr_f32(attrs, "p", 2.0);
            ops::map(ins[0], |x| x.powf(p))
        }
        OpKind::Tanh => ops::map(ins[0], f32::tanh),
        OpKind::Gelu => match attrs.get("approx").map(String::as_str) {
            Some("tanh") => nn::gelu_tanh(ins[0]),
            _ => nn::gelu_exact(ins[0]),
        },
        OpKind::Silu => nn::silu(ins[0]),
        OpKind::Relu => ops::map(ins[0], |x| x.max(0.0)),
        OpKind::Softmax => nn::softmax(ins[0]),
        OpKind::LayerNorm => nn::layernorm(ins[0], ins[1], ins[2], 1e-5),
        OpKind::RmsNorm => nn::rmsnorm(ins[0], ins[1], 1e-6),
        OpKind::Attention => {
            // fused GQA: expand kv heads inside the kernel (no HBM cost —
            // the whole point of the c4 fix)
            let reps = attr_usize(attrs, "gqa_reps", 1);
            let nhd = attrs.get("layout").map(String::as_str) == Some("nhd");
            let (k, v) = if reps > 1 {
                let head_dim = if nhd { 2 } else { 1 };
                (
                    ops::repeat_interleave(ins[1], head_dim, reps),
                    ops::repeat_interleave(ins[2], head_dim, reps),
                )
            } else {
                (ins[1].clone(), ins[2].clone())
            };
            if nhd {
                nn::attention_nhd(ins[0], &k, &v)
            } else {
                nn::attention_hnd(ins[0], &k, &v)
            }
        }
        OpKind::Conv2d => {
            let pad = attr_usize(attrs, "pad", 1);
            let groups = attr_usize(attrs, "groups", 1);
            match attrs.get("layout").map(String::as_str) {
                Some("nhwc") => conv::conv2d_nhwc(ins[0], ins[1], pad, groups),
                _ => match attrs.get("algo").map(String::as_str) {
                    Some("im2col") => conv::conv2d_im2col(ins[0], ins[1], pad),
                    _ => conv::conv2d_nchw(ins[0], ins[1], pad, groups),
                },
            }
        }
        OpKind::Permute => ins[0].permute(&attr_list(attrs, "perm")),
        OpKind::Reshape => ins[0].reshape(&attr_list(attrs, "shape")),
        OpKind::Contiguous | OpKind::Copy => ins[0].contiguous(),
        OpKind::Concat => {
            let dim = attr_usize(attrs, "dim", 0);
            Tensor::concat(ins, dim)
        }
        OpKind::SplitChunk => {
            let dim = attr_usize(attrs, "dim", 0);
            let chunks = attr_usize(attrs, "chunks", 1);
            let index = attr_usize(attrs, "index", 0);
            ins[0].split(dim, chunks)[index].contiguous()
        }
        OpKind::Slice => {
            let dim = attr_usize(attrs, "dim", 0);
            ins[0]
                .slice(dim, attr_usize(attrs, "start", 0), attr_usize(attrs, "stop", ins[0].shape()[dim]))
                .contiguous()
        }
        OpKind::TopK => ops::topk_lastdim(ins[0], attr_usize(attrs, "k", 1)),
        OpKind::Sort => ops::sort_lastdim_desc(ins[0]),
        OpKind::CumSum => ops::cumsum_lastdim(ins[0]),
        OpKind::RepeatInterleave => {
            ops::repeat_interleave(ins[0], attr_usize(attrs, "dim", 0), attr_usize(attrs, "reps", 1))
        }
        OpKind::Embedding => {
            let ids: Vec<usize> = attr_list(attrs, "ids");
            ops::embedding(ins[0], &ids)
        }
        OpKind::Arange => Tensor::arange(attr_usize(attrs, "n", 1)),
        OpKind::CrossEntropy => {
            let targets = attr_list(attrs, "targets");
            Tensor::from_vec(vec![nn::cross_entropy(ins[0], &targets)], &[1])
        }
        OpKind::Eigvals => {
            // symmetrise then solve (c6: the efficient path for symmetric inputs)
            let sym = ops::scale(&ops::add(ins[0], &ins[0].t().contiguous()), 0.5);
            let ev = crate::linalg::eigvalsh(&sym);
            let n = ev.len();
            Tensor::from_vec(ev, &[n])
        }
        OpKind::Stft => crate::linalg::stft_mag(
            ins[0],
            attr_usize(attrs, "frame", 32),
            attr_usize(attrs, "hop", 16),
        ),
        OpKind::Expm => crate::linalg::expm(ins[0]),
        OpKind::CountNonzero => {
            Tensor::from_vec(vec![ops::count_nonzero(ins[0]) as f32], &[1])
        }
        OpKind::AllReduce => ins[0].contiguous(), // single-rank view: identity
        OpKind::Barrier | OpKind::Idle => ins
            .first()
            .map(|t| (*t).clone())
            .unwrap_or_else(|| Tensor::zeros(&[1])),
        OpKind::Input | OpKind::Weight | OpKind::Output => unreachable!("handled by run()"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Prng;

    fn simple_program(tf32_config: bool) -> (Executor, Program) {
        let mut g = Graph::new("test");
        let x = g.add(OpKind::Input, &[], "x");
        let w = g.add(OpKind::Weight, &[], "w");
        let m = g.add(OpKind::MatMul, &[x, w], "proj");
        let gl = g.add_attr1(OpKind::Gelu, &[m], "act", "approx", "tanh");
        g.add(OpKind::Output, &[gl], "out");
        let mut rng = Prng::new(1);
        let mut prog = Program::new(g);
        prog.feed(0, Tensor::randn(&mut rng, &[16, 32]));
        prog.feed(1, Tensor::randn(&mut rng, &[32, 8]));
        let mut config = Env::new();
        if tf32_config {
            config.set("allow_tf32", "true");
        }
        let exec = Executor::new(DeviceSpec::h200_sim(), Dispatcher::new(), config);
        (exec, prog)
    }

    #[test]
    fn run_produces_tensors_energy_trace() {
        let (exec, prog) = simple_program(false);
        let arts = exec.run(&prog);
        assert_eq!(arts.output().shape(), &[16, 8]);
        assert!(arts.total_energy_j > 0.0);
        assert!(arts.gpu_time_us > 0.0);
        assert_eq!(arts.records.len(), 2); // matmul + gelu
        assert_eq!(arts.trace.kernel_call_paths().len(), 2);
    }

    #[test]
    fn energy_by_op_sorted_desc() {
        let (exec, prog) = simple_program(false);
        let arts = exec.run(&prog);
        let breakdown = arts.energy_by_op();
        assert!(breakdown.windows(2).all(|w| w[0].1 >= w[1].1));
    }

    #[test]
    fn tracing_overhead_increases_wall_time() {
        let (mut exec, prog) = simple_program(false);
        let traced = exec.run(&prog);
        exec.opts.tracing = false;
        let untraced = exec.run(&prog);
        assert!(traced.wall_time_us > untraced.wall_time_us);
        assert_eq!(traced.gpu_time_us, untraced.gpu_time_us);
    }

    #[test]
    fn power_trace_energy_matches_records() {
        let (exec, prog) = simple_program(false);
        let arts = exec.run(&prog);
        let from_trace = arts.power.total_energy();
        let rel = (from_trace - arts.total_energy_j).abs() / arts.total_energy_j;
        assert!(rel < 0.05, "trace {from_trace} vs records {}", arts.total_energy_j);
    }

    /// A program mixing a hot matmul with low-power Idle/Barrier waits
    /// (the ops the old trace-side clamp skewed).
    fn mixed_power_program() -> (Executor, Program) {
        let mut g = Graph::new("mixed");
        let x = g.add(OpKind::Input, &[], "x");
        let w = g.add(OpKind::Weight, &[], "w");
        let m = g.add(OpKind::MatMul, &[x, w], "proj");
        let mut at = crate::graph::Attrs::new();
        at.insert("wait_us".into(), "2000".into());
        let idle = g.add_attrs(OpKind::Idle, &[m], "wait.idle", at);
        let gl = g.add(OpKind::Gelu, &[idle], "act");
        g.add(OpKind::Output, &[gl], "out");
        let mut rng = Prng::new(21);
        let mut prog = Program::new(g);
        prog.feed(0, Tensor::randn(&mut rng, &[16, 32]));
        prog.feed(1, Tensor::randn(&mut rng, &[32, 32]));
        let exec = Executor::new(DeviceSpec::h200_sim(), Dispatcher::new(), Env::new());
        (exec, prog)
    }

    /// Regression (energy-view divergence): the power pushed to the
    /// trace must be exactly the record's average power, so the
    /// physical-meter integral and the record sum agree tightly even on
    /// low-power Idle ops (the old clamp added up to 1 W there).
    #[test]
    fn power_integral_reconciled_with_records_on_idle_ops() {
        let (exec, prog) = mixed_power_program();
        let arts = exec.run(&prog);
        // the idle op ran at device idle power, below base_w
        let idle_rec = arts.records.iter().find(|r| r.label == "wait.idle").expect("idle record");
        assert!(idle_rec.avg_power_w < exec.device.base_w);
        let idle_seg = arts
            .power
            .segments
            .iter()
            .find(|s| (s.watts - idle_rec.avg_power_w).abs() < 1e-12)
            .expect("trace segment carries the record's own power");
        assert!((idle_seg.dur_us() - idle_rec.time_us).abs() < 1e-9);
        // integral over the whole trace == sum of records, tightly
        let meter = crate::energy::sampler::PhysicalMeter;
        let from_trace = meter.energy_j(&arts.power, 0.0, arts.power.duration_us());
        let rel = (from_trace - arts.total_energy_j).abs() / arts.total_energy_j;
        assert!(rel < 1e-9, "trace {from_trace} vs records {}", arts.total_energy_j);
    }

    /// Regression: a malformed Output node with no inputs must not
    /// panic the run (or the memory-bounding retention pass), and
    /// `output()` must skip it.
    #[test]
    fn malformed_output_node_does_not_panic() {
        let mut g = Graph::new("malformed");
        let x = g.add(OpKind::Input, &[], "x");
        let w = g.add(OpKind::Weight, &[], "w");
        let m = g.add(OpKind::MatMul, &[x, w], "proj");
        g.add(OpKind::Output, &[], "dangling"); // no inputs
        g.add(OpKind::Output, &[m], "out");
        let mut rng = Prng::new(3);
        let mut prog = Program::new(g);
        prog.feed(0, Tensor::randn(&mut rng, &[8, 8]));
        prog.feed(1, Tensor::randn(&mut rng, &[8, 8]));
        let mut exec = Executor::new(DeviceSpec::h200_sim(), Dispatcher::new(), Env::new());
        exec.opts.record_tensors = false; // exercises the retention pass
        let arts = exec.run(&prog);
        assert_eq!(arts.output().shape(), &[8, 8]);
        // the real output's tensor was kept by the retention pass
        assert!(arts.tensors[2].is_some());
    }

    #[test]
    fn observer_sees_every_kernel_and_segment() {
        let (exec, prog) = simple_program(false);
        let mut seen = Vec::new();
        let arts = exec.run_observed(&prog, |r, s| seen.push((r.label.clone(), s)));
        assert_eq!(seen.len(), arts.records.len());
        for ((label, seg), (rec, pseg)) in
            seen.iter().zip(arts.records.iter().zip(arts.power.segments.iter()))
        {
            assert_eq!(label, &rec.label);
            assert_eq!(seg, pseg);
        }
    }

    /// With the content guard enabled, every record carries a finite
    /// order-2 moment sketch of its output, bit-identical between the
    /// batch and streaming paths (they share `exec_kernel`).
    #[test]
    fn content_sketch_attached_when_enabled() {
        let (mut exec, prog) = simple_program(false);
        exec.opts.content_sketch = true;
        let arts = exec.run(&prog);
        for r in &arts.records {
            assert_eq!(r.moments.len(), 2, "{}", r.label);
            assert!(r.moments.iter().all(|m| m.is_finite() && *m > 0.0), "{}", r.label);
        }
        let streamed: Vec<(KernelRecord, Segment)> = exec.stream(&prog).collect();
        for ((sr, _), br) in streamed.iter().zip(arts.records.iter()) {
            assert_eq!(sr.moments, br.moments, "{}", sr.label);
        }
        exec.opts.content_sketch = false;
        assert!(exec.run(&prog).records.iter().all(|r| r.moments.is_empty()));
    }

    /// The streaming iterator must reproduce the batch run's records
    /// exactly (same kernels, energies, times) while keeping memory
    /// bounded: tensors are freed at last use.
    #[test]
    fn stream_exec_matches_batch_run() {
        let (exec, prog) = mixed_power_program();
        let arts = exec.run(&prog);
        let mut stream = exec.stream(&prog);
        let streamed: Vec<(KernelRecord, Segment)> = stream.by_ref().collect();
        assert_eq!(streamed.len(), arts.records.len());
        for ((sr, sseg), (br, bseg)) in streamed.iter().zip(arts.records.iter().zip(arts.power.segments.iter())) {
            assert_eq!(sr.node, br.node);
            assert_eq!(sr.op, br.op);
            assert_eq!(sr.label, br.label);
            assert_eq!(sr.api, br.api);
            assert_eq!(sr.dispatch_key, br.dispatch_key);
            assert_eq!(sr.kernel, br.kernel);
            assert_eq!(sr.corr_id, br.corr_id);
            assert_eq!(sr.call_path, br.call_path);
            assert_eq!(sr.bb_trace, br.bb_trace);
            assert_eq!(sr.energy_j.to_bits(), br.energy_j.to_bits(), "{}", sr.label);
            assert_eq!(sr.time_us.to_bits(), br.time_us.to_bits(), "{}", sr.label);
            assert_eq!(sr.avg_power_w.to_bits(), br.avg_power_w.to_bits(), "{}", sr.label);
            assert_eq!(sseg, bseg);
        }
        let stats = stream.stats();
        assert_eq!(stats.ops, arts.records.len());
        assert!((stats.energy_j - arts.total_energy_j).abs() < 1e-12);
        assert!((stats.wall_time_us - arts.wall_time_us).abs() < 1e-9);
        // all tensors freed by the end (sinks are never retained)
        assert!(stats.live_tensors_peak >= 2);
        assert!(stats.live_tensors_peak < prog.graph.len());
    }
}
