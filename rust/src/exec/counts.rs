//! FLOP / HBM-byte accounting per operator.
//!
//! Counts derive from input/output tensor shapes — the standard
//! analytical cost model (2mnk for GEMM, read+write streams for
//! elementwise). Returns `(flops, bytes, n_kernel_launches)`; the
//! launch count captures ops that real frameworks implement as several
//! kernels (e.g. the unfused GELU decomposition already appears as
//! separate graph nodes, but `eigvals`-style composite ops charge their
//! internal launches here).

use crate::graph::{Attrs, OpKind};
use crate::tensor::Tensor;

/// (flops, hbm_bytes, kernel_launches) for one operator application.
pub fn op_counts(op: OpKind, attrs: &Attrs, ins: &[&Tensor], out: &Tensor) -> (f64, f64, usize) {
    let in_bytes: f64 = ins.iter().map(|t| t.bytes() as f64).sum();
    let out_bytes = out.bytes() as f64;
    let out_n = out.numel() as f64;
    match op {
        OpKind::MatMul => {
            let a = ins[0];
            let b = ins[1];
            let k = *a.shape().last().unwrap() as f64;
            (2.0 * out_n * k, in_bytes + out_bytes, 1)
        }
        OpKind::AddMm => {
            let a = ins[1];
            let k = *a.shape().last().unwrap() as f64;
            // fused epilogue: bias read rides along with the GEMM
            (2.0 * out_n * k + out_n, in_bytes + out_bytes, 1)
        }
        OpKind::Attention => {
            // q,k,v = [b, h, s, d] (fused flash-style kernel)
            let q = ins[0];
            let r = q.rank();
            let (s, d) = (q.shape()[r - 2] as f64, q.shape()[r - 1] as f64);
            let bh: f64 = q.shape()[..r - 2].iter().product::<usize>() as f64;
            let flops = bh * (2.0 * s * s * d * 2.0 + 5.0 * s * s);
            (flops, in_bytes + out_bytes, 1)
        }
        OpKind::Conv2d => {
            let x = ins[0];
            let w = ins[1];
            let groups: f64 = attrs.get("groups").and_then(|s| s.parse().ok()).unwrap_or(1.0);
            let (kh, kw) = (w.shape()[2] as f64, w.shape()[3] as f64);
            let cin_per_group = w.shape()[1] as f64;
            let flops = 2.0 * out_n * cin_per_group * kh * kw;
            let bytes = match attrs.get("algo").map(String::as_str) {
                // im2col materialises the column matrix: extra traffic
                Some("im2col") => {
                    let cols = out_n / w.shape()[0] as f64 * cin_per_group * kh * kw * groups;
                    in_bytes + out_bytes + 2.0 * 4.0 * cols
                }
                _ => in_bytes + out_bytes,
            };
            (flops, bytes, if attrs.get("algo").map(String::as_str) == Some("im2col") { 2 } else { 1 })
        }
        OpKind::Softmax => (5.0 * out_n, in_bytes + out_bytes, 1),
        OpKind::LayerNorm | OpKind::RmsNorm => (8.0 * out_n, in_bytes + out_bytes, 1),
        OpKind::Gelu | OpKind::Silu | OpKind::Tanh => (8.0 * out_n, in_bytes + out_bytes, 1),
        OpKind::Add | OpKind::Sub | OpKind::Mul | OpKind::Div | OpKind::Scale | OpKind::Pow | OpKind::Relu => {
            (out_n, in_bytes + out_bytes, 1)
        }
        OpKind::Contiguous | OpKind::Copy => (0.0, in_bytes + out_bytes, 1),
        OpKind::Concat | OpKind::SplitChunk | OpKind::Slice => (0.0, in_bytes.min(out_bytes) + out_bytes, 1),
        OpKind::TopK => {
            let last = *ins[0].shape().last().unwrap() as f64;
            // selection-based top-k: ~n log k work
            let k: f64 = attrs.get("k").and_then(|s| s.parse().ok()).unwrap_or(1.0);
            (ins[0].numel() as f64 * (k.max(2.0)).log2(), in_bytes + out_bytes, 1).max_flops(last)
        }
        OpKind::Sort => {
            let last = *ins[0].shape().last().unwrap() as f64;
            (ins[0].numel() as f64 * last.log2().max(1.0), 2.0 * in_bytes + out_bytes, 2)
        }
        OpKind::CumSum => (out_n, in_bytes + out_bytes, 1),
        OpKind::RepeatInterleave => (0.0, in_bytes + out_bytes, 1),
        OpKind::Embedding => (0.0, out_bytes * 2.0, 1),
        OpKind::Arange => (out_n, out_bytes, 1),
        OpKind::CrossEntropy => {
            let n = ins[0].numel() as f64;
            (6.0 * n, in_bytes + out_bytes, 2)
        }
        OpKind::Eigvals => {
            let n = ins[0].shape()[0] as f64;
            // iterative eigensolver: O(n^3) with a sweep constant
            (30.0 * n * n * n, in_bytes * 4.0, 8)
        }
        OpKind::Stft => {
            let frame: f64 = attrs.get("frame").and_then(|s| s.parse().ok()).unwrap_or(32.0);
            (out_n * frame * 4.0, in_bytes * 2.0 + out_bytes, 3)
        }
        OpKind::Expm => {
            // scaling-and-squaring: ~18 GEMMs fused into ~8 launches
            let n = ins[0].shape()[0] as f64;
            (2.0 * 18.0 * n * n * n, in_bytes * 18.0, 8)
        }
        OpKind::CountNonzero => (ins[0].numel() as f64, in_bytes, 1),
        OpKind::AllReduce => {
            // ring all-reduce moves 2x the payload over the link
            (ins[0].numel() as f64, 2.0 * in_bytes, 1)
        }
        OpKind::Barrier | OpKind::Idle => (0.0, 0.0, 1),
        OpKind::Input | OpKind::Weight | OpKind::Output | OpKind::Permute | OpKind::Reshape => (0.0, 0.0, 0),
    }
}

/// Small helper so `TopK` can express "at least one pass over the row".
trait MaxFlops {
    fn max_flops(self, last: f64) -> (f64, f64, usize);
}

impl MaxFlops for (f64, f64, usize) {
    fn max_flops(self, last: f64) -> (f64, f64, usize) {
        (self.0.max(last), self.1, self.2)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Attrs;
    use crate::util::Prng;

    #[test]
    fn matmul_flops_2mnk() {
        let mut rng = Prng::new(1);
        let a = Tensor::randn(&mut rng, &[4, 8]);
        let b = Tensor::randn(&mut rng, &[8, 16]);
        let out = crate::tensor::ops::matmul(&a, &b);
        let (f, _, _) = op_counts(OpKind::MatMul, &Attrs::new(), &[&a, &b], &out);
        assert_eq!(f, 2.0 * 4.0 * 8.0 * 16.0);
    }

    #[test]
    fn elementwise_bytes_read_plus_write() {
        let mut rng = Prng::new(2);
        let a = Tensor::randn(&mut rng, &[100]);
        let b = Tensor::randn(&mut rng, &[100]);
        let out = crate::tensor::ops::add(&a, &b);
        let (_, bytes, _) = op_counts(OpKind::Add, &Attrs::new(), &[&a, &b], &out);
        assert_eq!(bytes, (100.0 * 4.0) * 3.0);
    }

    #[test]
    fn im2col_charges_more_bytes_than_direct() {
        let mut rng = Prng::new(3);
        let x = Tensor::randn(&mut rng, &[1, 8, 16, 16]);
        let w = Tensor::randn(&mut rng, &[8, 8, 3, 3]);
        let out = crate::tensor::conv::conv2d_nchw(&x, &w, 1, 1);
        let direct = op_counts(OpKind::Conv2d, &Attrs::new(), &[&x, &w], &out);
        let mut attrs = Attrs::new();
        attrs.insert("algo".into(), "im2col".into());
        let im2col = op_counts(OpKind::Conv2d, &attrs, &[&x, &w], &out);
        assert!(im2col.1 > direct.1 * 1.5);
        assert_eq!(im2col.0, direct.0); // same math
    }

    #[test]
    fn allreduce_moves_double_payload() {
        let mut rng = Prng::new(4);
        let g = Tensor::randn(&mut rng, &[1000]);
        let (_, bytes, _) = op_counts(OpKind::AllReduce, &Attrs::new(), &[&g], &g);
        assert_eq!(bytes, 2.0 * 4000.0);
    }

    #[test]
    fn virtual_ops_are_free() {
        let t = Tensor::zeros(&[10]);
        for op in [OpKind::Permute, OpKind::Reshape] {
            let (f, b, l) = op_counts(op, &Attrs::new(), &[&t], &t);
            assert_eq!((f, b, l), (0.0, 0.0, 0));
        }
    }

    #[test]
    fn sort_costs_more_than_topk() {
        let mut rng = Prng::new(5);
        let a = Tensor::randn(&mut rng, &[64, 1024]);
        let sorted = crate::tensor::ops::sort_lastdim_desc(&a);
        let mut attrs = Attrs::new();
        attrs.insert("k".into(), "8".into());
        let top = crate::tensor::ops::topk_lastdim(&a, 8);
        let (fs, bs, _) = op_counts(OpKind::Sort, &Attrs::new(), &[&a], &sorted);
        let (ft, bt, _) = op_counts(OpKind::TopK, &attrs, &[&a], &top);
        assert!(fs > ft, "sort flops {fs} <= topk {ft}");
        assert!(bs > bt);
    }
}
