//! End-to-end Magneton pipeline (Fig 6): run two systems on the same
//! workload → profile energy per operator → match semantically
//! equivalent subgraphs → detect waste → diagnose root causes.
//! [`fleet`] batches many such audits over a bounded worker pool.

pub mod fleet;

use std::time::Instant;

use crate::detect::{detect, DetectConfig, Finding};
use crate::diagnose::{diagnose, Diagnosis};
use crate::dispatch::Env;
use crate::energy::DeviceSpec;
use crate::exec::{Dispatcher, ExecOptions, Executor, Program, RunArtifacts};
use crate::fingerprint::{MomentEngine, RustMomentEngine};
use crate::matching::{find_equivalent_tensors, recursive_match, Region};

/// One system's side of a differential audit: how to run it.
pub struct SysRun {
    pub label: String,
    pub dispatcher: Dispatcher,
    pub env: Env,
    pub prog: Program,
}

impl SysRun {
    pub fn new(label: &str, dispatcher: Dispatcher, env: Env, prog: Program) -> SysRun {
        SysRun { label: label.to_string(), dispatcher, env, prog }
    }
}

/// Everything an audit produces.
pub struct AuditOutcome {
    pub a: RunArtifacts,
    pub b: RunArtifacts,
    pub eq_pairs: usize,
    pub regions: Vec<Region>,
    pub findings: Vec<Finding>,
    /// Diagnoses of findings that are genuine waste (not trade-offs).
    pub diagnoses: Vec<(Finding, Diagnosis)>,
    /// Wall time of the matching stage, µs (Fig 9).
    pub match_time_us: f64,
    /// Relative end-to-end energy difference |A−B| / max.
    pub e2e_diff_frac: f64,
}

impl AuditOutcome {
    /// Did Magneton flag any waste?
    pub fn detected(&self) -> bool {
        !self.findings.is_empty()
    }
}

/// The Magneton profiler-coordinator.
pub struct Magneton {
    /// Tensor-equivalence tolerance ε (paper sweeps 1e-7..0.2; optimal
    /// band 1e-4..1.8e-2).
    pub eps: f64,
    pub cfg: DetectConfig,
    pub device: DeviceSpec,
    /// Moment engine for fingerprints (Rust fallback or PJRT kernel).
    pub engine: Box<dyn MomentEngine + Send>,
    /// Tracing options applied to both runs.
    pub exec_opts: ExecOptions,
}

impl Magneton {
    pub fn new(device: DeviceSpec) -> Magneton {
        Magneton {
            eps: 5e-3,
            cfg: DetectConfig::default(),
            device,
            engine: Box::new(RustMomentEngine),
            exec_opts: ExecOptions::default(),
        }
    }

    /// Execute one side under this coordinator's device/options.
    pub fn run_side(&self, side: &SysRun) -> RunArtifacts {
        let mut exec = Executor::new(self.device.clone(), side.dispatcher.clone(), side.env.clone());
        exec.opts = self.exec_opts.clone();
        exec.run(&side.prog)
    }

    /// Full differential audit of two systems on the same workload.
    pub fn audit(&self, a: &SysRun, b: &SysRun) -> AuditOutcome {
        let ra = self.run_side(a);
        let rb = self.run_side(b);
        self.audit_runs(a, b, ra, rb)
    }

    /// Audit pre-executed runs (used by benches that time stages).
    pub fn audit_runs(
        &self,
        a: &SysRun,
        b: &SysRun,
        ra: RunArtifacts,
        rb: RunArtifacts,
    ) -> AuditOutcome {
        let t0 = Instant::now();
        let eq = find_equivalent_tensors(&ra, &rb, self.eps, self.engine.as_ref());
        let regions = recursive_match(&ra.graph, &rb.graph, &eq);
        let match_time_us = t0.elapsed().as_secs_f64() * 1e6;

        let findings = detect(&ra, &rb, &regions, &self.cfg);
        let diagnoses = findings
            .iter()
            .filter(|f| !f.is_tradeoff)
            .map(|f| {
                let disp = match f.wasteful {
                    crate::detect::Side::A => &a.dispatcher,
                    crate::detect::Side::B => &b.dispatcher,
                };
                (f.clone(), diagnose(f, &ra, &rb, disp))
            })
            .collect();
        let e2e_diff_frac = (ra.total_energy_j - rb.total_energy_j).abs()
            / ra.total_energy_j.max(rb.total_energy_j).max(1e-30);
        AuditOutcome {
            a: ra,
            b: rb,
            eq_pairs: eq.len(),
            regions,
            findings,
            diagnoses,
            match_time_us,
            e2e_diff_frac,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{Graph, OpKind};
    use crate::tensor::Tensor;
    use crate::util::Prng;

    fn mk_run(label: &str, eff: f64) -> SysRun {
        let mut rng = Prng::new(5);
        let x = Tensor::randn(&mut rng, &[128, 256]);
        let w = Tensor::randn(&mut rng, &[256, 256]);
        let mut g = Graph::new(label);
        let xi = g.add(OpKind::Input, &[], "x");
        let wi = g.add(OpKind::Weight, &[], "w");
        let m = g.add(OpKind::MatMul, &[xi, wi], "proj");
        g.add(OpKind::Output, &[m], "out");
        let mut prog = Program::new(g);
        prog.feed(0, x);
        prog.feed(1, w);
        let mut disp = Dispatcher::new();
        disp.register(
            "matmul",
            crate::dispatch::Routine::direct(
                "torch.matmul",
                vec![],
                crate::dispatch::KernelChoice::new("gemm", crate::energy::ComputeUnit::TensorCore)
                    .quality(eff, 1.0, 1.0),
            ),
        );
        SysRun::new(label, disp, Env::new(), prog)
    }

    #[test]
    fn audit_detects_and_diagnoses() {
        let mag = Magneton::new(DeviceSpec::h200_sim());
        let out = mag.audit(&mk_run("bad", 0.6), &mk_run("good", 1.0));
        assert!(out.eq_pairs > 0);
        assert!(out.detected());
        assert!(!out.diagnoses.is_empty());
        assert!(out.match_time_us > 0.0);
    }

    #[test]
    fn audit_of_identical_systems_is_clean() {
        let mag = Magneton::new(DeviceSpec::h200_sim());
        let out = mag.audit(&mk_run("x", 1.0), &mk_run("y", 1.0));
        assert!(!out.detected());
        assert!(out.e2e_diff_frac < 0.01);
    }
}
