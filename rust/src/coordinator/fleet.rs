//! Fleet-scale differential auditing (the multi-workload layer the
//! ROADMAP's north star asks for): run N system pairs concurrently over
//! a bounded worker pool and aggregate their [`AuditOutcome`]s into a
//! ranked cross-system waste report.
//!
//! Each worker owns its private [`Magneton`] coordinator (the Rust
//! moment engine is zero-sized, so per-worker construction is free) and
//! the pairs fan out through [`pool::par_map`], which bounds concurrency
//! at [`FleetAudit::workers`] while preserving submission order before
//! the final ranking — results are therefore deterministic regardless
//! of worker count.

use std::time::Instant;

use crate::coordinator::{AuditOutcome, Magneton, SysRun};
use crate::detect::DetectConfig;
use crate::energy::DeviceSpec;
use crate::exec::ExecOptions;
use crate::util::pool;

/// One named audit job: two systems on the same workload.
pub struct FleetPair {
    pub name: String,
    pub a: SysRun,
    pub b: SysRun,
}

/// The aggregated result of one pair's audit.
pub struct FleetEntry {
    pub name: String,
    pub outcome: AuditOutcome,
    /// Joules lost to genuine (non-trade-off) waste findings.
    pub wasted_j: f64,
    pub findings: usize,
    pub tradeoffs: usize,
}

/// A finished fleet audit, entries ranked most-wasteful first.
pub struct FleetReport {
    pub entries: Vec<FleetEntry>,
    pub total_wasted_j: f64,
    pub total_findings: usize,
    /// End-to-end wall time of the fleet run, µs.
    pub wall_time_us: f64,
    pub workers: usize,
}

impl FleetReport {
    /// Pairs where Magneton flagged at least one finding.
    pub fn flagged(&self) -> usize {
        self.entries.iter().filter(|e| e.findings > 0).count()
    }
}

/// Joules attributable to genuine waste in one audit (the ranking key):
/// the absolute energy gap of every non-trade-off finding.
pub fn waste_joules(outcome: &AuditOutcome) -> f64 {
    outcome
        .findings
        .iter()
        .filter(|f| !f.is_tradeoff)
        .map(|f| (f.energy_a_j - f.energy_b_j).abs())
        .sum()
}

/// Batch coordinator: queue [`SysRun`] pairs, then [`FleetAudit::run`]
/// them over a bounded worker pool.
pub struct FleetAudit {
    pub device: DeviceSpec,
    /// Tensor-equivalence tolerance ε (see [`Magneton::eps`]).
    pub eps: f64,
    pub cfg: DetectConfig,
    pub exec_opts: ExecOptions,
    /// Maximum concurrent audits.
    pub workers: usize,
    pairs: Vec<FleetPair>,
}

impl FleetAudit {
    pub fn new(device: DeviceSpec) -> FleetAudit {
        let defaults = Magneton::new(device.clone());
        FleetAudit {
            device,
            eps: defaults.eps,
            cfg: defaults.cfg,
            exec_opts: defaults.exec_opts,
            workers: pool::default_threads(),
            pairs: Vec::new(),
        }
    }

    /// Queue one audit job.
    pub fn add_pair(&mut self, name: &str, a: SysRun, b: SysRun) -> &mut Self {
        self.pairs.push(FleetPair { name: name.to_string(), a, b });
        self
    }

    pub fn len(&self) -> usize {
        self.pairs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pairs.is_empty()
    }

    /// Run every queued pair over at most [`FleetAudit::workers`]
    /// concurrent audits and rank the outcomes by wasted joules.
    pub fn run(&self) -> FleetReport {
        let t0 = Instant::now();
        let workers = self.workers.max(1).min(self.pairs.len().max(1));
        let mut entries: Vec<FleetEntry> = pool::par_map(&self.pairs, workers, |p| {
            let mut mag = Magneton::new(self.device.clone());
            mag.eps = self.eps;
            mag.cfg = self.cfg;
            mag.exec_opts = self.exec_opts.clone();
            let outcome = mag.audit(&p.a, &p.b);
            let wasted_j = waste_joules(&outcome);
            let findings = outcome.findings.len();
            let tradeoffs = outcome.findings.iter().filter(|f| f.is_tradeoff).count();
            FleetEntry { name: p.name.clone(), outcome, wasted_j, findings, tradeoffs }
        });
        // rank most-wasteful first; tie-break on name so the report is
        // stable across worker counts
        entries.sort_by(|x, y| y.wasted_j.total_cmp(&x.wasted_j).then_with(|| x.name.cmp(&y.name)));
        let total_wasted_j = entries.iter().map(|e| e.wasted_j).sum();
        let total_findings = entries.iter().map(|e| e.findings).sum();
        FleetReport {
            entries,
            total_wasted_j,
            total_findings,
            wall_time_us: t0.elapsed().as_secs_f64() * 1e6,
            workers,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dispatch::{Env, KernelChoice, Routine};
    use crate::energy::ComputeUnit;
    use crate::exec::{Dispatcher, Program};
    use crate::graph::{Graph, OpKind};
    use crate::tensor::Tensor;
    use crate::util::Prng;

    /// A small matmul system whose kernel efficiency is `eff` (1.0 =
    /// optimal; lower burns extra energy at equal time).
    fn mk_run(label: &str, seed: u64, eff: f64) -> SysRun {
        let mut rng = Prng::new(seed);
        let x = Tensor::randn(&mut rng, &[128, 256]);
        let w = Tensor::randn(&mut rng, &[256, 256]);
        let mut g = Graph::new(label);
        let xi = g.add(OpKind::Input, &[], "x");
        let wi = g.add(OpKind::Weight, &[], "w");
        let m = g.add(OpKind::MatMul, &[xi, wi], "proj");
        g.add(OpKind::Output, &[m], "out");
        let mut prog = Program::new(g);
        prog.feed(0, x);
        prog.feed(1, w);
        let mut disp = Dispatcher::new();
        disp.register(
            "matmul",
            Routine::direct(
                "torch.matmul",
                vec![],
                KernelChoice::new("gemm", ComputeUnit::TensorCore).quality(eff, 1.0, 1.0),
            ),
        );
        SysRun::new(label, disp, Env::new(), prog)
    }

    fn fleet_of(n: usize, workers: usize) -> FleetReport {
        let mut fleet = FleetAudit::new(DeviceSpec::h200_sim());
        fleet.workers = workers;
        for i in 0..n {
            // alternate wasteful and clean pairs; share the workload seed
            // within a pair so the two sides compute the same tensors
            let eff = if i % 2 == 0 { 0.6 } else { 1.0 };
            fleet.add_pair(
                &format!("pair-{i}"),
                mk_run("sys-a", 40 + i as u64, eff),
                mk_run("sys-b", 40 + i as u64, 1.0),
            );
        }
        fleet.run()
    }

    #[test]
    fn fleet_audits_all_pairs_and_ranks_by_waste() {
        let r = fleet_of(8, 4);
        assert_eq!(r.entries.len(), 8);
        // wasteful pairs flagged, clean pairs silent
        assert_eq!(r.flagged(), 4);
        // ranking is descending in wasted joules
        for w in r.entries.windows(2) {
            assert!(w[0].wasted_j >= w[1].wasted_j);
        }
        // aggregates match per-entry sums
        let sum: f64 = r.entries.iter().map(|e| e.wasted_j).sum();
        assert!((r.total_wasted_j - sum).abs() < 1e-12);
        assert_eq!(
            r.total_findings,
            r.entries.iter().map(|e| e.findings).sum::<usize>()
        );
        assert!(r.total_wasted_j > 0.0);
    }

    #[test]
    fn fleet_result_independent_of_worker_count() {
        let serial = fleet_of(6, 1);
        let parallel = fleet_of(6, 8);
        assert_eq!(serial.entries.len(), parallel.entries.len());
        for (s, p) in serial.entries.iter().zip(parallel.entries.iter()) {
            assert_eq!(s.name, p.name);
            assert_eq!(s.findings, p.findings);
            assert!((s.wasted_j - p.wasted_j).abs() < 1e-12, "{}", s.name);
        }
    }

    #[test]
    fn clean_fleet_reports_no_waste() {
        let mut fleet = FleetAudit::new(DeviceSpec::h200_sim());
        for i in 0..3 {
            fleet.add_pair(
                &format!("clean-{i}"),
                mk_run("a", 7, 1.0),
                mk_run("b", 7, 1.0),
            );
        }
        let r = fleet.run();
        assert_eq!(r.flagged(), 0);
        assert_eq!(r.total_wasted_j, 0.0);
    }
}
