//! Fleet-scale differential auditing (the multi-workload layer the
//! ROADMAP's north star asks for): run N system pairs concurrently over
//! a bounded worker pool and aggregate their [`AuditOutcome`]s into a
//! ranked cross-system waste report.
//!
//! Each worker owns its private [`Magneton`] coordinator (the Rust
//! moment engine is zero-sized, so per-worker construction is free) and
//! the pairs fan out through [`pool::par_map`], which bounds concurrency
//! at [`FleetAudit::workers`] while preserving submission order before
//! the final ranking — results are therefore deterministic regardless
//! of worker count.
//!
//! Beyond per-pair audits, the streaming fleet correlates divergence
//! *across* pairs: when at least [`StreamFleet::correlate_min`] pairs
//! recover a resync within one correlation window of op positions
//! (shared-cause divergence — a config push, a model reload, a noisy
//! neighbour), their [`ResyncEvent`]s are coalesced into a single
//! ranked [`FleetDivergence`] — one fleet-wide alarm instead of N
//! per-pair ones, with per-pair attribution retained. With
//! [`StreamFleet::snapshot_dir`] set, every pair's windows, resyncs,
//! and summary — plus the fleet ranking and divergence events — are
//! persisted as replayable snapshots ([`crate::telemetry`]).

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::time::Instant;

use crate::coordinator::{AuditOutcome, Magneton, SysRun};
use crate::detect::DetectConfig;
use crate::energy::{DeviceSpec, Segment};
use crate::exec::{ExecOptions, Executor, KernelRecord};
use crate::stream::{
    workload_sig_of_program, ResyncEvent, StreamAuditor, StreamConfig, StreamSummary, WindowReport,
};
use crate::telemetry::{RankEntry, SessionHeader, SinkConfig, Snapshot, SnapshotSink};
use crate::util::{fnv1a, pool, Prng};
use crate::workload::ArrivalProcess;

/// One named audit job: two systems on the same workload.
pub struct FleetPair {
    pub name: String,
    pub a: SysRun,
    pub b: SysRun,
}

/// The aggregated result of one pair's audit.
pub struct FleetEntry {
    pub name: String,
    pub outcome: AuditOutcome,
    /// Joules lost to genuine (non-trade-off) waste findings.
    pub wasted_j: f64,
    pub findings: usize,
    pub tradeoffs: usize,
}

/// A finished fleet audit, entries ranked most-wasteful first.
pub struct FleetReport {
    pub entries: Vec<FleetEntry>,
    pub total_wasted_j: f64,
    pub total_findings: usize,
    /// End-to-end wall time of the fleet run, µs.
    pub wall_time_us: f64,
    pub workers: usize,
}

impl FleetReport {
    /// Pairs where Magneton flagged at least one finding.
    pub fn flagged(&self) -> usize {
        self.entries.iter().filter(|e| e.findings > 0).count()
    }
}

/// Joules attributable to genuine waste in one audit (the ranking key):
/// the absolute energy gap of every non-trade-off finding.
pub fn waste_joules(outcome: &AuditOutcome) -> f64 {
    outcome
        .findings
        .iter()
        .filter(|f| !f.is_tradeoff)
        .map(|f| (f.energy_a_j - f.energy_b_j).abs())
        .sum()
}

/// Batch coordinator: queue [`SysRun`] pairs, then [`FleetAudit::run`]
/// them over a bounded worker pool.
pub struct FleetAudit {
    pub device: DeviceSpec,
    /// Tensor-equivalence tolerance ε (see [`Magneton::eps`]).
    pub eps: f64,
    pub cfg: DetectConfig,
    pub exec_opts: ExecOptions,
    /// Maximum concurrent audits.
    pub workers: usize,
    pairs: Vec<FleetPair>,
}

impl FleetAudit {
    pub fn new(device: DeviceSpec) -> FleetAudit {
        let defaults = Magneton::new(device.clone());
        FleetAudit {
            device,
            eps: defaults.eps,
            cfg: defaults.cfg,
            exec_opts: defaults.exec_opts,
            workers: pool::default_threads(),
            pairs: Vec::new(),
        }
    }

    /// Queue one audit job.
    pub fn add_pair(&mut self, name: &str, a: SysRun, b: SysRun) -> &mut Self {
        self.pairs.push(FleetPair { name: name.to_string(), a, b });
        self
    }

    pub fn len(&self) -> usize {
        self.pairs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pairs.is_empty()
    }

    /// Run every queued pair over at most [`FleetAudit::workers`]
    /// concurrent audits and rank the outcomes by wasted joules.
    pub fn run(&self) -> FleetReport {
        let t0 = Instant::now();
        let workers = self.workers.max(1).min(self.pairs.len().max(1));
        let mut entries: Vec<FleetEntry> = pool::par_map(&self.pairs, workers, |p| {
            let mut mag = Magneton::new(self.device.clone());
            mag.eps = self.eps;
            mag.cfg = self.cfg;
            mag.exec_opts = self.exec_opts.clone();
            let outcome = mag.audit(&p.a, &p.b);
            let wasted_j = waste_joules(&outcome);
            let findings = outcome.findings.len();
            let tradeoffs = outcome.findings.iter().filter(|f| f.is_tradeoff).count();
            FleetEntry { name: p.name.clone(), outcome, wasted_j, findings, tradeoffs }
        });
        // rank most-wasteful first; tie-break on name so the report is
        // stable across worker counts
        entries.sort_by(|x, y| y.wasted_j.total_cmp(&x.wasted_j).then_with(|| x.name.cmp(&y.name)));
        let total_wasted_j = entries.iter().map(|e| e.wasted_j).sum();
        let total_findings = entries.iter().map(|e| e.findings).sum();
        FleetReport {
            entries,
            total_wasted_j,
            total_findings,
            wall_time_us: t0.elapsed().as_secs_f64() * 1e6,
            workers,
        }
    }
}

/// Drive one streaming event-source pair through an auditor,
/// materialising request-arrival idle gaps every `ops_per_request` op
/// pairs on both sides (`ops_per_request == 0` disables gaps). The gap
/// sequence is sampled once from `rng` and applied to both rings, so
/// the arrival process itself can never desynchronise the pair.
/// Emitted windows stream through `on_window`; returns the final
/// summary. Generic over any `(KernelRecord, Segment)` iterator — a
/// live [`crate::exec::StreamExec`] (fleet workers, the `stream_audit`
/// example) or a channel receiver draining chunked ingestion
/// (`magneton stream`) — so the pairing protocol exists exactly once.
pub fn drive_pair_with_arrivals(
    aud: &mut StreamAuditor,
    mut a: impl Iterator<Item = (KernelRecord, Segment)>,
    mut b: impl Iterator<Item = (KernelRecord, Segment)>,
    arrival: ArrivalProcess,
    ops_per_request: usize,
    rng: &mut Prng,
    mut on_window: impl FnMut(WindowReport),
) -> StreamSummary {
    let mut pairs = 0usize;
    let mut request = 0usize;
    loop {
        let na = a.next();
        let nb = b.next();
        if na.is_none() && nb.is_none() {
            break;
        }
        if let Some((rec, seg)) = na {
            aud.ingest_a(&rec, seg);
        }
        if let Some((rec, seg)) = nb {
            aud.ingest_b(&rec, seg);
        }
        pairs += 1;
        if ops_per_request > 0 && pairs % ops_per_request == 0 {
            request += 1;
            let gap = arrival.gap_us(rng, request);
            if gap > 0.0 {
                aud.ingest_idle_a(gap);
                aud.ingest_idle_b(gap);
            }
        }
        for w in aud.take_emitted() {
            on_window(w);
        }
    }
    let summary = aud.finish();
    for w in aud.take_emitted() {
        on_window(w);
    }
    summary
}

/// The aggregated result of one streaming pair.
pub struct StreamFleetEntry {
    pub name: String,
    pub summary: StreamSummary,
    /// Snapshot-sink IO errors for this pair (0 when no sink is
    /// configured).
    pub snapshot_errors: usize,
}

/// A finished streaming fleet audit, ranked most-wasteful first.
pub struct StreamFleetReport {
    pub entries: Vec<StreamFleetEntry>,
    pub total_wasted_j: f64,
    /// Matched op pairs audited across all streams.
    pub total_ops: usize,
    /// Fleet-wide coalesced divergence events (see
    /// [`correlate_divergences`]), in op-position order.
    pub divergences: Vec<FleetDivergence>,
    /// Snapshot IO errors across the pairs and the fleet-level sink.
    pub snapshot_errors: usize,
    /// End-to-end wall time of the fleet run, µs.
    pub wall_time_us: f64,
    pub workers: usize,
}

/// One pair's share of a fleet-wide divergence.
#[derive(Clone, Debug)]
pub struct DivergentPair {
    pub name: String,
    /// Matched-op position of this pair's first coalesced resync.
    pub at_ops: usize,
    /// Resync events coalesced for this pair.
    pub resyncs: usize,
    /// Total events skipped re-anchoring this pair.
    pub skipped: usize,
}

/// A fleet-wide divergence: at least `correlate_min` pairs recovered a
/// resync within one correlation window of matched-op positions — one
/// alarm for what is almost certainly a shared cause, instead of N
/// independent per-pair resync lines.
#[derive(Clone, Debug)]
pub struct FleetDivergence {
    /// Matched-op position of the earliest coalesced resync.
    pub at_ops_min: usize,
    /// Matched-op position of the latest coalesced resync.
    pub at_ops_max: usize,
    /// Per-pair attribution, ranked by skipped events (descending,
    /// name tiebreak).
    pub pairs: Vec<DivergentPair>,
}

/// Coalesce per-pair [`ResyncEvent`]s into fleet-wide
/// [`FleetDivergence`] events. Events are sorted by matched-op
/// position and swept greedily: a cluster opens at the first unclaimed
/// event and absorbs every event within `window_ops` positions of it.
/// A cluster touching at least `min_pairs` *distinct* pairs becomes
/// one divergence event (a pair with several resyncs in the cluster is
/// attributed once, with its events and skips summed); smaller
/// clusters stay per-pair noise and produce nothing.
///
/// Positions are comparable across pairs because every pair of one
/// fleet runs the same workload program, so `ResyncEvent::at_ops`
/// indexes the same logical op sequence.
///
/// The input is each pair's **in-memory** resync log, which is capped
/// (the auditor retains the first `RESYNC_LOG_CAP` = 32 events so its
/// memory stays bounded; the counters stay exact and the snapshot sink
/// persists every event). A pair that saturates that cap is chronically
/// diverging — permanently flagged `aligned: false` with exact
/// `resyncs`/`resync_skipped` totals — so its later events being absent
/// from live correlation is a deliberate bound, not lost evidence: the
/// full event history remains on disk for offline analysis via
/// `magneton replay`.
pub fn correlate_divergences(
    entries: &[StreamFleetEntry],
    window_ops: usize,
    min_pairs: usize,
) -> Vec<FleetDivergence> {
    let mut events: Vec<(usize, &str, &ResyncEvent)> = Vec::new();
    for e in entries {
        for ev in &e.summary.resync_log {
            events.push((ev.at_ops, e.name.as_str(), ev));
        }
    }
    events.sort_by(|a, b| a.0.cmp(&b.0).then_with(|| a.1.cmp(b.1)));
    let min_pairs = min_pairs.max(1);
    let mut out = Vec::new();
    let mut i = 0;
    while i < events.len() {
        let start = events[i].0;
        let mut j = i + 1;
        while j < events.len() && events[j].0 <= start.saturating_add(window_ops) {
            j += 1;
        }
        let mut by_pair: BTreeMap<&str, DivergentPair> = BTreeMap::new();
        for &(at, name, ev) in &events[i..j] {
            let cell = by_pair.entry(name).or_insert_with(|| DivergentPair {
                name: name.to_string(),
                at_ops: at,
                resyncs: 0,
                skipped: 0,
            });
            cell.resyncs += 1;
            cell.skipped += ev.skipped_a + ev.skipped_b;
        }
        if by_pair.len() >= min_pairs {
            let mut pairs: Vec<DivergentPair> = by_pair.into_values().collect();
            pairs.sort_by(|x, y| y.skipped.cmp(&x.skipped).then_with(|| x.name.cmp(&y.name)));
            out.push(FleetDivergence { at_ops_min: start, at_ops_max: events[j - 1].0, pairs });
        }
        i = j;
    }
    out
}

impl StreamFleetReport {
    /// Streams where at least one window was flagged.
    pub fn flagged(&self) -> usize {
        self.entries.iter().filter(|e| e.summary.windows_flagged > 0).count()
    }
}

/// Streaming fleet audit: N long-running serving pairs, each consumed
/// chunk-by-chunk through a [`StreamAuditor`] over the bounded worker
/// pool. Unlike [`FleetAudit`], no run is ever materialised — each
/// worker zips two [`crate::exec::StreamExec`] iterators into its
/// auditor, so per-stream memory is bounded by the ring/window sizes
/// regardless of stream length.
pub struct StreamFleet {
    pub device: DeviceSpec,
    pub cfg: StreamConfig,
    pub exec_opts: ExecOptions,
    /// Maximum concurrent stream audits.
    pub workers: usize,
    /// Request arrival process driving every pair (idle lulls are
    /// materialised in both rings).
    pub arrival: ArrivalProcess,
    /// Op pairs per request (gap-injection stride); `0` disables gaps.
    pub ops_per_request: usize,
    /// Seed of the per-pair arrival rngs (forked per pair name, so
    /// results are independent of worker count and submission order).
    pub arrival_seed: u64,
    /// Minimum distinct pairs resyncing inside one correlation window
    /// for the fleet to coalesce them into one [`FleetDivergence`].
    pub correlate_min: usize,
    /// Correlation window in matched-op positions; `0` (the default)
    /// uses `cfg.window_ops` — divergences closer than one detection
    /// window are indistinguishable anyway.
    pub correlate_window_ops: usize,
    /// When set, each pair appends its window/resync/summary snapshots
    /// under this directory (`pair-<submission index>-<name>-NNNNNN.ndjson`
    /// — the index keeps file series distinct across duplicate pair
    /// names and names that sanitize to the same stem) and the fleet
    /// appends its ranking and divergence events
    /// (`fleet-NNNNNN.ndjson`), rotation-bounded by `sink_cfg`.
    /// `magneton replay --dir <dir>` re-renders all of it offline.
    pub snapshot_dir: Option<PathBuf>,
    /// Rotation bounds shared by the per-pair and fleet-level sinks.
    pub sink_cfg: SinkConfig,
    /// Session identity stamped into every per-pair sink as a
    /// [`SessionHeader`] (workload fingerprint from the pair's side-A
    /// program, arrival + config digests). Requires `snapshot_dir`;
    /// `None` writes no headers, so the directory cannot be matched by
    /// `magneton diff`.
    pub session_id: Option<String>,
    /// Free-form deploy tag carried alongside `session_id`.
    pub deploy_tag: String,
    /// Fleet-global index of this process's first pair. A producer
    /// shard auditing pairs `[base, base+n)` of a larger fleet sets
    /// this so its snapshot file prefixes (`pair-<global idx>-<name>`)
    /// interleave with the other shards' under the same total order an
    /// unsharded run would have written — the property that makes
    /// `magneton merge` output bit-identical to a single-process run.
    pub pair_index_base: usize,
    /// Shard identity stamped into every session header (with
    /// `session_id` set): operator-chosen shard name, zero-based shard
    /// index, and total shard count. The defaults (`""`, 0, 1) mean
    /// "unsharded".
    pub shard_id: String,
    pub shard_index: usize,
    pub shard_count: usize,
    pairs: Vec<FleetPair>,
}

impl StreamFleet {
    pub fn new(device: DeviceSpec) -> StreamFleet {
        StreamFleet {
            device,
            cfg: StreamConfig::default(),
            // streams guard output content by default: the sketch is
            // cheap at serving-op sizes and rides the kernel records
            exec_opts: ExecOptions { content_sketch: true, ..ExecOptions::default() },
            workers: pool::default_threads(),
            arrival: ArrivalProcess::BackToBack,
            ops_per_request: 0,
            arrival_seed: 0x6d61_676e,
            correlate_min: 2,
            correlate_window_ops: 0,
            snapshot_dir: None,
            sink_cfg: SinkConfig::default(),
            session_id: None,
            deploy_tag: String::new(),
            pair_index_base: 0,
            shard_id: String::new(),
            shard_index: 0,
            shard_count: 1,
            pairs: Vec::new(),
        }
    }

    /// Queue one serving stream pair. Names must be unique: they key
    /// snapshot attribution, replay ranking verification, and
    /// divergence correlation, all of which would silently collapse
    /// two same-named pairs into one.
    pub fn add_pair(&mut self, name: &str, a: SysRun, b: SysRun) -> &mut Self {
        assert!(
            !self.pairs.iter().any(|q| q.name == name),
            "duplicate stream pair name `{name}`: pair names key snapshot attribution, \
             ranking verification, and divergence correlation"
        );
        self.pairs.push(FleetPair { name: name.to_string(), a, b });
        self
    }

    pub fn len(&self) -> usize {
        self.pairs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pairs.is_empty()
    }

    /// Audit every queued stream pair concurrently and rank by waste.
    pub fn run(&self) -> StreamFleetReport {
        let t0 = Instant::now();
        let workers = self.workers.max(1).min(self.pairs.len().max(1));
        let indexed: Vec<(usize, &FleetPair)> = self.pairs.iter().enumerate().collect();
        let mut entries: Vec<StreamFleetEntry> = pool::par_map(&indexed, workers, |&(idx, p)| {
            let mut exec_a = Executor::new(self.device.clone(), p.a.dispatcher.clone(), p.a.env.clone());
            exec_a.opts = self.exec_opts.clone();
            let mut exec_b = Executor::new(self.device.clone(), p.b.dispatcher.clone(), p.b.env.clone());
            exec_b.opts = self.exec_opts.clone();
            let mut aud = StreamAuditor::new(self.cfg.clone(), self.device.idle_w);
            let mut snapshot_errors = 0usize;
            if let Some(dir) = &self.snapshot_dir {
                // the submission index keeps file series distinct even
                // when two (unique) pair names sanitize to the same
                // filename stem ("svc.a" vs "svc a") — otherwise their
                // concurrent sinks would interleave appends and delete
                // each other's files during rotation. The index is
                // fleet-*global* (base + local) so sharded producers'
                // series interleave into the unsharded file order at
                // merge time.
                let prefix = format!("pair-{:03}-{}", self.pair_index_base + idx, p.name);
                match SnapshotSink::new(dir.clone(), &prefix, self.sink_cfg.clone()) {
                    Ok(sink) => {
                        // the session header (workload fingerprint of
                        // the pair's program) goes first in the series,
                        // so this directory stays joinable with other
                        // deploys of the same workload (magneton diff)
                        if let Some(id) = &self.session_id {
                            let sig = workload_sig_of_program(&p.a.prog);
                            aud.set_session_header(
                                SessionHeader::new(
                                    id,
                                    &self.deploy_tag,
                                    &p.name,
                                    &sig,
                                    &self.arrival.describe(),
                                    self.cfg.digest(),
                                )
                                .with_shard(&self.shard_id, self.shard_index, self.shard_count),
                            );
                        }
                        aud.set_sink(&p.name, sink)
                    }
                    Err(_) => snapshot_errors += 1,
                }
            }
            let mut sa = exec_a.stream(&p.a.prog);
            let mut sb = exec_b.stream(&p.b.prog);
            // lock-step interleave (pending skew ≤ 1) with arrival
            // gaps; per-window reports are dropped from memory — with a
            // sink configured they persist on disk — while the summary
            // keeps the aggregates
            let mut rng = Prng::new(self.arrival_seed ^ fnv1a(p.name.bytes()));
            let summary = drive_pair_with_arrivals(
                &mut aud,
                &mut sa,
                &mut sb,
                self.arrival,
                self.ops_per_request,
                &mut rng,
                |_| {},
            );
            snapshot_errors += aud.sink_errors();
            StreamFleetEntry { name: p.name.clone(), summary, snapshot_errors }
        });
        entries.sort_by(|x, y| {
            y.summary
                .wasted_j
                .total_cmp(&x.summary.wasted_j)
                .then_with(|| x.name.cmp(&y.name))
        });
        let total_wasted_j = entries.iter().map(|e| e.summary.wasted_j).sum();
        let total_ops = entries.iter().map(|e| e.summary.ops).sum();
        // cross-pair resync correlation: one fleet-wide alarm instead
        // of N per-pair ones when divergence strikes together
        let window = if self.correlate_window_ops > 0 {
            self.correlate_window_ops
        } else {
            self.cfg.window_ops
        };
        let divergences = correlate_divergences(&entries, window, self.correlate_min);
        let mut snapshot_errors: usize = entries.iter().map(|e| e.snapshot_errors).sum();
        if let Some(dir) = &self.snapshot_dir {
            match SnapshotSink::new(dir.clone(), "fleet", self.sink_cfg.clone()) {
                Ok(mut sink) => {
                    for d in &divergences {
                        if sink.append(&Snapshot::Divergence { event: d.clone() }).is_err() {
                            snapshot_errors += 1;
                        }
                    }
                    let ranking: Vec<RankEntry> = entries
                        .iter()
                        .map(|e| RankEntry {
                            name: e.name.clone(),
                            wasted_j: e.summary.wasted_j,
                            ops: e.summary.ops,
                            windows: e.summary.windows,
                            windows_flagged: e.summary.windows_flagged,
                            resyncs: e.summary.resyncs,
                            aligned: e.summary.aligned,
                        })
                        .collect();
                    if sink.append(&Snapshot::Fleet { ranking }).is_err() {
                        snapshot_errors += 1;
                    }
                }
                Err(_) => snapshot_errors += 1,
            }
        }
        StreamFleetReport {
            entries,
            total_wasted_j,
            total_ops,
            divergences,
            snapshot_errors,
            wall_time_us: t0.elapsed().as_secs_f64() * 1e6,
            workers,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dispatch::{Env, KernelChoice, Routine};
    use crate::energy::ComputeUnit;
    use crate::exec::{Dispatcher, Program};
    use crate::graph::{Graph, OpKind};
    use crate::tensor::Tensor;
    use crate::util::Prng;
    use crate::workload::{serving_dispatcher, serving_stream_program, ServingStream};

    /// A small matmul system whose kernel efficiency is `eff` (1.0 =
    /// optimal; lower burns extra energy at equal time).
    fn mk_run(label: &str, seed: u64, eff: f64) -> SysRun {
        let mut rng = Prng::new(seed);
        let x = Tensor::randn(&mut rng, &[128, 256]);
        let w = Tensor::randn(&mut rng, &[256, 256]);
        let mut g = Graph::new(label);
        let xi = g.add(OpKind::Input, &[], "x");
        let wi = g.add(OpKind::Weight, &[], "w");
        let m = g.add(OpKind::MatMul, &[xi, wi], "proj");
        g.add(OpKind::Output, &[m], "out");
        let mut prog = Program::new(g);
        prog.feed(0, x);
        prog.feed(1, w);
        let mut disp = Dispatcher::new();
        disp.register(
            "matmul",
            Routine::direct(
                "torch.matmul",
                vec![],
                KernelChoice::new("gemm", ComputeUnit::TensorCore).quality(eff, 1.0, 1.0),
            ),
        );
        SysRun::new(label, disp, Env::new(), prog)
    }

    fn fleet_of(n: usize, workers: usize) -> FleetReport {
        let mut fleet = FleetAudit::new(DeviceSpec::h200_sim());
        fleet.workers = workers;
        for i in 0..n {
            // alternate wasteful and clean pairs; share the workload seed
            // within a pair so the two sides compute the same tensors
            let eff = if i % 2 == 0 { 0.6 } else { 1.0 };
            fleet.add_pair(
                &format!("pair-{i}"),
                mk_run("sys-a", 40 + i as u64, eff),
                mk_run("sys-b", 40 + i as u64, 1.0),
            );
        }
        fleet.run()
    }

    #[test]
    fn fleet_audits_all_pairs_and_ranks_by_waste() {
        let r = fleet_of(8, 4);
        assert_eq!(r.entries.len(), 8);
        // wasteful pairs flagged, clean pairs silent
        assert_eq!(r.flagged(), 4);
        // ranking is descending in wasted joules
        for w in r.entries.windows(2) {
            assert!(w[0].wasted_j >= w[1].wasted_j);
        }
        // aggregates match per-entry sums
        let sum: f64 = r.entries.iter().map(|e| e.wasted_j).sum();
        assert!((r.total_wasted_j - sum).abs() < 1e-12);
        assert_eq!(
            r.total_findings,
            r.entries.iter().map(|e| e.findings).sum::<usize>()
        );
        assert!(r.total_wasted_j > 0.0);
    }

    #[test]
    fn fleet_result_independent_of_worker_count() {
        let serial = fleet_of(6, 1);
        let parallel = fleet_of(6, 8);
        assert_eq!(serial.entries.len(), parallel.entries.len());
        for (s, p) in serial.entries.iter().zip(parallel.entries.iter()) {
            assert_eq!(s.name, p.name);
            assert_eq!(s.findings, p.findings);
            assert!((s.wasted_j - p.wasted_j).abs() < 1e-12, "{}", s.name);
        }
    }

    #[test]
    fn clean_fleet_reports_no_waste() {
        let mut fleet = FleetAudit::new(DeviceSpec::h200_sim());
        for i in 0..3 {
            fleet.add_pair(
                &format!("clean-{i}"),
                mk_run("a", 7, 1.0),
                mk_run("b", 7, 1.0),
            );
        }
        let r = fleet.run();
        assert_eq!(r.flagged(), 0);
        assert_eq!(r.total_wasted_j, 0.0);
    }

    /// A serving stream pair: side A's matmuls run at quality `eff`.
    fn mk_stream_run(label: &str, seed: u64, eff: f64, requests: usize) -> SysRun {
        let mut rng = Prng::new(seed);
        let spec = ServingStream { requests, batch: 64, d_model: 128 };
        let prog = serving_stream_program(&mut rng, &spec);
        SysRun::new(label, serving_dispatcher(eff), Env::new(), prog)
    }

    fn stream_fleet_of(workers: usize, requests: usize) -> StreamFleetReport {
        let mut fleet = StreamFleet::new(DeviceSpec::h200_sim());
        fleet.workers = workers;
        fleet.cfg.window_ops = 40;
        fleet.cfg.hop_ops = 40;
        fleet.cfg.ring_cap = 64;
        for (i, eff) in [0.6, 1.0, 0.7].iter().enumerate() {
            fleet.add_pair(
                &format!("stream-{i}"),
                mk_stream_run("sys-a", 90 + i as u64, *eff, requests),
                mk_stream_run("sys-b", 90 + i as u64, 1.0, requests),
            );
        }
        fleet.run()
    }

    /// Synthetic fleet entry carrying only a resync log — the input
    /// `correlate_divergences` actually reads.
    fn entry_with_resyncs(name: &str, events: &[(usize, usize)]) -> StreamFleetEntry {
        let resync_log: Vec<ResyncEvent> = events
            .iter()
            .map(|&(at, skipped)| ResyncEvent { at_ops: at, skipped_a: 0, skipped_b: skipped })
            .collect();
        StreamFleetEntry {
            name: name.to_string(),
            summary: StreamSummary {
                ops: 1000,
                windows: 10,
                energy_a_j: 1.0,
                energy_b_j: 1.0,
                time_a_us: 1.0,
                time_b_us: 1.0,
                wasted_j: 0.0,
                windows_flagged: 0,
                windows_quarantined: resync_log.len(),
                top_labels: vec![],
                aligned: resync_log.is_empty(),
                fingerprint_a: 1,
                fingerprint_b: 1,
                unpaired: 0,
                resyncs: resync_log.len(),
                resync_skipped: events.iter().map(|&(_, s)| s).sum(),
                resync_log,
                content_mismatches: 0,
                reports_dropped: 0,
                peak_retained_segments: 0,
                peak_window_pairs: 0,
                peak_pending: 0,
            },
            snapshot_errors: 0,
        }
    }

    /// Three pairs resync within one correlation window: the fleet
    /// coalesces them into exactly one divergence event with all three
    /// attributed, ranked by skipped events.
    #[test]
    fn simultaneous_divergence_coalesces_into_one_event() {
        let entries = vec![
            entry_with_resyncs("p0", &[(437, 1)]),
            entry_with_resyncs("p1", &[(438, 3)]),
            entry_with_resyncs("p2", &[(439, 1)]),
        ];
        let divs = correlate_divergences(&entries, 100, 2);
        assert_eq!(divs.len(), 1, "one fleet-wide alarm, not three per-pair ones");
        let d = &divs[0];
        assert_eq!(d.at_ops_min, 437);
        assert_eq!(d.at_ops_max, 439);
        assert_eq!(d.pairs.len(), 3);
        // ranked by skipped (desc), name tiebreak
        assert_eq!(d.pairs[0].name, "p1");
        assert_eq!(d.pairs[0].skipped, 3);
        assert_eq!(d.pairs[1].name, "p0");
        assert_eq!(d.pairs[2].name, "p2");
    }

    /// Pair names key snapshot attribution and ranking verification;
    /// a duplicate would silently collapse two pairs into one, so it
    /// is rejected at add time.
    #[test]
    #[should_panic(expected = "duplicate stream pair name")]
    fn duplicate_stream_pair_names_are_rejected() {
        let mut fleet = StreamFleet::new(DeviceSpec::h200_sim());
        fleet.add_pair("svc", mk_stream_run("a", 1, 1.0, 4), mk_stream_run("b", 1, 1.0, 4));
        fleet.add_pair("svc", mk_stream_run("a", 2, 1.0, 4), mk_stream_run("b", 2, 1.0, 4));
    }

    /// Below `correlate_min` distinct pairs nothing coalesces — a lone
    /// pair resyncing repeatedly stays per-pair noise.
    #[test]
    fn lone_pair_resyncs_do_not_become_fleet_events() {
        let entries = vec![
            entry_with_resyncs("p0", &[(100, 1), (120, 1), (140, 1)]),
            entry_with_resyncs("p1", &[]),
        ];
        assert!(correlate_divergences(&entries, 100, 2).is_empty());
        // min_pairs 1 degenerates to per-cluster reporting
        assert_eq!(correlate_divergences(&entries, 100, 1).len(), 1);
    }

    /// Resyncs farther apart than the window form separate clusters;
    /// each cluster qualifies independently.
    #[test]
    fn far_apart_divergences_stay_separate_events() {
        let entries = vec![
            entry_with_resyncs("p0", &[(100, 1), (5000, 2)]),
            entry_with_resyncs("p1", &[(130, 1), (5040, 1)]),
            entry_with_resyncs("p2", &[(5020, 1)]),
        ];
        let divs = correlate_divergences(&entries, 100, 2);
        assert_eq!(divs.len(), 2);
        assert_eq!(divs[0].pairs.len(), 2);
        assert_eq!(divs[0].at_ops_min, 100);
        assert_eq!(divs[1].pairs.len(), 3);
        assert_eq!(divs[1].at_ops_min, 5000);
        assert_eq!(divs[1].at_ops_max, 5040);
        // a pair with several resyncs in one cluster is attributed once
        let p0 = divs[1].pairs.iter().find(|p| p.name == "p0").unwrap();
        assert_eq!(p0.resyncs, 1);
        assert_eq!(p0.skipped, 2);
    }

    /// The streaming fleet must flag the two wasteful streams, keep the
    /// clean one silent, rank by waste, and never retain more power
    /// segments than the ring allows — on multi-hundred-op streams.
    #[test]
    fn stream_fleet_flags_wasteful_streams_with_bounded_memory() {
        let r = stream_fleet_of(3, 24); // 120 kernel ops per side
        assert_eq!(r.entries.len(), 3);
        assert_eq!(r.flagged(), 2);
        assert_eq!(r.total_ops, 3 * 120);
        // aligned same-workload pairs: no resyncs, no fleet divergence,
        // and no snapshot sink configured means no snapshot errors
        assert!(r.divergences.is_empty());
        assert_eq!(r.snapshot_errors, 0);
        for w in r.entries.windows(2) {
            assert!(w[0].summary.wasted_j >= w[1].summary.wasted_j);
        }
        // the 0.6-efficiency stream wastes more than the 0.7 one
        assert_eq!(r.entries[0].name, "stream-0");
        assert_eq!(r.entries[1].name, "stream-2");
        assert!(r.entries[2].summary.wasted_j == 0.0);
        for e in &r.entries {
            assert!(e.summary.aligned, "{}", e.name);
            assert!(
                e.summary.peak_retained_segments <= 64,
                "{}: ring overflow {}",
                e.name,
                e.summary.peak_retained_segments
            );
            assert!(e.summary.peak_pending <= 1, "{}", e.name);
            // matmul call sites carry the waste
            if e.summary.wasted_j > 0.0 {
                let top = &e.summary.top_labels[0].0;
                assert!(top == "serve.proj" || top == "serve.out", "{top}");
            }
        }
    }

    #[test]
    fn stream_fleet_result_independent_of_worker_count() {
        let serial = stream_fleet_of(1, 16);
        let parallel = stream_fleet_of(8, 16);
        assert_eq!(serial.entries.len(), parallel.entries.len());
        for (s, p) in serial.entries.iter().zip(parallel.entries.iter()) {
            assert_eq!(s.name, p.name);
            assert_eq!(s.summary.ops, p.summary.ops);
            assert_eq!(s.summary.windows, p.summary.windows);
            assert!((s.summary.wasted_j - p.summary.wasted_j).abs() < 1e-12, "{}", s.name);
        }
    }

    fn arrival_fleet(workers: usize, arrival: ArrivalProcess) -> StreamFleetReport {
        let mut fleet = StreamFleet::new(DeviceSpec::h200_sim());
        fleet.workers = workers;
        fleet.cfg.window_ops = 40;
        fleet.cfg.hop_ops = 40;
        fleet.cfg.ring_cap = 64;
        fleet.arrival = arrival;
        fleet.ops_per_request = ServingStream::default().ops_per_request();
        for (i, eff) in [0.6, 1.0].iter().enumerate() {
            fleet.add_pair(
                &format!("arrival-{i}"),
                mk_stream_run("sys-a", 70 + i as u64, *eff, 24),
                mk_stream_run("sys-b", 70 + i as u64, 1.0, 24),
            );
        }
        fleet.run()
    }

    /// Poisson arrivals interleave idle lulls into both rings without
    /// desynchronising the pair: detection verdicts match the
    /// back-to-back run, memory stays ring-bounded, and the result is
    /// still independent of worker count (per-pair arrival rngs).
    #[test]
    fn stream_fleet_with_poisson_arrivals_stays_aligned() {
        let poisson = ArrivalProcess::Poisson { rate_hz: 500.0 };
        let r = arrival_fleet(2, poisson);
        assert_eq!(r.entries.len(), 2);
        assert_eq!(r.flagged(), 1);
        for e in &r.entries {
            assert!(e.summary.aligned, "{}", e.name);
            assert_eq!(e.summary.resyncs, 0, "{}", e.name);
            assert_eq!(e.summary.content_mismatches, 0, "{}", e.name);
            assert!(e.summary.peak_retained_segments <= 64, "{}", e.name);
        }
        // same verdicts as the gap-free process: arrivals change the
        // power timeline, not the per-op energy accounting
        let steady = arrival_fleet(2, ArrivalProcess::BackToBack);
        for (p, s) in r.entries.iter().zip(steady.entries.iter()) {
            assert_eq!(p.summary.ops, s.summary.ops);
            assert!((p.summary.wasted_j - s.summary.wasted_j).abs() < 1e-12);
        }
        // deterministic across worker counts despite sampled gaps
        let serial = arrival_fleet(1, poisson);
        for (a, b) in r.entries.iter().zip(serial.entries.iter()) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.summary.ops, b.summary.ops);
            assert!((a.summary.energy_a_j - b.summary.energy_a_j).abs() < 1e-12);
        }
    }

    /// The streaming exec pairs carry content sketches by default, and
    /// same-seed pairs agree on them (no false content alarms).
    #[test]
    fn stream_fleet_content_guard_is_quiet_on_equivalent_pairs() {
        let r = stream_fleet_of(2, 12);
        for e in &r.entries {
            assert_eq!(e.summary.content_mismatches, 0, "{}", e.name);
        }
    }
}
