//! Fleet-scale differential auditing (the multi-workload layer the
//! ROADMAP's north star asks for): run N system pairs concurrently over
//! a bounded worker pool and aggregate their [`AuditOutcome`]s into a
//! ranked cross-system waste report.
//!
//! Each worker owns its private [`Magneton`] coordinator (the Rust
//! moment engine is zero-sized, so per-worker construction is free) and
//! the pairs fan out through [`pool::par_map`], which bounds concurrency
//! at [`FleetAudit::workers`] while preserving submission order before
//! the final ranking — results are therefore deterministic regardless
//! of worker count.

use std::time::Instant;

use crate::coordinator::{AuditOutcome, Magneton, SysRun};
use crate::detect::DetectConfig;
use crate::energy::{DeviceSpec, Segment};
use crate::exec::{ExecOptions, Executor, KernelRecord};
use crate::stream::{StreamAuditor, StreamConfig, StreamSummary, WindowReport};
use crate::util::{fnv1a, pool, Prng};
use crate::workload::ArrivalProcess;

/// One named audit job: two systems on the same workload.
pub struct FleetPair {
    pub name: String,
    pub a: SysRun,
    pub b: SysRun,
}

/// The aggregated result of one pair's audit.
pub struct FleetEntry {
    pub name: String,
    pub outcome: AuditOutcome,
    /// Joules lost to genuine (non-trade-off) waste findings.
    pub wasted_j: f64,
    pub findings: usize,
    pub tradeoffs: usize,
}

/// A finished fleet audit, entries ranked most-wasteful first.
pub struct FleetReport {
    pub entries: Vec<FleetEntry>,
    pub total_wasted_j: f64,
    pub total_findings: usize,
    /// End-to-end wall time of the fleet run, µs.
    pub wall_time_us: f64,
    pub workers: usize,
}

impl FleetReport {
    /// Pairs where Magneton flagged at least one finding.
    pub fn flagged(&self) -> usize {
        self.entries.iter().filter(|e| e.findings > 0).count()
    }
}

/// Joules attributable to genuine waste in one audit (the ranking key):
/// the absolute energy gap of every non-trade-off finding.
pub fn waste_joules(outcome: &AuditOutcome) -> f64 {
    outcome
        .findings
        .iter()
        .filter(|f| !f.is_tradeoff)
        .map(|f| (f.energy_a_j - f.energy_b_j).abs())
        .sum()
}

/// Batch coordinator: queue [`SysRun`] pairs, then [`FleetAudit::run`]
/// them over a bounded worker pool.
pub struct FleetAudit {
    pub device: DeviceSpec,
    /// Tensor-equivalence tolerance ε (see [`Magneton::eps`]).
    pub eps: f64,
    pub cfg: DetectConfig,
    pub exec_opts: ExecOptions,
    /// Maximum concurrent audits.
    pub workers: usize,
    pairs: Vec<FleetPair>,
}

impl FleetAudit {
    pub fn new(device: DeviceSpec) -> FleetAudit {
        let defaults = Magneton::new(device.clone());
        FleetAudit {
            device,
            eps: defaults.eps,
            cfg: defaults.cfg,
            exec_opts: defaults.exec_opts,
            workers: pool::default_threads(),
            pairs: Vec::new(),
        }
    }

    /// Queue one audit job.
    pub fn add_pair(&mut self, name: &str, a: SysRun, b: SysRun) -> &mut Self {
        self.pairs.push(FleetPair { name: name.to_string(), a, b });
        self
    }

    pub fn len(&self) -> usize {
        self.pairs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pairs.is_empty()
    }

    /// Run every queued pair over at most [`FleetAudit::workers`]
    /// concurrent audits and rank the outcomes by wasted joules.
    pub fn run(&self) -> FleetReport {
        let t0 = Instant::now();
        let workers = self.workers.max(1).min(self.pairs.len().max(1));
        let mut entries: Vec<FleetEntry> = pool::par_map(&self.pairs, workers, |p| {
            let mut mag = Magneton::new(self.device.clone());
            mag.eps = self.eps;
            mag.cfg = self.cfg;
            mag.exec_opts = self.exec_opts.clone();
            let outcome = mag.audit(&p.a, &p.b);
            let wasted_j = waste_joules(&outcome);
            let findings = outcome.findings.len();
            let tradeoffs = outcome.findings.iter().filter(|f| f.is_tradeoff).count();
            FleetEntry { name: p.name.clone(), outcome, wasted_j, findings, tradeoffs }
        });
        // rank most-wasteful first; tie-break on name so the report is
        // stable across worker counts
        entries.sort_by(|x, y| y.wasted_j.total_cmp(&x.wasted_j).then_with(|| x.name.cmp(&y.name)));
        let total_wasted_j = entries.iter().map(|e| e.wasted_j).sum();
        let total_findings = entries.iter().map(|e| e.findings).sum();
        FleetReport {
            entries,
            total_wasted_j,
            total_findings,
            wall_time_us: t0.elapsed().as_secs_f64() * 1e6,
            workers,
        }
    }
}

/// Drive one streaming event-source pair through an auditor,
/// materialising request-arrival idle gaps every `ops_per_request` op
/// pairs on both sides (`ops_per_request == 0` disables gaps). The gap
/// sequence is sampled once from `rng` and applied to both rings, so
/// the arrival process itself can never desynchronise the pair.
/// Emitted windows stream through `on_window`; returns the final
/// summary. Generic over any `(KernelRecord, Segment)` iterator — a
/// live [`crate::exec::StreamExec`] (fleet workers, the `stream_audit`
/// example) or a channel receiver draining chunked ingestion
/// (`magneton stream`) — so the pairing protocol exists exactly once.
pub fn drive_pair_with_arrivals(
    aud: &mut StreamAuditor,
    mut a: impl Iterator<Item = (KernelRecord, Segment)>,
    mut b: impl Iterator<Item = (KernelRecord, Segment)>,
    arrival: ArrivalProcess,
    ops_per_request: usize,
    rng: &mut Prng,
    mut on_window: impl FnMut(WindowReport),
) -> StreamSummary {
    let mut pairs = 0usize;
    let mut request = 0usize;
    loop {
        let na = a.next();
        let nb = b.next();
        if na.is_none() && nb.is_none() {
            break;
        }
        if let Some((rec, seg)) = na {
            aud.ingest_a(&rec, seg);
        }
        if let Some((rec, seg)) = nb {
            aud.ingest_b(&rec, seg);
        }
        pairs += 1;
        if ops_per_request > 0 && pairs % ops_per_request == 0 {
            request += 1;
            let gap = arrival.gap_us(rng, request);
            if gap > 0.0 {
                aud.ingest_idle_a(gap);
                aud.ingest_idle_b(gap);
            }
        }
        for w in aud.take_emitted() {
            on_window(w);
        }
    }
    let summary = aud.finish();
    for w in aud.take_emitted() {
        on_window(w);
    }
    summary
}

/// The aggregated result of one streaming pair.
pub struct StreamFleetEntry {
    pub name: String,
    pub summary: StreamSummary,
}

/// A finished streaming fleet audit, ranked most-wasteful first.
pub struct StreamFleetReport {
    pub entries: Vec<StreamFleetEntry>,
    pub total_wasted_j: f64,
    /// Matched op pairs audited across all streams.
    pub total_ops: usize,
    /// End-to-end wall time of the fleet run, µs.
    pub wall_time_us: f64,
    pub workers: usize,
}

impl StreamFleetReport {
    /// Streams where at least one window was flagged.
    pub fn flagged(&self) -> usize {
        self.entries.iter().filter(|e| e.summary.windows_flagged > 0).count()
    }
}

/// Streaming fleet audit: N long-running serving pairs, each consumed
/// chunk-by-chunk through a [`StreamAuditor`] over the bounded worker
/// pool. Unlike [`FleetAudit`], no run is ever materialised — each
/// worker zips two [`crate::exec::StreamExec`] iterators into its
/// auditor, so per-stream memory is bounded by the ring/window sizes
/// regardless of stream length.
pub struct StreamFleet {
    pub device: DeviceSpec,
    pub cfg: StreamConfig,
    pub exec_opts: ExecOptions,
    /// Maximum concurrent stream audits.
    pub workers: usize,
    /// Request arrival process driving every pair (idle lulls are
    /// materialised in both rings).
    pub arrival: ArrivalProcess,
    /// Op pairs per request (gap-injection stride); `0` disables gaps.
    pub ops_per_request: usize,
    /// Seed of the per-pair arrival rngs (forked per pair name, so
    /// results are independent of worker count and submission order).
    pub arrival_seed: u64,
    pairs: Vec<FleetPair>,
}

impl StreamFleet {
    pub fn new(device: DeviceSpec) -> StreamFleet {
        StreamFleet {
            device,
            cfg: StreamConfig::default(),
            // streams guard output content by default: the sketch is
            // cheap at serving-op sizes and rides the kernel records
            exec_opts: ExecOptions { content_sketch: true, ..ExecOptions::default() },
            workers: pool::default_threads(),
            arrival: ArrivalProcess::BackToBack,
            ops_per_request: 0,
            arrival_seed: 0x6d61_676e,
            pairs: Vec::new(),
        }
    }

    /// Queue one serving stream pair.
    pub fn add_pair(&mut self, name: &str, a: SysRun, b: SysRun) -> &mut Self {
        self.pairs.push(FleetPair { name: name.to_string(), a, b });
        self
    }

    pub fn len(&self) -> usize {
        self.pairs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pairs.is_empty()
    }

    /// Audit every queued stream pair concurrently and rank by waste.
    pub fn run(&self) -> StreamFleetReport {
        let t0 = Instant::now();
        let workers = self.workers.max(1).min(self.pairs.len().max(1));
        let mut entries: Vec<StreamFleetEntry> = pool::par_map(&self.pairs, workers, |p| {
            let mut exec_a = Executor::new(self.device.clone(), p.a.dispatcher.clone(), p.a.env.clone());
            exec_a.opts = self.exec_opts.clone();
            let mut exec_b = Executor::new(self.device.clone(), p.b.dispatcher.clone(), p.b.env.clone());
            exec_b.opts = self.exec_opts.clone();
            let mut aud = StreamAuditor::new(self.cfg.clone(), self.device.idle_w);
            let mut sa = exec_a.stream(&p.a.prog);
            let mut sb = exec_b.stream(&p.b.prog);
            // lock-step interleave (pending skew ≤ 1) with arrival
            // gaps; per-window reports are dropped — the summary keeps
            // the aggregates
            let mut rng = Prng::new(self.arrival_seed ^ fnv1a(p.name.bytes()));
            let summary = drive_pair_with_arrivals(
                &mut aud,
                &mut sa,
                &mut sb,
                self.arrival,
                self.ops_per_request,
                &mut rng,
                |_| {},
            );
            StreamFleetEntry { name: p.name.clone(), summary }
        });
        entries.sort_by(|x, y| {
            y.summary
                .wasted_j
                .total_cmp(&x.summary.wasted_j)
                .then_with(|| x.name.cmp(&y.name))
        });
        let total_wasted_j = entries.iter().map(|e| e.summary.wasted_j).sum();
        let total_ops = entries.iter().map(|e| e.summary.ops).sum();
        StreamFleetReport {
            entries,
            total_wasted_j,
            total_ops,
            wall_time_us: t0.elapsed().as_secs_f64() * 1e6,
            workers,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dispatch::{Env, KernelChoice, Routine};
    use crate::energy::ComputeUnit;
    use crate::exec::{Dispatcher, Program};
    use crate::graph::{Graph, OpKind};
    use crate::tensor::Tensor;
    use crate::util::Prng;
    use crate::workload::{serving_dispatcher, serving_stream_program, ServingStream};

    /// A small matmul system whose kernel efficiency is `eff` (1.0 =
    /// optimal; lower burns extra energy at equal time).
    fn mk_run(label: &str, seed: u64, eff: f64) -> SysRun {
        let mut rng = Prng::new(seed);
        let x = Tensor::randn(&mut rng, &[128, 256]);
        let w = Tensor::randn(&mut rng, &[256, 256]);
        let mut g = Graph::new(label);
        let xi = g.add(OpKind::Input, &[], "x");
        let wi = g.add(OpKind::Weight, &[], "w");
        let m = g.add(OpKind::MatMul, &[xi, wi], "proj");
        g.add(OpKind::Output, &[m], "out");
        let mut prog = Program::new(g);
        prog.feed(0, x);
        prog.feed(1, w);
        let mut disp = Dispatcher::new();
        disp.register(
            "matmul",
            Routine::direct(
                "torch.matmul",
                vec![],
                KernelChoice::new("gemm", ComputeUnit::TensorCore).quality(eff, 1.0, 1.0),
            ),
        );
        SysRun::new(label, disp, Env::new(), prog)
    }

    fn fleet_of(n: usize, workers: usize) -> FleetReport {
        let mut fleet = FleetAudit::new(DeviceSpec::h200_sim());
        fleet.workers = workers;
        for i in 0..n {
            // alternate wasteful and clean pairs; share the workload seed
            // within a pair so the two sides compute the same tensors
            let eff = if i % 2 == 0 { 0.6 } else { 1.0 };
            fleet.add_pair(
                &format!("pair-{i}"),
                mk_run("sys-a", 40 + i as u64, eff),
                mk_run("sys-b", 40 + i as u64, 1.0),
            );
        }
        fleet.run()
    }

    #[test]
    fn fleet_audits_all_pairs_and_ranks_by_waste() {
        let r = fleet_of(8, 4);
        assert_eq!(r.entries.len(), 8);
        // wasteful pairs flagged, clean pairs silent
        assert_eq!(r.flagged(), 4);
        // ranking is descending in wasted joules
        for w in r.entries.windows(2) {
            assert!(w[0].wasted_j >= w[1].wasted_j);
        }
        // aggregates match per-entry sums
        let sum: f64 = r.entries.iter().map(|e| e.wasted_j).sum();
        assert!((r.total_wasted_j - sum).abs() < 1e-12);
        assert_eq!(
            r.total_findings,
            r.entries.iter().map(|e| e.findings).sum::<usize>()
        );
        assert!(r.total_wasted_j > 0.0);
    }

    #[test]
    fn fleet_result_independent_of_worker_count() {
        let serial = fleet_of(6, 1);
        let parallel = fleet_of(6, 8);
        assert_eq!(serial.entries.len(), parallel.entries.len());
        for (s, p) in serial.entries.iter().zip(parallel.entries.iter()) {
            assert_eq!(s.name, p.name);
            assert_eq!(s.findings, p.findings);
            assert!((s.wasted_j - p.wasted_j).abs() < 1e-12, "{}", s.name);
        }
    }

    #[test]
    fn clean_fleet_reports_no_waste() {
        let mut fleet = FleetAudit::new(DeviceSpec::h200_sim());
        for i in 0..3 {
            fleet.add_pair(
                &format!("clean-{i}"),
                mk_run("a", 7, 1.0),
                mk_run("b", 7, 1.0),
            );
        }
        let r = fleet.run();
        assert_eq!(r.flagged(), 0);
        assert_eq!(r.total_wasted_j, 0.0);
    }

    /// A serving stream pair: side A's matmuls run at quality `eff`.
    fn mk_stream_run(label: &str, seed: u64, eff: f64, requests: usize) -> SysRun {
        let mut rng = Prng::new(seed);
        let spec = ServingStream { requests, batch: 64, d_model: 128 };
        let prog = serving_stream_program(&mut rng, &spec);
        SysRun::new(label, serving_dispatcher(eff), Env::new(), prog)
    }

    fn stream_fleet_of(workers: usize, requests: usize) -> StreamFleetReport {
        let mut fleet = StreamFleet::new(DeviceSpec::h200_sim());
        fleet.workers = workers;
        fleet.cfg.window_ops = 40;
        fleet.cfg.hop_ops = 40;
        fleet.cfg.ring_cap = 64;
        for (i, eff) in [0.6, 1.0, 0.7].iter().enumerate() {
            fleet.add_pair(
                &format!("stream-{i}"),
                mk_stream_run("sys-a", 90 + i as u64, *eff, requests),
                mk_stream_run("sys-b", 90 + i as u64, 1.0, requests),
            );
        }
        fleet.run()
    }

    /// The streaming fleet must flag the two wasteful streams, keep the
    /// clean one silent, rank by waste, and never retain more power
    /// segments than the ring allows — on multi-hundred-op streams.
    #[test]
    fn stream_fleet_flags_wasteful_streams_with_bounded_memory() {
        let r = stream_fleet_of(3, 24); // 120 kernel ops per side
        assert_eq!(r.entries.len(), 3);
        assert_eq!(r.flagged(), 2);
        assert_eq!(r.total_ops, 3 * 120);
        for w in r.entries.windows(2) {
            assert!(w[0].summary.wasted_j >= w[1].summary.wasted_j);
        }
        // the 0.6-efficiency stream wastes more than the 0.7 one
        assert_eq!(r.entries[0].name, "stream-0");
        assert_eq!(r.entries[1].name, "stream-2");
        assert!(r.entries[2].summary.wasted_j == 0.0);
        for e in &r.entries {
            assert!(e.summary.aligned, "{}", e.name);
            assert!(
                e.summary.peak_retained_segments <= 64,
                "{}: ring overflow {}",
                e.name,
                e.summary.peak_retained_segments
            );
            assert!(e.summary.peak_pending <= 1, "{}", e.name);
            // matmul call sites carry the waste
            if e.summary.wasted_j > 0.0 {
                let top = &e.summary.top_labels[0].0;
                assert!(top == "serve.proj" || top == "serve.out", "{top}");
            }
        }
    }

    #[test]
    fn stream_fleet_result_independent_of_worker_count() {
        let serial = stream_fleet_of(1, 16);
        let parallel = stream_fleet_of(8, 16);
        assert_eq!(serial.entries.len(), parallel.entries.len());
        for (s, p) in serial.entries.iter().zip(parallel.entries.iter()) {
            assert_eq!(s.name, p.name);
            assert_eq!(s.summary.ops, p.summary.ops);
            assert_eq!(s.summary.windows, p.summary.windows);
            assert!((s.summary.wasted_j - p.summary.wasted_j).abs() < 1e-12, "{}", s.name);
        }
    }

    fn arrival_fleet(workers: usize, arrival: ArrivalProcess) -> StreamFleetReport {
        let mut fleet = StreamFleet::new(DeviceSpec::h200_sim());
        fleet.workers = workers;
        fleet.cfg.window_ops = 40;
        fleet.cfg.hop_ops = 40;
        fleet.cfg.ring_cap = 64;
        fleet.arrival = arrival;
        fleet.ops_per_request = ServingStream::default().ops_per_request();
        for (i, eff) in [0.6, 1.0].iter().enumerate() {
            fleet.add_pair(
                &format!("arrival-{i}"),
                mk_stream_run("sys-a", 70 + i as u64, *eff, 24),
                mk_stream_run("sys-b", 70 + i as u64, 1.0, 24),
            );
        }
        fleet.run()
    }

    /// Poisson arrivals interleave idle lulls into both rings without
    /// desynchronising the pair: detection verdicts match the
    /// back-to-back run, memory stays ring-bounded, and the result is
    /// still independent of worker count (per-pair arrival rngs).
    #[test]
    fn stream_fleet_with_poisson_arrivals_stays_aligned() {
        let poisson = ArrivalProcess::Poisson { rate_hz: 500.0 };
        let r = arrival_fleet(2, poisson);
        assert_eq!(r.entries.len(), 2);
        assert_eq!(r.flagged(), 1);
        for e in &r.entries {
            assert!(e.summary.aligned, "{}", e.name);
            assert_eq!(e.summary.resyncs, 0, "{}", e.name);
            assert_eq!(e.summary.content_mismatches, 0, "{}", e.name);
            assert!(e.summary.peak_retained_segments <= 64, "{}", e.name);
        }
        // same verdicts as the gap-free process: arrivals change the
        // power timeline, not the per-op energy accounting
        let steady = arrival_fleet(2, ArrivalProcess::BackToBack);
        for (p, s) in r.entries.iter().zip(steady.entries.iter()) {
            assert_eq!(p.summary.ops, s.summary.ops);
            assert!((p.summary.wasted_j - s.summary.wasted_j).abs() < 1e-12);
        }
        // deterministic across worker counts despite sampled gaps
        let serial = arrival_fleet(1, poisson);
        for (a, b) in r.entries.iter().zip(serial.entries.iter()) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.summary.ops, b.summary.ops);
            assert!((a.summary.energy_a_j - b.summary.energy_a_j).abs() < 1e-12);
        }
    }

    /// The streaming exec pairs carry content sketches by default, and
    /// same-seed pairs agree on them (no false content alarms).
    #[test]
    fn stream_fleet_content_guard_is_quiet_on_equivalent_pairs() {
        let r = stream_fleet_of(2, 12);
        for e in &r.entries {
            assert_eq!(e.summary.content_mismatches, 0, "{}", e.name);
        }
    }
}
