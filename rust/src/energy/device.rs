//! Parametric GPU device models.

/// A simulated GPU. Parameters are loosely calibrated to public H200 /
/// RTX 4090 figures; what matters for Magneton is the *ratios* (tensor
/// core vs CUDA core pJ/FLOP, HBM energy per byte vs on-chip, idle vs
/// busy-wait power), not absolute Joules.
#[derive(Clone, Debug)]
pub struct DeviceSpec {
    pub name: String,
    /// Power drawn when fully idle (P-state floor), Watts.
    pub idle_w: f64,
    /// Static power while any kernel is resident, Watts.
    pub base_w: f64,
    /// Peak sustained power cap, Watts.
    pub max_w: f64,
    /// Tensor-core throughput (TF32/BF16), TFLOP/s.
    pub tc_tflops: f64,
    /// CUDA-core FP32 throughput, TFLOP/s.
    pub cc_tflops: f64,
    /// Special-function (exp/tanh) throughput, TFLOP/s.
    pub sfu_tflops: f64,
    /// Tensor-core energy, pJ per FLOP.
    pub tc_pj_per_flop: f64,
    /// CUDA-core energy, pJ per FLOP.
    pub cc_pj_per_flop: f64,
    /// SFU energy, pJ per FLOP.
    pub sfu_pj_per_flop: f64,
    /// HBM bandwidth, GB/s.
    pub hbm_gbps: f64,
    /// HBM access energy, pJ per byte.
    pub hbm_pj_per_byte: f64,
    /// Interconnect (NVLink) bandwidth for collectives, GB/s.
    pub nvlink_gbps: f64,
    /// Interconnect energy, pJ per byte.
    pub nvlink_pj_per_byte: f64,
    /// Per-kernel launch overhead, microseconds.
    pub launch_overhead_us: f64,
}

impl DeviceSpec {
    /// H200-like simulated device (Testbed-B stand-in).
    pub fn h200_sim() -> DeviceSpec {
        DeviceSpec {
            name: "sim-h200".into(),
            idle_w: 90.0,
            base_w: 140.0,
            max_w: 700.0,
            tc_tflops: 165.0, // TF32 dense
            cc_tflops: 67.0,
            sfu_tflops: 17.0,
            tc_pj_per_flop: 2.8,
            cc_pj_per_flop: 4.5,
            sfu_pj_per_flop: 12.0,
            hbm_gbps: 4800.0,
            hbm_pj_per_byte: 20.0,
            nvlink_gbps: 900.0,
            nvlink_pj_per_byte: 25.0,
            launch_overhead_us: 0.1,
        }
    }

    /// RTX 4090-like simulated device (Testbed-A stand-in).
    pub fn rtx4090_sim() -> DeviceSpec {
        DeviceSpec {
            name: "sim-rtx4090".into(),
            idle_w: 25.0,
            base_w: 60.0,
            max_w: 450.0,
            tc_tflops: 82.0,
            cc_tflops: 82.0, // Ada FP32 == TF32 rate without sparsity
            sfu_tflops: 10.0,
            tc_pj_per_flop: 3.4,
            cc_pj_per_flop: 4.1,
            sfu_pj_per_flop: 13.0,
            hbm_gbps: 1008.0,
            hbm_pj_per_byte: 24.0,
            nvlink_gbps: 32.0, // PCIe fallback
            nvlink_pj_per_byte: 40.0,
            launch_overhead_us: 0.15,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_are_sane() {
        for d in [DeviceSpec::h200_sim(), DeviceSpec::rtx4090_sim()] {
            assert!(d.idle_w < d.base_w && d.base_w < d.max_w, "{}", d.name);
            assert!(d.tc_pj_per_flop <= d.cc_pj_per_flop, "{}", d.name);
            assert!(d.tc_tflops >= d.cc_tflops, "{}", d.name);
            assert!(d.hbm_gbps > 0.0 && d.launch_overhead_us > 0.0);
        }
    }

    #[test]
    fn tensor_core_energy_advantage_holds() {
        // The c1/c8 misconfiguration cases rely on TC being strictly
        // cheaper per FLOP than CC on the H200 model.
        let d = DeviceSpec::h200_sim();
        // per-FLOP energy advantage of tensor cores
        assert!(d.cc_pj_per_flop / d.tc_pj_per_flop > 1.5);
        // full-tilt dynamic power stays under the cap alongside base power
        let dyn_w = d.tc_tflops * 1e12 * d.tc_pj_per_flop * 1e-12;
        assert!(d.base_w + dyn_w < d.max_w);
    }
}
