//! Power/energy measurement methods over a [`PowerTrace`].
//!
//! Three readers of the same ground-truth trace (paper §5.2 + Table 4):
//!
//! * [`PhysicalMeter`] — µs-resolution exact integration (the ElmorLabs
//!   PMD2 stand-in; ground truth).
//! * [`NvmlSampler`] — vendor-counter emulation: low sample rate
//!   (10–50 Hz), reporting latency, and EMA smoothing. Reading a
//!   sub-millisecond kernel through it produces the up-to-80 % errors
//!   the paper reports.
//! * [`WindowedMeter`] — Zeus-style begin/end windows on top of NVML
//!   readings, with the 100 ms minimum-window restriction.

use super::power::PowerTrace;

/// Exact integration of the trace — the physical power meter stand-in.
#[derive(Clone, Copy, Debug)]
pub struct PhysicalMeter;

impl PhysicalMeter {
    /// Energy in Joules over the interval.
    pub fn energy_j(&self, trace: &PowerTrace, t0_us: f64, t1_us: f64) -> f64 {
        trace.energy_between(t0_us, t1_us)
    }

    /// Average power in Watts over the interval.
    pub fn avg_power_w(&self, trace: &PowerTrace, t0_us: f64, t1_us: f64) -> f64 {
        if t1_us <= t0_us {
            return trace.power_at(t0_us);
        }
        self.energy_j(trace, t0_us, t1_us) / ((t1_us - t0_us) * 1e-6)
    }
}

/// NVML-like sampled power counter.
#[derive(Clone, Debug)]
pub struct NvmlSampler {
    /// Counter update frequency (paper: 10–50 Hz).
    pub sample_hz: f64,
    /// Reporting latency: a sample at time `t` reflects power at
    /// `t - latency` (paper: "delayed by hundreds of milliseconds").
    pub latency_us: f64,
    /// EMA smoothing factor applied by the driver (0 = no smoothing).
    pub ema_alpha: f64,
}

impl Default for NvmlSampler {
    fn default() -> NvmlSampler {
        NvmlSampler { sample_hz: 20.0, latency_us: 120_000.0, ema_alpha: 0.6 }
    }
}

impl NvmlSampler {
    /// The counter value visible at wall time `t_us`: the EMA of the
    /// delayed samples taken so far.
    pub fn reading_at(&self, trace: &PowerTrace, t_us: f64) -> f64 {
        let step = 1e6 / self.sample_hz;
        // Reconstruct the sample sequence up to t; EMA over it.
        let mut ema = trace.idle_w;
        let mut t_sample = 0.0;
        while t_sample <= t_us {
            let observed = trace.power_at((t_sample - self.latency_us).max(0.0));
            ema = if self.ema_alpha > 0.0 {
                self.ema_alpha * ema + (1.0 - self.ema_alpha) * observed
            } else {
                observed
            };
            t_sample += step;
        }
        ema
    }

    /// Energy estimate over a window: mean of the counter readings that
    /// fall inside it × duration (what NVML-based profilers do). Windows
    /// shorter than a sample period see at most one stale reading.
    pub fn energy_j(&self, trace: &PowerTrace, t0_us: f64, t1_us: f64) -> f64 {
        let step = 1e6 / self.sample_hz;
        let mut readings = Vec::new();
        // samples strictly inside the window
        let mut t = (t0_us / step).ceil() * step;
        while t <= t1_us {
            readings.push(self.reading_at(trace, t));
            t += step;
        }
        let avg = if readings.is_empty() {
            // no counter update inside the window: caller sees the last
            // (stale) reading
            self.reading_at(trace, t0_us)
        } else {
            readings.iter().sum::<f64>() / readings.len() as f64
        };
        avg * (t1_us - t0_us) * 1e-6
    }

    /// Average-power estimate for the window.
    pub fn avg_power_w(&self, trace: &PowerTrace, t0_us: f64, t1_us: f64) -> f64 {
        if t1_us <= t0_us {
            return self.reading_at(trace, t0_us);
        }
        self.energy_j(trace, t0_us, t1_us) / ((t1_us - t0_us) * 1e-6)
    }
}

/// Zeus-style windowed meter with a minimum-window restriction.
#[derive(Clone, Debug)]
pub struct WindowedMeter {
    pub nvml: NvmlSampler,
    /// Minimum window for a reliable measurement (paper: 100 ms).
    pub min_window_us: f64,
}

impl Default for WindowedMeter {
    fn default() -> WindowedMeter {
        WindowedMeter { nvml: NvmlSampler::default(), min_window_us: 100_000.0 }
    }
}

/// Result of a windowed measurement.
#[derive(Clone, Copy, Debug)]
pub struct WindowReading {
    pub energy_j: f64,
    /// False when the window was shorter than the minimum and the value
    /// is unreliable (Zeus would refuse / average across kernels).
    pub reliable: bool,
}

impl WindowedMeter {
    pub fn measure(&self, trace: &PowerTrace, t0_us: f64, t1_us: f64) -> WindowReading {
        WindowReading {
            energy_j: self.nvml.energy_j(trace, t0_us, t1_us),
            reliable: (t1_us - t0_us) >= self.min_window_us,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Trace: 400ms idle(90W), then a 0.5ms kernel at 450W, then 400ms
    /// at 200W. The short kernel is invisible to NVML.
    fn bursty_trace() -> PowerTrace {
        let mut tr = PowerTrace::new(90.0);
        tr.push(400_000.0, 90.0);
        tr.push(500.0, 450.0);
        tr.push(400_000.0, 200.0);
        tr
    }

    #[test]
    fn physical_meter_is_exact() {
        let tr = bursty_trace();
        let m = PhysicalMeter;
        let e = m.energy_j(&tr, 400_000.0, 400_500.0);
        assert!((e - 450.0 * 500.0 * 1e-6).abs() < 1e-9);
        assert!((m.avg_power_w(&tr, 400_000.0, 400_500.0) - 450.0).abs() < 1e-9);
    }

    #[test]
    fn nvml_misses_short_kernels_badly() {
        let tr = bursty_trace();
        let nvml = NvmlSampler::default();
        let est = nvml.avg_power_w(&tr, 400_000.0, 400_500.0);
        let truth = 450.0;
        let err = (est - truth) / truth;
        // the paper reports up to ~80% divergence; we must at least be
        // far below the truth
        assert!(err < -0.5, "nvml error {err} not pessimistic enough (est {est})");
    }

    #[test]
    fn nvml_ok_on_long_steady_windows() {
        let mut tr = PowerTrace::new(90.0);
        tr.push(3_000_000.0, 300.0); // 3 s steady
        let nvml = NvmlSampler::default();
        let est = nvml.avg_power_w(&tr, 1_000_000.0, 2_500_000.0);
        assert!((est - 300.0).abs() / 300.0 < 0.05, "est {est}");
    }

    #[test]
    fn windowed_meter_flags_short_windows() {
        let tr = bursty_trace();
        let zeus = WindowedMeter::default();
        assert!(!zeus.measure(&tr, 400_000.0, 400_500.0).reliable);
        assert!(zeus.measure(&tr, 0.0, 200_000.0).reliable);
    }

    #[test]
    fn latency_makes_reading_stale() {
        let mut tr = PowerTrace::new(90.0);
        tr.push(200_000.0, 90.0);
        tr.push(1_000_000.0, 400.0);
        let nvml = NvmlSampler { sample_hz: 20.0, latency_us: 150_000.0, ema_alpha: 0.0 };
        // right after the jump, the reading still reflects the idle past
        let r = nvml.reading_at(&tr, 210_000.0);
        assert!((r - 90.0).abs() < 1.0, "stale reading expected, got {r}");
        // much later it catches up
        let r2 = nvml.reading_at(&tr, 900_000.0);
        assert!((r2 - 400.0).abs() < 1.0, "caught-up reading expected, got {r2}");
    }
}
