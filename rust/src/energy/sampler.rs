//! Power/energy measurement methods over a [`PowerTrace`].
//!
//! Three readers of the same ground-truth trace (paper §5.2 + Table 4):
//!
//! * [`PhysicalMeter`] — µs-resolution exact integration (the ElmorLabs
//!   PMD2 stand-in; ground truth).
//! * [`NvmlSampler`] — vendor-counter emulation: low sample rate
//!   (10–50 Hz), reporting latency, and EMA smoothing. Reading a
//!   sub-millisecond kernel through it produces the up-to-80 % errors
//!   the paper reports.
//! * [`WindowedMeter`] — Zeus-style begin/end windows on top of NVML
//!   readings, with the 100 ms minimum-window restriction.
//!
//! # The cursor-vs-rescan contract
//!
//! The NVML model has two readout paths, and they are contractually
//! **bit-identical**:
//!
//! * **cursor** — [`NvmlSampler::advance`] carries the driver's EMA
//!   fold forward in a [`SamplerState`]: a later query consumes only
//!   the samples since the previous one, so a monotone sweep of
//!   readings is `O(samples)` total. Queries must be non-decreasing in
//!   time (a counter cannot un-see a sample); an earlier query returns
//!   the current EMA untouched. The cursor is generic over
//!   [`PowerSource`], so it reads finished [`PowerTrace`]s and live
//!   [`crate::stream::PowerRing`]s alike — on a ring, history evicted
//!   before the cursor reached it reads as idle power.
//! * **rescan** — [`NvmlSampler::reading_at_rescan`] /
//!   [`NvmlSampler::energy_j_rescan`] re-run the fold from `t = 0` on
//!   every query: `O(readings × samples)`, quadratic over a full-trace
//!   sweep. Retained verbatim as the reference implementation and the
//!   strawman benched in `benches/stream_scaling.rs`.
//!
//! Both paths walk the identical *indexed* sample grid (`k · Δ`, never
//! an accumulated `t += Δ`, which drifts an ulp per step) in the same
//! observation order with the same EMA arithmetic, so their readings
//! agree to the last bit — enforced by the golden tests below,
//! including at ≥ 1e9 µs offsets.

use super::power::{PowerSource, PowerTrace};

/// Exact integration of the trace — the physical power meter stand-in.
#[derive(Clone, Copy, Debug)]
pub struct PhysicalMeter;

impl PhysicalMeter {
    /// Energy in Joules over the interval.
    pub fn energy_j(&self, trace: &PowerTrace, t0_us: f64, t1_us: f64) -> f64 {
        trace.energy_between(t0_us, t1_us)
    }

    /// Average power in Watts over the interval.
    pub fn avg_power_w(&self, trace: &PowerTrace, t0_us: f64, t1_us: f64) -> f64 {
        if t1_us <= t0_us {
            return trace.power_at(t0_us);
        }
        self.energy_j(trace, t0_us, t1_us) / ((t1_us - t0_us) * 1e-6)
    }
}

/// NVML-like sampled power counter.
#[derive(Clone, Debug)]
pub struct NvmlSampler {
    /// Counter update frequency (paper: 10–50 Hz).
    pub sample_hz: f64,
    /// Reporting latency: a sample at time `t` reflects power at
    /// `t - latency` (paper: "delayed by hundreds of milliseconds").
    pub latency_us: f64,
    /// EMA smoothing factor applied by the driver (0 = no smoothing).
    pub ema_alpha: f64,
}

impl Default for NvmlSampler {
    fn default() -> NvmlSampler {
        NvmlSampler { sample_hz: 20.0, latency_us: 120_000.0, ema_alpha: 0.6 }
    }
}

/// Incremental cursor over the driver's sample sequence.
///
/// The EMA the driver maintains is a left fold over the samples taken
/// at `0, Δ, 2Δ, …` (Δ = one sample period). The old implementation
/// re-ran that fold from `t = 0` on *every* query, making a full-trace
/// readout `O(readings × samples)` — quadratic in trace length, and
/// exactly the kind of software energy waste the paper hunts (§5.2).
/// `SamplerState` carries the fold forward instead: advancing to a
/// later wall time consumes only the samples in between, so a sweep of
/// monotonically increasing queries is `O(samples)` total.
///
/// Queries must be non-decreasing in time (the counter cannot un-see a
/// sample); an earlier query simply returns the current EMA untouched.
/// Because the cursor replays the exact accumulation sequence of the
/// from-scratch fold (the indexed sample grid `k · Δ` starting at
/// k = 0, same observation order, same EMA arithmetic), its readings
/// are **bit-identical** to [`NvmlSampler::reading_at_rescan`] —
/// enforced by a golden test below, including at ≥ 1e9 µs offsets
/// where an accumulated (`t += Δ`) grid would have drifted.
#[derive(Clone, Copy, Debug)]
pub struct SamplerState {
    /// Current EMA value — what the counter shows right now.
    pub ema: f64,
    /// Wall time of the next sample the driver will take, µs.
    pub t_next_us: f64,
    /// Samples consumed so far.
    pub samples: usize,
}

impl SamplerState {
    /// Fresh cursor at `t = 0` showing the idle floor.
    pub fn new(idle_w: f64) -> SamplerState {
        SamplerState { ema: idle_w, t_next_us: 0.0, samples: 0 }
    }
}

impl NvmlSampler {
    /// One sample period, µs.
    pub fn step_us(&self) -> f64 {
        1e6 / self.sample_hz
    }

    /// Advance `state` to wall time `t_us`, consuming the samples in
    /// between, and return the counter value visible at `t_us`.
    /// `O(new samples)`, not `O(t · hz)`. The sample grid is indexed
    /// (`k · step`), never accumulated (`t += step`): accumulation
    /// drifts by an ulp per step, which far into a long stream adds or
    /// loses whole samples against the rescan reference grid.
    pub fn advance<P: PowerSource + ?Sized>(
        &self,
        state: &mut SamplerState,
        trace: &P,
        t_us: f64,
    ) -> f64 {
        let step = self.step_us();
        while state.samples as f64 * step <= t_us {
            let t_k = state.samples as f64 * step;
            let observed = trace.power_at_us((t_k - self.latency_us).max(0.0));
            state.ema = if self.ema_alpha > 0.0 {
                self.ema_alpha * state.ema + (1.0 - self.ema_alpha) * observed
            } else {
                observed
            };
            state.samples += 1;
            state.t_next_us = state.samples as f64 * step;
        }
        state.ema
    }

    /// The counter value visible at wall time `t_us`: the EMA of the
    /// delayed samples taken so far. One forward pass from `t = 0`; for
    /// repeated queries carry a [`SamplerState`] and use
    /// [`NvmlSampler::advance`] instead.
    pub fn reading_at<P: PowerSource + ?Sized>(&self, trace: &P, t_us: f64) -> f64 {
        let mut state = SamplerState::new(trace.idle_watts());
        self.advance(&mut state, trace, t_us)
    }

    /// The pre-cursor implementation, kept verbatim as the golden
    /// reference (and the "old path" flag of `benches/stream_scaling`):
    /// re-simulates the driver EMA from `t = 0` for this single query.
    pub fn reading_at_rescan(&self, trace: &PowerTrace, t_us: f64) -> f64 {
        let step = self.step_us();
        // Reconstruct the sample sequence up to t; EMA over it. The
        // grid is indexed (`k · step`) like the cursor's, so the two
        // paths walk bit-identical sample times at any offset.
        let mut ema = trace.idle_w;
        let mut k = 0.0f64;
        while k * step <= t_us {
            let observed = trace.power_at((k * step - self.latency_us).max(0.0));
            ema = if self.ema_alpha > 0.0 {
                self.ema_alpha * ema + (1.0 - self.ema_alpha) * observed
            } else {
                observed
            };
            k += 1.0;
        }
        ema
    }

    /// Energy estimate over a window: mean of the counter readings that
    /// fall inside it × duration (what NVML-based profilers do). Windows
    /// shorter than a sample period see at most one stale reading.
    /// `O(samples up to t1)` via one shared cursor.
    pub fn energy_j<P: PowerSource + ?Sized>(&self, trace: &P, t0_us: f64, t1_us: f64) -> f64 {
        let mut state = SamplerState::new(trace.idle_watts());
        self.energy_j_with(&mut state, trace, t0_us, t1_us)
    }

    /// Cursor-carrying energy read for streaming use: `state` must not
    /// have been advanced past `t0_us`'s first in-window sample. The
    /// shared cursor is what turns a sweep of per-op windows (the 1000×
    /// replay path, a live stream readout) from quadratic to linear.
    pub fn energy_j_with<P: PowerSource + ?Sized>(
        &self,
        state: &mut SamplerState,
        trace: &P,
        t0_us: f64,
        t1_us: f64,
    ) -> f64 {
        let step = self.step_us();
        let mut sum = 0.0;
        let mut n = 0usize;
        // samples strictly inside the window, on the indexed grid
        // (k · step) so long-offset windows can't drift off it
        let mut k = (t0_us / step).ceil();
        while k * step <= t1_us {
            sum += self.advance(state, trace, k * step);
            n += 1;
            k += 1.0;
        }
        let avg = if n == 0 {
            // no counter update inside the window: caller sees the last
            // (stale) reading
            self.advance(state, trace, t0_us)
        } else {
            sum / n as f64
        };
        avg * (t1_us - t0_us) * 1e-6
    }

    /// The pre-cursor window estimate: one from-scratch re-simulation
    /// per reading, `O(readings × samples)`. Golden reference only.
    pub fn energy_j_rescan(&self, trace: &PowerTrace, t0_us: f64, t1_us: f64) -> f64 {
        let step = self.step_us();
        let mut readings = Vec::new();
        let mut k = (t0_us / step).ceil();
        while k * step <= t1_us {
            readings.push(self.reading_at_rescan(trace, k * step));
            k += 1.0;
        }
        let avg = if readings.is_empty() {
            self.reading_at_rescan(trace, t0_us)
        } else {
            readings.iter().sum::<f64>() / readings.len() as f64
        };
        avg * (t1_us - t0_us) * 1e-6
    }

    /// Average-power estimate for the window.
    pub fn avg_power_w<P: PowerSource + ?Sized>(&self, trace: &P, t0_us: f64, t1_us: f64) -> f64 {
        if t1_us <= t0_us {
            return self.reading_at(trace, t0_us);
        }
        self.energy_j(trace, t0_us, t1_us) / ((t1_us - t0_us) * 1e-6)
    }
}

/// Zeus-style windowed meter with a minimum-window restriction.
#[derive(Clone, Debug)]
pub struct WindowedMeter {
    pub nvml: NvmlSampler,
    /// Minimum window for a reliable measurement (paper: 100 ms).
    pub min_window_us: f64,
}

impl Default for WindowedMeter {
    fn default() -> WindowedMeter {
        WindowedMeter { nvml: NvmlSampler::default(), min_window_us: 100_000.0 }
    }
}

/// Result of a windowed measurement.
#[derive(Clone, Copy, Debug)]
pub struct WindowReading {
    pub energy_j: f64,
    /// False when the window was shorter than the minimum and the value
    /// is unreliable (Zeus would refuse / average across kernels).
    pub reliable: bool,
}

impl WindowedMeter {
    pub fn measure(&self, trace: &PowerTrace, t0_us: f64, t1_us: f64) -> WindowReading {
        WindowReading {
            energy_j: self.nvml.energy_j(trace, t0_us, t1_us),
            reliable: (t1_us - t0_us) >= self.min_window_us,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Trace: 400ms idle(90W), then a 0.5ms kernel at 450W, then 400ms
    /// at 200W. The short kernel is invisible to NVML.
    fn bursty_trace() -> PowerTrace {
        let mut tr = PowerTrace::new(90.0);
        tr.push(400_000.0, 90.0);
        tr.push(500.0, 450.0);
        tr.push(400_000.0, 200.0);
        tr
    }

    #[test]
    fn physical_meter_is_exact() {
        let tr = bursty_trace();
        let m = PhysicalMeter;
        let e = m.energy_j(&tr, 400_000.0, 400_500.0);
        assert!((e - 450.0 * 500.0 * 1e-6).abs() < 1e-9);
        assert!((m.avg_power_w(&tr, 400_000.0, 400_500.0) - 450.0).abs() < 1e-9);
    }

    #[test]
    fn nvml_misses_short_kernels_badly() {
        let tr = bursty_trace();
        let nvml = NvmlSampler::default();
        let est = nvml.avg_power_w(&tr, 400_000.0, 400_500.0);
        let truth = 450.0;
        let err = (est - truth) / truth;
        // the paper reports up to ~80% divergence; we must at least be
        // far below the truth
        assert!(err < -0.5, "nvml error {err} not pessimistic enough (est {est})");
    }

    #[test]
    fn nvml_ok_on_long_steady_windows() {
        let mut tr = PowerTrace::new(90.0);
        tr.push(3_000_000.0, 300.0); // 3 s steady
        let nvml = NvmlSampler::default();
        let est = nvml.avg_power_w(&tr, 1_000_000.0, 2_500_000.0);
        assert!((est - 300.0).abs() / 300.0 < 0.05, "est {est}");
    }

    #[test]
    fn windowed_meter_flags_short_windows() {
        let tr = bursty_trace();
        let zeus = WindowedMeter::default();
        assert!(!zeus.measure(&tr, 400_000.0, 400_500.0).reliable);
        assert!(zeus.measure(&tr, 0.0, 200_000.0).reliable);
    }

    /// A longer, irregular trace exercising many EMA updates.
    fn long_trace() -> PowerTrace {
        let mut tr = PowerTrace::new(85.0);
        for i in 0..400u32 {
            // deterministic pseudo-varied durations and powers
            let dur = 3_000.0 + (i % 17) as f64 * 700.0;
            let w = 90.0 + ((i * 37) % 260) as f64;
            tr.push(dur, w);
        }
        tr
    }

    /// Golden comparison: the incremental cursor must be bit-identical
    /// to the retained from-scratch re-simulation, for both a sweep of
    /// point readings and a sweep of window reads — including windows
    /// shorter than a sample period (the stale-reading fallback).
    #[test]
    fn cursor_matches_rescan_bitwise() {
        let tr = long_trace();
        for nvml in [
            NvmlSampler::default(),
            NvmlSampler { sample_hz: 50.0, latency_us: 200_000.0, ema_alpha: 0.0 },
            NvmlSampler { sample_hz: 13.0, latency_us: 0.0, ema_alpha: 0.9 },
        ] {
            // point readings through one shared cursor vs rescans
            let mut state = SamplerState::new(tr.idle_w);
            let mut t = 0.0;
            while t < tr.duration_us() {
                let inc = nvml.advance(&mut state, &tr, t);
                let old = nvml.reading_at_rescan(&tr, t);
                assert_eq!(inc.to_bits(), old.to_bits(), "t={t} hz={}", nvml.sample_hz);
                t += 41_000.0; // off-grid query times
            }
            // window reads: long, short (sub-sample-period), zero-width,
            // and far past the trace end (≥ 1e9 µs) where an accumulated
            // sample grid would have drifted off the rescan grid
            for (t0, t1) in [
                (0.0, tr.duration_us()),
                (100_000.0, 900_000.0),
                (123_456.0, 123_900.0),
                (500_000.0, 500_000.0),
                (1e9, 1e9 + 400_000.0),
                (2.5e9 + 123.0, 2.5e9 + 360_123.0),
            ] {
                let inc = nvml.energy_j(&tr, t0, t1);
                let old = nvml.energy_j_rescan(&tr, t0, t1);
                assert_eq!(inc.to_bits(), old.to_bits(), "[{t0},{t1}] hz={}", nvml.sample_hz);
            }
            // point readings at large offsets through a fresh cursor
            let mut far = SamplerState::new(tr.idle_w);
            for t in [1e9, 1e9 + 37_000.0, 3e9] {
                let inc = nvml.advance(&mut far, &tr, t);
                let old = nvml.reading_at_rescan(&tr, t);
                assert_eq!(inc.to_bits(), old.to_bits(), "far t={t} hz={}", nvml.sample_hz);
            }
        }
    }

    /// The sample grid is indexed, not accumulated: after advancing a
    /// cursor to t = 1e9 µs at a binary-inexact step (1e6/30 µs), the
    /// consumed sample count is exactly the number of k with
    /// k·Δ <= t in f64 — an accumulated `t += Δ` grid drifts by whole
    /// samples at this range.
    #[test]
    fn sample_grid_is_drift_free_at_large_offsets() {
        let tr = long_trace();
        let nvml = NvmlSampler { sample_hz: 30.0, latency_us: 0.0, ema_alpha: 0.5 };
        let mut state = SamplerState::new(tr.idle_w);
        nvml.advance(&mut state, &tr, 1e9);
        // step = 1e6/30 rounds up in f64, so 30000·step > 1e9: the
        // consumed samples are k = 0..=29999, exactly 30000 of them
        assert!(30_000.0 * nvml.step_us() > 1e9);
        assert_eq!(state.samples, 30_000);
        // t_next_us stays on the indexed grid
        assert_eq!(state.t_next_us.to_bits(), (30_000.0 * nvml.step_us()).to_bits());
    }

    /// Cursor queries are monotone: an out-of-order (earlier) query
    /// returns the current counter value without consuming samples.
    #[test]
    fn cursor_is_monotone_and_sticky() {
        let tr = long_trace();
        let nvml = NvmlSampler::default();
        let mut state = SamplerState::new(tr.idle_w);
        let r1 = nvml.advance(&mut state, &tr, 800_000.0);
        let consumed = state.samples;
        let r2 = nvml.advance(&mut state, &tr, 100_000.0); // earlier: no-op
        assert_eq!(r1.to_bits(), r2.to_bits());
        assert_eq!(state.samples, consumed);
    }

    /// The shared-cursor window sweep (replay-style back-to-back
    /// windows) agrees with fresh-cursor reads of the same windows.
    #[test]
    fn shared_cursor_window_sweep_matches_fresh() {
        let tr = long_trace();
        let nvml = NvmlSampler::default();
        let mut state = SamplerState::new(tr.idle_w);
        let mut t0 = 0.0;
        while t0 + 150_000.0 <= tr.duration_us() {
            let shared = nvml.energy_j_with(&mut state, &tr, t0, t0 + 150_000.0);
            let fresh = nvml.energy_j(&tr, t0, t0 + 150_000.0);
            assert_eq!(shared.to_bits(), fresh.to_bits(), "window at {t0}");
            t0 += 150_000.0;
        }
    }

    #[test]
    fn latency_makes_reading_stale() {
        let mut tr = PowerTrace::new(90.0);
        tr.push(200_000.0, 90.0);
        tr.push(1_000_000.0, 400.0);
        let nvml = NvmlSampler { sample_hz: 20.0, latency_us: 150_000.0, ema_alpha: 0.0 };
        // right after the jump, the reading still reflects the idle past
        let r = nvml.reading_at(&tr, 210_000.0);
        assert!((r - 90.0).abs() < 1.0, "stale reading expected, got {r}");
        // much later it catches up
        let r2 = nvml.reading_at(&tr, 900_000.0);
        assert!((r2 - 400.0).abs() < 1.0, "caught-up reading expected, got {r2}");
    }
}
