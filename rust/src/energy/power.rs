//! Power-vs-time traces: piecewise-constant instantaneous device power.
//!
//! Every executor run appends one segment per kernel (its average power
//! over its duration). The samplers (physical meter / NVML / Zeus) all
//! read from the same trace, so their disagreement is purely a
//! *measurement* artefact — exactly the effect Table 4 quantifies.

/// One constant-power interval.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Segment {
    pub t_start_us: f64,
    pub t_end_us: f64,
    pub watts: f64,
}

impl Segment {
    /// Duration of the interval, µs.
    pub fn dur_us(&self) -> f64 {
        self.t_end_us - self.t_start_us
    }

    /// Energy of the interval, Joules.
    pub fn energy_j(&self) -> f64 {
        self.watts * self.dur_us() * 1e-6
    }
}

/// Anything that can report instantaneous device power at a wall-time
/// point. Implemented by the fully-materialised [`PowerTrace`] and by
/// the bounded [`crate::stream::PowerRing`], so the sampler cursor
/// ([`super::sampler::SamplerState`]) can read either a finished run or
/// a live, eviction-bounded stream.
pub trait PowerSource {
    /// Instantaneous power at `t_us` (idle outside covered intervals).
    fn power_at_us(&self, t_us: f64) -> f64;

    /// Power reported when no interval covers a time point.
    fn idle_watts(&self) -> f64;
}

impl PowerSource for PowerTrace {
    fn power_at_us(&self, t_us: f64) -> f64 {
        self.power_at(t_us)
    }

    fn idle_watts(&self) -> f64 {
        self.idle_w
    }
}

/// Piecewise-constant power timeline (segments are contiguous and
/// appended in time order).
#[derive(Clone, Debug, Default)]
pub struct PowerTrace {
    pub segments: Vec<Segment>,
    /// Power reported when no segment covers a time point.
    pub idle_w: f64,
}

impl PowerTrace {
    pub fn new(idle_w: f64) -> PowerTrace {
        PowerTrace { segments: Vec::new(), idle_w }
    }

    /// Current end-of-trace timestamp.
    pub fn now_us(&self) -> f64 {
        self.segments.last().map(|s| s.t_end_us).unwrap_or(0.0)
    }

    /// Append a segment of `dur_us` at `watts` starting at `now_us`.
    pub fn push(&mut self, dur_us: f64, watts: f64) -> Segment {
        let t0 = self.now_us();
        let seg = Segment { t_start_us: t0, t_end_us: t0 + dur_us, watts };
        self.segments.push(seg);
        seg
    }

    /// Instantaneous power at time `t_us`: the first segment still
    /// open at `t` (`partition_point` over the ended-by-`t` prefix,
    /// the same rule [`crate::stream::PowerRing::power_at_us`] uses —
    /// a shared boundary `t == t_end_us` reads the *next* segment,
    /// the final end reads idle).
    pub fn power_at(&self, t_us: f64) -> f64 {
        if self.segments.is_empty() {
            return self.idle_w;
        }
        let lo = self.segments.partition_point(|s| s.t_end_us <= t_us);
        if lo < self.segments.len() && self.segments[lo].t_start_us <= t_us {
            self.segments[lo].watts
        } else {
            self.idle_w
        }
    }

    /// Exact energy (J) over [t0, t1] by integrating segments.
    pub fn energy_between(&self, t0_us: f64, t1_us: f64) -> f64 {
        assert!(t1_us >= t0_us);
        let mut e = 0.0;
        let mut covered = 0.0;
        for s in &self.segments {
            let lo = s.t_start_us.max(t0_us);
            let hi = s.t_end_us.min(t1_us);
            if hi > lo {
                e += s.watts * (hi - lo) * 1e-6;
                covered += hi - lo;
            }
        }
        // uncovered time is idle
        e + self.idle_w * ((t1_us - t0_us) - covered).max(0.0) * 1e-6
    }

    /// Total energy over the whole trace.
    pub fn total_energy(&self) -> f64 {
        self.energy_between(0.0, self.now_us())
    }

    /// Total duration (µs).
    pub fn duration_us(&self) -> f64 {
        self.now_us()
    }

    /// Resample at `hz` for plotting (Fig 4): (t_ms, watts) points.
    pub fn resample(&self, hz: f64) -> Vec<(f64, f64)> {
        let step_us = 1e6 / hz;
        let mut out = Vec::new();
        let mut t = 0.0;
        while t <= self.now_us() {
            out.push((t / 1e3, self.power_at(t)));
            t += step_us;
        }
        out
    }

    /// Concatenate another trace after this one (shifting its times).
    pub fn extend_shifted(&mut self, other: &PowerTrace) {
        let base = self.now_us();
        for s in &other.segments {
            self.segments.push(Segment {
                t_start_us: s.t_start_us + base,
                t_end_us: s.t_end_us + base,
                watts: s.watts,
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_is_contiguous() {
        let mut tr = PowerTrace::new(50.0);
        tr.push(100.0, 200.0);
        tr.push(50.0, 400.0);
        assert_eq!(tr.segments[1].t_start_us, 100.0);
        assert_eq!(tr.now_us(), 150.0);
    }

    #[test]
    fn power_at_lookup() {
        let mut tr = PowerTrace::new(50.0);
        tr.push(100.0, 200.0);
        tr.push(100.0, 400.0);
        assert_eq!(tr.power_at(50.0), 200.0);
        assert_eq!(tr.power_at(150.0), 400.0);
        assert_eq!(tr.power_at(500.0), 50.0); // past the end: idle
    }

    /// Boundary semantics: a shared boundary (`t == t_end_us` of one
    /// segment == `t_start_us` of the next) reads the next segment;
    /// the final `t_end_us` reads idle.
    #[test]
    fn power_at_boundary_semantics() {
        let mut tr = PowerTrace::new(50.0);
        tr.push(100.0, 200.0);
        tr.push(100.0, 400.0);
        assert_eq!(tr.power_at(0.0), 200.0);
        assert_eq!(tr.power_at(100.0), 400.0); // shared boundary -> next
        assert_eq!(tr.power_at(200.0), 50.0); // final end -> idle
    }

    #[test]
    fn energy_integration_exact() {
        let mut tr = PowerTrace::new(50.0);
        tr.push(1000.0, 100.0); // 1ms @ 100W = 0.1 J
        tr.push(1000.0, 300.0); // 1ms @ 300W = 0.3 J
        assert!((tr.total_energy() - 0.4).abs() < 1e-12);
        assert!((tr.energy_between(500.0, 1500.0) - (0.05 + 0.15)).abs() < 1e-12);
    }

    #[test]
    fn uncovered_time_is_idle_energy() {
        let tr = PowerTrace::new(100.0);
        // empty trace, 1 second window -> 100 J * 1e-6 * 1e6? No: 100W * 1s = 100 J
        assert!((tr.energy_between(0.0, 1e6) - 100.0).abs() < 1e-9);
    }

    #[test]
    fn resample_counts() {
        let mut tr = PowerTrace::new(0.0);
        tr.push(1e6, 100.0); // 1 second
        let pts = tr.resample(10.0); // 10 Hz -> 11 points incl. endpoints
        assert_eq!(pts.len(), 11);
        assert!(pts.iter().take(10).all(|&(_, w)| w == 100.0));
    }

    #[test]
    fn extend_shifts_times() {
        let mut a = PowerTrace::new(0.0);
        a.push(100.0, 10.0);
        let mut b = PowerTrace::new(0.0);
        b.push(50.0, 20.0);
        a.extend_shifted(&b);
        assert_eq!(a.segments[1].t_start_us, 100.0);
        assert_eq!(a.now_us(), 150.0);
    }
}
