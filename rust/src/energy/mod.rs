//! Simulated-GPU energy substrate.
//!
//! The paper measures real GPUs (RTX 4090 / H200) with a physical power
//! meter; neither is available here, so this module is the substitution
//! (DESIGN.md §Hardware-Adaptation): a parametric device model that maps
//! kernel descriptors (FLOPs, HBM bytes, compute unit, implementation
//! quality) to `(time, energy)` via a roofline, and a [`PowerTrace`]
//! timeline from which the paper's three measurement methods are
//! simulated — exact integration (physical meter), 20 Hz delayed
//! sampling (NVML), and windowed reads (Zeus).
//!
//! The model preserves the *relationships* Magneton's algorithms exploit:
//! fused kernels move fewer HBM bytes than unfused chains, tensor-core
//! math costs fewer pJ/FLOP than CUDA-core math, strided access wastes
//! bandwidth, and busy-wait synchronisation burns near-peak power while
//! an idle GPU draws idle power.

pub mod device;
pub mod cost;
pub mod power;
pub mod sampler;

pub use cost::{ComputeUnit, KernelCost, KernelDesc};
pub use device::DeviceSpec;
pub use power::{PowerSource, PowerTrace, Segment};
