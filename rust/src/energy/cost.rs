//! Kernel cost model: descriptor → (time, energy, average power).

use super::device::DeviceSpec;

/// Which execution unit a kernel's math runs on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ComputeUnit {
    /// Tensor cores (TF32/BF16 matmul).
    TensorCore,
    /// CUDA cores (FP32 FMA).
    CudaCore,
    /// Special-function units (exp, tanh, rsqrt-heavy kernels).
    Sfu,
    /// Pure data movement (copies, layout changes).
    Mem,
    /// Interconnect collective (all-reduce).
    Link,
    /// No work: occupies time at a fixed power (barrier spin / idle).
    Fixed,
}

/// A launched kernel, described in hardware-neutral terms. Produced by
/// the executor (shapes → flops/bytes) plus the dispatcher (variant
/// multipliers); consumed by [`KernelDesc::cost`].
#[derive(Clone, Debug)]
pub struct KernelDesc {
    /// CUDA-kernel-style name, e.g. `ampere_sgemm_128x64_tn`.
    pub name: String,
    pub unit: ComputeUnit,
    /// Floating-point operations.
    pub flops: f64,
    /// Bytes moved to/from HBM (or over the link for collectives).
    pub bytes: f64,
    /// Implementation quality in (0, 1]: fraction of the energy-optimal
    /// implementation; the dispatcher lowers this for kernels the paper
    /// calls out as energy-inefficient (extra power at equal speed).
    pub efficiency: f64,
    /// Wall-time multiplier (strided access, low occupancy).
    pub time_mult: f64,
    /// Fixed duration for `ComputeUnit::Fixed` kernels, microseconds.
    pub fixed_time_us: f64,
    /// Power for `ComputeUnit::Fixed` kernels, Watts (e.g. busy-wait spin
    /// near base power vs idle at the P-state floor).
    pub fixed_power_w: f64,
}

impl KernelDesc {
    /// Compute kernel with default quality.
    pub fn compute(name: &str, unit: ComputeUnit, flops: f64, bytes: f64) -> KernelDesc {
        KernelDesc {
            name: name.to_string(),
            unit,
            flops,
            bytes,
            efficiency: 1.0,
            time_mult: 1.0,
            fixed_time_us: 0.0,
            fixed_power_w: 0.0,
        }
    }

    /// Fixed-time kernel (barrier spin, idle wait).
    pub fn fixed(name: &str, time_us: f64, power_w: f64) -> KernelDesc {
        KernelDesc {
            name: name.to_string(),
            unit: ComputeUnit::Fixed,
            flops: 0.0,
            bytes: 0.0,
            efficiency: 1.0,
            time_mult: 1.0,
            fixed_time_us: time_us,
            fixed_power_w: power_w,
        }
    }

    /// Apply a dispatch-variant adjustment (builder style).
    pub fn with_quality(mut self, efficiency: f64, time_mult: f64) -> KernelDesc {
        assert!(efficiency > 0.0 && efficiency <= 1.0);
        assert!(time_mult >= 1.0);
        self.efficiency = efficiency;
        self.time_mult = time_mult;
        self
    }

    /// Evaluate against a device: roofline time + energy accounting.
    pub fn cost(&self, dev: &DeviceSpec) -> KernelCost {
        if self.unit == ComputeUnit::Fixed {
            let e = self.fixed_power_w * self.fixed_time_us * 1e-6;
            return KernelCost {
                time_us: self.fixed_time_us,
                energy_j: e,
                avg_power_w: self.fixed_power_w,
            };
        }
        let (tflops, pj_flop) = match self.unit {
            ComputeUnit::TensorCore => (dev.tc_tflops, dev.tc_pj_per_flop),
            ComputeUnit::CudaCore => (dev.cc_tflops, dev.cc_pj_per_flop),
            ComputeUnit::Sfu => (dev.sfu_tflops, dev.sfu_pj_per_flop),
            ComputeUnit::Mem => (f64::INFINITY, 0.0),
            ComputeUnit::Link => (f64::INFINITY, 0.0),
            ComputeUnit::Fixed => unreachable!(),
        };
        let (gbps, pj_byte) = match self.unit {
            ComputeUnit::Link => (dev.nvlink_gbps, dev.nvlink_pj_per_byte),
            _ => (dev.hbm_gbps, dev.hbm_pj_per_byte),
        };
        let t_compute_us = self.flops / (tflops * 1e12) * 1e6;
        let t_mem_us = self.bytes / (gbps * 1e9) * 1e6;
        let time_us = (t_compute_us.max(t_mem_us) + dev.launch_overhead_us) * self.time_mult;
        // dynamic energy, inflated by implementation inefficiency
        let e_dyn = (self.flops * pj_flop + self.bytes * pj_byte) * 1e-12 / self.efficiency;
        let e_static = dev.base_w * time_us * 1e-6;
        let energy_j = e_dyn + e_static;
        let avg_power_w = (energy_j / (time_us * 1e-6)).min(dev.max_w);
        // clamp energy to the power cap (thermally limited kernels)
        let energy_j = energy_j.min(avg_power_w * time_us * 1e-6);
        KernelCost { time_us, energy_j, avg_power_w }
    }
}

/// Evaluated cost of one kernel launch.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct KernelCost {
    pub time_us: f64,
    pub energy_j: f64,
    pub avg_power_w: f64,
}

/// FLOP/byte helpers used by the executor.
pub mod counts {
    /// Matmul `[b, m, k] x [k, n]`: FLOPs and HBM bytes (f32).
    pub fn matmul(b: usize, m: usize, k: usize, n: usize) -> (f64, f64) {
        let flops = 2.0 * b as f64 * m as f64 * k as f64 * n as f64;
        let bytes = 4.0 * b as f64 * (m as f64 * k as f64 + k as f64 * n as f64 + m as f64 * n as f64);
        (flops, bytes)
    }

    /// Elementwise kernel over n elements with `reads` input streams.
    pub fn elementwise(n: usize, reads: usize, flops_per_elem: f64) -> (f64, f64) {
        (flops_per_elem * n as f64, 4.0 * n as f64 * (reads as f64 + 1.0))
    }

    /// Direct conv NCHW: flops and bytes.
    pub fn conv2d(n: usize, c: usize, h: usize, w: usize, oc: usize, kh: usize, kw: usize, groups: usize) -> (f64, f64) {
        let oh = h as f64;
        let ow = w as f64; // same-padding assumption for counting
        let flops = 2.0 * n as f64 * oc as f64 * oh * ow * (c / groups) as f64 * kh as f64 * kw as f64;
        let bytes = 4.0
            * (n as f64 * c as f64 * h as f64 * w as f64
                + oc as f64 * (c / groups) as f64 * kh as f64 * kw as f64
                + n as f64 * oc as f64 * oh * ow);
        (flops, bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::energy::device::DeviceSpec;

    fn dev() -> DeviceSpec {
        DeviceSpec::h200_sim()
    }

    #[test]
    fn bigger_kernels_cost_more() {
        let (f1, b1) = counts::matmul(1, 128, 128, 128);
        let (f2, b2) = counts::matmul(1, 256, 256, 256);
        let c1 = KernelDesc::compute("mm1", ComputeUnit::TensorCore, f1, b1).cost(&dev());
        let c2 = KernelDesc::compute("mm2", ComputeUnit::TensorCore, f2, b2).cost(&dev());
        assert!(c2.time_us > c1.time_us);
        assert!(c2.energy_j > c1.energy_j);
    }

    #[test]
    fn tensor_core_beats_cuda_core_on_energy_and_time() {
        // the c1/c8 allow_tf32 cases: same matmul, different unit
        let (f, b) = counts::matmul(8, 1024, 768, 768);
        let tc = KernelDesc::compute("tc", ComputeUnit::TensorCore, f, b).cost(&dev());
        let cc = KernelDesc::compute("cc", ComputeUnit::CudaCore, f, b).cost(&dev());
        assert!(tc.energy_j < cc.energy_j);
        assert!(tc.time_us < cc.time_us);
    }

    #[test]
    fn inefficiency_raises_energy_not_time() {
        let (f, b) = counts::matmul(1, 512, 512, 512);
        let good = KernelDesc::compute("g", ComputeUnit::TensorCore, f, b).cost(&dev());
        let bad = KernelDesc::compute("b", ComputeUnit::TensorCore, f, b)
            .with_quality(0.8, 1.0)
            .cost(&dev());
        assert!(bad.energy_j > good.energy_j * 1.05);
        assert!((bad.time_us - good.time_us).abs() < 1e-9);
    }

    #[test]
    fn fused_fewer_bytes_less_energy() {
        // fused elementwise chain vs 5 separate kernels over same data
        let n = 1 << 20;
        let (f, b) = counts::elementwise(n, 1, 8.0);
        let fused = KernelDesc::compute("fused", ComputeUnit::Sfu, f, b).cost(&dev());
        let mut unfused_e = 0.0;
        for _ in 0..5 {
            let (f5, b5) = counts::elementwise(n, 1, 1.6);
            unfused_e += KernelDesc::compute("k", ComputeUnit::Sfu, f5, b5).cost(&dev()).energy_j;
        }
        assert!(unfused_e > fused.energy_j * 1.5, "{unfused_e} vs {}", fused.energy_j);
    }

    #[test]
    fn fixed_kernels_integrate_power() {
        let spin = KernelDesc::fixed("spin", 1000.0, 300.0).cost(&dev());
        assert!((spin.energy_j - 0.3).abs() < 1e-9);
        let idle = KernelDesc::fixed("idle", 1000.0, 90.0).cost(&dev());
        assert!(idle.energy_j < spin.energy_j);
    }

    #[test]
    fn power_capped_at_max() {
        let (f, b) = counts::matmul(64, 4096, 4096, 4096);
        let c = KernelDesc::compute("huge", ComputeUnit::TensorCore, f, b).cost(&dev());
        assert!(c.avg_power_w <= dev().max_w + 1e-9);
    }

    #[test]
    fn monotone_in_flops_property() {
        use crate::prop;
        let gen = prop::usizes(1, 4096);
        prop::forall("energy monotone in flops", &gen, 64, |&m| {
            let a = KernelDesc::compute("a", ComputeUnit::CudaCore, (m * 1000) as f64, 1e6).cost(&dev());
            let b = KernelDesc::compute("b", ComputeUnit::CudaCore, ((m + 1) * 1000) as f64, 1e6).cost(&dev());
            b.energy_j >= a.energy_j
        });
    }
}
