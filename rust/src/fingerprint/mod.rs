//! SVD-invariant tensor fingerprints (paper §4.2, "Matching Equivalent
//! Tensors").
//!
//! Layout transformations (permute/reshape) reorder tensor entries
//! without changing the singular-value spectra of its matricizations.
//! For an r-way tensor we enumerate the non-trivial dimension subsets
//! `G`, matricize with `G` as rows, and record a spectrum invariant per
//! unfolding. Since `sigma(T_(G)) == sigma(T_(Gc))`, only the canonical
//! half of the subsets is computed.
//!
//! Instead of a full thin SVD per unfolding, the hot path records the
//! **spectral moments** `tr(G^k)`, `G = M M^T`, `k = 1..K` — the power
//! sums of squared singular values, which determine the spectrum and
//! are computable as pure matmuls. That is exactly the computation the
//! L1 Pallas kernel (`python/compile/kernels/fingerprint.py`) performs
//! on the MXU; [`MomentEngine`] abstracts over the Rust fallback and the
//! PJRT-compiled artifact ([`crate::runtime`]). Exact Jacobi-SVD
//! spectra ([`crate::linalg::singular_values`]) validate the moment
//! path in tests.

use std::collections::BTreeMap;

use crate::tensor::Tensor;

/// Number of spectral moments per unfolding.
pub const MOMENT_ORDER: usize = 4;

/// Structural signature of one kernel-op event: FNV-1a over the
/// call-site label and op name (0xff separates the parts so
/// `("ab", "c")` ≠ `("a", "bc")`). This is the unit the streaming
/// auditor's positional pairing compares and the session-level
/// [`WorkloadSig`] folds over, so a workload hashes identically whether
/// it is fingerprinted statically (from the program graph) or
/// dynamically (from the emitted kernel records).
pub fn op_signature(label: &str, op_name: &str) -> u64 {
    crate::util::fnv1a(label.bytes().chain([0xffu8]).chain(op_name.bytes()))
}

/// SplitMix64 finaliser: full-avalanche mixing applied to each op
/// signature before the commutative fold in [`WorkloadSig`], so the
/// multiset hash is sensitive to every bit of every signature (a plain
/// sum of raw FNV values would let related labels cancel).
pub fn mix64(v: u64) -> u64 {
    let mut z = v.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Deterministic, order-independent signature of a workload's kernel-op
/// multiset: the wrapping sum of [`mix64`]-mixed [`op_signature`]s plus
/// the explicit per-label op counts behind it.
///
/// Two runs of the same workload — on different days, different worker
/// counts, even different op *orders* (the fold is commutative) —
/// produce the same signature, which is what lets
/// [`crate::telemetry::session`] join persisted sessions from different
/// deploys for longitudinal differential auditing. The label counts are
/// kept explicit (not just hashed) so tolerant matching can reason
/// about *partial* overlap between two workloads.
///
/// ```
/// use magneton::fingerprint::WorkloadSig;
///
/// let mut a = WorkloadSig::new();
/// a.add("serve.proj", "matmul");
/// a.add("serve.act", "gelu");
/// let mut b = WorkloadSig::new();
/// b.add("serve.act", "gelu"); // other order, same multiset
/// b.add("serve.proj", "matmul");
/// assert_eq!(a.fp(), b.fp());
/// ```
#[derive(Clone, Debug, Default, PartialEq)]
pub struct WorkloadSig {
    fp: u64,
    total_ops: usize,
    labels: BTreeMap<String, usize>,
}

impl WorkloadSig {
    pub fn new() -> WorkloadSig {
        WorkloadSig::default()
    }

    /// Fold one kernel-op event into the signature.
    pub fn add(&mut self, label: &str, op_name: &str) {
        self.fp = self.fp.wrapping_add(mix64(op_signature(label, op_name)));
        self.total_ops += 1;
        if let Some(n) = self.labels.get_mut(label) {
            *n += 1;
        } else {
            self.labels.insert(label.to_string(), 1);
        }
    }

    /// Fold another signature in (multiset union — used to combine the
    /// per-pair signatures of one session into a session-level one).
    pub fn merge(&mut self, other: &WorkloadSig) {
        self.fp = self.fp.wrapping_add(other.fp);
        self.total_ops += other.total_ops;
        for (label, n) in &other.labels {
            *self.labels.entry(label.clone()).or_insert(0) += n;
        }
    }

    /// The order-independent multiset hash (0 for an empty workload).
    pub fn fp(&self) -> u64 {
        self.fp
    }

    /// Kernel ops folded in.
    pub fn total_ops(&self) -> usize {
        self.total_ops
    }

    /// Per-label op counts (label-sorted).
    pub fn labels(&self) -> &BTreeMap<String, usize> {
        &self.labels
    }

    /// Per-label op counts as a label-sorted vector (the form the
    /// session header persists).
    pub fn label_counts(&self) -> Vec<(String, usize)> {
        self.labels.iter().map(|(l, &n)| (l.clone(), n)).collect()
    }
}

/// Computes spectral moments of a matricized tensor. Implementations:
/// the in-process Rust engine (default) and the PJRT-compiled Pallas
/// kernel (see `runtime::PjrtMomentEngine`).
pub trait MomentEngine: Sync {
    /// `tr((M M^T)^k)` for `k = 1..=order`, with `M` oriented so that
    /// `rows <= cols`.
    fn moments(&self, mat: &Tensor, order: usize) -> Vec<f64>;

    /// Engine name for reports.
    fn name(&self) -> &'static str {
        "rust"
    }
}

/// Pure-Rust moment engine (f64 accumulation).
pub struct RustMomentEngine;

impl MomentEngine for RustMomentEngine {
    fn moments(&self, mat: &Tensor, order: usize) -> Vec<f64> {
        crate::linalg::spectral_moments(mat, order)
    }
}

/// Invariants of one unfolding.
#[derive(Clone, Debug, PartialEq)]
pub struct UnfoldingInvariant {
    /// Bitmask over dims selecting the row group `G`.
    pub mask: u32,
    /// Raw moments `tr(G^k)`, k = 1..=MOMENT_ORDER.
    pub moments: Vec<f64>,
}

/// Layout-invariant fingerprint of a tensor.
#[derive(Clone, Debug)]
pub struct Fingerprint {
    pub numel: usize,
    /// Frobenius norm (= sqrt of first moment; cheap prefilter).
    pub fro: f64,
    /// Invariants for the canonical half of the non-trivial unfoldings,
    /// sorted canonically so comparison is layout-independent.
    pub unfoldings: Vec<UnfoldingInvariant>,
}

/// Matricize `t` with dims in `mask` as rows (row-major within groups).
pub fn unfold(t: &Tensor, mask: u32) -> Tensor {
    let r = t.rank();
    let rows_dims: Vec<usize> = (0..r).filter(|i| mask & (1 << i) != 0).collect();
    let cols_dims: Vec<usize> = (0..r).filter(|i| mask & (1 << i) == 0).collect();
    let m: usize = rows_dims.iter().map(|&d| t.shape()[d]).product();
    let n: usize = cols_dims.iter().map(|&d| t.shape()[d]).product();
    let perm: Vec<usize> = rows_dims.iter().chain(cols_dims.iter()).copied().collect();
    t.permute(&perm).contiguous().reshape(&[m, n])
}

/// Orient a matrix so rows <= cols (spectra invariant under transpose).
fn orient(m: Tensor) -> Tensor {
    if m.shape()[0] <= m.shape()[1] {
        m
    } else {
        m.t().contiguous()
    }
}

/// Canonical unfolding masks for rank `r`: one representative of each
/// `{G, Gc}` pair (the one containing dim 0), excluding trivial sets.
/// Rank-1 tensors get the single row-vector unfolding (mask 0 marker).
pub fn canonical_masks(r: usize) -> Vec<u32> {
    if r <= 1 {
        return vec![0];
    }
    let full = (1u32 << r) - 1;
    (1..full)
        .filter(|g| g & 1 == 1) // contains dim 0 => canonical half
        .collect()
}

/// Compute the fingerprint of a tensor with a given engine.
pub fn fingerprint_with(engine: &dyn MomentEngine, t: &Tensor) -> Fingerprint {
    let numel = t.numel();
    let r = t.rank().max(1);
    let mut unfoldings = Vec::new();
    for mask in canonical_masks(r) {
        let mat = if r == 1 {
            t.reshape(&[1, numel])
        } else {
            orient(unfold(t, mask))
        };
        let moments = engine.moments(&mat, MOMENT_ORDER);
        unfoldings.push(UnfoldingInvariant { mask, moments });
    }
    // canonical sort: by moment vector, so two layouts of the same data
    // produce the same sequence
    unfoldings.sort_by(|a, b| {
        // lexicographic total order over moment vectors (NaN-safe)
        let lex = a
            .moments
            .iter()
            .zip(b.moments.iter())
            .map(|(x, y)| x.total_cmp(y))
            .find(|o| o.is_ne())
            .unwrap_or(std::cmp::Ordering::Equal);
        lex.then(a.moments.len().cmp(&b.moments.len()))
    });
    let fro = unfoldings
        .first()
        .map(|u| u.moments[0].max(0.0).sqrt())
        .unwrap_or(0.0);
    Fingerprint { numel, fro, unfoldings }
}

/// Fingerprint with the default Rust engine.
pub fn fingerprint(t: &Tensor) -> Fingerprint {
    fingerprint_with(&RustMomentEngine, t)
}

/// Cheap per-op content sketch for streaming output guards
/// ([`crate::stream`]): spectral moments of order 2 over the tensor's
/// first canonical unfolding only. A fraction of a full
/// [`Fingerprint`]'s cost (one unfolding instead of the canonical
/// half-set), transpose-insensitive via the same orientation rule, and
/// cheap enough to attach to every kernel record of a live stream.
pub fn content_sketch(engine: &dyn MomentEngine, t: &Tensor) -> Vec<f64> {
    if t.numel() == 0 {
        return Vec::new();
    }
    let r = t.rank();
    let mat = if r <= 1 {
        t.reshape(&[1, t.numel()])
    } else {
        orient(unfold(t, canonical_masks(r)[0]))
    };
    engine.moments(&mat, 2)
}

/// Relative distance between two moment vectors: max over k of the
/// relative difference.
fn moment_distance(a: &[f64], b: &[f64]) -> f64 {
    let mut d: f64 = 0.0;
    for (x, y) in a.iter().zip(b.iter()) {
        let rel = (x - y).abs() / x.abs().max(y.abs()).max(1e-30);
        d = d.max(rel);
    }
    d
}

impl Fingerprint {
    /// Distance in [0, inf): 0 for identical invariant sets. Tensors
    /// with different element counts are infinitely far apart.
    pub fn distance(&self, other: &Fingerprint) -> f64 {
        if self.numel != other.numel {
            return f64::INFINITY;
        }
        // Injective greedy matching from the smaller invariant list into
        // the larger (rank can differ across systems after reshapes).
        let (small, large) = if self.unfoldings.len() <= other.unfoldings.len() {
            (&self.unfoldings, &other.unfoldings)
        } else {
            (&other.unfoldings, &self.unfoldings)
        };
        let mut used = vec![false; large.len()];
        let mut worst: f64 = 0.0;
        for u in small {
            let mut best = f64::INFINITY;
            let mut best_j = None;
            for (j, v) in large.iter().enumerate() {
                if used[j] {
                    continue;
                }
                let d = moment_distance(&u.moments, &v.moments);
                if d < best {
                    best = d;
                    best_j = Some(j);
                }
            }
            if let Some(j) = best_j {
                used[j] = true;
            }
            worst = worst.max(best);
        }
        worst
    }

    /// The paper's equivalence predicate at tolerance eps.
    pub fn matches(&self, other: &Fingerprint, eps: f64) -> bool {
        self.distance(other) <= eps
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Prng;

    #[test]
    fn canonical_masks_counts() {
        assert_eq!(canonical_masks(1), vec![0]);
        assert_eq!(canonical_masks(2), vec![1]); // {0} vs {1}: one pair
        assert_eq!(canonical_masks(3).len(), 3); // 2^3-2 = 6 unfoldings, 3 pairs
        assert_eq!(canonical_masks(4).len(), 7);
    }

    #[test]
    fn identical_tensors_distance_zero() {
        let mut rng = Prng::new(1);
        let t = Tensor::randn(&mut rng, &[4, 6, 8]);
        let f1 = fingerprint(&t);
        let f2 = fingerprint(&t.clone());
        assert!(f1.distance(&f2) < 1e-12);
    }

    #[test]
    fn permuted_layouts_match() {
        // HND vs NHD attention layouts (paper's motivating example)
        let mut rng = Prng::new(2);
        let hnd = Tensor::randn(&mut rng, &[2, 3, 5, 7]);
        let nhd = hnd.permute(&[0, 2, 1, 3]).contiguous();
        let f1 = fingerprint(&hnd);
        let f2 = fingerprint(&nhd);
        assert!(f1.matches(&f2, 1e-4), "distance {}", f1.distance(&f2));
    }

    #[test]
    fn elementwise_comparison_would_fail_where_fingerprint_succeeds() {
        let mut rng = Prng::new(3);
        let a = Tensor::randn(&mut rng, &[4, 8, 16]);
        let b = a.permute(&[1, 0, 2]).contiguous();
        // naive element-wise check fails (different layout)…
        assert!(a.to_vec() != b.to_vec());
        // …but the invariant sets match
        assert!(fingerprint(&a).matches(&fingerprint(&b), 1e-4));
    }

    #[test]
    fn different_tensors_do_not_match() {
        let mut rng = Prng::new(4);
        let a = Tensor::randn(&mut rng, &[8, 8]);
        let b = Tensor::randn(&mut rng, &[8, 8]);
        let d = fingerprint(&a).distance(&fingerprint(&b));
        assert!(d > 0.05, "independent tensors too close: {d}");
    }

    #[test]
    fn different_numel_never_matches() {
        let a = Tensor::zeros(&[4, 4]);
        let b = Tensor::zeros(&[4, 5]);
        assert_eq!(fingerprint(&a).distance(&fingerprint(&b)), f64::INFINITY);
    }

    #[test]
    fn reshaped_matrix_still_matches_via_injective_map() {
        // [B, S, H] vs [B*S, H]: systems flatten batch dims differently
        let mut rng = Prng::new(5);
        let t3 = Tensor::randn(&mut rng, &[4, 6, 10]);
        let t2 = t3.reshape(&[24, 10]);
        let f3 = fingerprint(&t3);
        let f2 = fingerprint(&t2);
        // the 2-D tensor's single unfolding appears among the 3-D one's
        assert!(f3.matches(&f2, 1e-6), "distance {}", f3.distance(&f2));
    }

    #[test]
    fn moments_match_exact_svd_spectrum() {
        let mut rng = Prng::new(6);
        let t = Tensor::randn(&mut rng, &[5, 12]);
        let f = fingerprint(&t);
        let sv = crate::linalg::singular_values(&t);
        let m1: f64 = sv.iter().map(|&s| (s as f64).powi(2)).sum();
        let rel = (f.unfoldings[0].moments[0] - m1).abs() / m1;
        assert!(rel < 1e-3, "tr(G) {} vs sum sigma^2 {m1}", f.unfoldings[0].moments[0]);
    }

    #[test]
    fn small_noise_within_loose_tolerance() {
        // TF32-rounded results must still match at the paper's optimal
        // epsilon range (1e-4..1.8e-2)
        let mut rng = Prng::new(7);
        let a = Tensor::randn(&mut rng, &[16, 16]);
        let noisy = crate::tensor::ops::map(&a, crate::tensor::ops::tf32_round);
        let d = fingerprint(&a).distance(&fingerprint(&noisy));
        assert!(d < 1e-2, "tf32 noise distance {d}");
        assert!(d > 0.0);
    }

    #[test]
    fn prop_fingerprint_invariant_under_random_permutations() {
        use crate::prop;
        let gen = prop::Gen::new(|r| {
            let rank = r.range(2, 4);
            let shape: Vec<usize> = (0..rank).map(|_| r.range(2, 6)).collect();
            let t = Tensor::randn(r, &shape);
            let mut perm: Vec<usize> = (0..rank).collect();
            r.shuffle(&mut perm);
            (t, perm)
        });
        prop::forall("fingerprint permute-invariant", &gen, 40, |(t, perm)| {
            let p = t.permute(perm).contiguous();
            fingerprint(t).matches(&fingerprint(&p), 1e-4)
        });
    }

    /// The streaming content sketch: deterministic on identical data,
    /// transpose-insensitive, and separating for genuinely different
    /// tensors — at a fraction of a full fingerprint's cost.
    #[test]
    fn content_sketch_separates_and_is_transpose_insensitive() {
        let mut rng = Prng::new(9);
        let a = Tensor::randn(&mut rng, &[8, 16]);
        let b = Tensor::randn(&mut rng, &[8, 16]);
        let sa = content_sketch(&RustMomentEngine, &a);
        assert_eq!(sa.len(), 2);
        assert_eq!(sa, content_sketch(&RustMomentEngine, &a.clone()));
        let st = content_sketch(&RustMomentEngine, &a.t().contiguous());
        for (x, y) in sa.iter().zip(st.iter()) {
            assert!((x - y).abs() <= 1e-6 * x.abs().max(y.abs()), "{x} vs {y}");
        }
        let sb = content_sketch(&RustMomentEngine, &b);
        let rel = (sa[0] - sb[0]).abs() / sa[0].abs().max(sb[0].abs());
        assert!(rel > 1e-3, "independent tensors too close: {rel}");
        // rank-1 and rank-3 shapes are sketchable too
        assert_eq!(content_sketch(&RustMomentEngine, &Tensor::randn(&mut rng, &[32])).len(), 2);
        assert_eq!(content_sketch(&RustMomentEngine, &Tensor::randn(&mut rng, &[2, 3, 4])).len(), 2);
    }

    /// The workload multiset signature: order-independent, count- and
    /// label-sensitive, and mergeable.
    #[test]
    fn workload_sig_is_an_order_independent_multiset_hash() {
        let mut fwd = WorkloadSig::new();
        let mut rev = WorkloadSig::new();
        let ops = [("serve.proj", "matmul"), ("serve.act", "gelu"), ("serve.proj", "matmul")];
        for (l, o) in ops {
            fwd.add(l, o);
        }
        for (l, o) in ops.iter().rev() {
            rev.add(l, o);
        }
        assert_eq!(fwd.fp(), rev.fp());
        assert_eq!(fwd.total_ops(), 3);
        assert_eq!(fwd.label_counts(), vec![("serve.act".into(), 1), ("serve.proj".into(), 2)]);
        // multiset-sensitive: dropping one duplicate changes the hash
        let mut fewer = WorkloadSig::new();
        fewer.add("serve.proj", "matmul");
        fewer.add("serve.act", "gelu");
        assert_ne!(fwd.fp(), fewer.fp());
        // label- and op-sensitive
        let mut other = fewer.clone();
        other.add("serve.out", "matmul");
        assert_ne!(fwd.fp(), other.fp());
        // merge == folding both multisets into one
        let mut merged = fewer.clone();
        let mut tail = WorkloadSig::new();
        tail.add("serve.proj", "matmul");
        merged.merge(&tail);
        assert_eq!(merged.fp(), fwd.fp());
        assert_eq!(merged.total_ops(), fwd.total_ops());
        assert_eq!(merged.label_counts(), fwd.label_counts());
        // the label/op separator matters
        assert_ne!(op_signature("ab", "c"), op_signature("a", "bc"));
    }

    #[test]
    fn rank1_tensors_fingerprintable() {
        let mut rng = Prng::new(8);
        let v = Tensor::randn(&mut rng, &[32]);
        let f = fingerprint(&v);
        assert_eq!(f.unfoldings.len(), 1);
        assert!(f.fro > 0.0);
    }
}
