//! Property-based testing mini-framework (the offline registry has no
//! `proptest`). Provides value generators over a deterministic [`Prng`]
//! and a `forall` runner with case-count control and failing-seed
//! reporting. Used throughout the crate to check coordinator invariants:
//! dominator-tree properties, matcher soundness, energy-model
//! monotonicity, fingerprint invariance under layout transforms, etc.

use crate::util::Prng;

/// A generator of random values of type `T`.
pub struct Gen<T> {
    f: Box<dyn Fn(&mut Prng) -> T>,
}

impl<T: 'static> Gen<T> {
    /// Build a generator from a closure.
    pub fn new<F: Fn(&mut Prng) -> T + 'static>(f: F) -> Gen<T> {
        Gen { f: Box::new(f) }
    }

    /// Sample one value.
    pub fn sample(&self, rng: &mut Prng) -> T {
        (self.f)(rng)
    }

    /// Map the generated value.
    pub fn map<U: 'static, F: Fn(T) -> U + 'static>(self, f: F) -> Gen<U> {
        Gen::new(move |r| f((self.f)(r)))
    }
}

/// usize in [lo, hi] inclusive.
pub fn usizes(lo: usize, hi: usize) -> Gen<usize> {
    Gen::new(move |r| r.range(lo, hi))
}

/// f32 in [lo, hi).
pub fn f32s(lo: f32, hi: f32) -> Gen<f32> {
    Gen::new(move |r| r.range_f32(lo, hi))
}

/// Vec of `n` standard-normal f32s where n is drawn from [nlo, nhi].
pub fn normal_vecs(nlo: usize, nhi: usize) -> Gen<Vec<f32>> {
    Gen::new(move |r| {
        let n = r.range(nlo, nhi);
        r.normal_vec(n)
    })
}

/// Tensor shapes with `rank` in [rlo, rhi] and dims in [dlo, dhi].
pub fn shapes(rlo: usize, rhi: usize, dlo: usize, dhi: usize) -> Gen<Vec<usize>> {
    Gen::new(move |r| {
        let rank = r.range(rlo, rhi);
        (0..rank).map(|_| r.range(dlo, dhi)).collect()
    })
}

/// Configuration for a property run.
#[derive(Clone, Copy, Debug)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
}

/// Default seed: ASCII "MAGNETON" as a u64.
pub const DEFAULT_SEED: u64 = 0x4d41_474e_4554_4f4e;

impl Default for Config {
    fn default() -> Config {
        Config { cases: 64, seed: DEFAULT_SEED }
    }
}

/// Run `prop` over `cases` samples from `gen`; panics with the failing
/// seed and case index on the first violation.
pub fn forall<T: std::fmt::Debug + 'static>(
    name: &str,
    gen: &Gen<T>,
    cases: usize,
    prop: impl Fn(&T) -> bool,
) {
    forall_seeded(name, gen, cases, DEFAULT_SEED, prop)
}

/// Like [`forall`] with an explicit seed.
pub fn forall_seeded<T: std::fmt::Debug + 'static>(
    name: &str,
    gen: &Gen<T>,
    cases: usize,
    seed: u64,
    prop: impl Fn(&T) -> bool,
) {
    let mut rng = Prng::new(seed);
    for case in 0..cases {
        let value = gen.sample(&mut rng);
        if !prop(&value) {
            panic!(
                "property `{name}` failed at case {case}/{cases} (seed={seed:#x})\nvalue: {value:?}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivially() {
        forall("usize in range", &usizes(1, 10), 100, |&n| (1..=10).contains(&n));
    }

    #[test]
    #[should_panic(expected = "property `always false` failed")]
    fn forall_reports_failure() {
        forall("always false", &usizes(0, 1), 10, |_| false);
    }

    #[test]
    fn shapes_generator_respects_bounds() {
        forall("shape bounds", &shapes(1, 4, 2, 8), 200, |s| {
            (1..=4).contains(&s.len()) && s.iter().all(|&d| (2..=8).contains(&d))
        });
    }

    #[test]
    fn map_composes() {
        let g = usizes(1, 5).map(|n| n * 2);
        let mut rng = Prng::new(1);
        for _ in 0..50 {
            let v = g.sample(&mut rng);
            assert!(v % 2 == 0 && (2..=10).contains(&v));
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let g = normal_vecs(3, 6);
        let mut a = Prng::new(9);
        let mut b = Prng::new(9);
        assert_eq!(g.sample(&mut a), g.sample(&mut b));
    }
}
