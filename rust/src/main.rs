//! Magneton CLI — the leader entrypoint.
//!
//! ```text
//! magneton cases [--id c10] [--eps 1e-3] [--threshold 0.10]
//! magneton fleet                      # Fig 5 cross-system comparison
//! magneton ddp [--iters 20]           # Fig 4 power timeline
//! magneton breakdown [--id c10]       # Fig 2-style per-op breakdown
//! magneton accuracy                   # Table 4 measurement accuracy
//! magneton artifacts [--dir artifacts]# list loadable PJRT artifacts
//! ```

use magneton::cases;
use magneton::coordinator::Magneton;
use magneton::energy::DeviceSpec;
use magneton::report;
use magneton::util::cli::Args;
use magneton::util::table::{fmt_joules, Table};
use magneton::util::Prng;

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().map(String::as_str).unwrap_or("help");
    match cmd {
        "cases" => cmd_cases(&args),
        "fleet" => cmd_fleet(&args),
        "ddp" => cmd_ddp(&args),
        "breakdown" => cmd_breakdown(&args),
        "accuracy" => cmd_accuracy(),
        "artifacts" => cmd_artifacts(&args),
        _ => print_help(),
    }
}

fn print_help() {
    println!(
        "magneton — differential energy debugging for ML systems\n\n\
         USAGE: magneton <command> [options]\n\n\
         COMMANDS:\n\
         \x20 cases      run known + new case audits (--id cX for one)\n\
         \x20 fleet      cross-system energy comparison (Fig 5)\n\
         \x20 ddp        DDP join-vs-early-exit power timeline (Fig 4)\n\
         \x20 breakdown  per-operator energy breakdown of a case (Fig 2)\n\
         \x20 accuracy   power-measurement accuracy comparison (Table 4)\n\
         \x20 artifacts  list PJRT artifacts and smoke-run the fingerprint kernel\n\n\
         OPTIONS: --id <case> --eps <f64> --threshold <f64> --seed <u64> --device <h200|rtx4090>"
    );
}

fn device(args: &Args) -> DeviceSpec {
    match args.get("device", "h200") {
        "rtx4090" => DeviceSpec::rtx4090_sim(),
        _ => DeviceSpec::h200_sim(),
    }
}

fn magneton(args: &Args) -> Magneton {
    let mut m = Magneton::new(device(args));
    m.eps = args.get_parse("eps", 1e-3);
    m.cfg.energy_threshold = args.get_parse("threshold", 0.10);
    m
}

fn cmd_cases(args: &Args) {
    let mag = magneton(args);
    let mut rng = Prng::new(args.get_parse("seed", 2026u64));
    let scenarios: Vec<cases::Scenario> = match args.options.get("id") {
        Some(id) => cases::by_id(id).into_iter().collect(),
        None => cases::known_cases().into_iter().chain(cases::new_cases()).collect(),
    };
    for s in scenarios {
        println!("\n##### case {} ({}) — {}", s.id, s.issue, s.description);
        let (a, b) = (s.build)(&mut rng);
        let out = mag.audit(&a, &b);
        println!("{}", report::render_audit(&a.label, &b.label, &out));
        if s.expect_undetected {
            println!(
                "paper expectation: NOT detected (CPU-side issue) — magneton {}",
                if out.detected() { "detected (unexpected)" } else { "correctly silent" }
            );
        }
    }
}

fn cmd_fleet(args: &Args) {
    use magneton::systems::llm;
    let mag = magneton(args);
    let mut rng = Prng::new(args.get_parse("seed", 2026u64));
    let params = llm::TransformerParams::new(&mut rng, llm::LlmSpec::gpt2_sim());
    let mut t = Table::new(vec!["system", "energy", "J/token", "kernels"]);
    let tokens = (params.spec.batch * params.spec.seq) as f64;
    for (name, opts, disp, env) in [
        (
            "mini-hf-transformers",
            llm::LlmBuildOpts::hf(),
            llm::hf_dispatcher(),
            llm::default_env(magneton::systems::SystemId::MiniHf),
        ),
        (
            "mini-vllm",
            llm::LlmBuildOpts::vllm(),
            llm::vllm_dispatcher(),
            llm::default_env(magneton::systems::SystemId::MiniVllm),
        ),
        (
            "mini-sglang",
            llm::LlmBuildOpts::sglang(),
            llm::sglang_dispatcher(),
            llm::default_env(magneton::systems::SystemId::MiniSglang),
        ),
    ] {
        let run = magneton::coordinator::SysRun::new(name, disp, env, llm::build_llm(&params, &opts));
        let arts = mag.run_side(&run);
        t.row(vec![
            name.to_string(),
            fmt_joules(arts.total_energy_j),
            format!("{:.3} mJ", arts.total_energy_j / tokens * 1e3),
            arts.records.len().to_string(),
        ]);
    }
    println!("{}", t.render());
}

fn cmd_ddp(args: &Args) {
    use magneton::workload::{run_ddp, DdpWorkload, SyncStrategy};
    let dev = device(args);
    let mut w = DdpWorkload::paper_setup();
    w.iterations = args.get_parse("iters", 20usize);
    let join = run_ddp(&dev, &w, SyncStrategy::Join, 7);
    let exit = run_ddp(&dev, &w, SyncStrategy::EarlyExit, 7);
    println!(
        "dist.Join: {}   early-exit: {}   saving {:.1}%",
        fmt_joules(join.total_energy_j),
        fmt_joules(exit.total_energy_j),
        (1.0 - exit.total_energy_j / join.total_energy_j) * 100.0
    );
}

fn cmd_breakdown(args: &Args) {
    let mag = magneton(args);
    let mut rng = Prng::new(args.get_parse("seed", 2026u64));
    let id = args.get("id", "c10");
    let Some(s) = cases::by_id(id) else {
        println!("unknown case {id}");
        return;
    };
    let (a, b) = (s.build)(&mut rng);
    for (label, run) in [(&a.label, &a), (&b.label, &b)] {
        let arts = mag.run_side(run);
        println!("\n--- {label}: total {} ---", fmt_joules(arts.total_energy_j));
        println!("{}", report::energy_breakdown(&arts, 5).render());
    }
}

fn cmd_accuracy() {
    // Table 4 lives in benches/table4_accuracy.rs; here a quick preview
    println!("run `cargo bench --bench table4_accuracy` for the full table");
}

fn cmd_artifacts(args: &Args) {
    let dir = std::path::PathBuf::from(args.get("dir", "artifacts"));
    match magneton::runtime::PjrtRuntime::cpu() {
        Err(e) => println!("PJRT unavailable: {e}"),
        Ok(mut rt) => match rt.load_dir(&dir) {
            Err(e) => println!("no artifacts loaded from {dir:?}: {e}"),
            Ok(n) => {
                println!("loaded {n} artifacts: {:?}", rt.names());
                match magneton::runtime::PjrtMomentEngine::load(&dir) {
                    Ok(eng) => {
                        use magneton::fingerprint::MomentEngine;
                        let mut rng = Prng::new(1);
                        let t = magneton::tensor::Tensor::randn(&mut rng, &[16, 64]);
                        let m = eng.moments(&t, 4);
                        println!("fingerprint kernel smoke: moments = {m:?}");
                    }
                    Err(e) => println!("fingerprint engine: {e}"),
                }
            }
        },
    }
}
