//! Magneton CLI — the leader entrypoint.
//!
//! ```text
//! magneton cases [--id c10] [--eps 1e-3] [--threshold 0.10]
//! magneton fleet                      # Fig 5 cross-system comparison
//! magneton ddp [--iters 20]           # Fig 4 power timeline
//! magneton breakdown [--id c10]       # Fig 2-style per-op breakdown
//! magneton accuracy                   # Table 4 measurement accuracy
//! magneton artifacts [--dir artifacts]# list loadable PJRT artifacts
//! magneton stream [--requests 500 --arrival poisson|bursty|steady]
//!                                     # online serving-stream audit
//! ```

use magneton::cases;
use magneton::coordinator::Magneton;
use magneton::energy::DeviceSpec;
use magneton::report;
use magneton::util::cli::Args;
use magneton::util::table::{fmt_joules, Table};
use magneton::util::Prng;

/// Subcommand names, reserved at parse time so a bare flag never
/// swallows one as its value (`magneton --verbose cases`).
const SUBCOMMANDS: &[&str] =
    &["cases", "fleet", "ddp", "breakdown", "accuracy", "artifacts", "stream", "help"];

fn main() {
    let args = Args::from_env_reserved(SUBCOMMANDS);
    let cmd = args.positional.first().map(String::as_str).unwrap_or("help");
    match cmd {
        "cases" => cmd_cases(&args),
        "fleet" => cmd_fleet(&args),
        "ddp" => cmd_ddp(&args),
        "breakdown" => cmd_breakdown(&args),
        "accuracy" => cmd_accuracy(),
        "artifacts" => cmd_artifacts(&args),
        "stream" => cmd_stream(&args),
        _ => print_help(),
    }
}

fn print_help() {
    println!(
        "magneton — differential energy debugging for ML systems\n\n\
         USAGE: magneton <command> [options]\n\n\
         COMMANDS:\n\
         \x20 cases      run known + new case audits (--id cX for one)\n\
         \x20 fleet      cross-system energy comparison (Fig 5)\n\
         \x20 ddp        DDP join-vs-early-exit power timeline (Fig 4)\n\
         \x20 breakdown  per-operator energy breakdown of a case (Fig 2)\n\
         \x20 accuracy   power-measurement accuracy comparison (Table 4)\n\
         \x20 artifacts  list PJRT artifacts and smoke-run the fingerprint kernel\n\
         \x20 stream     online audit of a live serving pair: chunked channel\n\
         \x20            ingestion, request-arrival idle gaps, resync + content\n\
         \x20            guards, rolling window reports, then a streaming fleet\n\n\
         OPTIONS: --id <case> --eps <f64> --threshold <f64> --seed <u64> --device <h200|rtx4090>\n\
         STREAM:  --requests <n=500> --arrival <poisson|bursty|steady> --rate <hz=200>\n\
         \x20        --burst <n=16> --window <pairs=250> --hop <pairs> --ring <segs=512>\n\
         \x20        --chunk <events=64> --queue <chunks=4> --max-emitted <n=64>\n\
         \x20        --eff <0..1=0.62> --pairs <fleet pairs=3>"
    );
}

fn device(args: &Args) -> DeviceSpec {
    match args.get("device", "h200") {
        "rtx4090" => DeviceSpec::rtx4090_sim(),
        _ => DeviceSpec::h200_sim(),
    }
}

fn magneton(args: &Args) -> Magneton {
    let mut m = Magneton::new(device(args));
    m.eps = args.get_parse("eps", 1e-3);
    m.cfg.energy_threshold = args.get_parse("threshold", 0.10);
    m
}

fn cmd_cases(args: &Args) {
    let mag = magneton(args);
    let mut rng = Prng::new(args.get_parse("seed", 2026u64));
    let scenarios: Vec<cases::Scenario> = match args.options.get("id") {
        Some(id) => cases::by_id(id).into_iter().collect(),
        None => cases::known_cases().into_iter().chain(cases::new_cases()).collect(),
    };
    for s in scenarios {
        println!("\n##### case {} ({}) — {}", s.id, s.issue, s.description);
        let (a, b) = (s.build)(&mut rng);
        let out = mag.audit(&a, &b);
        println!("{}", report::render_audit(&a.label, &b.label, &out));
        if s.expect_undetected {
            println!(
                "paper expectation: NOT detected (CPU-side issue) — magneton {}",
                if out.detected() { "detected (unexpected)" } else { "correctly silent" }
            );
        }
    }
}

fn cmd_fleet(args: &Args) {
    use magneton::systems::llm;
    let mag = magneton(args);
    let mut rng = Prng::new(args.get_parse("seed", 2026u64));
    let params = llm::TransformerParams::new(&mut rng, llm::LlmSpec::gpt2_sim());
    let mut t = Table::new(vec!["system", "energy", "J/token", "kernels"]);
    let tokens = (params.spec.batch * params.spec.seq) as f64;
    for (name, opts, disp, env) in [
        (
            "mini-hf-transformers",
            llm::LlmBuildOpts::hf(),
            llm::hf_dispatcher(),
            llm::default_env(magneton::systems::SystemId::MiniHf),
        ),
        (
            "mini-vllm",
            llm::LlmBuildOpts::vllm(),
            llm::vllm_dispatcher(),
            llm::default_env(magneton::systems::SystemId::MiniVllm),
        ),
        (
            "mini-sglang",
            llm::LlmBuildOpts::sglang(),
            llm::sglang_dispatcher(),
            llm::default_env(magneton::systems::SystemId::MiniSglang),
        ),
    ] {
        let run = magneton::coordinator::SysRun::new(name, disp, env, llm::build_llm(&params, &opts));
        let arts = mag.run_side(&run);
        t.row(vec![
            name.to_string(),
            fmt_joules(arts.total_energy_j),
            format!("{:.3} mJ", arts.total_energy_j / tokens * 1e3),
            arts.records.len().to_string(),
        ]);
    }
    println!("{}", t.render());
}

fn cmd_ddp(args: &Args) {
    use magneton::workload::{run_ddp, DdpWorkload, SyncStrategy};
    let dev = device(args);
    let mut w = DdpWorkload::paper_setup();
    w.iterations = args.get_parse("iters", 20usize);
    let join = run_ddp(&dev, &w, SyncStrategy::Join, 7);
    let exit = run_ddp(&dev, &w, SyncStrategy::EarlyExit, 7);
    println!(
        "dist.Join: {}   early-exit: {}   saving {:.1}%",
        fmt_joules(join.total_energy_j),
        fmt_joules(exit.total_energy_j),
        (1.0 - exit.total_energy_j / join.total_energy_j) * 100.0
    );
}

fn cmd_breakdown(args: &Args) {
    let mag = magneton(args);
    let mut rng = Prng::new(args.get_parse("seed", 2026u64));
    let id = args.get("id", "c10");
    let Some(s) = cases::by_id(id) else {
        println!("unknown case {id}");
        return;
    };
    let (a, b) = (s.build)(&mut rng);
    for (label, run) in [(&a.label, &a), (&b.label, &b)] {
        let arts = mag.run_side(run);
        println!("\n--- {label}: total {} ---", fmt_joules(arts.total_energy_j));
        println!("{}", report::energy_breakdown(&arts, 5).render());
    }
}

fn cmd_accuracy() {
    // Table 4 lives in benches/table4_accuracy.rs; here a quick preview
    println!("run `cargo bench --bench table4_accuracy` for the full table");
}

/// Online streaming audit: two producer threads execute a serving pair
/// and ship `(KernelRecord, Segment)` events in bounded chunks over
/// `sync_channel`s (the backpressure knob: at most `queue × chunk`
/// events are in flight per side); the consumer pairs them through a
/// `StreamAuditor`, materialising request-arrival idle gaps, printing
/// every rolling window report, and finishing with a streaming fleet
/// over N concurrent pairs under the same arrival process.
fn cmd_stream(args: &Args) {
    use magneton::coordinator::fleet::{drive_pair_with_arrivals, StreamFleet};
    use magneton::coordinator::SysRun;
    use magneton::dispatch::Env;
    use magneton::energy::Segment;
    use magneton::exec::{Executor, KernelRecord};
    use magneton::stream::{StreamAuditor, StreamConfig};
    use magneton::workload::{serving_dispatcher, serving_stream_program, ArrivalProcess, ServingStream};
    use std::sync::mpsc;
    use std::thread;

    let device = device(args);
    let requests: usize = args.get_parse("requests", 500usize);
    let rate: f64 = args.get_parse("rate", 200.0f64);
    let burst: usize = args.get_parse("burst", 16usize);
    let arrival_kind = args.get("arrival", "poisson");
    let Some(arrival) = ArrivalProcess::parse(arrival_kind, rate, burst) else {
        println!("unknown arrival process `{arrival_kind}` (expected steady|poisson|bursty)");
        return;
    };
    let spec = ServingStream { requests, ..Default::default() };
    let chunk_len: usize = args.get_parse("chunk", 64usize).max(1);
    let queue: usize = args.get_parse("queue", 4usize).max(1);
    // clamp user input rather than panic on the auditor's internal
    // asserts: window/ring must be positive, hop > window would leak
    // pairs out of the waste ledger
    let window_ops = args.get_parse("window", 250usize).max(1);
    let mut cfg = StreamConfig {
        window_ops,
        hop_ops: args.get_parse("hop", window_ops).clamp(1, window_ops),
        ring_cap: args.get_parse("ring", 512usize).max(1),
        max_emitted: args.get_parse("max-emitted", 64usize),
        ..StreamConfig::default()
    };
    // the consumer ingests chunk-by-chunk, so inter-side skew is
    // bounded by one chunk; keep pending headroom over it
    cfg.max_pending = cfg.max_pending.max(2 * chunk_len);
    let seed: u64 = args.get_parse("seed", 2026u64);
    let eff: f64 = args.get_parse("eff", 0.62f64);

    println!(
        "magneton stream: {} requests ({} kernel ops/side), {:?} arrivals,\n\
         window {} pairs, ring {} segments, chunks of {} over a {}-deep channel\n",
        spec.requests,
        spec.kernel_ops(),
        arrival,
        cfg.window_ops,
        cfg.ring_cap,
        chunk_len,
        queue
    );

    let spawn_side = |side_eff: f64| -> (mpsc::Receiver<Vec<(KernelRecord, Segment)>>, thread::JoinHandle<()>) {
        let (tx, rx) = mpsc::sync_channel::<Vec<(KernelRecord, Segment)>>(queue);
        let dev = device.clone();
        let handle = thread::spawn(move || {
            let mut rng = Prng::new(seed);
            let prog = serving_stream_program(&mut rng, &spec);
            let mut exec = Executor::new(dev, serving_dispatcher(side_eff), Env::new());
            exec.opts.content_sketch = true;
            let stream = exec.stream(&prog);
            let mut chunk = Vec::with_capacity(chunk_len);
            for ev in stream {
                chunk.push(ev);
                if chunk.len() == chunk_len {
                    if tx.send(std::mem::take(&mut chunk)).is_err() {
                        return; // consumer hung up
                    }
                    chunk.reserve(chunk_len);
                }
            }
            if !chunk.is_empty() {
                let _ = tx.send(chunk);
            }
        });
        (rx, handle)
    };
    let (rx_a, handle_a) = spawn_side(eff);
    let (rx_b, handle_b) = spawn_side(1.0);

    // the consumer: the one shared pairing protocol, fed by iterators
    // that drain the chunked channels (recv blocks = backpressure)
    let mut aud = StreamAuditor::new(cfg.clone(), device.idle_w);
    let mut arrival_rng = Prng::new(seed ^ 0xa441_b815);
    let ops_per_request = spec.ops_per_request();
    let summary = drive_pair_with_arrivals(
        &mut aud,
        rx_a.into_iter().flatten(),
        rx_b.into_iter().flatten(),
        arrival,
        ops_per_request,
        &mut arrival_rng,
        |w| println!("{}", report::render_window(&w)),
    );
    handle_a.join().expect("producer A panicked");
    handle_b.join().expect("producer B panicked");
    if let (Some(wa), Some(wb)) = (aud.nvml_reading_a(), aud.nvml_reading_b()) {
        println!("\nlive NVML counters: A {wa:.0} W, B {wb:.0} W (arrival lulls read through the rings)");
    }
    println!();
    print!("{}", report::render_stream("inefficient-vs-optimal", &summary));

    // final stage: a streaming fleet over N concurrent serving pairs
    // under the same arrival process
    let fleet_pairs: usize = args.get_parse("pairs", 3usize);
    let mut fleet = StreamFleet::new(device);
    fleet.cfg = cfg;
    fleet.arrival = arrival;
    fleet.ops_per_request = ops_per_request;
    fleet.arrival_seed = seed;
    let fleet_spec = ServingStream { requests: (requests / 5).max(20), ..spec };
    for i in 0..fleet_pairs {
        let pair_eff = if i % 2 == 0 { eff } else { 1.0 };
        let mut ra = Prng::new(seed + 1 + i as u64);
        let mut rb = Prng::new(seed + 1 + i as u64);
        fleet.add_pair(
            &format!("serving-{i}"),
            SysRun::new("sys-a", serving_dispatcher(pair_eff), Env::new(), serving_stream_program(&mut ra, &fleet_spec)),
            SysRun::new("sys-b", serving_dispatcher(1.0), Env::new(), serving_stream_program(&mut rb, &fleet_spec)),
        );
    }
    println!(
        "\nstreaming fleet: {} pairs x {} ops under {:?} arrivals over {} workers...",
        fleet.len(),
        fleet_spec.kernel_ops(),
        arrival,
        fleet.workers
    );
    let r = fleet.run();
    print!("{}", report::render_stream_fleet(&r));
}

fn cmd_artifacts(args: &Args) {
    let dir = std::path::PathBuf::from(args.get("dir", "artifacts"));
    match magneton::runtime::PjrtRuntime::cpu() {
        Err(e) => println!("PJRT unavailable: {e}"),
        Ok(mut rt) => match rt.load_dir(&dir) {
            Err(e) => println!("no artifacts loaded from {dir:?}: {e}"),
            Ok(n) => {
                println!("loaded {n} artifacts: {:?}", rt.names());
                match magneton::runtime::PjrtMomentEngine::load(&dir) {
                    Ok(eng) => {
                        use magneton::fingerprint::MomentEngine;
                        let mut rng = Prng::new(1);
                        let t = magneton::tensor::Tensor::randn(&mut rng, &[16, 64]);
                        let m = eng.moments(&t, 4);
                        println!("fingerprint kernel smoke: moments = {m:?}");
                    }
                    Err(e) => println!("fingerprint engine: {e}"),
                }
            }
        },
    }
}
