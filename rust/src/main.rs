//! Magneton CLI — the leader entrypoint.
//!
//! ```text
//! magneton cases [--id c10] [--eps 1e-3] [--threshold 0.10]
//! magneton fleet                      # Fig 5 cross-system comparison
//! magneton ddp [--iters 20]           # Fig 4 power timeline
//! magneton breakdown [--id c10]       # Fig 2-style per-op breakdown
//! magneton accuracy                   # Table 4 measurement accuracy
//! magneton artifacts [--dir artifacts]# list loadable PJRT artifacts
//! magneton stream [--requests 500 --arrival poisson|bursty|steady]
//!                 [--snapshot-dir d]  # online serving-stream audit
//!                 [--shard k/M --shard-id host] # one producer shard
//! magneton replay --dir <d> [--follow] # re-render persisted snapshots
//! magneton merge <shard dirs...> [--out d] # combine producer shards
//! magneton dash --dir <d> [--follow]  # live terminal fleet dashboard
//! ```
//!
//! Commands exit non-zero on failure (a missing snapshot/artifact
//! directory, a snapshot that fails verification) so the CLI is
//! scriptable; diagnostics go to stderr, reports to stdout.

use std::path::PathBuf;
use std::process::ExitCode;

use magneton::cases;
use magneton::coordinator::Magneton;
use magneton::energy::DeviceSpec;
use magneton::report;
use magneton::util::cli::Args;
use magneton::util::table::{fmt_joules, Table};
use magneton::util::Prng;

/// Subcommand names, reserved at parse time so a bare flag never
/// swallows one as its value (`magneton --verbose cases`).
const SUBCOMMANDS: &[&str] = &[
    "cases", "fleet", "ddp", "breakdown", "accuracy", "artifacts", "stream", "replay", "merge",
    "diff", "lint", "dash", "help",
];

fn main() -> ExitCode {
    let args = Args::from_env_reserved(SUBCOMMANDS);
    let cmd = args.positional.first().map(String::as_str).unwrap_or("help");
    let result = match cmd {
        "cases" => {
            cmd_cases(&args);
            Ok(())
        }
        "fleet" => {
            cmd_fleet(&args);
            Ok(())
        }
        "ddp" => {
            cmd_ddp(&args);
            Ok(())
        }
        "breakdown" => {
            cmd_breakdown(&args);
            Ok(())
        }
        "accuracy" => {
            cmd_accuracy();
            Ok(())
        }
        "artifacts" => cmd_artifacts(&args),
        "stream" => cmd_stream(&args),
        "replay" => cmd_replay(&args),
        "merge" => cmd_merge(&args),
        "diff" => cmd_diff(&args),
        "lint" => cmd_lint(&args),
        "dash" => cmd_dash(&args),
        "help" => {
            print_help();
            Ok(())
        }
        other => {
            // a typo'd subcommand must not exit 0 — a script gating on
            // `magneton repaly` would otherwise silently skip its check
            print_help();
            Err(magneton::Error::msg(format!("unknown command `{other}`")))
        }
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("magneton {cmd}: {e}");
            ExitCode::FAILURE
        }
    }
}

fn print_help() {
    println!(
        "magneton — differential energy debugging for ML systems\n\n\
         USAGE: magneton <command> [options]\n\n\
         COMMANDS:\n\
         \x20 cases      run known + new case audits (--id cX for one)\n\
         \x20 fleet      cross-system energy comparison (Fig 5)\n\
         \x20 ddp        DDP join-vs-early-exit power timeline (Fig 4)\n\
         \x20 breakdown  per-operator energy breakdown of a case (Fig 2)\n\
         \x20 accuracy   power-measurement accuracy comparison (Table 4)\n\
         \x20 artifacts  list PJRT artifacts and smoke-run the fingerprint kernel\n\
         \x20 stream     online audit of a live serving pair: chunked channel\n\
         \x20            ingestion, request-arrival idle gaps, resync + content\n\
         \x20            guards, rolling window reports, then a streaming fleet;\n\
         \x20            --snapshot-dir <d> persists replayable NDJSON snapshots\n\
         \x20 replay     reload a snapshot directory (--dir <d>) offline:\n\
         \x20            re-render windows, per-pair summaries, fleet ranking and\n\
         \x20            divergence events, and verify the ranking bit-for-bit;\n\
         \x20            --follow tails a live directory instead (rotation-aware,\n\
         \x20            live feed on stderr), quiesces after --idle-ms, then\n\
         \x20            prints the identical post-hoc replay; online invariants\n\
         \x20            (--max-op-j --max-window-waste-pct --max-resyncs-per-min)\n\
         \x20            raise typed alarms, published on --alarm-port; exits\n\
         \x20            non-zero under --deny-alarms if any alarm was raised\n\
         \x20 merge      combine producer-shard snapshot directories (written by\n\
         \x20            `stream --shard k/M`) into one logical session: refuses\n\
         \x20            mixed sessions/configs and duplicate shards, re-ranks the\n\
         \x20            fleet and re-correlates divergences fleet-wide, renders\n\
         \x20            the merged report (bit-identical to an unsharded run),\n\
         \x20            and --out <d> persists it as an ordinary snapshot dir\n\
         \x20 diff       cross-session differential replay: match two persisted\n\
         \x20            sessions (--dir-a/--dir-b) by workload fingerprint, align\n\
         \x20            their windows, and rank per-label energy regressions;\n\
         \x20            exits non-zero above --regress-threshold, refuses\n\
         \x20            non-matching workloads with a diagnostic\n\
         \x20 lint       static energy lint: run the graph-IR analysis passes over\n\
         \x20            every built-in system program (plus a config-lint layer)\n\
         \x20            without spending a joule; ranked findings with cost-model\n\
         \x20            waste estimates; --verify applies each suggested rewrite\n\
         \x20            and A/Bs it through the differential pipeline; --expect\n\
         \x20            <manifest> gates on declared findings; exits non-zero at\n\
         \x20            or above --deny <severity>; --diff adds the static\n\
         \x20            differential audit: match regions between same-family\n\
         \x20            targets (every pair, or --target-a A --target-b B) and\n\
         \x20            rank per-region cost-model deltas without running either;\n\
         \x20            --interact adds the joint config-space interaction search:\n\
         \x20            per dispatch routine, flag-sliced branch-and-bound over all\n\
         \x20            config flags finds 1-minimal flag sets whose joint flip\n\
         \x20            saves energy where no single flip survives the gate,\n\
         \x20            reported as `interact~<target>` pseudo-targets;\n\
         \x20            --json <path> writes the full report machine-readably\n\
         \x20 dash       terminal fleet dashboard over a snapshot directory\n\
         \x20            (--dir <d>): rolling per-pair waste, fleet ranking,\n\
         \x20            divergence feed, and alarm log; --follow re-renders as\n\
         \x20            the stream writes, with the same invariant flags and\n\
         \x20            --deny-alarms gate as `replay --follow`\n\n\
         OPTIONS: --id <case> --eps <f64> --threshold <f64> --seed <u64> --device <h200|rtx4090>\n\
         STREAM:  --requests <n=500> --arrival <poisson|bursty|steady> --rate <hz=200>\n\
         \x20        --burst <n=16> --window <pairs=250> --hop <pairs> --ring <segs=512>\n\
         \x20        --chunk <events=64> --queue <chunks=4> --max-emitted <n=64>\n\
         \x20        --eff <0..1=0.62> --pairs <fleet pairs=3> --snapshot-dir <dir>\n\
         \x20        --session-id <id=stream> --deploy-tag <tag>\n\
         \x20        --shard <k/M> --shard-id <name=shard-k>  (audit only this\n\
         \x20        shard's slice of the fleet; requires --snapshot-dir)\n\
         REPLAY:  --dir <dir=snapshots> --windows <n=12> --no-ranking-ok\n\
         \x20        --follow --poll-ms <n=100> --idle-ms <n=2000> --deny-alarms\n\
         \x20        --max-op-j <J> --max-window-waste-pct <pct>\n\
         \x20        --max-resyncs-per-min <rate> --alarm-port <p> --alarm-queue <n=64>\n\
         DASH:    --dir <dir=snapshots> --follow + the REPLAY invariant flags\n\
         MERGE:   <shard dirs...> or --dir <a,b,c> --out <dir> --windows <n=12>\n\
         \x20        --window <correlate ops=256> --min-pairs <n=2> --partial-ok\n\
         DIFF:    --dir-a <dir> --dir-b <dir> --regress-threshold <frac=0.05>\n\
         \x20        --threshold <frac=0.10> --tolerant --min-overlap <frac=0.8>\n\
         LINT:    --target <name substr> --only <rule> --deny <info|warn|error=error>\n\
         \x20        --expect <manifest> --verify --threads <n> --seed <u64=7>\n\
         \x20        --diff --target-a <name> --target-b <name>\n\
         \x20        --interact --max-joint-flags <n=8> --json <path>\n\
         \x20        --window/--hop/--lookahead/--content-eps (stream-config lint overrides)"
    );
}

fn device(args: &Args) -> DeviceSpec {
    match args.get("device", "h200") {
        "rtx4090" => DeviceSpec::rtx4090_sim(),
        _ => DeviceSpec::h200_sim(),
    }
}

fn magneton(args: &Args) -> Magneton {
    let mut m = Magneton::new(device(args));
    m.eps = args.get_parse("eps", 1e-3);
    m.cfg.energy_threshold = args.get_parse("threshold", 0.10);
    m
}

/// Directory option shared by `artifacts --dir`, `replay --dir`, and
/// `stream --snapshot-dir`: one resolution rule for all of them.
fn dir_arg(args: &Args, key: &str, default: &str) -> PathBuf {
    PathBuf::from(args.get(key, default))
}

fn cmd_cases(args: &Args) {
    let mag = magneton(args);
    let mut rng = Prng::new(args.get_parse("seed", 2026u64));
    let scenarios: Vec<cases::Scenario> = match args.options.get("id") {
        Some(id) => cases::by_id(id).into_iter().collect(),
        None => cases::known_cases().into_iter().chain(cases::new_cases()).collect(),
    };
    for s in scenarios {
        println!("\n##### case {} ({}) — {}", s.id, s.issue, s.description);
        let (a, b) = (s.build)(&mut rng);
        let out = mag.audit(&a, &b);
        println!("{}", report::render_audit(&a.label, &b.label, &out));
        if s.expect_undetected {
            println!(
                "paper expectation: NOT detected (CPU-side issue) — magneton {}",
                if out.detected() { "detected (unexpected)" } else { "correctly silent" }
            );
        }
    }
}

fn cmd_fleet(args: &Args) {
    use magneton::systems::llm;
    let mag = magneton(args);
    let mut rng = Prng::new(args.get_parse("seed", 2026u64));
    let params = llm::TransformerParams::new(&mut rng, llm::LlmSpec::gpt2_sim());
    let mut t = Table::new(vec!["system", "energy", "J/token", "kernels"]);
    let tokens = (params.spec.batch * params.spec.seq) as f64;
    for (name, opts, disp, env) in [
        (
            "mini-hf-transformers",
            llm::LlmBuildOpts::hf(),
            llm::hf_dispatcher(),
            llm::default_env(magneton::systems::SystemId::MiniHf),
        ),
        (
            "mini-vllm",
            llm::LlmBuildOpts::vllm(),
            llm::vllm_dispatcher(),
            llm::default_env(magneton::systems::SystemId::MiniVllm),
        ),
        (
            "mini-sglang",
            llm::LlmBuildOpts::sglang(),
            llm::sglang_dispatcher(),
            llm::default_env(magneton::systems::SystemId::MiniSglang),
        ),
    ] {
        let run = magneton::coordinator::SysRun::new(name, disp, env, llm::build_llm(&params, &opts));
        let arts = mag.run_side(&run);
        t.row(vec![
            name.to_string(),
            fmt_joules(arts.total_energy_j),
            format!("{:.3} mJ", arts.total_energy_j / tokens * 1e3),
            arts.records.len().to_string(),
        ]);
    }
    println!("{}", t.render());
}

fn cmd_ddp(args: &Args) {
    use magneton::workload::{run_ddp, DdpWorkload, SyncStrategy};
    let dev = device(args);
    let mut w = DdpWorkload::paper_setup();
    w.iterations = args.get_parse("iters", 20usize);
    let join = run_ddp(&dev, &w, SyncStrategy::Join, 7);
    let exit = run_ddp(&dev, &w, SyncStrategy::EarlyExit, 7);
    println!(
        "dist.Join: {}   early-exit: {}   saving {:.1}%",
        fmt_joules(join.total_energy_j),
        fmt_joules(exit.total_energy_j),
        (1.0 - exit.total_energy_j / join.total_energy_j) * 100.0
    );
}

fn cmd_breakdown(args: &Args) {
    let mag = magneton(args);
    let mut rng = Prng::new(args.get_parse("seed", 2026u64));
    let id = args.get("id", "c10");
    let Some(s) = cases::by_id(id) else {
        println!("unknown case {id}");
        return;
    };
    let (a, b) = (s.build)(&mut rng);
    for (label, run) in [(&a.label, &a), (&b.label, &b)] {
        let arts = mag.run_side(run);
        println!("\n--- {label}: total {} ---", fmt_joules(arts.total_energy_j));
        println!("{}", report::energy_breakdown(&arts, 5).render());
    }
}

fn cmd_accuracy() {
    // Table 4 lives in benches/table4_accuracy.rs; here a quick preview
    println!("run `cargo bench --bench table4_accuracy` for the full table");
}

/// Online streaming audit: two producer threads execute a serving pair
/// and ship `(KernelRecord, Segment)` events in bounded chunks over
/// `sync_channel`s (the backpressure knob: at most `queue × chunk`
/// events are in flight per side); the consumer pairs them through a
/// `StreamAuditor`, materialising request-arrival idle gaps, printing
/// every rolling window report, and finishing with a streaming fleet
/// over N concurrent pairs under the same arrival process. With
/// `--snapshot-dir <d>`, every window, resync, and summary — plus the
/// fleet ranking and divergence events — are persisted as replayable
/// NDJSON snapshots (`magneton replay --dir <d>`).
fn cmd_stream(args: &Args) -> magneton::Result<()> {
    use magneton::coordinator::fleet::{drive_pair_with_arrivals, StreamFleet};
    use magneton::coordinator::SysRun;
    use magneton::dispatch::Env;
    use magneton::energy::Segment;
    use magneton::exec::{Executor, KernelRecord};
    use magneton::stream::{workload_sig_of_program, StreamAuditor, StreamConfig};
    use magneton::telemetry::{SessionHeader, SinkConfig, SnapshotSink};
    use magneton::workload::{serving_dispatcher, serving_stream_program, ArrivalProcess, ServingStream};
    use std::sync::mpsc;
    use std::thread;

    let device = device(args);
    let requests: usize = args.get_parse("requests", 500usize);
    let rate: f64 = args.get_parse("rate", 200.0f64);
    let burst: usize = args.get_parse("burst", 16usize);
    let arrival_kind = args.get("arrival", "poisson");
    let Some(arrival) = ArrivalProcess::parse(arrival_kind, rate, burst) else {
        return Err(magneton::Error::msg(format!(
            "unknown arrival process `{arrival_kind}` (expected steady|poisson|bursty)"
        )));
    };
    let spec = ServingStream { requests, ..Default::default() };
    let chunk_len: usize = args.get_parse("chunk", 64usize).max(1);
    let queue: usize = args.get_parse("queue", 4usize).max(1);
    // clamp user input rather than panic on the auditor's internal
    // asserts: window/ring must be positive, hop > window would leak
    // pairs out of the waste ledger
    let window_ops = args.get_parse("window", 250usize).max(1);
    let mut cfg = StreamConfig {
        window_ops,
        hop_ops: args.get_parse("hop", window_ops).clamp(1, window_ops),
        ring_cap: args.get_parse("ring", 512usize).max(1),
        max_emitted: args.get_parse("max-emitted", 64usize),
        ..StreamConfig::default()
    };
    // the consumer ingests chunk-by-chunk, so inter-side skew is
    // bounded by one chunk; keep pending headroom over it
    cfg.max_pending = cfg.max_pending.max(2 * chunk_len);
    let seed: u64 = args.get_parse("seed", 2026u64);
    let eff: f64 = args.get_parse("eff", 0.62f64);
    let snapshot_dir = args.options.get("snapshot-dir").map(PathBuf::from);
    // session identity for cross-session matching (`magneton diff`):
    // free-form, stamped into every sink's SessionHeader
    let session_id = args.get("session-id", "stream").to_string();
    let deploy_tag = args.get("deploy-tag", "").to_string();
    // producer-shard mode: `--shard k/M` audits only this process's
    // slice of the fleet pairs; `magneton merge` recombines the shard
    // directories into the unsharded session bit-for-bit
    let shard = match args.options.get("shard") {
        Some(spec) => {
            let parsed = spec.split_once('/').and_then(|(k, m)| {
                let k: usize = k.trim().parse().ok()?;
                let m: usize = m.trim().parse().ok()?;
                (k >= 1 && k <= m).then_some((k - 1, m))
            });
            match parsed {
                Some(p) => Some(p),
                None => {
                    return Err(magneton::Error::msg(format!(
                        "bad --shard `{spec}`: expected k/M with 1 <= k <= M (e.g. --shard 2/4)"
                    )))
                }
            }
        }
        None => None,
    };
    if shard.is_some() && snapshot_dir.is_none() {
        return Err(magneton::Error::msg(
            "--shard requires --snapshot-dir: a producer shard exists to persist its slice \
             for `magneton merge`",
        ));
    }

    println!(
        "magneton stream: {} requests ({} kernel ops/side), {:?} arrivals,\n\
         window {} pairs, ring {} segments, chunks of {} over a {}-deep channel\n",
        spec.requests,
        spec.kernel_ops(),
        arrival,
        cfg.window_ops,
        cfg.ring_cap,
        chunk_len,
        queue
    );

    let ops_per_request = spec.ops_per_request();
    // The single-pair channel stage runs only unsharded: it audits one
    // process-local pair, so M shard invocations would persist M copies
    // of it and the merged directory could never match an unsharded
    // run. Sharded producers write exactly their fleet slice — an
    // unsharded reference for merge comparisons is `--shard 1/1`.
    let pair_sink_errors = if shard.is_some() {
        0
    } else {
        let spawn_side = |side_eff: f64| -> (mpsc::Receiver<Vec<(KernelRecord, Segment)>>, thread::JoinHandle<()>) {
            let (tx, rx) = mpsc::sync_channel::<Vec<(KernelRecord, Segment)>>(queue);
            let dev = device.clone();
            let handle = thread::spawn(move || {
                let mut rng = Prng::new(seed);
                let prog = serving_stream_program(&mut rng, &spec);
                let mut exec = Executor::new(dev, serving_dispatcher(side_eff), Env::new());
                exec.opts.content_sketch = true;
                let stream = exec.stream(&prog);
                let mut chunk = Vec::with_capacity(chunk_len);
                for ev in stream {
                    chunk.push(ev);
                    if chunk.len() == chunk_len {
                        if tx.send(std::mem::take(&mut chunk)).is_err() {
                            return; // consumer hung up
                        }
                        chunk.reserve(chunk_len);
                    }
                }
                if !chunk.is_empty() {
                    let _ = tx.send(chunk);
                }
            });
            (rx, handle)
        };
        let (rx_a, handle_a) = spawn_side(eff);
        let (rx_b, handle_b) = spawn_side(1.0);

        // the consumer: the one shared pairing protocol, fed by iterators
        // that drain the chunked channels (recv blocks = backpressure)
        let mut aud = StreamAuditor::new(cfg.clone(), device.idle_w);
        let pair_name = "inefficient-vs-optimal";
        if let Some(dir) = &snapshot_dir {
            let sink = SnapshotSink::new(dir.clone(), "pair-inefficient-vs-optimal", SinkConfig::default())
                .map_err(|e| e.context("snapshot sink"))?;
            // the session header is computed statically from the program
            // the producers will execute, so it lands first in the series
            let mut sig_rng = Prng::new(seed);
            let sig = workload_sig_of_program(&serving_stream_program(&mut sig_rng, &spec));
            aud.set_session_header(SessionHeader::new(
                &session_id,
                &deploy_tag,
                pair_name,
                &sig,
                &arrival.describe(),
                cfg.digest(),
            ));
            aud.set_sink(pair_name, sink);
        }
        let mut arrival_rng = Prng::new(seed ^ 0xa441_b815);
        let summary = drive_pair_with_arrivals(
            &mut aud,
            rx_a.into_iter().flatten(),
            rx_b.into_iter().flatten(),
            arrival,
            ops_per_request,
            &mut arrival_rng,
            |w| println!("{}", report::render_window(&w)),
        );
        handle_a.join().expect("producer A panicked");
        handle_b.join().expect("producer B panicked");
        // remembered and failed at the end (after the reports render), so a
        // full disk cannot silently produce a truncated snapshot directory
        let pair_sink_errors = aud.sink_errors();
        if pair_sink_errors > 0 {
            eprintln!("warning: {pair_sink_errors} snapshot writes failed");
        }
        if let (Some(wa), Some(wb)) = (aud.nvml_reading_a(), aud.nvml_reading_b()) {
            println!("\nlive NVML counters: A {wa:.0} W, B {wb:.0} W (arrival lulls read through the rings)");
        }
        println!();
        print!("{}", report::render_stream(pair_name, &summary));
        pair_sink_errors
    };

    // final stage: a streaming fleet over N concurrent serving pairs
    // under the same arrival process (sharded: this shard's slice of
    // the same fleet, under fleet-global pair indices and seeds)
    let fleet_pairs: usize = args.get_parse("pairs", 3usize);
    let (pair_lo, pair_hi) = match shard {
        Some((idx, count)) => {
            let per_shard = fleet_pairs.div_ceil(count);
            ((idx * per_shard).min(fleet_pairs), ((idx + 1) * per_shard).min(fleet_pairs))
        }
        None => (0, fleet_pairs),
    };
    if let Some((idx, count)) = shard {
        // an empty slice would persist a directory with no session
        // header, which `magneton merge` rightly refuses — fail the
        // producer up front instead
        if pair_lo >= pair_hi {
            return Err(magneton::Error::msg(format!(
                "--shard {}/{} has no pairs to audit: the fleet has only {fleet_pairs} pairs \
                 (raise --pairs or lower the shard count)",
                idx + 1,
                count
            )));
        }
    }
    let mut fleet = StreamFleet::new(device);
    fleet.cfg = cfg;
    fleet.arrival = arrival;
    fleet.ops_per_request = ops_per_request;
    fleet.arrival_seed = seed;
    fleet.snapshot_dir = snapshot_dir.clone();
    fleet.session_id = snapshot_dir.as_ref().map(|_| session_id.clone());
    fleet.deploy_tag = deploy_tag.clone();
    if let Some((idx, count)) = shard {
        fleet.pair_index_base = pair_lo;
        fleet.shard_index = idx;
        fleet.shard_count = count;
        fleet.shard_id = match args.options.get("shard-id") {
            Some(id) => id.clone(),
            None => format!("shard-{}", idx + 1),
        };
    }
    let fleet_spec = ServingStream { requests: (requests / 5).max(20), ..spec };
    for i in pair_lo..pair_hi {
        let pair_eff = if i % 2 == 0 { eff } else { 1.0 };
        let mut ra = Prng::new(seed + 1 + i as u64);
        let mut rb = Prng::new(seed + 1 + i as u64);
        fleet.add_pair(
            &format!("serving-{i}"),
            SysRun::new("sys-a", serving_dispatcher(pair_eff), Env::new(), serving_stream_program(&mut ra, &fleet_spec)),
            SysRun::new("sys-b", serving_dispatcher(1.0), Env::new(), serving_stream_program(&mut rb, &fleet_spec)),
        );
    }
    match shard {
        Some((idx, count)) => println!(
            "\nstreaming fleet shard {}/{} (`{}`): pairs {}..{} of {} x {} ops under {:?} \
             arrivals over {} workers...",
            idx + 1,
            count,
            fleet.shard_id,
            pair_lo,
            pair_hi,
            fleet_pairs,
            fleet_spec.kernel_ops(),
            arrival,
            fleet.workers
        ),
        None => println!(
            "\nstreaming fleet: {} pairs x {} ops under {:?} arrivals over {} workers...",
            fleet.len(),
            fleet_spec.kernel_ops(),
            arrival,
            fleet.workers
        ),
    }
    let r = fleet.run();
    print!("{}", report::render_stream_fleet(&r));
    if pair_sink_errors + r.snapshot_errors > 0 {
        let msg = format!(
            "{} snapshot writes failed ({pair_sink_errors} single-pair, {} fleet)",
            pair_sink_errors + r.snapshot_errors,
            r.snapshot_errors
        );
        return Err(magneton::Error::msg(msg));
    }
    if let Some(dir) = &snapshot_dir {
        println!(
            "\nsnapshots persisted under {} — replay with `magneton replay --dir {}`",
            dir.display(),
            dir.display()
        );
    }
    Ok(())
}

/// Offline replay of a snapshot directory: re-render the persisted
/// windows, resyncs, per-pair summaries, fleet ranking, and divergence
/// events, then verify the ranking reproduces the per-pair waste
/// ledgers bit-for-bit (non-zero exit on mismatch, so CI can gate on
/// it).
fn cmd_replay(args: &Args) -> magneton::Result<()> {
    use magneton::telemetry::Replay;
    if args.flag("follow") {
        return cmd_replay_follow(args);
    }
    let dir = dir_arg(args, "dir", "snapshots");
    let replay = Replay::load(&dir)?;
    println!(
        "replaying {}: {} windows, {} resyncs, {} summaries, {} rankings, {} divergences\n",
        dir.display(),
        replay.windows.len(),
        replay.resyncs.len(),
        replay.summaries.len(),
        replay.rankings.len(),
        replay.divergences.len()
    );
    if replay.windows.is_empty() && replay.summaries.is_empty() {
        return Err(magneton::Error::msg(format!("no snapshots found under {}", dir.display())));
    }
    print_replay_body(&replay, args)?;
    deny_alarms_gate(args, replay.alarms.len())
}

/// The operator-declared online invariants, parsed from the shared
/// `--max-op-j` / `--max-window-waste-pct` / `--max-resyncs-per-min`
/// flags (`replay --follow` and `dash`).
fn invariants_from(args: &Args) -> magneton::Result<Vec<magneton::dash::Invariant>> {
    use magneton::dash::Invariant;
    let mut v = Vec::new();
    for (key, mk) in [
        ("max-op-j", Invariant::MaxOpJ as fn(f64) -> Invariant),
        ("max-window-waste-pct", Invariant::MaxWindowWastePct as fn(f64) -> Invariant),
        ("max-resyncs-per-min", Invariant::MaxResyncsPerMin as fn(f64) -> Invariant),
    ] {
        if let Some(raw) = args.options.get(key) {
            let limit: f64 = raw.parse().map_err(|_| {
                magneton::Error::msg(format!("--{key} expects a number, got `{raw}`"))
            })?;
            v.push(mk(limit));
        }
    }
    Ok(v)
}

/// Optional TCP alarm feed (`--alarm-port <p>`, 0 for ephemeral), with
/// a bounded per-subscriber queue (`--alarm-queue <n>`).
fn alarm_publisher(args: &Args) -> magneton::Result<Option<magneton::dash::AlarmPublisher>> {
    let Some(port) = args.options.get("alarm-port") else { return Ok(None) };
    let publisher = magneton::dash::AlarmPublisher::new(args.get_parse("alarm-queue", 64usize));
    let bound = publisher.serve(&format!("127.0.0.1:{port}"))?;
    eprintln!("alarm feed listening on 127.0.0.1:{bound}");
    Ok(Some(publisher))
}

/// The `--deny-alarms` CI gate, shared by `replay` and `dash`.
fn deny_alarms_gate(args: &Args, alarms: usize) -> magneton::Result<()> {
    if args.flag("deny-alarms") && alarms > 0 {
        return Err(magneton::Error::msg(format!(
            "{alarms} invariant alarm(s) raised (--deny-alarms)"
        )));
    }
    Ok(())
}

/// `magneton replay --follow`: tail a live snapshot directory through
/// the rotation-aware follower, stream windows/resyncs/divergences and
/// invariant alarms to *stderr* as they land, and — once the directory
/// has been quiet for `--idle-ms` — print the canonical replay to
/// stdout, byte-identical to what `magneton replay --dir <d>` prints
/// for the completed directory (asserted in `tests/follow.rs` and the
/// CI dash smoke).
fn cmd_replay_follow(args: &Args) -> magneton::Result<()> {
    use magneton::dash::Monitor;
    use magneton::telemetry::follow::Follower;
    use magneton::telemetry::Snapshot;
    let dir = dir_arg(args, "dir", "snapshots");
    let poll_ms: u64 = args.get_parse("poll-ms", 100u64);
    let idle_ms: u64 = args.get_parse("idle-ms", 2000u64);
    let mut monitor = Monitor::new(invariants_from(args)?);
    let mut publisher = alarm_publisher(args)?;
    let mut follower = Follower::new(&dir);
    let mut idle = 0u64;
    loop {
        let fresh = follower.poll()?;
        if fresh.is_empty() {
            if idle >= idle_ms {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(poll_ms));
            idle += poll_ms;
            continue;
        }
        idle = 0;
        for snap in &fresh {
            // the live feed goes to stderr so stdout stays the
            // canonical (byte-comparable) replay
            match snap {
                Snapshot::Window { pair, report } => {
                    eprintln!("[{pair}] {}", report::render_window(report));
                }
                Snapshot::Resync { pair, event } => {
                    eprintln!(
                        "[{pair}] resync at op {}: skipped {} (A) + {} (B)",
                        event.at_ops, event.skipped_a, event.skipped_b
                    );
                }
                Snapshot::Divergence { event } => {
                    eprintln!("{}", report::render_divergence(event));
                }
                Snapshot::Alarm { alarm } => eprintln!("{}", report::render_alarm(alarm)),
                _ => {}
            }
            for alarm in monitor.observe(snap) {
                eprintln!("{}", report::render_alarm(&alarm));
                if let Some(p) = publisher.as_mut() {
                    p.publish(&Snapshot::Alarm { alarm }.to_line());
                }
            }
        }
    }
    if let Some(p) = &publisher {
        if p.dropped > 0 {
            eprintln!("alarm feed: {} line(s) dropped on stalled subscribers", p.dropped);
        }
    }
    if follower.reanchors + follower.vanished > 0 {
        eprintln!(
            "follow: re-anchored {} time(s), {} file(s) vanished before open (rotation races \
             survived; snapshots consumed before a drop are retained)",
            follower.reanchors, follower.vanished
        );
    }
    let live_alarms = monitor.alarms.len();
    let replay = follower.into_replay();
    println!(
        "replaying {}: {} windows, {} resyncs, {} summaries, {} rankings, {} divergences\n",
        dir.display(),
        replay.windows.len(),
        replay.resyncs.len(),
        replay.summaries.len(),
        replay.rankings.len(),
        replay.divergences.len()
    );
    if replay.windows.is_empty() && replay.summaries.is_empty() {
        return Err(magneton::Error::msg(format!("no snapshots found under {}", dir.display())));
    }
    print_replay_body(&replay, args)?;
    deny_alarms_gate(args, live_alarms + replay.alarms.len())
}

/// Shared rendering of a loaded [`Replay`](magneton::telemetry::Replay):
/// session lines, persisted windows (elided to `--windows`), resyncs,
/// per-pair summaries, divergence events, fleet rankings, the
/// no-ranking gate, and the bit-for-bit verification gate. Both
/// `magneton replay` and `magneton merge` print exactly one headline
/// line (with a trailing blank line) before this body, so their
/// outputs are byte-comparable from the second line on — the CI merge
/// smoke relies on that to prove sharded == unsharded.
fn print_replay_body(replay: &magneton::telemetry::Replay, args: &Args) -> magneton::Result<()> {
    for h in &replay.sessions {
        println!(
            "session {} [{}] scope {}: workload {:016x} ({} ops, {} arrivals)",
            h.session_id, h.deploy_tag, h.scope, h.workload_fp, h.total_ops, h.arrival
        );
    }
    if !replay.sessions.is_empty() {
        println!();
    }
    let max_windows: usize = args.get_parse("windows", 12usize);
    let skip = replay.windows.len().saturating_sub(max_windows);
    if skip > 0 {
        println!("... {skip} earlier windows elided (raise with --windows <n>)");
    }
    for (pair, w) in replay.windows.iter().skip(skip) {
        println!("[{pair}] {}", report::render_window(w));
    }
    for (pair, ev) in &replay.resyncs {
        println!(
            "[{pair}] resync at op {}: skipped {} (A) + {} (B)",
            ev.at_ops, ev.skipped_a, ev.skipped_b
        );
    }
    for (pair, s) in &replay.summaries {
        println!();
        print!("{}", report::render_stream(pair, s));
    }
    if !replay.divergences.is_empty() {
        println!();
        for d in &replay.divergences {
            println!("{}", report::render_divergence(d));
        }
    }
    if !replay.alarms.is_empty() {
        println!();
        for a in &replay.alarms {
            println!("{}", report::render_alarm(a));
        }
    }
    for ranking in &replay.rankings {
        println!("\npersisted fleet ranking:");
        print!("{}", report::render_ranking(ranking));
    }
    // a directory with summaries but no ranking is an interrupted or
    // truncated fleet run — exactly what the verification gate exists
    // to catch, so it must not pass vacuously (`--no-ranking-ok`
    // accepts directories written by a bare StreamAuditor sink, which
    // never produces a fleet ranking)
    if replay.rankings.is_empty() && !args.flag("no-ranking-ok") {
        return Err(magneton::Error::msg(
            "no fleet ranking snapshot found: the fleet stage never persisted its ranking \
             (interrupted run or truncated directory); pass --no-ranking-ok for directories \
             written without a fleet",
        ));
    }
    match replay.verify_ranking() {
        Ok(n) => {
            println!("\nreplay verified: {n} ranking entries reproduce their pair summaries bit-for-bit");
            Ok(())
        }
        Err(e) => Err(magneton::Error::msg(format!(
            "persisted ranking does not reproduce the summaries: {e}"
        ))),
    }
}

/// Merge coordinator: load producer-shard snapshot directories by
/// their session headers, refuse mixed sessions / config digests /
/// overlapping pair scopes with reasoned diagnostics, recombine the
/// shards into the unsharded session (bit-for-bit — see
/// `telemetry::merge`), re-run fleet divergence correlation across all
/// shards, render the merged report through the same body as
/// `magneton replay`, and optionally persist the merged directory with
/// `--out`.
fn cmd_merge(args: &Args) -> magneton::Result<()> {
    use magneton::telemetry::merge::{merge_shards, MergeConfig};
    let mut dirs: Vec<PathBuf> = args.positional.iter().skip(1).map(PathBuf::from).collect();
    if let Some(list) = args.options.get("dir") {
        dirs.extend(list.split(',').map(str::trim).filter(|d| !d.is_empty()).map(PathBuf::from));
    }
    if dirs.is_empty() {
        return Err(magneton::Error::msg(
            "no shard directories given: pass them positionally (`magneton merge a/ b/`) or \
             comma-separated via --dir a,b",
        ));
    }
    let cfg = MergeConfig {
        correlate_window_ops: args.get_parse("window", 256usize),
        correlate_min: args.get_parse("min-pairs", 2usize),
        allow_partial: args.flag("partial-ok"),
    };
    let merged = merge_shards(&dirs, &cfg)?;
    // the shard inventory and damage accounting go to stderr so stdout
    // stays byte-comparable with `magneton replay` of an unsharded run
    for s in &merged.shards {
        eprintln!(
            "shard {}/{} `{}` ({}): {} pairs, {} snapshots in {} files{}{}{}",
            s.shard_index + 1,
            s.shard_count,
            s.shard_id,
            s.dir.display(),
            s.pairs,
            s.snapshots,
            s.files,
            if s.torn_fragments > 0 {
                format!(", {} torn fragment(s) skipped", s.torn_fragments)
            } else {
                String::new()
            },
            if s.missing_rotations > 0 {
                format!(", {} missing rotation file(s)", s.missing_rotations)
            } else {
                String::new()
            },
            if s.vanished > 0 {
                format!(", {} file(s) vanished mid-scan", s.vanished)
            } else {
                String::new()
            },
        );
    }
    if merged.torn_fragments + merged.missing_rotations + merged.vanished > 0 {
        eprintln!(
            "warning: merged with damage: {} torn fragment(s), {} missing rotation file(s), \
             {} vanished mid-scan — attribution for undamaged pairs is unaffected",
            merged.torn_fragments, merged.missing_rotations, merged.vanished
        );
    }
    println!(
        "merged {} shards of session {}: {} windows, {} resyncs, {} summaries, {} rankings, {} divergences\n",
        merged.shards.len(),
        merged.session_id,
        merged.replay.windows.len(),
        merged.replay.resyncs.len(),
        merged.replay.summaries.len(),
        merged.replay.rankings.len(),
        merged.replay.divergences.len()
    );
    print_replay_body(&merged.replay, args)?;
    if let Some(out) = args.options.get("out") {
        let out = PathBuf::from(out);
        let written = merged.persist(&out)?;
        eprintln!(
            "merged session persisted under {} ({written} snapshots) — replay with \
             `magneton replay --dir {}`",
            out.display(),
            out.display()
        );
    }
    Ok(())
}

/// Terminal fleet dashboard over a snapshot directory: rolling
/// per-pair waste, fleet ranking, divergence feed, and alarm log —
/// one frame over the directory as it stands, or (with `--follow`) a
/// frame per batch of fresh snapshots until the stream quiesces. The
/// same invariant flags as `replay --follow` run online; `--deny-alarms`
/// turns any violation into a non-zero exit.
fn cmd_dash(args: &Args) -> magneton::Result<()> {
    use magneton::dash::{DashState, Monitor};
    use magneton::telemetry::follow::Follower;
    use magneton::telemetry::Snapshot;
    let dir = dir_arg(args, "dir", "snapshots");
    let follow = args.flag("follow");
    let poll_ms: u64 = args.get_parse("poll-ms", 200u64);
    let idle_ms: u64 = args.get_parse("idle-ms", 2000u64);
    let mut monitor = Monitor::new(invariants_from(args)?);
    let mut publisher = alarm_publisher(args)?;
    let mut state = DashState::new();
    let mut follower = Follower::new(&dir);
    let mut idle = 0u64;
    loop {
        let fresh = follower.poll()?;
        if fresh.is_empty() {
            if !follow || idle >= idle_ms {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(poll_ms));
            idle += poll_ms;
            continue;
        }
        idle = 0;
        for snap in &fresh {
            state.observe(snap);
            for alarm in monitor.observe(snap) {
                eprintln!("{}", report::render_alarm(&alarm));
                let snap = Snapshot::Alarm { alarm };
                if let Some(p) = publisher.as_mut() {
                    p.publish(&snap.to_line());
                }
                state.observe(&snap);
            }
        }
        if follow {
            println!("{}", report::render_dash(&state));
        }
    }
    if state.pairs.is_empty() {
        return Err(magneton::Error::msg(format!(
            "no snapshots found under {} (is the stream writing there yet?)",
            dir.display()
        )));
    }
    if !follow {
        print!("{}", report::render_dash(&state));
    }
    if let Some(p) = &publisher {
        if p.dropped > 0 {
            eprintln!("alarm feed: {} line(s) dropped on stalled subscribers", p.dropped);
        }
    }
    deny_alarms_gate(args, state.alarms.len())
}

/// Cross-session differential replay: load two persisted sessions,
/// refuse them unless their workload fingerprints match (exactly, or
/// tolerantly on label-multiset overlap with `--tolerant`), align their
/// persisted windows, run the differential detector over the paired
/// per-label ledgers, and render the ranked regression report. Exits
/// non-zero when the regression exceeds `--regress-threshold` — the CI
/// gate for "this deploy wastes more energy on the same workload".
fn cmd_diff(args: &Args) -> magneton::Result<()> {
    use magneton::telemetry::session::{diff_sessions, DiffConfig, MatchMode, SessionInfo};
    let Some(dir_a) = args.options.get("dir-a") else {
        return Err(magneton::Error::msg("missing --dir-a <snapshot dir of session A>"));
    };
    let Some(dir_b) = args.options.get("dir-b") else {
        return Err(magneton::Error::msg("missing --dir-b <snapshot dir of session B>"));
    };
    let a = SessionInfo::load(&PathBuf::from(dir_a))?;
    let b = SessionInfo::load(&PathBuf::from(dir_b))?;
    let mode = if args.flag("tolerant") {
        MatchMode::Tolerant { min_overlap: args.get_parse("min-overlap", 0.8f64) }
    } else {
        MatchMode::Exact
    };
    let cfg = DiffConfig {
        mode,
        energy_threshold: args.get_parse("threshold", 0.10f64),
        ..DiffConfig::default()
    };
    // refusal of incomparable sessions surfaces here as a non-zero
    // exit carrying the match diagnostic
    let diff = diff_sessions(&a, &b, &cfg)?;
    print!("{}", report::render_session_diff(&diff));
    let regress: f64 = args.get_parse("regress-threshold", 0.05f64);
    if diff.regressed(regress) {
        return Err(magneton::Error::msg(format!(
            "energy regression above threshold: session {:+.1}% overall, worst label {:+.1}% \
             (threshold {:.1}%)",
            diff.total_delta_frac() * 100.0,
            diff.max_regression_frac() * 100.0,
            regress * 100.0
        )));
    }
    println!(
        "\nno regression above {:.1}%: session delta {:+.1}%, worst label {:+.1}%",
        regress * 100.0,
        diff.total_delta_frac() * 100.0,
        diff.max_regression_frac() * 100.0
    );
    Ok(())
}

/// Static energy lint: run the analysis passes over every built-in
/// system program (and the known-case graphs the rules are expected to
/// rediscover) without executing anything, then optionally `--verify`
/// each suggested rewrite by A/B-ing original vs fixed program through
/// the differential pipeline, `--expect <manifest>` to gate on declared
/// findings, and `--deny <severity>` to make findings fail the build.
/// `--diff` adds the static differential audit: regions of same-family
/// targets are matched (hash, then label, then coarse-bucket, then
/// fuzzy tier) and their cost-model bills diffed into ranked `diff~a~b`
/// pseudo-targets the same manifest/deny machinery gates. `--interact`
/// adds the joint config-space interaction search (`interact~<target>`
/// pseudo-targets with 1-minimal flag-set diagnoses), and `--json
/// <path>` writes the whole report machine-readably.
fn cmd_lint(args: &Args) -> magneton::Result<()> {
    use magneton::analysis::{
        builtin_targets, check_manifest, diff_suite, diff_targets, gate_manifest, interact_name,
        interact_suite, lint_detect_config, lint_stream_config, lint_suite, parse_manifest,
        rule_names, sort_findings, verify_finding, InteractConfig, Severity, StaticDiffConfig,
        TargetReport,
    };
    use magneton::detect::DetectConfig;
    use magneton::stream::StreamConfig;

    let dev = device(args);
    let seed: u64 = args.get_parse("seed", 7u64);
    let threads: usize = args.get_parse("threads", magneton::util::pool::default_threads());
    let deny_name = args.get("deny", "error");
    let Some(deny) = Severity::parse(deny_name) else {
        return Err(magneton::Error::msg(format!(
            "unknown severity `{deny_name}` (expected info|warn|error)"
        )));
    };
    // reject typo'd rule names up front: `--only redundnat-sync` used
    // to silently lint nothing and exit 0
    if let Some(rule) = args.options.get("only") {
        let valid = rule_names();
        if !valid.contains(&rule.as_str()) {
            return Err(magneton::Error::msg(format!(
                "unknown rule `{rule}` for --only (valid rules: {})",
                valid.join(", ")
            )));
        }
    }
    let mut targets = builtin_targets(seed);
    if let Some(filter) = args.options.get("target") {
        targets.retain(|t| t.name.contains(filter.as_str()));
        if targets.is_empty() {
            return Err(magneton::Error::msg(format!("no lint target matches `{filter}`")));
        }
    }
    let mut rep = lint_suite(&targets, &dev, threads);
    if let Some(rule) = args.options.get("only") {
        for t in &mut rep.targets {
            t.findings.retain(|f| f.rule == rule.as_str());
        }
    }
    // config-lint layer: the stream/detect configs the CLI would run
    // with (overridable, so foot-guns are demonstrable: `--window 100
    // --hop 200` must fail the deny gate)
    let window = args.get_parse("window", StreamConfig::default().window_ops);
    let scfg = StreamConfig {
        window_ops: window,
        hop_ops: args.get_parse("hop", window),
        resync_lookahead: args
            .get_parse("lookahead", StreamConfig::default().resync_lookahead),
        content_eps: args.get_parse("content-eps", StreamConfig::default().content_eps),
        ..StreamConfig::default()
    };
    let dcfg = DetectConfig {
        energy_threshold: args.get_parse("threshold", DetectConfig::default().energy_threshold),
        ..DetectConfig::default()
    };
    let mut cfg_findings = lint_stream_config(&scfg);
    cfg_findings.extend(lint_detect_config(&dcfg));
    sort_findings(&mut cfg_findings);
    if !cfg_findings.is_empty() {
        rep.targets.insert(
            0,
            TargetReport {
                name: "config".into(),
                nodes: 0,
                static_j: 0.0,
                findings: cfg_findings,
                error: None,
                interactions: vec![],
            },
        );
    }
    // joint config-space interaction search: each target's
    // `interact~<name>` pseudo-target carries the 1-minimal flag-set
    // diagnoses, so render_lint shows the marginal-vs-joint breakdown
    // and --expect/--deny/--verify gate them with the same machinery
    if args.flag("interact") {
        let icfg = InteractConfig { max_joint_flags: args.get_parse("max-joint-flags", 8usize) };
        for ir in interact_suite(&targets, &dev, threads, &icfg) {
            let mut tr = ir.to_target_report();
            if let Some(rule) = args.options.get("only") {
                tr.findings.retain(|f| f.rule == rule.as_str());
            }
            rep.targets.push(tr);
        }
    }
    rep.total_findings = rep.targets.iter().map(|t| t.findings.len()).sum();
    rep.total_est_wasted_j =
        rep.targets.iter().flat_map(|t| &t.findings).map(|f| f.est_wasted_j).sum();
    print!("{}", report::render_lint(&rep));

    // static differential audit: match regions between same-family
    // targets and rank the cost-model deltas; each pair's findings join
    // the report as a `diff~a~b` pseudo-target so `--expect`/`--deny`
    // gate them with the same machinery
    let diff_cfg = StaticDiffConfig::default();
    if args.flag("diff") {
        let diffs = match (args.options.get("target-a"), args.options.get("target-b")) {
            (Some(a), Some(b)) => {
                let pick = |name: &String| {
                    targets.iter().find(|t| t.name == name.as_str()).ok_or_else(|| {
                        magneton::Error::msg(format!("no lint target named `{name}`"))
                    })
                };
                vec![diff_targets(pick(a)?, pick(b)?, &dev, &diff_cfg)?]
            }
            (None, None) => diff_suite(&targets, &dev, threads, &diff_cfg),
            _ => {
                return Err(magneton::Error::msg(
                    "--target-a and --target-b must be passed together \
                     (or neither, to diff every same-family pair)",
                ))
            }
        };
        for d in &diffs {
            println!();
            print!("{}", report::render_static_diff(d));
        }
        if let Some(d) = diffs.iter().find(|d| d.error.is_some()) {
            return Err(magneton::Error::msg(format!(
                "static diff {} vs {}: {}",
                d.target_a,
                d.target_b,
                d.error.clone().unwrap_or_default()
            )));
        }
        for d in &diffs {
            let mut tr = d.to_target_report(&diff_cfg);
            if let Some(rule) = args.options.get("only") {
                tr.findings.retain(|f| f.rule == rule.as_str());
            }
            rep.targets.push(tr);
        }
        rep.total_findings = rep.targets.iter().map(|t| t.findings.len()).sum();
        rep.total_est_wasted_j =
            rep.targets.iter().flat_map(|t| &t.findings).map(|f| f.est_wasted_j).sum();
    }

    // machine-readable escape hatch: the full report (findings, rewrite
    // steps, interaction diagnoses) as lossless JSON, written after all
    // pseudo-targets joined so nothing rendered above is missing
    if let Some(path) = args.options.get("json") {
        std::fs::write(path, report::lint_report_json(&rep).render())
            .map_err(|e| magneton::Error::msg(format!("writing --json {path}: {e}")))?;
        eprintln!("lint report written to {path}");
    }

    if let Some(path) = args.options.get("expect") {
        let text = std::fs::read_to_string(path)
            .map_err(|e| magneton::Error::msg(format!("reading manifest {path}: {e}")))?;
        let expected = parse_manifest(&text)?;
        // pseudo-target families only exist behind their flag; a plain
        // lint run must not fail on (or vacuously require) them
        let expected = gate_manifest(
            expected,
            &[("diff~", args.flag("diff")), ("interact~", args.flag("interact"))],
        );
        let unmet = check_manifest(&rep, &expected);
        if !unmet.is_empty() {
            let missing: Vec<String> = unmet
                .iter()
                .map(|e| format!("{} {} ~{}", e.target, e.rule, e.label_substr))
                .collect();
            return Err(magneton::Error::msg(format!(
                "manifest {path}: {}/{} expected findings missing: {}",
                unmet.len(),
                expected.len(),
                missing.join("; ")
            )));
        }
        println!("\nmanifest: all {} expected findings present", expected.len());
    }

    if args.flag("verify") {
        // measure-after-fix: for each target, apply the top rewritable
        // finding and A/B it against the original program
        println!();
        let mut checked = 0usize;
        let mut disagreed = 0usize;
        for t in &targets {
            // a target's rewritable findings may live on its plain
            // report or (under --interact) its interact~ pseudo-target
            let Some(f) = rep
                .targets
                .iter()
                .filter(|r| r.name == t.name || r.name == interact_name(&t.name))
                .flat_map(|r| r.findings.iter())
                .find(|f| !f.steps.is_empty())
            else {
                continue;
            };
            let v = verify_finding(&t.run, f, &dev)?;
            checked += 1;
            if !v.same_sign {
                disagreed += 1;
            }
            print!("{}", report::render_verify(&v));
        }
        if checked == 0 {
            return Err(magneton::Error::msg(
                "--verify: no finding carries a mechanical rewrite to apply",
            ));
        }
        if disagreed > 0 {
            return Err(magneton::Error::msg(format!(
                "{disagreed}/{checked} verified findings contradict their static estimate"
            )));
        }
        println!("verify: {checked}/{checked} measured deltas agree in sign with the static estimates");
    }

    let worst = rep.targets.iter().flat_map(|t| &t.findings).map(|f| f.severity).max();
    if let Some(w) = worst {
        if w >= deny {
            return Err(magneton::Error::msg(format!(
                "findings at severity `{}` meet --deny {}",
                w.name(),
                deny.name()
            )));
        }
    }
    Ok(())
}

/// List PJRT artifacts and smoke-run the fingerprint kernel. Exits
/// non-zero when the runtime is unavailable or nothing loads, so
/// scripts can gate on artifact presence instead of parsing stdout.
fn cmd_artifacts(args: &Args) -> magneton::Result<()> {
    let dir = dir_arg(args, "dir", "artifacts");
    let mut rt = magneton::runtime::PjrtRuntime::cpu().map_err(|e| e.context("PJRT unavailable"))?;
    let n = rt
        .load_dir(&dir)
        .map_err(|e| e.context(format!("no artifacts loaded from {}", dir.display())))?;
    println!("loaded {n} artifacts: {:?}", rt.names());
    let eng = magneton::runtime::PjrtMomentEngine::load(&dir)
        .map_err(|e| e.context("fingerprint engine"))?;
    {
        use magneton::fingerprint::MomentEngine;
        let mut rng = Prng::new(1);
        let t = magneton::tensor::Tensor::randn(&mut rng, &[16, 64]);
        let m = eng.moments(&t, 4);
        println!("fingerprint kernel smoke: moments = {m:?}");
    }
    Ok(())
}
