//! Semantically-equivalent subgraph matching (paper §4.2, Algorithm 1).
//!
//! Step 1 ([`find_equivalent_tensors`]) fingerprints every recorded
//! node-output tensor in both runs (fanned out over worker threads) and
//! finds cross-system pairs whose SVD-invariant sets match within ε.
//! Instead of the all-pairs `O(|G₁|·|G₂|)` comparison, a bucketed
//! [`CandidateIndex`] keyed on `(numel, quantized Frobenius band)`
//! restricts each query tensor to a small candidate set that provably
//! contains every pair the exhaustive prefilter would accept; the
//! exhaustive scan is kept behind [`MatchOptions::exhaustive`] and a
//! property test asserts both paths produce identical [`EqSet`]s.
//!
//! Step 2 ([`recursive_match`]) is the topology-aware divide-and-conquer:
//! build dominator trees, walk the dominator paths of both graphs, keep
//! the longest order-preserving chain of equivalent-tensor pairs as cut
//! points, split both graphs at the cuts, and recurse into the matching
//! segments — independent segments are dispatched in parallel through
//! [`util::pool`](crate::util::pool). Segments that admit no further
//! cuts are emitted as matched regions — the units Magneton compares
//! for energy.
//!
//! [`brute_force_match`] is the strawman baseline of Fig 9: enumerate
//! interval pairs of the two topological orders and test boundary
//! equivalence, with combinatorial cost on large graphs.

use std::collections::{BTreeMap, BTreeSet};

use crate::exec::RunArtifacts;
use crate::fingerprint::{fingerprint_with, Fingerprint, MomentEngine, RustMomentEngine};
use crate::graph::dom::GraphDom;
use crate::graph::{Graph, NodeId, OpKind};
use crate::util::pool;

/// Pairs of equivalent tensors `(node_in_A, node_in_B)`.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct EqSet {
    pub pairs: Vec<(NodeId, NodeId)>,
    set: BTreeSet<(NodeId, NodeId)>,
}

impl EqSet {
    pub fn from_pairs(pairs: Vec<(NodeId, NodeId)>) -> EqSet {
        let set = pairs.iter().copied().collect();
        EqSet { pairs, set }
    }

    pub fn contains(&self, a: NodeId, b: NodeId) -> bool {
        self.set.contains(&(a, b))
    }

    pub fn len(&self) -> usize {
        self.pairs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pairs.is_empty()
    }
}

/// Minimum element count for a tensor to act as a cut anchor: tiny
/// tensors (scalars, small biases) collide across unrelated sites.
pub const MIN_ANCHOR_NUMEL: usize = 8;

/// Options for the equivalent-tensor search.
#[derive(Clone, Copy, Debug, Default)]
pub struct MatchOptions {
    /// Use the all-pairs `O(|G₁|·|G₂|)` scan instead of the candidate
    /// index. Kept as the validation/strawman path; both paths return
    /// identical [`EqSet`]s (enforced by a property test).
    pub exhaustive: bool,
}

/// Fingerprint every recorded tensor of a run (indexed by node id).
pub fn fingerprint_run(
    arts: &RunArtifacts,
    engine: &dyn MomentEngine,
    threads: usize,
) -> Vec<Option<Fingerprint>> {
    let jobs: Vec<Option<&crate::tensor::Tensor>> = arts
        .graph
        .nodes
        .iter()
        .map(|n| {
            // Outputs duplicate their producer; Weights are parameter
            // edges — excluded from the dominator flow analysis, so they
            // can never anchor a cut and need no fingerprint.
            if n.op == OpKind::Output || n.op == OpKind::Weight {
                return None;
            }
            arts.tensors[n.id].as_ref().filter(|t| t.numel() >= MIN_ANCHOR_NUMEL)
        })
        .collect();
    pool::par_map(&jobs, threads, |t| t.map(|t| fingerprint_with(engine, t)))
}

/// The shared pair predicate: numel gate, relative-Frobenius prefilter
/// at `4·max(eps, 1e-12)`, then the full invariant match. Both the
/// exhaustive scan and the candidate index accept exactly the pairs
/// this function accepts.
fn pair_matches(fi: &Fingerprint, fj: &Fingerprint, eps: f64) -> bool {
    if fi.numel != fj.numel {
        return false;
    }
    let fro_gap = (fi.fro - fj.fro).abs() / fi.fro.abs().max(fj.fro.abs()).max(1e-30);
    if fro_gap > fro_delta(eps) {
        return false;
    }
    fi.matches(fj, eps)
}

/// Width of the Frobenius prefilter gate.
fn fro_delta(eps: f64) -> f64 {
    eps.max(1e-12) * 4.0
}

/// Bucketed candidate index over one side's fingerprints, keyed on
/// `(numel, quantized log-Frobenius band)`.
///
/// Two fingerprints can only pass the Frobenius gate (relative gap
/// ≤ δ) if their log-norms differ by at most `−ln(1−δ)`, so a query at
/// band `b` probes bands `b−r ..= b+r` with
/// `r = ⌈−ln(1−δ)/ln(1+δ)⌉ + 1` and provably sees every admissible
/// candidate. Zero-norm tensors live in a dedicated bucket (a zero vs
/// non-zero pair has gap 1 > δ for δ < 1). For δ ≥ 1 the gate accepts
/// everything and the index degenerates to the exhaustive scan.
pub struct CandidateIndex {
    buckets: BTreeMap<(usize, i64), Vec<NodeId>>,
    /// Node ids with a fingerprint, ascending (δ ≥ 1 fallback).
    all: Vec<NodeId>,
    band_w: f64,
    radius: i64,
    degenerate: bool,
}

/// Bucket key for zero-norm tensors (ln is undefined there).
const ZERO_BAND: i64 = i64::MIN;

impl CandidateIndex {
    /// Build the index over `fps` (one side's per-node fingerprints).
    pub fn build(fps: &[Option<Fingerprint>], eps: f64) -> CandidateIndex {
        let delta = fro_delta(eps);
        let degenerate = delta >= 1.0;
        let band_w = (1.0 + delta).ln();
        let radius = if degenerate {
            0
        } else {
            (-(1.0 - delta).ln() / band_w).ceil() as i64 + 1
        };
        let mut buckets: BTreeMap<(usize, i64), Vec<NodeId>> = BTreeMap::new();
        let mut all = Vec::new();
        for (j, fp) in fps.iter().enumerate() {
            let Some(fp) = fp else { continue };
            all.push(j);
            buckets
                .entry((fp.numel, Self::band(fp.fro, band_w)))
                .or_default()
                .push(j);
        }
        CandidateIndex { buckets, all, band_w, radius, degenerate }
    }

    fn band(fro: f64, band_w: f64) -> i64 {
        if fro <= 0.0 {
            ZERO_BAND
        } else {
            (fro.ln() / band_w).floor() as i64
        }
    }

    /// Node ids whose fingerprints could pass the Frobenius gate against
    /// `q`, in ascending order. A superset of the true matches; never
    /// misses one.
    pub fn candidates(&self, q: &Fingerprint) -> Vec<NodeId> {
        if self.degenerate {
            return self.all.clone();
        }
        let qb = Self::band(q.fro, self.band_w);
        if qb == ZERO_BAND {
            return self
                .buckets
                .get(&(q.numel, ZERO_BAND))
                .cloned()
                .unwrap_or_default();
        }
        let mut out = Vec::new();
        for b in qb.saturating_sub(self.radius)..=qb.saturating_add(self.radius) {
            if let Some(v) = self.buckets.get(&(q.numel, b)) {
                out.extend_from_slice(v);
            }
        }
        // bands are probed in ascending order and each node id lives in
        // exactly one bucket, but ids across bands interleave
        out.sort_unstable();
        out
    }

    /// Total number of non-empty buckets (introspection/benchmarks).
    pub fn bucket_count(&self) -> usize {
        self.buckets.len()
    }
}

/// Pairwise equivalent-tensor discovery at tolerance `eps` using the
/// default (indexed) strategy.
pub fn find_equivalent_tensors(
    a: &RunArtifacts,
    b: &RunArtifacts,
    eps: f64,
    engine: &dyn MomentEngine,
) -> EqSet {
    find_equivalent_tensors_with(a, b, eps, engine, MatchOptions::default())
}

/// Pairwise equivalent-tensor discovery with an explicit strategy.
pub fn find_equivalent_tensors_with(
    a: &RunArtifacts,
    b: &RunArtifacts,
    eps: f64,
    engine: &dyn MomentEngine,
    opts: MatchOptions,
) -> EqSet {
    let threads = pool::default_threads();
    let fa = fingerprint_run(a, engine, threads);
    let fb = fingerprint_run(b, engine, threads);
    pairs_from_fingerprints(&fa, &fb, eps, opts)
}

/// The pair-discovery stage alone (fingerprints already computed).
/// Public so benchmarks can time it apart from fingerprinting.
pub fn pairs_from_fingerprints(
    fa: &[Option<Fingerprint>],
    fb: &[Option<Fingerprint>],
    eps: f64,
    opts: MatchOptions,
) -> EqSet {
    let mut pairs = Vec::new();
    if opts.exhaustive {
        for (i, fi) in fa.iter().enumerate() {
            let Some(fi) = fi else { continue };
            for (j, fj) in fb.iter().enumerate() {
                let Some(fj) = fj else { continue };
                if pair_matches(fi, fj, eps) {
                    pairs.push((i, j));
                }
            }
        }
    } else {
        let index = CandidateIndex::build(fb, eps);
        for (i, fi) in fa.iter().enumerate() {
            let Some(fi) = fi else { continue };
            for j in index.candidates(fi) {
                let fj = fb[j].as_ref().expect("indexed nodes have fingerprints");
                if pair_matches(fi, fj, eps) {
                    pairs.push((i, j));
                }
            }
        }
    }
    EqSet::from_pairs(pairs)
}

/// A matched pair of subgraphs (node ids in the original graphs).
#[derive(Clone, Debug)]
pub struct Region {
    pub a_nodes: Vec<NodeId>,
    pub b_nodes: Vec<NodeId>,
}

impl Region {
    pub fn size(&self) -> usize {
        self.a_nodes.len().max(self.b_nodes.len())
    }
}

/// Below this depth, independent segment recursions are dispatched over
/// the worker pool; deeper levels recurse sequentially so nested calls
/// do not oversubscribe threads.
const PARALLEL_DEPTH: usize = 1;

/// Algorithm 1: recursive dominator-path matching. `ga`/`gb` are whole
/// graphs whose inputs/outputs are assumed semantically equivalent
/// (same workload fed to both systems). Top-level segments run in
/// parallel; the emitted region order is identical to the sequential
/// recursion.
pub fn recursive_match(ga: &Graph, gb: &Graph, eq: &EqSet) -> Vec<Region> {
    let a_all: Vec<NodeId> = (0..ga.len()).collect();
    let b_all: Vec<NodeId> = (0..gb.len()).collect();
    match_sub(ga, gb, a_all, b_all, eq, 0)
}

fn match_sub(
    ga: &Graph,
    gb: &Graph,
    a_nodes: Vec<NodeId>,
    b_nodes: Vec<NodeId>,
    eq: &EqSet,
    depth: usize,
) -> Vec<Region> {
    if a_nodes.is_empty() && b_nodes.is_empty() {
        return Vec::new();
    }
    if a_nodes.is_empty() || b_nodes.is_empty() || depth > 64 {
        return vec![Region { a_nodes, b_nodes }];
    }
    // induced subgraphs + id maps (new -> old)
    let (ia, map_a) = ga.induced(&a_nodes, "a");
    let (ib, map_b) = gb.induced(&b_nodes, "b");
    let back_a: Vec<NodeId> = invert(&map_a);
    let back_b: Vec<NodeId> = invert(&map_b);

    let da = GraphDom::analyze(&ia);
    let db = GraphDom::analyze(&ib);
    let pa: Vec<NodeId> = da.dominator_path();
    let pb: Vec<NodeId> = db.dominator_path();

    // E: order-preserving chain of equivalent pairs along the paths
    // (longest monotone chain via O(n^2) LIS).
    let mut candidates: Vec<(usize, usize)> = Vec::new();
    for (i, &na) in pa.iter().enumerate() {
        for (j, &nb) in pb.iter().enumerate() {
            if eq.contains(back_a[na], back_b[nb]) {
                candidates.push((i, j));
            }
        }
    }
    let chain = longest_monotone_chain(&candidates);

    if chain.len() <= 1 {
        // no interior structure to cut on: this pair is one region
        return vec![Region { a_nodes, b_nodes }];
    }

    let mut out = Vec::new();
    // every cut pair is itself a matched (single-op) region
    for &(i, j) in &chain {
        out.push(Region {
            a_nodes: vec![back_a[pa[i]]],
            b_nodes: vec![back_b[pb[j]]],
        });
    }

    // segments: before first cut, between consecutive cuts, after last
    let seg_a = |from: Option<usize>, to: Option<usize>| -> Vec<NodeId> {
        segment_nodes(&ia, &da, &pa, from, to).into_iter().map(|v| back_a[v]).collect()
    };
    let seg_b = |from: Option<usize>, to: Option<usize>| -> Vec<NodeId> {
        segment_nodes(&ib, &db, &pb, from, to).into_iter().map(|v| back_b[v]).collect()
    };

    let mut boundaries: Vec<(Option<usize>, Option<usize>)> = Vec::new();
    boundaries.push((None, Some(0)));
    for w in 0..chain.len() - 1 {
        boundaries.push((Some(w), Some(w + 1)));
    }
    boundaries.push((Some(chain.len() - 1), None));

    let jobs: Vec<(Vec<NodeId>, Vec<NodeId>)> = boundaries
        .into_iter()
        .filter_map(|(lo, hi)| {
            let a_seg = seg_a(lo.map(|w| chain[w].0), hi.map(|w| chain[w].0));
            let b_seg = seg_b(lo.map(|w| chain[w].1), hi.map(|w| chain[w].1));
            if a_seg.is_empty() && b_seg.is_empty() {
                None
            } else {
                Some((a_seg, b_seg))
            }
        })
        .collect();

    if depth < PARALLEL_DEPTH && jobs.len() > 1 {
        // independent segment recursions fan out over the worker pool;
        // par_map preserves job order, so the region order matches the
        // sequential recursion exactly
        let threads = pool::default_threads().min(jobs.len());
        let results = pool::par_map(&jobs, threads, |(a_seg, b_seg)| {
            match_sub(ga, gb, a_seg.clone(), b_seg.clone(), eq, depth + 1)
        });
        for r in results {
            out.extend(r);
        }
    } else {
        for (a_seg, b_seg) in jobs {
            out.extend(match_sub(ga, gb, a_seg, b_seg, eq, depth + 1));
        }
    }
    out
}

fn invert(map: &std::collections::BTreeMap<NodeId, NodeId>) -> Vec<NodeId> {
    let mut v = vec![0; map.len()];
    for (&old, &new) in map {
        v[new] = old;
    }
    v
}

/// Nodes strictly between cut path positions `from` and `to` (either may
/// be a virtual boundary). Uses dominator/post-dominator containment.
fn segment_nodes(
    g: &Graph,
    gd: &GraphDom,
    path: &[NodeId],
    from: Option<usize>,
    to: Option<usize>,
) -> Vec<NodeId> {
    let lo = from.map(|i| path[i]);
    let hi = to.map(|i| path[i]);
    (0..g.len())
        .filter(|&v| {
            if Some(v) == lo || Some(v) == hi {
                return false;
            }
            let after = match lo {
                Some(c) => gd.dom.dominates(c, v),
                None => true,
            };
            let before = match hi {
                Some(c) => gd.pdom.dominates(c, v),
                None => match lo {
                    // tail segment: exclude anything before the last cut
                    Some(c) => !gd.pdom.dominates(c, v),
                    None => true,
                },
            };
            after && before
        })
        .collect()
}

/// Longest strictly-monotone (in both coordinates) chain of index pairs.
fn longest_monotone_chain(pairs: &[(usize, usize)]) -> Vec<(usize, usize)> {
    if pairs.is_empty() {
        return Vec::new();
    }
    let mut sorted = pairs.to_vec();
    sorted.sort();
    let n = sorted.len();
    let mut best_len = vec![1usize; n];
    let mut prev = vec![usize::MAX; n];
    for i in 0..n {
        for j in 0..i {
            if sorted[j].0 < sorted[i].0
                && sorted[j].1 < sorted[i].1
                && best_len[j] + 1 > best_len[i]
            {
                best_len[i] = best_len[j] + 1;
                prev[i] = j;
            }
        }
    }
    let mut i = (0..n).max_by_key(|&i| best_len[i]).unwrap();
    let mut chain = vec![sorted[i]];
    while prev[i] != usize::MAX {
        i = prev[i];
        chain.push(sorted[i]);
    }
    chain.reverse();
    chain
}

/// Strawman baseline (Fig 9): enumerate contiguous topological intervals
/// of both graphs and accept interval pairs whose endpoint tensors are
/// equivalent. Cost grows with |G₁|²·|G₂|²; `work_limit` bounds the
/// number of pair checks (returns None when exceeded, modelling the
/// paper's 5-minute timeout).
pub fn brute_force_match(
    ga: &Graph,
    gb: &Graph,
    eq: &EqSet,
    work_limit: u64,
) -> Option<Vec<Region>> {
    let ta = ga.topo_order();
    let tb = gb.topo_order();
    let mut out = Vec::new();
    let mut work: u64 = 0;
    for ia in 0..ta.len() {
        for ja in ia..ta.len() {
            for ib in 0..tb.len() {
                for jb in ib..tb.len() {
                    work += 1;
                    if work > work_limit {
                        return None;
                    }
                    // boundary test: interval entry and exit tensors equivalent
                    if eq.contains(ta[ia], tb[ib]) && eq.contains(ta[ja], tb[jb]) {
                        out.push(Region {
                            a_nodes: ta[ia..=ja].to_vec(),
                            b_nodes: tb[ib..=jb].to_vec(),
                        });
                    }
                }
            }
        }
    }
    Some(out)
}

/// Convenience wrapper: fingerprint, find pairs, and match two runs.
pub fn match_runs(a: &RunArtifacts, b: &RunArtifacts, eps: f64) -> (EqSet, Vec<Region>) {
    let eq = find_equivalent_tensors(a, b, eps, &RustMomentEngine);
    let regions = recursive_match(&a.graph, &b.graph, &eq);
    (eq, regions)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dispatch::Env;
    use crate::energy::DeviceSpec;
    use crate::exec::{Dispatcher, Executor, Program};
    use crate::tensor::Tensor;
    use crate::util::Prng;

    /// System A: x -> matmul(w1) -> gelu -> matmul(w2)
    /// System B: same math, but the first matmul output passes through a
    /// redundant copy, and gelu is decomposed differently upstream.
    fn two_programs() -> (Program, Program) {
        let mut rng = Prng::new(7);
        let x = Tensor::randn(&mut rng, &[8, 16]);
        let w1 = Tensor::randn(&mut rng, &[16, 12]);
        let w2 = Tensor::randn(&mut rng, &[12, 4]);

        let mut ga = Graph::new("sysA");
        let ax = ga.add(OpKind::Input, &[], "x");
        let aw1 = ga.add(OpKind::Weight, &[], "w1");
        let aw2 = ga.add(OpKind::Weight, &[], "w2");
        let m1 = ga.add(OpKind::MatMul, &[ax, aw1], "proj1");
        let g1 = ga.add_attr1(OpKind::Gelu, &[m1], "act", "approx", "tanh");
        let m2 = ga.add(OpKind::MatMul, &[g1, aw2], "proj2");
        ga.add(OpKind::Output, &[m2], "out");
        let mut pa = Program::new(ga);
        pa.feed(0, x.clone());
        pa.feed(1, w1.clone());
        pa.feed(2, w2.clone());

        let mut gb = Graph::new("sysB");
        let bx = gb.add(OpKind::Input, &[], "x");
        let bw1 = gb.add(OpKind::Weight, &[], "w1");
        let bw2 = gb.add(OpKind::Weight, &[], "w2");
        let n1 = gb.add(OpKind::MatMul, &[bx, bw1], "dense1");
        let cp = gb.add(OpKind::Copy, &[n1], "redundant_copy");
        let g2 = gb.add_attr1(OpKind::Gelu, &[cp], "activation", "approx", "tanh");
        let n2 = gb.add(OpKind::MatMul, &[g2, bw2], "dense2");
        gb.add(OpKind::Output, &[n2], "out");
        let mut pb = Program::new(gb);
        pb.feed(0, x);
        pb.feed(1, w1);
        pb.feed(2, w2);
        (pa, pb)
    }

    fn run(p: &Program) -> RunArtifacts {
        Executor::new(DeviceSpec::h200_sim(), Dispatcher::new(), Env::new()).run(p)
    }

    #[test]
    fn eq_pairs_found_across_systems() {
        let (pa, pb) = two_programs();
        let (a, b) = (run(&pa), run(&pb));
        let eq = find_equivalent_tensors(&a, &b, 1e-4, &RustMomentEngine);
        // matmul outputs, gelu outputs, copies, inputs, weights all pair up
        assert!(eq.len() >= 4, "only {} pairs", eq.len());
        // proj1 (node 3) matches both dense1 (3) and its copy (4)
        assert!(eq.contains(3, 3));
        assert!(eq.contains(3, 4));
    }

    #[test]
    fn indexed_matches_exhaustive_on_fixture() {
        let (pa, pb) = two_programs();
        let (a, b) = (run(&pa), run(&pb));
        for eps in [1e-7, 1e-4, 1e-3, 5e-2, 0.2, 0.5] {
            let fast = find_equivalent_tensors_with(
                &a, &b, eps, &RustMomentEngine, MatchOptions { exhaustive: false },
            );
            let slow = find_equivalent_tensors_with(
                &a, &b, eps, &RustMomentEngine, MatchOptions { exhaustive: true },
            );
            assert_eq!(fast, slow, "eps {eps}: indexed vs exhaustive diverge");
        }
    }

    /// Property: on randomized program pairs the candidate index returns
    /// exactly the exhaustive EqSet (the acceptance criterion of the
    /// indexed pipeline).
    #[test]
    fn prop_indexed_eqset_identical_to_exhaustive() {
        use crate::prop;
        let gen = prop::Gen::new(|r| {
            let d = r.range(8, 12);
            let m = r.range(8, 12);
            let x = Tensor::randn(r, &[m, d]);
            let depth = r.range(2, 5);
            let mk = |with_copies: bool, rr: &mut Prng| {
                let mut g = Graph::new("rand");
                let xi = g.add(OpKind::Input, &[], "x");
                let mut cur = xi;
                let mut weights: Vec<(NodeId, Tensor)> = Vec::new();
                for l in 0..depth {
                    match rr.below(4) {
                        0 => {
                            let w = g.add(OpKind::Weight, &[], "w");
                            // weights are feeds: generated deterministically
                            // below from the layer index
                            weights.push((w, Tensor::randn(&mut Prng::new(1000 + l as u64), &[d, d])));
                            cur = g.add(OpKind::MatMul, &[cur, w], "mm");
                        }
                        1 => cur = g.add(OpKind::Gelu, &[cur], "gelu"),
                        2 => cur = g.add(OpKind::Tanh, &[cur], "tanh"),
                        _ => cur = g.add(OpKind::Relu, &[cur], "relu"),
                    }
                    // deterministic by layer index so A's and B's op
                    // draws from `rr` stay in sync
                    if with_copies && l % 2 == 1 {
                        cur = g.add(OpKind::Copy, &[cur], "copy");
                    }
                }
                g.add(OpKind::Output, &[cur], "out");
                let mut p = Program::new(g);
                p.feed(0, x.clone());
                for (node, t) in weights {
                    p.feed(node, t);
                }
                p
            };
            // the two systems share the op sequence seed so their math
            // overlaps, but B sprinkles redundant copies
            let seq_seed = r.next_u64();
            let pa = mk(false, &mut Prng::new(seq_seed));
            let pb = mk(true, &mut Prng::new(seq_seed));
            (pa, pb, r.range_f32(0.0, 1.0))
        });
        prop::forall("indexed == exhaustive", &gen, 25, |(pa, pb, eps_knob)| {
            let (a, b) = (run(pa), run(pb));
            // sweep the paper's epsilon range plus a degenerate-band case
            let eps = match (eps_knob * 4.0) as usize {
                0 => 1e-6,
                1 => 1e-4,
                2 => 1e-2,
                _ => 0.3,
            };
            let fast = find_equivalent_tensors_with(
                &a, &b, eps, &RustMomentEngine, MatchOptions { exhaustive: false },
            );
            let slow = find_equivalent_tensors_with(
                &a, &b, eps, &RustMomentEngine, MatchOptions { exhaustive: true },
            );
            fast == slow
        });
    }

    #[test]
    fn candidate_index_never_misses_gate_pairs() {
        // direct unit check on the index: every pair accepted by the
        // Frobenius gate appears in the candidate set
        let mut rng = Prng::new(42);
        let tensors: Vec<Tensor> = (0..40)
            .map(|_| {
                let s = rng.range(3, 6);
                Tensor::randn(&mut rng, &[s, s])
            })
            .collect();
        for eps in [1e-6, 1e-3, 0.1] {
            let fps: Vec<Option<Fingerprint>> = tensors
                .iter()
                .map(|t| Some(fingerprint_with(&RustMomentEngine, t)))
                .collect();
            let index = CandidateIndex::build(&fps, eps);
            let delta = super::fro_delta(eps);
            for fi in fps.iter().flatten() {
                let cands = index.candidates(fi);
                for (j, fj) in fps.iter().enumerate() {
                    let fj = fj.as_ref().unwrap();
                    if fi.numel != fj.numel {
                        continue;
                    }
                    let gap = (fi.fro - fj.fro).abs()
                        / fi.fro.abs().max(fj.fro.abs()).max(1e-30);
                    if gap <= delta {
                        assert!(
                            cands.contains(&j),
                            "eps {eps}: index missed node {j} (gap {gap:.3e})"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn recursive_match_produces_regions_covering_differences() {
        let (pa, pb) = two_programs();
        let (a, b) = (run(&pa), run(&pb));
        let (eq, regions) = match_runs(&a, &b, 1e-4);
        assert!(!eq.is_empty());
        assert!(!regions.is_empty());
        // every region's nodes exist in their graphs
        for r in &regions {
            assert!(r.a_nodes.iter().all(|&n| n < a.graph.len()));
            assert!(r.b_nodes.iter().all(|&n| n < b.graph.len()));
        }
        // some region must expose the asymmetry around the redundant copy
        let has_asym = regions.iter().any(|r| {
            let a_copies = r.a_nodes.iter().filter(|&&n| a.graph.nodes[n].op == OpKind::Copy).count();
            let b_copies = r.b_nodes.iter().filter(|&&n| b.graph.nodes[n].op == OpKind::Copy).count();
            b_copies > a_copies
        });
        assert!(has_asym, "no region isolates the redundant copy: {regions:?}");
    }

    #[test]
    fn identical_programs_match_node_for_node() {
        let (pa, _) = two_programs();
        let a = run(&pa);
        let b = run(&pa);
        let (eq, regions) = match_runs(&a, &b, 1e-6);
        // diagonal pairs exist for all anchorable nodes
        for n in 0..a.graph.len() {
            if a.tensors[n].as_ref().map(|t| t.numel() >= MIN_ANCHOR_NUMEL).unwrap_or(false)
                && a.graph.nodes[n].op != OpKind::Output
                && a.graph.nodes[n].op != OpKind::Weight
            {
                assert!(eq.contains(n, n), "node {n} missing diagonal pair");
            }
        }
        assert!(!regions.is_empty());
    }

    #[test]
    fn longest_chain_is_monotone() {
        let pairs = vec![(0, 3), (1, 1), (2, 2), (3, 0), (4, 4)];
        let chain = longest_monotone_chain(&pairs);
        assert!(chain.windows(2).all(|w| w[0].0 < w[1].0 && w[0].1 < w[1].1));
        assert_eq!(chain.len(), 3); // (1,1),(2,2),(4,4)
    }

    #[test]
    fn brute_force_times_out_on_budget() {
        let (pa, pb) = two_programs();
        let (a, b) = (run(&pa), run(&pb));
        let eq = find_equivalent_tensors(&a, &b, 1e-4, &RustMomentEngine);
        assert!(brute_force_match(&a.graph, &b.graph, &eq, 10).is_none());
        assert!(brute_force_match(&a.graph, &b.graph, &eq, u64::MAX).is_some());
    }

    #[test]
    fn brute_force_agrees_regions_exist_on_small_graphs() {
        let (pa, pb) = two_programs();
        let (a, b) = (run(&pa), run(&pb));
        let eq = find_equivalent_tensors(&a, &b, 1e-4, &RustMomentEngine);
        let bf = brute_force_match(&a.graph, &b.graph, &eq, u64::MAX).unwrap();
        assert!(!bf.is_empty());
    }
}
