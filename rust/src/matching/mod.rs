//! Semantically-equivalent subgraph matching (paper §4.2, Algorithm 1).
//!
//! Step 1 ([`find_equivalent_tensors`]) fingerprints every recorded
//! node-output tensor in both runs and finds cross-system pairs whose
//! SVD-invariant sets match within ε — `O(|G₁|·|G₂|)` comparisons with a
//! cheap (numel, ‖·‖_F) prefilter and fingerprints computed once per
//! node (fanned out over worker threads).
//!
//! Step 2 ([`recursive_match`]) is the topology-aware divide-and-conquer:
//! build dominator trees, walk the dominator paths of both graphs, keep
//! the longest order-preserving chain of equivalent-tensor pairs as cut
//! points, split both graphs at the cuts, and recurse into the matching
//! segments. Segments that admit no further cuts are emitted as matched
//! regions — the units Magneton compares for energy.
//!
//! [`brute_force_match`] is the strawman baseline of Fig 9: enumerate
//! interval pairs of the two topological orders and test boundary
//! equivalence, with combinatorial cost on large graphs.

use std::collections::BTreeSet;

use crate::exec::RunArtifacts;
use crate::fingerprint::{fingerprint_with, Fingerprint, MomentEngine, RustMomentEngine};
use crate::graph::dom::GraphDom;
use crate::graph::{Graph, NodeId, OpKind};
use crate::util::pool;

/// Pairs of equivalent tensors `(node_in_A, node_in_B)`.
#[derive(Clone, Debug, Default)]
pub struct EqSet {
    pub pairs: Vec<(NodeId, NodeId)>,
    set: BTreeSet<(NodeId, NodeId)>,
}

impl EqSet {
    pub fn from_pairs(pairs: Vec<(NodeId, NodeId)>) -> EqSet {
        let set = pairs.iter().copied().collect();
        EqSet { pairs, set }
    }

    pub fn contains(&self, a: NodeId, b: NodeId) -> bool {
        self.set.contains(&(a, b))
    }

    pub fn len(&self) -> usize {
        self.pairs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pairs.is_empty()
    }
}

/// Minimum element count for a tensor to act as a cut anchor: tiny
/// tensors (scalars, small biases) collide across unrelated sites.
pub const MIN_ANCHOR_NUMEL: usize = 8;

/// Fingerprint every recorded tensor of a run (indexed by node id).
pub fn fingerprint_run(
    arts: &RunArtifacts,
    engine: &dyn MomentEngine,
    threads: usize,
) -> Vec<Option<Fingerprint>> {
    let jobs: Vec<Option<&crate::tensor::Tensor>> = arts
        .graph
        .nodes
        .iter()
        .map(|n| {
            // Outputs duplicate their producer; Weights are parameter
            // edges — excluded from the dominator flow analysis, so they
            // can never anchor a cut and need no fingerprint.
            if n.op == OpKind::Output || n.op == OpKind::Weight {
                return None;
            }
            arts.tensors[n.id].as_ref().filter(|t| t.numel() >= MIN_ANCHOR_NUMEL)
        })
        .collect();
    pool::par_map(&jobs, threads, |t| t.map(|t| fingerprint_with(engine, t)))
}

/// Pairwise equivalent-tensor discovery at tolerance `eps`.
pub fn find_equivalent_tensors(
    a: &RunArtifacts,
    b: &RunArtifacts,
    eps: f64,
    engine: &dyn MomentEngine,
) -> EqSet {
    let threads = pool::default_threads();
    let fa = fingerprint_run(a, engine, threads);
    let fb = fingerprint_run(b, engine, threads);
    let mut pairs = Vec::new();
    for (i, fi) in fa.iter().enumerate() {
        let Some(fi) = fi else { continue };
        for (j, fj) in fb.iter().enumerate() {
            let Some(fj) = fj else { continue };
            // prefilter: numel + Frobenius gate before full invariant match
            if fi.numel != fj.numel {
                continue;
            }
            let fro_gap = (fi.fro - fj.fro).abs() / fi.fro.abs().max(fj.fro.abs()).max(1e-30);
            if fro_gap > eps.max(1e-12) * 4.0 {
                continue;
            }
            if fi.matches(fj, eps) {
                pairs.push((i, j));
            }
        }
    }
    EqSet::from_pairs(pairs)
}

/// A matched pair of subgraphs (node ids in the original graphs).
#[derive(Clone, Debug)]
pub struct Region {
    pub a_nodes: Vec<NodeId>,
    pub b_nodes: Vec<NodeId>,
}

impl Region {
    pub fn size(&self) -> usize {
        self.a_nodes.len().max(self.b_nodes.len())
    }
}

/// Algorithm 1: recursive dominator-path matching. `ga`/`gb` are whole
/// graphs whose inputs/outputs are assumed semantically equivalent
/// (same workload fed to both systems).
pub fn recursive_match(ga: &Graph, gb: &Graph, eq: &EqSet) -> Vec<Region> {
    let a_all: Vec<NodeId> = (0..ga.len()).collect();
    let b_all: Vec<NodeId> = (0..gb.len()).collect();
    let mut out = Vec::new();
    match_sub(ga, gb, a_all, b_all, eq, &mut out, 0);
    out
}

fn match_sub(
    ga: &Graph,
    gb: &Graph,
    a_nodes: Vec<NodeId>,
    b_nodes: Vec<NodeId>,
    eq: &EqSet,
    out: &mut Vec<Region>,
    depth: usize,
) {
    if a_nodes.is_empty() && b_nodes.is_empty() {
        return;
    }
    if a_nodes.is_empty() || b_nodes.is_empty() || depth > 64 {
        out.push(Region { a_nodes, b_nodes });
        return;
    }
    // induced subgraphs + id maps (new -> old)
    let (ia, map_a) = ga.induced(&a_nodes, "a");
    let (ib, map_b) = gb.induced(&b_nodes, "b");
    let back_a: Vec<NodeId> = invert(&map_a);
    let back_b: Vec<NodeId> = invert(&map_b);

    let da = GraphDom::analyze(&ia);
    let db = GraphDom::analyze(&ib);
    let pa: Vec<NodeId> = da.dominator_path();
    let pb: Vec<NodeId> = db.dominator_path();

    // E: order-preserving chain of equivalent pairs along the paths
    // (longest monotone chain via O(n^2) LIS).
    let mut candidates: Vec<(usize, usize)> = Vec::new();
    for (i, &na) in pa.iter().enumerate() {
        for (j, &nb) in pb.iter().enumerate() {
            if eq.contains(back_a[na], back_b[nb]) {
                candidates.push((i, j));
            }
        }
    }
    let chain = longest_monotone_chain(&candidates);

    if chain.len() <= 1 {
        // no interior structure to cut on: this pair is one region
        out.push(Region { a_nodes, b_nodes });
        return;
    }

    // every cut pair is itself a matched (single-op) region
    for &(i, j) in &chain {
        out.push(Region {
            a_nodes: vec![back_a[pa[i]]],
            b_nodes: vec![back_b[pb[j]]],
        });
    }

    // segments: before first cut, between consecutive cuts, after last
    let seg_a = |from: Option<usize>, to: Option<usize>| -> Vec<NodeId> {
        segment_nodes(&ia, &da, &pa, from, to).into_iter().map(|v| back_a[v]).collect()
    };
    let seg_b = |from: Option<usize>, to: Option<usize>| -> Vec<NodeId> {
        segment_nodes(&ib, &db, &pb, from, to).into_iter().map(|v| back_b[v]).collect()
    };

    let mut boundaries: Vec<(Option<usize>, Option<usize>)> = Vec::new();
    boundaries.push((None, Some(0)));
    for w in 0..chain.len() - 1 {
        boundaries.push((Some(w), Some(w + 1)));
    }
    boundaries.push((Some(chain.len() - 1), None));

    for (lo, hi) in boundaries {
        let a_seg = seg_a(lo.map(|w| chain[w].0), hi.map(|w| chain[w].0));
        let b_seg = seg_b(lo.map(|w| chain[w].1), hi.map(|w| chain[w].1));
        if a_seg.is_empty() && b_seg.is_empty() {
            continue;
        }
        match_sub(ga, gb, a_seg, b_seg, eq, out, depth + 1);
    }
}

fn invert(map: &std::collections::BTreeMap<NodeId, NodeId>) -> Vec<NodeId> {
    let mut v = vec![0; map.len()];
    for (&old, &new) in map {
        v[new] = old;
    }
    v
}

/// Nodes strictly between cut path positions `from` and `to` (either may
/// be a virtual boundary). Uses dominator/post-dominator containment.
fn segment_nodes(
    g: &Graph,
    gd: &GraphDom,
    path: &[NodeId],
    from: Option<usize>,
    to: Option<usize>,
) -> Vec<NodeId> {
    let lo = from.map(|i| path[i]);
    let hi = to.map(|i| path[i]);
    (0..g.len())
        .filter(|&v| {
            if Some(v) == lo || Some(v) == hi {
                return false;
            }
            let after = match lo {
                Some(c) => gd.dom.dominates(c, v),
                None => true,
            };
            let before = match hi {
                Some(c) => gd.pdom.dominates(c, v),
                None => match lo {
                    // tail segment: exclude anything before the last cut
                    Some(c) => !gd.pdom.dominates(c, v),
                    None => true,
                },
            };
            after && before
        })
        .collect()
}

/// Longest strictly-monotone (in both coordinates) chain of index pairs.
fn longest_monotone_chain(pairs: &[(usize, usize)]) -> Vec<(usize, usize)> {
    if pairs.is_empty() {
        return Vec::new();
    }
    let mut sorted = pairs.to_vec();
    sorted.sort();
    let n = sorted.len();
    let mut best_len = vec![1usize; n];
    let mut prev = vec![usize::MAX; n];
    for i in 0..n {
        for j in 0..i {
            if sorted[j].0 < sorted[i].0
                && sorted[j].1 < sorted[i].1
                && best_len[j] + 1 > best_len[i]
            {
                best_len[i] = best_len[j] + 1;
                prev[i] = j;
            }
        }
    }
    let mut i = (0..n).max_by_key(|&i| best_len[i]).unwrap();
    let mut chain = vec![sorted[i]];
    while prev[i] != usize::MAX {
        i = prev[i];
        chain.push(sorted[i]);
    }
    chain.reverse();
    chain
}

/// Strawman baseline (Fig 9): enumerate contiguous topological intervals
/// of both graphs and accept interval pairs whose endpoint tensors are
/// equivalent. Cost grows with |G₁|²·|G₂|²; `work_limit` bounds the
/// number of pair checks (returns None when exceeded, modelling the
/// paper's 5-minute timeout).
pub fn brute_force_match(
    ga: &Graph,
    gb: &Graph,
    eq: &EqSet,
    work_limit: u64,
) -> Option<Vec<Region>> {
    let ta = ga.topo_order();
    let tb = gb.topo_order();
    let mut out = Vec::new();
    let mut work: u64 = 0;
    for ia in 0..ta.len() {
        for ja in ia..ta.len() {
            for ib in 0..tb.len() {
                for jb in ib..tb.len() {
                    work += 1;
                    if work > work_limit {
                        return None;
                    }
                    // boundary test: interval entry and exit tensors equivalent
                    if eq.contains(ta[ia], tb[ib]) && eq.contains(ta[ja], tb[jb]) {
                        out.push(Region {
                            a_nodes: ta[ia..=ja].to_vec(),
                            b_nodes: tb[ib..=jb].to_vec(),
                        });
                    }
                }
            }
        }
    }
    Some(out)
}

/// Convenience wrapper: fingerprint, find pairs, and match two runs.
pub fn match_runs(a: &RunArtifacts, b: &RunArtifacts, eps: f64) -> (EqSet, Vec<Region>) {
    let eq = find_equivalent_tensors(a, b, eps, &RustMomentEngine);
    let regions = recursive_match(&a.graph, &b.graph, &eq);
    (eq, regions)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dispatch::Env;
    use crate::energy::DeviceSpec;
    use crate::exec::{Dispatcher, Executor, Program};
    use crate::tensor::Tensor;
    use crate::util::Prng;

    /// System A: x -> matmul(w1) -> gelu -> matmul(w2)
    /// System B: same math, but the first matmul output passes through a
    /// redundant copy, and gelu is decomposed differently upstream.
    fn two_programs() -> (Program, Program) {
        let mut rng = Prng::new(7);
        let x = Tensor::randn(&mut rng, &[8, 16]);
        let w1 = Tensor::randn(&mut rng, &[16, 12]);
        let w2 = Tensor::randn(&mut rng, &[12, 4]);

        let mut ga = Graph::new("sysA");
        let ax = ga.add(OpKind::Input, &[], "x");
        let aw1 = ga.add(OpKind::Weight, &[], "w1");
        let aw2 = ga.add(OpKind::Weight, &[], "w2");
        let m1 = ga.add(OpKind::MatMul, &[ax, aw1], "proj1");
        let g1 = ga.add_attr1(OpKind::Gelu, &[m1], "act", "approx", "tanh");
        let m2 = ga.add(OpKind::MatMul, &[g1, aw2], "proj2");
        ga.add(OpKind::Output, &[m2], "out");
        let mut pa = Program::new(ga);
        pa.feed(0, x.clone());
        pa.feed(1, w1.clone());
        pa.feed(2, w2.clone());

        let mut gb = Graph::new("sysB");
        let bx = gb.add(OpKind::Input, &[], "x");
        let bw1 = gb.add(OpKind::Weight, &[], "w1");
        let bw2 = gb.add(OpKind::Weight, &[], "w2");
        let n1 = gb.add(OpKind::MatMul, &[bx, bw1], "dense1");
        let cp = gb.add(OpKind::Copy, &[n1], "redundant_copy");
        let g2 = gb.add_attr1(OpKind::Gelu, &[cp], "activation", "approx", "tanh");
        let n2 = gb.add(OpKind::MatMul, &[g2, bw2], "dense2");
        gb.add(OpKind::Output, &[n2], "out");
        let mut pb = Program::new(gb);
        pb.feed(0, x);
        pb.feed(1, w1);
        pb.feed(2, w2);
        (pa, pb)
    }

    fn run(p: &Program) -> RunArtifacts {
        Executor::new(DeviceSpec::h200_sim(), Dispatcher::new(), Env::new()).run(p)
    }

    #[test]
    fn eq_pairs_found_across_systems() {
        let (pa, pb) = two_programs();
        let (a, b) = (run(&pa), run(&pb));
        let eq = find_equivalent_tensors(&a, &b, 1e-4, &RustMomentEngine);
        // matmul outputs, gelu outputs, copies, inputs, weights all pair up
        assert!(eq.len() >= 4, "only {} pairs", eq.len());
        // proj1 (node 3) matches both dense1 (3) and its copy (4)
        assert!(eq.contains(3, 3));
        assert!(eq.contains(3, 4));
    }

    #[test]
    fn recursive_match_produces_regions_covering_differences() {
        let (pa, pb) = two_programs();
        let (a, b) = (run(&pa), run(&pb));
        let (eq, regions) = match_runs(&a, &b, 1e-4);
        assert!(!eq.is_empty());
        assert!(!regions.is_empty());
        // every region's nodes exist in their graphs
        for r in &regions {
            assert!(r.a_nodes.iter().all(|&n| n < a.graph.len()));
            assert!(r.b_nodes.iter().all(|&n| n < b.graph.len()));
        }
        // some region must expose the asymmetry around the redundant copy
        let has_asym = regions.iter().any(|r| {
            let a_copies = r.a_nodes.iter().filter(|&&n| a.graph.nodes[n].op == OpKind::Copy).count();
            let b_copies = r.b_nodes.iter().filter(|&&n| b.graph.nodes[n].op == OpKind::Copy).count();
            b_copies > a_copies
        });
        assert!(has_asym, "no region isolates the redundant copy: {regions:?}");
    }

    #[test]
    fn identical_programs_match_node_for_node() {
        let (pa, _) = two_programs();
        let a = run(&pa);
        let b = run(&pa);
        let (eq, regions) = match_runs(&a, &b, 1e-6);
        // diagonal pairs exist for all anchorable nodes
        for n in 0..a.graph.len() {
            if a.tensors[n].as_ref().map(|t| t.numel() >= MIN_ANCHOR_NUMEL).unwrap_or(false)
                && a.graph.nodes[n].op != OpKind::Output
                && a.graph.nodes[n].op != OpKind::Weight
            {
                assert!(eq.contains(n, n), "node {n} missing diagonal pair");
            }
        }
        assert!(!regions.is_empty());
    }

    #[test]
    fn longest_chain_is_monotone() {
        let pairs = vec![(0, 3), (1, 1), (2, 2), (3, 0), (4, 4)];
        let chain = longest_monotone_chain(&pairs);
        assert!(chain.windows(2).all(|w| w[0].0 < w[1].0 && w[0].1 < w[1].1));
        assert_eq!(chain.len(), 3); // (1,1),(2,2),(4,4)
    }

    #[test]
    fn brute_force_times_out_on_budget() {
        let (pa, pb) = two_programs();
        let (a, b) = (run(&pa), run(&pb));
        let eq = find_equivalent_tensors(&a, &b, 1e-4, &RustMomentEngine);
        assert!(brute_force_match(&a.graph, &b.graph, &eq, 10).is_none());
        assert!(brute_force_match(&a.graph, &b.graph, &eq, u64::MAX).is_some());
    }

    #[test]
    fn brute_force_agrees_regions_exist_on_small_graphs() {
        let (pa, pb) = two_programs();
        let (a, b) = (run(&pa), run(&pb));
        let eq = find_equivalent_tensors(&a, &b, 1e-4, &RustMomentEngine);
        let bf = brute_force_match(&a.graph, &b.graph, &eq, u64::MAX).unwrap();
        assert!(!bf.is_empty());
    }
}
