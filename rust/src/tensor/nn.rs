//! Neural-network primitives: softmax, layernorm, GELU (exact / tanh /
//! unfused decomposition), attention helpers, cross-entropy. These are
//! the operator bodies the mini ML systems execute; the *unfused* GELU
//! decomposition mirrors the 5-kernel HuggingFace implementation the
//! paper contrasts with vLLM's fused kernel (§6.3).

use super::ops::{add, map, matmul, mul, scale, sub};
use super::Tensor;

/// Softmax along the last dim (numerically stable).
pub fn softmax(a: &Tensor) -> Tensor {
    let shape = a.shape().to_vec();
    let last = *shape.last().unwrap();
    let rows = a.numel() / last;
    let v = a.to_vec();
    let mut out = vec![0.0f32; v.len()];
    for r in 0..rows {
        let row = &v[r * last..(r + 1) * last];
        let m = row.iter().cloned().fold(f32::MIN, f32::max);
        let mut denom = 0.0f32;
        for (j, &x) in row.iter().enumerate() {
            let e = (x - m).exp();
            out[r * last + j] = e;
            denom += e;
        }
        for j in 0..last {
            out[r * last + j] /= denom;
        }
    }
    Tensor::from_vec(out, &shape)
}

/// LayerNorm over the last dim with learned gamma/beta.
pub fn layernorm(a: &Tensor, gamma: &Tensor, beta: &Tensor, eps: f32) -> Tensor {
    let shape = a.shape().to_vec();
    let last = *shape.last().unwrap();
    assert_eq!(gamma.numel(), last);
    assert_eq!(beta.numel(), last);
    let rows = a.numel() / last;
    let v = a.to_vec();
    let g = gamma.to_vec();
    let b = beta.to_vec();
    let mut out = vec![0.0f32; v.len()];
    for r in 0..rows {
        let row = &v[r * last..(r + 1) * last];
        let mean = row.iter().sum::<f32>() / last as f32;
        let var = row.iter().map(|x| (x - mean).powi(2)).sum::<f32>() / last as f32;
        let inv = 1.0 / (var + eps).sqrt();
        for j in 0..last {
            out[r * last + j] = (row[j] - mean) * inv * g[j] + b[j];
        }
    }
    Tensor::from_vec(out, &shape)
}

/// Exact GELU: x * Phi(x).
pub fn gelu_exact(a: &Tensor) -> Tensor {
    map(a, |x| 0.5 * x * (1.0 + erf(x / std::f32::consts::SQRT_2)))
}

/// Tanh-approximation GELU (the formulation GPT-2 uses).
pub fn gelu_tanh(a: &Tensor) -> Tensor {
    map(a, |x| {
        0.5 * x * (1.0 + ((0.797_884_6) * (x + 0.044715 * x * x * x)).tanh())
    })
}

/// The *unfused* tanh-GELU as five separate elementwise kernels — the
/// HuggingFace-style decomposition (pow, mul-add, scale, tanh, final mul).
/// Numerically identical to [`gelu_tanh`]; the executor charges five
/// kernel launches and 5x the HBM round-trips for it.
pub fn gelu_tanh_unfused_steps(a: &Tensor) -> (Vec<Tensor>, Tensor) {
    let x3 = map(a, |x| x * x * x); // kernel 1: pow
    let inner = add(a, &scale(&x3, 0.044715)); // kernel 2: mul-add
    let scaled = scale(&inner, 0.797_884_6); // kernel 3: scale
    let t = map(&scaled, f32::tanh); // kernel 4: tanh
    let half = scale(&add(&t, &Tensor::full(&[1], 1.0)), 0.5);
    let out = mul(a, &half); // kernel 5: mul
    (vec![x3, inner, scaled, t.clone()], out)
}

/// erf via Abramowitz–Stegun 7.1.26 (|err| < 1.5e-7).
pub fn erf(x: f32) -> f32 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.327_591_1 * x);
    let y = 1.0
        - (((((1.061_405_429 * t - 1.453_152_027) * t) + 1.421_413_741) * t
            - 0.284_496_736)
            * t
            + 0.254_829_592)
            * t
            * (-x * x).exp();
    sign * y
}

/// Scaled dot-product attention over `[b, h, s, d]` Q/K/V (HND layout).
pub fn attention_hnd(q: &Tensor, k: &Tensor, v: &Tensor) -> Tensor {
    let d = *q.shape().last().unwrap() as f32;
    let scores = scale(&matmul(q, &k.t()), 1.0 / d.sqrt());
    let probs = softmax(&scores);
    matmul(&probs, v)
}

/// Attention with NHD-layout inputs `[b, s, h, d]` (SGLang-style): the
/// math permutes to HND internally and permutes back, producing the same
/// values in the caller's layout.
pub fn attention_nhd(q: &Tensor, k: &Tensor, v: &Tensor) -> Tensor {
    let to_hnd = |t: &Tensor| t.permute(&[0, 2, 1, 3]).contiguous();
    let o = attention_hnd(&to_hnd(q), &to_hnd(k), &to_hnd(v));
    o.permute(&[0, 2, 1, 3]).contiguous()
}

/// Cross-entropy loss from logits `[n, c]` and integer targets.
pub fn cross_entropy(logits: &Tensor, targets: &[usize]) -> f32 {
    assert_eq!(logits.rank(), 2);
    let (n, c) = (logits.shape()[0], logits.shape()[1]);
    assert_eq!(targets.len(), n);
    let probs = softmax(logits);
    let pv = probs.to_vec();
    let mut loss = 0.0f32;
    for (i, &t) in targets.iter().enumerate() {
        assert!(t < c);
        loss -= pv[i * c + t].max(1e-12).ln();
    }
    loss / n as f32
}

/// SiLU (used by Llama-style MLPs in the mini systems).
pub fn silu(a: &Tensor) -> Tensor {
    map(a, |x| x / (1.0 + (-x).exp()))
}

/// RMSNorm over the last dim (Llama-style).
pub fn rmsnorm(a: &Tensor, gamma: &Tensor, eps: f32) -> Tensor {
    let shape = a.shape().to_vec();
    let last = *shape.last().unwrap();
    assert_eq!(gamma.numel(), last);
    let rows = a.numel() / last;
    let v = a.to_vec();
    let g = gamma.to_vec();
    let mut out = vec![0.0f32; v.len()];
    for r in 0..rows {
        let row = &v[r * last..(r + 1) * last];
        let ms = row.iter().map(|x| x * x).sum::<f32>() / last as f32;
        let inv = 1.0 / (ms + eps).sqrt();
        for j in 0..last {
            out[r * last + j] = row[j] * inv * g[j];
        }
    }
    Tensor::from_vec(out, &shape)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ops::sum_all;
    use crate::util::Prng;

    #[test]
    fn softmax_rows_sum_to_one() {
        let mut rng = Prng::new(1);
        let a = Tensor::randn(&mut rng, &[5, 7]);
        let s = softmax(&a);
        for r in 0..5 {
            let row = s.slice(0, r, r + 1);
            assert!((sum_all(&row) - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn softmax_invariant_to_shift() {
        let a = Tensor::from_vec(vec![1., 2., 3.], &[1, 3]);
        let b = Tensor::from_vec(vec![1001., 1002., 1003.], &[1, 3]);
        assert!(softmax(&a).allclose(&softmax(&b), 1e-6, 1e-6));
    }

    #[test]
    fn layernorm_zero_mean_unit_var() {
        let mut rng = Prng::new(2);
        let a = Tensor::randn(&mut rng, &[4, 32]);
        let g = Tensor::full(&[32], 1.0);
        let b = Tensor::zeros(&[32]);
        let ln = layernorm(&a, &g, &b, 1e-5);
        let v = ln.to_vec();
        for r in 0..4 {
            let row = &v[r * 32..(r + 1) * 32];
            let mean = row.iter().sum::<f32>() / 32.0;
            let var = row.iter().map(|x| (x - mean).powi(2)).sum::<f32>() / 32.0;
            assert!(mean.abs() < 1e-5);
            assert!((var - 1.0).abs() < 1e-3);
        }
    }

    #[test]
    fn gelu_unfused_matches_fused() {
        let mut rng = Prng::new(3);
        let a = Tensor::randn(&mut rng, &[64]);
        let fused = gelu_tanh(&a);
        let (_tmps, unfused) = gelu_tanh_unfused_steps(&a);
        assert!(fused.allclose(&unfused, 1e-6, 1e-5));
    }

    #[test]
    fn gelu_tanh_close_to_exact() {
        let mut rng = Prng::new(4);
        let a = Tensor::randn(&mut rng, &[256]);
        let d = gelu_tanh(&a).max_abs_diff(&gelu_exact(&a));
        assert!(d < 5e-3, "tanh approx too far: {d}");
    }

    #[test]
    fn erf_known_values() {
        assert!((erf(0.0)).abs() < 1e-7);
        assert!((erf(1.0) - 0.8427).abs() < 1e-3);
        assert!((erf(-1.0) + 0.8427).abs() < 1e-3);
        assert!((erf(3.0) - 1.0).abs() < 1e-4);
    }

    #[test]
    fn attention_layouts_agree() {
        let mut rng = Prng::new(5);
        // HND: [b, h, s, d]
        let q = Tensor::randn(&mut rng, &[2, 3, 4, 8]);
        let k = Tensor::randn(&mut rng, &[2, 3, 4, 8]);
        let v = Tensor::randn(&mut rng, &[2, 3, 4, 8]);
        let hnd = attention_hnd(&q, &k, &v);
        // NHD inputs are the permuted views of the same tensors
        let p = |t: &Tensor| t.permute(&[0, 2, 1, 3]).contiguous();
        let nhd = attention_nhd(&p(&q), &p(&k), &p(&v));
        assert!(p(&hnd).allclose(&nhd, 1e-5, 1e-4));
    }

    #[test]
    fn cross_entropy_perfect_prediction_near_zero() {
        let logits = Tensor::from_vec(vec![100., 0., 0., 0., 100., 0.], &[2, 3]);
        let loss = cross_entropy(&logits, &[0, 1]);
        assert!(loss < 1e-5);
    }

    #[test]
    fn rmsnorm_unit_scale() {
        let a = Tensor::from_vec(vec![3., 4.], &[1, 2]);
        let g = Tensor::full(&[2], 1.0);
        let r = rmsnorm(&a, &g, 0.0);
        // rms = sqrt((9+16)/2); x / rms
        let rms = (12.5f32).sqrt();
        assert!((r.at(&[0, 0]) - 3.0 / rms).abs() < 1e-6);
    }

    #[test]
    fn silu_midpoint() {
        let a = Tensor::from_vec(vec![0.0], &[1]);
        assert!((silu(&a).at(&[0]) - 0.0).abs() < 1e-7);
    }
}
