//! Strided f32 tensor substrate.
//!
//! The simulated ML systems ([`crate::systems`]) execute their
//! computational graphs on this library, which gives Magneton real
//! numerics to fingerprint and match. Tensors are `f32` with explicit
//! shape/strides over shared storage, so layout-sensitive behaviours the
//! paper exploits (HND vs NHD attention layouts, non-contiguous
//! LayerNorm inputs, NCHW vs NHWC convolutions) are faithfully
//! represented: `permute` produces a *view* and `contiguous` performs a
//! real copy that the energy model charges for.

pub mod ops;
pub mod nn;
pub mod conv;

use std::sync::Arc;

/// Dense f32 tensor with explicit strides over shared storage.
#[derive(Clone, Debug)]
pub struct Tensor {
    shape: Vec<usize>,
    /// Strides in elements (row-major for freshly created tensors).
    strides: Vec<usize>,
    data: Arc<Vec<f32>>,
    offset: usize,
}

/// Row-major (C-order) strides for a shape.
pub fn contiguous_strides(shape: &[usize]) -> Vec<usize> {
    let mut strides = vec![1usize; shape.len()];
    for i in (0..shape.len().saturating_sub(1)).rev() {
        strides[i] = strides[i + 1] * shape[i + 1];
    }
    strides
}

impl Tensor {
    /// Build from a flat row-major buffer.
    pub fn from_vec(data: Vec<f32>, shape: &[usize]) -> Tensor {
        let numel: usize = shape.iter().product();
        assert_eq!(data.len(), numel, "data length {} != numel {}", data.len(), numel);
        Tensor {
            strides: contiguous_strides(shape),
            shape: shape.to_vec(),
            data: Arc::new(data),
            offset: 0,
        }
    }

    /// All-zeros tensor.
    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor::from_vec(vec![0.0; shape.iter().product()], shape)
    }

    /// Tensor filled with a constant.
    pub fn full(shape: &[usize], v: f32) -> Tensor {
        Tensor::from_vec(vec![v; shape.iter().product()], shape)
    }

    /// Standard-normal tensor from a PRNG (deterministic workloads).
    pub fn randn(rng: &mut crate::util::Prng, shape: &[usize]) -> Tensor {
        Tensor::from_vec(rng.normal_vec(shape.iter().product()), shape)
    }

    /// `arange(0..n)` as f32, shaped.
    pub fn arange(n: usize) -> Tensor {
        Tensor::from_vec((0..n).map(|i| i as f32).collect(), &[n])
    }

    /// Shape accessor.
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    /// Strides accessor (elements).
    pub fn strides(&self) -> &[usize] {
        &self.strides
    }

    /// Number of dimensions.
    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Total element count.
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    /// Size in bytes (f32 elements).
    pub fn bytes(&self) -> usize {
        self.numel() * 4
    }

    /// Whether the view is row-major contiguous.
    pub fn is_contiguous(&self) -> bool {
        self.strides == contiguous_strides(&self.shape)
    }

    /// Element at a multi-index.
    #[inline]
    pub fn at(&self, idx: &[usize]) -> f32 {
        debug_assert_eq!(idx.len(), self.rank());
        let mut off = self.offset;
        for (i, &ix) in idx.iter().enumerate() {
            debug_assert!(ix < self.shape[i]);
            off += ix * self.strides[i];
        }
        self.data[off]
    }

    /// Flat row-major element access (handles non-contiguous views).
    #[inline]
    pub fn at_flat(&self, mut flat: usize) -> f32 {
        let mut off = self.offset;
        for i in (0..self.rank()).rev() {
            let d = self.shape[i];
            off += (flat % d) * self.strides[i];
            flat /= d;
        }
        self.data[off]
    }

    /// Copy out as a flat row-major Vec (materialises views).
    pub fn to_vec(&self) -> Vec<f32> {
        if self.is_contiguous() {
            return self.data[self.offset..self.offset + self.numel()].to_vec();
        }
        let n = self.numel();
        let mut out = Vec::with_capacity(n);
        let rank = self.rank();
        let mut idx = vec![0usize; rank];
        for _ in 0..n {
            out.push(self.at(&idx));
            // increment multi-index (row-major)
            for i in (0..rank).rev() {
                idx[i] += 1;
                if idx[i] < self.shape[i] {
                    break;
                }
                idx[i] = 0;
            }
        }
        out
    }

    /// Values as a borrowed slice when contiguous, else a materialised
    /// copy — the allocation-free fast path for hot kernels.
    pub fn values(&self) -> std::borrow::Cow<'_, [f32]> {
        if self.is_contiguous() {
            std::borrow::Cow::Borrowed(&self.data[self.offset..self.offset + self.numel()])
        } else {
            std::borrow::Cow::Owned(self.to_vec())
        }
    }

    /// Borrow the underlying contiguous slice; panics if not contiguous.
    pub fn as_slice(&self) -> &[f32] {
        assert!(self.is_contiguous(), "as_slice on non-contiguous tensor");
        &self.data[self.offset..self.offset + self.numel()]
    }

    /// Row-major materialised copy.
    pub fn contiguous(&self) -> Tensor {
        if self.is_contiguous() {
            self.clone()
        } else {
            Tensor::from_vec(self.to_vec(), &self.shape)
        }
    }

    /// Reshape (requires contiguous; returns a view sharing storage).
    pub fn reshape(&self, shape: &[usize]) -> Tensor {
        let numel: usize = shape.iter().product();
        assert_eq!(numel, self.numel(), "reshape numel mismatch");
        let base = self.contiguous();
        Tensor {
            strides: contiguous_strides(shape),
            shape: shape.to_vec(),
            data: base.data,
            offset: base.offset,
        }
    }

    /// Permute dimensions — a zero-copy view (layout change only).
    pub fn permute(&self, perm: &[usize]) -> Tensor {
        assert_eq!(perm.len(), self.rank());
        let mut seen = vec![false; perm.len()];
        for &p in perm {
            assert!(p < perm.len() && !seen[p], "invalid permutation {perm:?}");
            seen[p] = true;
        }
        Tensor {
            shape: perm.iter().map(|&p| self.shape[p]).collect(),
            strides: perm.iter().map(|&p| self.strides[p]).collect(),
            data: Arc::clone(&self.data),
            offset: self.offset,
        }
    }

    /// Transpose the last two dims (view).
    pub fn t(&self) -> Tensor {
        let r = self.rank();
        assert!(r >= 2);
        let mut perm: Vec<usize> = (0..r).collect();
        perm.swap(r - 2, r - 1);
        self.permute(&perm)
    }

    /// Slice along `dim`: [start, stop) — a view.
    pub fn slice(&self, dim: usize, start: usize, stop: usize) -> Tensor {
        assert!(dim < self.rank() && start <= stop && stop <= self.shape[dim]);
        let mut shape = self.shape.clone();
        shape[dim] = stop - start;
        Tensor {
            shape,
            strides: self.strides.clone(),
            data: Arc::clone(&self.data),
            offset: self.offset + start * self.strides[dim],
        }
    }

    /// Split into `n` equal chunks along `dim`.
    pub fn split(&self, dim: usize, n: usize) -> Vec<Tensor> {
        assert!(self.shape[dim] % n == 0, "split: {} % {} != 0", self.shape[dim], n);
        let chunk = self.shape[dim] / n;
        (0..n)
            .map(|i| self.slice(dim, i * chunk, (i + 1) * chunk))
            .collect()
    }

    /// Concatenate along `dim` (materialises).
    pub fn concat(parts: &[&Tensor], dim: usize) -> Tensor {
        assert!(!parts.is_empty());
        let mut shape = parts[0].shape.to_vec();
        for p in &parts[1..] {
            assert_eq!(p.rank(), shape.len());
            for (i, (&a, &b)) in shape.iter().zip(p.shape.iter()).enumerate() {
                if i != dim {
                    assert_eq!(a, b, "concat shape mismatch on dim {i}");
                }
            }
        }
        shape[dim] = parts.iter().map(|p| p.shape[dim]).sum();
        let outer: usize = shape[..dim].iter().product();
        let inner: usize = shape[dim + 1..].iter().product();
        let mut out = Vec::with_capacity(shape.iter().product());
        let mats: Vec<Vec<f32>> = parts.iter().map(|p| p.to_vec()).collect();
        for o in 0..outer {
            for (p, mat) in parts.iter().zip(mats.iter()) {
                let rows = p.shape[dim];
                let start = o * rows * inner;
                out.extend_from_slice(&mat[start..start + rows * inner]);
            }
        }
        Tensor::from_vec(out, &shape)
    }

    /// Max |a - b| over all elements (shape-checked).
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        let a = self.to_vec();
        let b = other.to_vec();
        a.iter()
            .zip(b.iter())
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f32, f32::max)
    }

    /// Max element-wise relative difference (the paper's ≤1 % output guard).
    pub fn max_rel_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        let a = self.to_vec();
        let b = other.to_vec();
        a.iter()
            .zip(b.iter())
            .map(|(x, y)| (x - y).abs() / x.abs().max(y.abs()).max(1e-6))
            .fold(0.0f32, f32::max)
    }

    /// Largest absolute element value.
    pub fn max_abs(&self) -> f32 {
        self.to_vec().iter().map(|x| x.abs()).fold(0.0, f32::max)
    }

    /// Globally-normalised relative difference: max |a−b| over the
    /// larger of the two tensors' max-magnitudes. This is the output
    /// guard used by detection — element-wise relative error diverges
    /// meaninglessly on near-zero entries.
    pub fn global_rel_diff(&self, other: &Tensor) -> f32 {
        let scale = self.max_abs().max(other.max_abs()).max(1e-12);
        self.max_abs_diff(other) / scale
    }

    /// allclose with absolute + relative tolerance.
    pub fn allclose(&self, other: &Tensor, atol: f32, rtol: f32) -> bool {
        if self.shape != other.shape {
            return false;
        }
        let a = self.to_vec();
        let b = other.to_vec();
        a.iter()
            .zip(b.iter())
            .all(|(x, y)| (x - y).abs() <= atol + rtol * y.abs())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Prng;

    #[test]
    fn from_vec_roundtrip() {
        let t = Tensor::from_vec(vec![1., 2., 3., 4., 5., 6.], &[2, 3]);
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.at(&[1, 2]), 6.0);
        assert_eq!(t.to_vec(), vec![1., 2., 3., 4., 5., 6.]);
        assert!(t.is_contiguous());
    }

    #[test]
    fn permute_is_view_and_correct() {
        let t = Tensor::from_vec((0..24).map(|i| i as f32).collect(), &[2, 3, 4]);
        let p = t.permute(&[2, 0, 1]);
        assert_eq!(p.shape(), &[4, 2, 3]);
        assert!(!p.is_contiguous());
        assert_eq!(p.at(&[3, 1, 2]), t.at(&[1, 2, 3]));
        // materialisation round-trips through the inverse permutation
        let back = p.permute(&[1, 2, 0]);
        assert_eq!(back.to_vec(), t.to_vec());
    }

    #[test]
    fn transpose_2d() {
        let t = Tensor::from_vec(vec![1., 2., 3., 4., 5., 6.], &[2, 3]);
        let tt = t.t();
        assert_eq!(tt.shape(), &[3, 2]);
        assert_eq!(tt.to_vec(), vec![1., 4., 2., 5., 3., 6.]);
    }

    #[test]
    fn reshape_preserves_order() {
        let t = Tensor::from_vec((0..12).map(|i| i as f32).collect(), &[3, 4]);
        let r = t.reshape(&[2, 6]);
        assert_eq!(r.to_vec(), t.to_vec());
    }

    #[test]
    fn slice_and_split() {
        let t = Tensor::from_vec((0..12).map(|i| i as f32).collect(), &[3, 4]);
        let s = t.slice(0, 1, 3);
        assert_eq!(s.shape(), &[2, 4]);
        assert_eq!(s.at(&[0, 0]), 4.0);
        let parts = t.split(1, 2);
        assert_eq!(parts.len(), 2);
        assert_eq!(parts[0].shape(), &[3, 2]);
        assert_eq!(parts[1].at(&[0, 0]), 2.0);
    }

    #[test]
    fn concat_inverts_split() {
        let mut rng = Prng::new(1);
        let t = Tensor::randn(&mut rng, &[4, 6]);
        for dim in 0..2 {
            let parts = t.split(dim, 2);
            let refs: Vec<&Tensor> = parts.iter().collect();
            let cat = Tensor::concat(&refs, dim);
            assert_eq!(cat.to_vec(), t.to_vec());
        }
    }

    #[test]
    fn concat_along_middle_dim() {
        let a = Tensor::from_vec((0..8).map(|i| i as f32).collect(), &[2, 2, 2]);
        let b = Tensor::full(&[2, 1, 2], 9.0);
        let c = Tensor::concat(&[&a, &b], 1);
        assert_eq!(c.shape(), &[2, 3, 2]);
        assert_eq!(c.at(&[0, 2, 0]), 9.0);
        assert_eq!(c.at(&[1, 1, 1]), 7.0);
    }

    #[test]
    fn at_flat_matches_to_vec() {
        let t = Tensor::from_vec((0..24).map(|i| i as f32).collect(), &[2, 3, 4]);
        let p = t.permute(&[1, 0, 2]);
        let v = p.to_vec();
        for i in 0..p.numel() {
            assert_eq!(p.at_flat(i), v[i]);
        }
    }

    #[test]
    fn allclose_tolerances() {
        let a = Tensor::from_vec(vec![1.0, 2.0], &[2]);
        let b = Tensor::from_vec(vec![1.0 + 1e-6, 2.0 - 1e-6], &[2]);
        assert!(a.allclose(&b, 1e-5, 0.0));
        assert!(!a.allclose(&b, 1e-8, 0.0));
    }

    #[test]
    #[should_panic(expected = "reshape numel mismatch")]
    fn reshape_bad_numel_panics() {
        Tensor::zeros(&[2, 3]).reshape(&[4, 2]);
    }

    #[test]
    fn contiguous_materialises_views() {
        let t = Tensor::from_vec((0..6).map(|i| i as f32).collect(), &[2, 3]);
        let v = t.t();
        assert!(!v.is_contiguous());
        let c = v.contiguous();
        assert!(c.is_contiguous());
        assert_eq!(c.to_vec(), v.to_vec());
    }
}
