//! Elementwise, matmul, and reduction kernels over [`Tensor`].
//!
//! These are the *numerics* behind the simulated ML systems' operators;
//! the energy model charges for them separately via kernel descriptors
//! (see [`crate::energy`]). `matmul` supports an optional TF32-style
//! mantissa truncation so the `allow_tf32` misconfiguration cases (c1,
//! c8, pytorch-153195) produce genuinely different numerics within the
//! paper's ≤1 % output-difference guard.

use super::Tensor;

/// Truncate an f32 mantissa to 10 bits — the TF32 input rounding
/// performed by tensor cores.
#[inline]
pub fn tf32_round(x: f32) -> f32 {
    f32::from_bits(x.to_bits() & 0xFFFF_E000)
}

/// Elementwise binary op with trailing broadcast (b may be a vector of
/// size = last dim, or a scalar, or the full shape).
fn zip_broadcast(a: &Tensor, b: &Tensor, f: impl Fn(f32, f32) -> f32) -> Tensor {
    let av = a.to_vec();
    let n = av.len();
    let out: Vec<f32> = if b.shape() == a.shape() {
        let bv = b.to_vec();
        av.iter().zip(bv.iter()).map(|(&x, &y)| f(x, y)).collect()
    } else if b.numel() == 1 {
        let y = b.at_flat(0);
        av.iter().map(|&x| f(x, y)).collect()
    } else {
        // broadcast along the last dimension
        let last = *a.shape().last().expect("rank >= 1");
        assert_eq!(
            b.numel(),
            last,
            "broadcast requires b to be scalar, last-dim vector, or same shape"
        );
        let bv = b.to_vec();
        (0..n).map(|i| f(av[i], bv[i % last])).collect()
    };
    Tensor::from_vec(out, a.shape())
}

/// a + b (with trailing broadcast).
pub fn add(a: &Tensor, b: &Tensor) -> Tensor {
    zip_broadcast(a, b, |x, y| x + y)
}

/// a - b (with trailing broadcast).
pub fn sub(a: &Tensor, b: &Tensor) -> Tensor {
    zip_broadcast(a, b, |x, y| x - y)
}

/// a * b (with trailing broadcast).
pub fn mul(a: &Tensor, b: &Tensor) -> Tensor {
    zip_broadcast(a, b, |x, y| x * y)
}

/// a / b (with trailing broadcast).
pub fn div(a: &Tensor, b: &Tensor) -> Tensor {
    zip_broadcast(a, b, |x, y| x / y)
}

/// a * scalar.
pub fn scale(a: &Tensor, s: f32) -> Tensor {
    Tensor::from_vec(a.to_vec().iter().map(|&x| x * s).collect(), a.shape())
}

/// Unary map.
pub fn map(a: &Tensor, f: impl Fn(f32) -> f32) -> Tensor {
    Tensor::from_vec(a.to_vec().iter().map(|&x| f(x)).collect(), a.shape())
}

/// Matrix multiply over the last two dims with leading-batch handling:
/// `[.., m, k] x [.., k, n] -> [.., m, n]`; `b` may omit batch dims.
/// `tf32` truncates inputs to 10-bit mantissas (tensor-core emulation).
pub fn matmul_ex(a: &Tensor, b: &Tensor, tf32: bool) -> Tensor {
    let ar = a.rank();
    let br = b.rank();
    assert!(ar >= 2 && br >= 2, "matmul requires rank >= 2");
    let (m, k) = (a.shape()[ar - 2], a.shape()[ar - 1]);
    let (kb, n) = (b.shape()[br - 2], b.shape()[br - 1]);
    assert_eq!(k, kb, "matmul inner-dim mismatch: {k} vs {kb}");
    let batch: usize = a.shape()[..ar - 2].iter().product();
    let b_batch: usize = b.shape()[..br - 2].iter().product();
    assert!(
        b_batch == batch || b_batch == 1,
        "matmul batch mismatch: {batch} vs {b_batch}"
    );
    let av = a.values();
    let bv = b.values();
    let prep = |x: f32| if tf32 { tf32_round(x) } else { x };
    let mut out = vec![0.0f32; batch * m * n];
    for bi in 0..batch {
        let abase = bi * m * k;
        let bbase = if b_batch == 1 { 0 } else { bi * k * n };
        let obase = bi * m * n;
        // ikj loop order: streams through b rows, accumulates into out rows.
        for i in 0..m {
            let arow = &av[abase + i * k..abase + (i + 1) * k];
            let orow = &mut out[obase + i * n..obase + (i + 1) * n];
            for (kk, &aik) in arow.iter().enumerate() {
                let aik = prep(aik);
                if aik == 0.0 {
                    continue;
                }
                let brow = &bv[bbase + kk * n..bbase + (kk + 1) * n];
                for (j, &bkj) in brow.iter().enumerate() {
                    orow[j] += aik * prep(bkj);
                }
            }
        }
    }
    let mut shape = a.shape()[..ar - 2].to_vec();
    shape.push(m);
    shape.push(n);
    Tensor::from_vec(out, &shape)
}

/// Standard f32 matmul.
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    matmul_ex(a, b, false)
}

/// Fused `bias + a @ b` (torch.addmm semantics; bias broadcast on rows).
pub fn addmm(bias: &Tensor, a: &Tensor, b: &Tensor, tf32: bool) -> Tensor {
    let mm = matmul_ex(a, b, tf32);
    add(&mm, bias)
}

/// Sum over all elements.
pub fn sum_all(a: &Tensor) -> f32 {
    a.to_vec().iter().sum()
}

/// Mean over all elements.
pub fn mean_all(a: &Tensor) -> f32 {
    sum_all(a) / a.numel() as f32
}

/// Reduce-sum along `dim` (keeps remaining dims).
pub fn sum_dim(a: &Tensor, dim: usize) -> Tensor {
    let shape = a.shape();
    assert!(dim < shape.len());
    let outer: usize = shape[..dim].iter().product();
    let d = shape[dim];
    let inner: usize = shape[dim + 1..].iter().product();
    let v = a.to_vec();
    let mut out = vec![0.0f32; outer * inner];
    for o in 0..outer {
        for j in 0..d {
            let base = (o * d + j) * inner;
            for i in 0..inner {
                out[o * inner + i] += v[base + i];
            }
        }
    }
    let mut oshape: Vec<usize> = shape[..dim].to_vec();
    oshape.extend_from_slice(&shape[dim + 1..]);
    if oshape.is_empty() {
        oshape.push(1);
    }
    Tensor::from_vec(out, &oshape)
}

/// Row-wise max along the last dim.
pub fn max_lastdim(a: &Tensor) -> Tensor {
    let shape = a.shape();
    let last = *shape.last().unwrap();
    let rows = a.numel() / last;
    let v = a.to_vec();
    let out: Vec<f32> = (0..rows)
        .map(|r| v[r * last..(r + 1) * last].iter().cloned().fold(f32::MIN, f32::max))
        .collect();
    Tensor::from_vec(out, &shape[..shape.len() - 1])
}

/// Count of non-zero elements (TF `count_nonzero`, case c16).
pub fn count_nonzero(a: &Tensor) -> usize {
    a.to_vec().iter().filter(|&&x| x != 0.0).count()
}

/// Top-k values along the last dim, descending (SGLang top-k, case c3).
pub fn topk_lastdim(a: &Tensor, k: usize) -> Tensor {
    let shape = a.shape();
    let last = *shape.last().unwrap();
    assert!(k <= last);
    let rows = a.numel() / last;
    let v = a.to_vec();
    let mut out = Vec::with_capacity(rows * k);
    for r in 0..rows {
        let mut row: Vec<f32> = v[r * last..(r + 1) * last].to_vec();
        row.sort_by(|x, y| y.total_cmp(x));
        out.extend_from_slice(&row[..k]);
    }
    let mut oshape = shape[..shape.len() - 1].to_vec();
    oshape.push(k);
    Tensor::from_vec(out, &oshape)
}

/// `repeat_interleave` along `dim` (Megatron GQA key/value expansion, c4).
pub fn repeat_interleave(a: &Tensor, dim: usize, reps: usize) -> Tensor {
    let shape = a.shape();
    let outer: usize = shape[..dim].iter().product();
    let d = shape[dim];
    let inner: usize = shape[dim + 1..].iter().product();
    let v = a.to_vec();
    let mut out = Vec::with_capacity(v.len() * reps);
    for o in 0..outer {
        for j in 0..d {
            let base = (o * d + j) * inner;
            for _ in 0..reps {
                out.extend_from_slice(&v[base..base + inner]);
            }
        }
    }
    let mut oshape = shape.to_vec();
    oshape[dim] = d * reps;
    Tensor::from_vec(out, &oshape)
}

/// Sort along the last dim, descending (the inefficient top-k path of
/// case c3 sorts the full row before slicing).
pub fn sort_lastdim_desc(a: &Tensor) -> Tensor {
    let shape = a.shape();
    let last = *shape.last().unwrap();
    let rows = a.numel() / last;
    let v = a.to_vec();
    let mut out = Vec::with_capacity(v.len());
    for r in 0..rows {
        let mut row: Vec<f32> = v[r * last..(r + 1) * last].to_vec();
        row.sort_by(|x, y| y.total_cmp(x));
        out.extend_from_slice(&row);
    }
    Tensor::from_vec(out, shape)
}

/// Cumulative sum along the last dim.
pub fn cumsum_lastdim(a: &Tensor) -> Tensor {
    let shape = a.shape();
    let last = *shape.last().unwrap();
    let rows = a.numel() / last;
    let v = a.to_vec();
    let mut out = Vec::with_capacity(v.len());
    for r in 0..rows {
        let mut acc = 0.0f32;
        for j in 0..last {
            acc += v[r * last + j];
            out.push(acc);
        }
    }
    Tensor::from_vec(out, shape)
}

/// Embedding lookup: ids (flat, values cast to usize) into table [v, h].
pub fn embedding(table: &Tensor, ids: &[usize]) -> Tensor {
    assert_eq!(table.rank(), 2);
    let h = table.shape()[1];
    let tv = table.to_vec();
    let mut out = Vec::with_capacity(ids.len() * h);
    for &id in ids {
        assert!(id < table.shape()[0], "embedding id {id} out of range");
        out.extend_from_slice(&tv[id * h..(id + 1) * h]);
    }
    Tensor::from_vec(out, &[ids.len(), h])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Prng;

    #[test]
    fn matmul_small_known() {
        let a = Tensor::from_vec(vec![1., 2., 3., 4.], &[2, 2]);
        let b = Tensor::from_vec(vec![1., 1., 1., 1.], &[2, 2]);
        assert_eq!(matmul(&a, &b).to_vec(), vec![3., 3., 7., 7.]);
    }

    #[test]
    fn matmul_batched_broadcast_b() {
        let mut rng = Prng::new(2);
        let a = Tensor::randn(&mut rng, &[3, 4, 5]);
        let b = Tensor::randn(&mut rng, &[5, 6]);
        let c = matmul(&a, &b);
        assert_eq!(c.shape(), &[3, 4, 6]);
        // slice 0 equals standalone matmul of slice 0
        let a0 = a.slice(0, 0, 1).reshape(&[4, 5]);
        let c0 = matmul(&a0, &b);
        assert!(c.slice(0, 0, 1).reshape(&[4, 6]).allclose(&c0, 1e-6, 1e-6));
    }

    #[test]
    fn addmm_equals_add_plus_mm() {
        let mut rng = Prng::new(3);
        let a = Tensor::randn(&mut rng, &[8, 16]);
        let b = Tensor::randn(&mut rng, &[16, 12]);
        let bias = Tensor::randn(&mut rng, &[12]);
        let fused = addmm(&bias, &a, &b, false);
        let unfused = add(&matmul(&a, &b), &bias);
        assert!(fused.allclose(&unfused, 1e-6, 1e-6));
    }

    #[test]
    fn tf32_differs_slightly_but_within_1pct() {
        let mut rng = Prng::new(4);
        let a = Tensor::randn(&mut rng, &[32, 64]);
        let b = Tensor::randn(&mut rng, &[64, 32]);
        let exact = matmul_ex(&a, &b, false);
        let tf32 = matmul_ex(&a, &b, true);
        let d = exact.max_abs_diff(&tf32);
        assert!(d > 0.0, "tf32 must change numerics");
        // relative error stays small (paper's <=1% guard)
        let denom = exact.to_vec().iter().map(|x| x.abs()).fold(0.0f32, f32::max);
        assert!(d / denom < 0.01, "rel err {}", d / denom);
    }

    #[test]
    fn broadcast_modes() {
        let a = Tensor::from_vec(vec![1., 2., 3., 4.], &[2, 2]);
        let s = Tensor::from_vec(vec![10.], &[1]);
        assert_eq!(add(&a, &s).to_vec(), vec![11., 12., 13., 14.]);
        let v = Tensor::from_vec(vec![10., 20.], &[2]);
        assert_eq!(add(&a, &v).to_vec(), vec![11., 22., 13., 24.]);
    }

    #[test]
    fn sum_dim_matches_manual() {
        let a = Tensor::from_vec((0..24).map(|i| i as f32).collect(), &[2, 3, 4]);
        let s = sum_dim(&a, 1);
        assert_eq!(s.shape(), &[2, 4]);
        assert_eq!(s.at(&[0, 0]), 0. + 4. + 8.);
        assert_eq!(s.at(&[1, 3]), 15. + 19. + 23.);
    }

    #[test]
    fn topk_sorted_desc() {
        let a = Tensor::from_vec(vec![3., 1., 4., 1., 5., 9., 2., 6.], &[2, 4]);
        let t = topk_lastdim(&a, 2);
        assert_eq!(t.to_vec(), vec![4., 3., 9., 6.]);
    }

    #[test]
    fn repeat_interleave_expands() {
        let a = Tensor::from_vec(vec![1., 2., 3., 4.], &[2, 2]);
        let r = repeat_interleave(&a, 0, 2);
        assert_eq!(r.shape(), &[4, 2]);
        assert_eq!(r.to_vec(), vec![1., 2., 1., 2., 3., 4., 3., 4.]);
    }

    #[test]
    fn count_nonzero_counts() {
        let a = Tensor::from_vec(vec![0., 1., 0., 2., 3., 0.], &[6]);
        assert_eq!(count_nonzero(&a), 3);
    }

    #[test]
    fn embedding_lookup() {
        let table = Tensor::from_vec((0..6).map(|i| i as f32).collect(), &[3, 2]);
        let e = embedding(&table, &[2, 0]);
        assert_eq!(e.to_vec(), vec![4., 5., 0., 1.]);
    }

    #[test]
    fn sort_then_slice_equals_topk() {
        let a = Tensor::from_vec(vec![3., 1., 4., 1., 5., 9., 2., 6.], &[2, 4]);
        let sorted = sort_lastdim_desc(&a);
        let sliced = sorted.slice(1, 0, 2).contiguous();
        assert_eq!(sliced.to_vec(), topk_lastdim(&a, 2).to_vec());
    }

    #[test]
    fn cumsum_lastdim_known() {
        let a = Tensor::from_vec(vec![1., 2., 3., 4.], &[2, 2]);
        assert_eq!(cumsum_lastdim(&a).to_vec(), vec![1., 3., 3., 7.]);
    }

    #[test]
    fn matmul_on_views_matches_contiguous() {
        let mut rng = Prng::new(5);
        let a = Tensor::randn(&mut rng, &[6, 8]);
        let at_view = a.t(); // non-contiguous view
        let b = Tensor::randn(&mut rng, &[6, 4]);
        let via_view = matmul(&at_view, &b);
        let via_copy = matmul(&at_view.contiguous(), &b);
        assert!(via_view.allclose(&via_copy, 1e-6, 1e-6));
    }
}
