//! 2-D convolution in NCHW and NHWC layouts, plus grouped convolution.
//!
//! The layout split matters for the paper's cases: Fig 5c compares conv
//! energy across PyTorch/TF/JAX, and two of the new issues
//! (pytorch-157334, jax-29875, tf-96396) are layout-dependent kernel
//! inefficiencies. Both layouts compute identical values; the energy
//! model charges different memory-access costs per (layout, kernel
//! variant) pair.

use super::Tensor;

/// Direct convolution, NCHW input `[n, c, h, w]`, weight `[o, c/g, kh, kw]`,
/// stride 1, symmetric zero padding, `groups` channel groups.
pub fn conv2d_nchw(x: &Tensor, w: &Tensor, pad: usize, groups: usize) -> Tensor {
    assert_eq!(x.rank(), 4);
    assert_eq!(w.rank(), 4);
    let (n, c, h, wd) = (x.shape()[0], x.shape()[1], x.shape()[2], x.shape()[3]);
    let (oc, icg, kh, kw) = (w.shape()[0], w.shape()[1], w.shape()[2], w.shape()[3]);
    assert_eq!(c % groups, 0);
    assert_eq!(oc % groups, 0);
    assert_eq!(icg, c / groups, "weight in-channels/groups mismatch");
    let oh = h + 2 * pad - kh + 1;
    let ow = wd + 2 * pad - kw + 1;
    let xv = x.to_vec();
    let wv = w.to_vec();
    let mut out = vec![0.0f32; n * oc * oh * ow];
    let ocg = oc / groups;
    for ni in 0..n {
        for g in 0..groups {
            for ocl in 0..ocg {
                let o = g * ocg + ocl;
                for oy in 0..oh {
                    for ox in 0..ow {
                        let mut acc = 0.0f32;
                        for icl in 0..icg {
                            let ci = g * icg + icl;
                            for ky in 0..kh {
                                let iy = oy + ky;
                                if iy < pad || iy >= h + pad {
                                    continue;
                                }
                                let iy = iy - pad;
                                for kx in 0..kw {
                                    let ix = ox + kx;
                                    if ix < pad || ix >= wd + pad {
                                        continue;
                                    }
                                    let ix = ix - pad;
                                    let xi = ((ni * c + ci) * h + iy) * wd + ix;
                                    let wi = ((o * icg + icl) * kh + ky) * kw + kx;
                                    acc += xv[xi] * wv[wi];
                                }
                            }
                        }
                        out[((ni * oc + o) * oh + oy) * ow + ox] = acc;
                    }
                }
            }
        }
    }
    Tensor::from_vec(out, &[n, oc, oh, ow])
}

/// NHWC convolution: input `[n, h, w, c]`, same weight layout
/// `[o, c/g, kh, kw]`; computed by converting layout, so values match
/// [`conv2d_nchw`] exactly. The executor charges NHWC-variant memory
/// costs for it.
pub fn conv2d_nhwc(x: &Tensor, w: &Tensor, pad: usize, groups: usize) -> Tensor {
    let x_nchw = x.permute(&[0, 3, 1, 2]).contiguous();
    let o = conv2d_nchw(&x_nchw, w, pad, groups);
    o.permute(&[0, 2, 3, 1]).contiguous()
}

/// im2col + GEMM convolution (the "algorithm selection" alternative some
/// frameworks dispatch to). Identical values; different cost profile —
/// a large intermediate matrix is materialised.
pub fn conv2d_im2col(x: &Tensor, w: &Tensor, pad: usize) -> Tensor {
    assert_eq!(x.rank(), 4);
    let (n, c, h, wd) = (x.shape()[0], x.shape()[1], x.shape()[2], x.shape()[3]);
    let (oc, ic, kh, kw) = (w.shape()[0], w.shape()[1], w.shape()[2], w.shape()[3]);
    assert_eq!(ic, c);
    let oh = h + 2 * pad - kh + 1;
    let ow = wd + 2 * pad - kw + 1;
    let xv = x.to_vec();
    // cols: [n*oh*ow, c*kh*kw]
    let mut cols = vec![0.0f32; n * oh * ow * c * kh * kw];
    let row_len = c * kh * kw;
    for ni in 0..n {
        for oy in 0..oh {
            for ox in 0..ow {
                let row = (ni * oh + oy) * ow + ox;
                for ci in 0..c {
                    for ky in 0..kh {
                        for kx in 0..kw {
                            let iy = oy + ky;
                            let ix = ox + kx;
                            if iy < pad || iy >= h + pad || ix < pad || ix >= wd + pad {
                                continue;
                            }
                            let v = xv[((ni * c + ci) * h + (iy - pad)) * wd + (ix - pad)];
                            cols[row * row_len + (ci * kh + ky) * kw + kx] = v;
                        }
                    }
                }
            }
        }
    }
    let cols_t = Tensor::from_vec(cols, &[n * oh * ow, row_len]);
    let w_t = Tensor::from_vec(w.to_vec(), &[oc, row_len]);
    let out = super::ops::matmul(&cols_t, &w_t.t()); // [n*oh*ow, oc]
    out.reshape(&[n, oh, ow, oc]).permute(&[0, 3, 1, 2]).contiguous()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Prng;

    #[test]
    fn identity_kernel_passthrough() {
        // 1x1 kernel with weight 1 on a single channel = identity
        let x = Tensor::from_vec((0..16).map(|i| i as f32).collect(), &[1, 1, 4, 4]);
        let w = Tensor::from_vec(vec![1.0], &[1, 1, 1, 1]);
        let y = conv2d_nchw(&x, &w, 0, 1);
        assert_eq!(y.to_vec(), x.to_vec());
    }

    #[test]
    fn box_filter_sums() {
        let x = Tensor::full(&[1, 1, 3, 3], 1.0);
        let w = Tensor::full(&[1, 1, 3, 3], 1.0);
        let y = conv2d_nchw(&x, &w, 1, 1);
        assert_eq!(y.shape(), &[1, 1, 3, 3]);
        // centre sees all 9 ones; corner sees 4
        assert_eq!(y.at(&[0, 0, 1, 1]), 9.0);
        assert_eq!(y.at(&[0, 0, 0, 0]), 4.0);
    }

    #[test]
    fn nhwc_matches_nchw() {
        let mut rng = Prng::new(1);
        let x = Tensor::randn(&mut rng, &[2, 3, 8, 8]);
        let w = Tensor::randn(&mut rng, &[4, 3, 3, 3]);
        let a = conv2d_nchw(&x, &w, 1, 1);
        let x_nhwc = x.permute(&[0, 2, 3, 1]).contiguous();
        let b = conv2d_nhwc(&x_nhwc, &w, 1, 1);
        let b_nchw = b.permute(&[0, 3, 1, 2]).contiguous();
        assert!(a.allclose(&b_nchw, 1e-5, 1e-5));
    }

    #[test]
    fn im2col_matches_direct() {
        let mut rng = Prng::new(2);
        let x = Tensor::randn(&mut rng, &[2, 3, 6, 6]);
        let w = Tensor::randn(&mut rng, &[5, 3, 3, 3]);
        let a = conv2d_nchw(&x, &w, 1, 1);
        let b = conv2d_im2col(&x, &w, 1);
        assert!(a.allclose(&b, 1e-4, 1e-4));
    }

    #[test]
    fn grouped_conv_partitions_channels() {
        let mut rng = Prng::new(3);
        let x = Tensor::randn(&mut rng, &[1, 4, 5, 5]);
        let w = Tensor::randn(&mut rng, &[4, 2, 3, 3]);
        let y = conv2d_nchw(&x, &w, 1, 2);
        assert_eq!(y.shape(), &[1, 4, 5, 5]);
        // group 0 output depends only on channels 0..2: zeroing 2..4 must not change it
        let mut xz = x.to_vec();
        for ci in 2..4 {
            for i in 0..25 {
                xz[ci * 25 + i] = 0.0;
            }
        }
        let y2 = conv2d_nchw(&Tensor::from_vec(xz, &[1, 4, 5, 5]), &w, 1, 2);
        let g0 = y.slice(1, 0, 2);
        let g0b = y2.slice(1, 0, 2);
        assert!(g0.contiguous().allclose(&g0b.contiguous(), 1e-6, 1e-6));
    }

    #[test]
    fn output_shape_with_padding() {
        let x = Tensor::zeros(&[1, 2, 7, 9]);
        let w = Tensor::zeros(&[3, 2, 3, 3]);
        let y = conv2d_nchw(&x, &w, 1, 1);
        assert_eq!(y.shape(), &[1, 3, 7, 9]);
        let y0 = conv2d_nchw(&x, &w, 0, 1);
        assert_eq!(y0.shape(), &[1, 3, 5, 7]);
    }
}
