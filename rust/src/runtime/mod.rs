//! PJRT/XLA runtime: loads the AOT-compiled artifacts produced by
//! `python/compile/aot.py` (HLO *text* — see DESIGN.md) and executes
//! them on the CPU PJRT client from the Rust hot path. Python never runs
//! at profiling time.
//!
//! The offline registry does not carry the `xla` crate, so the default
//! build ships a stub: every constructor returns a descriptive
//! [`crate::Error`] and callers fall back to the pure-Rust moment engine
//! ([`crate::fingerprint::RustMomentEngine`]). The `pjrt` cargo feature
//! is a reservation for re-introducing the real binding from a vendored
//! `xla` crate — enabling it today is a hard compile error (see below)
//! rather than a silently broken build.
//!
//! Two uses:
//! * [`PjrtMomentEngine`] — the L1 Pallas fingerprint kernel, compiled
//!   once per canonical matrix shape; tensors are zero-padded up to the
//!   nearest canonical shape (zero rows/columns leave Gram-trace
//!   moments unchanged) and the Rust engine remains the fallback.
//! * Reference-model execution — the jax-lowered GPT-2 block variants,
//!   used by integration tests to validate the Rust executor's
//!   numerics against XLA.

use std::path::{Path, PathBuf};
use std::sync::atomic::AtomicUsize;

use crate::fingerprint::{MomentEngine, RustMomentEngine, MOMENT_ORDER};
use crate::tensor::Tensor;
use crate::{Error, Result};

#[cfg(feature = "pjrt")]
compile_error!(
    "the `pjrt` feature requires the xla-backed runtime implementation, which is not \
     vendored in this tree; restore it in src/runtime/ before enabling the feature"
);

/// Canonical fingerprint-kernel shapes compiled by `aot.py`
/// (rows × cols). Keep in sync with `python/compile/aot.py::FP_SHAPES`.
pub const FP_SHAPES: &[(usize, usize)] = &[(32, 256), (64, 1024), (128, 4096)];

/// Default artifact directory (workspace-relative).
pub fn default_artifact_dir() -> PathBuf {
    // honour MAGNETON_ARTIFACTS, else walk up from cwd looking for
    // an `artifacts/` directory
    if let Ok(p) = std::env::var("MAGNETON_ARTIFACTS") {
        return PathBuf::from(p);
    }
    let mut dir = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
    loop {
        let cand = dir.join("artifacts");
        if cand.is_dir() {
            return cand;
        }
        if !dir.pop() {
            return PathBuf::from("artifacts");
        }
    }
}

#[cfg(not(feature = "pjrt"))]
fn backend_unavailable() -> Error {
    Error::msg(
        "PJRT backend not built: enable the `pjrt` cargo feature with a vendored \
         `xla` crate (the Rust moment engine remains the fallback)",
    )
}

/// A PJRT CPU runtime holding compiled executables by name.
///
/// Without the `pjrt` feature this is a stub whose constructor fails;
/// the type and its methods exist so call sites compile unchanged.
pub struct PjrtRuntime {
    /// Names of loaded artifacts (stub build: always empty).
    names: Vec<String>,
}

#[cfg(not(feature = "pjrt"))]
impl PjrtRuntime {
    /// Create a CPU PJRT client. Stub build: always fails.
    pub fn cpu() -> Result<PjrtRuntime> {
        Err(backend_unavailable())
    }

    /// Load and compile one HLO-text artifact under `name`.
    pub fn load_file(&mut self, _name: &str, path: &Path) -> Result<()> {
        Err(backend_unavailable().context(format!("load {path:?}")))
    }

    /// Load every `*.hlo.txt` in a directory; returns how many loaded.
    pub fn load_dir(&mut self, dir: &Path) -> Result<usize> {
        Err(backend_unavailable().context(format!("load dir {dir:?}")))
    }

    pub fn has(&self, name: &str) -> bool {
        self.names.iter().any(|n| n == name)
    }

    pub fn names(&self) -> Vec<&str> {
        self.names.iter().map(String::as_str).collect()
    }

    /// Execute an artifact on f32 inputs; returns all tuple outputs as
    /// flat vectors. Stub build: always fails.
    pub fn execute_f32(&self, name: &str, _inputs: &[(&[f32], &[usize])]) -> Result<Vec<Vec<f32>>> {
        Err(backend_unavailable().context(format!("execute {name}")))
    }
}

/// Moment engine backed by the Pallas fingerprint kernel compiled to a
/// PJRT executable. Falls back to the Rust engine when no canonical
/// shape fits (or, in stub builds, for every call).
pub struct PjrtMomentEngine {
    fallback: RustMomentEngine,
    /// Count of PJRT-served vs fallback calls (perf accounting).
    pub served: AtomicUsize,
    pub fell_back: AtomicUsize,
}

impl PjrtMomentEngine {
    /// Load fingerprint artifacts from `dir`. Errors if none found or
    /// (stub build) the PJRT backend is not compiled in.
    #[cfg(not(feature = "pjrt"))]
    pub fn load(dir: &Path) -> Result<PjrtMomentEngine> {
        let found = FP_SHAPES
            .iter()
            .filter(|(m, n)| dir.join(format!("fingerprint_{m}x{n}.hlo.txt")).exists())
            .count();
        if found == 0 {
            return Err(Error::msg(format!(
                "no fingerprint artifacts in {dir:?} (run `make artifacts`)"
            )));
        }
        Err(backend_unavailable())
    }

    /// Smallest canonical shape that fits (rows ≤ m, cols ≤ n).
    fn canonical_for(rows: usize, cols: usize) -> Option<(usize, usize)> {
        FP_SHAPES
            .iter()
            .copied()
            .find(|&(m, n)| rows <= m && cols <= n)
    }
}

impl MomentEngine for PjrtMomentEngine {
    fn moments(&self, mat: &Tensor, order: usize) -> Vec<f64> {
        use std::sync::atomic::Ordering::Relaxed;
        let (rows, cols) = (mat.shape()[0], mat.shape()[1]);
        if Self::canonical_for(rows, cols).is_none() || order > MOMENT_ORDER {
            self.fell_back.fetch_add(1, Relaxed);
            return self.fallback.moments(mat, order);
        }
        // Stub build: the kernel cannot be invoked, every call falls back.
        self.fell_back.fetch_add(1, Relaxed);
        self.fallback.moments(mat, order)
    }

    fn name(&self) -> &'static str {
        "pjrt-pallas"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// These tests exercise the real PJRT path and are skipped when
    /// `make artifacts` has not run yet (or in stub builds, where
    /// `load` fails and `engine()` returns None).
    fn engine() -> Option<PjrtMomentEngine> {
        let dir = default_artifact_dir();
        PjrtMomentEngine::load(&dir).ok()
    }

    #[test]
    fn canonical_shape_selection() {
        assert_eq!(PjrtMomentEngine::canonical_for(10, 100), Some((32, 256)));
        assert_eq!(PjrtMomentEngine::canonical_for(64, 1024), Some((64, 1024)));
        assert_eq!(PjrtMomentEngine::canonical_for(4096, 4096), None);
    }

    #[test]
    fn stub_runtime_reports_unavailable() {
        if cfg!(feature = "pjrt") {
            return;
        }
        let err = PjrtRuntime::cpu().err().expect("stub must fail");
        assert!(err.to_string().contains("pjrt"), "{err}");
    }

    #[test]
    fn pjrt_moments_match_rust_engine() {
        let Some(eng) = engine() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let mut rng = crate::util::Prng::new(21);
        let t = Tensor::randn(&mut rng, &[20, 150]);
        let pj = eng.moments(&t, MOMENT_ORDER);
        let rs = RustMomentEngine.moments(&t, MOMENT_ORDER);
        for (a, b) in pj.iter().zip(rs.iter()) {
            let rel = (a - b).abs() / b.abs().max(1e-9);
            assert!(rel < 1e-3, "pjrt {a} vs rust {b}");
        }
    }

    #[test]
    fn pjrt_engine_fingerprints_match_layouts() {
        let Some(eng) = engine() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let mut rng = crate::util::Prng::new(22);
        let t = Tensor::randn(&mut rng, &[4, 8, 16]);
        let p = t.permute(&[1, 0, 2]).contiguous();
        let f1 = crate::fingerprint::fingerprint_with(&eng, &t);
        let f2 = crate::fingerprint::fingerprint_with(&eng, &p);
        assert!(f1.matches(&f2, 1e-3), "distance {}", f1.distance(&f2));
    }
}
