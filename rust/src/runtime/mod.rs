//! PJRT/XLA runtime: loads the AOT-compiled artifacts produced by
//! `python/compile/aot.py` (HLO *text* — see DESIGN.md and
//! `/opt/xla-example`'s gotchas) and executes them on the CPU PJRT
//! client from the Rust hot path. Python never runs at profiling time.
//!
//! Two uses:
//! * [`PjrtMomentEngine`] — the L1 Pallas fingerprint kernel, compiled
//!   once per canonical matrix shape; tensors are zero-padded up to the
//!   nearest canonical shape (zero rows/columns leave Gram-trace
//!   moments unchanged) and the Rust engine remains the fallback.
//! * Reference-model execution — the jax-lowered GPT-2 block variants,
//!   used by integration tests to validate the Rust executor's
//!   numerics against XLA.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{anyhow, Context, Result};

use crate::fingerprint::{MomentEngine, RustMomentEngine, MOMENT_ORDER};
use crate::tensor::Tensor;

/// Canonical fingerprint-kernel shapes compiled by `aot.py`
/// (rows × cols). Keep in sync with `python/compile/aot.py::FP_SHAPES`.
pub const FP_SHAPES: &[(usize, usize)] = &[(32, 256), (64, 1024), (128, 4096)];

/// Default artifact directory (workspace-relative).
pub fn default_artifact_dir() -> PathBuf {
    // honour MAGNETON_ARTIFACTS, else walk up from cwd looking for
    // an `artifacts/` directory
    if let Ok(p) = std::env::var("MAGNETON_ARTIFACTS") {
        return PathBuf::from(p);
    }
    let mut dir = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
    loop {
        let cand = dir.join("artifacts");
        if cand.is_dir() {
            return cand;
        }
        if !dir.pop() {
            return PathBuf::from("artifacts");
        }
    }
}

/// A PJRT CPU runtime holding compiled executables by name.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    execs: BTreeMap<String, xla::PjRtLoadedExecutable>,
}

impl PjrtRuntime {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<PjrtRuntime> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(PjrtRuntime { client, execs: BTreeMap::new() })
    }

    /// Load and compile one HLO-text artifact under `name`.
    pub fn load_file(&mut self, name: &str, path: &Path) -> Result<()> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .map_err(|e| anyhow!("parse {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(|e| anyhow!("compile {name}: {e:?}"))?;
        self.execs.insert(name.to_string(), exe);
        Ok(())
    }

    /// Load every `*.hlo.txt` in a directory; returns how many loaded.
    pub fn load_dir(&mut self, dir: &Path) -> Result<usize> {
        let mut n = 0;
        for entry in std::fs::read_dir(dir).with_context(|| format!("read {dir:?}"))? {
            let path = entry?.path();
            let fname = path.file_name().and_then(|s| s.to_str()).unwrap_or("");
            if let Some(stem) = fname.strip_suffix(".hlo.txt") {
                self.load_file(stem, &path)?;
                n += 1;
            }
        }
        Ok(n)
    }

    pub fn has(&self, name: &str) -> bool {
        self.execs.contains_key(name)
    }

    pub fn names(&self) -> Vec<&str> {
        self.execs.keys().map(String::as_str).collect()
    }

    /// Execute an artifact on f32 inputs; returns all tuple outputs as
    /// flat vectors. (aot.py lowers with `return_tuple=True`.)
    pub fn execute_f32(&self, name: &str, inputs: &[(&[f32], &[usize])]) -> Result<Vec<Vec<f32>>> {
        let exe = self
            .execs
            .get(name)
            .ok_or_else(|| anyhow!("artifact `{name}` not loaded"))?;
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|(data, shape)| {
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(data)
                    .reshape(&dims)
                    .map_err(|e| anyhow!("reshape input: {e:?}"))
            })
            .collect::<Result<_>>()?;
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal: {e:?}"))?;
        let parts = result.to_tuple().map_err(|e| anyhow!("to_tuple: {e:?}"))?;
        parts
            .iter()
            .map(|l| l.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}")))
            .collect()
    }
}

/// Moment engine backed by the Pallas fingerprint kernel compiled to a
/// PJRT executable. Falls back to the Rust engine when no canonical
/// shape fits.
pub struct PjrtMomentEngine {
    runtime: Mutex<PjrtRuntime>,
    fallback: RustMomentEngine,
    /// Count of PJRT-served vs fallback calls (perf accounting).
    pub served: std::sync::atomic::AtomicUsize,
    pub fell_back: std::sync::atomic::AtomicUsize,
}

// SAFETY: the xla crate's client/executable wrappers hold `Rc`s and raw
// pointers, making them `!Send`/`!Sync` even though the underlying PJRT
// CPU client is thread-safe. Every access to the runtime (and therefore
// every Rc clone/drop and FFI call) happens while holding the `Mutex`,
// so cross-thread use is fully serialised.
unsafe impl Send for PjrtMomentEngine {}
unsafe impl Sync for PjrtMomentEngine {}

impl PjrtMomentEngine {
    /// Load fingerprint artifacts from `dir`. Errors if none found.
    pub fn load(dir: &Path) -> Result<PjrtMomentEngine> {
        let mut rt = PjrtRuntime::cpu()?;
        let mut found = 0;
        for &(m, n) in FP_SHAPES {
            let name = format!("fingerprint_{m}x{n}");
            let path = dir.join(format!("{name}.hlo.txt"));
            if path.exists() {
                rt.load_file(&name, &path)?;
                found += 1;
            }
        }
        if found == 0 {
            return Err(anyhow!("no fingerprint artifacts in {dir:?} (run `make artifacts`)"));
        }
        Ok(PjrtMomentEngine {
            runtime: Mutex::new(rt),
            fallback: RustMomentEngine,
            served: Default::default(),
            fell_back: Default::default(),
        })
    }

    /// Smallest canonical shape that fits (rows ≤ m, cols ≤ n).
    fn canonical_for(rows: usize, cols: usize) -> Option<(usize, usize)> {
        FP_SHAPES
            .iter()
            .copied()
            .find(|&(m, n)| rows <= m && cols <= n)
    }
}

impl MomentEngine for PjrtMomentEngine {
    fn moments(&self, mat: &Tensor, order: usize) -> Vec<f64> {
        use std::sync::atomic::Ordering::Relaxed;
        let (rows, cols) = (mat.shape()[0], mat.shape()[1]);
        let Some((m, n)) = Self::canonical_for(rows, cols) else {
            self.fell_back.fetch_add(1, Relaxed);
            return self.fallback.moments(mat, order);
        };
        if order > MOMENT_ORDER {
            self.fell_back.fetch_add(1, Relaxed);
            return self.fallback.moments(mat, order);
        }
        // zero-pad into the canonical shape: padding rows/cols with
        // zeros leaves every tr((M Mᵀ)^k) unchanged
        let src = mat.to_vec();
        let mut padded = vec![0.0f32; m * n];
        for r in 0..rows {
            padded[r * n..r * n + cols].copy_from_slice(&src[r * cols..(r + 1) * cols]);
        }
        let name = format!("fingerprint_{m}x{n}");
        let rt = self.runtime.lock().unwrap();
        match rt.execute_f32(&name, &[(&padded, &[m, n])]) {
            Ok(outs) => {
                self.served.fetch_add(1, Relaxed);
                // kernel returns one vector of MOMENT_ORDER moments
                outs[0].iter().take(order).map(|&x| x as f64).collect()
            }
            Err(_) => {
                self.fell_back.fetch_add(1, Relaxed);
                self.fallback.moments(mat, order)
            }
        }
    }

    fn name(&self) -> &'static str {
        "pjrt-pallas"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// These tests exercise the real PJRT path and are skipped when
    /// `make artifacts` has not run yet.
    fn engine() -> Option<PjrtMomentEngine> {
        let dir = default_artifact_dir();
        PjrtMomentEngine::load(&dir).ok()
    }

    #[test]
    fn canonical_shape_selection() {
        assert_eq!(PjrtMomentEngine::canonical_for(10, 100), Some((32, 256)));
        assert_eq!(PjrtMomentEngine::canonical_for(64, 1024), Some((64, 1024)));
        assert_eq!(PjrtMomentEngine::canonical_for(4096, 4096), None);
    }

    #[test]
    fn pjrt_moments_match_rust_engine() {
        let Some(eng) = engine() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let mut rng = crate::util::Prng::new(21);
        let t = Tensor::randn(&mut rng, &[20, 150]);
        let pj = eng.moments(&t, MOMENT_ORDER);
        let rs = RustMomentEngine.moments(&t, MOMENT_ORDER);
        for (a, b) in pj.iter().zip(rs.iter()) {
            let rel = (a - b).abs() / b.abs().max(1e-9);
            assert!(rel < 1e-3, "pjrt {a} vs rust {b}");
        }
        assert!(eng.served.load(std::sync::atomic::Ordering::Relaxed) > 0);
    }

    #[test]
    fn pjrt_engine_fingerprints_match_layouts() {
        let Some(eng) = engine() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let mut rng = crate::util::Prng::new(22);
        let t = Tensor::randn(&mut rng, &[4, 8, 16]);
        let p = t.permute(&[1, 0, 2]).contiguous();
        let f1 = crate::fingerprint::fingerprint_with(&eng, &t);
        let f2 = crate::fingerprint::fingerprint_with(&eng, &p);
        assert!(f1.matches(&f2, 1e-3), "distance {}", f1.distance(&f2));
    }
}
