//! Report rendering: audit summaries, the Table 2 replica, energy
//! breakdowns (Fig 2 style), the ranked cross-system fleet waste
//! report, and rolling summaries for streaming audits, with CSV
//! persistence under `results/`.

use crate::analysis::diff::{MatchTier, RegionVerdict};
use crate::analysis::{LintReport, RewriteStep, StaticDiffReport, VerifyOutcome};
use crate::telemetry::json::Json;
use crate::coordinator::fleet::{FleetDivergence, FleetReport, StreamFleetReport};
use crate::coordinator::AuditOutcome;
use crate::exec::RunArtifacts;
use crate::stream::{StreamSummary, WindowReport};
use crate::telemetry::session::{MatchVerdict, SessionDiff};
use crate::dash::DashState;
use crate::telemetry::{Alarm, RankEntry};
use crate::util::table::{fmt_joules, fmt_us, Table};

/// Joules with an explicit sign (for delta columns).
fn fmt_joules_signed(j: f64) -> String {
    if j < 0.0 {
        format!("-{}", fmt_joules(-j))
    } else {
        format!("+{}", fmt_joules(j))
    }
}

/// Render an audit outcome as a human-readable report.
pub fn render_audit(name_a: &str, name_b: &str, out: &AuditOutcome) -> String {
    let mut s = String::new();
    s.push_str(&format!("=== Magneton audit: {name_a} vs {name_b} ===\n"));
    s.push_str(&format!(
        "energy: {} vs {}  (e2e diff {:.1}%)\n",
        fmt_joules(out.a.total_energy_j),
        fmt_joules(out.b.total_energy_j),
        out.e2e_diff_frac * 100.0
    ));
    s.push_str(&format!(
        "time:   {} vs {}\n",
        fmt_us(out.a.gpu_time_us),
        fmt_us(out.b.gpu_time_us)
    ));
    s.push_str(&format!(
        "matched: {} equivalent tensor pairs, {} regions ({} matched in {})\n",
        out.eq_pairs,
        out.regions.len(),
        out.regions.iter().map(|r| r.size()).sum::<usize>(),
        fmt_us(out.match_time_us)
    ));
    if out.findings.is_empty() {
        s.push_str("no energy waste detected above threshold\n");
    }
    for (i, (f, d)) in out.diagnoses.iter().enumerate() {
        s.push_str(&format!("\n--- finding #{} ---\n{}\n{}\n", i + 1, f.summary(), d.render()));
    }
    s
}

/// Ranked cross-system waste table for a finished fleet audit: one row
/// per pair, most wasteful first (the ranking [`FleetReport`] computed).
pub fn fleet_table(report: &FleetReport) -> Table {
    let mut t = Table::new(vec![
        "rank", "pair", "energy A", "energy B", "findings", "trade-offs", "wasted", "e2e diff",
    ]);
    for (i, e) in report.entries.iter().enumerate() {
        t.row(vec![
            (i + 1).to_string(),
            e.name.clone(),
            fmt_joules(e.outcome.a.total_energy_j),
            fmt_joules(e.outcome.b.total_energy_j),
            e.findings.to_string(),
            e.tradeoffs.to_string(),
            fmt_joules(e.wasted_j),
            format!("{:.1}%", e.outcome.e2e_diff_frac * 100.0),
        ]);
    }
    t
}

/// Human-readable fleet report: ranked table plus aggregate summary.
pub fn render_fleet(report: &FleetReport) -> String {
    let mut s = String::new();
    s.push_str(&format!(
        "=== Magneton fleet audit: {} pairs, {} workers, {} ===\n",
        report.entries.len(),
        report.workers,
        fmt_us(report.wall_time_us)
    ));
    s.push_str(&fleet_table(report).render());
    s.push_str(&format!(
        "total: {} wasted across {} findings in {}/{} flagged pairs\n",
        fmt_joules(report.total_wasted_j),
        report.total_findings,
        report.flagged(),
        report.entries.len()
    ));
    s
}

/// One-line rolling view of an emitted detection window (the streaming
/// counterpart of a finding summary).
pub fn render_window(w: &WindowReport) -> String {
    let flagged: Vec<String> = w
        .findings
        .iter()
        .map(|f| {
            format!(
                "{} {:+.1}%{}",
                f.label,
                f.diff_frac * 100.0,
                if f.is_tradeoff { " (trade-off)" } else { "" }
            )
        })
        .collect();
    let seq = if w.seq == WindowReport::PEEK_SEQ { "peek".to_string() } else { w.seq.to_string() };
    let mut tags = String::new();
    if w.resyncs > 0 {
        tags.push_str(&format!("  [RESYNC x{}]", w.resyncs));
    }
    if w.quarantined {
        tags.push_str("  [QUARANTINED]");
    } else if !w.aligned {
        tags.push_str("  [STREAMS DIVERGED]");
    }
    if w.content_mismatches > 0 {
        tags.push_str(&format!("  [content: {} pairs diverged]", w.content_mismatches));
    }
    format!(
        "window #{:<4} {:>4} pairs  A {} vs B {}  wasted {}  {}{}",
        seq,
        w.pairs,
        fmt_joules(w.energy_a_j),
        fmt_joules(w.energy_b_j),
        fmt_joules(w.wasted_j),
        if flagged.is_empty() { "clean".to_string() } else { flagged.join(", ") },
        tags,
    )
}

/// Rolling waste summary of one stream audit: cumulative energies,
/// waste ledger by call site, and the memory high-water marks that
/// prove the audit stayed bounded.
pub fn render_stream(name: &str, s: &StreamSummary) -> String {
    let mut out = String::new();
    out.push_str(&format!("=== Magneton stream audit: {name} ===\n"));
    out.push_str(&format!(
        "ops: {} pairs over {} windows ({} flagged){}\n",
        s.ops,
        s.windows,
        s.windows_flagged,
        if s.aligned { "" } else { "  [STREAMS DIVERGED]" },
    ));
    out.push_str(&format!("workload fingerprint: {:016x}", s.fingerprint_a));
    if s.fingerprint_b != s.fingerprint_a {
        out.push_str(&format!(" vs {:016x} (B differs)", s.fingerprint_b));
    }
    if s.unpaired > 0 {
        out.push_str(&format!("  [{} events unpaired]", s.unpaired));
    }
    out.push('\n');
    out.push_str(&format!(
        "energy: {} vs {}  wasted {}\n",
        fmt_joules(s.energy_a_j),
        fmt_joules(s.energy_b_j),
        fmt_joules(s.wasted_j)
    ));
    if s.resyncs > 0 {
        out.push_str(&format!(
            "resyncs: {} ({} ops skipped, {} windows quarantined)\n",
            s.resyncs, s.resync_skipped, s.windows_quarantined
        ));
    }
    if s.content_mismatches > 0 {
        out.push_str(&format!(
            "content guard: {} matched pairs diverged beyond tolerance\n",
            s.content_mismatches
        ));
    }
    if s.reports_dropped > 0 {
        out.push_str(&format!(
            "backpressure: {} undrained window reports dropped\n",
            s.reports_dropped
        ));
    }
    out.push_str(&format!(
        "memory: {} power segments retained at peak, {} window pairs, {} pending\n",
        s.peak_retained_segments, s.peak_window_pairs, s.peak_pending
    ));
    if !s.top_labels.is_empty() {
        let mut t = Table::new(vec!["call site", "wasted", "windows"]);
        for (label, j, n) in s.top_labels.iter().take(8) {
            t.row(vec![label.clone(), fmt_joules(*j), n.to_string()]);
        }
        out.push_str(&t.render());
    }
    out
}

/// Ranked table for a finished streaming fleet audit.
pub fn stream_fleet_table(report: &StreamFleetReport) -> Table {
    let mut t = Table::new(vec![
        "rank", "stream", "ops", "energy A", "energy B", "wasted", "flagged", "resyncs", "aligned",
    ]);
    for (i, e) in report.entries.iter().enumerate() {
        t.row(vec![
            (i + 1).to_string(),
            e.name.clone(),
            e.summary.ops.to_string(),
            fmt_joules(e.summary.energy_a_j),
            fmt_joules(e.summary.energy_b_j),
            fmt_joules(e.summary.wasted_j),
            format!("{}/{}", e.summary.windows_flagged, e.summary.windows),
            e.summary.resyncs.to_string(),
            if e.summary.aligned { "yes" } else { "NO" }.to_string(),
        ]);
    }
    t
}

/// One fleet-wide coalesced divergence alarm: the single line that
/// replaces N per-pair resync reports, attribution retained.
pub fn render_divergence(d: &FleetDivergence) -> String {
    let attribution: Vec<String> = d
        .pairs
        .iter()
        .map(|p| {
            format!(
                "{} ({} resync{}, {} skipped, first at op {})",
                p.name,
                p.resyncs,
                if p.resyncs == 1 { "" } else { "s" },
                p.skipped,
                p.at_ops
            )
        })
        .collect();
    format!(
        "!!! fleet divergence at ops {}..{}: {} pairs resynced together — {}",
        d.at_ops_min,
        d.at_ops_max,
        d.pairs.len(),
        attribution.join("; ")
    )
}

/// One online-invariant violation, as the live feed and the replay
/// body print it.
pub fn render_alarm(a: &Alarm) -> String {
    let at = match a.seq {
        Some(seq) => format!(" window #{seq}"),
        None => String::new(),
    };
    format!(
        "ALARM [{}] {}{}: {} over limit {} — {}",
        a.invariant, a.pair, at, a.value, a.limit, a.detail
    )
}

/// One terminal dashboard frame for `magneton dash`: fleet ranking
/// (most wasteful pair first), rolling totals, the divergence feed,
/// and the alarm log.
pub fn render_dash(d: &DashState) -> String {
    let mut s = String::new();
    let session =
        if d.session.is_empty() { "(no header yet)".to_string() } else { d.session.clone() };
    s.push_str(&format!(
        "=== Magneton live fleet dash: session {} — {} pairs, {} windows, {} resyncs ===\n",
        session,
        d.pairs.len(),
        d.windows,
        d.resyncs,
    ));
    if d.pairs.is_empty() {
        s.push_str("waiting for snapshots...\n");
        return s;
    }
    let mut t = Table::new(vec![
        "rank", "pair", "ops", "energy A", "energy B", "wasted", "flagged", "resyncs", "aligned",
        "state",
    ]);
    for (i, (name, p)) in d.ranked().iter().enumerate() {
        t.row(vec![
            (i + 1).to_string(),
            (*name).clone(),
            p.ops.to_string(),
            fmt_joules(p.energy_a_j),
            fmt_joules(p.energy_b_j),
            fmt_joules(p.wasted_j),
            format!("{}/{}", p.windows_flagged, p.windows),
            p.resyncs.to_string(),
            if p.aligned { "yes" } else { "NO" }.to_string(),
            if p.summarized { "final" } else { "live" }.to_string(),
        ]);
    }
    s.push_str(&t.render());
    let wasted: f64 = d.pairs.values().map(|p| p.wasted_j).sum();
    s.push_str(&format!("fleet waste: {}\n", fmt_joules(wasted)));
    let skip = d.divergences.len().saturating_sub(4);
    if skip > 0 {
        s.push_str(&format!("... {skip} earlier divergences\n"));
    }
    for dv in d.divergences.iter().skip(skip) {
        s.push_str(&render_divergence(dv));
        s.push('\n');
    }
    if !d.alarms.is_empty() {
        s.push_str(&format!("alarms ({} total):\n", d.alarms.len()));
        let skip = d.alarms.len().saturating_sub(8);
        if skip > 0 {
            s.push_str(&format!("... {skip} earlier alarms\n"));
        }
        for a in d.alarms.iter().skip(skip) {
            s.push_str(&render_alarm(a));
            s.push('\n');
        }
    }
    s
}

/// Ranked cross-session regression report: the `magneton diff` output.
/// Regressions lead the table (largest ΔJ first); the footer carries
/// the session-level totals, waste/divergence deltas, and the window
/// alignment summary.
pub fn render_session_diff(d: &SessionDiff) -> String {
    let mut s = String::new();
    s.push_str(&format!(
        "=== Magneton session diff: {} -> {} ===\n",
        d.session_a, d.session_b
    ));
    match &d.verdict {
        MatchVerdict::Exact => {
            s.push_str("workloads match exactly (identical fingerprint multisets)\n");
        }
        MatchVerdict::Tolerant { overlap } => {
            s.push_str(&format!(
                "workloads match tolerantly: label-multiset overlap {:.1}%\n",
                overlap * 100.0
            ));
        }
        MatchVerdict::Incomparable { reason } => {
            // diff_sessions refuses these; render defensively anyway
            s.push_str(&format!("WORKLOADS INCOMPARABLE: {reason}\n"));
        }
    }
    for note in &d.notes {
        s.push_str(&format!("note: {note}\n"));
    }
    let frac = d.total_delta_frac();
    s.push_str(&format!(
        "energy: {} -> {}  ({}{:.1}%)\n",
        fmt_joules(d.total_a_j),
        fmt_joules(d.total_b_j),
        if frac >= 0.0 { "+" } else { "" },
        frac * 100.0
    ));
    s.push_str(&format!(
        "waste vs in-session reference: {} -> {}\n",
        fmt_joules(d.wasted_a_j),
        fmt_joules(d.wasted_b_j)
    ));
    if d.resyncs_a + d.resyncs_b + d.divergences_a + d.divergences_b > 0 {
        s.push_str(&format!(
            "divergence events: {} resyncs / {} fleet divergences -> {} / {}\n",
            d.resyncs_a, d.divergences_a, d.resyncs_b, d.divergences_b
        ));
    }
    s.push_str(&format!(
        "windows: {} aligned, {} realigns ({} + {} skipped), {} forced\n",
        d.windows.aligned,
        d.windows.realigns,
        d.windows.skipped_a,
        d.windows.skipped_b,
        d.windows.forced
    ));
    if !d.labels.is_empty() {
        let mut t = Table::new(vec![
            "rank", "label", "ops A->B", "energy A", "energy B", "delta", "delta%", "waste A->B",
            "verdict",
        ]);
        for (i, l) in d.labels.iter().enumerate() {
            let signed_frac = if l.delta_j >= 0.0 { l.delta_frac } else { -l.delta_frac };
            let verdict = if l.delta_frac >= d.energy_threshold {
                if l.delta_j > 0.0 {
                    "REGRESSED"
                } else {
                    "improved"
                }
            } else {
                "~"
            };
            t.row(vec![
                (i + 1).to_string(),
                l.label.clone(),
                if l.ops_a == l.ops_b {
                    l.ops_a.to_string()
                } else {
                    format!("{}->{}", l.ops_a, l.ops_b)
                },
                fmt_joules(l.energy_a_j),
                fmt_joules(l.energy_b_j),
                fmt_joules_signed(l.delta_j),
                format!("{:+.1}%", signed_frac * 100.0),
                format!("{}->{}", fmt_joules(l.waste_a_j), fmt_joules(l.waste_b_j)),
                verdict.to_string(),
            ]);
        }
        s.push_str(&t.render());
    }
    for (label, j) in &d.new_labels {
        s.push_str(&format!("new label in B: {label} ({})\n", fmt_joules(*j)));
    }
    for (label, j) in &d.vanished_labels {
        s.push_str(&format!("vanished label (A only): {label} ({})\n", fmt_joules(*j)));
    }
    s
}

/// Ranked table for a persisted fleet ranking (the replay-side
/// counterpart of [`stream_fleet_table`]).
pub fn render_ranking(ranking: &[RankEntry]) -> String {
    let mut t =
        Table::new(vec!["rank", "stream", "ops", "wasted", "flagged", "resyncs", "aligned"]);
    for (i, e) in ranking.iter().enumerate() {
        t.row(vec![
            (i + 1).to_string(),
            e.name.clone(),
            e.ops.to_string(),
            fmt_joules(e.wasted_j),
            format!("{}/{}", e.windows_flagged, e.windows),
            e.resyncs.to_string(),
            if e.aligned { "yes" } else { "NO" }.to_string(),
        ]);
    }
    t.render()
}

/// Human-readable streaming fleet report.
pub fn render_stream_fleet(report: &StreamFleetReport) -> String {
    let mut s = String::new();
    s.push_str(&format!(
        "=== Magneton streaming fleet audit: {} streams, {} workers, {} ===\n",
        report.entries.len(),
        report.workers,
        fmt_us(report.wall_time_us)
    ));
    s.push_str(&stream_fleet_table(report).render());
    for d in &report.divergences {
        s.push_str(&render_divergence(d));
        s.push('\n');
    }
    if report.snapshot_errors > 0 {
        s.push_str(&format!("snapshot sink: {} IO errors\n", report.snapshot_errors));
    }
    s.push_str(&format!(
        "total: {} wasted across {} op pairs in {}/{} flagged streams\n",
        fmt_joules(report.total_wasted_j),
        report.total_ops,
        report.flagged(),
        report.entries.len()
    ));
    s
}

/// Ranked static-lint report: per-target finding tables (severity
/// desc, then estimated waste desc — the order the lint passes already
/// produce) under an aggregate header.
pub fn render_lint(report: &LintReport) -> String {
    let mut s = String::new();
    s.push_str(&format!(
        "=== Magneton lint: {} targets, {} findings, est. {} wasted ===\n",
        report.targets.len(),
        report.total_findings,
        fmt_joules(report.total_est_wasted_j)
    ));
    for t in &report.targets {
        if let Some(err) = &t.error {
            s.push_str(&format!("\n--- {}: INVALID ({err}) ---\n", t.name));
            continue;
        }
        s.push_str(&format!(
            "\n--- {}: {} nodes, static cost {}, {} finding{} ---\n",
            t.name,
            t.nodes,
            fmt_joules(t.static_j),
            t.findings.len(),
            if t.findings.len() == 1 { "" } else { "s" }
        ));
        if t.findings.is_empty() {
            continue;
        }
        let mut tab = Table::new(vec!["sev", "rule", "site", "est. wasted", "suggestion"]);
        for f in &t.findings {
            tab.row(vec![
                f.severity.name().to_string(),
                f.rule.to_string(),
                f.label.clone(),
                fmt_joules(f.est_wasted_j),
                f.suggestion.clone(),
            ]);
        }
        s.push_str(&tab.render());
        // joint-search diagnoses: the marginal-vs-joint breakdown that
        // explains *why* the flag set is 1-minimal — each flag alone
        // either costs energy or blows the time budget
        for d in &t.interactions {
            let set = d.flag_set();
            s.push_str(&format!(
                "interaction `{}`: {{{set}}} jointly saves {} across {} node(s) \
                 ({} -> {}, 1-minimal)\n",
                d.label,
                fmt_joules(d.joint_saved_j),
                d.nodes.len(),
                d.kernel_now,
                d.kernel_then,
            ));
            for m in &d.marginals {
                let verb = if m.saved_j > 0.0 {
                    format!("saves {}", fmt_joules(m.saved_j))
                } else {
                    format!("costs {}", fmt_joules(-m.saved_j))
                };
                let gate = if m.time_ok { "" } else { " but breaks the time budget" };
                s.push_str(&format!(
                    "    flag `{}={}` alone {verb}{gate} — {}\n",
                    m.flag, m.value, m.source
                ));
            }
        }
    }
    s
}

/// Machine-readable `magneton lint --json` payload: the full lint
/// report — findings, rewrite steps, and joint-search interaction
/// diagnoses — through the telemetry JSON writer (floats render
/// shortest-round-trip, so estimates survive bit-for-bit).
pub fn lint_report_json(report: &LintReport) -> Json {
    let step_json = |st: &RewriteStep| -> Json {
        match st {
            RewriteStep::Bypass { node, replacement } => Json::obj()
                .field("kind", "bypass")
                .field("node", *node)
                .field("replacement", *replacement)
                .build(),
            RewriteStep::Remove { node } => {
                Json::obj().field("kind", "remove").field("node", *node).build()
            }
            RewriteStep::SetAttr { node, key, value } => Json::obj()
                .field("kind", "set_attr")
                .field("node", *node)
                .field("key", key.as_str())
                .field("value", value.as_str())
                .build(),
            RewriteStep::FuseAddMm { mm, add } => Json::obj()
                .field("kind", "fuse_addmm")
                .field("mm", *mm)
                .field("add", *add)
                .build(),
        }
    };
    let ids = |nodes: &[usize]| Json::Arr(nodes.iter().map(|&n| Json::from(n)).collect());
    let targets: Vec<Json> = report
        .targets
        .iter()
        .map(|t| {
            let findings: Vec<Json> = t
                .findings
                .iter()
                .map(|f| {
                    Json::obj()
                        .field("rule", f.rule)
                        .field("severity", f.severity.name())
                        .field("nodes", ids(&f.nodes))
                        .field("label", f.label.as_str())
                        .field("est_wasted_j", f.est_wasted_j)
                        .field("suggestion", f.suggestion.as_str())
                        .field(
                            "steps",
                            Json::Arr(f.steps.iter().map(step_json).collect()),
                        )
                        .build()
                })
                .collect();
            let interactions: Vec<Json> = t
                .interactions
                .iter()
                .map(|d| {
                    let marginals: Vec<Json> = d
                        .marginals
                        .iter()
                        .map(|m| {
                            Json::obj()
                                .field("flag", m.flag.as_str())
                                .field("value", m.value.as_str())
                                .field("source", m.source.as_str())
                                .field("saved_j", m.saved_j)
                                .field("time_ok", m.time_ok)
                                .build()
                        })
                        .collect();
                    let assignment: Vec<Json> = d
                        .assignment
                        .iter()
                        .map(|(k, v)| {
                            Json::obj()
                                .field("flag", k.as_str())
                                .field("value", v.as_str())
                                .build()
                        })
                        .collect();
                    Json::obj()
                        .field("nodes", ids(&d.nodes))
                        .field("label", d.label.as_str())
                        .field("assignment", Json::Arr(assignment))
                        .field("joint_saved_j", d.joint_saved_j)
                        .field("kernel_now", d.kernel_now.as_str())
                        .field("kernel_then", d.kernel_then.as_str())
                        .field("marginals", Json::Arr(marginals))
                        .build()
                })
                .collect();
            Json::obj()
                .field("name", t.name.as_str())
                .field("nodes", t.nodes)
                .field("static_j", t.static_j)
                .field(
                    "error",
                    t.error.as_ref().map(|e| Json::from(e.as_str())).unwrap_or(Json::Null),
                )
                .field("findings", Json::Arr(findings))
                .field("interactions", Json::Arr(interactions))
                .build()
        })
        .collect();
    Json::obj()
        .field("targets", Json::Arr(targets))
        .field("total_findings", report.total_findings)
        .field("total_est_wasted_j", report.total_est_wasted_j)
        .build()
}

/// Ranked static differential report: the `magneton lint --diff`
/// output. Flagged region pairs lead the table (largest |ΔJ| first, the
/// order [`StaticDiffReport`] already holds); within-threshold pairs
/// are summarised, and unmatched regions are attributed per side.
pub fn render_static_diff(d: &StaticDiffReport) -> String {
    let mut s = String::new();
    s.push_str(&format!(
        "=== Magneton static diff: {} vs {} ===\n",
        d.target_a, d.target_b
    ));
    if let Some(err) = &d.error {
        s.push_str(&format!("INVALID: {err}\n"));
        return s;
    }
    s.push_str(&format!(
        "graphs: {} vs {} nodes  static cost {} vs {}  (delta {})\n",
        d.nodes_a,
        d.nodes_b,
        fmt_joules(d.total_a_j),
        fmt_joules(d.total_b_j),
        fmt_joules_signed(d.total_b_j - d.total_a_j)
    ));
    let tier_count = |t: MatchTier| d.regions.iter().filter(|r| r.tier == t).count();
    s.push_str(&format!(
        "regions: {} matched ({} hash / {} label / {} bucket / {} fuzzy), {} + {} unmatched\n",
        d.regions.len(),
        tier_count(MatchTier::Hash),
        tier_count(MatchTier::Label),
        tier_count(MatchTier::Bucket),
        tier_count(MatchTier::Fuzzy),
        d.unmatched_a.len(),
        d.unmatched_b.len()
    ));
    let flagged: Vec<_> = d.regions.iter().filter(|r| r.verdict != RegionVerdict::Close).collect();
    let close = d.regions.len() - flagged.len();
    if !flagged.is_empty() {
        let mut t = Table::new(vec![
            "rank", "region", "op", "kernel A", "kernel B", "energy A", "energy B", "delta",
            "tier", "verdict",
        ]);
        for (i, r) in flagged.iter().enumerate() {
            t.row(vec![
                (i + 1).to_string(),
                if r.label_a == r.label_b {
                    r.label_a.clone()
                } else {
                    format!("{} <-> {}", r.label_a, r.label_b)
                },
                r.op.to_string(),
                r.kernel_a.clone(),
                r.kernel_b.clone(),
                fmt_joules(r.a_j),
                fmt_joules(r.b_j),
                fmt_joules_signed(r.delta_j),
                r.tier.name().to_string(),
                r.verdict.name().to_string(),
            ]);
        }
        s.push_str(&t.render());
    }
    if close > 0 {
        s.push_str(&format!("{close} matched region(s) within threshold\n"));
    }
    for (owner, regions) in [(&d.target_a, &d.unmatched_a), (&d.target_b, &d.unmatched_b)] {
        for u in regions.iter() {
            s.push_str(&format!(
                "unmatched on {owner}: {} ({}, {})\n",
                u.label,
                u.op,
                fmt_joules(u.cost_j)
            ));
        }
    }
    s
}

/// One-line verdict of a measure-after-fix verification.
pub fn render_verify(v: &VerifyOutcome) -> String {
    format!(
        "verify [{}] `{}` on {}: predicted {} saved, measured {} ({} -> {})  sign {}  detector {}\n",
        v.rule,
        v.label,
        v.target,
        fmt_joules(v.est_wasted_j),
        fmt_joules_signed(v.measured_delta_j),
        fmt_joules(v.energy_before_j),
        fmt_joules(v.energy_after_j),
        if v.same_sign { "CONFIRMED" } else { "MISMATCH" },
        if v.detected { "flagged the pair" } else { "below threshold" },
    )
}

/// Fig 2-style top-k energy breakdown of a run.
pub fn energy_breakdown(arts: &RunArtifacts, top: usize) -> Table {
    let mut t = Table::new(vec!["op", "energy", "share"]);
    let by_op = arts.energy_by_op();
    let total: f64 = by_op.iter().map(|(_, e)| e).sum();
    for (op, e) in by_op.iter().take(top) {
        t.row(vec![
            op.clone(),
            fmt_joules(*e),
            format!("{:.1}%", e / total * 100.0),
        ]);
    }
    t
}

/// Per-label (call-site) breakdown, most expensive first.
pub fn label_breakdown(arts: &RunArtifacts, top: usize) -> Table {
    let mut agg: std::collections::BTreeMap<String, (f64, f64)> = Default::default();
    for r in &arts.records {
        let e = agg.entry(r.label.clone()).or_insert((0.0, 0.0));
        e.0 += r.energy_j;
        e.1 += r.time_us;
    }
    let mut rows: Vec<(String, f64, f64)> = agg.into_iter().map(|(k, (e, t))| (k, e, t)).collect();
    rows.sort_by(|a, b| b.1.total_cmp(&a.1));
    let mut t = Table::new(vec!["site", "energy", "time"]);
    for (label, e, us) in rows.into_iter().take(top) {
        t.row(vec![label, fmt_joules(e), fmt_us(us)]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{Magneton, SysRun};
    use crate::dispatch::Env;
    use crate::energy::DeviceSpec;
    use crate::exec::{Dispatcher, Program};
    use crate::graph::{Graph, OpKind};
    use crate::tensor::Tensor;
    use crate::util::Prng;

    fn small_run() -> SysRun {
        let mut rng = Prng::new(3);
        let mut g = Graph::new("r");
        let x = g.add(OpKind::Input, &[], "x");
        let w = g.add(OpKind::Weight, &[], "w");
        let m = g.add(OpKind::MatMul, &[x, w], "proj");
        let gl = g.add(OpKind::Gelu, &[m], "act");
        g.add(OpKind::Output, &[gl], "out");
        let mut p = Program::new(g);
        p.feed(0, Tensor::randn(&mut rng, &[32, 32]));
        p.feed(1, Tensor::randn(&mut rng, &[32, 32]));
        SysRun::new("sys", Dispatcher::new(), Env::new(), p)
    }

    #[test]
    fn audit_report_renders() {
        let mag = Magneton::new(DeviceSpec::h200_sim());
        let out = mag.audit(&small_run(), &small_run());
        let s = render_audit("A", "B", &out);
        assert!(s.contains("Magneton audit"));
        assert!(s.contains("equivalent tensor pairs"));
    }

    #[test]
    fn breakdown_shares_sum_to_100() {
        let mag = Magneton::new(DeviceSpec::h200_sim());
        let arts = mag.run_side(&small_run());
        let t = energy_breakdown(&arts, 10);
        assert!(!t.is_empty());
        let csv = t.to_csv();
        assert!(csv.contains("matmul"));
    }

    #[test]
    fn label_breakdown_sorted() {
        let mag = Magneton::new(DeviceSpec::h200_sim());
        let arts = mag.run_side(&small_run());
        let t = label_breakdown(&arts, 5);
        assert!(t.len() >= 2);
    }

    #[test]
    fn stream_reports_render() {
        use crate::coordinator::fleet::StreamFleet;
        use crate::workload::{serving_dispatcher, serving_stream_program, ServingStream};
        let spec = ServingStream { requests: 10, batch: 64, d_model: 128 };
        let mut fleet = StreamFleet::new(DeviceSpec::h200_sim());
        fleet.cfg.window_ops = 25;
        fleet.cfg.hop_ops = 25;
        let mk = |eff: f64| {
            let mut rng = Prng::new(44);
            SysRun::new("s", serving_dispatcher(eff), Env::new(), serving_stream_program(&mut rng, &spec))
        };
        fleet.add_pair("hot", mk(0.6), mk(1.0));
        fleet.add_pair("clean", mk(1.0), mk(1.0));
        let r = fleet.run();
        let rendered = render_stream_fleet(&r);
        assert!(rendered.contains("streaming fleet audit"));
        assert!(rendered.contains("hot") && rendered.contains("clean"));
        assert_eq!(stream_fleet_table(&r).len(), 2);
        // per-stream rolling summary
        let top = &r.entries[0];
        assert_eq!(top.name, "hot");
        let s = render_stream(&top.name, &top.summary);
        assert!(s.contains("stream audit: hot"));
        assert!(s.contains("wasted"));
        assert!(s.contains("serve.proj") || s.contains("serve.out"));
    }

    #[test]
    fn divergence_and_ranking_render() {
        use crate::coordinator::fleet::DivergentPair;
        let d = FleetDivergence {
            at_ops_min: 437,
            at_ops_max: 439,
            pairs: vec![
                DivergentPair { name: "serving-1".into(), at_ops: 438, resyncs: 2, skipped: 3 },
                DivergentPair { name: "serving-0".into(), at_ops: 437, resyncs: 1, skipped: 1 },
            ],
        };
        let line = render_divergence(&d);
        assert!(line.contains("ops 437..439"), "{line}");
        assert!(line.contains("2 pairs"), "{line}");
        assert!(line.contains("serving-1 (2 resyncs, 3 skipped, first at op 438)"), "{line}");
        let ranking = vec![RankEntry {
            name: "hot".into(),
            wasted_j: 1.5,
            ops: 100,
            windows: 4,
            windows_flagged: 3,
            resyncs: 0,
            aligned: true,
        }];
        let table = render_ranking(&ranking);
        assert!(table.contains("hot"), "{table}");
        assert!(table.contains("3/4"), "{table}");
    }

    #[test]
    fn session_diff_renders_ranked_regressions() {
        use crate::telemetry::session::{LabelDelta, SessionDiff, WindowAlignment};
        let delta = |label: &str, ea: f64, eb: f64| LabelDelta {
            label: label.to_string(),
            ops_a: 100,
            ops_b: 100,
            energy_a_j: ea,
            energy_b_j: eb,
            delta_j: eb - ea,
            delta_frac: (eb - ea).abs() / ea.max(eb),
            waste_a_j: 0.0,
            waste_b_j: (eb - ea).max(0.0),
        };
        let d = SessionDiff {
            session_a: "deploy-a".into(),
            session_b: "deploy-b (canary)".into(),
            verdict: MatchVerdict::Exact,
            notes: vec!["arrival processes differ (steady vs poisson@200Hz)".into()],
            labels: vec![delta("serve.proj", 1.0, 1.5), delta("serve.act", 0.5, 0.5)],
            new_labels: vec![("serve.extra".into(), 0.25)],
            vanished_labels: vec![("serve.old".into(), 0.125)],
            total_a_j: 1.5,
            total_b_j: 2.0,
            wasted_a_j: 0.0,
            wasted_b_j: 0.5,
            resyncs_a: 0,
            resyncs_b: 1,
            divergences_a: 0,
            divergences_b: 0,
            windows: WindowAlignment {
                aligned: 10,
                realigns: 1,
                skipped_a: 0,
                skipped_b: 1,
                forced: 0,
            },
            energy_threshold: 0.10,
        };
        let s = render_session_diff(&d);
        assert!(s.contains("session diff: deploy-a -> deploy-b (canary)"), "{s}");
        assert!(s.contains("match exactly"), "{s}");
        assert!(s.contains("note: arrival processes differ"), "{s}");
        assert!(s.contains("REGRESSED"), "{s}");
        assert!(s.contains("serve.proj"), "{s}");
        assert!(s.contains("+500.00 mJ"), "{s}");
        assert!(s.contains("+33.3%"), "{s}");
        assert!(s.contains("0.00 uJ->500.00 mJ"), "{s}");
        assert!(s.contains("new label in B: serve.extra"), "{s}");
        assert!(s.contains("vanished label (A only): serve.old"), "{s}");
        assert!(s.contains("10 aligned, 1 realigns (0 + 1 skipped), 0 forced"), "{s}");
        // the regressed label ranks first, the flat one is "~"
        let proj_pos = s.find("serve.proj").unwrap();
        let act_pos = s.find("serve.act").unwrap();
        assert!(proj_pos < act_pos, "regression must rank first");
    }

    #[test]
    fn static_diff_renders_flagged_regions_and_unmatched() {
        use crate::analysis::diff::{RegionDelta, UnmatchedRegion};
        let region = |la: &str, lb: &str, aj: f64, bj: f64, tier, verdict| RegionDelta {
            node_a: 3,
            node_b: 5,
            label_a: la.to_string(),
            label_b: lb.to_string(),
            op: "conv2d",
            kernel_a: "implicit_gemm_tf32".into(),
            kernel_b: "implicit_gemm_fp32".into(),
            a_j: aj,
            b_j: bj,
            delta_j: bj - aj,
            tier,
            verdict,
        };
        let d = StaticDiffReport {
            target_a: "mini-stable-diffusion".into(),
            target_b: "case-c8".into(),
            nodes_a: 30,
            nodes_b: 30,
            total_a_j: 1.0,
            total_b_j: 1.4,
            regions: vec![
                region(
                    "sd.resnet.conv1",
                    "sd.resnet.conv1",
                    0.4,
                    0.7,
                    MatchTier::Hash,
                    RegionVerdict::BWasteful,
                ),
                region(
                    "torch.conv2d",
                    "tf.conv2d",
                    0.3,
                    0.3,
                    MatchTier::Label,
                    RegionVerdict::Close,
                ),
            ],
            unmatched_a: vec![],
            unmatched_b: vec![UnmatchedRegion {
                node: 9,
                label: "sd.skip.concat".into(),
                op: "concat",
                cost_j: 0.05,
            }],
            error: None,
        };
        let s = render_static_diff(&d);
        assert!(s.contains("static diff: mini-stable-diffusion vs case-c8"), "{s}");
        assert!(s.contains("30 vs 30 nodes"), "{s}");
        assert!(
            s.contains("2 matched (1 hash / 1 label / 0 bucket / 0 fuzzy), 0 + 1 unmatched"),
            "{s}"
        );
        assert!(s.contains("sd.resnet.conv1"), "{s}");
        assert!(s.contains("B WASTEFUL"), "{s}");
        assert!(s.contains("+300.00 mJ"), "{s}");
        // within-threshold pair stays out of the table
        assert!(!s.contains("torch.conv2d"), "{s}");
        assert!(s.contains("1 matched region(s) within threshold"), "{s}");
        assert!(s.contains("unmatched on case-c8: sd.skip.concat (concat, 50.00 mJ)"), "{s}");

        let broken = StaticDiffReport { error: Some("graph has a cycle".into()), ..d };
        let s = render_static_diff(&broken);
        assert!(s.contains("INVALID: graph has a cycle"), "{s}");
        assert!(!s.contains("regions:"), "{s}");
    }

    #[test]
    fn lint_report_renders_findings_and_errors() {
        use crate::analysis::{LintFinding, LintReport, Severity, TargetReport};
        let r = LintReport {
            targets: vec![
                TargetReport {
                    name: "mini-x".into(),
                    nodes: 12,
                    static_j: 0.5,
                    findings: vec![LintFinding {
                        rule: "redundant-sync",
                        severity: Severity::Warn,
                        nodes: vec![3],
                        label: "dist.Join.barrier".into(),
                        est_wasted_j: 0.099,
                        suggestion: "drop the barrier".into(),
                        steps: vec![],
                    }],
                    error: None,
                    interactions: vec![],
                },
                TargetReport {
                    name: "mini-broken".into(),
                    nodes: 2,
                    static_j: 0.0,
                    findings: vec![],
                    error: Some("graph `g` has a cycle through node 1 (`a`)".into()),
                    interactions: vec![],
                },
            ],
            total_findings: 1,
            total_est_wasted_j: 0.099,
        };
        let s = render_lint(&r);
        assert!(s.contains("Magneton lint: 2 targets, 1 findings"), "{s}");
        assert!(s.contains("redundant-sync"), "{s}");
        assert!(s.contains("dist.Join.barrier"), "{s}");
        assert!(s.contains("mini-broken: INVALID"), "{s}");
        assert!(s.contains("has a cycle"), "{s}");
    }

    #[test]
    fn lint_interactions_render_marginal_breakdown_and_json_round_trips() {
        use crate::analysis::interact::FlagMarginal;
        use crate::analysis::{
            InteractionDiagnosis, LintFinding, LintReport, RewriteStep, Severity, TargetReport,
        };
        let diag = InteractionDiagnosis {
            nodes: vec![4, 9],
            label: "sd.resnet.conv1".into(),
            assignment: vec![
                ("torch.backends.cuda.matmul.allow_tf32".into(), "1".into()),
                ("torch.channels_last memory_format".into(), "1".into()),
            ],
            joint_saved_j: 6.25e-4,
            kernel_now: "ampere_sgemm_fp32_128x128".into(),
            kernel_then: "ampere_tf32_s1688gemm_128x128_nhwc".into(),
            marginals: vec![
                FlagMarginal {
                    flag: "torch.backends.cuda.matmul.allow_tf32".into(),
                    value: "1".into(),
                    source: "configuration flag `allow_tf32`".into(),
                    saved_j: 1.5e-4,
                    time_ok: false,
                },
                FlagMarginal {
                    flag: "torch.channels_last memory_format".into(),
                    value: "1".into(),
                    source: "configuration flag `channels_last`".into(),
                    saved_j: -6.0e-5,
                    time_ok: true,
                },
            ],
        };
        let r = LintReport {
            targets: vec![TargetReport {
                name: "interact~case-c8-joint".into(),
                nodes: 30,
                static_j: 0.125,
                findings: vec![LintFinding {
                    rule: "interaction",
                    severity: Severity::Warn,
                    nodes: vec![4, 9],
                    label: "sd.resnet.conv1".into(),
                    est_wasted_j: 6.25e-4,
                    suggestion: "set both flags jointly".into(),
                    steps: vec![RewriteStep::SetAttr {
                        node: 4,
                        key: "torch.backends.cuda.matmul.allow_tf32".into(),
                        value: "1".into(),
                    }],
                }],
                error: None,
                interactions: vec![diag],
            }],
            total_findings: 1,
            total_est_wasted_j: 6.25e-4,
        };
        let s = render_lint(&r);
        assert!(s.contains("interaction `sd.resnet.conv1`"), "{s}");
        assert!(s.contains("across 2 node(s)"), "{s}");
        assert!(s.contains("1-minimal"), "{s}");
        // per-flag marginal lines: the tf32 flip alone blows the time
        // budget, the layout flip alone costs energy
        assert!(s.contains("alone saves") && s.contains("but breaks the time budget"), "{s}");
        assert!(s.contains("alone costs"), "{s}");

        let rendered = lint_report_json(&r).render();
        let back = Json::parse(&rendered).expect("lint json parses back");
        let tgt = &back.get("targets").unwrap().as_arr().unwrap()[0];
        assert_eq!(tgt.get("name").unwrap().as_str(), Some("interact~case-c8-joint"));
        assert_eq!(tgt.get("error"), Some(&Json::Null));
        let f = &tgt.get("findings").unwrap().as_arr().unwrap()[0];
        assert_eq!(f.get("rule").unwrap().as_str(), Some("interaction"));
        // lossless floats: estimates survive the round trip bit-for-bit
        let est = f.get("est_wasted_j").unwrap().as_f64().unwrap();
        assert_eq!(est.to_bits(), (6.25e-4f64).to_bits());
        let st = &f.get("steps").unwrap().as_arr().unwrap()[0];
        assert_eq!(st.get("kind").unwrap().as_str(), Some("set_attr"));
        assert_eq!(st.get("node").unwrap().as_usize(), Some(4));
        let d = &tgt.get("interactions").unwrap().as_arr().unwrap()[0];
        assert_eq!(d.get("assignment").unwrap().as_arr().unwrap().len(), 2);
        let joint = d.get("joint_saved_j").unwrap().as_f64().unwrap();
        assert_eq!(joint.to_bits(), (6.25e-4f64).to_bits());
        let m = &d.get("marginals").unwrap().as_arr().unwrap()[0];
        assert_eq!(m.get("time_ok").unwrap().as_bool(), Some(false));
        let marg = m.get("saved_j").unwrap().as_f64().unwrap();
        assert_eq!(marg.to_bits(), (1.5e-4f64).to_bits());
    }

    #[test]
    fn verify_line_reports_sign_agreement() {
        use crate::analysis::VerifyOutcome;
        let v = VerifyOutcome {
            target: "case-c9".into(),
            label: "dist.Join.barrier".into(),
            rule: "redundant-sync",
            est_wasted_j: 0.099,
            measured_delta_j: 0.097,
            energy_before_j: 1.0,
            energy_after_j: 0.903,
            same_sign: true,
            detected: true,
        };
        let s = render_verify(&v);
        assert!(s.contains("CONFIRMED"), "{s}");
        assert!(s.contains("case-c9"), "{s}");
        assert!(s.contains("flagged the pair"), "{s}");
    }

    #[test]
    fn fleet_report_renders_ranked_rows() {
        let mut fleet = crate::coordinator::fleet::FleetAudit::new(DeviceSpec::h200_sim());
        fleet.add_pair("alpha", small_run(), small_run());
        fleet.add_pair("beta", small_run(), small_run());
        let r = fleet.run();
        let s = render_fleet(&r);
        assert!(s.contains("fleet audit"));
        assert!(s.contains("alpha") && s.contains("beta"));
        assert!(s.contains("total:"));
        assert_eq!(fleet_table(&r).len(), 2);
    }
}
