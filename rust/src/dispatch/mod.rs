//! Miniature framework-dispatch VM.
//!
//! Real ML frameworks choose CUDA kernels deep inside C++ dispatch code
//! that branches on configuration flags (`allow_tf32`), API arguments
//! (`use_tensor_cores`), and input properties (contiguity, layout). The
//! paper's Algorithm 2 diagnoses energy waste by instrumenting exactly
//! those functions with basic-block tracing, re-running both
//! applications, and extracting the control variable at the first
//! basic-block divergence.
//!
//! This module is the substrate that makes that algorithm executable
//! here: each framework API has a [`Routine`] — a tiny CFG of basic
//! blocks whose terminators branch on an environment (config flags ∪
//! operator attributes) and finally launch a [`KernelChoice`]. Running a
//! routine yields both the chosen kernel and the exact BB trace, and a
//! provenance table maps every branch variable back to its ultimate
//! source (the configuration parameter or API argument a developer can
//! change) — the paper's backward data-flow step.

use std::collections::{BTreeMap, BTreeSet};

use crate::energy::ComputeUnit;
use crate::trace::Frame;

/// Runtime environment a routine branches on: config flags merged with
/// per-op attributes (attributes win).
#[derive(Clone, Debug, Default)]
pub struct Env {
    pub values: BTreeMap<String, String>,
}

impl Env {
    pub fn new() -> Env {
        Env::default()
    }

    pub fn set(&mut self, k: &str, v: &str) -> &mut Self {
        self.values.insert(k.to_string(), v.to_string());
        self
    }

    pub fn with(mut self, k: &str, v: &str) -> Env {
        self.set(k, v);
        self
    }

    /// Read a variable; absent variables read as "" (false-y).
    pub fn get(&self, k: &str) -> &str {
        self.values.get(k).map(String::as_str).unwrap_or("")
    }

    /// Merge `other` on top of `self`.
    pub fn merged(&self, other: &BTreeMap<String, String>) -> Env {
        let mut v = self.values.clone();
        for (k, val) in other {
            v.insert(k.clone(), val.clone());
        }
        Env { values: v }
    }
}

/// The kernel a routine ultimately launches, with its cost-relevant
/// variant parameters (consumed by the executor's cost model).
#[derive(Clone, Debug)]
pub struct KernelChoice {
    /// CUDA-kernel-style name, e.g. `ampere_sgemm_tf32_128x64`.
    pub kernel: String,
    pub unit: ComputeUnit,
    /// Implementation quality in (0,1]: <1 draws extra power.
    pub efficiency: f64,
    /// Wall-time multiplier (strided access, low occupancy).
    pub time_mult: f64,
    /// Extra HBM traffic multiplier (implicit copies, bad layouts).
    pub bytes_mult: f64,
}

impl KernelChoice {
    pub fn new(kernel: &str, unit: ComputeUnit) -> KernelChoice {
        KernelChoice {
            kernel: kernel.to_string(),
            unit,
            efficiency: 1.0,
            time_mult: 1.0,
            bytes_mult: 1.0,
        }
    }

    pub fn quality(mut self, efficiency: f64, time_mult: f64, bytes_mult: f64) -> KernelChoice {
        self.efficiency = efficiency;
        self.time_mult = time_mult;
        self.bytes_mult = bytes_mult;
        self
    }
}

/// Basic-block terminator.
#[derive(Clone, Debug)]
pub enum Term {
    /// Branch on `env[var] == eq`.
    CondBranch { var: String, eq: String, then_bb: usize, else_bb: usize },
    /// Multi-way branch on `env[var]`.
    Switch { var: String, arms: Vec<(String, usize)>, default_bb: usize },
    /// Unconditional jump.
    Jump { bb: usize },
    /// Launch `choices[idx]` and return.
    Launch { idx: usize },
}

/// One basic block inside a routine.
#[derive(Clone, Debug)]
pub struct Block {
    /// Function the block belongs to (gives Algorithm 2 its frames).
    pub func: String,
    pub term: Term,
}

/// Where a branch variable ultimately comes from.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum VarSource {
    /// Global configuration flag, e.g. `torch.backends.cuda.matmul.allow_tf32`.
    ConfigFlag(String),
    /// Argument of the calling API, e.g. `use_tensor_cores=` of FlashInfer.
    ApiArgument(String),
    /// Property of the input tensor, e.g. `contiguous layout`.
    InputProperty(String),
}

impl VarSource {
    pub fn describe(&self) -> String {
        match self {
            VarSource::ConfigFlag(s) => format!("configuration flag `{s}`"),
            VarSource::ApiArgument(s) => format!("API argument `{s}`"),
            VarSource::InputProperty(s) => format!("input property `{s}`"),
        }
    }
}

/// A dispatch routine: the CFG a framework runs between the public API
/// and the kernel launch.
#[derive(Clone, Debug)]
pub struct Routine {
    /// Public API name, e.g. `torch.matmul`.
    pub api: String,
    /// C++-side frames between the API and the launch (inflection-point
    /// context for Algorithm 2).
    pub frames: Vec<Frame>,
    pub blocks: Vec<Block>,
    pub choices: Vec<KernelChoice>,
    /// Backward-dataflow table: branch var → ultimate source.
    pub provenance: BTreeMap<String, VarSource>,
}

/// Result of running a routine under an environment.
#[derive(Clone, Debug)]
pub struct Outcome {
    pub choice: KernelChoice,
    /// `(func, block_index)` sequence — the basic-block trace Algorithm 2
    /// diffs after instrumentation.
    pub bb_trace: Vec<(String, usize)>,
    /// Full call path: API frame + routine frames + launching function.
    pub call_path: Vec<Frame>,
}

impl Routine {
    /// Single-block routine that always launches `choice`.
    pub fn direct(api: &str, frames: Vec<Frame>, choice: KernelChoice) -> Routine {
        Routine {
            api: api.to_string(),
            frames,
            blocks: vec![Block { func: api.to_string(), term: Term::Launch { idx: 0 } }],
            choices: vec![choice],
            provenance: BTreeMap::new(),
        }
    }

    /// Two-way routine: branch once on `var == eq` in function `func`.
    pub fn branch_on(
        api: &str,
        frames: Vec<Frame>,
        func: &str,
        var: &str,
        eq: &str,
        source: VarSource,
        if_true: KernelChoice,
        if_false: KernelChoice,
    ) -> Routine {
        let mut provenance = BTreeMap::new();
        provenance.insert(var.to_string(), source);
        Routine {
            api: api.to_string(),
            frames,
            blocks: vec![
                Block {
                    func: func.to_string(),
                    term: Term::CondBranch {
                        var: var.to_string(),
                        eq: eq.to_string(),
                        then_bb: 1,
                        else_bb: 2,
                    },
                },
                Block { func: func.to_string(), term: Term::Launch { idx: 0 } },
                Block { func: func.to_string(), term: Term::Launch { idx: 1 } },
            ],
            choices: vec![if_true, if_false],
            provenance,
        }
    }

    /// Execute under `env`, producing the kernel choice and BB trace.
    pub fn run(&self, env: &Env) -> Outcome {
        let mut bb = 0usize;
        let mut bb_trace = Vec::new();
        let mut guard = 0usize;
        loop {
            guard += 1;
            assert!(guard <= 10_000, "dispatch routine `{}` does not terminate", self.api);
            let block = &self.blocks[bb];
            bb_trace.push((block.func.clone(), bb));
            match &block.term {
                Term::CondBranch { var, eq, then_bb, else_bb } => {
                    bb = if env.get(var) == eq { *then_bb } else { *else_bb };
                }
                Term::Switch { var, arms, default_bb } => {
                    let v = env.get(var);
                    bb = arms
                        .iter()
                        .find(|(val, _)| val == v)
                        .map(|(_, b)| *b)
                        .unwrap_or(*default_bb);
                }
                Term::Jump { bb: nxt } => bb = *nxt,
                Term::Launch { idx } => {
                    let choice = self.choices[*idx].clone();
                    let mut call_path = vec![Frame::py(&self.api)];
                    call_path.extend(self.frames.clone());
                    call_path.push(Frame::cpp(&block.func));
                    return Outcome { choice, bb_trace, call_path };
                }
            }
        }
    }

    /// Variable read by the terminator of a given block (the paper's
    /// `ExtractControlVariable`).
    pub fn control_var(&self, bb: usize) -> Option<&str> {
        match &self.blocks[bb].term {
            Term::CondBranch { var, .. } | Term::Switch { var, .. } => Some(var),
            _ => None,
        }
    }

    /// Backward data-flow: the ultimate source of a branch variable.
    pub fn source_of(&self, var: &str) -> Option<&VarSource> {
        self.provenance.get(var)
    }

    /// Finite value space of every branch variable in the CFG: each
    /// literal some terminator tests the variable against, plus `""`
    /// (unset — what [`Env::get`] reads for an absent flag). Because
    /// terminators only ever compare against literals, this space is
    /// exhaustive: any other value behaves exactly like one of these.
    pub fn branch_space(&self) -> BTreeMap<String, Vec<String>> {
        let mut space: BTreeMap<String, std::collections::BTreeSet<String>> = BTreeMap::new();
        for block in &self.blocks {
            match &block.term {
                Term::CondBranch { var, eq, .. } => {
                    let vals = space.entry(var.clone()).or_default();
                    vals.insert(String::new());
                    vals.insert(eq.clone());
                }
                Term::Switch { var, arms, .. } => {
                    let vals = space.entry(var.clone()).or_default();
                    vals.insert(String::new());
                    for (v, _) in arms {
                        vals.insert(v.clone());
                    }
                }
                Term::Jump { .. } | Term::Launch { .. } => {}
            }
        }
        space.into_iter().map(|(k, v)| (k, v.into_iter().collect())).collect()
    }

    /// Symbolically execute the CFG over its whole (finite) config
    /// space: every assignment of branch variables to tested-literal-
    /// or-unset values, in deterministic (BTreeMap) order. Each point
    /// records the assignment tried and the [`KernelChoice`] it
    /// reaches, so callers can spot assignments whose kernel is
    /// strictly energy-dominated by a reachable alternative.
    pub fn enumerate_outcomes(&self) -> Vec<ConfigOutcome> {
        let space: Vec<(String, Vec<String>)> = self.branch_space().into_iter().collect();
        let points: usize = space.iter().map(|(_, vs)| vs.len()).product();
        let mut out = Vec::with_capacity(points);
        for mut point in 0..points {
            let mut assignment = BTreeMap::new();
            let mut env = Env::new();
            for (var, vals) in &space {
                let v = &vals[point % vals.len()];
                point /= vals.len();
                assignment.insert(var.clone(), v.clone());
                if !v.is_empty() {
                    env.set(var, v);
                }
            }
            let choice_idx = self.launch_idx(&env);
            out.push(ConfigOutcome {
                assignment,
                choice_idx,
                choice: self.choices[choice_idx].clone(),
            });
        }
        out
    }

    /// Walk the CFG under a fully concrete `env` to the launched choice
    /// index — the public face of [`Routine::run`] for callers that only
    /// need the index (the joint interaction search replays thousands of
    /// assignments and must not pay for trace allocation).
    pub fn launch_for(&self, env: &Env) -> usize {
        self.launch_idx(env)
    }

    /// Choice indices reachable under a *partial* assignment: variables
    /// present in `assigned` are pinned (`""` means explicitly unset),
    /// absent variables are free and explore every successor. This is
    /// the optimistic-bound substrate of branch-and-bound dominance
    /// pruning: any kernel the remaining free flags could still select
    /// is in the returned set. Deterministic (worklist in block order,
    /// `BTreeSet` result) and cycle-safe via a visited set.
    pub fn reachable_choices(&self, assigned: &BTreeMap<String, String>) -> BTreeSet<usize> {
        let mut reachable = BTreeSet::new();
        let mut seen = vec![false; self.blocks.len()];
        let mut work = vec![0usize];
        while let Some(bb) = work.pop() {
            if seen[bb] {
                continue;
            }
            seen[bb] = true;
            match &self.blocks[bb].term {
                Term::CondBranch { var, eq, then_bb, else_bb } => match assigned.get(var) {
                    Some(v) => work.push(if v == eq { *then_bb } else { *else_bb }),
                    None => {
                        work.push(*then_bb);
                        work.push(*else_bb);
                    }
                },
                Term::Switch { var, arms, default_bb } => match assigned.get(var) {
                    Some(v) => work.push(
                        arms.iter().find(|(val, _)| val == v).map(|(_, b)| *b).unwrap_or(*default_bb),
                    ),
                    None => {
                        work.push(*default_bb);
                        for (_, b) in arms {
                            work.push(*b);
                        }
                    }
                },
                Term::Jump { bb: nxt } => work.push(*nxt),
                Term::Launch { idx } => {
                    reachable.insert(*idx);
                }
            }
        }
        reachable
    }

    /// Walk the CFG under `env` to the launched choice index.
    fn launch_idx(&self, env: &Env) -> usize {
        let mut bb = 0usize;
        let mut guard = 0usize;
        loop {
            guard += 1;
            assert!(guard <= 10_000, "dispatch routine `{}` does not terminate", self.api);
            match &self.blocks[bb].term {
                Term::CondBranch { var, eq, then_bb, else_bb } => {
                    bb = if env.get(var) == eq { *then_bb } else { *else_bb };
                }
                Term::Switch { var, arms, default_bb } => {
                    let v = env.get(var);
                    bb = arms
                        .iter()
                        .find(|(val, _)| val == v)
                        .map(|(_, b)| *b)
                        .unwrap_or(*default_bb);
                }
                Term::Jump { bb: nxt } => bb = *nxt,
                Term::Launch { idx } => return *idx,
            }
        }
    }
}

/// One point of a routine's symbolically enumerated config space: the
/// branch-variable assignment tried and the kernel it selects.
#[derive(Clone, Debug)]
pub struct ConfigOutcome {
    /// Branch-variable assignment, var → tested value (`""` = unset).
    pub assignment: BTreeMap<String, String>,
    /// Index into [`Routine::choices`] of the launched kernel.
    pub choice_idx: usize,
    pub choice: KernelChoice,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tf32_routine() -> Routine {
        Routine::branch_on(
            "torch.matmul",
            vec![Frame::cpp("at::native::matmul"), Frame::cpp("at::cuda::blas::gemm")],
            "at::cuda::blas::gemm",
            "allow_tf32",
            "true",
            VarSource::ConfigFlag("torch.backends.cuda.matmul.allow_tf32".into()),
            KernelChoice::new("ampere_tf32_s1688gemm", ComputeUnit::TensorCore),
            KernelChoice::new("ampere_sgemm_f32", ComputeUnit::CudaCore),
        )
    }

    #[test]
    fn branch_selects_kernel_by_config() {
        let r = tf32_routine();
        let on = r.run(&Env::new().with("allow_tf32", "true"));
        assert_eq!(on.choice.kernel, "ampere_tf32_s1688gemm");
        let off = r.run(&Env::new());
        assert_eq!(off.choice.kernel, "ampere_sgemm_f32");
    }

    #[test]
    fn bb_traces_diverge_at_branch() {
        let r = tf32_routine();
        let a = r.run(&Env::new().with("allow_tf32", "true")).bb_trace;
        let b = r.run(&Env::new()).bb_trace;
        assert_eq!(a[0], b[0]); // shared entry block
        assert_ne!(a[1], b[1]); // divergence right after the branch
    }

    #[test]
    fn control_var_and_provenance() {
        let r = tf32_routine();
        assert_eq!(r.control_var(0), Some("allow_tf32"));
        let src = r.source_of("allow_tf32").unwrap();
        assert_eq!(
            src.describe(),
            "configuration flag `torch.backends.cuda.matmul.allow_tf32`"
        );
    }

    #[test]
    fn call_path_layers() {
        let r = tf32_routine();
        let o = r.run(&Env::new());
        assert_eq!(o.call_path[0], Frame::py("torch.matmul"));
        assert!(o.call_path.len() >= 3);
    }

    #[test]
    fn switch_routine() {
        let mut prov = BTreeMap::new();
        prov.insert("layout".to_string(), VarSource::InputProperty("memory_format".into()));
        let r = Routine {
            api: "conv2d".into(),
            frames: vec![],
            blocks: vec![
                Block {
                    func: "cudnn_dispatch".into(),
                    term: Term::Switch {
                        var: "layout".into(),
                        arms: vec![("nchw".into(), 1), ("nhwc".into(), 2)],
                        default_bb: 1,
                    },
                },
                Block { func: "cudnn_dispatch".into(), term: Term::Launch { idx: 0 } },
                Block { func: "cudnn_dispatch".into(), term: Term::Launch { idx: 1 } },
            ],
            choices: vec![
                KernelChoice::new("implicit_gemm_nchw", ComputeUnit::TensorCore),
                KernelChoice::new("implicit_gemm_nhwc", ComputeUnit::TensorCore),
            ],
            provenance: prov,
        };
        assert_eq!(r.run(&Env::new().with("layout", "nhwc")).choice.kernel, "implicit_gemm_nhwc");
        assert_eq!(r.run(&Env::new().with("layout", "weird")).choice.kernel, "implicit_gemm_nchw");
    }

    #[test]
    fn env_merge_attrs_override() {
        let base = Env::new().with("a", "1").with("b", "2");
        let mut attrs = BTreeMap::new();
        attrs.insert("b".to_string(), "9".to_string());
        let m = base.merged(&attrs);
        assert_eq!(m.get("a"), "1");
        assert_eq!(m.get("b"), "9");
    }

    #[test]
    fn branch_space_collects_tested_literals_plus_unset() {
        let r = tf32_routine();
        let space = r.branch_space();
        assert_eq!(space.len(), 1);
        assert_eq!(space["allow_tf32"], vec!["".to_string(), "true".to_string()]);
    }

    #[test]
    fn enumeration_covers_the_full_config_space() {
        let r = tf32_routine();
        let outcomes = r.enumerate_outcomes();
        assert_eq!(outcomes.len(), 2);
        let on = outcomes.iter().find(|o| o.assignment["allow_tf32"] == "true").unwrap();
        let off = outcomes.iter().find(|o| o.assignment["allow_tf32"].is_empty()).unwrap();
        assert_eq!(on.choice.unit, ComputeUnit::TensorCore);
        assert_eq!(off.choice.unit, ComputeUnit::CudaCore);
        assert_ne!(on.choice_idx, off.choice_idx);
        // symbolic enumeration agrees with concrete execution point-wise
        for o in &outcomes {
            let mut env = Env::new();
            for (k, v) in &o.assignment {
                if !v.is_empty() {
                    env.set(k, v);
                }
            }
            assert_eq!(r.run(&env).choice.kernel, o.choice.kernel);
        }
    }

    #[test]
    fn enumeration_handles_switch_and_direct_routines() {
        let mut prov = BTreeMap::new();
        prov.insert("layout".to_string(), VarSource::InputProperty("memory_format".into()));
        let r = Routine {
            api: "conv2d".into(),
            frames: vec![],
            blocks: vec![
                Block {
                    func: "cudnn_dispatch".into(),
                    term: Term::Switch {
                        var: "layout".into(),
                        arms: vec![("nchw".into(), 1), ("nhwc".into(), 2)],
                        default_bb: 1,
                    },
                },
                Block { func: "cudnn_dispatch".into(), term: Term::Launch { idx: 0 } },
                Block { func: "cudnn_dispatch".into(), term: Term::Launch { idx: 1 } },
            ],
            choices: vec![
                KernelChoice::new("implicit_gemm_nchw", ComputeUnit::TensorCore),
                KernelChoice::new("implicit_gemm_nhwc", ComputeUnit::TensorCore),
            ],
            provenance: prov,
        };
        // "", "nchw", "nhwc" — unset falls through to the default arm
        let outcomes = r.enumerate_outcomes();
        assert_eq!(outcomes.len(), 3);
        let reachable: std::collections::BTreeSet<usize> =
            outcomes.iter().map(|o| o.choice_idx).collect();
        assert_eq!(reachable.len(), 2);

        let d = Routine::direct(
            "jax.lax.add",
            vec![],
            KernelChoice::new("fusion_add", ComputeUnit::CudaCore),
        );
        let outcomes = d.enumerate_outcomes();
        assert_eq!(outcomes.len(), 1);
        assert!(outcomes[0].assignment.is_empty());
    }

    #[test]
    fn enumeration_is_deterministic() {
        let r = tf32_routine();
        let a: Vec<(BTreeMap<String, String>, usize)> =
            r.enumerate_outcomes().into_iter().map(|o| (o.assignment, o.choice_idx)).collect();
        let b: Vec<(BTreeMap<String, String>, usize)> =
            r.enumerate_outcomes().into_iter().map(|o| (o.assignment, o.choice_idx)).collect();
        assert_eq!(a, b);
    }

    #[test]
    fn launch_for_agrees_with_run() {
        let r = tf32_routine();
        for env in [Env::new(), Env::new().with("allow_tf32", "true")] {
            assert_eq!(r.choices[r.launch_for(&env)].kernel, r.run(&env).choice.kernel);
        }
    }

    #[test]
    fn reachable_choices_narrow_as_flags_pin() {
        let r = tf32_routine();
        let free: Vec<usize> = r.reachable_choices(&BTreeMap::new()).into_iter().collect();
        assert_eq!(free, vec![0, 1], "free flags reach both kernels");
        let mut on = BTreeMap::new();
        on.insert("allow_tf32".to_string(), "true".to_string());
        assert_eq!(r.reachable_choices(&on).into_iter().collect::<Vec<_>>(), vec![0]);
        // "" pins the flag to *unset* — not the same as leaving it free
        let mut off = BTreeMap::new();
        off.insert("allow_tf32".to_string(), String::new());
        assert_eq!(r.reachable_choices(&off).into_iter().collect::<Vec<_>>(), vec![1]);
    }

    #[test]
    fn reachable_choices_explore_switch_arms_and_default() {
        let mut prov = BTreeMap::new();
        prov.insert("layout".to_string(), VarSource::InputProperty("memory_format".into()));
        let r = Routine {
            api: "conv2d".into(),
            frames: vec![],
            blocks: vec![
                Block {
                    func: "cudnn_dispatch".into(),
                    term: Term::Switch {
                        var: "layout".into(),
                        arms: vec![("nchw".into(), 1), ("nhwc".into(), 2)],
                        default_bb: 1,
                    },
                },
                Block { func: "cudnn_dispatch".into(), term: Term::Launch { idx: 0 } },
                Block { func: "cudnn_dispatch".into(), term: Term::Launch { idx: 1 } },
            ],
            choices: vec![
                KernelChoice::new("implicit_gemm_nchw", ComputeUnit::TensorCore),
                KernelChoice::new("implicit_gemm_nhwc", ComputeUnit::TensorCore),
            ],
            provenance: prov,
        };
        let free: Vec<usize> = r.reachable_choices(&BTreeMap::new()).into_iter().collect();
        assert_eq!(free, vec![0, 1]);
        let mut pinned = BTreeMap::new();
        pinned.insert("layout".to_string(), "nhwc".to_string());
        assert_eq!(r.reachable_choices(&pinned).into_iter().collect::<Vec<_>>(), vec![1]);
    }

    #[test]
    fn direct_routine_trivial_trace() {
        let r = Routine::direct(
            "jax.lax.add",
            vec![],
            KernelChoice::new("fusion_add", ComputeUnit::CudaCore),
        );
        let o = r.run(&Env::new());
        assert_eq!(o.bb_trace.len(), 1);
        assert_eq!(o.choice.kernel, "fusion_add");
    }
}
