//! Energy-waste detection over matched regions (paper §4.2 + §6.1).
//!
//! A matched region pair is flagged as *software energy waste* when the
//! energy of the two semantically equivalent implementations differs by
//! more than the detection threshold (paper default 10 %, reducible to
//! 5 % without false positives) **and** the efficient variant is not a
//! performance/accuracy trade-off: it must not be more than 1 % slower,
//! and the two runs' final outputs must agree within 1 % element-wise
//! relative difference.

use crate::exec::RunArtifacts;
use crate::matching::Region;

/// Which run wastes energy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Side {
    A,
    B,
}

/// Detection thresholds (paper §6.1 defaults).
#[derive(Clone, Copy, Debug)]
pub struct DetectConfig {
    /// Minimum relative energy difference to flag (default 10 %).
    pub energy_threshold: f64,
    /// Max slowdown allowed for the efficient variant (default 1 %).
    pub perf_tolerance: f64,
    /// Max element-wise relative output difference (default 1 %).
    pub output_tolerance: f64,
}

impl Default for DetectConfig {
    fn default() -> DetectConfig {
        DetectConfig { energy_threshold: 0.10, perf_tolerance: 0.01, output_tolerance: 0.01 }
    }
}

/// A detected energy-waste finding.
#[derive(Clone, Debug)]
pub struct Finding {
    pub region: Region,
    pub energy_a_j: f64,
    pub energy_b_j: f64,
    pub time_a_us: f64,
    pub time_b_us: f64,
    /// Relative energy difference |eA − eB| / max(eA, eB).
    pub diff_frac: f64,
    /// The wasteful side.
    pub wasteful: Side,
    /// True when the "efficient" side pays > perf_tolerance in time —
    /// i.e. this is a performance-energy trade-off, not waste (Fig 1).
    pub is_tradeoff: bool,
    /// Operator labels of the wasteful region (diagnosis entry points).
    pub labels: Vec<String>,
}

impl Finding {
    /// Human summary line.
    pub fn summary(&self) -> String {
        let side = match self.wasteful {
            Side::A => "A",
            Side::B => "B",
        };
        format!(
            "side {side} wastes {:.1}% energy over ops [{}] ({} vs {})",
            self.diff_frac * 100.0,
            self.labels.join(", "),
            crate::util::table::fmt_joules(self.energy_a_j),
            crate::util::table::fmt_joules(self.energy_b_j),
        )
    }
}

/// Per-node `(energy_j, time_us)` accumulated over a run's kernel
/// records in one pass, so region costing is `O(records + Σ|region|)`
/// instead of the old `O(records × |region|)` scan per region.
fn per_node_costs(arts: &RunArtifacts) -> Vec<(f64, f64)> {
    let mut costs = vec![(0.0, 0.0); arts.graph.len()];
    for r in &arts.records {
        if let Some(c) = costs.get_mut(r.node) {
            c.0 += r.energy_j;
            c.1 += r.time_us;
        }
    }
    costs
}

fn region_cost(costs: &[(f64, f64)], nodes: &[usize]) -> (f64, f64) {
    let mut e = 0.0;
    let mut t = 0.0;
    for &n in nodes {
        let (ne, nt) = costs[n];
        e += ne;
        t += nt;
    }
    (e, t)
}

/// Verify the two runs compute the same thing (the paper's ≤1 %
/// element-wise guard). Falls back to fingerprint distance when the
/// final layouts differ in shape.
pub fn outputs_agree(a: &RunArtifacts, b: &RunArtifacts, tol: f64) -> bool {
    let oa = a.output();
    let ob = b.output();
    if oa.shape() == ob.shape() {
        (oa.global_rel_diff(ob) as f64) <= tol
    } else if oa.numel() == ob.numel() {
        crate::fingerprint::fingerprint(oa).distance(&crate::fingerprint::fingerprint(ob)) <= tol
    } else {
        false
    }
}

/// Detect energy waste across matched regions. Returns findings above
/// the threshold, most wasteful first. Regions whose efficient variant
/// trades performance for energy are annotated, not dropped — callers
/// (and Table 2) distinguish waste from trade-offs.
pub fn detect(
    a: &RunArtifacts,
    b: &RunArtifacts,
    regions: &[Region],
    cfg: &DetectConfig,
) -> Vec<Finding> {
    let output_ok = outputs_agree(a, b, cfg.output_tolerance);
    let costs_a = per_node_costs(a);
    let costs_b = per_node_costs(b);
    let mut findings = Vec::new();
    for region in regions {
        let (ea, ta) = region_cost(&costs_a, &region.a_nodes);
        let (eb, tb) = region_cost(&costs_b, &region.b_nodes);
        if ea <= 0.0 && eb <= 0.0 {
            continue;
        }
        let diff = (ea - eb).abs() / ea.max(eb);
        if diff < cfg.energy_threshold || !output_ok {
            continue;
        }
        let wasteful = if ea > eb { Side::A } else { Side::B };
        // trade-off check: does the efficient side lose wall time?
        let (t_waste, t_eff) = match wasteful {
            Side::A => (ta, tb),
            Side::B => (tb, ta),
        };
        let is_tradeoff = t_eff > t_waste * (1.0 + cfg.perf_tolerance);
        let labels = match wasteful {
            Side::A => region
                .a_nodes
                .iter()
                .map(|&n| a.graph.nodes[n].label.clone())
                .collect(),
            Side::B => region
                .b_nodes
                .iter()
                .map(|&n| b.graph.nodes[n].label.clone())
                .collect(),
        };
        findings.push(Finding {
            region: region.clone(),
            energy_a_j: ea,
            energy_b_j: eb,
            time_a_us: ta,
            time_b_us: tb,
            diff_frac: diff,
            wasteful,
            is_tradeoff,
            labels,
        });
    }
    findings.sort_by(|x, y| {
        let ka = x.energy_a_j.max(x.energy_b_j) * x.diff_frac;
        let kb = y.energy_a_j.max(y.energy_b_j) * y.diff_frac;
        kb.total_cmp(&ka)
    });
    findings
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dispatch::{Env, KernelChoice, Routine, VarSource};
    use crate::energy::{ComputeUnit, DeviceSpec};
    use crate::exec::{Dispatcher, Executor, Program};
    use crate::graph::{Graph, OpKind};
    use crate::matching::match_runs;
    use crate::tensor::Tensor;
    use crate::trace::Frame;
    use crate::util::Prng;

    /// Two identical-math programs where system A's matmul dispatches to
    /// an inefficient kernel (extra power at equal time).
    fn build(eff_a: f64) -> (RunArtifacts, RunArtifacts) {
        let mut rng = Prng::new(11);
        // big enough that dynamic energy dominates launch/static power
        let x = Tensor::randn(&mut rng, &[256, 256]);
        let w = Tensor::randn(&mut rng, &[256, 256]);

        let make_prog = |name: &str| {
            let mut g = Graph::new(name);
            let xi = g.add(OpKind::Input, &[], "x");
            let wi = g.add(OpKind::Weight, &[], "w");
            let m = g.add(OpKind::MatMul, &[xi, wi], "proj");
            let gl = g.add_attr1(OpKind::Gelu, &[m], "act", "approx", "tanh");
            g.add(OpKind::Output, &[gl], "out");
            let mut p = Program::new(g);
            p.feed(0, x.clone());
            p.feed(1, w.clone());
            p
        };

        let mut disp_a = Dispatcher::new();
        disp_a.register(
            "matmul",
            Routine::branch_on(
                "torch.matmul",
                vec![Frame::cpp("at::cuda::blas::gemm")],
                "at::cuda::blas::gemm",
                "allow_tf32",
                "true",
                VarSource::ConfigFlag("allow_tf32".into()),
                KernelChoice::new("tf32_gemm", ComputeUnit::TensorCore),
                KernelChoice::new("legacy_sgemm", ComputeUnit::TensorCore).quality(eff_a, 1.0, 1.0),
            ),
        );
        let a = Executor::new(DeviceSpec::h200_sim(), disp_a, Env::new()).run(&make_prog("A"));
        let mut disp_b = Dispatcher::new();
        disp_b.register(
            "matmul",
            Routine::direct(
                "torch.matmul",
                vec![Frame::cpp("at::cuda::blas::gemm")],
                KernelChoice::new("tf32_gemm", ComputeUnit::TensorCore),
            ),
        );
        let b = Executor::new(DeviceSpec::h200_sim(), disp_b, Env::new()).run(&make_prog("B"));
        (a, b)
    }

    #[test]
    fn detects_inefficient_kernel_region() {
        let (a, b) = build(0.55);
        let (_eq, regions) = match_runs(&a, &b, 1e-3);
        let findings = detect(&a, &b, &regions, &DetectConfig::default());
        assert!(!findings.is_empty(), "no findings");
        let top = &findings[0];
        assert_eq!(top.wasteful, Side::A);
        assert!(top.diff_frac > 0.10);
        assert!(!top.is_tradeoff);
        assert!(top.labels.iter().any(|l| l == "proj"), "{:?}", top.labels);
    }

    #[test]
    fn no_findings_when_systems_equal() {
        let (a, b) = build(1.0);
        let (_eq, regions) = match_runs(&a, &b, 1e-3);
        let findings = detect(&a, &b, &regions, &DetectConfig::default());
        assert!(findings.is_empty(), "{:?}", findings.iter().map(|f| f.summary()).collect::<Vec<_>>());
    }

    #[test]
    fn threshold_controls_sensitivity() {
        let (a, b) = build(0.93); // ~7% extra energy on the matmul
        let (_eq, regions) = match_runs(&a, &b, 1e-3);
        let strict = detect(&a, &b, &regions, &DetectConfig { energy_threshold: 0.10, ..Default::default() });
        let loose = detect(&a, &b, &regions, &DetectConfig { energy_threshold: 0.03, ..Default::default() });
        assert!(strict.len() < loose.len());
    }

    #[test]
    fn outputs_agree_guard() {
        let (a, b) = build(0.55);
        assert!(outputs_agree(&a, &b, 0.01));
    }

    /// Regression: a NaN energy record (e.g. a corrupted power sample)
    /// must not panic the detector's ranking sort (`f64::total_cmp`).
    #[test]
    fn nan_energy_record_does_not_panic() {
        let (mut a, b) = build(0.55);
        // poison one record and make sure multiple findings still rank
        if let Some(r) = a.records.first_mut() {
            r.energy_j = f64::NAN;
        }
        let (_eq, regions) = match_runs(&a, &b, 1e-3);
        let findings = detect(&a, &b, &regions, &DetectConfig::default());
        // sort must complete and respect the total order (descending)
        for w in findings.windows(2) {
            let ka = w[0].energy_a_j.max(w[0].energy_b_j) * w[0].diff_frac;
            let kb = w[1].energy_a_j.max(w[1].energy_b_j) * w[1].diff_frac;
            assert!(ka.total_cmp(&kb).is_ge());
        }
    }
}
