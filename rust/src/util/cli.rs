//! Minimal CLI argument parser (the offline registry has no `clap`).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional args.

use std::collections::BTreeMap;

/// Parsed command-line arguments.
#[derive(Clone, Debug, Default)]
pub struct Args {
    /// Positional arguments in order.
    pub positional: Vec<String>,
    /// `--key value` / `--key=value` options.
    pub options: BTreeMap<String, String>,
    /// Bare `--flag`s.
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw argument strings (no argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut args = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|nxt| !nxt.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    args.options.insert(rest.to_string(), v);
                } else {
                    args.flags.push(rest.to_string());
                }
            } else {
                args.positional.push(a);
            }
        }
        args
    }

    /// Parse the process arguments (skipping argv[0]).
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    /// True if `--name` was passed as a bare flag or `--name=true`.
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
            || self.options.get(name).map(|v| v == "true").unwrap_or(false)
    }

    /// String option with default.
    pub fn get<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.options.get(name).map(String::as_str).unwrap_or(default)
    }

    /// Typed option with default; panics with a clear message on parse error.
    pub fn get_parse<T: std::str::FromStr>(&self, name: &str, default: T) -> T
    where
        T::Err: std::fmt::Display,
    {
        match self.options.get(name) {
            None => default,
            Some(v) => v
                .parse()
                .unwrap_or_else(|e| panic!("invalid value for --{name}: {v} ({e})")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|x| x.to_string()))
    }

    #[test]
    fn positional_and_options() {
        let a = parse(&["detect", "--threshold", "0.1", "--verbose", "--out=res"]);
        assert_eq!(a.positional, vec!["detect"]);
        assert_eq!(a.get("threshold", "0"), "0.1");
        assert_eq!(a.get("out", ""), "res");
        assert!(a.flag("verbose"));
    }

    #[test]
    fn typed_defaults() {
        let a = parse(&["--n", "42"]);
        assert_eq!(a.get_parse("n", 0usize), 42);
        assert_eq!(a.get_parse("missing", 7usize), 7);
        assert!((a.get_parse("missing", 0.5f64) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse(&["--a", "--b"]);
        assert!(a.flag("a"));
        assert!(a.flag("b"));
    }

    #[test]
    #[should_panic]
    fn bad_typed_value_panics() {
        let a = parse(&["--n", "notanum"]);
        let _: usize = a.get_parse("n", 0);
    }
}
