//! Minimal CLI argument parser (the offline registry has no `clap`).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional args.

use std::collections::BTreeMap;

/// Parsed command-line arguments.
#[derive(Clone, Debug, Default)]
pub struct Args {
    /// Positional arguments in order.
    pub positional: Vec<String>,
    /// `--key value` / `--key=value` options.
    pub options: BTreeMap<String, String>,
    /// Bare `--flag`s.
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw argument strings (no argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        Args::parse_reserved(raw, &[])
    }

    /// Like [`Args::parse`], but while no positional has been seen yet,
    /// tokens in `reserved` (the CLI's subcommand names) are never
    /// consumed as a bare flag's value: `--verbose cases` parses as
    /// flag `verbose` + positional `cases` instead of option
    /// `verbose=cases` (which silently emptied the positional list and
    /// fell through to the help screen). Once the subcommand is parsed,
    /// reserved words are ordinary values again (`artifacts --dir
    /// stream` works); to pass one *before* the subcommand, use the
    /// unambiguous `--key=value` form. Negative numbers (`--offset -5`)
    /// still parse as values — only `--`-prefixed tokens and
    /// pre-subcommand reserved words stop a bare flag.
    pub fn parse_reserved<I: IntoIterator<Item = String>>(raw: I, reserved: &[&str]) -> Args {
        let mut args = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|nxt| {
                        !nxt.starts_with("--")
                            && !(args.positional.is_empty() && reserved.contains(&nxt.as_str()))
                    })
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    args.options.insert(rest.to_string(), v);
                } else {
                    args.flags.push(rest.to_string());
                }
            } else {
                args.positional.push(a);
            }
        }
        args
    }

    /// Parse the process arguments (skipping argv[0]).
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    /// Parse the process arguments with reserved subcommand words.
    pub fn from_env_reserved(reserved: &[&str]) -> Args {
        Args::parse_reserved(std::env::args().skip(1), reserved)
    }

    /// True if `--name` was passed as a bare flag or `--name=true`.
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
            || self.options.get(name).map(|v| v == "true").unwrap_or(false)
    }

    /// String option with default.
    pub fn get<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.options.get(name).map(String::as_str).unwrap_or(default)
    }

    /// Typed option with default; panics with a clear message on parse error.
    pub fn get_parse<T: std::str::FromStr>(&self, name: &str, default: T) -> T
    where
        T::Err: std::fmt::Display,
    {
        match self.options.get(name) {
            None => default,
            Some(v) => v
                .parse()
                .unwrap_or_else(|e| panic!("invalid value for --{name}: {v} ({e})")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|x| x.to_string()))
    }

    #[test]
    fn positional_and_options() {
        let a = parse(&["detect", "--threshold", "0.1", "--verbose", "--out=res"]);
        assert_eq!(a.positional, vec!["detect"]);
        assert_eq!(a.get("threshold", "0"), "0.1");
        assert_eq!(a.get("out", ""), "res");
        assert!(a.flag("verbose"));
    }

    #[test]
    fn typed_defaults() {
        let a = parse(&["--n", "42"]);
        assert_eq!(a.get_parse("n", 0usize), 42);
        assert_eq!(a.get_parse("missing", 7usize), 7);
        assert!((a.get_parse("missing", 0.5f64) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse(&["--a", "--b"]);
        assert!(a.flag("a"));
        assert!(a.flag("b"));
    }

    fn parse_r(s: &[&str], reserved: &[&str]) -> Args {
        Args::parse_reserved(s.iter().map(|x| x.to_string()), reserved)
    }

    /// Regression: a bare flag before a subcommand must not swallow it
    /// (`magneton --verbose cases` used to parse as `verbose=cases`
    /// with no positionals, so the CLI printed help instead).
    #[test]
    fn bare_flag_does_not_swallow_reserved_subcommand() {
        let a = parse_r(&["--verbose", "cases", "--id", "c10"], &["cases", "fleet"]);
        assert_eq!(a.positional, vec!["cases"]);
        assert!(a.flag("verbose"));
        assert!(a.options.get("verbose").is_none());
        assert_eq!(a.get("id", ""), "c10");
    }

    /// The `=` form stays unambiguous: it can pass even a reserved
    /// word as a value.
    #[test]
    fn equals_form_can_pass_reserved_word() {
        let a = parse_r(&["--cmd=cases", "fleet"], &["cases", "fleet"]);
        assert_eq!(a.get("cmd", ""), "cases");
        assert_eq!(a.positional, vec!["fleet"]);
    }

    /// Negative numeric values must still be consumed by the preceding
    /// option (they start with `-`, not `--`, and are not reserved).
    #[test]
    fn negative_numeric_values_are_option_values() {
        let a = parse_r(&["cases", "--offset", "-5", "--scale", "-0.25"], &["cases"]);
        assert_eq!(a.positional, vec!["cases"]);
        assert_eq!(a.get_parse("offset", 0i64), -5);
        assert!((a.get_parse("scale", 0.0f64) + 0.25).abs() < 1e-12);
    }

    /// Non-reserved tokens after a bare flag keep the old greedy
    /// behaviour (a value, not a positional).
    #[test]
    fn unreserved_token_still_parses_as_value() {
        let a = parse_r(&["--device", "rtx4090", "cases"], &["cases"]);
        assert_eq!(a.get("device", ""), "rtx4090");
        assert_eq!(a.positional, vec!["cases"]);
    }

    /// Once the subcommand is parsed, a reserved word is an ordinary
    /// option value again: `artifacts --dir stream` must not discard
    /// the user's path.
    #[test]
    fn reserved_word_is_plain_value_after_subcommand() {
        let a = parse_r(&["artifacts", "--dir", "stream"], &["artifacts", "stream"]);
        assert_eq!(a.positional, vec!["artifacts"]);
        assert_eq!(a.get("dir", "default"), "stream");
        assert!(!a.flag("dir"));
    }

    #[test]
    #[should_panic]
    fn bad_typed_value_panics() {
        let a = parse(&["--n", "notanum"]);
        let _: usize = a.get_parse("n", 0);
    }
}
