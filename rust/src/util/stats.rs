//! Descriptive statistics used by the energy model, profilers, and the
//! bench harness.

/// Summary statistics over a sample of f64 observations.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

impl Summary {
    /// Compute the summary of a sample. Empty samples yield zeros.
    pub fn of(xs: &[f64]) -> Summary {
        if xs.is_empty() {
            return Summary { n: 0, mean: 0.0, std: 0.0, min: 0.0, max: 0.0, p50: 0.0, p95: 0.0, p99: 0.0 };
        }
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        let mut sorted = xs.to_vec();
        sorted.sort_by(f64::total_cmp);
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: percentile_sorted(&sorted, 0.50),
            p95: percentile_sorted(&sorted, 0.95),
            p99: percentile_sorted(&sorted, 0.99),
        }
    }
}

/// Linear-interpolated percentile of an ascending-sorted slice.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = pos - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Relative difference |a-b| / max(|a|,|b|,eps).
pub fn rel_diff(a: f64, b: f64) -> f64 {
    (a - b).abs() / a.abs().max(b.abs()).max(1e-12)
}

/// Geometric mean of positive values.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    (xs.iter().map(|x| x.max(1e-300).ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// F1 score from precision/recall counts.
pub fn f1_score(tp: usize, fp: usize, fn_: usize) -> f64 {
    if tp == 0 {
        return 0.0;
    }
    let p = tp as f64 / (tp + fp) as f64;
    let r = tp as f64 / (tp + fn_) as f64;
    2.0 * p * r / (p + r)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_constant() {
        let s = Summary::of(&[5.0; 10]);
        assert_eq!(s.mean, 5.0);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.p50, 5.0);
        assert_eq!(s.min, 5.0);
        assert_eq!(s.max, 5.0);
    }

    #[test]
    fn summary_of_range() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let s = Summary::of(&xs);
        assert!((s.mean - 50.5).abs() < 1e-9);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 100.0);
        assert!((s.p50 - 50.5).abs() < 1e-9);
    }

    #[test]
    fn summary_empty() {
        let s = Summary::of(&[]);
        assert_eq!(s.n, 0);
        assert_eq!(s.mean, 0.0);
    }

    #[test]
    fn percentile_endpoints() {
        let xs = [1.0, 2.0, 3.0];
        assert_eq!(percentile_sorted(&xs, 0.0), 1.0);
        assert_eq!(percentile_sorted(&xs, 1.0), 3.0);
        assert_eq!(percentile_sorted(&xs, 0.5), 2.0);
    }

    #[test]
    fn rel_diff_symmetry() {
        assert!((rel_diff(10.0, 11.0) - rel_diff(11.0, 10.0)).abs() < 1e-15);
        assert_eq!(rel_diff(0.0, 0.0), 0.0);
    }

    #[test]
    fn f1_perfect_and_zero() {
        assert_eq!(f1_score(10, 0, 0), 1.0);
        assert_eq!(f1_score(0, 5, 5), 0.0);
    }

    #[test]
    fn geomean_of_powers() {
        let g = geomean(&[1.0, 100.0]);
        assert!((g - 10.0).abs() < 1e-9);
    }
}
