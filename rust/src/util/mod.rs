//! Small self-contained utilities shared across the crate.
//!
//! The offline crate registry lacks `rand`, `clap`, `criterion`, and
//! `serde`, so this module provides the minimal equivalents Magneton
//! needs: a deterministic PRNG, descriptive statistics, an ASCII table
//! printer, a tiny CLI argument parser, a JSON writer, a scoped thread
//! pool, and a bench harness used by the `benches/` targets.

pub mod prng;
pub mod stats;
pub mod table;
pub mod cli;
pub mod json;
pub mod pool;
pub mod bench;

pub use prng::Prng;
pub use stats::Summary;
pub use table::Table;

/// FNV-1a over a byte stream — the one 64-bit structural hash shared by
/// the stream auditor's op identities and the fleet's per-pair rng
/// derivation (keep the constants in one place).
pub fn fnv1a<I: IntoIterator<Item = u8>>(bytes: I) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod fnv_tests {
    #[test]
    fn fnv1a_matches_reference_vectors() {
        // canonical FNV-1a 64-bit test vectors
        assert_eq!(super::fnv1a([]), 0xcbf29ce484222325);
        assert_eq!(super::fnv1a("a".bytes()), 0xaf63dc4c8601ec8c);
        assert_eq!(super::fnv1a("foobar".bytes()), 0x85944171f73967e8);
    }
}
