//! Small self-contained utilities shared across the crate.
//!
//! The offline crate registry lacks `rand`, `clap`, `criterion`, and
//! `serde`, so this module provides the minimal equivalents Magneton
//! needs: a deterministic PRNG, descriptive statistics, an ASCII table
//! printer, a tiny CLI argument parser, a JSON writer, a scoped thread
//! pool, and a bench harness used by the `benches/` targets.

pub mod prng;
pub mod stats;
pub mod table;
pub mod cli;
pub mod json;
pub mod pool;
pub mod bench;

pub use prng::Prng;
pub use stats::Summary;
pub use table::Table;
