//! Deterministic pseudo-random number generation.
//!
//! SplitMix64 for seeding + xoshiro256** for the stream. Deterministic
//! across platforms so tests, workload generators, and the fuzzing
//! harness (Table 3 discovery) are reproducible.

/// xoshiro256** seeded via SplitMix64.
#[derive(Clone, Debug)]
pub struct Prng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Prng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Prng { s }
    }

    /// Derive an independent stream (for per-worker rngs).
    pub fn fork(&mut self, tag: u64) -> Prng {
        Prng::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform f32 in [lo, hi).
    #[inline]
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Uniform integer in [0, n). `n` must be > 0.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's method would be overkill; modulo bias is negligible
        // for our n ≪ 2^64 use-cases.
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in [lo, hi] inclusive.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo + 1)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Standard-normal f32.
    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Bernoulli with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Vector of standard-normal f32s.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal_f32()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Prng::new(42);
        let mut b = Prng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Prng::new(1);
        let mut b = Prng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Prng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_respects_bound() {
        let mut r = Prng::new(9);
        for n in 1..50 {
            for _ in 0..100 {
                assert!(r.below(n) < n);
            }
        }
    }

    #[test]
    fn normal_has_sane_moments() {
        let mut r = Prng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Prng::new(3);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Prng::new(5);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
