//! Micro-bench harness used by the `benches/` targets (`harness = false`;
//! the offline registry has no `criterion`). Provides warm-up, adaptive
//! iteration counts, and summary statistics, plus helpers to persist
//! regenerated paper tables/figures under `results/`.

use std::time::{Duration, Instant};

use super::json::Json;
use super::stats::Summary;

/// Result of timing a closure.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    /// Per-iteration wall time in microseconds.
    pub us: Summary,
    pub iters: usize,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<40} {:>12} /iter  (p50 {:>10}, n={})",
            self.name,
            super::table::fmt_us(self.us.mean),
            super::table::fmt_us(self.us.p50),
            self.iters
        )
    }

    /// Machine-readable form for the `BENCH_*.json` trajectory files.
    pub fn to_json(&self) -> Json {
        Json::obj()
            .field("name", self.name.as_str())
            .field("mean_us", self.us.mean)
            .field("p50_us", self.us.p50)
            .field("p95_us", self.us.p95)
            .field("min_us", self.us.min)
            .field("max_us", self.us.max)
            .field("iters", self.iters)
            .build()
    }
}

/// Time `f`, choosing an iteration count so total time ≈ `budget`.
pub fn bench<F: FnMut()>(name: &str, budget: Duration, mut f: F) -> BenchResult {
    // Warm-up + calibration.
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().max(Duration::from_nanos(50));
    let iters = (budget.as_secs_f64() / once.as_secs_f64()).clamp(3.0, 1000.0) as usize;
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64() * 1e6);
    }
    BenchResult { name: name.to_string(), us: Summary::of(&samples), iters }
}

/// Time one invocation of `f`, returning (result, micros).
pub fn time_once<R, F: FnOnce() -> R>(f: F) -> (R, f64) {
    let t = Instant::now();
    let r = f();
    (r, t.elapsed().as_secs_f64() * 1e6)
}

/// Run `f` with a wall-clock timeout on a helper thread; returns `None` on
/// timeout (used for the brute-force matcher baseline in Fig 9, which the
/// paper reports as timing out at 5 minutes).
pub fn with_timeout<R: Send + 'static, F: FnOnce() -> R + Send + 'static>(
    timeout: Duration,
    f: F,
) -> Option<R> {
    let (tx, rx) = std::sync::mpsc::channel();
    std::thread::spawn(move || {
        let _ = tx.send(f());
    });
    rx.recv_timeout(timeout).ok()
}

/// Write a regenerated table/figure to `<workspace>/results/<name>` (both
/// the rendered text and CSV), creating the directory if needed. Bench
/// binaries run with the package (`rust/`) as cwd, so walk up to the
/// outermost directory that still contains a `Cargo.toml`.
pub fn persist(name: &str, text: &str, csv: Option<&str>) {
    let dir = results_dir();
    let _ = std::fs::write(dir.join(format!("{name}.txt")), text);
    if let Some(csv) = csv {
        let _ = std::fs::write(dir.join(format!("{name}.csv")), csv);
    }
}

/// Write a machine-readable bench record to `<workspace>/results/<name>.json`
/// (e.g. `BENCH_lint` → `results/BENCH_lint.json`). Every bench binary
/// funnels through this one emitter so the JSON files share a schema and
/// can be diffed commit-over-commit as an in-tree perf trajectory.
pub fn persist_json(name: &str, json: &Json) {
    let dir = results_dir();
    let mut text = json.render();
    text.push('\n');
    let _ = std::fs::write(dir.join(format!("{name}.json")), text);
}

/// Convenience: wrap a bench-binary's results in the shared trajectory
/// schema `{bench, results: [...], extra...}` and persist it as
/// `results/BENCH_<bench>.json`.
pub fn persist_bench_json(bench: &str, results: &[BenchResult], extra: &[(&str, Json)]) {
    let mut obj = Json::obj()
        .field("bench", bench)
        .field("results", results.iter().map(BenchResult::to_json).collect::<Vec<_>>());
    for (k, v) in extra {
        obj = obj.field(k, v.clone());
    }
    persist_json(&format!("BENCH_{bench}"), &obj.build());
}

fn results_dir() -> std::path::PathBuf {
    let mut root = std::env::current_dir().unwrap_or_else(|_| ".".into());
    while root.parent().map(|p| p.join("Cargo.toml").exists()).unwrap_or(false) {
        root = root.parent().unwrap().to_path_buf();
    }
    let dir = root.join("results");
    let _ = std::fs::create_dir_all(&dir);
    dir
}

/// Standard header printed by every bench binary.
pub fn banner(fig: &str, caption: &str) {
    println!("=== Magneton bench: {fig} ===");
    println!("{caption}");
    println!();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_positive_time() {
        let r = bench("noop-ish", Duration::from_millis(20), || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(r.us.mean > 0.0);
        assert!(r.iters >= 3);
    }

    #[test]
    fn timeout_fires() {
        let r = with_timeout(Duration::from_millis(30), || {
            std::thread::sleep(Duration::from_secs(5));
            1
        });
        assert!(r.is_none());
    }

    #[test]
    fn timeout_passes_result() {
        let r = with_timeout(Duration::from_secs(5), || 42);
        assert_eq!(r, Some(42));
    }

    #[test]
    fn time_once_returns_value() {
        let (v, us) = time_once(|| 7);
        assert_eq!(v, 7);
        assert!(us >= 0.0);
    }

    #[test]
    fn bench_result_serialises_to_json() {
        let r = BenchResult {
            name: "lint suite".into(),
            us: Summary::of(&[10.0, 20.0, 30.0]),
            iters: 3,
        };
        let j = r.to_json().render();
        assert!(j.contains("\"name\":\"lint suite\""), "got: {j}");
        assert!(j.contains("\"mean_us\":20"), "got: {j}");
        assert!(j.contains("\"iters\":3"), "got: {j}");
    }
}
