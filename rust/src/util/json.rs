//! Tiny JSON value + writer (no `serde` in the offline registry).
//! Used to persist reports and bench results under `results/`.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Object builder entry point.
    pub fn obj() -> JsonObj {
        JsonObj(BTreeMap::new())
    }

    /// Serialize to a compact JSON string.
    pub fn render(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    // integer shortcut, except for -0.0: `0` would lose
                    // the sign bit the round trip promises to keep
                    if *x == x.trunc() && x.abs() < 1e15 && (*x != 0.0 || x.is_sign_positive()) {
                        let _ = write!(out, "{}", *x as i64);
                    } else {
                        let _ = write!(out, "{}", x);
                    }
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl From<Vec<Json>> for Json {
    fn from(v: Vec<Json>) -> Json {
        Json::Arr(v)
    }
}

/// Fluent object builder.
pub struct JsonObj(BTreeMap<String, Json>);

impl JsonObj {
    pub fn field<V: Into<Json>>(mut self, k: &str, v: V) -> JsonObj {
        self.0.insert(k.to_string(), v.into());
        self
    }
    pub fn build(self) -> Json {
        Json::Obj(self.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_nested() {
        let j = Json::obj()
            .field("name", "magneton")
            .field("n", 3usize)
            .field("ok", true)
            .field("xs", Json::Arr(vec![Json::Num(1.5), Json::Null]))
            .build();
        assert_eq!(
            j.render(),
            r#"{"n":3,"name":"magneton","ok":true,"xs":[1.5,null]}"#
        );
    }

    #[test]
    fn escapes_strings() {
        let j = Json::Str("a\"b\\c\nd".into());
        assert_eq!(j.render(), r#""a\"b\\c\nd""#);
    }

    #[test]
    fn integers_render_without_decimal() {
        assert_eq!(Json::Num(42.0).render(), "42");
        assert_eq!(Json::Num(0.5).render(), "0.5");
    }

    #[test]
    fn negative_zero_keeps_its_sign() {
        assert_eq!(Json::Num(-0.0).render(), "-0");
        assert_eq!(Json::Num(0.0).render(), "0");
    }

    #[test]
    fn non_finite_is_null() {
        assert_eq!(Json::Num(f64::NAN).render(), "null");
    }
}
