//! Scoped thread-pool helpers (no `tokio`/`rayon` offline). The matcher
//! fans tensor-fingerprint work out across worker threads; determinism is
//! preserved because results are collected by index.

/// Map `f` over `items` using up to `threads` scoped worker threads,
/// returning results in input order.
pub fn par_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let threads = threads.max(1).min(items.len().max(1));
    if threads <= 1 || items.len() <= 1 {
        return items.iter().map(&f).collect();
    }
    // Work-stealing over an atomic cursor, but lock-free on the result
    // path: each worker accumulates `(index, result)` pairs privately and
    // the parent merges them after join — no per-item Mutex allocation.
    let next = std::sync::atomic::AtomicUsize::new(0);
    let mut out: Vec<Option<R>> = (0..items.len()).map(|_| None).collect();
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..threads)
            .map(|_| {
                scope.spawn(|| {
                    let mut local: Vec<(usize, R)> = Vec::new();
                    loop {
                        let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                        if i >= items.len() {
                            break;
                        }
                        local.push((i, f(&items[i])));
                    }
                    local
                })
            })
            .collect();
        for h in handles {
            for (i, r) in h.join().expect("par_map worker panicked") {
                out[i] = Some(r);
            }
        }
    });
    out.into_iter().map(|r| r.unwrap()).collect()
}

/// Number of worker threads to use by default (cores, capped).
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .min(16)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let xs: Vec<usize> = (0..1000).collect();
        let ys = par_map(&xs, 8, |x| x * 2);
        assert_eq!(ys, xs.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_fallback() {
        let xs = vec![1, 2, 3];
        assert_eq!(par_map(&xs, 1, |x| x + 1), vec![2, 3, 4]);
    }

    #[test]
    fn empty_input() {
        let xs: Vec<u32> = vec![];
        assert!(par_map(&xs, 4, |x| *x).is_empty());
    }

    #[test]
    fn more_threads_than_items() {
        let xs = vec![5];
        assert_eq!(par_map(&xs, 64, |x| x * x), vec![25]);
    }
}
