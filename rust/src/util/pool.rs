//! Scoped thread-pool helpers (no `tokio`/`rayon` offline). The matcher
//! fans tensor-fingerprint work out across worker threads; determinism is
//! preserved because results are collected by index.

/// Map `f` over `items` using up to `threads` scoped worker threads,
/// returning results in input order.
pub fn par_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let threads = threads.max(1).min(items.len().max(1));
    if threads <= 1 || items.len() <= 1 {
        return items.iter().map(&f).collect();
    }
    let next = std::sync::atomic::AtomicUsize::new(0);
    let mut out: Vec<Option<R>> = (0..items.len()).map(|_| None).collect();
    let slots: Vec<std::sync::Mutex<&mut Option<R>>> =
        out.iter_mut().map(std::sync::Mutex::new).collect();
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if i >= items.len() {
                    break;
                }
                let r = f(&items[i]);
                **slots[i].lock().unwrap() = Some(r);
            });
        }
    });
    out.into_iter().map(|r| r.unwrap()).collect()
}

/// Number of worker threads to use by default (cores, capped).
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .min(16)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let xs: Vec<usize> = (0..1000).collect();
        let ys = par_map(&xs, 8, |x| x * 2);
        assert_eq!(ys, xs.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_fallback() {
        let xs = vec![1, 2, 3];
        assert_eq!(par_map(&xs, 1, |x| x + 1), vec![2, 3, 4]);
    }

    #[test]
    fn empty_input() {
        let xs: Vec<u32> = vec![];
        assert!(par_map(&xs, 4, |x| *x).is_empty());
    }

    #[test]
    fn more_threads_than_items() {
        let xs = vec![5];
        assert_eq!(par_map(&xs, 64, |x| x * x), vec![25]);
    }
}
