//! ASCII table rendering for reports and bench output (the paper's tables
//! are regenerated as text tables by the bench harness).

/// A simple column-aligned ASCII table.
#[derive(Clone, Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// Create a table with the given header cells.
    pub fn new<S: Into<String>>(header: Vec<S>) -> Table {
        Table { header: header.into_iter().map(Into::into).collect(), rows: Vec::new() }
    }

    /// Append a row; shorter rows are padded with empty cells.
    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) -> &mut Self {
        self.rows.push(cells.into_iter().map(Into::into).collect());
        self
    }

    /// Number of data rows.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// True if the table has no data rows.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Render to a string with column alignment and a separator rule.
    pub fn render(&self) -> String {
        let ncols = self
            .rows
            .iter()
            .map(|r| r.len())
            .chain(std::iter::once(self.header.len()))
            .max()
            .unwrap_or(0);
        let mut widths = vec![0usize; ncols];
        let all = std::iter::once(&self.header).chain(self.rows.iter());
        for row in all {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.chars().count());
            }
        }
        let mut out = String::new();
        let fmt_row = |row: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for (i, w) in widths.iter().enumerate() {
                let cell = row.get(i).map(String::as_str).unwrap_or("");
                line.push_str(&format!("{:<width$}", cell, width = w));
                if i + 1 < widths.len() {
                    line.push_str("  ");
                }
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        let rule_len = widths.iter().sum::<usize>() + 2 * (ncols.saturating_sub(1));
        out.push_str(&"-".repeat(rule_len));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    /// Render as CSV (for `results/*.csv` emission).
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        out.push_str(&self.header.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

/// Format a Joule value with adaptive units.
pub fn fmt_joules(j: f64) -> String {
    if j.abs() >= 1000.0 {
        format!("{:.2} kJ", j / 1000.0)
    } else if j.abs() >= 1.0 {
        format!("{:.2} J", j)
    } else if j.abs() >= 1e-3 {
        format!("{:.2} mJ", j * 1e3)
    } else {
        format!("{:.2} uJ", j * 1e6)
    }
}

/// Format microseconds with adaptive units.
pub fn fmt_us(us: f64) -> String {
    if us >= 1e6 {
        format!("{:.2} s", us / 1e6)
    } else if us >= 1e3 {
        format!("{:.2} ms", us / 1e3)
    } else {
        format!("{:.1} us", us)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(vec!["id", "name"]);
        t.row(vec!["1", "alpha"]);
        t.row(vec!["22", "b"]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("id"));
        assert!(lines[2].starts_with("1 "));
    }

    #[test]
    fn csv_escapes_commas() {
        let mut t = Table::new(vec!["a"]);
        t.row(vec!["x,y"]);
        assert!(t.to_csv().contains("\"x,y\""));
    }

    #[test]
    fn pads_short_rows() {
        let mut t = Table::new(vec!["a", "b", "c"]);
        t.row(vec!["1"]);
        let s = t.render();
        assert!(s.lines().count() == 3);
    }

    #[test]
    fn joule_units() {
        assert_eq!(fmt_joules(1500.0), "1.50 kJ");
        assert_eq!(fmt_joules(2.5), "2.50 J");
        assert_eq!(fmt_joules(0.002), "2.00 mJ");
        assert_eq!(fmt_joules(2e-6), "2.00 uJ");
    }

    #[test]
    fn time_units() {
        assert_eq!(fmt_us(1_500_000.0), "1.50 s");
        assert_eq!(fmt_us(1500.0), "1.50 ms");
        assert_eq!(fmt_us(15.0), "15.0 us");
    }
}
