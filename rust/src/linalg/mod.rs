//! Dense linear algebra substrate: one-sided Jacobi SVD (singular
//! values), symmetric Jacobi eigensolver, and Gram matrices.
//!
//! Magneton's tensor-equivalence test (paper §4.2) compares the
//! singular-value spectra of all non-trivial matricizations of a tensor.
//! This module is the *exact* path; the hot path uses spectral moments
//! computed by the Pallas-lowered fingerprint kernel (see
//! [`crate::fingerprint`]), validated against this implementation.

use crate::tensor::Tensor;

/// Singular values of an `m x n` matrix (descending), via one-sided
/// Jacobi on the thinner orientation. Accurate to ~1e-5 relative for the
/// well-conditioned tensors Magneton fingerprints.
pub fn singular_values(a: &Tensor) -> Vec<f32> {
    assert_eq!(a.rank(), 2, "singular_values expects a matrix");
    let (m, n) = (a.shape()[0], a.shape()[1]);
    // Work on A^T A's implicit form: one-sided Jacobi orthogonalises the
    // columns of the wider-than-tall orientation's transpose.
    let (rows, cols, data) = if m >= n {
        (m, n, a.to_vec())
    } else {
        (n, m, a.t().to_vec())
    };
    // Column-major copy for cache-friendly column ops.
    let mut col = vec![0.0f64; rows * cols];
    for r in 0..rows {
        for c in 0..cols {
            col[c * rows + r] = data[r * cols + c] as f64;
        }
    }
    one_sided_jacobi(&mut col, rows, cols)
}

/// One-sided Jacobi: rotate column pairs until all are orthogonal; the
/// singular values are the resulting column norms.
fn one_sided_jacobi(col: &mut [f64], rows: usize, cols: usize) -> Vec<f32> {
    let max_sweeps = 60;
    let eps = 1e-14;
    for _ in 0..max_sweeps {
        let mut off = 0.0f64;
        for p in 0..cols {
            for q in (p + 1)..cols {
                let (mut app, mut aqq, mut apq) = (0.0f64, 0.0f64, 0.0f64);
                let (cp, cq) = (p * rows, q * rows);
                for r in 0..rows {
                    let (x, y) = (col[cp + r], col[cq + r]);
                    app += x * x;
                    aqq += y * y;
                    apq += x * y;
                }
                off += apq.abs();
                if apq.abs() <= eps * (app * aqq).sqrt() {
                    continue;
                }
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                for r in 0..rows {
                    let (x, y) = (col[cp + r], col[cq + r]);
                    col[cp + r] = c * x - s * y;
                    col[cq + r] = s * x + c * y;
                }
            }
        }
        if off < 1e-12 {
            break;
        }
    }
    let mut sv: Vec<f32> = (0..cols)
        .map(|c| {
            let s: f64 = (0..rows).map(|r| col[c * rows + r].powi(2)).sum();
            s.sqrt() as f32
        })
        .collect();
    sv.sort_by(|a, b| b.total_cmp(a));
    sv
}

/// Eigenvalues of a symmetric matrix (descending) via classical Jacobi.
pub fn eigvalsh(a: &Tensor) -> Vec<f32> {
    assert_eq!(a.rank(), 2);
    let n = a.shape()[0];
    assert_eq!(n, a.shape()[1], "eigvalsh expects square");
    let mut m: Vec<f64> = a.to_vec().iter().map(|&x| x as f64).collect();
    let idx = |i: usize, j: usize| i * n + j;
    for _sweep in 0..100 {
        // largest off-diagonal magnitude
        let mut off = 0.0f64;
        for i in 0..n {
            for j in (i + 1)..n {
                off += m[idx(i, j)].powi(2);
            }
        }
        if off.sqrt() < 1e-12 {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = m[idx(p, q)];
                if apq.abs() < 1e-15 {
                    continue;
                }
                let theta = (m[idx(q, q)] - m[idx(p, p)]) / (2.0 * apq);
                let t = theta.signum() / (theta.abs() + (1.0 + theta * theta).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                for k in 0..n {
                    let (akp, akq) = (m[idx(k, p)], m[idx(k, q)]);
                    m[idx(k, p)] = c * akp - s * akq;
                    m[idx(k, q)] = s * akp + c * akq;
                }
                for k in 0..n {
                    let (apk, aqk) = (m[idx(p, k)], m[idx(q, k)]);
                    m[idx(p, k)] = c * apk - s * aqk;
                    m[idx(q, k)] = s * apk + c * aqk;
                }
            }
        }
    }
    let mut ev: Vec<f32> = (0..n).map(|i| m[idx(i, i)] as f32).collect();
    ev.sort_by(|a, b| b.total_cmp(a));
    ev
}

/// Gram matrix `G = A Aᵀ` (`[m, n] -> [m, m]`). Prefers the smaller side:
/// callers should orient `A` so `m <= n`.
pub fn gram(a: &Tensor) -> Tensor {
    assert_eq!(a.rank(), 2);
    crate::tensor::ops::matmul(a, &a.t())
}

/// Frobenius norm.
pub fn fro_norm(a: &Tensor) -> f32 {
    a.to_vec().iter().map(|x| (*x as f64).powi(2)).sum::<f64>().sqrt() as f32
}

/// Spectral moments tr(G^k), k = 1..=order, of G = A Aᵀ, computed by
/// repeated multiplication. These are the power sums of squared singular
/// values — the fingerprint invariants of [`crate::fingerprint`].
pub fn spectral_moments(a: &Tensor, order: usize) -> Vec<f64> {
    assert!(order <= 4, "moment order > 4 not supported by the fast path");
    let g = gram(a);
    let m = g.shape()[0];
    let gv: Vec<f64> = g.to_vec().iter().map(|&x| x as f64).collect();
    let mut moments = Vec::with_capacity(order);
    // m1 = tr(G)
    moments.push((0..m).map(|i| gv[i * m + i]).sum());
    if order >= 2 {
        // m2 = tr(G^2) = ||G||_F^2 (G symmetric) — no matmul needed
        moments.push(gv.iter().map(|x| x * x).sum());
    }
    if order >= 3 {
        // one m^3 product: G2 = G * G
        let mut g2 = vec![0.0f64; m * m];
        for i in 0..m {
            for l in 0..m {
                let c = gv[i * m + l];
                if c == 0.0 {
                    continue;
                }
                let row = &gv[l * m..(l + 1) * m];
                let out = &mut g2[i * m..(i + 1) * m];
                for j in 0..m {
                    out[j] += c * row[j];
                }
            }
        }
        // m3 = tr(G^3) = <G2, G>;  m4 = tr(G^4) = ||G2||_F^2
        moments.push(g2.iter().zip(gv.iter()).map(|(a, b)| a * b).sum());
        if order >= 4 {
            moments.push(g2.iter().map(|x| x * x).sum());
        }
    }
    moments.truncate(order);
    moments
}

/// Matrix exponential by scaling-and-squaring with a truncated Taylor
/// series (the jax-28614/jax-9239 cases exercise `expm`/`stft`; this is
/// the reference numeric used by those scenarios).
pub fn expm(a: &Tensor) -> Tensor {
    assert_eq!(a.rank(), 2);
    let n = a.shape()[0];
    assert_eq!(n, a.shape()[1]);
    // scale so ||A/2^s||_1 < 0.5
    let norm1: f32 = (0..n)
        .map(|j| (0..n).map(|i| a.at(&[i, j]).abs()).sum::<f32>())
        .fold(0.0, f32::max);
    let s = if norm1 > 0.5 { (norm1 / 0.5).log2().ceil() as i32 } else { 0 };
    let scale = 0.5f32.powi(s);
    let av: Vec<f32> = a.to_vec().iter().map(|&x| x * scale).collect();
    let scaled = Tensor::from_vec(av, &[n, n]);
    // Taylor: I + X + X^2/2! + ... (18 terms)
    let mut result = eye(n);
    let mut term = eye(n);
    for k in 1..=18usize {
        term = crate::tensor::ops::scale(
            &crate::tensor::ops::matmul(&term, &scaled),
            1.0 / k as f32,
        );
        result = crate::tensor::ops::add(&result, &term);
    }
    // square back s times
    for _ in 0..s {
        result = crate::tensor::ops::matmul(&result, &result);
    }
    result
}

/// Identity matrix.
pub fn eye(n: usize) -> Tensor {
    let mut v = vec![0.0f32; n * n];
    for i in 0..n {
        v[i * n + i] = 1.0;
    }
    Tensor::from_vec(v, &[n, n])
}

/// Naive STFT magnitude with precomputed twiddle tables: frame the
/// signal (hann window), take the DFT of each frame, return
/// `[n_frames, n_bins]` magnitudes.
pub fn stft_mag(signal: &Tensor, frame: usize, hop: usize) -> Tensor {
    assert_eq!(signal.rank(), 1);
    let x = signal.to_vec();
    let n = x.len();
    assert!(frame <= n && hop > 0);
    let n_frames = (n - frame) / hop + 1;
    let n_bins = frame / 2 + 1;
    let window: Vec<f32> = (0..frame)
        .map(|i| {
            0.5 - 0.5 * (2.0 * std::f32::consts::PI * i as f32 / frame as f32).cos()
        })
        .collect();
    // twiddle tables cos/sin[k * i] indexed [k][i]
    let mut cos_t = vec![0.0f64; n_bins * frame];
    let mut sin_t = vec![0.0f64; n_bins * frame];
    for k in 0..n_bins {
        for i in 0..frame {
            let ang = -2.0 * std::f64::consts::PI * (k * i) as f64 / frame as f64;
            cos_t[k * frame + i] = ang.cos();
            sin_t[k * frame + i] = ang.sin();
        }
    }
    let mut out = Vec::with_capacity(n_frames * n_bins);
    for f in 0..n_frames {
        let seg: Vec<f64> = (0..frame).map(|i| (x[f * hop + i] * window[i]) as f64).collect();
        for k in 0..n_bins {
            let (mut re, mut im) = (0.0f64, 0.0f64);
            let (ct, st) = (&cos_t[k * frame..(k + 1) * frame], &sin_t[k * frame..(k + 1) * frame]);
            for (i, &v) in seg.iter().enumerate() {
                re += v * ct[i];
                im += v * st[i];
            }
            out.push(((re * re + im * im).sqrt()) as f32);
        }
    }
    Tensor::from_vec(out, &[n_frames, n_bins])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Prng;

    #[test]
    fn svd_of_diagonal() {
        let a = Tensor::from_vec(vec![3., 0., 0., 0., 4., 0.], &[2, 3]);
        let sv = singular_values(&a);
        assert!((sv[0] - 4.0).abs() < 1e-4, "{sv:?}");
        assert!((sv[1] - 3.0).abs() < 1e-4, "{sv:?}");
    }

    #[test]
    fn svd_invariant_under_transpose() {
        let mut rng = Prng::new(1);
        let a = Tensor::randn(&mut rng, &[5, 9]);
        let s1 = singular_values(&a);
        let s2 = singular_values(&a.t().contiguous());
        for (x, y) in s1.iter().zip(s2.iter()) {
            assert!((x - y).abs() < 1e-3 * x.abs().max(1.0), "{s1:?} vs {s2:?}");
        }
    }

    #[test]
    fn svd_frobenius_identity() {
        // sum of squared singular values == squared Frobenius norm
        let mut rng = Prng::new(2);
        let a = Tensor::randn(&mut rng, &[6, 8]);
        let sv = singular_values(&a);
        let ss: f32 = sv.iter().map(|s| s * s).sum();
        let f = fro_norm(&a);
        assert!((ss - f * f).abs() < 1e-2 * (f * f), "{ss} vs {}", f * f);
    }

    #[test]
    fn eigvalsh_known_2x2() {
        let a = Tensor::from_vec(vec![2., 1., 1., 2.], &[2, 2]);
        let ev = eigvalsh(&a);
        assert!((ev[0] - 3.0).abs() < 1e-5);
        assert!((ev[1] - 1.0).abs() < 1e-5);
    }

    #[test]
    fn eig_of_gram_equals_squared_singulars() {
        let mut rng = Prng::new(3);
        let a = Tensor::randn(&mut rng, &[4, 7]);
        let sv = singular_values(&a);
        let ev = eigvalsh(&gram(&a));
        for (s, e) in sv.iter().zip(ev.iter()) {
            assert!((s * s - e).abs() < 1e-2 * e.abs().max(1.0), "{sv:?} {ev:?}");
        }
    }

    #[test]
    fn spectral_moments_match_singular_power_sums() {
        let mut rng = Prng::new(4);
        let a = Tensor::randn(&mut rng, &[5, 8]);
        let sv = singular_values(&a);
        let moments = spectral_moments(&a, 3);
        for k in 1..=3usize {
            let direct: f64 = sv.iter().map(|&s| (s as f64).powi(2 * k as i32)).sum();
            let rel = (moments[k - 1] - direct).abs() / direct.abs().max(1e-9);
            assert!(rel < 1e-3, "k={k}: {} vs {direct}", moments[k - 1]);
        }
    }

    #[test]
    fn expm_of_zero_is_identity() {
        let z = Tensor::zeros(&[3, 3]);
        let e = expm(&z);
        assert!(e.allclose(&eye(3), 1e-6, 1e-6));
    }

    #[test]
    fn expm_diagonal_matches_scalar_exp() {
        let d = Tensor::from_vec(vec![1.0, 0.0, 0.0, 2.0], &[2, 2]);
        let e = expm(&d);
        assert!((e.at(&[0, 0]) - 1f32.exp()).abs() < 1e-3);
        assert!((e.at(&[1, 1]) - 2f32.exp()).abs() < 1e-2);
        assert!(e.at(&[0, 1]).abs() < 1e-5);
    }

    #[test]
    fn expm_additive_on_commuting() {
        // exp(A) * exp(A) == exp(2A)
        let mut rng = Prng::new(6);
        let a = crate::tensor::ops::scale(&Tensor::randn(&mut rng, &[4, 4]), 0.3);
        let e1 = expm(&a);
        let e2 = crate::tensor::ops::matmul(&e1, &e1);
        let e3 = expm(&crate::tensor::ops::scale(&a, 2.0));
        assert!(e2.allclose(&e3, 1e-2, 1e-2));
    }

    #[test]
    fn stft_shape_and_pure_tone() {
        // a pure tone at bin 4 of a 32-sample frame dominates that bin
        let n = 256;
        let freq_bin = 4;
        let frame = 32;
        let x: Vec<f32> = (0..n)
            .map(|i| {
                (2.0 * std::f32::consts::PI * freq_bin as f32 * i as f32 / frame as f32).sin()
            })
            .collect();
        let s = stft_mag(&Tensor::from_vec(x, &[n]), frame, 16);
        assert_eq!(s.shape()[1], 17);
        // the tone bin has the largest magnitude in every frame
        for f in 0..s.shape()[0] {
            let row: Vec<f32> = (0..17).map(|k| s.at(&[f, k])).collect();
            let argmax = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .unwrap()
                .0;
            assert_eq!(argmax, freq_bin, "frame {f}: {row:?}");
        }
    }

    #[test]
    fn moments_invariant_under_row_permutation() {
        let mut rng = Prng::new(5);
        let a = Tensor::randn(&mut rng, &[6, 10]);
        let mut order: Vec<usize> = (0..6).collect();
        rng.shuffle(&mut order);
        let av = a.to_vec();
        let mut pv = Vec::with_capacity(av.len());
        for &r in &order {
            pv.extend_from_slice(&av[r * 10..(r + 1) * 10]);
        }
        let p = Tensor::from_vec(pv, &[6, 10]);
        let ma = spectral_moments(&a, 4);
        let mp = spectral_moments(&p, 4);
        for (x, y) in ma.iter().zip(mp.iter()) {
            assert!((x - y).abs() < 1e-6 * x.abs().max(1.0));
        }
    }
}
