//! Dominator analysis on computational graphs.
//!
//! Algorithm 1 (paper §4.2) cuts two graphs at equivalent tensors found
//! on their *dominator paths* — the chain source ≻ … ≻ sink in the
//! dominator tree. We implement the Cooper–Harvey–Kennedy iterative
//! dominator algorithm over reverse postorder, plus post-dominators (the
//! same computation on the reversed graph), which define the node
//! segments between consecutive cut points.

use super::{Graph, NodeId};

/// Immediate-dominator table: `idom[v]` is `v`'s immediate dominator;
/// `idom[root] == root`; unreachable nodes hold `usize::MAX`.
#[derive(Clone, Debug)]
pub struct DomTree {
    pub idom: Vec<NodeId>,
    pub root: NodeId,
    /// depth of each node in the dominator tree (root = 0).
    pub depth: Vec<usize>,
}

pub const UNREACHABLE: usize = usize::MAX;

fn postorder(n_nodes: usize, succ: &[Vec<NodeId>], root: NodeId) -> Vec<NodeId> {
    let mut order = Vec::with_capacity(n_nodes);
    let mut visited = vec![false; n_nodes];
    // iterative DFS with explicit phase
    let mut stack: Vec<(NodeId, usize)> = vec![(root, 0)];
    visited[root] = true;
    while let Some(&mut (v, ref mut i)) = stack.last_mut() {
        if *i < succ[v].len() {
            let child = succ[v][*i];
            *i += 1;
            if !visited[child] {
                visited[child] = true;
                stack.push((child, 0));
            }
        } else {
            order.push(v);
            stack.pop();
        }
    }
    order
}

/// Compute dominators of a flow graph given by successor lists.
pub fn dominators(n_nodes: usize, succ: &[Vec<NodeId>], root: NodeId) -> DomTree {
    let post = postorder(n_nodes, succ, root);
    let mut post_idx = vec![UNREACHABLE; n_nodes];
    for (i, &v) in post.iter().enumerate() {
        post_idx[v] = i;
    }
    // predecessor lists restricted to reachable nodes
    let mut pred = vec![Vec::new(); n_nodes];
    for v in 0..n_nodes {
        if post_idx[v] == UNREACHABLE {
            continue;
        }
        for &s in &succ[v] {
            if post_idx[s] != UNREACHABLE {
                pred[s].push(v);
            }
        }
    }
    let mut idom = vec![UNREACHABLE; n_nodes];
    idom[root] = root;
    let mut changed = true;
    while changed {
        changed = false;
        // reverse postorder
        for &v in post.iter().rev() {
            if v == root {
                continue;
            }
            let mut new_idom = UNREACHABLE;
            for &p in &pred[v] {
                if idom[p] == UNREACHABLE {
                    continue;
                }
                new_idom = if new_idom == UNREACHABLE {
                    p
                } else {
                    intersect(&idom, &post_idx, p, new_idom)
                };
            }
            if new_idom != UNREACHABLE && idom[v] != new_idom {
                idom[v] = new_idom;
                changed = true;
            }
        }
    }
    // depths
    let mut depth = vec![0usize; n_nodes];
    for &v in post.iter().rev() {
        if v != root && idom[v] != UNREACHABLE {
            depth[v] = depth[idom[v]] + 1;
        }
    }
    DomTree { idom, root, depth }
}

fn intersect(idom: &[NodeId], post_idx: &[usize], mut a: NodeId, mut b: NodeId) -> NodeId {
    while a != b {
        while post_idx[a] < post_idx[b] {
            a = idom[a];
        }
        while post_idx[b] < post_idx[a] {
            b = idom[b];
        }
    }
    a
}

impl DomTree {
    /// Does `a` dominate `b`? (reflexive)
    pub fn dominates(&self, a: NodeId, b: NodeId) -> bool {
        if self.idom[b] == UNREACHABLE && b != self.root {
            return false;
        }
        let mut v = b;
        loop {
            if v == a {
                return true;
            }
            if v == self.root {
                return false;
            }
            v = self.idom[v];
        }
    }

    /// The dominator path root → `sink`: every node that dominates
    /// `sink`, in root-first order.
    pub fn path_to(&self, sink: NodeId) -> Vec<NodeId> {
        let mut path = vec![sink];
        let mut v = sink;
        while v != self.root {
            v = self.idom[v];
            path.push(v);
        }
        path.reverse();
        path
    }
}

/// Dominator analysis of a computational graph, augmented with a virtual
/// source (dominating all graph sources) and virtual sink (dominated by
/// all graph sinks) so the dominator path is well-defined for
/// multi-input, multi-output graphs.
#[derive(Clone, Debug)]
pub struct GraphDom {
    /// dominator tree over ids 0..n+2; `vsrc = n`, `vsink = n + 1`.
    pub dom: DomTree,
    /// post-dominator tree (dominators of the reversed graph from vsink).
    pub pdom: DomTree,
    pub vsrc: NodeId,
    pub vsink: NodeId,
}

impl GraphDom {
    /// Run dominator + post-dominator analysis on `g`.
    ///
    /// The virtual source connects only to *activation* sources (not
    /// `Weight` nodes): the dominator path must follow the dataflow
    /// spine of the model, as in the paper's Figure 7, where parameter
    /// edges do not count as alternative paths. Weight nodes are
    /// unreachable in the forward dominator analysis and are simply
    /// ignored by it (they carry no energy).
    pub fn analyze(g: &Graph) -> GraphDom {
        let n = g.len();
        let vsrc = n;
        let vsink = n + 1;
        let mut succ = vec![Vec::new(); n + 2];
        for node in &g.nodes {
            for &i in &node.inputs {
                succ[i].push(node.id);
            }
        }
        let sources = g.sources();
        let activation_sources: Vec<NodeId> = sources
            .iter()
            .copied()
            .filter(|&s| g.nodes[s].op != crate::graph::OpKind::Weight)
            .collect();
        let roots = if activation_sources.is_empty() { sources } else { activation_sources };
        for s in roots {
            succ[vsrc].push(s);
        }
        for s in g.sinks() {
            succ[s].push(vsink);
        }
        let dom = dominators(n + 2, &succ, vsrc);
        // reversed graph for post-dominators
        let mut rsucc = vec![Vec::new(); n + 2];
        for (v, ss) in succ.iter().enumerate() {
            for &s in ss {
                rsucc[s].push(v);
            }
        }
        let pdom = dominators(n + 2, &rsucc, vsink);
        GraphDom { dom, pdom, vsrc, vsink }
    }

    /// The dominator path from virtual source to virtual sink,
    /// with the virtual endpoints stripped — the paper's `P`.
    pub fn dominator_path(&self) -> Vec<NodeId> {
        self.dom
            .path_to(self.vsink)
            .into_iter()
            .filter(|&v| v != self.vsrc && v != self.vsink)
            .collect()
    }

    /// Nodes strictly between two cut points: dominated by `a` and
    /// post-dominated by `b`, excluding the endpoints themselves.
    pub fn segment(&self, g: &Graph, a: NodeId, b: NodeId) -> Vec<NodeId> {
        (0..g.len())
            .filter(|&v| {
                v != a && v != b && self.dom.dominates(a, v) && self.pdom.dominates(b, v)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::OpKind;

    fn diamond() -> Graph {
        let mut g = Graph::new("d");
        let i = g.add(OpKind::Input, &[], "x");
        let a = g.add(OpKind::MatMul, &[i], "a");
        let b = g.add(OpKind::Gelu, &[a], "b");
        let c = g.add(OpKind::Tanh, &[a], "c");
        let d = g.add(OpKind::Add, &[b, c], "d");
        g.add(OpKind::Output, &[d], "out");
        g
    }

    #[test]
    fn diamond_dominators() {
        let g = diamond();
        let gd = GraphDom::analyze(&g);
        // a (id 1) dominates everything downstream
        assert!(gd.dom.dominates(1, 2));
        assert!(gd.dom.dominates(1, 3));
        assert!(gd.dom.dominates(1, 4));
        // neither branch dominates the join
        assert!(!gd.dom.dominates(2, 4));
        assert!(!gd.dom.dominates(3, 4));
    }

    #[test]
    fn dominator_path_skips_branches() {
        let g = diamond();
        let gd = GraphDom::analyze(&g);
        let p = gd.dominator_path();
        assert_eq!(p, vec![0, 1, 4, 5]); // input, matmul, join-add, output
    }

    #[test]
    fn chain_path_is_whole_chain() {
        let mut g = Graph::new("chain");
        let mut prev = g.add(OpKind::Input, &[], "x");
        for i in 0..5 {
            prev = g.add(OpKind::MatMul, &[prev], &format!("m{i}"));
        }
        let gd = GraphDom::analyze(&g);
        assert_eq!(gd.dominator_path().len(), 6);
    }

    #[test]
    fn segment_between_cuts() {
        let g = diamond();
        let gd = GraphDom::analyze(&g);
        // between matmul (1) and add (4): the two branch nodes
        let seg = gd.segment(&g, 1, 4);
        assert_eq!(seg, vec![2, 3]);
    }

    #[test]
    fn postdominators_mirror() {
        let g = diamond();
        let gd = GraphDom::analyze(&g);
        // the join post-dominates both branches
        assert!(gd.pdom.dominates(4, 2));
        assert!(gd.pdom.dominates(4, 3));
        // a branch does not post-dominate the fork
        assert!(!gd.pdom.dominates(2, 1));
    }

    #[test]
    fn multi_source_graph_has_virtual_root_path() {
        let mut g = Graph::new("ms");
        let x = g.add(OpKind::Input, &[], "x");
        let w = g.add(OpKind::Weight, &[], "w");
        let m = g.add(OpKind::MatMul, &[x, w], "m");
        g.add(OpKind::Output, &[m], "o");
        let gd = GraphDom::analyze(&g);
        let p = gd.dominator_path();
        // weights are not flow sources: the activation spine is
        // input -> matmul -> output
        assert_eq!(p, vec![0, 2, 3]);
    }

    #[test]
    fn weight_only_sources_fall_back() {
        let mut g = Graph::new("wonly");
        let w1 = g.add(OpKind::Weight, &[], "w1");
        let w2 = g.add(OpKind::Weight, &[], "w2");
        let m = g.add(OpKind::MatMul, &[w1, w2], "m");
        g.add(OpKind::Output, &[m], "o");
        let gd = GraphDom::analyze(&g);
        // degenerate graph: weights become roots so analysis still works
        assert!(gd.dominator_path().contains(&m));
    }

    #[test]
    fn dominates_is_reflexive_and_rooted() {
        let g = diamond();
        let gd = GraphDom::analyze(&g);
        for v in 0..g.len() {
            assert!(gd.dom.dominates(v, v));
            assert!(gd.dom.dominates(gd.vsrc, v));
        }
    }

    /// Property: on random DAGs, every node on the dominator path to the
    /// sink dominates the sink, and path depths strictly increase.
    #[test]
    fn prop_dominator_path_sound_on_random_dags() {
        use crate::prop;
        let gen = prop::Gen::new(|r| {
            let n = r.range(4, 40);
            let mut g = Graph::new("rand");
            g.add(OpKind::Input, &[], "x");
            for i in 1..n {
                let k = r.range(1, 2.min(i));
                let mut ins = Vec::new();
                for _ in 0..k {
                    ins.push(r.below(i));
                }
                ins.dedup();
                g.add(OpKind::MatMul, &ins, "n");
            }
            g
        });
        prop::forall("dominator path sound", &gen, 60, |g| {
            let gd = GraphDom::analyze(g);
            let p = gd.dom.path_to(gd.vsink);
            p.iter().all(|&v| gd.dom.dominates(v, gd.vsink))
                && p.windows(2).all(|w| gd.dom.depth[w[1]] == gd.dom.depth[w[0]] + 1)
        });
    }
}
