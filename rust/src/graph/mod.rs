//! Computational-graph IR: operators are nodes, tensors are edges.
//!
//! Magneton never compares ML systems at the source level (paper §4.2);
//! it compares their computational graphs. Each node produces exactly
//! one output tensor (multi-output ops like `split` are modelled as one
//! `SplitChunk` node per chunk), so "tensor" and "node output" coincide,
//! matching the paper's formulation where equivalent-tensor pairs become
//! cut points of the recursive subgraph matcher.

pub mod dom;

use std::collections::BTreeMap;

/// Node identifier within one [`Graph`].
pub type NodeId = usize;

/// Operator vocabulary shared by all mini ML systems.
///
/// The set covers every operator the paper's 24 cases touch: GEMM family
/// (`MatMul`/`AddMm`), elementwise, normalisation, attention, convolution,
/// layout ops (`Permute`/`Contiguous`/`Copy`), composition ops
/// (`Concat`/`SplitChunk`/`Slice`), the misc numerics ops behind cases
/// c3/c6/c14/c15/c16 (`TopK`/`Sort`/`Eigvals`/`Stft`/`Expm`/`CountNonzero`),
/// and distributed ops (`AllReduce`/`Barrier`/`Idle`) for the DDP case.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum OpKind {
    /// Model input placeholder.
    Input,
    /// Learned parameter (weights/bias).
    Weight,
    MatMul,
    /// Fused bias + matmul (torch.addmm).
    AddMm,
    Add,
    Sub,
    Mul,
    Div,
    Scale,
    Pow,
    Tanh,
    Gelu,
    Silu,
    Relu,
    Softmax,
    LayerNorm,
    RmsNorm,
    /// Fused scaled-dot-product attention.
    Attention,
    Conv2d,
    /// Layout permutation (zero-copy view).
    Permute,
    Reshape,
    /// Materialising layout change (charged memory traffic).
    Contiguous,
    /// Explicit device-to-device data copy.
    Copy,
    Concat,
    /// k-th output of a split.
    SplitChunk,
    Slice,
    TopK,
    Sort,
    CumSum,
    RepeatInterleave,
    Embedding,
    Arange,
    CrossEntropy,
    Eigvals,
    Stft,
    Expm,
    CountNonzero,
    /// Gradient all-reduce (DDP).
    AllReduce,
    /// Synchronisation barrier that keeps the GPU busy (dist.Join).
    Barrier,
    /// Idle period (early-exit path).
    Idle,
    /// Final output marker.
    Output,
}

impl OpKind {
    /// Stable lowercase name (used in reports and dispatch rules).
    pub fn name(&self) -> &'static str {
        match self {
            OpKind::Input => "input",
            OpKind::Weight => "weight",
            OpKind::MatMul => "matmul",
            OpKind::AddMm => "addmm",
            OpKind::Add => "add",
            OpKind::Sub => "sub",
            OpKind::Mul => "mul",
            OpKind::Div => "div",
            OpKind::Scale => "scale",
            OpKind::Pow => "pow",
            OpKind::Tanh => "tanh",
            OpKind::Gelu => "gelu",
            OpKind::Silu => "silu",
            OpKind::Relu => "relu",
            OpKind::Softmax => "softmax",
            OpKind::LayerNorm => "layernorm",
            OpKind::RmsNorm => "rmsnorm",
            OpKind::Attention => "attention",
            OpKind::Conv2d => "conv2d",
            OpKind::Permute => "permute",
            OpKind::Reshape => "reshape",
            OpKind::Contiguous => "contiguous",
            OpKind::Copy => "copy",
            OpKind::Concat => "concat",
            OpKind::SplitChunk => "split",
            OpKind::Slice => "slice",
            OpKind::TopK => "topk",
            OpKind::Sort => "sort",
            OpKind::CumSum => "cumsum",
            OpKind::RepeatInterleave => "repeat_interleave",
            OpKind::Embedding => "embedding",
            OpKind::Arange => "arange",
            OpKind::CrossEntropy => "cross_entropy",
            OpKind::Eigvals => "eigvals",
            OpKind::Stft => "stft",
            OpKind::Expm => "expm",
            OpKind::CountNonzero => "count_nonzero",
            OpKind::AllReduce => "all_reduce",
            OpKind::Barrier => "barrier",
            OpKind::Idle => "idle",
            OpKind::Output => "output",
        }
    }

    /// Ops that neither compute nor move data (free in the energy model).
    pub fn is_virtual(&self) -> bool {
        matches!(self, OpKind::Input | OpKind::Weight | OpKind::Output | OpKind::Permute | OpKind::Reshape)
    }
}

/// String attribute map (dispatch keys, layouts, fusion hints, …).
pub type Attrs = BTreeMap<String, String>;

/// One operator instance.
#[derive(Clone, Debug)]
pub struct Node {
    pub id: NodeId,
    pub op: OpKind,
    /// Producer nodes whose output tensors feed this op, in order.
    pub inputs: Vec<NodeId>,
    pub attrs: Attrs,
    /// Human-readable site, e.g. `"attn.q_proj"` — stands in for the
    /// source location the paper reports in diagnoses.
    pub label: String,
}

/// A DAG of operators. Edges are implied by `Node::inputs`.
#[derive(Clone, Debug, Default)]
pub struct Graph {
    pub nodes: Vec<Node>,
    /// Display name (system + workload).
    pub name: String,
}

impl Graph {
    pub fn new(name: &str) -> Graph {
        Graph { nodes: Vec::new(), name: name.to_string() }
    }

    /// Append a node; inputs must already exist (enforces acyclicity).
    pub fn add(&mut self, op: OpKind, inputs: &[NodeId], label: &str) -> NodeId {
        self.add_attrs(op, inputs, label, Attrs::new())
    }

    /// Append a node with attributes.
    pub fn add_attrs(&mut self, op: OpKind, inputs: &[NodeId], label: &str, attrs: Attrs) -> NodeId {
        let id = self.nodes.len();
        for &i in inputs {
            assert!(i < id, "input {i} must precede node {id} (acyclic by construction)");
        }
        self.nodes.push(Node { id, op, inputs: inputs.to_vec(), attrs, label: label.to_string() });
        id
    }

    /// Convenience: single attribute.
    pub fn add_attr1(&mut self, op: OpKind, inputs: &[NodeId], label: &str, k: &str, v: &str) -> NodeId {
        let mut a = Attrs::new();
        a.insert(k.to_string(), v.to_string());
        self.add_attrs(op, inputs, label, a)
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Successor adjacency (consumers of each node's output).
    pub fn consumers(&self) -> Vec<Vec<NodeId>> {
        let mut out = vec![Vec::new(); self.nodes.len()];
        for n in &self.nodes {
            for &i in &n.inputs {
                out[i].push(n.id);
            }
        }
        out
    }

    /// Nodes with no inputs (graph sources: Input/Weight/Arange).
    pub fn sources(&self) -> Vec<NodeId> {
        self.nodes.iter().filter(|n| n.inputs.is_empty()).map(|n| n.id).collect()
    }

    /// Nodes whose output no one consumes (graph sinks).
    pub fn sinks(&self) -> Vec<NodeId> {
        let cons = self.consumers();
        self.nodes.iter().filter(|n| cons[n.id].is_empty()).map(|n| n.id).collect()
    }

    /// Topological order (construction order is already topological, but
    /// this re-derives it as a structural check).
    pub fn topo_order(&self) -> Vec<NodeId> {
        let n = self.nodes.len();
        let mut indeg = vec![0usize; n];
        for node in &self.nodes {
            indeg[node.id] = node.inputs.len();
        }
        let cons = self.consumers();
        let mut queue: Vec<NodeId> = (0..n).filter(|&i| indeg[i] == 0).collect();
        let mut order = Vec::with_capacity(n);
        while let Some(v) = queue.pop() {
            order.push(v);
            for &c in &cons[v] {
                indeg[c] -= 1;
                if indeg[c] == 0 {
                    queue.push(c);
                }
            }
        }
        assert_eq!(order.len(), n, "graph has a cycle");
        order
    }

    /// Nodes reachable from `from` following consumer edges (inclusive).
    pub fn reachable_from(&self, from: NodeId) -> Vec<bool> {
        let cons = self.consumers();
        let mut seen = vec![false; self.nodes.len()];
        let mut stack = vec![from];
        while let Some(v) = stack.pop() {
            if seen[v] {
                continue;
            }
            seen[v] = true;
            for &c in &cons[v] {
                stack.push(c);
            }
        }
        seen
    }

    /// Nodes that can reach `to` following producer edges (inclusive).
    pub fn reaching(&self, to: NodeId) -> Vec<bool> {
        let mut seen = vec![false; self.nodes.len()];
        let mut stack = vec![to];
        while let Some(v) = stack.pop() {
            if seen[v] {
                continue;
            }
            seen[v] = true;
            for &p in &self.nodes[v].inputs {
                stack.push(p);
            }
        }
        seen
    }

    /// Induced subgraph on `keep` (a node-id set), remapping ids and
    /// dropping edges to excluded nodes. Returns the subgraph and the
    /// old-id → new-id map.
    pub fn induced(&self, keep: &[NodeId], name: &str) -> (Graph, BTreeMap<NodeId, NodeId>) {
        let mut keep_sorted = keep.to_vec();
        keep_sorted.sort_unstable();
        keep_sorted.dedup();
        let mut map = BTreeMap::new();
        let mut g = Graph::new(name);
        for &old in &keep_sorted {
            let node = &self.nodes[old];
            let inputs: Vec<NodeId> = node
                .inputs
                .iter()
                .filter_map(|i| map.get(i).copied())
                .collect();
            let new_id = g.add_attrs(node.op, &inputs, &node.label, node.attrs.clone());
            map.insert(old, new_id);
        }
        (g, map)
    }

    /// Graphviz DOT rendering (debugging aid).
    pub fn to_dot(&self) -> String {
        let mut s = format!("digraph \"{}\" {{\n", self.name);
        for n in &self.nodes {
            s.push_str(&format!("  n{} [label=\"{}:{}\"]\n", n.id, n.op.name(), n.label));
        }
        for n in &self.nodes {
            for &i in &n.inputs {
                s.push_str(&format!("  n{} -> n{}\n", i, n.id));
            }
        }
        s.push_str("}\n");
        s
    }

    /// Count of non-virtual (energy-bearing) operators.
    pub fn op_count(&self) -> usize {
        self.nodes.iter().filter(|n| !n.op.is_virtual()).count()
    }

    /// Structural validation for graphs that did not come through
    /// [`Graph::add`] (struct literals, deserialised artifacts):
    /// ids must match indices, inputs must be in range, and the edge
    /// relation must be acyclic. [`Graph::topo_order`] *panics* on a
    /// cycle; this returns a typed error instead, so entry points
    /// (lint, exec) can reject malformed graphs with a message naming
    /// the offending node rather than dying mid-analysis.
    pub fn validate(&self) -> crate::Result<()> {
        let n = self.nodes.len();
        for (idx, node) in self.nodes.iter().enumerate() {
            if node.id != idx {
                return Err(crate::Error::msg(format!(
                    "node `{}` has id {} but sits at index {idx}",
                    node.label, node.id
                )));
            }
            for &i in &node.inputs {
                if i >= n {
                    return Err(crate::Error::msg(format!(
                        "node {} (`{}`) reads out-of-range input {i} (graph has {n} nodes)",
                        node.id, node.label
                    )));
                }
            }
        }
        // Kahn's algorithm, minus the panic: nodes never drained sit on
        // a cycle.
        let mut indeg = vec![0usize; n];
        for node in &self.nodes {
            indeg[node.id] = node.inputs.len();
        }
        let cons = self.consumers();
        let mut queue: Vec<NodeId> = (0..n).filter(|&i| indeg[i] == 0).collect();
        let mut drained = 0usize;
        while let Some(v) = queue.pop() {
            drained += 1;
            for &c in &cons[v] {
                indeg[c] -= 1;
                if indeg[c] == 0 {
                    queue.push(c);
                }
            }
        }
        if drained != n {
            let stuck = (0..n).find(|&i| indeg[i] > 0).expect("undrained node");
            return Err(crate::Error::msg(format!(
                "graph `{}` has a cycle through node {stuck} (`{}`)",
                self.name, self.nodes[stuck].label
            )));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diamond() -> Graph {
        // in -> a -> {b, c} -> d(out)
        let mut g = Graph::new("diamond");
        let i = g.add(OpKind::Input, &[], "x");
        let a = g.add(OpKind::MatMul, &[i], "a");
        let b = g.add(OpKind::Gelu, &[a], "b");
        let c = g.add(OpKind::Tanh, &[a], "c");
        let d = g.add(OpKind::Add, &[b, c], "d");
        g.add(OpKind::Output, &[d], "out");
        g
    }

    #[test]
    fn topo_order_is_valid() {
        let g = diamond();
        let order = g.topo_order();
        let pos: BTreeMap<NodeId, usize> = order.iter().enumerate().map(|(i, &v)| (v, i)).collect();
        for n in &g.nodes {
            for &i in &n.inputs {
                assert!(pos[&i] < pos[&n.id]);
            }
        }
    }

    #[test]
    fn sources_and_sinks() {
        let g = diamond();
        assert_eq!(g.sources(), vec![0]);
        assert_eq!(g.sinks(), vec![5]);
    }

    #[test]
    fn reachability() {
        let g = diamond();
        let from_a = g.reachable_from(1);
        assert!(from_a[4] && from_a[5] && !from_a[0]);
        let to_d = g.reaching(4);
        assert!(to_d[0] && to_d[1] && to_d[2] && to_d[3] && !to_d[5]);
    }

    #[test]
    fn induced_subgraph_remaps() {
        let g = diamond();
        let (sub, map) = g.induced(&[1, 2, 4], "sub");
        assert_eq!(sub.len(), 3);
        // 'a' lost its input (excluded), 'b' keeps edge to 'a'
        assert!(sub.nodes[map[&1]].inputs.is_empty());
        assert_eq!(sub.nodes[map[&2]].inputs, vec![map[&1]]);
        // 'd' keeps only the edge from 'b'
        assert_eq!(sub.nodes[map[&4]].inputs, vec![map[&2]]);
    }

    #[test]
    #[should_panic(expected = "must precede")]
    fn forward_edge_panics() {
        let mut g = Graph::new("bad");
        g.add(OpKind::Add, &[3], "dangling");
    }

    #[test]
    fn op_count_skips_virtual() {
        let g = diamond();
        assert_eq!(g.op_count(), 4); // matmul, gelu, tanh, add
    }

    #[test]
    fn dot_contains_nodes_and_edges() {
        let g = diamond();
        let dot = g.to_dot();
        assert!(dot.contains("matmul"));
        assert!(dot.contains("n1 -> n2"));
    }

    fn raw_node(id: NodeId, op: OpKind, inputs: &[NodeId], label: &str) -> Node {
        Node { id, op, inputs: inputs.to_vec(), attrs: Attrs::new(), label: label.into() }
    }

    #[test]
    fn validate_accepts_well_formed_graph() {
        diamond().validate().unwrap();
    }

    /// Regression: `topo_order` panics on a cyclic graph, so a
    /// hand-built (or deserialised) cycle used to take the process
    /// down. `validate` must reject it with a typed error naming a
    /// node on the cycle.
    #[test]
    fn validate_rejects_cycle() {
        let g = Graph {
            name: "cyclic".into(),
            nodes: vec![
                raw_node(0, OpKind::Input, &[], "x"),
                raw_node(1, OpKind::Tanh, &[0, 2], "a"),
                raw_node(2, OpKind::Gelu, &[1], "b"),
            ],
        };
        let err = g.validate().unwrap_err();
        assert!(err.to_string().contains("cycle"), "got: {err}");
    }

    #[test]
    fn validate_rejects_out_of_range_input() {
        let g = Graph {
            name: "dangling".into(),
            nodes: vec![raw_node(0, OpKind::Add, &[7], "reader")],
        };
        let err = g.validate().unwrap_err();
        assert!(err.to_string().contains("out-of-range"), "got: {err}");
    }

    #[test]
    fn validate_rejects_id_index_mismatch() {
        let g = Graph {
            name: "shifted".into(),
            nodes: vec![raw_node(3, OpKind::Input, &[], "x")],
        };
        assert!(g.validate().is_err());
    }
}
