//! Root-cause diagnosis (paper §4.3, Algorithm 2).
//!
//! Given a detected finding (a matched region pair with divergent
//! energy), diagnosis explains *why* the wasteful implementation burns
//! more energy. Three mutually exclusive outcomes, mirroring the
//! paper's taxonomy:
//!
//! * **Redundant operation** — the wasteful region launches kernels the
//!   efficient region has no counterpart for (extra copies, barriers,
//!   repeat_interleave). Reported with the offending op labels.
//! * **API misuse** — the two regions call different framework APIs to
//!   compute the same tensors; the efficient side's API combination is
//!   the suggested fix.
//! * **Misconfiguration** — both sides call the *same* API but launch
//!   different kernels. FINDDEVIATIONPOINT walks the two kernel call
//!   paths to the last common frame, FINDKEYVAR re-runs the dispatch
//!   routine with basic-block tracing and diffs the traces to extract
//!   the branch variable, and backward data-flow maps the variable to
//!   its ultimate source (a config flag or API argument).

use std::collections::BTreeSet;

use crate::detect::{Finding, Side};
use crate::dispatch::VarSource;
use crate::exec::{Dispatcher, KernelRecord, RunArtifacts};
use crate::trace::Frame;

/// Diagnosis category (paper Table 1: Misconfiguration / API misuse /
/// Redundant).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Category {
    Misconfiguration,
    ApiMisuse,
    Redundant,
}

impl Category {
    pub fn name(&self) -> &'static str {
        match self {
            Category::Misconfiguration => "Misconfiguration",
            Category::ApiMisuse => "API misuse",
            Category::Redundant => "Redundant",
        }
    }
}

/// A completed diagnosis.
#[derive(Clone, Debug)]
pub struct Diagnosis {
    pub category: Category,
    /// The code/config entity the developer should change.
    pub subject: String,
    /// Last common function before the call paths diverge.
    pub deviation_func: Option<String>,
    /// Branch variable extracted from the BB-trace diff.
    pub key_var: Option<String>,
    /// Ultimate source of the key variable (backward data-flow).
    pub source: Option<VarSource>,
    /// Actionable suggestion derived from the efficient implementation.
    pub suggestion: String,
}

impl Diagnosis {
    pub fn render(&self) -> String {
        let mut s = format!("[{}] {}", self.category.name(), self.subject);
        if let Some(f) = &self.deviation_func {
            s.push_str(&format!("\n  deviation point: {f}"));
        }
        if let Some(v) = &self.key_var {
            s.push_str(&format!("\n  key variable:    {v}"));
        }
        if let Some(src) = &self.source {
            s.push_str(&format!("\n  root cause:      {}", src.describe()));
        }
        s.push_str(&format!("\n  suggestion:      {}", self.suggestion));
        s
    }
}

/// FINDDEVIATIONPOINT (Algorithm 2): first index where two call paths
/// diverge; returns the last common frame.
pub fn find_deviation_point(path1: &[Frame], path2: &[Frame]) -> Option<Frame> {
    let n = path1.len().min(path2.len());
    for i in 0..n {
        if path1[i] != path2[i] {
            return if i == 0 { None } else { Some(path1[i - 1].clone()) };
        }
    }
    // one path is a prefix of the other: deviation after the shared part
    if path1.len() != path2.len() && n > 0 {
        Some(path1[n - 1].clone())
    } else {
        None
    }
}

/// FINDKEYVAR (Algorithm 2): diff the two basic-block traces, locate the
/// last common block, and extract the control variable of its
/// terminator from the owning routine.
pub fn find_key_var(
    routine: &crate::dispatch::Routine,
    trace1: &[(String, usize)],
    trace2: &[(String, usize)],
) -> Option<String> {
    let n = trace1.len().min(trace2.len());
    let mut last_common: Option<usize> = None;
    for i in 0..n {
        if trace1[i] != trace2[i] {
            break;
        }
        last_common = Some(trace1[i].1);
    }
    let bb = last_common?;
    routine.control_var(bb).map(str::to_string)
}

fn kernels_of<'a>(arts: &'a RunArtifacts, nodes: &[usize]) -> Vec<&'a KernelRecord> {
    arts.records.iter().filter(|r| nodes.contains(&r.node)).collect()
}

/// Diagnose one finding. `disp_waste` is the dispatcher of the wasteful
/// system (needed to re-run routines with instrumentation — we replay
/// the dispatch to recover the routine the kernel came from).
pub fn diagnose(
    finding: &Finding,
    a: &RunArtifacts,
    b: &RunArtifacts,
    disp_waste: &Dispatcher,
) -> Diagnosis {
    let (waste_arts, eff_arts, waste_nodes, eff_nodes) = match finding.wasteful {
        Side::A => (a, b, &finding.region.a_nodes, &finding.region.b_nodes),
        Side::B => (b, a, &finding.region.b_nodes, &finding.region.a_nodes),
    };
    let waste_kernels = kernels_of(waste_arts, waste_nodes);
    let eff_kernels = kernels_of(eff_arts, eff_nodes);

    // ---- Case 1: redundant operations -------------------------------
    // The wasteful side launches ops whose API has no counterpart in
    // the efficient side.
    let eff_apis: BTreeSet<&str> = eff_kernels.iter().map(|k| k.api.as_str()).collect();
    let extra: Vec<&KernelRecord> = waste_kernels
        .iter()
        .filter(|k| !eff_apis.contains(k.api.as_str()))
        .copied()
        .collect();
    if !extra.is_empty() && waste_kernels.len() > eff_kernels.len() {
        let subjects: Vec<String> = extra
            .iter()
            .map(|k| format!("{} at `{}`", k.api, k.label))
            .collect();
        return Diagnosis {
            category: Category::Redundant,
            subject: subjects.join(", "),
            deviation_func: None,
            key_var: None,
            source: None,
            suggestion: format!(
                "remove the redundant operation(s); the peer system computes the same \
                 tensors with [{}]",
                eff_kernels
                    .iter()
                    .map(|k| k.api.as_str())
                    .collect::<Vec<_>>()
                    .join(", ")
            ),
        };
    }

    // Pair kernels positionally and find the first divergent pair.
    let divergent = waste_kernels
        .iter()
        .zip(eff_kernels.iter())
        .find(|(w, e)| w.kernel != e.kernel);

    if let Some((w, e)) = divergent {
        if w.api == e.api {
            // ---- Case 2: misconfiguration — same API, different kernel
            let dev = find_deviation_point(&w.call_path, &e.call_path);
            let routine = disp_waste.routine_for(w.op, &w.dispatch_key);
            // Re-run with instrumentation is implicit: bb traces are
            // recorded; diff them to find the key variable.
            let key = find_key_var(&routine, &w.bb_trace, &e.bb_trace);
            let source = key.as_deref().and_then(|k| routine.source_of(k).cloned());
            let suggestion = match &source {
                Some(s) => format!(
                    "set {} so `{}` dispatches to `{}` (as the efficient system does)",
                    s.describe(),
                    w.api,
                    e.kernel
                ),
                None => format!("make `{}` dispatch to `{}`", w.api, e.kernel),
            };
            return Diagnosis {
                category: Category::Misconfiguration,
                subject: format!("`{}` selects kernel `{}` instead of `{}`", w.api, w.kernel, e.kernel),
                deviation_func: dev.map(|f| f.func),
                key_var: key,
                source,
                suggestion,
            };
        }
        // ---- Case 3: API misuse — different APIs for the same task
        let dev = find_deviation_point(&w.call_path, &e.call_path);
        return Diagnosis {
            category: Category::ApiMisuse,
            subject: format!(
                "`{}` (kernel `{}`) is energy-inefficient for this task",
                w.api, w.kernel
            ),
            deviation_func: dev.map(|f| f.func),
            key_var: None,
            source: None,
            suggestion: format!(
                "replace with the peer implementation: [{}]",
                eff_kernels.iter().map(|k| k.api.as_str()).collect::<Vec<_>>().join(", ")
            ),
        };
    }

    // Same kernels on both sides but different energy: count mismatch
    // (one side launches the same API more times) is redundancy.
    if waste_kernels.len() != eff_kernels.len() {
        return Diagnosis {
            category: Category::Redundant,
            subject: format!(
                "{} launches {} kernels where the peer launches {}",
                waste_arts.graph.name,
                waste_kernels.len(),
                eff_kernels.len()
            ),
            deviation_func: None,
            key_var: None,
            source: None,
            suggestion: "eliminate the extra kernel launches".into(),
        };
    }

    // Fallback: identical structure — attribute to the biggest gap.
    let worst = waste_kernels
        .iter()
        .zip(eff_kernels.iter())
        .max_by(|(w1, e1), (w2, e2)| {
            (w1.energy_j - e1.energy_j).total_cmp(&(w2.energy_j - e2.energy_j))
        });
    let subject = match worst {
        Some((w, e)) => format!(
            "`{}` consumes {} vs peer {}",
            w.api,
            crate::util::table::fmt_joules(w.energy_j),
            crate::util::table::fmt_joules(e.energy_j)
        ),
        None => "no kernels in region".into(),
    };
    Diagnosis {
        category: Category::ApiMisuse,
        subject,
        deviation_func: None,
        key_var: None,
        source: None,
        suggestion: "profile the kernel parameters; same kernels draw different energy".into(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::Frame;

    #[test]
    fn deviation_point_basic() {
        let p1 = vec![Frame::py("api"), Frame::cpp("dispatch"), Frame::cuda("k1")];
        let p2 = vec![Frame::py("api"), Frame::cpp("dispatch"), Frame::cuda("k2")];
        let dev = find_deviation_point(&p1, &p2).unwrap();
        assert_eq!(dev, Frame::cpp("dispatch"));
    }

    #[test]
    fn deviation_point_at_root() {
        let p1 = vec![Frame::py("api_a")];
        let p2 = vec![Frame::py("api_b")];
        assert!(find_deviation_point(&p1, &p2).is_none());
    }

    #[test]
    fn deviation_point_prefix_paths() {
        let p1 = vec![Frame::py("a"), Frame::cpp("b")];
        let p2 = vec![Frame::py("a"), Frame::cpp("b"), Frame::cuda("k")];
        let dev = find_deviation_point(&p1, &p2).unwrap();
        assert_eq!(dev, Frame::cpp("b"));
    }

    #[test]
    fn key_var_from_bb_divergence() {
        use crate::dispatch::{KernelChoice, Routine, VarSource};
        use crate::energy::ComputeUnit;
        let r = Routine::branch_on(
            "torch.matmul",
            vec![],
            "gemm",
            "allow_tf32",
            "true",
            VarSource::ConfigFlag("torch.backends.cuda.matmul.allow_tf32".into()),
            KernelChoice::new("tf32", ComputeUnit::TensorCore),
            KernelChoice::new("fp32", ComputeUnit::CudaCore),
        );
        let t1 = r.run(&crate::dispatch::Env::new().with("allow_tf32", "true")).bb_trace;
        let t2 = r.run(&crate::dispatch::Env::new()).bb_trace;
        let key = find_key_var(&r, &t1, &t2).unwrap();
        assert_eq!(key, "allow_tf32");
        assert_eq!(
            r.source_of(&key).unwrap().describe(),
            "configuration flag `torch.backends.cuda.matmul.allow_tf32`"
        );
    }

    #[test]
    fn identical_traces_yield_no_key_var() {
        use crate::dispatch::{KernelChoice, Routine};
        use crate::energy::ComputeUnit;
        let r = Routine::direct("api", vec![], KernelChoice::new("k", ComputeUnit::CudaCore));
        let t = r.run(&crate::dispatch::Env::new()).bb_trace;
        // last common block is the Launch block, which has no control var
        assert!(find_key_var(&r, &t, &t).is_none());
    }
}
