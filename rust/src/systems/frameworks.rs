//! Mini ML-framework systems: PyTorch-, JAX-, and TensorFlow-flavoured
//! operator implementations (the paper's "ML libraries" category).
//!
//! These systems differ in convolution layout/algorithm choices
//! (Fig 5c, pytorch-157334, jax-29875, tf-96396) and in the misc
//! numeric APIs behind cases c6 (eigvals), c11 (busy-wait sync), c12
//! (non-contiguous LayerNorm), c13 (cross_entropy), c14 (stft), c15
//! (expm), and c16 (count_nonzero).

use crate::dispatch::{Env, KernelChoice, Routine, VarSource};
use crate::energy::ComputeUnit;
use crate::exec::{Dispatcher, Program};
use crate::graph::{Attrs, Graph, NodeId, OpKind};
use crate::tensor::Tensor;
use crate::trace::Frame;
use crate::util::Prng;

/// Convolution workload spec (Fig 5c: batch 128, hidden 512 — scaled
/// down for the simulated testbed; ratios preserved).
#[derive(Clone, Copy, Debug)]
pub struct ConvSpec {
    pub batch: usize,
    pub channels: usize,
    pub hw: usize,
    pub out_channels: usize,
    pub kernel: usize,
    pub groups: usize,
}

impl ConvSpec {
    pub fn fig5c() -> ConvSpec {
        ConvSpec { batch: 8, channels: 32, hw: 16, out_channels: 32, kernel: 3, groups: 1 }
    }

    pub fn grouped() -> ConvSpec {
        ConvSpec { groups: 4, ..ConvSpec::fig5c() }
    }
}

/// Shared conv weights so framework outputs are comparable.
pub fn conv_params(rng: &mut Prng, spec: ConvSpec) -> (Tensor, Tensor) {
    let x = Tensor::randn(rng, &[spec.batch, spec.channels, spec.hw, spec.hw]);
    let w = crate::tensor::ops::scale(
        &Tensor::randn(rng, &[spec.out_channels, spec.channels / spec.groups, spec.kernel, spec.kernel]),
        1.0 / (spec.channels as f32).sqrt(),
    );
    (x, w)
}

/// Layout a framework uses for convolution.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ConvLayout {
    Nchw,
    Nhwc,
}

/// Build a single-conv program in the given layout. The input feed is
/// always provided NCHW and permuted in-graph when the framework wants
/// NHWC (mirroring real framework format conversion).
pub fn build_conv(sys: &str, spec: ConvSpec, layout: ConvLayout, x: &Tensor, w: &Tensor, dispatch: &str) -> Program {
    let mut g = Graph::new(&format!("{sys}-conv"));
    let xi = g.add(OpKind::Input, &[], "x");
    let wi = g.add(OpKind::Weight, &[], "w");
    let mut attrs = Attrs::new();
    attrs.insert("pad".into(), (spec.kernel / 2).to_string());
    attrs.insert("groups".into(), spec.groups.to_string());
    attrs.insert("dispatch".into(), dispatch.into());
    let out = match layout {
        ConvLayout::Nchw => {
            attrs.insert("layout".into(), "nchw".into());
            g.add_attrs(OpKind::Conv2d, &[xi, wi], &format!("{sys}.conv2d"), attrs)
        }
        ConvLayout::Nhwc => {
            let p = g.add_attr1(OpKind::Permute, &[xi], &format!("{sys}.to_nhwc"), "perm", "0,2,3,1");
            let c = g.add(OpKind::Contiguous, &[p], &format!("{sys}.nhwc_copy"));
            attrs.insert("layout".into(), "nhwc".into());
            let o = g.add_attrs(OpKind::Conv2d, &[c, wi], &format!("{sys}.conv2d"), attrs);
            let p2 = g.add_attr1(OpKind::Permute, &[o], &format!("{sys}.to_nchw"), "perm", "0,3,1,2");
            g.add(OpKind::Contiguous, &[p2], &format!("{sys}.nchw_copy"))
        }
    };
    g.add(OpKind::Output, &[out], "out");
    let mut p = Program::new(g);
    p.feed(0, x.clone());
    p.feed(1, w.clone());
    p
}

/// Generic one-op program builder for the framework micro cases
/// (eigvals, stft, expm, count_nonzero, layernorm, cross-entropy...).
pub fn build_unary_op(
    sys: &str,
    op: OpKind,
    label: &str,
    attrs: Attrs,
    x: &Tensor,
    extra_weights: &[Tensor],
) -> Program {
    let mut g = Graph::new(&format!("{sys}-{label}"));
    let xi = g.add(OpKind::Input, &[], "x");
    let mut inputs = vec![xi];
    let mut feeds = vec![(xi, x.clone())];
    for (i, wt) in extra_weights.iter().enumerate() {
        let wi = g.add(OpKind::Weight, &[], &format!("w{i}"));
        inputs.push(wi);
        feeds.push((wi, wt.clone()));
    }
    let o = g.add_attrs(op, &inputs, label, attrs);
    g.add(OpKind::Output, &[o], "out");
    let mut p = Program::new(g);
    for (id, t) in feeds {
        p.feed(id, t);
    }
    p
}

// ---------------------------------------------------------------------
// Dispatchers
// ---------------------------------------------------------------------

/// PyTorch conv dispatch: cuDNN kernels, layout-sensitive (new issues
/// pytorch-157334 / tf-96396: cuDNN grouped-conv likes NHWC, custom
/// kernels like NCHW).
pub fn torch_conv_routine() -> Routine {
    Routine::branch_on(
        "torch.nn.functional.conv2d",
        vec![Frame::cpp("at::native::cudnn_convolution")],
        "cudnn::conv_dispatch",
        "layout",
        "nhwc",
        VarSource::InputProperty("memory_format (NCHW vs channels_last)".into()),
        KernelChoice::new("cudnn_implicit_gemm_nhwc", ComputeUnit::TensorCore),
        KernelChoice::new("cudnn_implicit_gemm_nchw", ComputeUnit::TensorCore).quality(0.72, 1.05, 1.25),
    )
}

/// TensorFlow conv dispatch: custom kernels, efficient under NCHW,
/// poor under NHWC — the mirror image of PyTorch (tf-96396).
pub fn tf_conv_routine() -> Routine {
    Routine::branch_on(
        "tf.nn.conv2d",
        vec![Frame::cpp("tensorflow::LaunchConv2DOp")],
        "tensorflow::conv_autotune",
        "layout",
        "nchw",
        VarSource::InputProperty("data_format (NHWC vs NCHW)".into()),
        KernelChoice::new("tf_custom_conv_nchw", ComputeUnit::TensorCore),
        KernelChoice::new("tf_custom_conv_nhwc", ComputeUnit::TensorCore).quality(0.8, 1.03, 1.3),
    )
}

/// JAX conv dispatch: XLA fusion, but grouped convs hit a slow cuDNN
/// path (new issue jax-29875). Also the Fig 5c outlier: JAX's conv is
/// 3.35x more energy-hungry than TF's on this workload.
pub fn jax_conv_routine() -> Routine {
    Routine::branch_on(
        "jax.lax.conv_general_dilated",
        vec![Frame::cpp("xla::gpu::ConvolutionThunk")],
        "xla::gpu::PickBestAlgorithm",
        "groups",
        "1",
        VarSource::ApiArgument("feature_group_count".into()),
        KernelChoice::new("xla_fused_conv", ComputeUnit::TensorCore).quality(0.25, 2.2, 1.5),
        KernelChoice::new("cudnn_grouped_conv_fallback", ComputeUnit::CudaCore).quality(0.45, 2.0, 1.8),
    )
}

/// `torch.linalg.eigvals`: ignores symmetry and runs the general
/// nonsymmetric solver (case c6, hf-34570). The efficient peer calls
/// `eigvalsh`.
pub fn torch_eigvals_routine() -> Routine {
    Routine::branch_on(
        "torch.linalg.eigvals",
        vec![Frame::cpp("at::native::linalg_eig")],
        "at::native::linalg_eig_dispatch",
        "assume_symmetric",
        "true",
        VarSource::ApiArgument("use torch.linalg.eigvalsh for symmetric inputs".into()),
        KernelChoice::new("cusolver_syevd", ComputeUnit::CudaCore),
        KernelChoice::new("cusolver_geev_general", ComputeUnit::CudaCore).quality(0.45, 1.0, 2.2),
    )
}

/// `F.cross_entropy` kernel selection (case c13, pytorch-141822).
pub fn torch_cross_entropy_routine() -> Routine {
    Routine::branch_on(
        "torch.nn.functional.cross_entropy",
        vec![Frame::cpp("at::native::cross_entropy_loss")],
        "at::native::log_softmax_dispatch",
        "fused_log_softmax",
        "true",
        VarSource::ConfigFlag("use fused log_softmax+nll path".into()),
        KernelChoice::new("fused_log_softmax_nll", ComputeUnit::Sfu),
        KernelChoice::new("softmax_then_nll_twopass", ComputeUnit::Sfu).quality(0.80, 1.0, 1.9),
    )
}

/// `jax.scipy.signal.stft` calls an inefficient low-level path (c14).
pub fn jax_stft_routine() -> Routine {
    Routine::branch_on(
        "jax.scipy.signal.stft",
        vec![Frame::cpp("xla::gpu::FftThunk")],
        "xla::fft_lowering",
        "use_rfft",
        "true",
        VarSource::ApiArgument("lower via rfft instead of full complex fft".into()),
        KernelChoice::new("cufft_r2c_batched", ComputeUnit::CudaCore),
        KernelChoice::new("cufft_c2c_full_with_pad", ComputeUnit::CudaCore).quality(0.62, 1.05, 1.8),
    )
}

/// `jax.scipy.linalg.expm` recomputes shared powers (c15).
pub fn jax_expm_routine() -> Routine {
    Routine::branch_on(
        "jax.scipy.linalg.expm",
        vec![Frame::cpp("xla::gpu::GemmThunk")],
        "jax::expm_pade_dispatch",
        "reuse_powers",
        "true",
        VarSource::ApiArgument("hoist repeated A^k computations".into()),
        KernelChoice::new("expm_pade_hoisted", ComputeUnit::TensorCore),
        KernelChoice::new("expm_pade_recompute", ComputeUnit::TensorCore).quality(0.55, 1.3, 1.9),
    )
}

/// `tf.math.count_nonzero` triggers implicit casts/copies (c16).
pub fn tf_count_nonzero_routine() -> Routine {
    Routine::branch_on(
        "tf.math.count_nonzero",
        vec![Frame::cpp("tensorflow::CountNonzeroOp")],
        "tensorflow::cast_and_reduce",
        "direct_reduce",
        "true",
        VarSource::ApiArgument("reduce on the original dtype (no implicit cast copy)".into()),
        KernelChoice::new("reduce_nonzero_direct", ComputeUnit::CudaCore),
        KernelChoice::new("cast_to_int64_then_reduce", ComputeUnit::CudaCore).quality(0.58, 1.06, 3.0),
    )
}

/// PyTorch dispatcher for framework-level comparisons.
pub fn torch_dispatcher() -> Dispatcher {
    let mut d = Dispatcher::new();
    d.register("matmul", super::torch_matmul_routine());
    d.register("torch.addmm", super::torch_addmm_routine());
    d.register("torch.nn.functional.layer_norm", super::layernorm_routine());
    d.register("torch.conv2d", torch_conv_routine());
    d.register("torch.linalg.eigvals", torch_eigvals_routine());
    d.register("torch.nn.functional.cross_entropy", torch_cross_entropy_routine());
    d
}

/// JAX dispatcher.
pub fn jax_dispatcher() -> Dispatcher {
    let mut d = Dispatcher::new();
    d.register(
        "matmul",
        Routine::direct(
            "jax.numpy.matmul",
            vec![Frame::cpp("xla::gpu::GemmThunk")],
            KernelChoice::new("xla_tf32_gemm_fused", ComputeUnit::TensorCore),
        ),
    );
    d.register("jax.conv2d", jax_conv_routine());
    d.register("jax.stft", jax_stft_routine());
    d.register("jax.expm", jax_expm_routine());
    d
}

/// TensorFlow dispatcher.
pub fn tf_dispatcher() -> Dispatcher {
    let mut d = Dispatcher::new();
    d.register(
        "matmul",
        Routine::direct(
            "tf.linalg.matmul",
            vec![Frame::cpp("tensorflow::MatMulOp")],
            KernelChoice::new("tf_tf32_gemm", ComputeUnit::TensorCore),
        ),
    );
    d.register("tf.conv2d", tf_conv_routine());
    d.register("tf.count_nonzero", tf_count_nonzero_routine());
    d
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::energy::DeviceSpec;
    use crate::exec::Executor;

    fn exec(disp: Dispatcher, env: Env) -> Executor {
        Executor::new(DeviceSpec::h200_sim(), disp, env)
    }

    #[test]
    fn conv_values_agree_across_frameworks_and_layouts() {
        let mut rng = Prng::new(1);
        let spec = ConvSpec::fig5c();
        let (x, w) = conv_params(&mut rng, spec);
        let pt = build_conv("torch", spec, ConvLayout::Nchw, &x, &w, "torch.conv2d");
        let tf = build_conv("tf", spec, ConvLayout::Nhwc, &x, &w, "tf.conv2d");
        let jx = build_conv("jax", spec, ConvLayout::Nchw, &x, &w, "jax.conv2d");
        let rp = exec(torch_dispatcher(), Env::new()).run(&pt);
        let rt = exec(tf_dispatcher(), Env::new()).run(&tf);
        let rj = exec(jax_dispatcher(), Env::new().with("groups", "1")).run(&jx);
        assert!((rp.output().global_rel_diff(rt.output()) as f64) < 0.01);
        assert!((rp.output().global_rel_diff(rj.output()) as f64) < 0.01);
    }

    #[test]
    fn fig5c_energy_spread_is_large() {
        // the paper reports up to 3.35x between JAX and TF on conv
        let mut rng = Prng::new(2);
        let spec = ConvSpec::fig5c();
        let (x, w) = conv_params(&mut rng, spec);
        let rt = exec(tf_dispatcher(), Env::new())
            .run(&build_conv("tf", spec, ConvLayout::Nchw, &x, &w, "tf.conv2d"));
        let rj = exec(jax_dispatcher(), Env::new().with("groups", "1"))
            .run(&build_conv("jax", spec, ConvLayout::Nchw, &x, &w, "jax.conv2d"));
        let ratio = rj.total_energy_j / rt.total_energy_j;
        assert!(ratio > 1.5, "jax/tf conv energy ratio only {ratio:.2}");
    }

    #[test]
    fn layout_dependent_kernel_choice() {
        let r = torch_conv_routine();
        let nchw = r.run(&Env::new().with("layout", "nchw"));
        let nhwc = r.run(&Env::new().with("layout", "nhwc"));
        assert_ne!(nchw.choice.kernel, nhwc.choice.kernel);
        assert!(nchw.choice.efficiency < nhwc.choice.efficiency);
    }

    #[test]
    fn eigvals_routines_differ_by_hint() {
        let r = torch_eigvals_routine();
        let gen = r.run(&Env::new());
        let sym = r.run(&Env::new().with("assume_symmetric", "true"));
        assert_eq!(gen.choice.kernel, "cusolver_geev_general");
        assert_eq!(sym.choice.kernel, "cusolver_syevd");
    }

    #[test]
    fn unary_op_builder_runs() {
        let mut rng = Prng::new(3);
        let x = Tensor::randn(&mut rng, &[16, 16]);
        let mut at = Attrs::new();
        at.insert("dispatch".into(), "torch.linalg.eigvals".into());
        let p = build_unary_op("torch", OpKind::Eigvals, "eig", at, &x, &[]);
        let r = exec(torch_dispatcher(), Env::new()).run(&p);
        assert_eq!(r.output().shape(), &[16]);
        assert!(r.total_energy_j > 0.0);
    }
}
