//! The simulated ML-system fleet (DESIGN.md substitution table).
//!
//! Nine mini systems reproduce the *implementation diversity* the paper
//! exploits: each builds a computational graph for the same task with
//! its own idioms (operator decompositions, tensor layouts, fused vs
//! unfused kernels) and its own dispatch routines (kernel selection
//! under configuration flags). Weights are shared across systems so two
//! systems given the same workload compute the same function — the
//! precondition of differential energy debugging.
//!
//! | mini system     | stands in for              | signature quirks |
//! |-----------------|----------------------------|------------------|
//! | `MiniHf`        | HuggingFace Transformers   | Conv1D/addmm projections, 5-kernel GELU, HND layout + contiguous copies, full-sequence LM head |
//! | `MiniVllm`      | vLLM                       | fused QKV, fused GELU, NHD layout, last-token LM head, `use_tensor_cores` flag |
//! | `MiniSglang`    | SGLang                     | like vLLM + sort-based top-k variant |
//! | `MiniMegatron`  | Megatron-LM                | GQA with `repeat_interleave`, DDP hooks |
//! | `MiniTorch`     | PyTorch                    | addmm kernels, `allow_tf32` off by default, busy-wait sync flag |
//! | `MiniJax`       | JAX                        | fused elementwise, grouped-conv cuDNN kernels |
//! | `MiniTf`        | TensorFlow                 | custom conv kernels, implicit copies in `count_nonzero` |
//! | `MiniSd`        | Stable Diffusion reference | UNet block, `allow_tf32` unset (c8) |
//! | `MiniDiffusers` | HF Diffusers               | UNet block with concat/split round-trip (c7) |

pub mod llm;
pub mod frameworks;
pub mod imagegen;

use crate::dispatch::{Env, KernelChoice, Routine, VarSource};
use crate::energy::ComputeUnit;
use crate::graph::{Graph, NodeId, OpKind};
use crate::trace::Frame;

/// Identity of a mini system.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SystemId {
    MiniHf,
    MiniVllm,
    MiniSglang,
    MiniMegatron,
    MiniTorch,
    MiniJax,
    MiniTf,
    MiniSd,
    MiniDiffusers,
}

impl SystemId {
    pub fn name(&self) -> &'static str {
        match self {
            SystemId::MiniHf => "mini-hf-transformers",
            SystemId::MiniVllm => "mini-vllm",
            SystemId::MiniSglang => "mini-sglang",
            SystemId::MiniMegatron => "mini-megatron",
            SystemId::MiniTorch => "mini-pytorch",
            SystemId::MiniJax => "mini-jax",
            SystemId::MiniTf => "mini-tensorflow",
            SystemId::MiniSd => "mini-stable-diffusion",
            SystemId::MiniDiffusers => "mini-diffusers",
        }
    }
}

// ---------------------------------------------------------------------
// Shared graph-building helpers
// ---------------------------------------------------------------------

/// HF-style linear: `addmm(bias, x, w)` in one fused op (the Conv1D of
/// `pytorch_utils.py` — the paper's Fig 3 snippet).
pub fn linear_addmm(g: &mut Graph, x: NodeId, w: NodeId, b: NodeId, label: &str) -> NodeId {
    let mut attrs = crate::graph::Attrs::new();
    attrs.insert("dispatch".into(), "torch.addmm".into());
    g.add_attrs(OpKind::AddMm, &[b, x, w], label, attrs)
}

/// vLLM-style linear: separate `matmul` + `add` kernels.
pub fn linear_matmul_add(g: &mut Graph, x: NodeId, w: NodeId, b: NodeId, label: &str) -> NodeId {
    let m = g.add(OpKind::MatMul, &[x, w], &format!("{label}.matmul"));
    g.add(OpKind::Add, &[m, b], &format!("{label}.add_bias"))
}

/// The HuggingFace 5-kernel tanh-GELU decomposition (§6.3's GELU case):
/// pow, scale+add, scale, tanh, mul — five separate HBM round trips.
pub fn gelu_unfused(g: &mut Graph, x: NodeId, label: &str) -> NodeId {
    let x3 = g.add_attr1(OpKind::Pow, &[x], &format!("{label}.pow3"), "p", "3");
    let sc = g.add_attr1(OpKind::Scale, &[x3], &format!("{label}.scale_c"), "s", "0.044715");
    let inner = g.add(OpKind::Add, &[x, sc], &format!("{label}.add"));
    let scaled = g.add_attr1(OpKind::Scale, &[inner], &format!("{label}.scale_s2pi"), "s", "0.7978846");
    let th = g.add(OpKind::Tanh, &[scaled], &format!("{label}.tanh"));
    // 0.5*x*(1+tanh) == 0.5*x*tanh + 0.5*x
    let half_tanh = g.add_attr1(OpKind::Scale, &[th], &format!("{label}.half_tanh"), "s", "0.5");
    let xt = g.add(OpKind::Mul, &[x, half_tanh], &format!("{label}.mul1"));
    let half_x = g.add_attr1(OpKind::Scale, &[x], &format!("{label}.half_x"), "s", "0.5");
    g.add(OpKind::Add, &[xt, half_x], &format!("{label}.mul_out"))
}

/// Fused single-kernel tanh GELU.
pub fn gelu_fused(g: &mut Graph, x: NodeId, label: &str, dispatch: &str) -> NodeId {
    let mut attrs = crate::graph::Attrs::new();
    attrs.insert("approx".into(), "tanh".into());
    attrs.insert("dispatch".into(), dispatch.into());
    g.add_attrs(OpKind::Gelu, &[x], label, attrs)
}

// ---------------------------------------------------------------------
// Common dispatch routines
// ---------------------------------------------------------------------

/// `torch.matmul`: branches on `allow_tf32` (case c8 / pytorch-153195).
pub fn torch_matmul_routine() -> Routine {
    Routine::branch_on(
        "torch.matmul",
        vec![Frame::cpp("at::native::matmul"), Frame::cpp("at::cuda::blas::gemm")],
        "at::cuda::blas::gemm",
        "allow_tf32",
        "true",
        VarSource::ConfigFlag("torch.backends.cuda.matmul.allow_tf32".into()),
        KernelChoice::new("ampere_tf32_s1688gemm_128x128", ComputeUnit::TensorCore),
        KernelChoice::new("ampere_sgemm_fp32_128x128", ComputeUnit::CudaCore),
    )
}

/// `torch.addmm`: the historically inefficient fused-epilogue kernel
/// (case c10, pytorch-141210) — extra power at equal speed.
pub fn torch_addmm_routine() -> Routine {
    Routine::branch_on(
        "torch.addmm",
        vec![Frame::cpp("at::native::addmm"), Frame::cpp("at::cuda::blas::gemm_and_bias")],
        "at::cuda::blas::gemm_and_bias",
        "allow_tf32",
        "true",
        VarSource::ConfigFlag("torch.backends.cuda.matmul.allow_tf32".into()),
        KernelChoice::new("ampere_tf32_gemm_bias_epilogue", ComputeUnit::TensorCore)
            .quality(0.60, 1.8, 1.15),
        KernelChoice::new("ampere_sgemm_bias_epilogue", ComputeUnit::CudaCore)
            .quality(0.60, 1.8, 1.15),
    )
}

/// Fused attention: branches on `use_tensor_cores` (case c1, vllm-9471).
pub fn attention_routine(api: &str) -> Routine {
    Routine::branch_on(
        api,
        vec![Frame::cpp("flashinfer::BatchPrefillWithKVCache")],
        "flashinfer::dispatch_by_tensor_cores",
        "use_tensor_cores",
        "false",
        VarSource::ApiArgument("use_tensor_cores".into()),
        KernelChoice::new("prefill_attn_cuda_core", ComputeUnit::CudaCore).quality(0.55, 1.6, 1.25),
        KernelChoice::new("prefill_attn_tensor_core_f16", ComputeUnit::TensorCore),
    )
}

/// LayerNorm: non-contiguous inputs trigger a strided kernel (c12).
pub fn layernorm_routine() -> Routine {
    Routine::branch_on(
        "torch.nn.functional.layer_norm",
        vec![Frame::cpp("at::native::layer_norm")],
        "at::native::layer_norm_kernel_impl",
        "input_contiguous",
        "false",
        VarSource::InputProperty("input tensor contiguity".into()),
        KernelChoice::new("vectorized_layer_norm_strided", ComputeUnit::CudaCore)
            .quality(0.78, 1.12, 1.8),
        KernelChoice::new("vectorized_layer_norm", ComputeUnit::CudaCore),
    )
}

/// Baseline environment shared by all systems.
pub fn base_env() -> Env {
    Env::new()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dispatch::Env;
    use crate::energy::DeviceSpec;
    use crate::exec::{Dispatcher, Executor, Program};
    use crate::tensor::Tensor;
    use crate::util::Prng;

    #[test]
    fn gelu_unfused_matches_fused_numerics() {
        let mut rng = Prng::new(1);
        let x = Tensor::randn(&mut rng, &[16, 32]);

        let mut g1 = Graph::new("fused");
        let i1 = g1.add(OpKind::Input, &[], "x");
        let f = gelu_fused(&mut g1, i1, "act", "gelu");
        g1.add(OpKind::Output, &[f], "out");
        let mut p1 = Program::new(g1);
        p1.feed(0, x.clone());

        let mut g2 = Graph::new("unfused");
        let i2 = g2.add(OpKind::Input, &[], "x");
        let u = gelu_unfused(&mut g2, i2, "act");
        g2.add(OpKind::Output, &[u], "out");
        let mut p2 = Program::new(g2);
        p2.feed(0, x);

        let exec = Executor::new(DeviceSpec::h200_sim(), Dispatcher::new(), Env::new());
        let r1 = exec.run(&p1);
        let r2 = exec.run(&p2);
        assert!(r1.output().allclose(r2.output(), 1e-5, 1e-4));
        // the unfused decomposition burns more energy for the same math
        assert!(r2.total_energy_j > r1.total_energy_j * 1.3,
            "unfused {} vs fused {}", r2.total_energy_j, r1.total_energy_j);
    }

    #[test]
    fn addmm_and_matmul_add_agree() {
        let mut rng = Prng::new(2);
        let x = Tensor::randn(&mut rng, &[8, 16]);
        let w = Tensor::randn(&mut rng, &[16, 8]);
        let b = Tensor::randn(&mut rng, &[8]);

        let build = |fused: bool| {
            let mut g = Graph::new(if fused { "addmm" } else { "mm+add" });
            let xi = g.add(OpKind::Input, &[], "x");
            let wi = g.add(OpKind::Weight, &[], "w");
            let bi = g.add(OpKind::Weight, &[], "b");
            let o = if fused {
                linear_addmm(&mut g, xi, wi, bi, "lin")
            } else {
                linear_matmul_add(&mut g, xi, wi, bi, "lin")
            };
            g.add(OpKind::Output, &[o], "out");
            let mut p = Program::new(g);
            p.feed(0, x.clone());
            p.feed(1, w.clone());
            p.feed(2, b.clone());
            p
        };
        let mut disp = Dispatcher::new();
        disp.register("torch.addmm", torch_addmm_routine());
        disp.register("matmul", torch_matmul_routine());
        let exec = Executor::new(
            DeviceSpec::h200_sim(),
            disp,
            Env::new().with("allow_tf32", "true"),
        );
        let r1 = exec.run(&build(true));
        let r2 = exec.run(&build(false));
        assert!(r1.output().allclose(r2.output(), 1e-4, 1e-3));
    }

    #[test]
    fn tf32_flag_changes_kernel_and_energy() {
        let r = torch_matmul_routine();
        let on = r.run(&Env::new().with("allow_tf32", "true"));
        let off = r.run(&Env::new());
        assert_eq!(on.choice.unit, ComputeUnit::TensorCore);
        assert_eq!(off.choice.unit, ComputeUnit::CudaCore);
    }
}
