//! Mini LLM serving/training systems: HF-Transformers-, vLLM-, SGLang-,
//! and Megatron-flavoured implementations of the same GPT-2-style
//! transformer forward pass.
//!
//! All four consume the same [`TransformerParams`] (shared weights), so
//! any two systems given the same workload compute the same function —
//! but their graphs differ exactly where the paper's cases live:
//! projection style (addmm vs matmul+add), QKV fusion, attention layout
//! (HND + contiguous copies vs NHD), GELU decomposition, GQA
//! `repeat_interleave`, and LM-head scope (all positions vs last).

use std::collections::BTreeMap;

use crate::dispatch::{Env, KernelChoice, Routine, VarSource};
use crate::energy::ComputeUnit;
use crate::exec::{Dispatcher, Program};
use crate::graph::{Attrs, Graph, NodeId, OpKind};
use crate::tensor::Tensor;
use crate::trace::Frame;
use crate::util::Prng;

use super::{gelu_fused, gelu_unfused, linear_addmm, linear_matmul_add};

/// Transformer architecture hyper-parameters.
#[derive(Clone, Copy, Debug)]
pub struct LlmSpec {
    pub batch: usize,
    pub seq: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub vocab: usize,
    pub layers: usize,
}

impl LlmSpec {
    /// GPT-2-small-shaped config scaled for the simulated testbed.
    pub fn gpt2_sim() -> LlmSpec {
        LlmSpec { batch: 4, seq: 64, d_model: 256, n_heads: 8, d_ff: 1024, vocab: 2048, layers: 1 }
    }

    /// Llama-8B-shaped (node-count-wise) config: more layers for the
    /// Fig 9 scalability experiment.
    pub fn llama_sim(layers: usize) -> LlmSpec {
        LlmSpec { batch: 2, seq: 32, d_model: 128, n_heads: 8, d_ff: 512, vocab: 1024, layers }
    }

    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }
}

/// Shared weights: one tensor bank consumed by every system.
#[derive(Clone, Debug)]
pub struct TransformerParams {
    pub spec: LlmSpec,
    pub bank: BTreeMap<String, Tensor>,
    /// Token ids for the workload.
    pub ids: Vec<usize>,
}

impl TransformerParams {
    pub fn new(rng: &mut Prng, spec: LlmSpec) -> TransformerParams {
        let mut bank = BTreeMap::new();
        let d = spec.d_model;
        let scale = 1.0 / (d as f32).sqrt();
        let mut t = |name: String, shape: &[usize]| {
            let n: usize = shape.iter().product();
            let data: Vec<f32> = (0..n).map(|_| rng.normal_f32() * scale).collect();
            bank.insert(name, Tensor::from_vec(data, shape));
        };
        t("wte".into(), &[spec.vocab, d]);
        t("wpe".into(), &[spec.seq, d]);
        for l in 0..spec.layers {
            t(format!("l{l}.ln1_g"), &[d]);
            t(format!("l{l}.ln1_b"), &[d]);
            t(format!("l{l}.qkv_w"), &[d, 3 * d]);
            t(format!("l{l}.qkv_b"), &[3 * d]);
            t(format!("l{l}.out_w"), &[d, d]);
            t(format!("l{l}.out_b"), &[d]);
            t(format!("l{l}.ln2_g"), &[d]);
            t(format!("l{l}.ln2_b"), &[d]);
            t(format!("l{l}.ff1_w"), &[d, spec.d_ff]);
            t(format!("l{l}.ff1_b"), &[spec.d_ff]);
            t(format!("l{l}.ff2_w"), &[spec.d_ff, d]);
            t(format!("l{l}.ff2_b"), &[d]);
        }
        t("lnf_g".into(), &[d]);
        t("lnf_b".into(), &[d]);
        // LN gains near 1 are more realistic than N(0, 1/sqrt d)
        for (k, v) in bank.iter_mut() {
            if k.ends_with("_g") {
                let ones: Vec<f32> = v.to_vec().iter().map(|x| 1.0 + 0.1 * x).collect();
                *v = Tensor::from_vec(ones, v.shape());
            }
        }
        let ids: Vec<usize> = (0..spec.batch * spec.seq).map(|_| rng.below(spec.vocab)).collect();
        TransformerParams { spec, bank, ids }
    }
}

/// Builder context: adds Weight nodes and records feeds.
struct Ctx<'a> {
    g: Graph,
    feeds: Vec<(NodeId, Tensor)>,
    params: &'a TransformerParams,
}

impl<'a> Ctx<'a> {
    fn new(name: &str, params: &'a TransformerParams) -> Ctx<'a> {
        Ctx { g: Graph::new(name), feeds: Vec::new(), params }
    }

    fn weight(&mut self, key: &str) -> NodeId {
        let t = self.params.bank.get(key).unwrap_or_else(|| panic!("missing weight {key}")).clone();
        let id = self.g.add(OpKind::Weight, &[], key);
        self.feeds.push((id, t));
        id
    }

    /// A weight that is a column slice of a bank tensor (HF's separate
    /// Q/K/V views of the fused QKV matrix).
    fn weight_slice_cols(&mut self, key: &str, lo: usize, hi: usize, label: &str) -> NodeId {
        let t = self.params.bank.get(key).unwrap().slice(1, lo, hi).contiguous();
        let id = self.g.add(OpKind::Weight, &[], label);
        self.feeds.push((id, t));
        id
    }

    fn weight_slice_1d(&mut self, key: &str, lo: usize, hi: usize, label: &str) -> NodeId {
        let t = self.params.bank.get(key).unwrap().slice(0, lo, hi).contiguous();
        let id = self.g.add(OpKind::Weight, &[], label);
        self.feeds.push((id, t));
        id
    }

    fn finish(self, out: NodeId) -> Program {
        let mut g = self.g;
        g.add(OpKind::Output, &[out], "out");
        let mut p = Program::new(g);
        for (id, t) in self.feeds {
            p.feed(id, t);
        }
        p
    }
}

fn ids_csv(ids: &[usize]) -> String {
    ids.iter().map(|i| i.to_string()).collect::<Vec<_>>().join(",")
}

/// Embedding + position add, shared front-end ([B*S, D]).
///
/// Token ids are the *model input*: they appear as an `Input` node so
/// the dominator analysis sees the activation spine starting at the
/// ids (weights are parameter edges, not flow sources).
fn embed_front(cx: &mut Ctx, sys: &str) -> NodeId {
    let spec = cx.params.spec;
    let ids_node = cx.g.add(OpKind::Input, &[], "token_ids");
    let ids_tensor = Tensor::from_vec(
        cx.params.ids.iter().map(|&i| i as f32).collect(),
        &[cx.params.ids.len()],
    );
    cx.feeds.push((ids_node, ids_tensor));
    let wte = cx.weight("wte");
    let mut at = Attrs::new();
    at.insert("ids".into(), ids_csv(&cx.params.ids));
    let tok = cx.g.add_attrs(OpKind::Embedding, &[wte, ids_node], &format!("{sys}.wte_lookup"), at);
    let wpe = cx.weight("wpe");
    // positions repeat per batch row: model as embedding lookup too
    let pos_ids: Vec<usize> = (0..spec.batch * spec.seq).map(|i| i % spec.seq).collect();
    let mut ap = Attrs::new();
    ap.insert("ids".into(), ids_csv(&pos_ids));
    let pos = cx.g.add_attrs(OpKind::Embedding, &[wpe], &format!("{sys}.wpe_lookup"), ap);
    cx.g.add(OpKind::Add, &[tok, pos], &format!("{sys}.embed_add"))
}

fn layernorm_node(cx: &mut Ctx, x: NodeId, gk: &str, bk: &str, label: &str, contiguous_input: bool) -> NodeId {
    let g = cx.weight(gk);
    let b = cx.weight(bk);
    let mut at = Attrs::new();
    at.insert("dispatch".into(), "torch.nn.functional.layer_norm".into());
    at.insert("input_contiguous".into(), if contiguous_input { "true" } else { "false" }.into());
    cx.g.add_attrs(OpKind::LayerNorm, &[x, g, b], label, at)
}

/// Options steering system quirks (used by the case library to toggle
/// the buggy/fixed variants).
#[derive(Clone, Debug)]
pub struct LlmBuildOpts {
    /// Use the fused-addmm projection kernels (HF) vs matmul+add.
    pub use_addmm: bool,
    /// HF-style unfused 5-kernel GELU.
    pub unfused_gelu: bool,
    /// HND attention layout with materialised contiguous() copies.
    pub hnd_layout: bool,
    /// LM head over all positions (redundant for decode; hf-38977).
    pub lm_head_all_positions: bool,
    /// Compute the LM head at all (fig 5 J/token workloads do).
    pub lm_head: bool,
    /// GQA: kv-head reduction factor with explicit repeat_interleave
    /// materialisation (Megatron, case c4). 1 = standard MHA.
    pub gqa_repeat: usize,
    /// Fuse the GQA expansion into the attention kernel (the fix for c4).
    pub gqa_fused: bool,
    /// Extra layout round-trip in attention (HF default tensor format,
    /// case c5).
    pub layout_roundtrip: bool,
    /// Sort-based top-k sampling (SGLang case c3); None = no sampling op.
    pub topk: Option<TopkImpl>,
    /// Dispatch-key prefix, e.g. "vllm".
    pub prefix: &'static str,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TopkImpl {
    /// Efficient fused top-k kernel.
    Fused,
    /// Full sort + slice (the energy-inefficient API combination).
    SortSlice,
}

impl LlmBuildOpts {
    pub fn hf() -> LlmBuildOpts {
        LlmBuildOpts {
            use_addmm: true,
            unfused_gelu: true,
            hnd_layout: true,
            lm_head_all_positions: true,
            lm_head: true,
            gqa_repeat: 1,
            gqa_fused: false,
            layout_roundtrip: true,
            topk: None,
            prefix: "hf",
        }
    }

    pub fn vllm() -> LlmBuildOpts {
        LlmBuildOpts {
            use_addmm: false,
            unfused_gelu: false,
            hnd_layout: false,
            lm_head_all_positions: false,
            lm_head: true,
            gqa_repeat: 1,
            gqa_fused: true,
            layout_roundtrip: false,
            topk: None,
            prefix: "vllm",
        }
    }

    pub fn sglang() -> LlmBuildOpts {
        LlmBuildOpts { topk: Some(TopkImpl::Fused), prefix: "sglang", ..LlmBuildOpts::vllm() }
    }

    pub fn megatron() -> LlmBuildOpts {
        LlmBuildOpts {
            gqa_repeat: 2,
            gqa_fused: false,
            prefix: "megatron",
            ..LlmBuildOpts::vllm()
        }
    }
}

/// Build a transformer forward program under the given options.
pub fn build_llm(params: &TransformerParams, opts: &LlmBuildOpts) -> Program {
    let spec = params.spec;
    let (b, s, d, h) = (spec.batch, spec.seq, spec.d_model, spec.n_heads);
    let dh = spec.head_dim();
    let sys = opts.prefix;
    let mut cx = Ctx::new(&format!("{sys}-llm"), params);
    let mut x = embed_front(&mut cx, sys);

    for l in 0..spec.layers {
        let pre = format!("{sys}.l{l}");
        let ln1 = layernorm_node(&mut cx, x, &format!("l{l}.ln1_g"), &format!("l{l}.ln1_b"), &format!("{pre}.ln1"), true);

        // ---- QKV projection --------------------------------------
        let (q2d, k2d, v2d);
        if opts.use_addmm {
            // separate Conv1D-style projections from sliced weights
            let wq = cx.weight_slice_cols(&format!("l{l}.qkv_w"), 0, d, &format!("l{l}.wq"));
            let wk = cx.weight_slice_cols(&format!("l{l}.qkv_w"), d, 2 * d, &format!("l{l}.wk"));
            let wv = cx.weight_slice_cols(&format!("l{l}.qkv_w"), 2 * d, 3 * d, &format!("l{l}.wv"));
            let bq = cx.weight_slice_1d(&format!("l{l}.qkv_b"), 0, d, &format!("l{l}.bq"));
            let bk = cx.weight_slice_1d(&format!("l{l}.qkv_b"), d, 2 * d, &format!("l{l}.bk"));
            let bv = cx.weight_slice_1d(&format!("l{l}.qkv_b"), 2 * d, 3 * d, &format!("l{l}.bv"));
            q2d = linear_addmm(&mut cx.g, ln1, wq, bq, &format!("{pre}.attn.q_proj"));
            k2d = linear_addmm(&mut cx.g, ln1, wk, bk, &format!("{pre}.attn.k_proj"));
            v2d = linear_addmm(&mut cx.g, ln1, wv, bv, &format!("{pre}.attn.v_proj"));
        } else {
            let w = cx.weight(&format!("l{l}.qkv_w"));
            let bias = cx.weight(&format!("l{l}.qkv_b"));
            let qkv = linear_matmul_add(&mut cx.g, ln1, w, bias, &format!("{pre}.attn.qkv_proj"));
            let mut split = |idx: usize, name: &str| {
                let mut at = Attrs::new();
                at.insert("dim".into(), "1".into());
                at.insert("chunks".into(), "3".into());
                at.insert("index".into(), idx.to_string());
                cx.g.add_attrs(OpKind::SplitChunk, &[qkv], &format!("{pre}.attn.{name}"), at)
            };
            q2d = split(0, "q_split");
            k2d = split(1, "k_split");
            v2d = split(2, "v_split");
        }

        // ---- reshape to attention layout -------------------------
        let kv_h = h / opts.gqa_repeat.max(1);
        let to4d = |cx: &mut Ctx, t: NodeId, heads: usize, name: &str| {
            let mut at = Attrs::new();
            at.insert("shape".into(), format!("{b},{s},{heads},{dh}"));
            cx.g.add_attrs(OpKind::Reshape, &[t], &format!("{pre}.attn.{name}_r"), at)
        };
        // GQA: k/v use fewer heads (slice columns before reshape)
        let (k2d, v2d) = if opts.gqa_repeat > 1 {
            let mut sl = |t: NodeId, name: &str| {
                let mut at = Attrs::new();
                at.insert("dim".into(), "1".into());
                at.insert("start".into(), "0".into());
                at.insert("stop".into(), (kv_h * dh).to_string());
                cx.g.add_attrs(OpKind::Slice, &[t], &format!("{pre}.attn.{name}_gqa_slice"), at)
            };
            (sl(k2d, "k"), sl(v2d, "v"))
        } else {
            (k2d, v2d)
        };
        let q4 = to4d(&mut cx, q2d, h, "q");
        let k4 = to4d(&mut cx, k2d, kv_h, "k");
        let v4 = to4d(&mut cx, v2d, kv_h, "v");

        let attn_out = if opts.hnd_layout {
            // permute to [B,H,S,dh] and materialise (HF's HND layout)
            let mut perm = |cx: &mut Ctx, t: NodeId, name: &str| {
                let p = cx.g.add_attr1(OpKind::Permute, &[t], &format!("{pre}.attn.{name}_hnd"), "perm", "0,2,1,3");
                cx.g.add(OpKind::Contiguous, &[p], &format!("{pre}.attn.{name}_contig"))
            };
            let mut qh = perm(&mut cx, q4, "q");
            let (mut kh, mut vh) = (perm(&mut cx, k4, "k"), perm(&mut cx, v4, "v"));
            if opts.layout_roundtrip {
                // c5: default tensor format forces an extra round trip
                let rt = |cx: &mut Ctx, t: NodeId, name: &str| {
                    let p = cx.g.add_attr1(OpKind::Permute, &[t], &format!("{pre}.attn.{name}_to_nhd"), "perm", "0,2,1,3");
                    let c = cx.g.add(OpKind::Contiguous, &[p], &format!("{pre}.attn.{name}_fmt_copy"));
                    let p2 = cx.g.add_attr1(OpKind::Permute, &[c], &format!("{pre}.attn.{name}_back"), "perm", "0,2,1,3");
                    cx.g.add(OpKind::Contiguous, &[p2], &format!("{pre}.attn.{name}_fmt_copy2"))
                };
                qh = rt(&mut cx, qh, "q");
                kh = rt(&mut cx, kh, "k");
                vh = rt(&mut cx, vh, "v");
            }
            // materialised GQA expansion (if not fused)
            let (kh, vh) = expand_gqa(&mut cx, kh, vh, opts, 1, &pre);
            let mut at = Attrs::new();
            at.insert("dispatch".into(), format!("{sys}.attention"));
            if opts.gqa_fused && opts.gqa_repeat > 1 {
                at.insert("gqa_reps".into(), opts.gqa_repeat.to_string());
            }
            let a = cx.g.add_attrs(OpKind::Attention, &[qh, kh, vh], &format!("{pre}.attn.sdpa"), at);
            // back to [B,S,H,dh] then 2-D
            let p = cx.g.add_attr1(OpKind::Permute, &[a], &format!("{pre}.attn.out_nhd"), "perm", "0,2,1,3");
            cx.g.add(OpKind::Contiguous, &[p], &format!("{pre}.attn.out_contig"))
        } else {
            // NHD layout: no permutes needed
            let (k4, v4) = expand_gqa(&mut cx, k4, v4, opts, 2, &pre);
            let mut at = Attrs::new();
            at.insert("dispatch".into(), format!("{sys}.attention"));
            at.insert("layout".into(), "nhd".into());
            if opts.gqa_fused && opts.gqa_repeat > 1 {
                at.insert("gqa_reps".into(), opts.gqa_repeat.to_string());
            }
            cx.g.add_attrs(OpKind::Attention, &[q4, k4, v4], &format!("{pre}.attn.flash"), at)
        };
        let mut at = Attrs::new();
        at.insert("shape".into(), format!("{},{}", b * s, d));
        let a2d = cx.g.add_attrs(OpKind::Reshape, &[attn_out], &format!("{pre}.attn.out_2d"), at);

        // ---- output projection + residual -------------------------
        let ow = cx.weight(&format!("l{l}.out_w"));
        let ob = cx.weight(&format!("l{l}.out_b"));
        let proj = if opts.use_addmm {
            linear_addmm(&mut cx.g, a2d, ow, ob, &format!("{pre}.attn.out_proj"))
        } else {
            linear_matmul_add(&mut cx.g, a2d, ow, ob, &format!("{pre}.attn.out_proj"))
        };
        let res1 = cx.g.add(OpKind::Add, &[x, proj], &format!("{pre}.residual1"));

        // ---- MLP ---------------------------------------------------
        let ln2 = layernorm_node(&mut cx, res1, &format!("l{l}.ln2_g"), &format!("l{l}.ln2_b"), &format!("{pre}.ln2"), true);
        let f1w = cx.weight(&format!("l{l}.ff1_w"));
        let f1b = cx.weight(&format!("l{l}.ff1_b"));
        let h1 = if opts.use_addmm {
            linear_addmm(&mut cx.g, ln2, f1w, f1b, &format!("{pre}.mlp.fc_in"))
        } else {
            linear_matmul_add(&mut cx.g, ln2, f1w, f1b, &format!("{pre}.mlp.fc_in"))
        };
        let act = if opts.unfused_gelu {
            gelu_unfused(&mut cx.g, h1, &format!("{pre}.mlp.gelu"))
        } else {
            gelu_fused(&mut cx.g, h1, &format!("{pre}.mlp.gelu"), &format!("{sys}.gelu"))
        };
        let f2w = cx.weight(&format!("l{l}.ff2_w"));
        let f2b = cx.weight(&format!("l{l}.ff2_b"));
        let h2 = if opts.use_addmm {
            linear_addmm(&mut cx.g, act, f2w, f2b, &format!("{pre}.mlp.fc_out"))
        } else {
            linear_matmul_add(&mut cx.g, act, f2w, f2b, &format!("{pre}.mlp.fc_out"))
        };
        x = cx.g.add(OpKind::Add, &[res1, h2], &format!("{pre}.residual2"));
    }

    // ---- final LN + LM head --------------------------------------
    let lnf = layernorm_node(&mut cx, x, "lnf_g", "lnf_b", &format!("{sys}.ln_f"), true);
    let mut out = lnf;
    if opts.lm_head {
        let wte = cx.weight("wte"); // weight tying: logits = x @ wteᵀ
        let wte_t = cx.g.add_attr1(OpKind::Permute, &[wte], &format!("{sys}.wte_t"), "perm", "1,0");
        out = if opts.lm_head_all_positions {
            // hf-38977: full-sequence logits, then keep the last row
            let logits = cx.g.add(OpKind::MatMul, &[lnf, wte_t], &format!("{sys}.lm_head_all"));
            let mut at = Attrs::new();
            at.insert("dim".into(), "0".into());
            // keep the final position of each batch row
            at.insert("start".into(), (b * s - b).to_string());
            at.insert("stop".into(), (b * s).to_string());
            cx.g.add_attrs(OpKind::Slice, &[logits], &format!("{sys}.lm_head_last_rows"), at)
        } else {
            let mut at = Attrs::new();
            at.insert("dim".into(), "0".into());
            at.insert("start".into(), (b * s - b).to_string());
            at.insert("stop".into(), (b * s).to_string());
            let last = cx.g.add_attrs(OpKind::Slice, &[lnf], &format!("{sys}.last_hidden"), at);
            cx.g.add(OpKind::MatMul, &[last, wte_t], &format!("{sys}.lm_head_last"))
        };
        if let Some(impl_) = opts.topk {
            out = match impl_ {
                TopkImpl::Fused => {
                    let mut at = Attrs::new();
                    at.insert("k".into(), "50".into());
                    at.insert("dispatch".into(), format!("{sys}.topk"));
                    cx.g.add_attrs(OpKind::TopK, &[out], &format!("{sys}.sample_topk"), at)
                }
                TopkImpl::SortSlice => {
                    let sorted = cx.g.add(OpKind::Sort, &[out], &format!("{sys}.sample_sort"));
                    let mut at = Attrs::new();
                    at.insert("dim".into(), "1".into());
                    at.insert("start".into(), "0".into());
                    at.insert("stop".into(), "50".into());
                    cx.g.add_attrs(OpKind::Slice, &[sorted], &format!("{sys}.sample_slice"), at)
                }
            };
        }
    }
    cx.finish(out)
}

/// Materialised GQA expansion (repeat_interleave) when not fused.
/// `dim_offset` selects the head dim: 1 for HND `[B,H,S,dh]`, 2 for NHD
/// `[B,S,H,dh]`.
fn expand_gqa(
    cx: &mut Ctx,
    k: NodeId,
    v: NodeId,
    opts: &LlmBuildOpts,
    head_dim_index: usize,
    pre: &str,
) -> (NodeId, NodeId) {
    if opts.gqa_repeat <= 1 || opts.gqa_fused {
        return (k, v);
    }
    let mut rep = |t: NodeId, name: &str| {
        let mut at = Attrs::new();
        at.insert("dim".into(), head_dim_index.to_string());
        at.insert("reps".into(), opts.gqa_repeat.to_string());
        cx.g.add_attrs(OpKind::RepeatInterleave, &[t], &format!("{pre}.attn.{name}_repeat_interleave"), at)
    };
    (rep(k, "k"), rep(v, "v"))
}

// ---------------------------------------------------------------------
// Dispatchers
// ---------------------------------------------------------------------

/// HF dispatcher: addmm epilogue kernels, HND attention.
pub fn hf_dispatcher() -> Dispatcher {
    let mut d = Dispatcher::new();
    d.register("torch.addmm", super::torch_addmm_routine());
    d.register("matmul", super::torch_matmul_routine());
    d.register("torch.nn.functional.layer_norm", super::layernorm_routine());
    d.register("hf.attention", super::attention_routine("hf.scaled_dot_product_attention"));
    d
}

/// vLLM dispatcher: cutlass TC gemms, fused gelu, flashinfer attention
/// with `use_tensor_cores` and the decode-copy flag (c2).
pub fn vllm_dispatcher() -> Dispatcher {
    let mut d = Dispatcher::new();
    d.register(
        "matmul",
        Routine::direct(
            "vllm.cutlass_gemm",
            vec![Frame::cpp("cutlass::gemm::device::GemmUniversal")],
            KernelChoice::new("cutlass_tf32_tensorop_gemm", ComputeUnit::TensorCore),
        ),
    );
    d.register("torch.nn.functional.layer_norm", super::layernorm_routine());
    d.register(
        "vllm.gelu",
        Routine::direct(
            "vllm.gelu_tanh_and_mul",
            vec![Frame::cpp("vllm::activation_kernels")],
            KernelChoice::new("gelu_tanh_and_mul_fused", ComputeUnit::Sfu),
        ),
    );
    d.register("vllm.attention", super::attention_routine("vllm.flashinfer_prefill"));
    d.register(
        "vllm.decode_attention",
        Routine::branch_on(
            "vllm.flashinfer_decode",
            vec![Frame::cpp("flashinfer::BatchDecodeWithPagedKVCache")],
            "flashinfer::decode_dispatch",
            "kv_cache_aligned",
            "false",
            VarSource::ApiArgument("kv_cache layout (redundant copy when unaligned)".into()),
            KernelChoice::new("decode_attn_with_copy", ComputeUnit::TensorCore).quality(0.92, 1.0, 1.45),
            KernelChoice::new("decode_attn_inplace", ComputeUnit::TensorCore),
        ),
    );
    d
}

/// SGLang dispatcher: vLLM-like plus a fused top-k kernel.
pub fn sglang_dispatcher() -> Dispatcher {
    let mut d = vllm_dispatcher();
    d.register("sglang.attention", super::attention_routine("sglang.radix_attention"));
    d.register(
        "sglang.gelu",
        Routine::direct(
            "sglang.gelu_tanh",
            vec![Frame::cpp("sgl_kernel::activation")],
            KernelChoice::new("sgl_gelu_tanh_fused", ComputeUnit::Sfu),
        ),
    );
    d.register(
        "sglang.topk",
        Routine::direct(
            "sglang.fused_topk",
            vec![Frame::cpp("sgl_kernel::topk_softmax")],
            KernelChoice::new("fused_topk_radix", ComputeUnit::CudaCore),
        ),
    );
    d
}

/// Megatron dispatcher: vLLM-like kernels under Megatron names.
pub fn megatron_dispatcher() -> Dispatcher {
    let mut d = Dispatcher::new();
    d.register(
        "matmul",
        Routine::direct(
            "megatron.fused_gemm",
            vec![Frame::cpp("megatron::core::tensor_parallel")],
            KernelChoice::new("te_tf32_gemm", ComputeUnit::TensorCore),
        ),
    );
    d.register("torch.nn.functional.layer_norm", super::layernorm_routine());
    d.register("megatron.attention", super::attention_routine("megatron.core_attention"));
    d.register(
        "megatron.gelu",
        Routine::direct(
            "megatron.bias_gelu_fused",
            vec![Frame::cpp("megatron::fused_kernels")],
            KernelChoice::new("bias_gelu_fused", ComputeUnit::Sfu),
        ),
    );
    d
}

/// Default per-system environment.
pub fn default_env(sys: super::SystemId) -> Env {
    match sys {
        // vLLM & friends ship with TF32 on
        super::SystemId::MiniVllm | super::SystemId::MiniSglang | super::SystemId::MiniMegatron => {
            Env::new().with("allow_tf32", "true").with("kv_cache_aligned", "true")
        }
        // HF inherits torch defaults: tf32 off in older versions
        super::SystemId::MiniHf => Env::new().with("allow_tf32", "true"),
        _ => Env::new(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::energy::DeviceSpec;
    use crate::exec::Executor;

    fn small_spec() -> LlmSpec {
        LlmSpec { batch: 2, seq: 8, d_model: 32, n_heads: 4, d_ff: 64, vocab: 64, layers: 1 }
    }

    fn run(params: &TransformerParams, opts: &LlmBuildOpts, disp: Dispatcher, env: Env) -> crate::exec::RunArtifacts {
        let prog = build_llm(params, opts);
        Executor::new(DeviceSpec::h200_sim(), disp, env).run(&prog)
    }

    #[test]
    fn hf_and_vllm_compute_same_function() {
        let mut rng = Prng::new(42);
        let params = TransformerParams::new(&mut rng, small_spec());
        let hf = run(&params, &LlmBuildOpts::hf(), hf_dispatcher(), default_env(super::super::SystemId::MiniHf));
        let vllm = run(&params, &LlmBuildOpts::vllm(), vllm_dispatcher(), default_env(super::super::SystemId::MiniVllm));
        let o1 = hf.output();
        let o2 = vllm.output();
        assert_eq!(o1.shape(), o2.shape());
        assert!(
            (o1.global_rel_diff(o2) as f64) < 0.01,
            "outputs diverge: {}",
            o1.max_rel_diff(o2)
        );
    }

    #[test]
    fn hf_consumes_more_energy_than_vllm() {
        // Fig 5b: HF is the least efficient serving stack
        let mut rng = Prng::new(43);
        let params = TransformerParams::new(&mut rng, LlmSpec::gpt2_sim());
        let hf = run(&params, &LlmBuildOpts::hf(), hf_dispatcher(), default_env(super::super::SystemId::MiniHf));
        let vllm = run(&params, &LlmBuildOpts::vllm(), vllm_dispatcher(), default_env(super::super::SystemId::MiniVllm));
        assert!(
            hf.total_energy_j > vllm.total_energy_j * 1.3,
            "hf {} vs vllm {}",
            hf.total_energy_j,
            vllm.total_energy_j
        );
    }

    #[test]
    fn sglang_and_megatron_run() {
        let mut rng = Prng::new(44);
        let params = TransformerParams::new(&mut rng, small_spec());
        let sg = run(&params, &LlmBuildOpts::sglang(), sglang_dispatcher(), default_env(super::super::SystemId::MiniSglang));
        let mg = run(&params, &LlmBuildOpts::megatron(), megatron_dispatcher(), default_env(super::super::SystemId::MiniMegatron));
        assert!(sg.total_energy_j > 0.0 && mg.total_energy_j > 0.0);
        // megatron's repeat_interleave appears in its kernel log
        assert!(mg.records.iter().any(|r| r.label.contains("repeat_interleave")));
    }

    #[test]
    fn gqa_fused_vs_materialised_same_values_less_energy() {
        let mut rng = Prng::new(45);
        let params = TransformerParams::new(&mut rng, small_spec());
        let bad = LlmBuildOpts::megatron(); // materialised repeat
        let good = LlmBuildOpts { gqa_fused: true, ..LlmBuildOpts::megatron() };
        let rb = run(&params, &bad, megatron_dispatcher(), default_env(super::super::SystemId::MiniMegatron));
        let rg = run(&params, &good, megatron_dispatcher(), default_env(super::super::SystemId::MiniMegatron));
        assert!((rb.output().global_rel_diff(rg.output()) as f64) < 0.01);
        assert!(rb.total_energy_j > rg.total_energy_j);
    }

    #[test]
    fn graph_sizes_scale_with_layers() {
        let mut rng = Prng::new(46);
        let p1 = TransformerParams::new(&mut rng, LlmSpec::llama_sim(2));
        let p2 = TransformerParams::new(&mut rng, LlmSpec::llama_sim(8));
        let g1 = build_llm(&p1, &LlmBuildOpts::vllm()).graph;
        let g2 = build_llm(&p2, &LlmBuildOpts::vllm()).graph;
        assert!(g2.len() > g1.len() * 3);
    }

    #[test]
    fn topk_variants_agree() {
        let mut rng = Prng::new(47);
        let params = TransformerParams::new(&mut rng, small_spec());
        let fused = LlmBuildOpts { topk: Some(TopkImpl::Fused), ..LlmBuildOpts::sglang() };
        let sorted = LlmBuildOpts { topk: Some(TopkImpl::SortSlice), ..LlmBuildOpts::sglang() };
        let rf = run(&params, &fused, sglang_dispatcher(), default_env(super::super::SystemId::MiniSglang));
        let rs = run(&params, &sorted, sglang_dispatcher(), default_env(super::super::SystemId::MiniSglang));
        assert_eq!(rf.output().shape(), rs.output().shape());
        assert!(rf.output().allclose(rs.output(), 1e-5, 1e-4));
        assert!(rs.total_energy_j > rf.total_energy_j);
    }
}
