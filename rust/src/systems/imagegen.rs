//! Mini image-generation systems: Stable-Diffusion-reference- and
//! Diffusers-flavoured UNet blocks (Fig 5d, cases c7/c8).
//!
//! Both build the same residual UNet block (conv → norm → SiLU → conv →
//! skip add → self-attention). The Diffusers variant round-trips the
//! skip connection through an unnecessary `concat`/`split` pair (case
//! c7, diffusers-12131); the SD-reference variant leaves `allow_tf32`
//! unset so its convolutions/matmuls run on CUDA cores (case c8,
//! sd-279 — fixed in release 1.10.1 for a 12.5 % end-to-end saving).

use crate::dispatch::{Block, Env, Frame, KernelChoice, Routine, Term, VarSource};
use crate::energy::ComputeUnit;
use crate::exec::{Dispatcher, Program};
use crate::graph::{Attrs, Graph, NodeId, OpKind};
use crate::tensor::Tensor;
use crate::util::Prng;

/// UNet block spec.
#[derive(Clone, Copy, Debug)]
pub struct UnetSpec {
    pub batch: usize,
    pub channels: usize,
    pub hw: usize,
}

impl UnetSpec {
    pub fn sd3_sim() -> UnetSpec {
        UnetSpec { batch: 2, channels: 64, hw: 24 }
    }
}

/// Shared UNet weights.
#[derive(Clone, Debug)]
pub struct UnetParams {
    pub spec: UnetSpec,
    pub x: Tensor,
    pub conv1_w: Tensor,
    pub conv2_w: Tensor,
    pub norm_g: Tensor,
    pub norm_b: Tensor,
    pub attn_qkv_w: Tensor,
    pub attn_out_w: Tensor,
}

impl UnetParams {
    pub fn new(rng: &mut Prng, spec: UnetSpec) -> UnetParams {
        let c = spec.channels;
        let scale = 1.0 / (c as f32).sqrt();
        let mk = |rng: &mut Prng, shape: &[usize]| {
            crate::tensor::ops::scale(&Tensor::randn(rng, shape), scale)
        };
        UnetParams {
            spec,
            x: Tensor::randn(rng, &[spec.batch, c, spec.hw, spec.hw]),
            conv1_w: mk(rng, &[c, c, 3, 3]),
            conv2_w: mk(rng, &[c, c, 3, 3]),
            norm_g: Tensor::full(&[c], 1.0),
            norm_b: Tensor::zeros(&[c]),
            attn_qkv_w: mk(rng, &[c, 3 * c]),
            attn_out_w: mk(rng, &[c, c]),
        }
    }
}

/// Build options for the two image-gen systems.
#[derive(Clone, Copy, Debug)]
pub struct UnetBuildOpts {
    /// Route the skip connection through concat+split (Diffusers, c7).
    pub concat_split_skip: bool,
    /// Dispatch prefix.
    pub prefix: &'static str,
}

impl UnetBuildOpts {
    pub fn sd() -> UnetBuildOpts {
        UnetBuildOpts { concat_split_skip: false, prefix: "sd" }
    }
    pub fn diffusers() -> UnetBuildOpts {
        UnetBuildOpts { concat_split_skip: true, prefix: "diffusers" }
    }
}

/// Build one UNet residual+attention block.
pub fn build_unet_block(params: &UnetParams, opts: &UnetBuildOpts) -> Program {
    let spec = params.spec;
    let (b, c, hw) = (spec.batch, spec.channels, spec.hw);
    let sys = opts.prefix;
    let mut g = Graph::new(&format!("{sys}-unet"));
    let mut feeds: Vec<(NodeId, Tensor)> = Vec::new();
    fn add_w(g: &mut Graph, feeds: &mut Vec<(NodeId, Tensor)>, name: &str, t: &Tensor) -> NodeId {
        let id = g.add(OpKind::Weight, &[], name);
        feeds.push((id, t.clone()));
        id
    }

    let xi = g.add(OpKind::Input, &[], "latent");
    feeds.push((xi, params.x.clone()));
    let w1 = add_w(&mut g, &mut feeds, "conv1_w", &params.conv1_w);
    let w2 = add_w(&mut g, &mut feeds, "conv2_w", &params.conv2_w);
    let ng = add_w(&mut g, &mut feeds, "norm_g", &params.norm_g);
    let nb = add_w(&mut g, &mut feeds, "norm_b", &params.norm_b);
    let qkv_w = add_w(&mut g, &mut feeds, "attn_qkv_w", &params.attn_qkv_w);
    let out_w = add_w(&mut g, &mut feeds, "attn_out_w", &params.attn_out_w);

    let mut conv = |g: &mut Graph, x: NodeId, w: NodeId, label: &str| {
        let mut at = Attrs::new();
        at.insert("pad".into(), "1".into());
        at.insert("dispatch".into(), "matmul".into()); // conv lowers through gemm dispatch
        at.insert("groups".into(), "1".into());
        g.add_attrs(OpKind::Conv2d, &[x, w], label, at)
    };

    // residual conv branch
    let c1 = conv(&mut g, xi, w1, &format!("{sys}.resnet.conv1"));
    let act = g.add(OpKind::Silu, &[c1], &format!("{sys}.resnet.silu"));
    let c2 = conv(&mut g, act, w2, &format!("{sys}.resnet.conv2"));

    // skip connection: direct add, or the wasteful concat+split round trip
    let skip_sum = if opts.concat_split_skip {
        let cat = g.add_attr1(OpKind::Concat, &[c2, xi], &format!("{sys}.skip.concat"), "dim", "1");
        let mut at = Attrs::new();
        at.insert("dim".into(), "1".into());
        at.insert("chunks".into(), "2".into());
        at.insert("index".into(), "0".into());
        let h = g.add_attrs(OpKind::SplitChunk, &[cat], &format!("{sys}.skip.split_h"), at);
        let mut at2 = Attrs::new();
        at2.insert("dim".into(), "1".into());
        at2.insert("chunks".into(), "2".into());
        at2.insert("index".into(), "1".into());
        let s = g.add_attrs(OpKind::SplitChunk, &[cat], &format!("{sys}.skip.split_skip"), at2);
        g.add(OpKind::Add, &[h, s], &format!("{sys}.skip.add"))
    } else {
        g.add(OpKind::Add, &[c2, xi], &format!("{sys}.skip.add"))
    };

    // spatial self-attention: [B,C,H,W] -> [B, HW, C]
    let mut at = Attrs::new();
    at.insert("shape".into(), format!("{b},{c},{}", hw * hw));
    let flat = g.add_attrs(OpKind::Reshape, &[skip_sum], &format!("{sys}.attn.flatten"), at);
    let seq = g.add_attr1(OpKind::Permute, &[flat], &format!("{sys}.attn.to_seq"), "perm", "0,2,1");
    let seq_c = g.add(OpKind::Contiguous, &[seq], &format!("{sys}.attn.seq_copy"));
    let norm = {
        let mut at = Attrs::new();
        at.insert("dispatch".into(), "torch.nn.functional.layer_norm".into());
        at.insert("input_contiguous".into(), "true".into());
        g.add_attrs(OpKind::LayerNorm, &[seq_c, ng, nb], &format!("{sys}.attn.groupnorm"), at)
    };
    let qkv = g.add_attr1(OpKind::MatMul, &[norm, qkv_w], &format!("{sys}.attn.qkv"), "dispatch", "matmul");
    let mut split = |g: &mut Graph, idx: usize, name: &str| {
        let mut at = Attrs::new();
        at.insert("dim".into(), "2".into());
        at.insert("chunks".into(), "3".into());
        at.insert("index".into(), idx.to_string());
        g.add_attrs(OpKind::SplitChunk, &[qkv], &format!("{sys}.attn.{name}"), at)
    };
    let q = split(&mut g, 0, "q");
    let k = split(&mut g, 1, "k");
    let v = split(&mut g, 2, "v");
    // single-head attention over [B, HW, C]: reshape to [B,1,HW,C]
    let mut r4 = |g: &mut Graph, t: NodeId, name: &str| {
        let mut at = Attrs::new();
        at.insert("shape".into(), format!("{b},1,{},{c}", hw * hw));
        g.add_attrs(OpKind::Reshape, &[t], &format!("{sys}.attn.{name}4"), at)
    };
    let q4 = r4(&mut g, q, "q");
    let k4 = r4(&mut g, k, "k");
    let v4 = r4(&mut g, v, "v");
    let mut at = Attrs::new();
    at.insert("dispatch".into(), format!("{sys}.attention"));
    let attn = g.add_attrs(OpKind::Attention, &[q4, k4, v4], &format!("{sys}.attn.sdpa"), at);
    let mut at = Attrs::new();
    at.insert("shape".into(), format!("{b},{},{c}", hw * hw));
    let attn3 = g.add_attrs(OpKind::Reshape, &[attn], &format!("{sys}.attn.out3"), at);
    let proj = g.add_attr1(OpKind::MatMul, &[attn3, out_w], &format!("{sys}.attn.out_proj"), "dispatch", "matmul");
    let out = g.add(OpKind::Add, &[proj, seq_c], &format!("{sys}.attn.residual"));

    g.add(OpKind::Output, &[out], "out");
    let mut p = Program::new(g);
    for (id, t) in feeds {
        p.feed(id, t);
    }
    p
}

/// Gemm routine with a genuine flag *interaction* (the joint-search
/// case, `case-c8-joint`): the TF32 tensor-core path only pays off
/// together with a channels-last layout. Alone, `allow_tf32` routes a
/// strided TF32 kernel whose gather cost makes it *slower* than the
/// fp32 SGEMM baseline (cheaper joules, blown time budget), and
/// `channels_last` alone just re-tiles the same CUDA-core SGEMM for
/// *more* energy at equal time — so every single-flag flip fails the
/// energy+time gate and only the joint assignment dominates.
pub fn joint_matmul_routine() -> Routine {
    let mut provenance = std::collections::BTreeMap::new();
    provenance.insert(
        "allow_tf32".to_string(),
        VarSource::ConfigFlag("torch.backends.cuda.matmul.allow_tf32".into()),
    );
    provenance.insert(
        "channels_last".to_string(),
        VarSource::ConfigFlag("torch.channels_last memory_format".into()),
    );
    let func = "at::cuda::blas::gemm";
    let cond = |var: &str, then_bb: usize, else_bb: usize| Block {
        func: func.to_string(),
        term: Term::CondBranch {
            var: var.to_string(),
            eq: "true".to_string(),
            then_bb,
            else_bb,
        },
    };
    let launch = |idx: usize| Block { func: func.to_string(), term: Term::Launch { idx } };
    Routine {
        api: "torch.matmul".to_string(),
        frames: vec![Frame::cpp("at::native::matmul"), Frame::cpp(func)],
        blocks: vec![
            cond("channels_last", 1, 2),
            cond("allow_tf32", 3, 4),
            cond("allow_tf32", 5, 6),
            launch(0),
            launch(1),
            launch(2),
            launch(3),
        ],
        choices: vec![
            // both flags: contiguous TF32 tensor-core gemm — strictly
            // less energy, strictly less time
            KernelChoice::new("ampere_tf32_s1688gemm_128x128_nhwc", ComputeUnit::TensorCore),
            // channels_last only: re-tiled fp32 SGEMM — same time,
            // more bytes moved, worse efficiency (rejected on energy)
            KernelChoice::new("ampere_sgemm_fp32_128x128_nhwc", ComputeUnit::CudaCore)
                .quality(0.95, 1.0, 1.1),
            // allow_tf32 only: strided TF32 gemm — the gather makes it
            // slower end-to-end than the fp32 baseline even though the
            // math is cheaper (rejected on time; 2.6 > cc/tc ratio)
            KernelChoice::new("ampere_tf32_s1688gemm_128x128_strided", ComputeUnit::TensorCore)
                .quality(0.85, 2.6, 1.4),
            // neither: the fp32 SGEMM baseline (the c8 bug)
            KernelChoice::new("ampere_sgemm_fp32_128x128", ComputeUnit::CudaCore),
        ],
        provenance,
    }
}

/// SD-reference dispatcher with the interaction-prone gemm: the
/// `case-c8-joint` builtin target where only the *joint* flip of
/// `allow_tf32` + `channels_last` saves energy.
pub fn sd_joint_dispatcher() -> Dispatcher {
    let mut d = sd_dispatcher();
    d.register("matmul", joint_matmul_routine());
    d
}

/// SD-reference dispatcher: torch kernels, `allow_tf32` comes from env.
pub fn sd_dispatcher() -> Dispatcher {
    let mut d = Dispatcher::new();
    d.register("matmul", super::torch_matmul_routine());
    d.register("torch.nn.functional.layer_norm", super::layernorm_routine());
    d.register("sd.attention", super::attention_routine("sd.cross_attention"));
    d
}

/// Diffusers dispatcher: same torch substrate.
pub fn diffusers_dispatcher() -> Dispatcher {
    let mut d = sd_dispatcher();
    d.register("diffusers.attention", super::attention_routine("diffusers.attn_processor"));
    d
}

/// Default env: Diffusers sets TF32 (post-fix); SD reference forgot it
/// (the c8 bug) — callers flip this for the fixed variant.
pub fn sd_env(tf32_enabled: bool) -> Env {
    if tf32_enabled {
        Env::new().with("allow_tf32", "true")
    } else {
        Env::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::energy::DeviceSpec;
    use crate::exec::Executor;

    fn run(p: &Program, d: Dispatcher, env: Env) -> crate::exec::RunArtifacts {
        Executor::new(DeviceSpec::h200_sim(), d, env).run(p)
    }

    #[test]
    fn sd_and_diffusers_agree_numerically() {
        let mut rng = Prng::new(1);
        let params = UnetParams::new(&mut rng, UnetSpec::sd3_sim());
        let sd = run(&build_unet_block(&params, &UnetBuildOpts::sd()), sd_dispatcher(), sd_env(true));
        let df = run(
            &build_unet_block(&params, &UnetBuildOpts::diffusers()),
            diffusers_dispatcher(),
            sd_env(true),
        );
        assert_eq!(sd.output().shape(), df.output().shape());
        assert!((sd.output().global_rel_diff(df.output()) as f64) < 0.01);
    }

    #[test]
    fn concat_split_skip_wastes_energy() {
        let mut rng = Prng::new(2);
        let params = UnetParams::new(&mut rng, UnetSpec::sd3_sim());
        let clean = run(&build_unet_block(&params, &UnetBuildOpts::sd()), sd_dispatcher(), sd_env(true));
        let waste = run(
            &build_unet_block(&params, &UnetBuildOpts::diffusers()),
            diffusers_dispatcher(),
            sd_env(true),
        );
        assert!(waste.total_energy_j > clean.total_energy_j);
        assert!(waste.records.iter().any(|r| r.label.contains("skip.concat")));
    }

    #[test]
    fn joint_routine_only_pays_off_with_both_flags() {
        let mut rng = Prng::new(4);
        let params = UnetParams::new(&mut rng, UnetSpec::sd3_sim());
        let prog = build_unet_block(&params, &UnetBuildOpts::sd());
        let base = run(&prog, sd_joint_dispatcher(), Env::new());
        let tf32 = run(&prog, sd_joint_dispatcher(), Env::new().with("allow_tf32", "true"));
        let layout = run(&prog, sd_joint_dispatcher(), Env::new().with("channels_last", "true"));
        let joint = run(
            &prog,
            sd_joint_dispatcher(),
            Env::new().with("allow_tf32", "true").with("channels_last", "true"),
        );
        // tf32 alone: cheaper joules but strictly slower (strided gather)
        assert!(tf32.gpu_time_us > base.gpu_time_us, "{} !> {}", tf32.gpu_time_us, base.gpu_time_us);
        // channels_last alone: same speed, strictly more energy
        assert!(
            layout.total_energy_j > base.total_energy_j,
            "{} !> {}",
            layout.total_energy_j,
            base.total_energy_j
        );
        // only the joint flip dominates the baseline on both axes
        assert!(joint.total_energy_j < base.total_energy_j);
        assert!(joint.gpu_time_us < base.gpu_time_us);
        assert!(joint.total_energy_j < tf32.total_energy_j.min(layout.total_energy_j));
    }

    #[test]
    fn tf32_off_costs_more_energy_same_values_within_1pct() {
        let mut rng = Prng::new(3);
        let params = UnetParams::new(&mut rng, UnetSpec::sd3_sim());
        let on = run(&build_unet_block(&params, &UnetBuildOpts::sd()), sd_dispatcher(), sd_env(true));
        let off = run(&build_unet_block(&params, &UnetBuildOpts::sd()), sd_dispatcher(), sd_env(false));
        assert!(off.total_energy_j > on.total_energy_j * 1.05,
            "tf32-off {} vs on {}", off.total_energy_j, on.total_energy_j);
        assert!((on.output().global_rel_diff(off.output()) as f64) < 0.01);
    }
}
