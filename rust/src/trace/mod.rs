//! Software-event tracing substrate (the CUPTI / libunwind /
//! `PyEval_SetProfile` stand-in, paper §5.1).
//!
//! The executor emits an [`Event`] per framework API call and per kernel
//! launch; correlation IDs link the CPU-side API record to the GPU-side
//! kernel record, and each API record carries a multi-layer call stack
//! (Python → C++ dispatch → CUDA runtime). Diagnosis (Algorithm 2) works
//! entirely off these records. A configurable per-event overhead models
//! the tracing cost measured in Fig 10.

use std::collections::BTreeMap;

/// Language layer of a stack frame (the paper's cross-layer stacks).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Layer {
    Python,
    Cpp,
    Cuda,
}

/// One stack frame: a function at a layer.
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct Frame {
    pub layer: Layer,
    pub func: String,
}

impl Frame {
    pub fn py(f: &str) -> Frame {
        Frame { layer: Layer::Python, func: f.to_string() }
    }
    pub fn cpp(f: &str) -> Frame {
        Frame { layer: Layer::Cpp, func: f.to_string() }
    }
    pub fn cuda(f: &str) -> Frame {
        Frame { layer: Layer::Cuda, func: f.to_string() }
    }
}

/// Call path from application entry down to the kernel launch site.
pub type CallPath = Vec<Frame>;

/// Kind of traced event.
#[derive(Clone, Debug, PartialEq)]
pub enum EventKind {
    /// Framework API call intercepted on the CPU side.
    ApiCall { api: String },
    /// GPU kernel execution (CUPTI Activity record stand-in).
    KernelLaunch { kernel: String, energy_j: f64 },
    /// Host↔device or device↔device copy.
    MemCopy { bytes: f64 },
}

/// A traced event with timing and correlation.
#[derive(Clone, Debug)]
pub struct Event {
    pub id: usize,
    /// Correlation ID linking an ApiCall to the kernels it launched.
    pub corr_id: u64,
    pub t_start_us: f64,
    pub t_end_us: f64,
    pub kind: EventKind,
    /// Captured call stack (populated for ApiCall events).
    pub stack: CallPath,
    /// Graph node that produced the event, if any.
    pub node: Option<usize>,
}

/// Append-only trace buffer.
#[derive(Clone, Debug, Default)]
pub struct TraceBuffer {
    pub events: Vec<Event>,
    next_corr: u64,
    /// Per-event CPU overhead charged when tracing is enabled, µs
    /// (interception + stack capture). Drives Fig 10.
    pub overhead_per_event_us: f64,
    /// Accumulated overhead, µs.
    pub total_overhead_us: f64,
    /// Correlation ID → index of the **first** ApiCall event recorded
    /// with it, maintained on `record` so `api_for_corr` and
    /// `kernel_call_paths` are O(log n) lookups instead of linear scans
    /// / per-call map rebuilds.
    api_index: BTreeMap<u64, usize>,
}

impl TraceBuffer {
    pub fn new(overhead_per_event_us: f64) -> TraceBuffer {
        TraceBuffer { overhead_per_event_us, ..Default::default() }
    }

    /// Allocate a fresh correlation ID.
    pub fn next_corr_id(&mut self) -> u64 {
        self.next_corr += 1;
        self.next_corr
    }

    /// Record an event; returns its index.
    pub fn record(
        &mut self,
        corr_id: u64,
        t_start_us: f64,
        t_end_us: f64,
        kind: EventKind,
        stack: CallPath,
        node: Option<usize>,
    ) -> usize {
        let id = self.events.len();
        if matches!(kind, EventKind::ApiCall { .. }) {
            self.api_index.entry(corr_id).or_insert(id);
        }
        self.events.push(Event { id, corr_id, t_start_us, t_end_us, kind, stack, node });
        self.total_overhead_us += self.overhead_per_event_us;
        id
    }

    /// All kernel-launch events.
    pub fn kernels(&self) -> impl Iterator<Item = &Event> {
        self.events
            .iter()
            .filter(|e| matches!(e.kind, EventKind::KernelLaunch { .. }))
    }

    /// The API-call event for a correlation ID, if any (the first one
    /// recorded with it). Indexed: O(log n), not a linear scan.
    pub fn api_for_corr(&self, corr: u64) -> Option<&Event> {
        self.api_index.get(&corr).map(|&i| &self.events[i])
    }

    /// Unified view: for every kernel, the call path of the API call that
    /// launched it (CPU↔GPU correlation, paper §5.1). Returns
    /// `(kernel_name, call_path, node)` tuples in launch order. Uses the
    /// maintained corr-id index instead of rebuilding a map per call.
    pub fn kernel_call_paths(&self) -> Vec<(String, CallPath, Option<usize>)> {
        self.kernels()
            .map(|k| {
                let kernel = match &k.kind {
                    EventKind::KernelLaunch { kernel, .. } => kernel.clone(),
                    _ => unreachable!(),
                };
                let mut path = self
                    .api_for_corr(k.corr_id)
                    .map(|api| api.stack.clone())
                    .unwrap_or_default();
                // the kernel itself is the leaf of the path
                path.push(Frame::cuda(&kernel));
                (kernel, path, k.node)
            })
            .collect()
    }

    /// Total energy attributed to kernels (for overhead-free accounting).
    pub fn kernel_energy_j(&self) -> f64 {
        self.kernels()
            .map(|e| match e.kind {
                EventKind::KernelLaunch { energy_j, .. } => energy_j,
                _ => 0.0,
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn correlation_links_api_to_kernel() {
        let mut tb = TraceBuffer::new(0.1);
        let c = tb.next_corr_id();
        tb.record(
            c,
            0.0,
            1.0,
            EventKind::ApiCall { api: "torch.matmul".into() },
            vec![Frame::py("model.forward"), Frame::cpp("at::matmul")],
            Some(3),
        );
        tb.record(
            c,
            1.0,
            5.0,
            EventKind::KernelLaunch { kernel: "sgemm_128".into(), energy_j: 0.5 },
            vec![],
            Some(3),
        );
        let paths = tb.kernel_call_paths();
        assert_eq!(paths.len(), 1);
        let (k, p, node) = &paths[0];
        assert_eq!(k, "sgemm_128");
        assert_eq!(p.len(), 3); // py + cpp + cuda leaf
        assert_eq!(p[2], Frame::cuda("sgemm_128"));
        assert_eq!(*node, Some(3));
    }

    #[test]
    fn overhead_accumulates() {
        let mut tb = TraceBuffer::new(0.5);
        for i in 0..10 {
            let c = tb.next_corr_id();
            tb.record(c, i as f64, i as f64 + 1.0, EventKind::MemCopy { bytes: 4.0 }, vec![], None);
        }
        assert!((tb.total_overhead_us - 5.0).abs() < 1e-12);
    }

    #[test]
    fn kernel_energy_sums() {
        let mut tb = TraceBuffer::new(0.0);
        for e in [0.25, 0.75] {
            let c = tb.next_corr_id();
            tb.record(c, 0.0, 1.0, EventKind::KernelLaunch { kernel: "k".into(), energy_j: e }, vec![], None);
        }
        assert!((tb.kernel_energy_j() - 1.0).abs() < 1e-12);
    }

    /// The maintained corr-id index must agree with the old linear scan
    /// on a buffer mixing api calls, kernels, copies, orphan kernels,
    /// and duplicate ApiCall corr-ids (first recorded wins).
    #[test]
    fn indexed_api_lookup_agrees_with_scan() {
        let mut tb = TraceBuffer::new(0.0);
        for i in 0..60u64 {
            let c = tb.next_corr_id();
            match i % 4 {
                0 => {
                    tb.record(c, 0.0, 1.0, EventKind::ApiCall { api: format!("api{i}") }, vec![Frame::py("f")], None);
                    tb.record(c, 1.0, 2.0, EventKind::KernelLaunch { kernel: format!("k{i}"), energy_j: 0.1 }, vec![], None);
                }
                1 => {
                    // duplicate ApiCall on the same corr: first must win
                    tb.record(c, 0.0, 1.0, EventKind::ApiCall { api: format!("first{i}") }, vec![], None);
                    tb.record(c, 1.0, 2.0, EventKind::ApiCall { api: format!("second{i}") }, vec![], None);
                }
                2 => {
                    // orphan kernel: no api record at all
                    tb.record(c, 0.0, 1.0, EventKind::KernelLaunch { kernel: format!("orphan{i}"), energy_j: 0.0 }, vec![], None);
                }
                _ => {
                    tb.record(c, 0.0, 1.0, EventKind::MemCopy { bytes: 8.0 }, vec![], None);
                }
            }
        }
        for corr in 0..=61u64 {
            let scanned = tb
                .events
                .iter()
                .find(|e| e.corr_id == corr && matches!(e.kind, EventKind::ApiCall { .. }))
                .map(|e| e.id);
            assert_eq!(tb.api_for_corr(corr).map(|e| e.id), scanned, "corr {corr}");
        }
    }

    #[test]
    fn corr_ids_unique() {
        let mut tb = TraceBuffer::new(0.0);
        let a = tb.next_corr_id();
        let b = tb.next_corr_id();
        assert_ne!(a, b);
    }

    #[test]
    fn kernel_without_api_still_has_leaf_path() {
        let mut tb = TraceBuffer::new(0.0);
        let c = tb.next_corr_id();
        tb.record(c, 0.0, 1.0, EventKind::KernelLaunch { kernel: "orphan".into(), energy_j: 0.0 }, vec![], None);
        let paths = tb.kernel_call_paths();
        assert_eq!(paths[0].1.len(), 1);
    }
}
