//! Rotation-aware tailing of a live snapshot directory.
//!
//! [`Follower`] polls a directory a [`super::SnapshotSink`] (or a whole
//! fleet of them) is still writing, decoding snapshots as they land:
//!
//! - **Resume by byte offset.** Each file is re-read from the byte
//!   after the last complete line consumed, so a poll costs O(new
//!   data), not O(file).
//! - **Torn tails are "retry", not damage.** A trailing fragment with
//!   no newline is a writer mid-`write_all`: the fragment is left in
//!   place and re-examined next poll ([`Follower::torn_retries`]
//!   counts the waits). Post-hoc consumers keep their stricter
//!   [`super::DirScan`] torn accounting.
//! - **Re-anchor, never error, on rotation races.** A file present in
//!   the listing but `NotFound` at open — or dropped from the listing
//!   entirely — was rotated away by the writer's byte budget. The
//!   follower forgets its cursor and keeps going
//!   ([`Follower::reanchors`]); snapshots already consumed from the
//!   dropped file are retained, so a long-lived follower can know
//!   *more* than a post-hoc replay of the pruned directory.
//! - **Canonical replay order on demand.** Snapshots are collected
//!   tagged with `(file_order_key, line index)`; [`Follower::into_replay`]
//!   reorders them into exactly the order [`super::load_dir`] produces,
//!   which is what makes `magneton replay --follow` of a completed run
//!   bit-identical to a post-hoc `magneton replay` (asserted in
//!   `tests/follow.rs`).
//!
//! The poll loop itself (sleep cadence, idle cutoff) belongs to the
//! caller — `magneton replay --follow` and `magneton dash --follow`
//! drive one [`Follower`] each; tests drive it in a tight loop with an
//! injected reader factory to reproduce the races deterministically.

use std::collections::BTreeMap;
use std::fs::File;
use std::io::Read as _;
use std::path::{Path, PathBuf};

use super::{file_order_key, snapshot_files, Replay, Snapshot};
use crate::Result;

/// Consumption state of one tailed file.
#[derive(Clone, Copy, Debug, Default)]
struct FileCursor {
    /// Bytes consumed: the offset just past the last complete line.
    offset: u64,
    /// Complete lines consumed (the absolute line index of the next
    /// line, which tags collected snapshots for canonical reordering).
    lines: usize,
}

/// One collected snapshot, tagged for canonical replay order.
type Tagged = ((String, u64, String), usize, Snapshot);

/// Incremental, rotation-aware reader of a live snapshot directory.
///
/// Create with [`Follower::new`], call [`Follower::poll`] on whatever
/// cadence suits (each call returns the snapshots that became complete
/// since the last), and finish with [`Follower::into_replay`] for the
/// canonical post-hoc view.
pub struct Follower {
    dir: PathBuf,
    cursors: BTreeMap<PathBuf, FileCursor>,
    collected: Vec<Tagged>,
    /// Times the follower forgot a cursor because its file rotated out
    /// from under it (dropped from the listing, `NotFound` at open, or
    /// recreated shorter than the consumed offset).
    pub reanchors: usize,
    /// Files listed but gone before they were ever opened (no cursor
    /// yet — nothing was lost, the race just counted).
    pub vanished: usize,
    /// Polls that found a trailing fragment still missing its newline
    /// and left it for the next poll.
    pub torn_retries: usize,
    /// Complete lines that failed to decode as snapshots and were
    /// skipped. A live tailer is lenient where [`super::load_dir`] is
    /// strict: one corrupt line must not blind the dashboard to every
    /// line after it.
    pub decode_errors: usize,
}

impl Follower {
    /// Tail `dir`. The directory does not have to exist yet — polls
    /// before the writer's first rotation simply return nothing.
    pub fn new(dir: impl Into<PathBuf>) -> Follower {
        Follower {
            dir: dir.into(),
            cursors: BTreeMap::new(),
            collected: Vec::new(),
            reanchors: 0,
            vanished: 0,
            torn_retries: 0,
            decode_errors: 0,
        }
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Snapshots collected so far.
    pub fn collected(&self) -> usize {
        self.collected.len()
    }

    /// One incremental pass over the directory: returns the snapshots
    /// whose lines became complete since the last poll, in arrival
    /// (file listing, then line) order.
    pub fn poll(&mut self) -> Result<Vec<Snapshot>> {
        self.poll_with(File::open)
    }

    /// [`Follower::poll`] with an injectable reader factory (the same
    /// pattern as [`super::scan_dir_with`]), so tests can inject the
    /// listing/open rotation race deterministically.
    pub fn poll_with<R, F>(&mut self, mut open: F) -> Result<Vec<Snapshot>>
    where
        R: std::io::Read,
        F: FnMut(&Path) -> std::io::Result<R>,
    {
        if !self.dir.exists() {
            return Ok(Vec::new());
        }
        let paths = match snapshot_files(&self.dir) {
            Ok(p) => p,
            // the directory itself can vanish between the check and
            // the listing (a whole session pruned); treat as empty
            Err(_) if !self.dir.exists() => return Ok(Vec::new()),
            Err(e) => return Err(e),
        };

        // files the budget rotated away since the last poll: forget
        // their cursors (their collected snapshots are retained)
        let gone: Vec<PathBuf> =
            self.cursors.keys().filter(|p| !paths.contains(*p)).cloned().collect();
        for p in gone {
            self.cursors.remove(&p);
            self.reanchors += 1;
        }

        let mut fresh = Vec::new();
        for path in &paths {
            let bytes = {
                let mut read_all = || -> std::io::Result<Vec<u8>> {
                    let mut r = open(path)?;
                    let mut bytes = Vec::new();
                    r.read_to_end(&mut bytes)?;
                    Ok(bytes)
                };
                match read_all() {
                    Ok(b) => b,
                    Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                        // listed, then rotated away before the open
                        if self.cursors.remove(path).is_some() {
                            self.reanchors += 1;
                        } else {
                            self.vanished += 1;
                        }
                        continue;
                    }
                    Err(e) => {
                        return Err(crate::Error::msg(format!(
                            "follow {}: {e}",
                            path.display()
                        )))
                    }
                }
            };
            let key = file_order_key(path);
            let cur = self.cursors.entry(path.clone()).or_default();
            if (bytes.len() as u64) < cur.offset {
                // shorter than what we consumed: the file was replaced
                // under the same name — restart it, discarding what the
                // vanished incarnation contributed
                *cur = FileCursor::default();
                self.reanchors += 1;
                self.collected.retain(|(k, _, _)| *k != key);
            }
            let tail = &bytes[cur.offset as usize..];
            let Some(nl) = tail.iter().rposition(|&b| b == b'\n') else {
                if !tail.is_empty() {
                    // writer mid-append: leave the fragment for later
                    self.torn_retries += 1;
                }
                continue;
            };
            let complete = &tail[..=nl];
            // lossy conversion: a torn multi-byte char can only sit in
            // the fragment we already excluded
            let text = String::from_utf8_lossy(complete);
            for line in text.lines() {
                let idx = cur.lines;
                cur.lines += 1;
                if line.trim().is_empty() {
                    continue;
                }
                match Snapshot::parse_line(line) {
                    Ok(snap) => {
                        self.collected.push((key.clone(), idx, snap.clone()));
                        fresh.push(snap);
                    }
                    Err(_) => self.decode_errors += 1,
                }
            }
            cur.offset += complete.len() as u64;
        }
        Ok(fresh)
    }

    /// Everything collected so far, reordered into canonical replay
    /// order — per-sink rotation series via [`file_order_key`], line
    /// order within each file; exactly the order [`super::load_dir`]
    /// yields for the same directory.
    pub fn ordered_snapshots(&self) -> Vec<Snapshot> {
        let mut tagged: Vec<&Tagged> = self.collected.iter().collect();
        tagged.sort_by(|a, b| a.0.cmp(&b.0).then_with(|| a.1.cmp(&b.1)));
        tagged.into_iter().map(|(_, _, s)| s.clone()).collect()
    }

    /// Consume the follower into the same [`Replay`] a post-hoc
    /// [`Replay::load`] of the (completed) directory would build.
    pub fn into_replay(self) -> Replay {
        let mut tagged = self.collected;
        tagged.sort_by(|a, b| a.0.cmp(&b.0).then_with(|| a.1.cmp(&b.1)));
        Replay::from_snapshots(tagged.into_iter().map(|(_, _, s)| s).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::super::{
        load_dir, ResyncEvent, SinkConfig, Snapshot, SnapshotSink,
    };
    use super::*;
    use std::fs;

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("magneton-follow-{tag}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        dir
    }

    fn resync(i: usize) -> Snapshot {
        Snapshot::Resync {
            pair: "p".into(),
            event: ResyncEvent { at_ops: i, skipped_a: 0, skipped_b: 1 },
        }
    }

    #[test]
    fn polling_a_nonexistent_directory_is_empty_not_an_error() {
        let mut f = Follower::new(tmp_dir("nodir"));
        assert!(f.poll().unwrap().is_empty());
        assert_eq!((f.reanchors, f.vanished, f.torn_retries), (0, 0, 0));
    }

    #[test]
    fn incremental_polls_resume_by_offset_and_match_a_posthoc_load() {
        let dir = tmp_dir("resume");
        let cfg = SinkConfig { max_snapshot_bytes: 0, rotate_bytes: 200 };
        let mut sink = SnapshotSink::new(&dir, "p", cfg).unwrap();
        let mut follower = Follower::new(&dir);
        let mut live = Vec::new();
        for i in 0..12 {
            sink.append(&resync(i)).unwrap();
            if i % 3 == 0 {
                live.extend(follower.poll().unwrap());
            }
        }
        live.extend(follower.poll().unwrap());
        assert!(sink.retained_files() >= 3, "the test must cross rotations");
        let posthoc: Vec<String> =
            load_dir(&dir).unwrap().iter().map(Snapshot::to_line).collect();
        let followed: Vec<String> =
            follower.ordered_snapshots().iter().map(Snapshot::to_line).collect();
        assert_eq!(followed, posthoc, "follow must be bit-identical to load_dir");
        assert_eq!(live.len(), posthoc.len(), "every line surfaced exactly once");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn torn_fragment_is_retried_then_consumed_when_completed() {
        use std::io::Write as _;
        let dir = tmp_dir("torn");
        let mut sink = SnapshotSink::new(&dir, "p", SinkConfig::default()).unwrap();
        sink.append(&resync(0)).unwrap();
        let mut follower = Follower::new(&dir);
        assert_eq!(follower.poll().unwrap().len(), 1);
        // fault injection: half a line, as an interrupted write_all
        let line = resync(1).to_line();
        let (half, rest) = line.split_at(line.len() / 2);
        let path = dir.join("p-000000.ndjson");
        let mut f =
            fs::OpenOptions::new().append(true).open(&path).unwrap();
        f.write_all(half.as_bytes()).unwrap();
        assert!(follower.poll().unwrap().is_empty(), "fragment must not decode");
        assert_eq!(follower.torn_retries, 1);
        assert_eq!(follower.decode_errors, 0, "a retry is not an error");
        f.write_all(rest.as_bytes()).unwrap();
        f.write_all(b"\n").unwrap();
        let got = follower.poll().unwrap();
        assert_eq!(got.len(), 1, "the completed line decodes on the next poll");
        assert_eq!(got[0].to_line(), line);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn file_dropped_between_listing_and_open_reanchors_without_loss() {
        let dir = tmp_dir("race");
        let cfg = SinkConfig { max_snapshot_bytes: 0, rotate_bytes: 150 };
        let mut sink = SnapshotSink::new(&dir, "p", cfg).unwrap();
        for i in 0..8 {
            sink.append(&resync(i)).unwrap();
        }
        let mut follower = Follower::new(&dir);
        follower.poll().unwrap();
        let before = follower.collected();
        assert!(before > 0);
        // the injected race: the oldest file is deleted between the
        // listing (which saw it) and the open
        let victim = dir.join("p-000000.ndjson");
        let fresh = follower
            .poll_with(|p: &Path| {
                if p == victim && p.exists() {
                    fs::remove_file(p)?;
                }
                fs::File::open(p)
            })
            .unwrap();
        assert!(fresh.is_empty(), "no new data in this poll");
        assert_eq!(follower.reanchors, 1, "the raced file re-anchored");
        assert_eq!(
            follower.collected(),
            before,
            "snapshots consumed before the drop are retained"
        );
        // the next plain poll no longer sees the file and stays clean
        follower.poll().unwrap();
        assert_eq!(follower.reanchors, 1);
        let _ = fs::remove_dir_all(&dir);
    }
}
