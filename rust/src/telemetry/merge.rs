//! Shard merging — the coordinator half of multi-process ingest.
//!
//! A production fleet is M hosts × N pairs, not one process holding
//! one giant ring. Each producer runs `magneton stream --shard k/M
//! --shard-id <name>` over its slice of the pair fleet and persists an
//! ordinary snapshot series whose [`SessionHeader`] carries the shard
//! identity (`shard_index`/`shard_count` plus the fleet-level
//! `session_id`). This module is the merge coordinator: it loads the
//! shard directories back by header, refuses mixed sessions with
//! reasoned diagnostics (the [`crate::telemetry::session`] discipline),
//! and combines the shards into one logical session that is
//! **bit-for-bit identical** to what a single unsharded process would
//! have persisted.
//!
//! The bit-identity contract rests on three properties:
//!
//! * **Partitioning** — every pair lives wholly inside one shard, and
//!   each pair's snapshots are already deterministic in isolation
//!   (name-hashed arrival RNGs make per-pair results independent of
//!   worker count and submission order). Merging therefore never adds
//!   floats: per-pair windows, summaries, and ledgers are copied
//!   verbatim.
//! * **Canonical interleave** — the combined file series is ordered by
//!   [`file_order_key`], the same total order `magneton replay` applies
//!   to a single directory. Producers stamp *fleet-global* pair indices
//!   into their sink prefixes (`pair-<global idx>-<name>`), so the
//!   interleaved order reproduces the unsharded directory's file order
//!   exactly, for any shard count and any merge order.
//! * **Canonical folds** — every aggregate that sums floats across
//!   pairs (fleet ranking totals, the combined per-label ledger) is
//!   folded in one fixed order (rank order, pair-name order). Float
//!   addition is not bitwise-associative, so associativity is obtained
//!   by *keeping per-pair granularity until a single canonical fold*,
//!   never by folding shard-partials in arrival order.
//!
//! Per-shard fleet artifacts (`Fleet` rankings, `Divergence` events)
//! are views over a partial fleet; the merge discards them and
//! recomputes both fleet-wide — re-running
//! [`correlate_divergences`] over the union of resync logs, which can
//! coalesce simultaneous divergences that no single shard had enough
//! pairs to see (the re-correlation caveat in DESIGN.md).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::coordinator::fleet::{correlate_divergences, FleetDivergence, StreamFleetEntry};
use crate::stream::LabelLedger;
use crate::telemetry::{
    file_order_key, scan_dir, RankEntry, Replay, SessionHeader, SinkConfig, Snapshot,
    SnapshotSink,
};
use crate::{Error, Result};

/// Knobs of a merge run.
#[derive(Clone, Debug)]
pub struct MergeConfig {
    /// Correlation window (matched-op positions) for the fleet-wide
    /// [`correlate_divergences`] re-run. To reproduce a stream run's
    /// own correlation bit-for-bit, pass the run's effective window
    /// (its `--window` unless it set `correlate_window_ops`).
    pub correlate_window_ops: usize,
    /// Minimum distinct pairs per coalesced divergence.
    pub correlate_min: usize,
    /// Accept an incomplete shard set (holes in `0..shard_count`).
    /// Attribution for the present shards stays exact; fleet totals
    /// are lower bounds.
    pub allow_partial: bool,
}

impl Default for MergeConfig {
    fn default() -> MergeConfig {
        MergeConfig { correlate_window_ops: 256, correlate_min: 2, allow_partial: false }
    }
}

/// One shard directory as the merge saw it — identity plus damage
/// counters, for the operator-facing inventory.
#[derive(Clone, Debug)]
pub struct ShardInfo {
    pub dir: PathBuf,
    pub shard_id: String,
    pub shard_index: usize,
    pub shard_count: usize,
    /// Snapshot files scanned.
    pub files: usize,
    /// Snapshots loaded (complete lines only).
    pub snapshots: usize,
    /// Pair scopes (session headers) the shard persisted.
    pub pairs: usize,
    /// Files ending in a torn trailing fragment (producer killed
    /// mid-append; the fragment is skipped, never fatal). Merge runs
    /// post-hoc — the producers are presumed dead — so final and
    /// interior tears ([`crate::telemetry::DirScan`]) both count.
    pub torn_fragments: usize,
    /// Interior holes in rotation-index series (a file lost from the
    /// *middle* of a sink's series — rotation only drops oldest files,
    /// so interior holes are damage).
    pub missing_rotations: usize,
    /// Files listed but gone by the time they were opened (a live
    /// writer's budget rotated them away mid-scan) — skipped and
    /// counted, never fatal.
    pub vanished: usize,
}

/// The merged logical session: a [`Replay`] equivalent to loading the
/// unsharded directory, plus the recomputed fleet-wide artifacts and
/// the shard inventory.
pub struct MergedSession {
    pub session_id: String,
    pub deploy_tag: String,
    /// Shards in `shard_index` order, whatever order they were given.
    pub shards: Vec<ShardInfo>,
    /// The merged replay: interleaved windows/resyncs/summaries/ledgers,
    /// normalized (unsharded) session headers, and the recomputed
    /// ranking + divergences — shaped exactly like `Replay::load` of a
    /// single-process directory.
    pub replay: Replay,
    /// Fleet entries (latest summary per pair), ranked most-wasteful
    /// first under the exact `StreamFleet::run` comparator.
    pub entries: Vec<StreamFleetEntry>,
    /// The recomputed fleet ranking (mirrors `entries`).
    pub ranking: Vec<RankEntry>,
    /// Fleet-wide divergences re-correlated over the union of the
    /// shards' resync logs.
    pub divergences: Vec<FleetDivergence>,
    /// Combined per-label ledger across all pairs, folded in canonical
    /// (pair-name, then label) order — merge-order invariant.
    pub fleet_ledger: Vec<LabelLedger>,
    /// Waste and op totals summed in rank order (the same fold
    /// `StreamFleet::run` performs).
    pub total_wasted_j: f64,
    pub total_ops: usize,
    /// Damage totals across shards.
    pub torn_fragments: usize,
    pub missing_rotations: usize,
    pub vanished: usize,
    /// Per-sink-prefix series (normalized header + data snapshots) in
    /// canonical file order, for [`MergedSession::persist`].
    series: Vec<(String, Option<SessionHeader>, Vec<Snapshot>)>,
}

/// One scanned shard awaiting the cross-shard checks.
struct ScannedShard {
    dir: PathBuf,
    scan: crate::telemetry::DirScan,
    headers: Vec<SessionHeader>,
}

fn shard_label(h: &SessionHeader) -> String {
    if h.shard_id.is_empty() {
        format!("shard {}/{}", h.shard_index + 1, h.shard_count)
    } else {
        format!("shard `{}` ({}/{})", h.shard_id, h.shard_index + 1, h.shard_count)
    }
}

/// Scan one shard directory and validate it in isolation: it must carry
/// session headers, agree with itself on the session identity, and
/// claim exactly one shard identity.
fn scan_shard(dir: &Path) -> Result<ScannedShard> {
    let scan = scan_dir(dir)?;
    let mut headers: Vec<SessionHeader> = Vec::new();
    for f in &scan.files {
        for s in &f.snapshots {
            if let Snapshot::Session { header } = s {
                if !headers.contains(header) {
                    headers.push(header.clone());
                }
            }
        }
    }
    if headers.is_empty() {
        return Err(Error::msg(format!(
            "{}: no session header found — merge loads shards by header; re-run the producer \
             with `--snapshot-dir` and `--session-id`",
            dir.display()
        )));
    }
    let first = headers[0].clone();
    let mut scopes: BTreeMap<&str, &SessionHeader> = BTreeMap::new();
    for h in &headers {
        if let Some(prev) = scopes.insert(h.scope.as_str(), h) {
            if *prev != *h {
                return Err(Error::msg(format!(
                    "{}: conflicting session headers for scope `{}` — the directory mixes more \
                     than one session (use a fresh directory per shard run)",
                    dir.display(),
                    h.scope
                )));
            }
        }
        if h.session_id != first.session_id || h.deploy_tag != first.deploy_tag {
            return Err(Error::msg(format!(
                "{}: headers disagree on the session identity (`{}` vs `{}`)",
                dir.display(),
                first.session_id,
                h.session_id
            )));
        }
        if h.shard_id != first.shard_id
            || h.shard_index != first.shard_index
            || h.shard_count != first.shard_count
        {
            return Err(Error::msg(format!(
                "{}: headers disagree on the shard identity ({} vs {}) — the directory mixes \
                 the output of more than one producer shard",
                dir.display(),
                shard_label(&first),
                shard_label(h)
            )));
        }
    }
    Ok(ScannedShard { dir: dir.to_path_buf(), scan, headers })
}

/// Load the shard directories, refuse anything that is not one
/// consistent partition of one logical session, and merge.
///
/// Refusals (each a reasoned diagnostic naming the offending
/// directories): missing headers, mixed `session_id`/`deploy_tag`,
/// mixed `config_digest` or arrival processes (windows persisted under
/// different configs are not position-comparable), mixed
/// `shard_count`, duplicate shard indices or non-empty shard ids (the
/// same shard given twice), pair scopes appearing in more than one
/// shard (not a partition), and — unless
/// [`MergeConfig::allow_partial`] — holes in the `0..shard_count`
/// index set.
pub fn merge_shards(dirs: &[PathBuf], cfg: &MergeConfig) -> Result<MergedSession> {
    if dirs.is_empty() {
        return Err(Error::msg("merge needs at least one shard directory"));
    }
    let mut shards: Vec<ScannedShard> = dirs
        .iter()
        .map(|d| scan_shard(d))
        .collect::<Result<_>>()?;
    // merge-order invariance starts here: whatever order the operator
    // listed the directories, everything below sees shard-index order
    shards.sort_by_key(|s| s.headers[0].shard_index);

    // ---- cross-shard refusals ------------------------------------------
    let anchor = shards[0].headers[0].clone();
    let mut scope_owner: BTreeMap<String, usize> = BTreeMap::new();
    for (i, s) in shards.iter().enumerate() {
        let h = &s.headers[0];
        if h.session_id != anchor.session_id || h.deploy_tag != anchor.deploy_tag {
            return Err(Error::msg(format!(
                "{} and {} are different sessions (`{}` [{}] vs `{}` [{}]) — merge combines \
                 shards of one logical session; use `magneton diff` to compare sessions",
                shards[0].dir.display(),
                s.dir.display(),
                anchor.session_id,
                anchor.deploy_tag,
                h.session_id,
                h.deploy_tag
            )));
        }
        if h.shard_count != anchor.shard_count {
            return Err(Error::msg(format!(
                "{} and {} disagree on the shard count ({} vs {}) — they come from different \
                 fleet partitions",
                shards[0].dir.display(),
                s.dir.display(),
                anchor.shard_count,
                h.shard_count
            )));
        }
        for hh in &s.headers {
            if hh.config_digest != anchor.config_digest {
                return Err(Error::msg(format!(
                    "{} was persisted under config digest {:016x} but {} under {:016x} — \
                     windows persisted under different stream/detect configs are not \
                     position-comparable, refusing to merge",
                    shards[0].dir.display(),
                    anchor.config_digest,
                    s.dir.display(),
                    hh.config_digest
                )));
            }
            if hh.arrival != anchor.arrival {
                return Err(Error::msg(format!(
                    "{} drove arrivals `{}` but {} drove `{}` — shards of one session share \
                     one arrival process",
                    shards[0].dir.display(),
                    anchor.arrival,
                    s.dir.display(),
                    hh.arrival
                )));
            }
            if let Some(&prev) = scope_owner.get(&hh.scope) {
                if prev != i {
                    return Err(Error::msg(format!(
                        "pair scope `{}` appears in both {} and {} — shards must partition \
                         the pair fleet (was a shard directory passed twice?)",
                        hh.scope,
                        shards[prev].dir.display(),
                        s.dir.display()
                    )));
                }
            }
            scope_owner.insert(hh.scope.clone(), i);
        }
    }
    for w in shards.windows(2) {
        let (a, b) = (&w[0].headers[0], &w[1].headers[0]);
        if a.shard_index == b.shard_index {
            return Err(Error::msg(format!(
                "{} and {} both claim shard index {} — the same shard was given twice",
                w[0].dir.display(),
                w[1].dir.display(),
                a.shard_index
            )));
        }
    }
    let mut ids: BTreeMap<&str, &Path> = BTreeMap::new();
    for s in &shards {
        let h = &s.headers[0];
        if h.shard_id.is_empty() {
            continue;
        }
        if let Some(prev) = ids.insert(h.shard_id.as_str(), &s.dir) {
            return Err(Error::msg(format!(
                "{} and {} both claim shard id `{}` — shard ids name producers uniquely",
                prev.display(),
                s.dir.display(),
                h.shard_id
            )));
        }
    }
    let present: Vec<usize> = shards.iter().map(|s| s.headers[0].shard_index).collect();
    let missing: Vec<usize> =
        (0..anchor.shard_count).filter(|i| !present.contains(i)).collect();
    if !missing.is_empty() && !cfg.allow_partial {
        return Err(Error::msg(format!(
            "incomplete shard set: {} of {} shards present, missing index(es) {:?} — pass \
             --partial-ok to merge anyway (totals become lower bounds)",
            present.len(),
            anchor.shard_count,
            missing
        )));
    }

    // ---- canonical interleave ------------------------------------------
    // All shards' files under one total order — the order a single
    // unsharded directory replays in. Pair-sink prefixes carry
    // fleet-global indices, so per-prefix keys are already distinct
    // across shards; the shard index only tiebreaks identical stems
    // (e.g. every shard's `fleet-000000`, whose snapshots are dropped
    // below anyway).
    let mut inventory = Vec::new();
    let mut files: Vec<((String, u64, String), usize, &crate::telemetry::FileScan)> = Vec::new();
    for (i, s) in shards.iter().enumerate() {
        let h = &s.headers[0];
        inventory.push(ShardInfo {
            dir: s.dir.clone(),
            shard_id: h.shard_id.clone(),
            shard_index: h.shard_index,
            shard_count: h.shard_count,
            files: s.scan.files.len(),
            snapshots: s.scan.files.iter().map(|f| f.snapshots.len()).sum(),
            pairs: s.headers.len(),
            torn_fragments: s.scan.torn_fragments(),
            missing_rotations: s.scan.missing_rotations,
            vanished: s.scan.vanished,
        });
        for f in &s.scan.files {
            files.push((file_order_key(&f.path), i, f));
        }
    }
    files.sort_by(|a, b| a.0.cmp(&b.0).then_with(|| a.1.cmp(&b.1)));

    // Per-shard fleet artifacts are views over a partial fleet —
    // dropped here, recomputed fleet-wide below. Session headers are
    // normalized back to the unsharded form: merged, the series once
    // again describes the whole logical session.
    let mut merged: Vec<Snapshot> = Vec::new();
    let mut series: Vec<(String, Option<SessionHeader>, Vec<Snapshot>)> = Vec::new();
    for (key, _, f) in &files {
        let prefix = key.0.clone();
        if !series.iter().any(|(p, _, _)| p == &prefix) {
            series.push((prefix.clone(), None, Vec::new()));
        }
        let slot = series.iter_mut().find(|(p, _, _)| p == &prefix).expect("pushed above");
        for snap in &f.snapshots {
            match snap {
                Snapshot::Fleet { .. } | Snapshot::Divergence { .. } => {}
                Snapshot::Session { header } => {
                    let norm = header.unsharded();
                    if slot.1.is_none() {
                        slot.1 = Some(norm.clone());
                    }
                    merged.push(Snapshot::Session { header: norm });
                }
                other => {
                    slot.2.push(other.clone());
                    merged.push(other.clone());
                }
            }
        }
    }
    let mut replay = Replay::from_snapshots(merged);

    // ---- fleet-wide recomputation --------------------------------------
    // Latest summary per pair, first-seen order, then the exact
    // `StreamFleet::run` ranking fold — so a replay of the merged
    // output verifies bit-for-bit against the per-pair summaries.
    let mut pair_names: Vec<String> = Vec::new();
    for (pair, _) in &replay.summaries {
        if !pair_names.iter().any(|p| p == pair) {
            pair_names.push(pair.clone());
        }
    }
    let mut entries: Vec<StreamFleetEntry> = pair_names
        .iter()
        .map(|name| StreamFleetEntry {
            name: name.clone(),
            summary: replay.summary_of(name).expect("name from summaries").clone(),
            snapshot_errors: 0,
        })
        .collect();
    entries.sort_by(|x, y| {
        y.summary.wasted_j.total_cmp(&x.summary.wasted_j).then_with(|| x.name.cmp(&y.name))
    });
    let total_wasted_j: f64 = entries.iter().map(|e| e.summary.wasted_j).sum();
    let total_ops: usize = entries.iter().map(|e| e.summary.ops).sum();
    let ranking: Vec<RankEntry> = entries
        .iter()
        .map(|e| RankEntry {
            name: e.name.clone(),
            wasted_j: e.summary.wasted_j,
            ops: e.summary.ops,
            windows: e.summary.windows,
            windows_flagged: e.summary.windows_flagged,
            resyncs: e.summary.resyncs,
            aligned: e.summary.aligned,
        })
        .collect();
    let divergences =
        correlate_divergences(&entries, cfg.correlate_window_ops, cfg.correlate_min);
    replay.rankings = vec![ranking.clone()];
    replay.divergences = divergences.clone();

    // combined per-label ledger: one canonical fold (pair-name order
    // outer, label order inner) — permutation-invariant by construction
    let mut ledger_pairs: Vec<String> = Vec::new();
    for (pair, _) in &replay.ledgers {
        if !ledger_pairs.iter().any(|p| p == pair) {
            ledger_pairs.push(pair.clone());
        }
    }
    ledger_pairs.sort();
    let mut fleet_ledger: BTreeMap<String, LabelLedger> = BTreeMap::new();
    for pair in &ledger_pairs {
        for e in replay.ledger_of(pair).unwrap_or(&[]) {
            fleet_ledger
                .entry(e.label.clone())
                .and_modify(|cell| cell.combine(e))
                .or_insert_with(|| e.clone());
        }
    }

    Ok(MergedSession {
        session_id: anchor.session_id,
        deploy_tag: anchor.deploy_tag,
        torn_fragments: inventory.iter().map(|s| s.torn_fragments).sum(),
        missing_rotations: inventory.iter().map(|s| s.missing_rotations).sum(),
        vanished: inventory.iter().map(|s| s.vanished).sum(),
        shards: inventory,
        replay,
        entries,
        ranking,
        divergences,
        fleet_ledger: fleet_ledger.into_values().collect(),
        total_wasted_j,
        total_ops,
        series,
    })
}

impl MergedSession {
    /// Persist the merged session into `out` as an ordinary snapshot
    /// directory: one sink per original pair prefix (normalized header
    /// first, then that pair's data snapshots in merged order) plus a
    /// `fleet` sink holding the recomputed divergences and ranking —
    /// the same layout an unsharded `StreamFleet` run writes, so
    /// `magneton replay` and `magneton diff` consume it unchanged.
    /// Returns the number of snapshots written.
    pub fn persist(&self, out: &Path) -> Result<usize> {
        // the merged directory is an archive, not a live ring: never
        // rotate, never drop
        let sink_cfg = SinkConfig { max_snapshot_bytes: 0, rotate_bytes: 0 };
        let mut written = 0usize;
        for (prefix, header, snaps) in &self.series {
            if header.is_none() && snaps.is_empty() {
                continue; // e.g. a shard's fleet series, fully dropped
            }
            let mut sink = SnapshotSink::new(out, prefix, sink_cfg.clone())?;
            if let Some(h) = header {
                sink.set_header(&Snapshot::Session { header: h.clone() })?;
                written += 1;
            }
            for s in snaps {
                sink.append(s)?;
            }
            written += snaps.len();
        }
        let mut fleet = SnapshotSink::new(out, "fleet", sink_cfg)?;
        for d in &self.divergences {
            fleet.append(&Snapshot::Divergence { event: d.clone() })?;
            written += 1;
        }
        fleet.append(&Snapshot::Fleet { ranking: self.ranking.clone() })?;
        Ok(written + 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fingerprint::WorkloadSig;
    use crate::stream::{ResyncEvent, StreamSummary};
    use std::fs;

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("magneton-telemetry-merge-{tag}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        dir
    }

    fn sig() -> WorkloadSig {
        let mut s = WorkloadSig::new();
        s.add("serve.proj", "matmul");
        s
    }

    fn summary(wasted: f64, resync_at: &[usize]) -> StreamSummary {
        StreamSummary {
            ops: 100,
            windows: 5,
            energy_a_j: 2.0,
            energy_b_j: 1.0,
            time_a_us: 1e5,
            time_b_us: 1e5,
            wasted_j: wasted,
            windows_flagged: 2,
            windows_quarantined: 0,
            top_labels: vec![("serve.proj".into(), wasted, 2)],
            aligned: resync_at.is_empty(),
            fingerprint_a: 7,
            fingerprint_b: 7,
            unpaired: 0,
            resyncs: resync_at.len(),
            resync_skipped: resync_at.len(),
            resync_log: resync_at
                .iter()
                .map(|&at| ResyncEvent { at_ops: at, skipped_a: 1, skipped_b: 0 })
                .collect(),
            content_mismatches: 0,
            reports_dropped: 0,
            peak_retained_segments: 8,
            peak_window_pairs: 5,
            peak_pending: 1,
        }
    }

    /// Write one shard dir holding `pairs`, each with a header, a
    /// summary, and a ledger line.
    fn write_shard(
        dir: &Path,
        session: &str,
        shard: (&str, usize, usize),
        pairs: &[(usize, &str, f64, &[usize])],
    ) {
        for &(global_idx, name, wasted, resyncs) in pairs {
            let prefix = format!("pair-{global_idx:03}-{name}");
            let mut sink = SnapshotSink::new(dir, &prefix, SinkConfig::default()).unwrap();
            let header = SessionHeader::new(session, "tag", name, &sig(), "steady", 0xc0ffee)
                .with_shard(shard.0, shard.1, shard.2);
            sink.set_header(&Snapshot::Session { header }).unwrap();
            sink.append(&Snapshot::Summary {
                pair: name.to_string(),
                summary: summary(wasted, resyncs),
            })
            .unwrap();
            sink.append(&Snapshot::Ledger {
                pair: name.to_string(),
                entries: vec![LabelLedger {
                    label: "serve.proj".into(),
                    ops: 100,
                    energy_a_j: 2.0,
                    energy_b_j: 1.0,
                    time_a_us: 1e5,
                    time_b_us: 1e5,
                }],
            })
            .unwrap();
        }
    }

    #[test]
    fn merge_refuses_mixed_sessions_and_duplicate_shards() {
        let base = tmp_dir("refuse");
        let s0 = base.join("s0");
        let s1 = base.join("s1");
        write_shard(&s0, "fleet-run", ("east", 0, 2), &[(0, "serving-0", 1.0, &[])]);
        write_shard(&s1, "OTHER-run", ("west", 1, 2), &[(1, "serving-1", 2.0, &[])]);
        let cfg = MergeConfig::default();
        let err = merge_shards(&[s0.clone(), s1.clone()], &cfg).unwrap_err();
        assert!(err.to_string().contains("different sessions"), "{err}");

        // duplicate shard id (a dir copied under a new name)
        let s1b = base.join("s1b");
        write_shard(&s1b, "fleet-run", ("east", 1, 2), &[(1, "serving-1", 2.0, &[])]);
        let err = merge_shards(&[s0.clone(), s1b], &cfg).unwrap_err();
        assert!(err.to_string().contains("shard id `east`"), "{err}");

        // the very same dir twice: duplicate index
        let err = merge_shards(&[s0.clone(), s0.clone()], &cfg).unwrap_err();
        assert!(err.to_string().contains("shard index 0"), "{err}");

        // missing shard refused without --partial-ok, accepted with it
        let err = merge_shards(&[s0.clone()], &cfg).unwrap_err();
        assert!(err.to_string().contains("incomplete shard set"), "{err}");
        let partial = MergeConfig { allow_partial: true, ..MergeConfig::default() };
        let m = merge_shards(&[s0], &partial).unwrap();
        assert_eq!(m.ranking.len(), 1);
        let _ = fs::remove_dir_all(&base);
    }

    /// The re-correlation caveat, made testable: two pairs on
    /// *different* shards resync at nearly the same op. Neither shard
    /// alone has `correlate_min` pairs, so no shard persisted a
    /// divergence — but the merged re-run coalesces them into one
    /// fleet-wide event.
    #[test]
    fn merge_recorrelates_cross_shard_divergences() {
        let base = tmp_dir("recorrelate");
        let s0 = base.join("s0");
        let s1 = base.join("s1");
        write_shard(&s0, "run", ("a", 0, 2), &[(0, "serving-0", 1.0, &[40])]);
        write_shard(&s1, "run", ("b", 1, 2), &[(1, "serving-1", 2.0, &[43])]);
        let cfg = MergeConfig { correlate_window_ops: 10, ..MergeConfig::default() };
        let m = merge_shards(&[s0, s1], &cfg).unwrap();
        assert_eq!(m.divergences.len(), 1, "cross-shard resyncs must coalesce");
        let d = &m.divergences[0];
        assert_eq!((d.at_ops_min, d.at_ops_max), (40, 43));
        assert_eq!(d.pairs.len(), 2);
        // ranking under the fleet comparator: serving-1 wastes more
        assert_eq!(m.ranking[0].name, "serving-1");
        assert_eq!(m.total_ops, 200);
        let _ = fs::remove_dir_all(&base);
    }

    /// Persisted merged output is an ordinary directory: replay loads
    /// it, the ranking verifies bit-for-bit, headers are normalized
    /// back to unsharded.
    #[test]
    fn persisted_merge_replays_and_verifies() {
        let base = tmp_dir("persist");
        let s0 = base.join("s0");
        let s1 = base.join("s1");
        write_shard(&s0, "run", ("a", 0, 2), &[(0, "serving-0", 1.0, &[])]);
        write_shard(&s1, "run", ("b", 1, 2), &[(1, "serving-1", 2.0, &[])]);
        let m = merge_shards(&[s0, s1], &MergeConfig::default()).unwrap();
        let out = base.join("merged");
        let written = m.persist(&out).unwrap();
        assert!(written >= 7, "headers + summaries + ledgers + fleet ranking");
        let r = Replay::load(&out).unwrap();
        assert_eq!(r.verify_ranking(), Ok(2));
        assert_eq!(r.sessions.len(), 2);
        assert!(r.sessions.iter().all(|h| !h.is_sharded()), "headers must be normalized");
        assert_eq!(r.rankings.len(), 1);
        assert_eq!(r.rankings[0][0].name, "serving-1");
        let _ = fs::remove_dir_all(&base);
    }
}
