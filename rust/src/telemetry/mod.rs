//! Durable, replayable telemetry for long-running audits.
//!
//! The streaming subsystem ([`crate::stream`]) keeps every retained
//! structure bounded, which means its findings are *transient*: once a
//! window report is drained and printed, the evidence is gone with the
//! process. For the operator-facing setting the paper targets —
//! serving fleets audited over days — the audit state must outlive the
//! process and stay inspectable after the fact. This module is that
//! layer:
//!
//! * [`Snapshot`] — the durable artifact kinds: per-window reports,
//!   per-pair resync events, cumulative per-pair summaries (the waste
//!   ledger), fleet rankings, fleet-wide [`FleetDivergence`] events,
//!   [`SessionHeader`] identity cards, and per-label cost ledgers —
//!   the last two are what [`session`] joins across deploys for
//!   `magneton diff`;
//! * [`json`] — the zero-dependency JSON reader completing the
//!   round trip with the writer in [`crate::util::json`]; every
//!   snapshot is one newline-delimited JSON line, and
//!   `Snapshot → json → Snapshot` is lossless (bit-for-bit on floats,
//!   escape-correct on strings — property-tested below);
//! * [`SnapshotSink`] — an appending NDJSON writer with **bounded
//!   rotation**: files are cut at [`SinkConfig::rotate_bytes`] and the
//!   oldest file is deleted (and counted) once the directory exceeds
//!   [`SinkConfig::max_snapshot_bytes`], so disk usage never scales
//!   with stream length — the same discipline the in-memory rings
//!   apply;
//! * [`Replay`] — loads a snapshot directory back into typed reports
//!   so `magneton replay` can re-render window/fleet/divergence views
//!   offline and [`Replay::verify_ranking`] can prove the persisted
//!   fleet ranking reproduces the per-pair waste ledgers bit-for-bit;
//! * [`follow`] — the live counterpart of [`Replay`]: a
//!   rotation-aware tailer that polls a snapshot directory while the
//!   writer is still appending, resumes mid-file by byte offset, and
//!   re-anchors when a rotated file drops out from under it
//!   (`magneton replay --follow`, `magneton dash`); [`Alarm`] is the
//!   typed artifact the online invariant monitor
//!   ([`crate::dash::Monitor`]) emits into the same schema.
//!
//! Producers: [`crate::stream::StreamAuditor::set_sink`] hooks one pair
//! to a sink; [`crate::coordinator::fleet::StreamFleet`] (via its
//! `snapshot_dir`) snapshots every pair plus the fleet-level ranking
//! and divergence events. `magneton stream --snapshot-dir <d>` turns
//! both on; `magneton replay --dir <d>` reads them back.
//!
//! ```
//! use magneton::stream::ResyncEvent;
//! use magneton::telemetry::Snapshot;
//!
//! let snap = Snapshot::Resync {
//!     pair: "serving-0 \"canary\"".into(), // escapes round-trip too
//!     event: ResyncEvent { at_ops: 437, skipped_a: 0, skipped_b: 1 },
//! };
//! let line = snap.to_line();
//! let back = Snapshot::parse_line(&line).unwrap();
//! assert_eq!(back.to_line(), line);
//! ```

use std::collections::{BTreeMap, VecDeque};
use std::fs::{self, File, OpenOptions};
use std::io::{Read as _, Write as _};
use std::path::{Path, PathBuf};

use crate::coordinator::fleet::{DivergentPair, FleetDivergence};
use crate::detect::Side;
use crate::fingerprint::WorkloadSig;
use crate::stream::{LabelLedger, ResyncEvent, StreamFinding, StreamSummary, WindowReport};
use crate::{Error, Result};

pub mod follow;
pub mod json;
pub mod merge;
pub mod session;

use json::Json;

/// Identity card of one persisted audit session: the workload
/// fingerprint and config digests that decide whether two snapshot
/// directories — two deploys, days apart — ran *the same workload* and
/// can therefore be differenced (`magneton diff`).
///
/// Written by [`SnapshotSink::set_header`] as the **first** line of the
/// sink's file series and re-written at the top of every file a
/// rotation opens, so the byte budget can drop the oldest data files
/// without ever dropping the session's identity.
#[derive(Clone, Debug, PartialEq)]
pub struct SessionHeader {
    /// Free-form session identity (operator-chosen: a deploy SHA, a
    /// date, a run id). Not used for matching — only for reporting.
    pub session_id: String,
    /// Free-form deploy tag grouping sessions of one rollout.
    pub deploy_tag: String,
    /// Which sink this header describes — the pair name for per-pair
    /// sinks. One session directory can hold several scopes (the
    /// `magneton stream` single pair plus its fleet pairs); session
    /// matching combines them.
    pub scope: String,
    /// Order-independent multiset hash over the workload's
    /// `(label, op)` signatures ([`WorkloadSig::fp`]).
    pub workload_fp: u64,
    /// Kernel ops the workload dispatches per side.
    pub total_ops: usize,
    /// Per-label op counts, label-sorted — the multiset behind
    /// `workload_fp`, kept explicit so tolerant matching can reason
    /// about partial overlap between two sessions.
    pub labels: Vec<(String, usize)>,
    /// Arrival-process description
    /// ([`crate::workload::ArrivalProcess::describe`]).
    pub arrival: String,
    /// Digest of the stream/detect configuration
    /// ([`crate::stream::StreamConfig::digest`]): windows persisted
    /// under different digests are not position-comparable.
    pub config_digest: u64,
    /// Operator-chosen name of the producer shard that wrote this
    /// series (`magneton stream --shard k/M --shard-id <name>`). Empty
    /// for an unsharded producer. `magneton merge` refuses two shard
    /// directories claiming the same non-empty id.
    pub shard_id: String,
    /// Zero-based index of the producer shard within the fleet
    /// partition. `0` for an unsharded producer.
    pub shard_index: usize,
    /// Total producer shards the fleet was partitioned over. `1` for an
    /// unsharded producer; the `session_id` is the fleet-level identity
    /// that groups the `shard_count` series of one logical session.
    pub shard_count: usize,
}

impl SessionHeader {
    pub fn new(
        session_id: &str,
        deploy_tag: &str,
        scope: &str,
        sig: &WorkloadSig,
        arrival: &str,
        config_digest: u64,
    ) -> SessionHeader {
        SessionHeader {
            session_id: session_id.to_string(),
            deploy_tag: deploy_tag.to_string(),
            scope: scope.to_string(),
            workload_fp: sig.fp(),
            total_ops: sig.total_ops(),
            labels: sig.label_counts(),
            arrival: arrival.to_string(),
            config_digest,
            shard_id: String::new(),
            shard_index: 0,
            shard_count: 1,
        }
    }

    /// Stamp shard identity onto the header (builder-style): `index`
    /// is zero-based, `count` is the fleet-wide shard total.
    pub fn with_shard(mut self, id: &str, index: usize, count: usize) -> SessionHeader {
        self.shard_id = id.to_string();
        self.shard_index = index;
        self.shard_count = count.max(1);
        self
    }

    /// True when this series was produced by one shard of a
    /// multi-process fleet partition.
    pub fn is_sharded(&self) -> bool {
        self.shard_count > 1 || !self.shard_id.is_empty()
    }

    /// A copy with the shard identity cleared — the canonical form
    /// `magneton merge` writes into the merged directory, where the
    /// series once again describes the whole logical session.
    pub fn unsharded(&self) -> SessionHeader {
        SessionHeader {
            shard_id: String::new(),
            shard_index: 0,
            shard_count: 1,
            ..self.clone()
        }
    }
}

/// One entry of a persisted fleet ranking: the aggregate counters an
/// operator dashboard ranks streams by, in rank order.
#[derive(Clone, Debug, PartialEq)]
pub struct RankEntry {
    pub name: String,
    /// Cumulative waste ledger of the pair, Joules.
    pub wasted_j: f64,
    pub ops: usize,
    pub windows: usize,
    pub windows_flagged: usize,
    pub resyncs: usize,
    pub aligned: bool,
}

/// One online-invariant violation raised while tailing a snapshot
/// stream — the typed artifact behind `--max-op-j`,
/// `--max-window-waste-pct`, and `--max-resyncs-per-min`
/// ([`crate::dash::Monitor`]). It lives in the telemetry schema (not in
/// `dash`) because it is persisted and published as an ordinary
/// [`Snapshot::Alarm`] NDJSON line: external collectors subscribe to
/// exactly what replay reads.
#[derive(Clone, Debug, PartialEq)]
pub struct Alarm {
    /// Stream pair the violation was observed on.
    pub pair: String,
    /// Invariant name (`max-op-j`, `max-window-waste-pct`,
    /// `max-resyncs-per-min`).
    pub invariant: String,
    /// Sequence number of the offending window; `None` (JSON `null`,
    /// like a peek window's seq) for alarms not tied to one window —
    /// the resync-rate invariant fires on resync events.
    pub seq: Option<usize>,
    /// Observed value that broke the invariant.
    pub value: f64,
    /// The operator-declared limit it broke.
    pub limit: f64,
    /// Human-readable context: the offending label, the rate window.
    pub detail: String,
}

/// One durable telemetry artifact — a single NDJSON line in a snapshot
/// file. The conversion to/from [`Json`] is lossless: floats keep their
/// bits (shortest round-trip formatting, non-finite forbidden by the
/// writer), `u64` fingerprints travel as hex strings so they never pass
/// through `f64`, and strings are escape-correct.
#[derive(Clone, Debug)]
pub enum Snapshot {
    /// One emitted detection window of one stream pair.
    Window { pair: String, report: WindowReport },
    /// One recovered divergence of one stream pair.
    Resync { pair: String, event: ResyncEvent },
    /// The cumulative summary (waste ledger) of one stream pair.
    Summary { pair: String, summary: StreamSummary },
    /// A fleet ranking, entries in rank order.
    Fleet { ranking: Vec<RankEntry> },
    /// A fleet-wide coalesced divergence event.
    Divergence { event: FleetDivergence },
    /// The session identity card ([`SessionHeader`]) — written first in
    /// a sink's series and re-written after every rotation.
    Session { header: SessionHeader },
    /// The cumulative per-label cost ledger of one pair, written at
    /// `finish` — the input `magneton diff` pairs across sessions.
    Ledger { pair: String, entries: Vec<LabelLedger> },
    /// An online-invariant violation ([`Alarm`]) raised by the live
    /// monitor while the stream ran.
    Alarm { alarm: Alarm },
}

impl Snapshot {
    pub fn to_json(&self) -> Json {
        match self {
            Snapshot::Window { pair, report } => Json::obj()
                .field("type", "window")
                .field("pair", pair.as_str())
                .field("report", window_json(report))
                .build(),
            Snapshot::Resync { pair, event } => Json::obj()
                .field("type", "resync")
                .field("pair", pair.as_str())
                .field("event", resync_json(event))
                .build(),
            Snapshot::Summary { pair, summary } => Json::obj()
                .field("type", "summary")
                .field("pair", pair.as_str())
                .field("summary", summary_json(summary))
                .build(),
            Snapshot::Fleet { ranking } => Json::obj()
                .field("type", "fleet")
                .field("ranking", Json::Arr(ranking.iter().map(rank_json).collect()))
                .build(),
            Snapshot::Divergence { event } => Json::obj()
                .field("type", "divergence")
                .field("event", divergence_json(event))
                .build(),
            Snapshot::Session { header } => Json::obj()
                .field("type", "session")
                .field("header", session_json(header))
                .build(),
            Snapshot::Ledger { pair, entries } => Json::obj()
                .field("type", "ledger")
                .field("pair", pair.as_str())
                .field("entries", Json::Arr(entries.iter().map(ledger_json).collect()))
                .build(),
            Snapshot::Alarm { alarm } => Json::obj()
                .field("type", "alarm")
                .field("alarm", alarm_json(alarm))
                .build(),
        }
    }

    pub fn from_json(j: &Json) -> Result<Snapshot> {
        let kind = req_str(j, "type")?;
        match kind {
            "window" => Ok(Snapshot::Window {
                pair: req_str(j, "pair")?.to_string(),
                report: window_from(req(j, "report")?)?,
            }),
            "resync" => Ok(Snapshot::Resync {
                pair: req_str(j, "pair")?.to_string(),
                event: resync_from(req(j, "event")?)?,
            }),
            "summary" => Ok(Snapshot::Summary {
                pair: req_str(j, "pair")?.to_string(),
                summary: summary_from(req(j, "summary")?)?,
            }),
            "fleet" => Ok(Snapshot::Fleet {
                ranking: req_arr(j, "ranking")?.iter().map(rank_from).collect::<Result<_>>()?,
            }),
            "divergence" => {
                Ok(Snapshot::Divergence { event: divergence_from(req(j, "event")?)? })
            }
            "session" => Ok(Snapshot::Session { header: session_from(req(j, "header")?)? }),
            "ledger" => Ok(Snapshot::Ledger {
                pair: req_str(j, "pair")?.to_string(),
                entries: req_arr(j, "entries")?.iter().map(ledger_from).collect::<Result<_>>()?,
            }),
            "alarm" => Ok(Snapshot::Alarm { alarm: alarm_from(req(j, "alarm")?)? }),
            other => Err(Error::msg(format!("unknown snapshot type `{other}`"))),
        }
    }

    /// Render as one NDJSON line (no trailing newline).
    pub fn to_line(&self) -> String {
        self.to_json().render()
    }

    /// Parse one NDJSON line.
    pub fn parse_line(line: &str) -> Result<Snapshot> {
        Snapshot::from_json(&Json::parse(line)?)
    }
}

// ---- field helpers ------------------------------------------------------

fn req<'a>(obj: &'a Json, key: &str) -> Result<&'a Json> {
    obj.get(key).ok_or_else(|| Error::msg(format!("missing snapshot field `{key}`")))
}

fn req_str<'a>(obj: &'a Json, key: &str) -> Result<&'a str> {
    req(obj, key)?
        .as_str()
        .ok_or_else(|| Error::msg(format!("snapshot field `{key}` is not a string")))
}

fn req_f64(obj: &Json, key: &str) -> Result<f64> {
    req(obj, key)?
        .as_f64()
        .ok_or_else(|| Error::msg(format!("snapshot field `{key}` is not a number")))
}

fn req_usize(obj: &Json, key: &str) -> Result<usize> {
    req(obj, key)?
        .as_usize()
        .ok_or_else(|| Error::msg(format!("snapshot field `{key}` is not an index")))
}

fn req_bool(obj: &Json, key: &str) -> Result<bool> {
    req(obj, key)?
        .as_bool()
        .ok_or_else(|| Error::msg(format!("snapshot field `{key}` is not a bool")))
}

fn req_arr<'a>(obj: &'a Json, key: &str) -> Result<&'a [Json]> {
    req(obj, key)?
        .as_arr()
        .ok_or_else(|| Error::msg(format!("snapshot field `{key}` is not an array")))
}

/// `u64` values (rolling fingerprints) use the full 64-bit range, which
/// `f64` cannot carry exactly — they travel as fixed-width hex strings.
fn hex_u64(v: u64) -> String {
    format!("{v:016x}")
}

fn req_hex_u64(obj: &Json, key: &str) -> Result<u64> {
    let s = req_str(obj, key)?;
    u64::from_str_radix(s, 16)
        .map_err(|e| Error::msg(format!("snapshot field `{key}`: bad hex `{s}`: {e}")))
}

fn side_str(s: Side) -> &'static str {
    match s {
        Side::A => "A",
        Side::B => "B",
    }
}

fn side_from(s: &str) -> Result<Side> {
    match s {
        "A" => Ok(Side::A),
        "B" => Ok(Side::B),
        other => Err(Error::msg(format!("unknown side `{other}`"))),
    }
}

// ---- per-type conversions -----------------------------------------------

fn finding_json(f: &StreamFinding) -> Json {
    Json::obj()
        .field("label", f.label.as_str())
        .field("ops", f.ops)
        .field("energy_a_j", f.energy_a_j)
        .field("energy_b_j", f.energy_b_j)
        .field("time_a_us", f.time_a_us)
        .field("time_b_us", f.time_b_us)
        .field("diff_frac", f.diff_frac)
        .field("wasteful", side_str(f.wasteful))
        .field("is_tradeoff", f.is_tradeoff)
        .build()
}

fn finding_from(j: &Json) -> Result<StreamFinding> {
    Ok(StreamFinding {
        label: req_str(j, "label")?.to_string(),
        ops: req_usize(j, "ops")?,
        energy_a_j: req_f64(j, "energy_a_j")?,
        energy_b_j: req_f64(j, "energy_b_j")?,
        time_a_us: req_f64(j, "time_a_us")?,
        time_b_us: req_f64(j, "time_b_us")?,
        diff_frac: req_f64(j, "diff_frac")?,
        wasteful: side_from(req_str(j, "wasteful")?)?,
        is_tradeoff: req_bool(j, "is_tradeoff")?,
    })
}

fn window_json(w: &WindowReport) -> Json {
    // PEEK_SEQ is usize::MAX — outside f64's exact range — and marks a
    // never-emitted report; it travels as null
    let seq = if w.seq == WindowReport::PEEK_SEQ { Json::Null } else { Json::Num(w.seq as f64) };
    Json::obj()
        .field("seq", seq)
        .field("pairs", w.pairs)
        .field("energy_a_j", w.energy_a_j)
        .field("energy_b_j", w.energy_b_j)
        .field("time_a_us", w.time_a_us)
        .field("time_b_us", w.time_b_us)
        .field("findings", Json::Arr(w.findings.iter().map(finding_json).collect()))
        .field("wasted_j", w.wasted_j)
        .field("aligned", w.aligned)
        .field("resyncs", w.resyncs)
        .field("quarantined", w.quarantined)
        .field("content_mismatches", w.content_mismatches)
        .field("window_fp", hex_u64(w.window_fp))
        .build()
}

fn window_from(j: &Json) -> Result<WindowReport> {
    let seq = match req(j, "seq")? {
        Json::Null => WindowReport::PEEK_SEQ,
        v => v.as_usize().ok_or_else(|| Error::msg("snapshot field `seq` is not an index"))?,
    };
    Ok(WindowReport {
        seq,
        pairs: req_usize(j, "pairs")?,
        energy_a_j: req_f64(j, "energy_a_j")?,
        energy_b_j: req_f64(j, "energy_b_j")?,
        time_a_us: req_f64(j, "time_a_us")?,
        time_b_us: req_f64(j, "time_b_us")?,
        findings: req_arr(j, "findings")?.iter().map(finding_from).collect::<Result<_>>()?,
        wasted_j: req_f64(j, "wasted_j")?,
        aligned: req_bool(j, "aligned")?,
        resyncs: req_usize(j, "resyncs")?,
        quarantined: req_bool(j, "quarantined")?,
        content_mismatches: req_usize(j, "content_mismatches")?,
        window_fp: req_hex_u64(j, "window_fp")?,
    })
}

fn session_json(h: &SessionHeader) -> Json {
    let labels = Json::Arr(
        h.labels
            .iter()
            .map(|(label, n)| Json::Arr(vec![Json::Str(label.clone()), Json::Num(*n as f64)]))
            .collect(),
    );
    Json::obj()
        .field("session_id", h.session_id.as_str())
        .field("deploy_tag", h.deploy_tag.as_str())
        .field("scope", h.scope.as_str())
        .field("workload_fp", hex_u64(h.workload_fp))
        .field("total_ops", h.total_ops)
        .field("labels", labels)
        .field("arrival", h.arrival.as_str())
        .field("config_digest", hex_u64(h.config_digest))
        .field("shard_id", h.shard_id.as_str())
        .field("shard_index", h.shard_index)
        .field("shard_count", h.shard_count)
        .build()
}

fn session_from(j: &Json) -> Result<SessionHeader> {
    let mut labels = Vec::new();
    for cell in req_arr(j, "labels")? {
        let parts = cell.as_arr().ok_or_else(|| Error::msg("labels entry is not an array"))?;
        if parts.len() != 2 {
            return Err(Error::msg("labels entry must be [label, ops]"));
        }
        let label =
            parts[0].as_str().ok_or_else(|| Error::msg("labels label is not a string"))?;
        let n = parts[1].as_usize().ok_or_else(|| Error::msg("labels ops is not an index"))?;
        labels.push((label.to_string(), n));
    }
    Ok(SessionHeader {
        session_id: req_str(j, "session_id")?.to_string(),
        deploy_tag: req_str(j, "deploy_tag")?.to_string(),
        scope: req_str(j, "scope")?.to_string(),
        workload_fp: req_hex_u64(j, "workload_fp")?,
        total_ops: req_usize(j, "total_ops")?,
        labels,
        arrival: req_str(j, "arrival")?.to_string(),
        config_digest: req_hex_u64(j, "config_digest")?,
        // shard identity was introduced after the first persisted
        // sessions; absent fields decode as the unsharded defaults so
        // pre-shard directories stay loadable
        shard_id: match j.get("shard_id") {
            Some(v) => v
                .as_str()
                .ok_or_else(|| Error::msg("snapshot field `shard_id` is not a string"))?
                .to_string(),
            None => String::new(),
        },
        shard_index: match j.get("shard_index") {
            Some(v) => v
                .as_usize()
                .ok_or_else(|| Error::msg("snapshot field `shard_index` is not an index"))?,
            None => 0,
        },
        shard_count: match j.get("shard_count") {
            Some(v) => v
                .as_usize()
                .ok_or_else(|| Error::msg("snapshot field `shard_count` is not an index"))?,
            None => 1,
        },
    })
}

fn ledger_json(e: &LabelLedger) -> Json {
    Json::obj()
        .field("label", e.label.as_str())
        .field("ops", e.ops)
        .field("energy_a_j", e.energy_a_j)
        .field("energy_b_j", e.energy_b_j)
        .field("time_a_us", e.time_a_us)
        .field("time_b_us", e.time_b_us)
        .build()
}

fn ledger_from(j: &Json) -> Result<LabelLedger> {
    Ok(LabelLedger {
        label: req_str(j, "label")?.to_string(),
        ops: req_usize(j, "ops")?,
        energy_a_j: req_f64(j, "energy_a_j")?,
        energy_b_j: req_f64(j, "energy_b_j")?,
        time_a_us: req_f64(j, "time_a_us")?,
        time_b_us: req_f64(j, "time_b_us")?,
    })
}

fn alarm_json(a: &Alarm) -> Json {
    // like a peek window's seq, the "no single window" case travels as
    // JSON null so it never collides with a real sequence number
    let seq = match a.seq {
        Some(s) => Json::Num(s as f64),
        None => Json::Null,
    };
    Json::obj()
        .field("pair", a.pair.as_str())
        .field("invariant", a.invariant.as_str())
        .field("seq", seq)
        .field("value", a.value)
        .field("limit", a.limit)
        .field("detail", a.detail.as_str())
        .build()
}

fn alarm_from(j: &Json) -> Result<Alarm> {
    let seq = match req(j, "seq")? {
        Json::Null => None,
        v => Some(
            v.as_usize().ok_or_else(|| Error::msg("snapshot field `seq` is not an index"))?,
        ),
    };
    Ok(Alarm {
        pair: req_str(j, "pair")?.to_string(),
        invariant: req_str(j, "invariant")?.to_string(),
        seq,
        value: req_f64(j, "value")?,
        limit: req_f64(j, "limit")?,
        detail: req_str(j, "detail")?.to_string(),
    })
}

fn resync_json(e: &ResyncEvent) -> Json {
    Json::obj()
        .field("at_ops", e.at_ops)
        .field("skipped_a", e.skipped_a)
        .field("skipped_b", e.skipped_b)
        .build()
}

fn resync_from(j: &Json) -> Result<ResyncEvent> {
    Ok(ResyncEvent {
        at_ops: req_usize(j, "at_ops")?,
        skipped_a: req_usize(j, "skipped_a")?,
        skipped_b: req_usize(j, "skipped_b")?,
    })
}

fn summary_json(s: &StreamSummary) -> Json {
    let top_labels = Json::Arr(
        s.top_labels
            .iter()
            .map(|(label, j, n)| {
                Json::Arr(vec![Json::Str(label.clone()), Json::Num(*j), Json::Num(*n as f64)])
            })
            .collect(),
    );
    Json::obj()
        .field("ops", s.ops)
        .field("windows", s.windows)
        .field("energy_a_j", s.energy_a_j)
        .field("energy_b_j", s.energy_b_j)
        .field("time_a_us", s.time_a_us)
        .field("time_b_us", s.time_b_us)
        .field("wasted_j", s.wasted_j)
        .field("windows_flagged", s.windows_flagged)
        .field("windows_quarantined", s.windows_quarantined)
        .field("top_labels", top_labels)
        .field("aligned", s.aligned)
        .field("fingerprint_a", hex_u64(s.fingerprint_a))
        .field("fingerprint_b", hex_u64(s.fingerprint_b))
        .field("unpaired", s.unpaired)
        .field("resyncs", s.resyncs)
        .field("resync_skipped", s.resync_skipped)
        .field("resync_log", Json::Arr(s.resync_log.iter().map(resync_json).collect()))
        .field("content_mismatches", s.content_mismatches)
        .field("reports_dropped", s.reports_dropped)
        .field("peak_retained_segments", s.peak_retained_segments)
        .field("peak_window_pairs", s.peak_window_pairs)
        .field("peak_pending", s.peak_pending)
        .build()
}

fn summary_from(j: &Json) -> Result<StreamSummary> {
    let mut top_labels = Vec::new();
    for cell in req_arr(j, "top_labels")? {
        let parts = cell
            .as_arr()
            .ok_or_else(|| Error::msg("top_labels entry is not an array"))?;
        if parts.len() != 3 {
            return Err(Error::msg("top_labels entry must be [label, wasted_j, windows]"));
        }
        let label = parts[0]
            .as_str()
            .ok_or_else(|| Error::msg("top_labels label is not a string"))?;
        let wasted = parts[1]
            .as_f64()
            .ok_or_else(|| Error::msg("top_labels wasted_j is not a number"))?;
        let windows = parts[2]
            .as_usize()
            .ok_or_else(|| Error::msg("top_labels windows is not an index"))?;
        top_labels.push((label.to_string(), wasted, windows));
    }
    Ok(StreamSummary {
        ops: req_usize(j, "ops")?,
        windows: req_usize(j, "windows")?,
        energy_a_j: req_f64(j, "energy_a_j")?,
        energy_b_j: req_f64(j, "energy_b_j")?,
        time_a_us: req_f64(j, "time_a_us")?,
        time_b_us: req_f64(j, "time_b_us")?,
        wasted_j: req_f64(j, "wasted_j")?,
        windows_flagged: req_usize(j, "windows_flagged")?,
        windows_quarantined: req_usize(j, "windows_quarantined")?,
        top_labels,
        aligned: req_bool(j, "aligned")?,
        fingerprint_a: req_hex_u64(j, "fingerprint_a")?,
        fingerprint_b: req_hex_u64(j, "fingerprint_b")?,
        unpaired: req_usize(j, "unpaired")?,
        resyncs: req_usize(j, "resyncs")?,
        resync_skipped: req_usize(j, "resync_skipped")?,
        resync_log: req_arr(j, "resync_log")?.iter().map(resync_from).collect::<Result<_>>()?,
        content_mismatches: req_usize(j, "content_mismatches")?,
        reports_dropped: req_usize(j, "reports_dropped")?,
        peak_retained_segments: req_usize(j, "peak_retained_segments")?,
        peak_window_pairs: req_usize(j, "peak_window_pairs")?,
        peak_pending: req_usize(j, "peak_pending")?,
    })
}

fn rank_json(e: &RankEntry) -> Json {
    Json::obj()
        .field("name", e.name.as_str())
        .field("wasted_j", e.wasted_j)
        .field("ops", e.ops)
        .field("windows", e.windows)
        .field("windows_flagged", e.windows_flagged)
        .field("resyncs", e.resyncs)
        .field("aligned", e.aligned)
        .build()
}

fn rank_from(j: &Json) -> Result<RankEntry> {
    Ok(RankEntry {
        name: req_str(j, "name")?.to_string(),
        wasted_j: req_f64(j, "wasted_j")?,
        ops: req_usize(j, "ops")?,
        windows: req_usize(j, "windows")?,
        windows_flagged: req_usize(j, "windows_flagged")?,
        resyncs: req_usize(j, "resyncs")?,
        aligned: req_bool(j, "aligned")?,
    })
}

fn divergence_json(d: &FleetDivergence) -> Json {
    let pairs = Json::Arr(
        d.pairs
            .iter()
            .map(|p| {
                Json::obj()
                    .field("name", p.name.as_str())
                    .field("at_ops", p.at_ops)
                    .field("resyncs", p.resyncs)
                    .field("skipped", p.skipped)
                    .build()
            })
            .collect(),
    );
    Json::obj()
        .field("at_ops_min", d.at_ops_min)
        .field("at_ops_max", d.at_ops_max)
        .field("pairs", pairs)
        .build()
}

fn divergence_from(j: &Json) -> Result<FleetDivergence> {
    let mut pairs = Vec::new();
    for p in req_arr(j, "pairs")? {
        pairs.push(DivergentPair {
            name: req_str(p, "name")?.to_string(),
            at_ops: req_usize(p, "at_ops")?,
            resyncs: req_usize(p, "resyncs")?,
            skipped: req_usize(p, "skipped")?,
        });
    }
    Ok(FleetDivergence {
        at_ops_min: req_usize(j, "at_ops_min")?,
        at_ops_max: req_usize(j, "at_ops_max")?,
        pairs,
    })
}

// ---- sink ---------------------------------------------------------------

/// Rotation bounds of a [`SnapshotSink`].
#[derive(Clone, Debug)]
pub struct SinkConfig {
    /// Total bytes retained across the sink's files. Once exceeded, the
    /// *oldest* file is deleted (counted in
    /// [`SnapshotSink::dropped_files`]) — the current file is never
    /// dropped. `0` = unbounded.
    pub max_snapshot_bytes: u64,
    /// The current file is closed and a new one begun once it would
    /// exceed this many bytes. A single snapshot line larger than the
    /// limit still lands in one (oversize) file. `0` = never rotate
    /// (one growing file; the total budget then cannot drop anything,
    /// since the current file is never deleted).
    pub rotate_bytes: u64,
}

impl Default for SinkConfig {
    fn default() -> SinkConfig {
        SinkConfig { max_snapshot_bytes: 8 * 1024 * 1024, rotate_bytes: 1024 * 1024 }
    }
}

/// File-name stem derived from a pair name: path separators and other
/// non-`[A-Za-z0-9_-]` characters become `-`, so arbitrary pair names
/// can never escape the snapshot directory.
pub fn sanitize_stem(name: &str) -> String {
    let s: String = name
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() || c == '-' || c == '_' { c } else { '-' })
        .collect();
    if s.is_empty() {
        "snap".to_string()
    } else {
        s
    }
}

/// Appending NDJSON snapshot writer with bounded rotation.
///
/// Files are named `<prefix>-NNNNNN.ndjson` with a zero-padded monotone
/// index; [`load_dir`] / [`Replay::load`] reconstruct chronological
/// order per sink by parsing that index (not by raw lexicographic
/// order, which would break past a million rotations). Writes go
/// straight to the file (one `write_all` per line, no buffering), so a
/// crashed process loses at most the line being written.
pub struct SnapshotSink {
    dir: PathBuf,
    prefix: String,
    cfg: SinkConfig,
    /// Retained files oldest-first: `(path, bytes)`; the last entry is
    /// the file currently being appended to.
    files: VecDeque<(PathBuf, u64)>,
    file: Option<File>,
    next_index: usize,
    /// Pinned session-header line (newline-terminated), written at the
    /// top of every file this sink opens so rotation can never drop it.
    header: Option<String>,
    /// Snapshots appended via [`SnapshotSink::append`] (header
    /// re-writes are counted in `written_bytes` but not here).
    pub written: usize,
    /// Bytes appended (including rotated-away files and header lines).
    pub written_bytes: u64,
    /// Oldest files deleted to honour the byte budget.
    pub dropped_files: usize,
    /// Bytes those dropped files held.
    pub dropped_bytes: u64,
}

impl SnapshotSink {
    /// Create the directory (if needed) and an empty sink. The first
    /// file is opened lazily on the first [`SnapshotSink::append`].
    ///
    /// Use a fresh (or per-run) directory per audit: a second sink with
    /// the same prefix appends to the first one's files, which is safe
    /// for replay (lines stay ordered) but makes the byte accounting —
    /// and therefore the rotation budget — restart from zero, and
    /// mixes the runs' summaries during ranking verification.
    pub fn new(dir: impl Into<PathBuf>, prefix: &str, cfg: SinkConfig) -> Result<SnapshotSink> {
        let dir = dir.into();
        fs::create_dir_all(&dir)
            .map_err(|e| Error::msg(format!("create snapshot dir {}: {e}", dir.display())))?;
        Ok(SnapshotSink {
            dir,
            prefix: sanitize_stem(prefix),
            cfg,
            files: VecDeque::new(),
            file: None,
            next_index: 0,
            header: None,
            written: 0,
            written_bytes: 0,
            dropped_files: 0,
            dropped_bytes: 0,
        })
    }

    /// Pin a session header to this sink: it is written immediately and
    /// re-written at the top of every file a rotation opens, so the
    /// byte budget can drop the oldest data files without ever dropping
    /// the session's identity ([`Replay`] dedupes the copies). Call it
    /// before the first [`SnapshotSink::append`] for the header to be
    /// literally first in the series; a mid-series call still persists
    /// it from the current position onward.
    pub fn set_header(&mut self, snap: &Snapshot) -> Result<()> {
        let mut line = snap.to_line();
        line.push('\n');
        self.header = Some(line.clone());
        if self.files.is_empty() {
            // writes the header as the new file's first line
            self.open_new_file()?;
        } else {
            self.raw_write(&line)?;
        }
        self.enforce_budget();
        Ok(())
    }

    /// Open the next file in the series; the pinned header (if any) is
    /// its first line.
    fn open_new_file(&mut self) -> Result<()> {
        let path = self.dir.join(format!("{}-{:06}.ndjson", self.prefix, self.next_index));
        self.next_index += 1;
        let f = OpenOptions::new()
            .create(true)
            .append(true)
            .open(&path)
            .map_err(|e| Error::msg(format!("open snapshot file {}: {e}", path.display())))?;
        self.file = Some(f);
        self.files.push_back((path, 0));
        if let Some(h) = self.header.clone() {
            self.raw_write(&h)?;
        }
        Ok(())
    }

    /// Append one newline-terminated line to the current file, keeping
    /// the byte accounting exact.
    ///
    /// Every failure is a typed [`Error`], never a panic: sinks run
    /// inside fleet worker threads whose callers count IO errors
    /// ([`crate::stream::StreamAuditor::sink_errors`]) and keep
    /// auditing — an unwind here would take the worker down with the
    /// snapshot it failed to write.
    fn raw_write(&mut self, line: &str) -> Result<()> {
        let bytes = line.len() as u64;
        let (Some(f), Some(cur)) = (self.file.as_mut(), self.files.back_mut()) else {
            return Err(Error::msg(
                "snapshot sink has no open file (a rotation open failed earlier)",
            ));
        };
        f.write_all(line.as_bytes())
            .map_err(|e| Error::msg(format!("append snapshot: {e}")))?;
        cur.1 += bytes;
        self.written_bytes += bytes;
        Ok(())
    }

    /// Drop oldest files (never the current one) until the byte budget
    /// holds.
    fn enforce_budget(&mut self) {
        if self.cfg.max_snapshot_bytes > 0 {
            while self.files.len() > 1 && self.total_bytes() > self.cfg.max_snapshot_bytes {
                let Some((old, sz)) = self.files.pop_front() else { break };
                let _ = fs::remove_file(&old);
                self.dropped_files += 1;
                self.dropped_bytes += sz;
            }
        }
    }

    /// Append one snapshot as an NDJSON line, rotating and enforcing
    /// the byte budget as needed.
    pub fn append(&mut self, snap: &Snapshot) -> Result<()> {
        let mut line = snap.to_line();
        line.push('\n');
        let bytes = line.len() as u64;
        let needs_new = match self.files.back() {
            None => true,
            Some((_, cur)) => {
                self.cfg.rotate_bytes > 0 && *cur > 0 && *cur + bytes > self.cfg.rotate_bytes
            }
        };
        if needs_new {
            self.open_new_file()?;
        }
        self.raw_write(&line)?;
        self.written += 1;
        self.enforce_budget();
        Ok(())
    }

    /// Bytes currently retained on disk across this sink's files.
    pub fn total_bytes(&self) -> u64 {
        self.files.iter().map(|(_, b)| b).sum()
    }

    /// Snapshot files currently retained.
    pub fn retained_files(&self) -> usize {
        self.files.len()
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }
}

// ---- replay -------------------------------------------------------------

/// Sort key reconstructing write order from a snapshot file name:
/// `(sink prefix, numeric rotation index, full stem)`. A plain
/// lexicographic sort would order a 7-digit rotation index before
/// `-0999999` and scramble the replay of a very long audit; comparing
/// the parsed index keeps per-sink chronology at any width. Files
/// without a `-<digits>` suffix (not written by a [`SnapshotSink`])
/// sort by name with index 0.
///
/// Public because [`merge`] interleaves the file series of several
/// shard directories under the same total order, which is what makes a
/// merged replay reproduce the single-process file order.
pub fn file_order_key(path: &Path) -> (String, u64, String) {
    let stem = path.file_stem().and_then(|s| s.to_str()).unwrap_or("").to_string();
    if let Some((prefix, idx)) = stem.rsplit_once('-') {
        if let Ok(i) = idx.parse::<u64>() {
            return (prefix.to_string(), i, stem);
        }
    }
    (stem.clone(), 0, stem)
}

/// The `*.ndjson` files under `dir`, sorted by [`file_order_key`] —
/// the listing step shared by [`load_dir`], the lazy header-only
/// session scan ([`session::SessionIndex::scan`]), and [`merge`].
pub fn snapshot_files(dir: &Path) -> Result<Vec<PathBuf>> {
    let rd = fs::read_dir(dir)
        .map_err(|e| Error::msg(format!("read snapshot dir {}: {e}", dir.display())))?;
    let mut paths = Vec::new();
    for entry in rd {
        let entry =
            entry.map_err(|e| Error::msg(format!("read snapshot dir {}: {e}", dir.display())))?;
        let path = entry.path();
        if path.extension().and_then(|e| e.to_str()) == Some("ndjson") {
            paths.push(path);
        }
    }
    paths.sort_by_key(|p| file_order_key(p));
    Ok(paths)
}

/// One parsed snapshot file of a directory scan.
pub struct FileScan {
    pub path: PathBuf,
    /// Complete (newline-terminated) snapshots of the file, in line
    /// order.
    pub snapshots: Vec<Snapshot>,
    /// True when the file ended in an unterminated fragment (a torn
    /// final line — the producer was killed mid-append).
    pub torn_fragment: bool,
}

/// A snapshot directory scanned file-by-file, with the damage counters
/// [`merge`] reports: torn trailing fragments (split by where they sit
/// in the series), rotation-index gaps (a file deleted from the
/// *middle* of a sink's series — the byte budget only ever drops the
/// oldest files, so a contiguous range that merely starts above zero is
/// normal while an interior hole is not), and files that vanished
/// between the listing and the read.
pub struct DirScan {
    pub files: Vec<FileScan>,
    /// Unterminated tails on the *newest* file of a sink's series.
    /// Against a live directory this is a writer mid-append, not
    /// damage; post-hoc it is the familiar killed-mid-append artifact
    /// (a crash loses at most the line being written).
    pub torn_final: usize,
    /// Unterminated tails on files the same sink *already rotated
    /// past* — the writer had moved on, so the tear can never be a
    /// live append: it is real corruption.
    pub torn_interior: usize,
    /// Interior gaps across all per-prefix rotation series.
    pub missing_rotations: usize,
    /// Files present in the listing but gone by the time they were
    /// opened — a live writer's byte budget rotated them away between
    /// the two steps. Skipped and counted, never fatal.
    pub vanished: usize,
}

impl DirScan {
    /// All torn fragments wherever they sit — what a post-hoc consumer
    /// ([`merge`], whose writer is presumed dead) reports as damage.
    pub fn torn_fragments(&self) -> usize {
        self.torn_final + self.torn_interior
    }
}

/// Scan every snapshot file under `dir` (rotation order via
/// [`file_order_key`], line order within a file), keeping per-file
/// grouping and damage counters. [`load_dir`] is the flattened view.
pub fn scan_dir(dir: &Path) -> Result<DirScan> {
    scan_dir_with(dir, File::open)
}

/// [`scan_dir`] with an injectable reader factory (the same pattern as
/// [`session::SessionIndex::scan_with`]), so tests can meter reads or
/// inject the listing/open race a live rotating writer produces.
///
/// A factory error of kind [`std::io::ErrorKind::NotFound`] means the
/// file rotated away between the directory listing and the open: that
/// file is skipped and counted in [`DirScan::vanished`] instead of
/// failing the surviving files. Any other IO error is still fatal.
pub fn scan_dir_with<R, F>(dir: &Path, mut open: F) -> Result<DirScan>
where
    R: std::io::Read,
    F: FnMut(&Path) -> std::io::Result<R>,
{
    let paths = snapshot_files(dir)?;
    let mut files = Vec::new();
    let mut vanished = 0usize;
    for path in paths {
        let read_all = |open: &mut F| -> std::io::Result<Vec<u8>> {
            let mut r = open(&path)?;
            let mut bytes = Vec::new();
            r.read_to_end(&mut bytes)?;
            Ok(bytes)
        };
        // bytes + lossy conversion: a torn multi-byte UTF-8 char in the
        // trailing fragment must not fail the read either (the fragment
        // is dropped below; intact lines are unaffected)
        let bytes = match read_all(&mut open) {
            Ok(b) => b,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                vanished += 1;
                continue;
            }
            Err(e) => return Err(Error::msg(format!("read {}: {e}", path.display()))),
        };
        let text = String::from_utf8_lossy(&bytes);
        let complete = match text.rfind('\n') {
            Some(pos) => &text[..pos + 1],
            None => "",
        };
        let torn_fragment = complete.len() < text.len();
        let mut snapshots = Vec::new();
        for (i, line) in complete.lines().enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            let snap = Snapshot::parse_line(line)
                .map_err(|e| e.context(format!("{} line {}", path.display(), i + 1)))?;
            snapshots.push(snap);
        }
        files.push(FileScan { path, snapshots, torn_fragment });
    }
    // classify torn tails: only the newest surviving file of a prefix
    // series may legitimately end mid-line (the writer could still be
    // appending to it); a torn file with a later rotation is damage
    let mut last_idx: BTreeMap<String, u64> = BTreeMap::new();
    for f in &files {
        let (prefix, idx, _) = file_order_key(&f.path);
        let e = last_idx.entry(prefix).or_insert(idx);
        *e = (*e).max(idx);
    }
    let (mut torn_final, mut torn_interior) = (0usize, 0usize);
    for f in &files {
        if !f.torn_fragment {
            continue;
        }
        let (prefix, idx, _) = file_order_key(&f.path);
        if last_idx.get(&prefix) == Some(&idx) {
            torn_final += 1;
        } else {
            torn_interior += 1;
        }
    }
    // interior rotation gaps per sink prefix: indices are assigned
    // consecutively at write time, and the budget drops oldest-first,
    // so any hole strictly inside the surviving range is a lost file
    let mut by_prefix: BTreeMap<String, Vec<u64>> = BTreeMap::new();
    for f in &files {
        let (prefix, idx, _) = file_order_key(&f.path);
        by_prefix.entry(prefix).or_default().push(idx);
    }
    let mut missing_rotations = 0usize;
    for indices in by_prefix.values() {
        // `files` is sorted by file_order_key, so per-prefix indices
        // arrive ascending
        for w in indices.windows(2) {
            missing_rotations += (w[1] - w[0]).saturating_sub(1) as usize;
        }
    }
    Ok(DirScan { files, torn_final, torn_interior, missing_rotations, vanished })
}

/// Load every snapshot under `dir` (all `*.ndjson` files, per-sink
/// rotation order via [`file_order_key`], line order within a file),
/// in write order per producer.
///
/// A process killed mid-append leaves an unterminated final fragment
/// in its current file; complete lines always end with `\n` (the sink
/// writes line + newline in one `write_all`), so such a fragment is
/// **skipped** rather than failing the whole replay — this is what
/// makes the sink's "a crash loses at most the line being written"
/// guarantee hold at read time. Newline-*terminated* lines that fail
/// to parse are genuine corruption and still error out.
pub fn load_dir(dir: &Path) -> Result<Vec<Snapshot>> {
    Ok(scan_dir(dir)?.files.into_iter().flat_map(|f| f.snapshots).collect())
}

/// A snapshot directory loaded back into typed reports, grouped by
/// artifact kind (each group in persisted order).
#[derive(Default)]
pub struct Replay {
    pub windows: Vec<(String, WindowReport)>,
    pub resyncs: Vec<(String, ResyncEvent)>,
    pub summaries: Vec<(String, StreamSummary)>,
    /// Every persisted fleet ranking (one per fleet run).
    pub rankings: Vec<Vec<RankEntry>>,
    pub divergences: Vec<FleetDivergence>,
    /// Distinct session headers found (rotation re-writes identical
    /// copies at the top of every file; exact duplicates are dropped
    /// here, so one entry remains per sink scope).
    pub sessions: Vec<SessionHeader>,
    /// Per-pair label ledgers, in persisted order.
    pub ledgers: Vec<(String, Vec<LabelLedger>)>,
    /// Persisted invariant alarms, in persisted order.
    pub alarms: Vec<Alarm>,
}

impl Replay {
    pub fn load(dir: &Path) -> Result<Replay> {
        Ok(Replay::from_snapshots(load_dir(dir)?))
    }

    /// Group an already-loaded snapshot sequence by artifact kind —
    /// the in-memory half of [`Replay::load`], reused by [`merge`] to
    /// build a replay over the interleaved files of several shard
    /// directories.
    pub fn from_snapshots(snapshots: impl IntoIterator<Item = Snapshot>) -> Replay {
        let mut r = Replay::default();
        for snap in snapshots {
            match snap {
                Snapshot::Window { pair, report } => r.windows.push((pair, report)),
                Snapshot::Resync { pair, event } => r.resyncs.push((pair, event)),
                Snapshot::Summary { pair, summary } => r.summaries.push((pair, summary)),
                Snapshot::Fleet { ranking } => r.rankings.push(ranking),
                Snapshot::Divergence { event } => r.divergences.push(event),
                Snapshot::Session { header } => {
                    if !r.sessions.contains(&header) {
                        r.sessions.push(header);
                    }
                }
                Snapshot::Ledger { pair, entries } => r.ledgers.push((pair, entries)),
                Snapshot::Alarm { alarm } => r.alarms.push(alarm),
            }
        }
        r
    }

    /// The most recent persisted summary for `pair`, if any.
    pub fn summary_of(&self, pair: &str) -> Option<&StreamSummary> {
        self.summaries.iter().rev().find(|(n, _)| n == pair).map(|(_, s)| s)
    }

    /// The most recent persisted label ledger for `pair`, if any.
    pub fn ledger_of(&self, pair: &str) -> Option<&[LabelLedger]> {
        self.ledgers.iter().rev().find(|(n, _)| n == pair).map(|(_, l)| l.as_slice())
    }

    /// Verify every persisted fleet ranking against the persisted
    /// per-pair summaries: entries must be in the exact order
    /// `StreamFleet::run` ranks (wasted joules descending, name
    /// tiebreak), and every entry's waste ledger must match its pair's
    /// summary **bit-for-bit** (`f64::to_bits`). Returns the number of
    /// entries checked.
    pub fn verify_ranking(&self) -> std::result::Result<usize, String> {
        let mut checked = 0;
        for ranking in &self.rankings {
            for w in ranking.windows(2) {
                let ord = w[1]
                    .wasted_j
                    .total_cmp(&w[0].wasted_j)
                    .then_with(|| w[0].name.cmp(&w[1].name));
                if ord == std::cmp::Ordering::Greater {
                    return Err(format!(
                        "ranking out of order: `{}` ({} J) before `{}` ({} J)",
                        w[0].name, w[0].wasted_j, w[1].name, w[1].wasted_j
                    ));
                }
            }
            for e in ranking {
                let Some(s) = self.summary_of(&e.name) else {
                    return Err(format!("ranking entry `{}` has no persisted summary", e.name));
                };
                if s.wasted_j.to_bits() != e.wasted_j.to_bits() {
                    return Err(format!(
                        "`{}`: ranking wasted_j {} differs from summary {}",
                        e.name, e.wasted_j, s.wasted_j
                    ));
                }
                if s.ops != e.ops
                    || s.windows != e.windows
                    || s.windows_flagged != e.windows_flagged
                    || s.resyncs != e.resyncs
                    || s.aligned != e.aligned
                {
                    return Err(format!("`{}`: ranking counters diverge from summary", e.name));
                }
                checked += 1;
            }
        }
        Ok(checked)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Prng;

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("magneton-telemetry-mod-{tag}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        dir
    }

    fn finding(label: &str) -> StreamFinding {
        StreamFinding {
            label: label.to_string(),
            ops: 4,
            energy_a_j: 0.6,
            energy_b_j: 0.4,
            time_a_us: 400.0,
            time_b_us: 400.0,
            diff_frac: 1.0 / 3.0,
            wasteful: Side::A,
            is_tradeoff: false,
        }
    }

    fn window(seq: usize, label: &str) -> WindowReport {
        WindowReport {
            seq,
            pairs: 8,
            energy_a_j: 1.23456789,
            energy_b_j: 0.1 + 0.2, // deliberately ugly float
            time_a_us: 800.0,
            time_b_us: 801.5,
            findings: vec![finding(label)],
            wasted_j: 0.2,
            aligned: true,
            resyncs: 0,
            quarantined: false,
            content_mismatches: 1,
            window_fp: 0x0123_4567_89ab_cdef,
        }
    }

    fn header(session_id: &str) -> SessionHeader {
        SessionHeader {
            session_id: session_id.to_string(),
            deploy_tag: "canary \"v2\"".into(),
            scope: "pair-0".into(),
            workload_fp: u64::MAX, // not representable in f64 — hex only
            total_ops: 5000,
            labels: vec![("serve.proj".into(), 2000), ("serve.act".into(), 3000)],
            arrival: "poisson@200Hz".into(),
            config_digest: 0xdead_beef_0123_4567,
            shard_id: "host-07 \"east\"".into(),
            shard_index: 3,
            shard_count: 8,
        }
    }

    fn ledger_entry(label: &str) -> LabelLedger {
        LabelLedger {
            label: label.to_string(),
            ops: 400,
            energy_a_j: 0.1 + 0.2, // deliberately ugly float
            energy_b_j: 0.25,
            time_a_us: 4000.0,
            time_b_us: 4000.5,
        }
    }

    fn summary(label: &str) -> StreamSummary {
        StreamSummary {
            ops: 1000,
            windows: 10,
            energy_a_j: 12.5,
            energy_b_j: 10.0,
            time_a_us: 1e6,
            time_b_us: 1e6 + 0.5,
            wasted_j: 2.5000000001,
            windows_flagged: 9,
            windows_quarantined: 1,
            top_labels: vec![(label.to_string(), 2.5000000001, 9), ("other".into(), 0.0, 0)],
            aligned: false,
            fingerprint_a: 0xdead_beef_0123_4567,
            fingerprint_b: u64::MAX, // not representable in f64 — must survive via hex
            unpaired: 1,
            resyncs: 1,
            resync_skipped: 1,
            resync_log: vec![ResyncEvent { at_ops: 437, skipped_a: 0, skipped_b: 1 }],
            content_mismatches: 2,
            reports_dropped: 3,
            peak_retained_segments: 128,
            peak_window_pairs: 100,
            peak_pending: 2,
        }
    }

    fn divergence() -> FleetDivergence {
        FleetDivergence {
            at_ops_min: 437,
            at_ops_max: 439,
            pairs: vec![
                DivergentPair { name: "serving-0".into(), at_ops: 437, resyncs: 2, skipped: 3 },
                DivergentPair { name: "serving-1".into(), at_ops: 439, resyncs: 1, skipped: 1 },
            ],
        }
    }

    /// Render-equality is a lossless-round-trip proof: the writer is
    /// injective on finite floats (shortest round-trip formatting) and
    /// on escaped strings.
    fn roundtrip(snap: &Snapshot) {
        let line = snap.to_line();
        let back = Snapshot::parse_line(&line).unwrap_or_else(|e| panic!("parse `{line}`: {e}"));
        assert_eq!(back.to_line(), line, "snapshot round trip not lossless");
    }

    #[test]
    fn every_snapshot_kind_round_trips() {
        roundtrip(&Snapshot::Window { pair: "p0".into(), report: window(3, "serve.proj") });
        roundtrip(&Snapshot::Resync {
            pair: "p0".into(),
            event: ResyncEvent { at_ops: 437, skipped_a: 0, skipped_b: 1 },
        });
        roundtrip(&Snapshot::Summary { pair: "p0".into(), summary: summary("serve.proj") });
        roundtrip(&Snapshot::Fleet {
            ranking: vec![RankEntry {
                name: "p0".into(),
                wasted_j: 2.5,
                ops: 1000,
                windows: 10,
                windows_flagged: 9,
                resyncs: 1,
                aligned: false,
            }],
        });
        roundtrip(&Snapshot::Divergence { event: divergence() });
        roundtrip(&Snapshot::Session { header: header("deploy \"2026-07-28\"") });
        roundtrip(&Snapshot::Ledger {
            pair: "p0".into(),
            entries: vec![ledger_entry("serve.proj"), ledger_entry("serve.act")],
        });
        roundtrip(&Snapshot::Alarm {
            alarm: Alarm {
                pair: "p0 \"canary\"".into(),
                invariant: "max-window-waste-pct".into(),
                seq: Some(42),
                value: 0.1 + 0.2, // deliberately ugly float
                limit: 0.25,
                detail: "label serve.proj 東京".into(),
            },
        });
        // the windowless form travels as JSON null, like a peek seq
        let line = Snapshot::Alarm {
            alarm: Alarm {
                pair: "p1".into(),
                invariant: "max-resyncs-per-min".into(),
                seq: None,
                value: 7.0,
                limit: 2.0,
                detail: "3 resyncs in 25.0s".into(),
            },
        }
        .to_line();
        assert!(line.contains("\"seq\":null"), "{line}");
        let Snapshot::Alarm { alarm } = Snapshot::parse_line(&line).unwrap() else {
            panic!("round trip changed the variant");
        };
        assert_eq!(alarm.seq, None);
        assert_eq!(alarm.value.to_bits(), 7.0f64.to_bits());
    }

    /// The session-header acceptance property: random headers with
    /// pathological strings and full-range u64 fingerprints round-trip
    /// losslessly through NDJSON — checked field-by-field.
    #[test]
    fn prop_session_header_round_trip_is_lossless() {
        let mut rng = Prng::new(0xbeef);
        let names = ["plain", "with \"quotes\"", "non-ascii 東京 🦀", "", "tab\tand\nnewline"];
        for (i, name) in names.iter().enumerate() {
            let mut h = header(name);
            h.deploy_tag = names[(i + 1) % names.len()].to_string();
            h.scope = names[(i + 2) % names.len()].to_string();
            h.workload_fp = rng.next_u64();
            h.config_digest = rng.next_u64();
            h.total_ops = rng.below(1_000_000);
            h.labels = (0..rng.below(6))
                .map(|k| (format!("{name}.l{k}"), rng.below(10_000)))
                .collect();
            h.shard_count = 1 + rng.below(8);
            h.shard_index = rng.below(h.shard_count);
            h.shard_id = names[rng.below(names.len())].to_string();
            let snap = Snapshot::Session { header: h.clone() };
            let line = snap.to_line();
            let Snapshot::Session { header: back } = Snapshot::parse_line(&line).unwrap() else {
                panic!("round trip changed the variant");
            };
            assert_eq!(back, h, "case {i}: `{line}`");
        }
    }

    /// Directories persisted before shard identity existed decode as
    /// unsharded: absent `shard_*` fields default to `("", 0, 1)`.
    #[test]
    fn pre_shard_session_lines_decode_as_unsharded() {
        let mut h = header("legacy");
        h.shard_id = String::new();
        h.shard_index = 0;
        h.shard_count = 1;
        let line = Snapshot::Session { header: h.clone() }.to_line();
        // strip the shard fields the writer now emits, simulating an
        // old producer
        let legacy = line
            .replace(",\"shard_id\":\"\"", "")
            .replace(",\"shard_index\":0", "")
            .replace(",\"shard_count\":1", "");
        assert_ne!(legacy, line, "the writer must emit shard fields");
        let Snapshot::Session { header: back } = Snapshot::parse_line(&legacy).unwrap() else {
            panic!("legacy session line changed variant");
        };
        assert_eq!(back, h);
        assert!(!back.is_sharded());
    }

    /// The tentpole durability property: the pinned header is written
    /// first and re-written at the top of every rotated file, so it is
    /// still found after the byte budget has dropped the oldest data
    /// files.
    #[test]
    fn session_header_survives_rotation_dropping_oldest_files() {
        let dir = tmp_dir("header-rotate");
        let cfg = SinkConfig { max_snapshot_bytes: 4096, rotate_bytes: 1024 };
        let mut sink = SnapshotSink::new(&dir, "pair-x", cfg).unwrap();
        let h = header("long-session");
        sink.set_header(&Snapshot::Session { header: h.clone() }).unwrap();
        let ev = ResyncEvent { at_ops: 1, skipped_a: 2, skipped_b: 3 };
        for _ in 0..300 {
            sink.append(&Snapshot::Resync { pair: "pair-x".into(), event: ev }).unwrap();
        }
        assert!(sink.dropped_files > 0, "budget must have forced drops");
        // byte accounting stays exact with header re-writes in play
        assert_eq!(sink.written_bytes, sink.total_bytes() + sink.dropped_bytes);
        let replay = Replay::load(&dir).unwrap();
        assert_eq!(replay.sessions.len(), 1, "rotation copies must dedupe to one header");
        assert_eq!(replay.sessions[0], h);
        // the header leads every retained file, so even a single
        // surviving file identifies the session
        let snaps = load_dir(&dir).unwrap();
        assert!(matches!(snaps[0], Snapshot::Session { .. }), "header must be first");
        let _ = fs::remove_dir_all(&dir);
    }

    /// `set_header` before any append puts the header literally first
    /// in the series even when nothing rotates.
    #[test]
    fn session_header_is_first_line_of_the_series() {
        let dir = tmp_dir("header-first");
        let mut sink = SnapshotSink::new(&dir, "p", SinkConfig::default()).unwrap();
        sink.set_header(&Snapshot::Session { header: header("s") }).unwrap();
        sink.append(&Snapshot::Resync {
            pair: "p".into(),
            event: ResyncEvent { at_ops: 1, skipped_a: 0, skipped_b: 1 },
        })
        .unwrap();
        let snaps = load_dir(&dir).unwrap();
        assert_eq!(snaps.len(), 2);
        assert!(matches!(snaps[0], Snapshot::Session { .. }));
        assert!(matches!(snaps[1], Snapshot::Resync { .. }));
        let _ = fs::remove_dir_all(&dir);
    }

    /// The satellite acceptance property: `Snapshot → json → Snapshot`
    /// is lossless for pathological strings (quotes, control chars,
    /// non-ASCII) and bit-exact on floats — checked field-by-field, not
    /// just by render equality.
    #[test]
    fn prop_snapshot_round_trip_is_lossless() {
        let labels = [
            "plain",
            "with \"quotes\" and \\backslashes\\",
            "newline\nand\ttab and \r",
            "control \u{0001}\u{0002}\u{001f}",
            "non-ascii 東京 🦀 Ωμέγα",
            "",
        ];
        let mut rng = Prng::new(0x5eed);
        for (i, label) in labels.iter().enumerate() {
            // floats drawn to hit ugly mantissas, tiny + huge magnitudes
            let mut s = summary(label);
            s.energy_a_j = rng.normal() * 10f64.powi(rng.below(30) as i32 - 15);
            s.wasted_j = rng.f64() / 3.0;
            s.fingerprint_a = rng.next_u64();
            s.fingerprint_b = rng.next_u64();
            let snap = Snapshot::Summary { pair: label.to_string(), summary: s.clone() };
            let line = snap.to_line();
            let back = Snapshot::parse_line(&line).unwrap();
            let Snapshot::Summary { pair, summary: t } = back else {
                panic!("round trip changed the variant");
            };
            assert_eq!(&pair, label, "case {i}");
            assert_eq!(t.energy_a_j.to_bits(), s.energy_a_j.to_bits(), "case {i}");
            assert_eq!(t.wasted_j.to_bits(), s.wasted_j.to_bits(), "case {i}");
            assert_eq!(t.fingerprint_a, s.fingerprint_a, "case {i}");
            assert_eq!(t.fingerprint_b, s.fingerprint_b, "case {i}");
            assert_eq!(t.top_labels[0].0, s.top_labels[0].0, "case {i}");
            assert_eq!(t.ops, s.ops);
            assert_eq!(t.resync_log.len(), s.resync_log.len());

            let mut w = window(i, label);
            w.findings[0].diff_frac = rng.f64();
            let snap = Snapshot::Window { pair: label.to_string(), report: w.clone() };
            let back = Snapshot::parse_line(&snap.to_line()).unwrap();
            let Snapshot::Window { report: r, .. } = back else {
                panic!("round trip changed the variant");
            };
            assert_eq!(r.findings[0].diff_frac.to_bits(), w.findings[0].diff_frac.to_bits());
            assert_eq!(r.findings[0].label, w.findings[0].label);
            assert_eq!(r.seq, w.seq);
        }
    }

    #[test]
    fn peek_seq_travels_as_null() {
        let w = window(WindowReport::PEEK_SEQ, "l");
        let snap = Snapshot::Window { pair: "p".into(), report: w };
        let line = snap.to_line();
        assert!(line.contains("\"seq\":null"), "{line}");
        let Snapshot::Window { report, .. } = Snapshot::parse_line(&line).unwrap() else {
            panic!("variant changed");
        };
        assert_eq!(report.seq, WindowReport::PEEK_SEQ);
    }

    #[test]
    fn malformed_snapshots_are_rejected() {
        for line in [
            "{}",
            r#"{"type":"nope"}"#,
            r#"{"type":"window","pair":"p"}"#,
            r#"{"type":"resync","pair":"p","event":{"at_ops":-1,"skipped_a":0,"skipped_b":0}}"#,
            r#"{"type":"summary","pair":"p","summary":{"ops":1}}"#,
            "not json",
        ] {
            assert!(Snapshot::parse_line(line).is_err(), "`{line}` should be rejected");
        }
    }

    #[test]
    fn sink_rotates_and_honours_byte_budget() {
        let dir = tmp_dir("rotate");
        let cfg = SinkConfig { max_snapshot_bytes: 4096, rotate_bytes: 1024 };
        let mut sink = SnapshotSink::new(&dir, "pair-x", cfg).unwrap();
        let ev = ResyncEvent { at_ops: 1, skipped_a: 2, skipped_b: 3 };
        for _ in 0..200 {
            sink.append(&Snapshot::Resync { pair: "pair-x".into(), event: ev }).unwrap();
        }
        assert_eq!(sink.written, 200);
        assert!(sink.dropped_files > 0, "budget should have forced drops");
        assert!(
            sink.total_bytes() <= 4096,
            "retained {} bytes > 4096 budget",
            sink.total_bytes()
        );
        // accounting is exact: written = retained + dropped
        assert_eq!(sink.written_bytes, sink.total_bytes() + sink.dropped_bytes);
        // on-disk state agrees with the sink's view
        let on_disk: Vec<_> = fs::read_dir(&dir).unwrap().map(|e| e.unwrap().path()).collect();
        assert_eq!(on_disk.len(), sink.retained_files());
        let disk_bytes: u64 =
            on_disk.iter().map(|p| fs::metadata(p).unwrap().len()).sum();
        assert_eq!(disk_bytes, sink.total_bytes());
        // the retained suffix still parses, in order
        let snaps = load_dir(&dir).unwrap();
        assert!(!snaps.is_empty() && snaps.len() < 200);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn sink_unbounded_budget_keeps_everything() {
        let dir = tmp_dir("unbounded");
        let cfg = SinkConfig { max_snapshot_bytes: 0, rotate_bytes: 512 };
        let mut sink = SnapshotSink::new(&dir, "p", cfg).unwrap();
        for i in 0..50 {
            sink.append(&Snapshot::Resync {
                pair: "p".into(),
                event: ResyncEvent { at_ops: i, skipped_a: 0, skipped_b: 1 },
            })
            .unwrap();
        }
        assert_eq!(sink.dropped_files, 0);
        let snaps = load_dir(&dir).unwrap();
        assert_eq!(snaps.len(), 50);
        // write order is preserved across file rotation
        for (i, s) in snaps.iter().enumerate() {
            let Snapshot::Resync { event, .. } = s else { panic!("variant changed") };
            assert_eq!(event.at_ops, i);
        }
        let _ = fs::remove_dir_all(&dir);
    }

    /// A crash mid-append leaves an unterminated trailing fragment;
    /// replay must skip exactly that fragment and keep every intact
    /// line — the read-side half of the sink's durability guarantee.
    #[test]
    fn torn_trailing_line_is_skipped_on_replay() {
        let dir = tmp_dir("torn");
        let mut sink = SnapshotSink::new(&dir, "p", SinkConfig::default()).unwrap();
        for i in 0..5 {
            sink.append(&Snapshot::Resync {
                pair: "p".into(),
                event: ResyncEvent { at_ops: i, skipped_a: 0, skipped_b: 1 },
            })
            .unwrap();
        }
        // simulate the crash: a partial line (no trailing newline),
        // torn mid-way through a multi-byte UTF-8 char for good measure
        use std::io::Write as _;
        let path = dir.join("p-000000.ndjson");
        let mut f = OpenOptions::new().append(true).open(&path).unwrap();
        f.write_all(b"{\"type\":\"resync\",\"pair\":\"\xf0\x9f\xa6").unwrap();
        let snaps = load_dir(&dir).expect("torn tail must not fail the replay");
        assert_eq!(snaps.len(), 5, "every intact line survives");
        // a newline-terminated garbage line is real corruption: error
        f.write_all(b"ADE\"}\nnot json\n").unwrap();
        assert!(load_dir(&dir).is_err());
        let _ = fs::remove_dir_all(&dir);
    }

    /// A sink whose directory is removed out from under it must fail
    /// with a typed error on the next rotation, never panic: fleet
    /// workers count sink errors and keep auditing.
    #[test]
    fn sink_io_failure_after_directory_removal_is_a_typed_error() {
        let dir = tmp_dir("sink-dir-removed");
        let cfg = SinkConfig { max_snapshot_bytes: 0, rotate_bytes: 256 };
        let mut sink = SnapshotSink::new(&dir, "p", cfg).unwrap();
        let ev = ResyncEvent { at_ops: 1, skipped_a: 0, skipped_b: 1 };
        sink.append(&Snapshot::Resync { pair: "p".into(), event: ev }).unwrap();
        fs::remove_dir_all(&dir).unwrap();
        // appends into the unlinked current file may still succeed (the
        // inode lives on); the next rotation must open a file in the
        // missing directory and error — typed, not unwinding
        let mut failed = 0usize;
        for _ in 0..64 {
            if sink.append(&Snapshot::Resync { pair: "p".into(), event: ev }).is_err() {
                failed += 1;
            }
        }
        assert!(failed > 0, "a removed directory must surface as append errors");
        // the sink stays usable as an object: accounting intact, no panic
        assert_eq!(sink.written_bytes, sink.total_bytes() + sink.dropped_bytes);
    }

    /// The torn-tail split: a fragment on the newest file of a series
    /// is a writer mid-append (`torn_final`); completing the line later
    /// clears it. A fragment on a file the sink already rotated past is
    /// real damage (`torn_interior`).
    #[test]
    fn torn_tail_on_newest_file_completes_on_a_later_scan() {
        use std::io::Write as _;
        let dir = tmp_dir("torn-split");
        let mut sink = SnapshotSink::new(&dir, "p", SinkConfig::default()).unwrap();
        for i in 0..3 {
            sink.append(&Snapshot::Resync {
                pair: "p".into(),
                event: ResyncEvent { at_ops: i, skipped_a: 0, skipped_b: 1 },
            })
            .unwrap();
        }
        // fault injection: append the first half of a line, as a live
        // writer's interrupted write_all would
        let line = Snapshot::Resync {
            pair: "p".into(),
            event: ResyncEvent { at_ops: 99, skipped_a: 0, skipped_b: 1 },
        }
        .to_line();
        let (half, rest) = line.split_at(line.len() / 2);
        let path = dir.join("p-000000.ndjson");
        let mut f = OpenOptions::new().append(true).open(&path).unwrap();
        f.write_all(half.as_bytes()).unwrap();
        let scan = scan_dir(&dir).unwrap();
        assert_eq!(scan.torn_final, 1, "mid-append tail is final, not interior");
        assert_eq!(scan.torn_interior, 0);
        assert_eq!(scan.torn_fragments(), 1);
        assert_eq!(scan.files[0].snapshots.len(), 3, "intact lines unaffected");
        // the writer completes the line: the tear disappears
        f.write_all(rest.as_bytes()).unwrap();
        f.write_all(b"\n").unwrap();
        let scan = scan_dir(&dir).unwrap();
        assert_eq!((scan.torn_final, scan.torn_interior), (0, 0));
        assert_eq!(scan.files[0].snapshots.len(), 4, "the completed line now parses");
        // the same tear on a non-newest file is interior damage
        fs::write(dir.join("p-000001.ndjson"), b"").unwrap();
        let mut f = OpenOptions::new().append(true).open(&path).unwrap();
        f.write_all(half.as_bytes()).unwrap();
        let scan = scan_dir(&dir).unwrap();
        assert_eq!((scan.torn_final, scan.torn_interior), (0, 1));
        let _ = fs::remove_dir_all(&dir);
    }

    /// The listing/open rotation race: a file listed but deleted before
    /// its open is skipped and counted, not a whole-load failure. Any
    /// other IO error stays fatal.
    #[test]
    fn file_rotated_away_between_listing_and_open_is_skipped_and_counted() {
        let dir = tmp_dir("vanish-race");
        let cfg = SinkConfig { max_snapshot_bytes: 0, rotate_bytes: 128 };
        let mut sink = SnapshotSink::new(&dir, "p", cfg).unwrap();
        for i in 0..20 {
            sink.append(&Snapshot::Resync {
                pair: "p".into(),
                event: ResyncEvent { at_ops: i, skipped_a: 0, skipped_b: 1 },
            })
            .unwrap();
        }
        assert!(sink.retained_files() >= 3, "need a rotated series");
        // the injected race: the second file is deleted between the
        // listing (which saw it) and the open
        let victim = dir.join("p-000001.ndjson");
        let scan = scan_dir_with(&dir, |p: &Path| {
            if p == victim {
                fs::remove_file(p)?;
            }
            File::open(p)
        })
        .unwrap();
        assert_eq!(scan.vanished, 1, "the raced file is counted, not fatal");
        assert!(scan.files.iter().all(|f| f.path != victim));
        assert!(!scan.files.is_empty(), "surviving files still load");
        // a non-NotFound IO error is real and still fails the scan
        let denied = scan_dir_with(&dir, |_p: &Path| -> std::io::Result<File> {
            Err(std::io::Error::new(std::io::ErrorKind::PermissionDenied, "injected"))
        });
        assert!(denied.is_err());
        let _ = fs::remove_dir_all(&dir);
    }

    /// Replay order is reconstructed from the parsed rotation index, so
    /// it survives the index growing a digit (where raw lexicographic
    /// order would put `-1000000` before `-0999999`).
    #[test]
    fn file_order_survives_index_width_growth() {
        let key = |s: &str| file_order_key(Path::new(s));
        assert!(key("p-0999999.ndjson") < key("p-1000000.ndjson"));
        assert!(key("p-000009.ndjson") < key("p-000010.ndjson"));
        // distinct sinks stay grouped by prefix
        assert!(key("a-000001.ndjson") < key("b-000000.ndjson"));
        // non-sink files fall back to name order without panicking
        assert!(key("aaa.ndjson") < key("bbb.ndjson"));
    }

    /// `rotate_bytes: 0` disables per-file rotation (one growing file)
    /// instead of panicking — a user-settable config must degrade, not
    /// take down a fleet worker.
    #[test]
    fn zero_rotate_bytes_means_single_growing_file() {
        let dir = tmp_dir("norotate");
        let cfg = SinkConfig { max_snapshot_bytes: 1024, rotate_bytes: 0 };
        let mut sink = SnapshotSink::new(&dir, "p", cfg).unwrap();
        let ev = ResyncEvent { at_ops: 1, skipped_a: 0, skipped_b: 1 };
        for _ in 0..100 {
            sink.append(&Snapshot::Resync { pair: "p".into(), event: ev }).unwrap();
        }
        // one file, never rotated; the current file is never dropped,
        // so the budget cannot delete anything either
        assert_eq!(sink.retained_files(), 1);
        assert_eq!(sink.dropped_files, 0);
        assert_eq!(load_dir(&dir).unwrap().len(), 100);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn sanitize_stem_neutralises_path_separators() {
        assert_eq!(sanitize_stem("serving-0"), "serving-0");
        assert_eq!(sanitize_stem("../../etc/passwd"), "------etc-passwd");
        assert_eq!(sanitize_stem("a/b\\c d"), "a-b-c-d");
        assert_eq!(sanitize_stem(""), "snap");
    }

    #[test]
    fn replay_groups_and_verifies_ranking() {
        let dir = tmp_dir("replay");
        let mut sink = SnapshotSink::new(&dir, "fleet", SinkConfig::default()).unwrap();
        let mut s0 = summary("serve.proj");
        s0.wasted_j = 2.5;
        let mut s1 = summary("serve.out");
        s1.wasted_j = 0.5;
        sink.append(&Snapshot::Summary { pair: "hot".into(), summary: s0.clone() }).unwrap();
        sink.append(&Snapshot::Summary { pair: "cool".into(), summary: s1.clone() }).unwrap();
        let rank = |name: &str, s: &StreamSummary| RankEntry {
            name: name.to_string(),
            wasted_j: s.wasted_j,
            ops: s.ops,
            windows: s.windows,
            windows_flagged: s.windows_flagged,
            resyncs: s.resyncs,
            aligned: s.aligned,
        };
        sink.append(&Snapshot::Fleet { ranking: vec![rank("hot", &s0), rank("cool", &s1)] })
            .unwrap();
        let replay = Replay::load(&dir).unwrap();
        assert_eq!(replay.summaries.len(), 2);
        assert_eq!(replay.rankings.len(), 1);
        assert_eq!(replay.verify_ranking(), Ok(2));
        assert!(replay.summary_of("hot").is_some());
        assert!(replay.summary_of("missing").is_none());

        // a tampered ledger no longer verifies
        let mut bad = Replay::load(&dir).unwrap();
        bad.rankings[0][0].wasted_j += 1e-9;
        assert!(bad.verify_ranking().is_err());
        // out-of-order ranking no longer verifies
        let mut swapped = Replay::load(&dir).unwrap();
        swapped.rankings[0].swap(0, 1);
        assert!(swapped.verify_ranking().is_err());
        let _ = fs::remove_dir_all(&dir);
    }
}
