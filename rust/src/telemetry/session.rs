//! Cross-session workload matching and differential replay — the
//! longitudinal arm of the paper's differential model.
//!
//! The batch and streaming auditors compare two *systems* running the
//! same workload at the same time. Production regressions more often
//! arrive the other way round: the **same system, days apart** — a new
//! deploy, a config push, a driver update — quietly spending more
//! energy on the same traffic. This module turns the persisted snapshot
//! store ([`crate::telemetry`]) into that comparison:
//!
//! * [`SessionInfo`] loads one snapshot directory as a *session*: its
//!   [`SessionHeader`]s (one per sink scope, deduped across rotation
//!   re-writes), replayed reports, and per-label ledgers;
//! * [`SessionIndex::scan`] indexes many directories lazily — only
//!   each file's first NDJSON line (the pinned session header) is
//!   read, so thousands of shard directories index in O(files) bytes —
//!   and [`SessionIndex::groups`] clusters the sessions whose workload
//!   fingerprints match — exactly, or tolerantly on label-multiset
//!   overlap for partially-overlapping runs;
//! * [`diff_sessions`] pairs two sessions of the same workload: it
//!   refuses incomparable pairs with a reasoned diagnostic, re-anchors
//!   their persisted window sequences by matched-op position (the same
//!   minimal-skip logic the live resync uses, applied to persisted
//!   window fingerprints instead of pending op queues), and runs the
//!   differential detector over the paired per-label energy ledgers,
//!   producing a ranked [`SessionDiff`]
//!   ([`crate::report::render_session_diff`], `magneton diff`).
//!
//! Side convention: within each session, side A is the system under
//! audit and side B its in-session reference, so the cross-session
//! comparison differences the two sessions' **side-A** ledgers (and
//! reports each session's own waste verdicts alongside).

use std::collections::BTreeMap;
use std::io::Read;
use std::path::{Path, PathBuf};

use crate::telemetry::{snapshot_files, Replay, SessionHeader, Snapshot};
use crate::{Error, Result};

/// One snapshot directory loaded as a session.
pub struct SessionInfo {
    pub dir: PathBuf,
    /// Distinct per-scope headers (a `magneton stream` directory holds
    /// the single pair's scope plus one per fleet pair).
    pub headers: Vec<SessionHeader>,
    pub replay: Replay,
}

impl SessionInfo {
    /// Load a snapshot directory as one session. Fails when the
    /// directory has no [`SessionHeader`] (written by sinks configured
    /// with a session identity), when two headers claim the same scope
    /// with different content (two sessions mixed into one directory),
    /// or when the headers disagree on the session identity.
    pub fn load(dir: &Path) -> Result<SessionInfo> {
        let replay = Replay::load(dir)?;
        let headers = replay.sessions.clone();
        SessionInfo::validate_headers(dir, &headers)?;
        Ok(SessionInfo { dir: dir.to_path_buf(), headers, replay })
    }

    /// The header invariants shared by the full [`SessionInfo::load`]
    /// and the lazy [`SessionIndex::scan`]: headers must exist, agree
    /// per scope, and agree on the session identity.
    fn validate_headers(dir: &Path, headers: &[SessionHeader]) -> Result<()> {
        if headers.is_empty() {
            return Err(Error::msg(format!(
                "{}: no session header found — the directory was persisted without a session \
                 identity (re-run `magneton stream --snapshot-dir` with --session-id, or an \
                 auditor with a session header set)",
                dir.display()
            )));
        }
        let mut scopes: BTreeMap<&str, &SessionHeader> = BTreeMap::new();
        for h in headers {
            if let Some(prev) = scopes.insert(h.scope.as_str(), h) {
                if *prev != *h {
                    return Err(Error::msg(format!(
                        "{}: conflicting session headers for scope `{}` — the directory mixes \
                         more than one session (use a fresh directory per run)",
                        dir.display(),
                        h.scope
                    )));
                }
            }
            if h.session_id != headers[0].session_id || h.deploy_tag != headers[0].deploy_tag {
                return Err(Error::msg(format!(
                    "{}: headers disagree on the session identity (`{}` vs `{}`)",
                    dir.display(),
                    headers[0].session_id,
                    h.session_id
                )));
            }
        }
        Ok(())
    }

    /// Load only the session headers of a directory — the lazy scan
    /// behind [`SessionIndex::scan`]. Reads the **first NDJSON line**
    /// of each snapshot file (the sink pins the session header there
    /// and re-writes it at the top of every rotated file), in bounded
    /// chunks, so indexing a directory costs O(files) bytes instead of
    /// O(snapshot bytes). The returned session's `replay` is empty;
    /// use [`SessionInfo::load`] when the reports themselves are
    /// needed (e.g. `magneton diff`).
    ///
    /// `open` abstracts the reader so tests can count bytes actually
    /// read; production passes `File::open`.
    pub fn load_headers_with<R, F>(dir: &Path, open: &mut F) -> Result<SessionInfo>
    where
        R: Read,
        F: FnMut(&Path) -> std::io::Result<R>,
    {
        let mut headers: Vec<SessionHeader> = Vec::new();
        for path in snapshot_files(dir)? {
            let reader = open(&path)
                .map_err(|e| Error::msg(format!("open {}: {e}", path.display())))?;
            let line = first_line(reader)
                .map_err(|e| Error::msg(format!("read {}: {e}", path.display())))?;
            // a file with no newline at all is empty or one torn
            // fragment — skipped, exactly like the full replay skips
            // torn trailing fragments
            let Some(line) = line else { continue };
            if line.trim().is_empty() {
                continue;
            }
            let snap = Snapshot::parse_line(&line)
                .map_err(|e| e.context(format!("{} line 1", path.display())))?;
            // files whose first line is not a header (e.g. the fleet
            // ranking sink, or a sink without a session identity)
            // contribute no header but stay valid snapshot files
            if let Snapshot::Session { header } = snap {
                if !headers.contains(&header) {
                    headers.push(header);
                }
            }
        }
        SessionInfo::validate_headers(dir, &headers)?;
        Ok(SessionInfo { dir: dir.to_path_buf(), headers, replay: Replay::default() })
    }

    pub fn session_id(&self) -> &str {
        &self.headers[0].session_id
    }

    pub fn deploy_tag(&self) -> &str {
        &self.headers[0].deploy_tag
    }

    /// Combined workload fingerprint across the session's scopes (the
    /// commutative multiset fold, so scope order is irrelevant).
    pub fn combined_fp(&self) -> u64 {
        self.headers.iter().fold(0u64, |acc, h| acc.wrapping_add(h.workload_fp))
    }

    /// Total kernel ops across the session's scopes.
    pub fn total_ops(&self) -> usize {
        self.headers.iter().map(|h| h.total_ops).sum()
    }

    /// Combined per-label op counts across scopes.
    pub fn label_counts(&self) -> BTreeMap<String, usize> {
        let mut out = BTreeMap::new();
        for h in &self.headers {
            for (label, n) in &h.labels {
                *out.entry(label.clone()).or_insert(0) += n;
            }
        }
        out
    }

    /// Display name: `session_id` plus the deploy tag when present.
    pub fn display_name(&self) -> String {
        if self.deploy_tag().is_empty() {
            self.session_id().to_string()
        } else {
            format!("{} ({})", self.session_id(), self.deploy_tag())
        }
    }

    /// Aggregated per-label side costs across the session's pairs
    /// (latest ledger per pair): `label -> (ops, energy_a, energy_b)`.
    fn aggregated_ledger(&self) -> BTreeMap<String, (usize, f64, f64)> {
        let mut out: BTreeMap<String, (usize, f64, f64)> = BTreeMap::new();
        for pair in self.pair_names_with_ledgers() {
            let Some(entries) = self.replay.ledger_of(&pair) else { continue };
            for e in entries {
                let cell = out.entry(e.label.clone()).or_insert((0, 0.0, 0.0));
                cell.0 += e.ops;
                cell.1 += e.energy_a_j;
                cell.2 += e.energy_b_j;
            }
        }
        out
    }

    /// Distinct pair names that persisted a ledger, in first-seen order.
    fn pair_names_with_ledgers(&self) -> Vec<String> {
        let mut seen = Vec::new();
        for (pair, _) in &self.replay.ledgers {
            if !seen.iter().any(|p| p == pair) {
                seen.push(pair.clone());
            }
        }
        seen
    }

    /// Aggregated per-label ledgered waste across the session's pairs
    /// (latest summary per pair): `label -> wasted_j`.
    fn aggregated_waste(&self) -> BTreeMap<String, f64> {
        let mut out: BTreeMap<String, f64> = BTreeMap::new();
        for pair in self.pair_names_with_summaries() {
            let Some(s) = self.replay.summary_of(&pair) else { continue };
            for (label, j, _) in &s.top_labels {
                *out.entry(label.clone()).or_insert(0.0) += j;
            }
        }
        out
    }

    fn pair_names_with_summaries(&self) -> Vec<String> {
        let mut seen = Vec::new();
        for (pair, _) in &self.replay.summaries {
            if !seen.iter().any(|p| p == pair) {
                seen.push(pair.clone());
            }
        }
        seen
    }

    /// Total ledgered waste and resync count across pairs.
    fn aggregated_summary_counters(&self) -> (f64, usize) {
        let mut wasted = 0.0;
        let mut resyncs = 0;
        for pair in self.pair_names_with_summaries() {
            if let Some(s) = self.replay.summary_of(&pair) {
                wasted += s.wasted_j;
                resyncs += s.resyncs;
            }
        }
        (wasted, resyncs)
    }
}

/// How strictly two sessions must agree to be considered the same
/// workload.
#[derive(Clone, Copy, Debug)]
pub enum MatchMode {
    /// Identical combined fingerprints and op counts.
    Exact,
    /// Label-multiset overlap of at least `min_overlap` (partially
    /// overlapping runs: a deploy that added or removed some call
    /// sites but mostly serves the same traffic).
    Tolerant { min_overlap: f64 },
}

/// Outcome of matching two sessions' workload fingerprints.
#[derive(Clone, Debug, PartialEq)]
pub enum MatchVerdict {
    /// Bit-identical combined fingerprints (and op counts).
    Exact,
    /// Fingerprints differ but the label multisets overlap by this
    /// fraction (≥ the tolerant threshold).
    Tolerant { overlap: f64 },
    /// The sessions did not run the same workload; the reason explains
    /// why (and is what `magneton diff` prints when refusing).
    Incomparable { reason: String },
}

/// Weighted label-multiset overlap of two sessions:
/// `Σ_label min(ops_a, ops_b) / max(total_a, total_b)` — 1.0 for
/// identical multisets, 0.0 for disjoint ones.
pub fn label_overlap(a: &BTreeMap<String, usize>, b: &BTreeMap<String, usize>) -> f64 {
    let total_a: usize = a.values().sum();
    let total_b: usize = b.values().sum();
    let denom = total_a.max(total_b);
    if denom == 0 {
        return 0.0;
    }
    let shared: usize = a
        .iter()
        .map(|(label, &na)| na.min(b.get(label).copied().unwrap_or(0)))
        .sum();
    shared as f64 / denom as f64
}

/// The largest per-label count differences between two multisets, for
/// diagnostics: `(label, ops_a, ops_b)`, biggest absolute gap first.
fn top_label_gaps(
    a: &BTreeMap<String, usize>,
    b: &BTreeMap<String, usize>,
    top: usize,
) -> Vec<(String, usize, usize)> {
    let mut gaps: Vec<(String, usize, usize)> = a
        .iter()
        .map(|(l, &na)| (l.clone(), na, b.get(l).copied().unwrap_or(0)))
        .chain(
            b.iter()
                .filter(|(l, _)| !a.contains_key(*l))
                .map(|(l, &nb)| (l.clone(), 0, nb)),
        )
        .filter(|&(_, na, nb)| na != nb)
        .collect();
    gaps.sort_by(|x, y| {
        let gx = x.1.abs_diff(x.2);
        let gy = y.1.abs_diff(y.2);
        gy.cmp(&gx).then_with(|| x.0.cmp(&y.0))
    });
    gaps.truncate(top);
    gaps
}

/// Match two sessions' workload fingerprints under `mode`.
pub fn match_sessions(a: &SessionInfo, b: &SessionInfo, mode: MatchMode) -> MatchVerdict {
    if a.total_ops() == 0 || b.total_ops() == 0 {
        return MatchVerdict::Incomparable {
            reason: "a session declares zero kernel ops — nothing to compare".to_string(),
        };
    }
    if a.combined_fp() == b.combined_fp() && a.total_ops() == b.total_ops() {
        return MatchVerdict::Exact;
    }
    let la = a.label_counts();
    let lb = b.label_counts();
    let overlap = label_overlap(&la, &lb);
    match mode {
        MatchMode::Tolerant { min_overlap } if overlap >= min_overlap => {
            MatchVerdict::Tolerant { overlap }
        }
        _ => {
            let gaps = top_label_gaps(&la, &lb, 4);
            let gap_lines: Vec<String> = gaps
                .iter()
                .map(|(l, na, nb)| format!("`{l}` {na} vs {nb} ops"))
                .collect();
            let hint = match mode {
                MatchMode::Exact => {
                    "; pass --tolerant to match partially-overlapping runs".to_string()
                }
                MatchMode::Tolerant { min_overlap } => {
                    format!(" (below the tolerant threshold {:.0}%)", min_overlap * 100.0)
                }
            };
            MatchVerdict::Incomparable {
                reason: format!(
                    "workload fingerprints do not match: {:016x} ({} ops) vs {:016x} ({} ops), \
                     label-multiset overlap {:.1}%{}{}",
                    a.combined_fp(),
                    a.total_ops(),
                    b.combined_fp(),
                    b.total_ops(),
                    overlap * 100.0,
                    if gap_lines.is_empty() {
                        String::new()
                    } else {
                        format!("; largest gaps: {}", gap_lines.join(", "))
                    },
                    hint
                ),
            }
        }
    }
}

/// Read a reader's first newline-terminated line in fixed-size chunks,
/// stopping at the first `\n` — the primitive that keeps the session
/// index's per-file cost bounded by the header line, not the file.
/// `None` when the reader holds no newline (empty file or a single
/// torn fragment).
fn first_line<R: Read>(mut r: R) -> std::io::Result<Option<String>> {
    let mut buf = Vec::new();
    let mut chunk = [0u8; 512];
    loop {
        let n = r.read(&mut chunk)?;
        if n == 0 {
            return Ok(None);
        }
        if let Some(pos) = chunk[..n].iter().position(|&b| b == b'\n') {
            buf.extend_from_slice(&chunk[..pos]);
            return Ok(Some(String::from_utf8_lossy(&buf).into_owned()));
        }
        buf.extend_from_slice(&chunk[..n]);
    }
}

/// An index over persisted sessions (one per scanned directory).
pub struct SessionIndex {
    pub sessions: Vec<SessionInfo>,
}

impl SessionIndex {
    /// Index every directory as one session — a **lazy header-only
    /// scan**: only the first NDJSON line of each snapshot file is
    /// read (in bounded chunks), so indexing thousands of shard
    /// directories costs O(files) bytes rather than re-parsing every
    /// persisted window. The indexed sessions carry headers only
    /// (`replay` is empty); [`SessionIndex::groups`] needs nothing
    /// more, and callers that go on to diff a session load it fully
    /// with [`SessionInfo::load`]. Directories without any session
    /// header are still refused with the same diagnostic as the full
    /// load.
    pub fn scan(dirs: &[PathBuf]) -> Result<SessionIndex> {
        SessionIndex::scan_with(dirs, &mut |p: &Path| std::fs::File::open(p))
    }

    /// [`SessionIndex::scan`] with an injectable reader factory, so
    /// tests can meter exactly how many bytes the lazy scan touches.
    pub fn scan_with<R, F>(dirs: &[PathBuf], open: &mut F) -> Result<SessionIndex>
    where
        R: Read,
        F: FnMut(&Path) -> std::io::Result<R>,
    {
        let mut sessions = Vec::new();
        for dir in dirs {
            sessions.push(SessionInfo::load_headers_with(dir, open)?);
        }
        Ok(SessionIndex { sessions })
    }

    /// Group session indices whose workloads match under `mode`
    /// (greedy: the first unclaimed session seeds a group and absorbs
    /// every later session matching it). Deterministic in scan order.
    pub fn groups(&self, mode: MatchMode) -> Vec<Vec<usize>> {
        let mut claimed = vec![false; self.sessions.len()];
        let mut out = Vec::new();
        for i in 0..self.sessions.len() {
            if claimed[i] {
                continue;
            }
            claimed[i] = true;
            let mut group = vec![i];
            for j in i + 1..self.sessions.len() {
                if claimed[j] {
                    continue;
                }
                let v = match_sessions(&self.sessions[i], &self.sessions[j], mode);
                if !matches!(v, MatchVerdict::Incomparable { .. }) {
                    claimed[j] = true;
                    group.push(j);
                }
            }
            out.push(group);
        }
        out
    }
}

/// Configuration of a cross-session diff.
#[derive(Clone, Debug)]
pub struct DiffConfig {
    pub mode: MatchMode,
    /// Minimum relative per-label energy delta for the renderer to mark
    /// a row REGRESSED/improved (mirrors the detector's threshold).
    pub energy_threshold: f64,
    /// Bounded lookahead of the window re-anchoring search.
    pub align_lookahead: usize,
}

impl Default for DiffConfig {
    fn default() -> DiffConfig {
        DiffConfig { mode: MatchMode::Exact, energy_threshold: 0.10, align_lookahead: 16 }
    }
}

/// One label's cross-session energy delta (session B minus session A,
/// on each session's side-A ledger).
#[derive(Clone, Debug)]
pub struct LabelDelta {
    pub label: String,
    pub ops_a: usize,
    pub ops_b: usize,
    /// Session A's audited-side energy under this label.
    pub energy_a_j: f64,
    /// Session B's audited-side energy under this label.
    pub energy_b_j: f64,
    /// `energy_b_j - energy_a_j`: positive = the newer session spends
    /// more on the same label (a regression candidate).
    pub delta_j: f64,
    /// `|delta_j| / max(energy_a_j, energy_b_j)`.
    pub delta_frac: f64,
    /// Each session's own ledgered waste under this label (vs its
    /// in-session reference side).
    pub waste_a_j: f64,
    pub waste_b_j: f64,
}

/// How the two sessions' persisted window sequences aligned.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct WindowAlignment {
    /// Window pairs whose fingerprints matched positionally.
    pub aligned: usize,
    /// Re-anchoring events (a minimal-skip anchor was found).
    pub realigns: usize,
    /// Windows skipped from session A to re-anchor (tail surplus
    /// included).
    pub skipped_a: usize,
    /// Windows skipped from session B.
    pub skipped_b: usize,
    /// Positions force-advanced with no anchor inside the lookahead.
    pub forced: usize,
}

/// A ranked cross-session regression report.
pub struct SessionDiff {
    pub session_a: String,
    pub session_b: String,
    pub verdict: MatchVerdict,
    /// Comparability caveats (config digest mismatch, arrival mismatch,
    /// per-label op-count drift) — flagged, not fatal.
    pub notes: Vec<String>,
    /// Labels present in both sessions, ranked regressions-first
    /// (`delta_j` descending).
    pub labels: Vec<LabelDelta>,
    /// Labels only session B ran: `(label, energy_b_j)`, energy
    /// descending.
    pub new_labels: Vec<(String, f64)>,
    /// Labels only session A ran: `(label, energy_a_j)`.
    pub vanished_labels: Vec<(String, f64)>,
    /// Audited-side session totals.
    pub total_a_j: f64,
    pub total_b_j: f64,
    /// Each session's own ledgered waste total.
    pub wasted_a_j: f64,
    pub wasted_b_j: f64,
    /// Divergence-event deltas: per-session resync and fleet-divergence
    /// counts.
    pub resyncs_a: usize,
    pub resyncs_b: usize,
    pub divergences_a: usize,
    pub divergences_b: usize,
    /// Window-sequence alignment summed over the pairs common to both
    /// sessions.
    pub windows: WindowAlignment,
    /// The render threshold the diff was computed under.
    pub energy_threshold: f64,
}

impl SessionDiff {
    /// Largest relative per-label regression (0.0 when session B
    /// improved or held everywhere).
    pub fn max_regression_frac(&self) -> f64 {
        self.labels
            .iter()
            .filter(|d| d.delta_j > 0.0)
            .map(|d| d.delta_frac)
            .fold(0.0, f64::max)
    }

    /// Relative session-level energy delta (positive = session B
    /// spends more overall).
    pub fn total_delta_frac(&self) -> f64 {
        let denom = self.total_a_j.max(self.total_b_j);
        if denom <= 0.0 {
            0.0
        } else {
            (self.total_b_j - self.total_a_j) / denom
        }
    }

    /// The `--regress-threshold` gate: true when the session-level
    /// delta or any single label regressed by at least `threshold`.
    pub fn regressed(&self, threshold: f64) -> bool {
        self.total_delta_frac() >= threshold || self.max_regression_frac() >= threshold
    }
}

/// Re-anchor two persisted window-fingerprint sequences by matched-op
/// position: positional pairing while fingerprints agree, and on a
/// mismatch a minimal-total-skip anchor search over a bounded lookahead
/// — the same shape as the live resync, run over persisted windows
/// instead of pending op queues.
pub fn align_windows(a: &[u64], b: &[u64], lookahead: usize) -> WindowAlignment {
    let mut out = WindowAlignment::default();
    let (mut i, mut j) = (0usize, 0usize);
    while i < a.len() && j < b.len() {
        if a[i] == b[j] {
            out.aligned += 1;
            i += 1;
            j += 1;
            continue;
        }
        // minimal total surplus first: the cheapest explanation of the
        // divergence, exactly like the live anchor search
        let mut found = None;
        'search: for d in 1..=(2 * lookahead.max(1)) {
            let lo = d.saturating_sub(lookahead);
            for da in lo..=d.min(lookahead) {
                let db = d - da;
                if i + da < a.len() && j + db < b.len() && a[i + da] == b[j + db] {
                    found = Some((da, db));
                    break 'search;
                }
            }
        }
        match found {
            Some((da, db)) => {
                out.realigns += 1;
                out.skipped_a += da;
                out.skipped_b += db;
                i += da;
                j += db;
            }
            None => {
                out.forced += 1;
                i += 1;
                j += 1;
            }
        }
    }
    // unmatched tails never aligned
    out.skipped_a += a.len() - i;
    out.skipped_b += b.len() - j;
    out
}

/// Pair names common to both sessions' persisted windows, in session
/// A's first-seen order.
fn common_window_pairs(a: &SessionInfo, b: &SessionInfo) -> Vec<String> {
    let mut names = Vec::new();
    for (pair, _) in &a.replay.windows {
        if !names.iter().any(|n| n == pair) && b.replay.windows.iter().any(|(p, _)| p == pair) {
            names.push(pair.clone());
        }
    }
    names
}

/// Diff two persisted sessions of the same workload. Refuses
/// incomparable sessions with the match diagnostic as the error; on a
/// match, differences the aggregated side-A label ledgers, aligns the
/// common pairs' window sequences, and returns the ranked
/// [`SessionDiff`].
pub fn diff_sessions(a: &SessionInfo, b: &SessionInfo, cfg: &DiffConfig) -> Result<SessionDiff> {
    let verdict = match_sessions(a, b, cfg.mode);
    if let MatchVerdict::Incomparable { reason } = &verdict {
        return Err(Error::msg(format!(
            "sessions {} and {} are not comparable: {reason}",
            a.display_name(),
            b.display_name()
        )));
    }
    let ledger_a = a.aggregated_ledger();
    let ledger_b = b.aggregated_ledger();
    if ledger_a.is_empty() || ledger_b.is_empty() {
        return Err(Error::msg(
            "a session has no persisted per-label ledger (`finish` never ran or the directory \
             predates ledger snapshots) — nothing to difference",
        ));
    }
    let waste_a = a.aggregated_waste();
    let waste_b = b.aggregated_waste();

    let mut notes = Vec::new();
    // config digests decide whether window sequences are comparable
    let digests_match = {
        let da: Vec<u64> = a.headers.iter().map(|h| h.config_digest).collect();
        let db: Vec<u64> = b.headers.iter().map(|h| h.config_digest).collect();
        da.iter().all(|d| db.contains(d)) && db.iter().all(|d| da.contains(d))
    };
    if !digests_match {
        notes.push(
            "stream/detect configs differ between the sessions: window alignment skipped, \
             ledger deltas remain valid"
                .to_string(),
        );
    }
    let arrivals_a: Vec<&str> = a.headers.iter().map(|h| h.arrival.as_str()).collect();
    let arrivals_b: Vec<&str> = b.headers.iter().map(|h| h.arrival.as_str()).collect();
    if arrivals_a != arrivals_b {
        notes.push(format!(
            "arrival processes differ ({} vs {}): idle-power timelines are not comparable, \
             per-op energies are",
            arrivals_a.join("/"),
            arrivals_b.join("/")
        ));
    }

    let mut labels = Vec::new();
    let mut vanished_labels = Vec::new();
    let mut drifted = 0usize;
    for (label, &(ops_a, ea, _)) in &ledger_a {
        match ledger_b.get(label) {
            Some(&(ops_b, eb, _)) => {
                if ops_a != ops_b {
                    drifted += 1;
                }
                let delta_j = eb - ea;
                let denom = ea.max(eb);
                labels.push(LabelDelta {
                    label: label.clone(),
                    ops_a,
                    ops_b,
                    energy_a_j: ea,
                    energy_b_j: eb,
                    delta_j,
                    delta_frac: if denom > 0.0 { delta_j.abs() / denom } else { 0.0 },
                    waste_a_j: waste_a.get(label).copied().unwrap_or(0.0),
                    waste_b_j: waste_b.get(label).copied().unwrap_or(0.0),
                });
            }
            None => vanished_labels.push((label.clone(), ea)),
        }
    }
    let mut new_labels: Vec<(String, f64)> = ledger_b
        .iter()
        .filter(|(label, _)| !ledger_a.contains_key(*label))
        .map(|(label, &(_, eb, _))| (label.clone(), eb))
        .collect();
    if drifted > 0 {
        notes.push(format!(
            "{drifted} label(s) ran different op counts across the sessions (resyncs or \
             tolerant matching): their absolute deltas include the count drift"
        ));
    }
    // rank regressions first (largest ΔJ down), improvements last
    labels.sort_by(|x, y| y.delta_j.total_cmp(&x.delta_j).then_with(|| x.label.cmp(&y.label)));
    new_labels.sort_by(|x, y| y.1.total_cmp(&x.1).then_with(|| x.0.cmp(&y.0)));
    vanished_labels.sort_by(|x, y| y.1.total_cmp(&x.1).then_with(|| x.0.cmp(&y.0)));

    let windows = if digests_match {
        let mut total = WindowAlignment::default();
        for pair in common_window_pairs(a, b) {
            let fps = |s: &SessionInfo| -> Vec<u64> {
                s.replay
                    .windows
                    .iter()
                    .filter(|(p, _)| *p == pair)
                    .map(|(_, w)| w.window_fp)
                    .collect()
            };
            let al = align_windows(&fps(a), &fps(b), cfg.align_lookahead);
            total.aligned += al.aligned;
            total.realigns += al.realigns;
            total.skipped_a += al.skipped_a;
            total.skipped_b += al.skipped_b;
            total.forced += al.forced;
        }
        total
    } else {
        WindowAlignment::default()
    };

    let (wasted_a_j, resyncs_a) = a.aggregated_summary_counters();
    let (wasted_b_j, resyncs_b) = b.aggregated_summary_counters();
    let total_a_j: f64 = ledger_a.values().map(|&(_, ea, _)| ea).sum();
    let total_b_j: f64 = ledger_b.values().map(|&(_, eb, _)| eb).sum();
    Ok(SessionDiff {
        session_a: a.display_name(),
        session_b: b.display_name(),
        verdict,
        notes,
        labels,
        new_labels,
        vanished_labels,
        total_a_j,
        total_b_j,
        wasted_a_j,
        wasted_b_j,
        resyncs_a,
        resyncs_b,
        divergences_a: a.replay.divergences.len(),
        divergences_b: b.replay.divergences.len(),
        windows,
        energy_threshold: cfg.energy_threshold,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fingerprint::WorkloadSig;
    use crate::telemetry::{SinkConfig, SnapshotSink};

    fn sig_of(ops: &[(&str, &str, usize)]) -> WorkloadSig {
        let mut sig = WorkloadSig::new();
        for &(label, op, n) in ops {
            for _ in 0..n {
                sig.add(label, op);
            }
        }
        sig
    }

    fn header(id: &str, scope: &str, ops: &[(&str, &str, usize)]) -> SessionHeader {
        SessionHeader::new(id, "", scope, &sig_of(ops), "steady", 0xc0ffee)
    }

    fn info(id: &str, ops: &[(&str, &str, usize)]) -> SessionInfo {
        SessionInfo {
            dir: PathBuf::from(format!("mem-{id}")),
            headers: vec![header(id, "pair", ops)],
            replay: Replay::default(),
        }
    }

    const BASE: &[(&str, &str, usize)] =
        &[("serve.proj", "matmul", 200), ("serve.act", "gelu", 200), ("serve.out", "matmul", 200)];

    #[test]
    fn exact_match_requires_identical_multisets() {
        let a = info("a", BASE);
        let b = info("b", BASE);
        assert_eq!(match_sessions(&a, &b, MatchMode::Exact), MatchVerdict::Exact);
        // order of scopes is irrelevant: split the same multiset in two
        let mut split = info("c", &[("serve.proj", "matmul", 200)]);
        split.headers.push(header(
            "c",
            "pair2",
            &[("serve.act", "gelu", 200), ("serve.out", "matmul", 200)],
        ));
        assert_eq!(match_sessions(&a, &split, MatchMode::Exact), MatchVerdict::Exact);
        // one extra op breaks exactness with a reasoned diagnostic
        let c = info(
            "d",
            &[
                ("serve.proj", "matmul", 201),
                ("serve.act", "gelu", 200),
                ("serve.out", "matmul", 200),
            ],
        );
        let MatchVerdict::Incomparable { reason } = match_sessions(&a, &c, MatchMode::Exact)
        else {
            panic!("must be incomparable in exact mode");
        };
        assert!(reason.contains("serve.proj"), "{reason}");
        assert!(reason.contains("--tolerant"), "{reason}");
    }

    #[test]
    fn tolerant_match_accepts_partial_overlap_above_threshold() {
        let a = info("a", BASE);
        // 500 of 620 ops shared with `a` (overlap ≈ 0.806)
        let b = info(
            "b",
            &[
                ("serve.proj", "matmul", 200),
                ("serve.act", "gelu", 200),
                ("serve.out", "matmul", 100),
                ("serve.extra", "softmax", 120),
            ],
        );
        let v = match_sessions(&a, &b, MatchMode::Tolerant { min_overlap: 0.8 });
        let MatchVerdict::Tolerant { overlap } = v else {
            panic!("expected tolerant match, got {v:?}");
        };
        assert!((overlap - 500.0 / 620.0).abs() < 1e-12);
        // a higher floor refuses the same pair, naming the overlap
        let v = match_sessions(&a, &b, MatchMode::Tolerant { min_overlap: 0.9 });
        let MatchVerdict::Incomparable { reason } = v else {
            panic!("expected refusal above the floor");
        };
        assert!(reason.contains("80.6%"), "{reason}");
        // disjoint workloads never match tolerantly
        let c = info("c", &[("train.step", "matmul", 600)]);
        assert!(matches!(
            match_sessions(&a, &c, MatchMode::Tolerant { min_overlap: 0.1 }),
            MatchVerdict::Incomparable { .. }
        ));
    }

    #[test]
    fn groups_cluster_matching_sessions() {
        let idx = SessionIndex {
            sessions: vec![
                info("a", BASE),
                info("b", &[("train.step", "matmul", 600)]),
                info("c", BASE),
                info("d", &[("train.step", "matmul", 600)]),
            ],
        };
        let groups = idx.groups(MatchMode::Exact);
        assert_eq!(groups, vec![vec![0, 2], vec![1, 3]]);
    }

    /// The window re-anchoring: one skipped window on either side costs
    /// exactly one skip, and everything after re-aligns.
    #[test]
    fn align_windows_reanchors_after_skips() {
        let a: Vec<u64> = (0..20).collect();
        // b is missing window 7 and has an extra window after 14
        let mut b: Vec<u64> = (0..20).filter(|&x| x != 7).collect();
        b.insert(14, 999);
        let al = align_windows(&a, &b, 8);
        assert_eq!(al.aligned, 19, "all shared windows must align");
        assert_eq!(al.realigns, 2);
        assert_eq!(al.skipped_a, 1); // a's window 7 has no partner
        assert_eq!(al.skipped_b, 1); // b's extra 999
        assert_eq!(al.forced, 0);
        // identical sequences align trivially
        let id = align_windows(&a, &a, 8);
        assert_eq!(id.aligned, 20);
        assert_eq!(id.realigns + id.skipped_a + id.skipped_b + id.forced, 0);
        // disjoint sequences force-advance without panicking
        let c: Vec<u64> = (100..110).collect();
        let disjoint = align_windows(&a[..10], &c, 4);
        assert_eq!(disjoint.aligned, 0);
        assert_eq!(disjoint.forced, 10);
    }

    /// End-to-end on real sinks: two in-memory-built sessions with a
    /// per-label regression diff correctly, ranked regressed-first; the
    /// diff is deterministic; incomparable sessions are refused.
    #[test]
    fn diff_ranks_injected_regression_first() {
        use std::fs;
        let base = std::env::temp_dir()
            .join(format!("magneton-session-unit-{}", std::process::id()));
        let _ = fs::remove_dir_all(&base);
        // build one persisted session: `scale` is the regressed label
        // in session B (1.5x side-A energy), everything else equal
        let build = |dir: &std::path::Path, id: &str, scale_e: f64| {
            use crate::energy::Segment;
            use crate::exec::KernelRecord;
            use crate::graph::OpKind;
            use crate::stream::{StreamAuditor, StreamConfig};
            use crate::trace::Frame;
            let cfg = StreamConfig { window_ops: 10, hop_ops: 10, nvml: None, ..Default::default() };
            let mut aud = StreamAuditor::new(cfg.clone(), 90.0);
            // sink + header attach BEFORE ingestion: windows are
            // persisted at emission time, and the header must lead the
            // series. The static multiset is known upfront here.
            let mut sig = WorkloadSig::new();
            for i in 0..100 {
                let (label, op) = if i % 2 == 0 {
                    ("serve.proj", crate::graph::OpKind::MatMul)
                } else {
                    ("serve.scale", crate::graph::OpKind::Mul)
                };
                sig.add(label, op.name());
            }
            let header = SessionHeader::new(id, "", "pair", &sig, "steady", cfg.digest());
            aud.set_session_header(header);
            aud.set_sink("pair", SnapshotSink::new(dir, "pair", SinkConfig::default()).unwrap());
            for i in 0..100 {
                let (label, op, e) = match i % 2 {
                    0 => ("serve.proj", OpKind::MatMul, 0.30),
                    _ => ("serve.scale", OpKind::Mul, scale_e),
                };
                let rec = |e: f64| KernelRecord {
                    node: 0,
                    op,
                    label: label.to_string(),
                    api: "api".into(),
                    dispatch_key: op.name().to_string(),
                    kernel: "k".into(),
                    time_us: 100.0,
                    energy_j: e,
                    avg_power_w: e / 100e-6,
                    corr_id: 0,
                    bb_trace: vec![],
                    call_path: vec![Frame::py("serve")],
                    moments: vec![],
                };
                let t = i as f64 * 100.0;
                let seg = |e: f64| Segment { t_start_us: t, t_end_us: t + 100.0, watts: e / 100e-6 };
                aud.ingest_a(&rec(e), seg(e));
                // the in-session reference side is always clean
                let e_ref = if i % 2 == 0 { 0.30 } else { 0.02 };
                aud.ingest_b(&rec(e_ref), seg(e_ref));
            }
            aud.finish();
            assert_eq!(aud.sink_errors(), 0);
        };
        let dir_a = base.join("a");
        let dir_b = base.join("b");
        build(&dir_a, "deploy-a", 0.02);
        build(&dir_b, "deploy-b", 0.03); // +50 % on serve.scale
        let a = SessionInfo::load(&dir_a).unwrap();
        let b = SessionInfo::load(&dir_b).unwrap();
        assert_eq!(a.session_id(), "deploy-a");
        let diff = diff_sessions(&a, &b, &DiffConfig::default()).unwrap();
        assert_eq!(diff.verdict, MatchVerdict::Exact);
        assert_eq!(diff.labels.len(), 2);
        assert_eq!(diff.labels[0].label, "serve.scale", "regressed label must rank first");
        assert!(diff.labels[0].delta_j > 0.0);
        assert!((diff.labels[0].delta_frac - 1.0 / 3.0).abs() < 1e-9);
        assert_eq!(diff.labels[1].delta_j, 0.0);
        assert!(diff.regressed(0.05));
        assert!(!diff.regressed(0.50));
        // windows aligned cleanly (same config digest, same workload)
        assert_eq!(diff.windows.aligned, 10);
        assert_eq!(diff.windows.forced, 0);
        // deterministic: a second load + diff produces identical deltas
        let diff2 = diff_sessions(
            &SessionInfo::load(&dir_a).unwrap(),
            &SessionInfo::load(&dir_b).unwrap(),
            &DiffConfig::default(),
        )
        .unwrap();
        assert_eq!(diff.labels[0].delta_j.to_bits(), diff2.labels[0].delta_j.to_bits());
        let _ = fs::remove_dir_all(&base);
    }
}
