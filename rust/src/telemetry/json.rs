//! JSON reader for the telemetry layer (the offline registry has no
//! `serde`, so snapshots are parsed by hand).
//!
//! The *writer* lives in [`crate::util::json`] — this module re-exports
//! its [`Json`] value type and completes the round trip with
//! [`Json::parse`] plus typed accessors. The pairing is escape-correct
//! by construction:
//!
//! * every string the writer escapes (`"`, `\\`, `\n`, `\t`, `\r`, and
//!   `\u` escapes for the remaining control characters) is decoded back
//!   to the identical Rust string, and non-ASCII text written raw reads
//!   back raw;
//! * finite floats are written in Rust's shortest round-trip `Display`
//!   form, so `parse(render(x))` returns the *bit-identical* `f64` —
//!   the property the snapshot replay relies on;
//! * non-finite floats are written as `null` (NaN-free output), so a
//!   parsed snapshot can never smuggle a NaN into a report.
//!
//! The number grammar is a small superset of JSON's (anything
//! `f64::from_str` accepts over the characters `0-9 + - . e E`), which
//! parses everything the writer emits.
//!
//! ```
//! use magneton::telemetry::json::Json;
//!
//! let j = Json::parse(r#"{"pair":"serving-0","wasted_j":0.25,"tags":["a\nb",null,true]}"#)
//!     .unwrap();
//! assert_eq!(j.get("pair").and_then(Json::as_str), Some("serving-0"));
//! assert_eq!(j.get("wasted_j").and_then(Json::as_f64), Some(0.25));
//! // render → parse → render is a fixed point
//! assert_eq!(j.render(), Json::parse(&j.render()).unwrap().render());
//! ```

use std::collections::BTreeMap;

pub use crate::util::json::{Json, JsonObj};

use crate::{Error, Result};

/// Recursive-descent JSON parser over a pre-decoded char buffer (UTF-8
/// handling comes for free from `str::chars`; snapshot lines are small,
/// so the O(n) buffer is irrelevant next to the file IO).
struct Parser {
    chars: Vec<char>,
    pos: usize,
    /// Remaining nesting budget: a corrupt/hostile line of 100k `[`s
    /// must come back as a parse `Err`, not a stack overflow.
    depth: usize,
}

/// Maximum container nesting accepted by [`Json::parse`] — snapshots
/// nest 4 levels; 128 leaves generous headroom while keeping recursion
/// depth (and stack use) bounded on malformed input.
const MAX_DEPTH: usize = 128;

impl Parser {
    fn peek(&self) -> Option<char> {
        self.chars.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<char> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(' ' | '\t' | '\n' | '\r')) {
            self.pos += 1;
        }
    }

    fn err(&self, msg: &str) -> Error {
        Error::msg(format!("json parse error at char {}: {msg}", self.pos))
    }

    fn expect(&mut self, want: char) -> Result<()> {
        match self.bump() {
            Some(c) if c == want => Ok(()),
            Some(c) => Err(self.err(&format!("expected `{want}`, found `{c}`"))),
            None => Err(self.err(&format!("expected `{want}`, found end of input"))),
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some('{') => self.nested(Parser::object),
            Some('[') => self.nested(Parser::array),
            Some('"') => Ok(Json::Str(self.string()?)),
            Some('t') => self.literal("true", Json::Bool(true)),
            Some('f') => self.literal("false", Json::Bool(false)),
            Some('n') => self.literal("null", Json::Null),
            Some(c) if c == '-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(self.err(&format!("unexpected character `{c}`"))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    /// Run a container parser one nesting level down, bounding the
    /// recursion depth.
    fn nested(&mut self, f: fn(&mut Parser) -> Result<Json>) -> Result<Json> {
        if self.depth == 0 {
            return Err(self.err(&format!("nesting deeper than {MAX_DEPTH} levels")));
        }
        self.depth -= 1;
        let v = f(self);
        self.depth += 1;
        v
    }

    fn literal(&mut self, lit: &str, value: Json) -> Result<Json> {
        for want in lit.chars() {
            if self.bump() != Some(want) {
                return Err(self.err(&format!("malformed literal (expected `{lit}`)")));
            }
        }
        Ok(value)
    }

    fn number(&mut self) -> Result<Json> {
        fn is_num_char(c: char) -> bool {
            c.is_ascii_digit() || matches!(c, '-' | '+' | '.' | 'e' | 'E')
        }
        let start = self.pos;
        while matches!(self.peek(), Some(c) if is_num_char(c)) {
            self.pos += 1;
        }
        let text: String = self.chars[start..self.pos].iter().collect();
        match text.parse::<f64>() {
            // overflowing literals (1e999) saturate to ±inf in FromStr;
            // the writer never emits non-finite values, so a corrupt
            // line must be rejected, not smuggled into reports
            Ok(x) if x.is_finite() => Ok(Json::Num(x)),
            Ok(_) => Err(self.err(&format!("non-finite number `{text}`"))),
            Err(e) => Err(self.err(&format!("bad number `{text}`: {e}"))),
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect('"')?;
        let mut out = String::new();
        loop {
            let c = self.bump().ok_or_else(|| self.err("unterminated string"))?;
            match c {
                '"' => return Ok(out),
                '\\' => {
                    let e = self.bump().ok_or_else(|| self.err("unterminated escape"))?;
                    match e {
                        '"' => out.push('"'),
                        '\\' => out.push('\\'),
                        '/' => out.push('/'),
                        'b' => out.push('\u{0008}'),
                        'f' => out.push('\u{000c}'),
                        'n' => out.push('\n'),
                        'r' => out.push('\r'),
                        't' => out.push('\t'),
                        'u' => {
                            let hi = self.hex4()?;
                            let cp = if (0xd800..0xdc00).contains(&hi) {
                                // UTF-16 surrogate pair: the low half
                                // must follow as another \u escape
                                if self.bump() != Some('\\') || self.bump() != Some('u') {
                                    return Err(self.err("high surrogate without a \\u low half"));
                                }
                                let lo = self.hex4()?;
                                if !(0xdc00..0xe000).contains(&lo) {
                                    return Err(self.err("invalid low surrogate"));
                                }
                                0x10000 + ((hi - 0xd800) << 10) + (lo - 0xdc00)
                            } else if (0xdc00..0xe000).contains(&hi) {
                                return Err(self.err("unpaired low surrogate"));
                            } else {
                                hi
                            };
                            let ch = char::from_u32(cp)
                                .ok_or_else(|| self.err("invalid \\u code point"))?;
                            out.push(ch);
                        }
                        other => return Err(self.err(&format!("unknown escape `\\{other}`"))),
                    }
                }
                c => out.push(c),
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = c
                .to_digit(16)
                .ok_or_else(|| self.err(&format!("non-hex digit `{c}` in \\u escape")))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn array(&mut self) -> Result<Json> {
        self.expect('[')?;
        let mut xs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(']') {
            self.pos += 1;
            return Ok(Json::Arr(xs));
        }
        loop {
            xs.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(',') => continue,
                Some(']') => return Ok(Json::Arr(xs)),
                _ => return Err(self.err("expected `,` or `]` in array")),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect('{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some('}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(':')?;
            let v = self.value()?;
            // duplicate keys: last one wins (the writer never emits them)
            m.insert(k, v);
            self.skip_ws();
            match self.bump() {
                Some(',') => continue,
                Some('}') => return Ok(Json::Obj(m)),
                _ => return Err(self.err("expected `,` or `}` in object")),
            }
        }
    }
}

impl Json {
    /// Parse one JSON value from `text` (the whole string must be the
    /// value, modulo surrounding whitespace).
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { chars: text.chars().collect(), pos: 0, depth: MAX_DEPTH };
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.chars.len() {
            return Err(p.err("trailing characters after value"));
        }
        Ok(v)
    }

    /// Field lookup on an object; `None` for other variants.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// Numeric value as an index: non-negative, fraction-free, and
    /// inside f64's exact-integer range.
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 && *x <= 9.0e15 => Some(*x as usize),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s.as_str()),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(xs) => Some(xs.as_slice()),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Prng;

    fn roundtrip(j: &Json) {
        let text = j.render();
        let back = Json::parse(&text).unwrap_or_else(|e| panic!("parse `{text}`: {e}"));
        assert_eq!(&back, j, "round trip changed the value for `{text}`");
        assert_eq!(back.render(), text, "render is not a fixed point for `{text}`");
    }

    #[test]
    fn scalars_round_trip() {
        roundtrip(&Json::Null);
        roundtrip(&Json::Bool(true));
        roundtrip(&Json::Bool(false));
        roundtrip(&Json::Num(0.0));
        roundtrip(&Json::Num(42.0));
        roundtrip(&Json::Num(-17.0));
        roundtrip(&Json::Num(0.1));
        roundtrip(&Json::Str(String::new()));
        roundtrip(&Json::Str("plain".into()));
    }

    /// Floats must round-trip bit-for-bit: shortest `Display` form out,
    /// `from_str` back — including negative zero, subnormals, huge
    /// magnitudes, and ugly fractions.
    #[test]
    fn floats_round_trip_bit_for_bit() {
        let cases = [
            0.0,
            -0.0,
            1.0 / 3.0,
            0.1 + 0.2,
            1e-300,
            -1e300,
            f64::MIN_POSITIVE,
            f64::MAX,
            5e-324, // smallest subnormal
            1e15,   // the writer's integer-shortcut boundary
            1e15 - 1.0,
            -(1e15 - 1.0),
            2.0f64.powi(53),
            437.25,
        ];
        for x in cases {
            let text = Json::Num(x).render();
            let back = Json::parse(&text).unwrap();
            let y = back.as_f64().unwrap();
            assert_eq!(y.to_bits(), x.to_bits(), "{x} → `{text}` → {y}");
        }
    }

    /// Non-finite floats are written as `null` (never `NaN`/`inf`
    /// tokens), so parsed snapshots are NaN-free by construction.
    #[test]
    fn non_finite_renders_null_and_parses_as_null() {
        for x in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            let text = Json::Num(x).render();
            assert_eq!(text, "null");
            assert_eq!(Json::parse(&text).unwrap(), Json::Null);
        }
    }

    #[test]
    fn pathological_strings_round_trip() {
        let cases = [
            "quote \" backslash \\ slash /".to_string(),
            "newline \n tab \t return \r".to_string(),
            "control \u{0000} \u{0001} \u{0008} \u{000c} \u{001f}".to_string(),
            "non-ascii: caffè, 東京, Ωμέγα".to_string(),
            "emoji beyond the BMP: 🦀🔋".to_string(),
            "line sep \u{2028} para sep \u{2029}".to_string(),
            "\\u0041 is not an escape once escaped".to_string(),
            "trailing backslash \\".to_string(),
        ];
        for s in cases {
            roundtrip(&Json::Str(s));
        }
    }

    #[test]
    fn escape_sequences_decode() {
        let j = Json::parse(r#""Aé\n\t\"\\\/\b\f\r""#).unwrap();
        assert_eq!(j.as_str(), Some("Aé\n\t\"\\/\u{0008}\u{000c}\r"));
        // surrogate pair → one astral code point
        let j = Json::parse(r#""🦀""#).unwrap();
        assert_eq!(j.as_str(), Some("🦀"));
    }

    #[test]
    fn malformed_inputs_are_rejected() {
        for text in [
            "",
            "{",
            "[1,",
            "[1 2]",
            r#"{"a" 1}"#,
            r#"{"a":1,}"#, // trailing comma (writer never emits one)
            "tru",
            "nul",
            "1e",
            "--1",
            "1e999",  // overflows to inf — non-finite must not parse
            "-1e999",
            "\"unterminated",
            r#""bad \q escape""#,
            r#""\ud800 lone high""#,
            r#""\udc00 lone low""#,
            r#""\u12""#,
            "1 2",     // trailing content
            "[1] []",  // trailing content
        ] {
            assert!(Json::parse(text).is_err(), "`{text}` should not parse");
        }
    }

    /// A hostile/corrupt line of deeply nested containers must come
    /// back as a parse error, never a stack overflow.
    #[test]
    fn pathological_nesting_is_rejected_not_overflowed() {
        let deep_arr = "[".repeat(100_000);
        assert!(Json::parse(&deep_arr).is_err());
        let deep_obj = "{\"k\":".repeat(100_000);
        assert!(Json::parse(&deep_obj).is_err());
        // nesting at the limit still parses
        let ok = format!("{}1{}", "[".repeat(MAX_DEPTH), "]".repeat(MAX_DEPTH));
        assert!(Json::parse(&ok).is_ok());
        let too_deep = format!("{}1{}", "[".repeat(MAX_DEPTH + 1), "]".repeat(MAX_DEPTH + 1));
        assert!(Json::parse(&too_deep).is_err());
    }

    #[test]
    fn whitespace_is_tolerated() {
        let j = Json::parse(" {\n\t\"a\" : [ 1 , 2 ] ,\r\n \"b\" : null } ").unwrap();
        assert_eq!(j.get("a").and_then(Json::as_arr).map(<[Json]>::len), Some(2));
        assert_eq!(j.get("b"), Some(&Json::Null));
    }

    #[test]
    fn accessors_are_typed() {
        let j = Json::parse(r#"{"n":3,"x":1.5,"s":"hi","b":false,"xs":[1],"neg":-1}"#).unwrap();
        assert_eq!(j.get("n").and_then(Json::as_usize), Some(3));
        assert_eq!(j.get("x").and_then(Json::as_usize), None, "fractional is not an index");
        assert_eq!(j.get("neg").and_then(Json::as_usize), None, "negative is not an index");
        assert_eq!(j.get("x").and_then(Json::as_f64), Some(1.5));
        assert_eq!(j.get("s").and_then(Json::as_str), Some("hi"));
        assert_eq!(j.get("b").and_then(Json::as_bool), Some(false));
        assert_eq!(j.get("xs").and_then(Json::as_arr).map(<[Json]>::len), Some(1));
        assert_eq!(j.get("missing"), None);
        assert_eq!(Json::Null.get("n"), None);
    }

    /// Property: randomly generated values (nested, with pathological
    /// strings and floats) survive render → parse → render unchanged.
    #[test]
    fn prop_random_values_round_trip() {
        let mut rng = Prng::new(0x7e1e);
        for _ in 0..200 {
            let j = gen_json(&mut rng, 3);
            roundtrip(&j);
        }
    }

    fn gen_string(rng: &mut Prng) -> String {
        let alphabet: Vec<char> =
            "ab\"\\\n\t\r\u{0}\u{1f}é東🦀 /".chars().collect();
        (0..rng.below(12)).map(|_| *rng.choose(&alphabet)).collect()
    }

    fn gen_f64(rng: &mut Prng) -> f64 {
        match rng.below(4) {
            0 => rng.below(2000) as f64 - 1000.0,
            1 => rng.normal() * 1e-6,
            2 => rng.normal() * 1e12,
            _ => rng.f64(),
        }
    }

    fn gen_json(rng: &mut Prng, depth: usize) -> Json {
        let top = if depth == 0 { 4 } else { 6 };
        match rng.below(top) {
            0 => Json::Null,
            1 => Json::Bool(rng.below(2) == 0),
            2 => Json::Num(gen_f64(rng)),
            3 => Json::Str(gen_string(rng)),
            4 => Json::Arr((0..rng.below(4)).map(|_| gen_json(rng, depth - 1)).collect()),
            _ => {
                let mut obj = Json::obj();
                for _ in 0..rng.below(4) {
                    obj = obj.field(&gen_string(rng), gen_json(rng, depth - 1));
                }
                obj.build()
            }
        }
    }
}
